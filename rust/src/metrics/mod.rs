//! Quality metrics used by the examples and the benchmark harness to
//! sanity-check that optimized and baseline backends compute the *same
//! model* (the paper stresses bitwise/statistical fidelity of the SVE
//! paths against the scalar ones).

/// Classification accuracy.
pub fn accuracy(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred
        .iter()
        .zip(truth)
        .filter(|(p, t)| (p.round() - t.round()).abs() < 0.5)
        .count();
    hits as f64 / pred.len() as f64
}

/// Binary confusion counts `(tp, fp, tn, fn)` with threshold 0.5.
pub fn confusion(pred: &[f64], truth: &[f64]) -> (usize, usize, usize, usize) {
    assert_eq!(pred.len(), truth.len());
    let (mut tp, mut fp, mut tn, mut fnn) = (0, 0, 0, 0);
    for (&p, &t) in pred.iter().zip(truth) {
        match (p >= 0.5, t >= 0.5) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, false) => tn += 1,
            (false, true) => fnn += 1,
        }
    }
    (tp, fp, tn, fnn)
}

/// Precision, recall and F1 for the positive class.
pub fn precision_recall_f1(pred: &[f64], truth: &[f64]) -> (f64, f64, f64) {
    let (tp, fp, _tn, fnn) = confusion(pred, truth);
    let precision = if tp + fp > 0 { tp as f64 / (tp + fp) as f64 } else { 0.0 };
    let recall = if tp + fnn > 0 { tp as f64 / (tp + fnn) as f64 } else { 0.0 };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    (precision, recall, f1)
}

/// Mean squared error.
pub fn mse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter().zip(truth).map(|(p, t)| (p - t) * (p - t)).sum::<f64>() / pred.len() as f64
}

/// Coefficient of determination R².
pub fn r2(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mean = truth.iter().sum::<f64>() / truth.len() as f64;
    let ss_tot: f64 = truth.iter().map(|t| (t - mean) * (t - mean)).sum();
    let ss_res: f64 = pred.iter().zip(truth).map(|(p, t)| (p - t) * (p - t)).sum();
    if ss_tot == 0.0 {
        return 0.0;
    }
    1.0 - ss_res / ss_tot
}

/// KMeans inertia: sum of squared distances to the assigned centroid.
pub fn inertia(
    x: &crate::tables::DenseTable<f64>,
    centroids: &crate::tables::DenseTable<f64>,
    assign: &[usize],
) -> f64 {
    (0..x.rows())
        .map(|i| crate::blas::sqdist(x.row(i), centroids.row(assign[i])))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1.0, 0.0, 1.0, 1.0], &[1.0, 0.0, 0.0, 1.0]), 0.75);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }

    #[test]
    fn confusion_counts() {
        let pred = [1.0, 1.0, 0.0, 0.0, 1.0];
        let truth = [1.0, 0.0, 0.0, 1.0, 1.0];
        assert_eq!(confusion(&pred, &truth), (2, 1, 1, 1));
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        let (p, r, f) = precision_recall_f1(&[1.0, 0.0], &[1.0, 0.0]);
        assert_eq!((p, r, f), (1.0, 1.0, 1.0));
        let (_, _, f0) = precision_recall_f1(&[0.0, 0.0], &[1.0, 1.0]);
        assert_eq!(f0, 0.0);
    }

    #[test]
    fn mse_and_r2() {
        let truth = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mse(&truth, &truth), 0.0);
        assert!((r2(&truth, &truth) - 1.0).abs() < 1e-12);
        let mean = [2.5; 4];
        assert!(r2(&mean, &truth).abs() < 1e-12); // predicting the mean → R²=0
    }
}
