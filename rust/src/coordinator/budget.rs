//! Deadline budgets with graceful degradation.
//!
//! A [`Budget`] caps a training call by wall-time and/or outer-iteration
//! count. Iterative solvers (Lloyd rounds, logreg epochs, SVM
//! generations, Jacobi sweeps) consume it through a per-call
//! [`BudgetMeter`], checked **only at outer-iteration boundaries** — the
//! points where the solver state is a complete, usable model — so on
//! expiry training returns the best-so-far model tagged with a
//! [`ConvergenceStatus`] instead of erroring. The iteration cap is
//! fully deterministic; the wall-time cap is deterministic in *where*
//! it can cut (only between iterations), though *when* it trips depends
//! on the machine. An unlimited budget (the default) costs nothing on
//! the hot path: no clock is read unless a deadline is set.
//!
//! The serving layer reuses the same meter at a finer grain: a
//! [`ServeRequest`](super::serve::ServeRequest) budget is checked once
//! at super-batch entry and once per execution **tile**, so one huge
//! super-batch cannot blow a deadline unobserved — there, a budget
//! "iteration" is a tile checkpoint. The resilience layer routes its
//! backoff and breaker-cooldown time through [`Budget`] too
//! ([`Budget::spin`], `coordinator/resilience.rs`), which is what
//! keeps this file the **only** library code that reads the clock
//! (PAL-CLOCK, `docs/INVARIANTS.md`).

use std::time::{Duration, Instant};

/// How a budgeted training run ended — carried on every iterative
/// model (`KMeansModel`, `LogRegModel`, `SvcModel`, `PcaModel`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConvergenceStatus {
    /// The solver met its own convergence criterion.
    Converged,
    /// The solver's `max_iter` (or the budget's iteration cap) ran out
    /// before convergence; the model is the last completed iterate.
    IterLimit,
    /// The budget's wall-time deadline expired; the model is the last
    /// iterate completed before the deadline.
    DeadlineExceeded,
}

/// Resource budget for one training call, carried on the
/// [`super::Context`]. Default: unlimited (checks compile to a pair of
/// `None` tests — uncapped runs are bit-identical to pre-budget
/// behavior).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Budget {
    /// Maximum wall-time for the whole call.
    pub max_wall_time: Option<Duration>,
    /// Maximum outer iterations (Lloyd rounds, epochs, generations,
    /// sweeps) across the call.
    pub max_iters: Option<usize>,
}

impl Budget {
    /// Unlimited budget (the default).
    pub const UNLIMITED: Budget = Budget { max_wall_time: None, max_iters: None };

    pub fn max_wall_time(mut self, d: Duration) -> Self {
        self.max_wall_time = Some(d);
        self
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = Some(n);
        self
    }

    pub fn is_unlimited(&self) -> bool {
        self.max_wall_time.is_none() && self.max_iters.is_none()
    }

    /// Block the calling thread until this budget expires — the
    /// resilience layer's backoff/cooldown-wait primitive
    /// (`coordinator/resilience.rs` never reads the clock itself;
    /// PAL-CLOCK). An iteration-cap budget spins its cap deterministic
    /// and clock-free (`n` yields); a wall-time budget parks in a
    /// yield loop until the deadline passes. The **unlimited** budget
    /// returns immediately: "no backoff configured" must wait zero
    /// time, not forever.
    pub fn spin(&self) {
        if self.is_unlimited() {
            return;
        }
        let mut m = self.meter();
        while m.check_before_iter().is_none() {
            std::thread::yield_now();
        }
    }

    /// Start metering one training call against this budget.
    pub fn meter(&self) -> BudgetMeter {
        BudgetMeter {
            // The clock is read once here and once per outer iteration —
            // and only when a deadline is actually set.
            deadline: self.max_wall_time.map(|d| Instant::now() + d),
            max_iters: self.max_iters,
            done: 0,
        }
    }
}

/// Per-call consumption state of a [`Budget`]. One meter per training
/// call; solvers call [`BudgetMeter::check_before_iter`] at the top of
/// each outer iteration.
#[derive(Clone, Debug)]
pub struct BudgetMeter {
    deadline: Option<Instant>,
    max_iters: Option<usize>,
    done: usize,
}

impl BudgetMeter {
    /// A meter that never expires (for internal callers without a
    /// context).
    pub fn unlimited() -> Self {
        Budget::UNLIMITED.meter()
    }

    /// Outer iterations completed so far.
    pub fn iters_done(&self) -> usize {
        self.done
    }

    /// Call at the top of each outer iteration: `None` ⇒ proceed (and
    /// the iteration is counted); `Some(status)` ⇒ stop now and tag the
    /// best-so-far model with `status`. The iteration cap is checked
    /// before the deadline so an `IterLimit` verdict is deterministic
    /// even when both are exceeded.
    pub fn check_before_iter(&mut self) -> Option<ConvergenceStatus> {
        if let Some(cap) = self.max_iters {
            if self.done >= cap {
                return Some(ConvergenceStatus::IterLimit);
            }
        }
        if let Some(deadline) = self.deadline {
            if Instant::now() >= deadline {
                return Some(ConvergenceStatus::DeadlineExceeded);
            }
        }
        self.done += 1;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unlimited_never_expires() {
        let mut m = BudgetMeter::unlimited();
        for _ in 0..10_000 {
            assert_eq!(m.check_before_iter(), None);
        }
        assert_eq!(m.iters_done(), 10_000);
        assert!(Budget::default().is_unlimited());
    }

    #[test]
    fn iter_cap_trips_deterministically() {
        let mut m = Budget::default().max_iters(3).meter();
        assert_eq!(m.check_before_iter(), None);
        assert_eq!(m.check_before_iter(), None);
        assert_eq!(m.check_before_iter(), None);
        assert_eq!(m.check_before_iter(), Some(ConvergenceStatus::IterLimit));
        // Expired meters stay expired.
        assert_eq!(m.check_before_iter(), Some(ConvergenceStatus::IterLimit));
        assert_eq!(m.iters_done(), 3);
    }

    #[test]
    fn elapsed_deadline_trips() {
        let mut m = Budget::default().max_wall_time(Duration::ZERO).meter();
        assert_eq!(m.check_before_iter(), Some(ConvergenceStatus::DeadlineExceeded));
    }

    #[test]
    fn iter_cap_wins_over_deadline() {
        let mut m =
            Budget::default().max_wall_time(Duration::ZERO).max_iters(0).meter();
        assert_eq!(m.check_before_iter(), Some(ConvergenceStatus::IterLimit));
    }

    #[test]
    fn spin_terminates_and_unlimited_spin_is_instant() {
        // Unlimited: must return immediately (a hang here would mean
        // "no backoff" waits forever).
        Budget::UNLIMITED.spin();
        // Iteration cap: deterministic, clock-free termination.
        Budget::default().max_iters(64).spin();
        // Wall-time: terminates once the deadline passes.
        Budget::default().max_wall_time(Duration::from_millis(1)).spin();
    }

    #[test]
    fn generous_deadline_allows_iterations() {
        let mut m = Budget::default().max_wall_time(Duration::from_secs(3600)).meter();
        for _ in 0..100 {
            assert_eq!(m.check_before_iter(), None);
        }
    }
}
