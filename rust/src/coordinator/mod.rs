//! Execution coordinator — the reproduction of the paper's ARM
//! enablement layer (§IV-A): the dynamic CPU-dispatch mechanism, the
//! per-algorithm backend ladder, and the fixed-shape batching that feeds
//! the AOT artifacts.
//!
//! The paper's dispatch selects NEON vs SVE code paths from CPU
//! capabilities at runtime; here the ladder is
//!
//! ```text
//!   Naive  <  Reference  <  Vectorized  <  Artifact
//! ```
//!
//! * **Naive** — branchy, allocation-heavy scalar code: the "stock
//!   scikit-learn on ARM" baseline of Fig. 5.
//! * **Reference** — the native blocked-BLAS backend: the "x86 oneDAL
//!   with MKL" stand-in of Fig. 6.
//! * **Vectorized** — branch-free, unit-stride, multi-accumulator
//!   kernels (the SVE-style rewrites of §IV-E) — this is the paper's
//!   contribution rung.
//! * **Artifact** — the AOT-compiled XLA/Pallas path executed via PJRT.
//!
//! `Backend::Auto` resolves at context build time from artifact
//! availability and the `ONEDAL_SVE_BACKEND` environment override,
//! mirroring oneDAL's `daal::services::Environment::getCpuId` probe.
//! The same variable also carries the **lane-profile** override
//! (`sve128`/`sve256`/`sve512`, comma-separable with a rung token):
//! profile tokens are consumed by [`crate::primitives::lanes`] — the
//! single approved read site — and only the remaining tokens reach
//! [`Backend::parse`] here. The resolved [`LaneProfile`] rides on the
//! [`Context`] and is what every kernel's geometry derives from.
//!
//! On top of dispatch and batching sits the serving layer
//! ([`serve`]): an [`InferenceSession`] coalesces many small query
//! batches into tile-aligned super-batches, scores them through the
//! fitted models' pack-free panel entry points, and demuxes results in
//! submission order under per-request [`Budget`]s. The resilient front
//! end ([`resilience`], [`serve::QueuedSession`]) adds admission
//! control over a bounded queue, deterministic retry of quarantined
//! faults, a per-model circuit breaker, and the graceful-degradation
//! rung ladder (`docs/RESILIENCE.md`).

pub mod batch;
pub mod budget;
pub mod resilience;
pub mod serve;

pub use batch::{pad_to, PaddedBatch};
pub use budget::{Budget, BudgetMeter, ConvergenceStatus};
pub use resilience::{
    BreakerPolicy, BreakerSnapshot, ResilienceStats, ResilientSession, RetryPolicy,
};
pub use serve::{
    InferenceSession, QueueStats, QueuedSession, ServeExecutor, ServeModel, ServeRequest,
    ServeResult, ServeRung, ServeStatus,
};

use crate::error::{Error, Result};
use crate::primitives::lanes::{self, LaneProfile};
use crate::runtime::{ArtifactRegistry, PjRtRuntime};
use std::sync::Arc;

/// Backend rungs (see module docs). Ordering is the dispatch preference.
#[derive(Clone, Copy, PartialEq, Eq, Debug, PartialOrd, Ord)]
pub enum Backend {
    Naive,
    Reference,
    Vectorized,
    Artifact,
    /// Resolve at `Context::build` time.
    Auto,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Ok(Backend::Naive),
            "reference" => Ok(Backend::Reference),
            "vectorized" => Ok(Backend::Vectorized),
            "artifact" => Ok(Backend::Artifact),
            "auto" => Ok(Backend::Auto),
            other => Err(Error::Param(format!("unknown backend {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Naive => "naive",
            Backend::Reference => "reference",
            Backend::Vectorized => "vectorized",
            Backend::Artifact => "artifact",
            Backend::Auto => "auto",
        }
    }
}

/// Shared execution context handed to every `train`/`infer` call —
/// oneDAL's environment + execution-context object rolled into one.
pub struct Context {
    backend: Backend,
    runtime: Option<Arc<PjRtRuntime>>,
    registry: ArtifactRegistry,
    threads: usize,
    budget: Budget,
    lane_profile: LaneProfile,
}

/// Builder for [`Context`].
pub struct ContextBuilder {
    backend: Backend,
    artifact_dir: String,
    threads: usize,
    budget: Budget,
    lane_profile: Option<LaneProfile>,
}

impl Default for ContextBuilder {
    fn default() -> Self {
        Self {
            backend: Backend::Auto,
            artifact_dir: "artifacts".into(),
            threads: crate::parallel::default_threads(),
            budget: Budget::UNLIMITED,
            lane_profile: None,
        }
    }
}

impl ContextBuilder {
    pub fn backend(mut self, b: Backend) -> Self {
        self.backend = b;
        self
    }

    pub fn artifact_dir<S: Into<String>>(mut self, dir: S) -> Self {
        self.artifact_dir = dir.into();
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Cap training calls made with this context by wall-time and/or
    /// outer-iteration count (see [`Budget`]). Default: unlimited.
    pub fn budget(mut self, b: Budget) -> Self {
        self.budget = b;
        self
    }

    /// Pin the SVE lane profile for this context, overriding the
    /// process default (`ONEDAL_SVE_BACKEND` profile token, else
    /// sve512). Cross-profile tests build contexts through this instead
    /// of mutating process state.
    pub fn lane_profile(mut self, p: LaneProfile) -> Self {
        self.lane_profile = Some(p);
        self
    }

    /// Resolve the dispatch ladder and (for the artifact rung) create the
    /// PJRT runtime.
    pub fn build(self) -> Result<Context> {
        // Environment override — the "disable SVE" switch of the paper's
        // conditional-compilation story, but at runtime. The profile
        // tokens of `ONEDAL_SVE_BACKEND` were consumed by the lanes
        // probe (the one approved env read); only the leftover rung
        // token, if any, is parsed here.
        let mut requested = self.backend;
        if let Some(env) = lanes::env_backend_request() {
            requested = Backend::parse(&env)?;
        }
        let lane_profile = self.lane_profile.unwrap_or_else(lanes::default_profile);
        let registry = ArtifactRegistry::load(&self.artifact_dir);
        let resolved = match requested {
            Backend::Auto => {
                if !registry.is_empty() {
                    Backend::Artifact
                } else {
                    Backend::Vectorized
                }
            }
            b => b,
        };
        let runtime = if resolved == Backend::Artifact {
            match PjRtRuntime::new(&self.artifact_dir) {
                Ok(rt) => Some(Arc::new(rt)),
                Err(e) => {
                    if requested == Backend::Artifact {
                        // Explicit request must not silently degrade.
                        return Err(e);
                    }
                    None
                }
            }
        } else {
            None
        };
        let resolved = if runtime.is_none() && resolved == Backend::Artifact {
            Backend::Vectorized
        } else {
            resolved
        };
        Ok(Context {
            backend: resolved,
            runtime,
            registry,
            threads: self.threads,
            budget: self.budget,
            lane_profile,
        })
    }
}

impl Context {
    pub fn builder() -> ContextBuilder {
        ContextBuilder::default()
    }

    /// A context pinned to a specific rung (used by the benches to sweep
    /// the ladder).
    pub fn with_backend(b: Backend) -> Result<Self> {
        Self::builder().backend(b).build()
    }

    /// The resolved backend (never `Auto`).
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Worker count for this context — the value the algorithm layer
    /// routes into the `*_threads` BLAS/VSL/sparse entry points (the
    /// oneDAL `threader_for` fan-out of the paper's multicore story).
    /// Defaults to [`crate::parallel::default_threads`]
    /// (`ONEDAL_SVE_THREADS` override, else available parallelism).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The training budget carried by this context (default unlimited).
    /// Iterative trainers draw a fresh [`BudgetMeter`] per call.
    pub fn budget(&self) -> Budget {
        self.budget
    }

    /// The SVE lane profile every kernel reached through this context
    /// runs at (lane widths, `MR×NR`/`KC` panel geometry, epilogue tile
    /// rows all derive from it). Resolved once at build time: builder
    /// override, else the process default
    /// ([`crate::primitives::lanes::default_profile`]).
    pub fn lane_profile(&self) -> LaneProfile {
        self.lane_profile
    }

    /// PJRT runtime, present only on the artifact rung.
    pub fn runtime(&self) -> Option<&PjRtRuntime> {
        self.runtime.as_deref()
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Effective rung for a kernel needing `dims`: artifact if a variant
    /// fits *and* the runtime is up, else the vectorized rung — the
    /// per-call dispatch the paper performs per algorithm kernel.
    pub fn dispatch(&self, kernel: &str, dims: &[usize]) -> Backend {
        if self.backend == Backend::Artifact {
            if self.runtime.is_some() && self.registry.best_fit(kernel, dims).is_some() {
                return Backend::Artifact;
            }
            return Backend::Vectorized;
        }
        self.backend
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_parse_round_trip() {
        for b in [Backend::Naive, Backend::Reference, Backend::Vectorized, Backend::Artifact] {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        assert!(Backend::parse("sve").is_err());
    }

    #[test]
    fn explicit_rungs_resolve_as_requested() {
        for b in [Backend::Naive, Backend::Reference, Backend::Vectorized] {
            let ctx = Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap();
            assert_eq!(ctx.backend(), b);
            assert!(ctx.runtime().is_none());
        }
    }

    #[test]
    fn auto_without_artifacts_is_vectorized() {
        let ctx = Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Auto)
            .build()
            .unwrap();
        assert_eq!(ctx.backend(), Backend::Vectorized);
    }

    #[test]
    fn dispatch_falls_back_for_unknown_kernel() {
        let ctx = Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .build()
            .unwrap();
        assert_eq!(ctx.dispatch("kmeans_assign", &[100, 10, 5]), Backend::Vectorized);
    }

    #[test]
    fn lane_profile_defaults_and_overrides() {
        // No builder override → the process default (sve512 unless the
        // environment said otherwise before first resolution).
        let ctx =
            Context::builder().artifact_dir("/nonexistent").backend(Backend::Naive).build().unwrap();
        assert_eq!(ctx.lane_profile(), lanes::default_profile());
        // Explicit override wins without touching process state.
        for p in LaneProfile::ALL {
            let ctx = Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Naive)
                .lane_profile(p)
                .build()
                .unwrap();
            assert_eq!(ctx.lane_profile(), p);
            assert_eq!(lanes::default_profile(), lanes::default_profile());
        }
    }

    #[test]
    fn threads_clamped_to_one() {
        let ctx = Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Naive)
            .threads(0)
            .build()
            .unwrap();
        assert_eq!(ctx.threads(), 1);
    }
}
