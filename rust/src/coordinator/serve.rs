//! Batched inference serving: many small concurrent query batches,
//! coalesced into TILE-aligned super-batches, scored through a fitted
//! model's pack-free entry points, and demuxed back in submission
//! order.
//!
//! ## Session lifecycle
//!
//! 1. Train a model; its corpus is packed **once** into the
//!    model-resident [`crate::primitives::packed::ModelPanel`].
//! 2. Wrap it in an [`InferenceSession`] (borrows the model).
//! 3. Submit a slice of [`ServeRequest`]s — each a small dense query
//!    batch with an optional per-request [`Budget`].
//! 4. [`InferenceSession::serve`] coalesces them into super-batches
//!    ([`InferenceSession::plan`]), pads each to a multiple of the
//!    session tile with zero rows (the [`super::batch`] pad-and-mask
//!    idiom), runs each super-batch through
//!    [`ServeModel::serve_batch`] under the `serve.batch` panic
//!    quarantine, and returns one [`ServeResult`] per request, in
//!    submission order.
//!
//! ## Determinism rules
//!
//! * **Input-keyed coalescing**: super-batch cuts depend only on the
//!   request sequence (row counts and dims) and the session's
//!   `max_super_rows` — never on timing, worker count, or budget
//!   state. The same request set always produces the same cuts.
//! * **Fixed-order demux**: each request's output is the fixed row
//!   range it occupies in its super-batch, so results demux in
//!   submission order regardless of the order super-batches complete
//!   ([`InferenceSession::serve_in_order`] executes them under an
//!   arbitrary permutation to prove it).
//! * **Row independence**: every served model scores rows
//!   independently (the engine's per-row contract), so a request's
//!   output bits do not depend on which neighbors shared its
//!   super-batch or on the zero padding rows — coalesced serving is
//!   bit-identical to sequential per-request calls at any worker
//!   count.
//!
//! ## Typed outcomes
//!
//! Each request's budget is metered from submission; a request whose
//! budget has expired by the time its super-batch executes gets a
//! [`ServeStatus::DeadlineExceeded`] outcome — its neighbors in the
//! same super-batch still complete, bit-identical to an all-unlimited
//! run. A panic or error inside a super-batch (see
//! [`crate::failpoint::SITE_SERVE_BATCH`]) is quarantined into
//! [`ServeStatus::Failed`] for that batch's live members only; other
//! super-batches are untouched and a retry runs clean.

use super::batch;
use super::budget::Budget;
use super::Context;
use crate::error::{Error, Result};
use crate::failpoint;
use crate::parallel;
use crate::tables::DenseTable;

/// Default super-batch row alignment — the fused distance engine's
/// query M-tile, so one padded super-batch fills whole engine tiles.
const DEFAULT_TILE: usize = 256;
/// Default cap on rows per coalesced super-batch.
const DEFAULT_MAX_SUPER_ROWS: usize = 1024;

/// A fitted model the serving layer can drive. Implementations route
/// through their quarantined, pack-free inference entry points (the
/// model-resident panel), and score rows independently — the property
/// the coalescing determinism contract rests on.
pub trait ServeModel {
    /// Feature dimension every query row must have.
    fn serve_dims(&self) -> usize;

    /// Output values per query row (all current models emit one).
    fn serve_width(&self) -> usize {
        1
    }

    /// Score one dense batch: `rows × serve_width()` values, row-major.
    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>>;
}

/// One client query batch: a small dense `rows × cols` block plus an
/// optional per-request [`Budget`] (deadline metered from submission).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
    budget: Budget,
}

impl ServeRequest {
    /// Validate shape up front so malformed requests are rejected at
    /// submission, not mid-super-batch.
    pub fn new(data: Vec<f64>, rows: usize, cols: usize) -> Result<Self> {
        if rows == 0 || cols == 0 || data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "serve: request buffer len {} != rows {rows} × cols {cols}",
                data.len()
            )));
        }
        Ok(Self { data, rows, cols, budget: Budget::UNLIMITED })
    }

    /// Attach a per-request budget. The deadline is metered from the
    /// moment the request set enters [`InferenceSession::serve`].
    pub fn with_budget(mut self, b: Budget) -> Self {
        self.budget = b;
        self
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }
}

/// How one request ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStatus {
    /// Scored; `output` holds `rows × serve_width()` values.
    Completed,
    /// The request's budget expired before its super-batch ran (the
    /// single scoring pass counts as one budget iteration, so an
    /// iteration cap of zero also lands here). No output.
    DeadlineExceeded,
    /// Shape mismatch at planning time, or a quarantined panic/error
    /// while this request's super-batch executed. No output.
    Failed,
}

/// Per-request outcome, returned in submission order.
#[derive(Clone, Debug)]
pub struct ServeResult {
    pub status: ServeStatus,
    /// `rows × serve_width()` values for [`ServeStatus::Completed`];
    /// `None` otherwise. Padded-tail rows are never included.
    pub output: Option<Vec<f64>>,
    /// Human-readable cause for [`ServeStatus::Failed`].
    pub error: Option<String>,
}

impl ServeResult {
    fn completed(output: Vec<f64>) -> Self {
        Self { status: ServeStatus::Completed, output: Some(output), error: None }
    }

    fn deadline() -> Self {
        Self { status: ServeStatus::DeadlineExceeded, output: None, error: None }
    }

    fn failed(msg: String) -> Self {
        Self { status: ServeStatus::Failed, output: None, error: Some(msg) }
    }

    pub fn is_completed(&self) -> bool {
        self.status == ServeStatus::Completed
    }
}

/// A serving session over one fitted model. Cheap to construct (borrows
/// the model; the expensive pack already happened at `train` time).
pub struct InferenceSession<'m, M: ServeModel> {
    model: &'m M,
    tile: usize,
    max_super_rows: usize,
}

impl<'m, M: ServeModel> InferenceSession<'m, M> {
    pub fn new(model: &'m M) -> Self {
        Self { model, tile: DEFAULT_TILE, max_super_rows: DEFAULT_MAX_SUPER_ROWS }
    }

    /// Super-batch row alignment (rows are zero-padded up to a multiple
    /// of this).
    pub fn tile(mut self, tile: usize) -> Self {
        assert!(tile > 0, "serve: tile must be positive");
        self.tile = tile;
        self
    }

    /// Cap on (unpadded) rows per coalesced super-batch.
    pub fn max_super_rows(mut self, rows: usize) -> Self {
        assert!(rows > 0, "serve: max_super_rows must be positive");
        self.max_super_rows = rows;
        self
    }

    /// Input-keyed coalescing plan: greedy contiguous grouping of the
    /// well-shaped requests (submission order preserved), cutting a new
    /// super-batch when the next request would push the current one
    /// past `max_super_rows`. A single oversized request still forms
    /// its own super-batch. Mis-shaped requests join no group — they
    /// fail without executing.
    pub fn plan(&self, requests: &[ServeRequest]) -> Vec<Vec<usize>> {
        let dims = self.model.serve_dims();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut cur_rows = 0usize;
        for (i, r) in requests.iter().enumerate() {
            if r.cols != dims {
                continue;
            }
            if !cur.is_empty() && cur_rows + r.rows > self.max_super_rows {
                groups.push(std::mem::take(&mut cur));
                cur_rows = 0;
            }
            cur.push(i);
            cur_rows += r.rows;
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
        groups
    }

    /// Serve a request set: plan, execute every super-batch in
    /// ascending order, demux. One [`ServeResult`] per request, in
    /// submission order.
    pub fn serve(&self, ctx: &Context, requests: &[ServeRequest]) -> Vec<ServeResult> {
        let order: Vec<usize> = (0..self.plan(requests).len()).collect();
        self.serve_in_order(ctx, requests, &order)
    }

    /// [`InferenceSession::serve`] with an explicit super-batch
    /// execution permutation — the shuffled-completion harness. Each
    /// request's output is the fixed row range it occupies in its
    /// super-batch, so any permutation yields bit-identical results;
    /// `tests/serve_property.rs` asserts it.
    ///
    /// # Panics
    ///
    /// If `exec_order` is not a permutation of
    /// `0..self.plan(requests).len()`.
    pub fn serve_in_order(
        &self,
        ctx: &Context,
        requests: &[ServeRequest],
        exec_order: &[usize],
    ) -> Vec<ServeResult> {
        let dims = self.model.serve_dims();
        let width = self.model.serve_width();
        let groups = self.plan(requests);
        assert_eq!(
            exec_order.len(),
            groups.len(),
            "serve: exec_order must permute the planned super-batches"
        );
        let mut seen = vec![false; groups.len()];
        for &g in exec_order {
            assert!(
                g < groups.len() && !seen[g],
                "serve: exec_order must permute the planned super-batches"
            );
            seen[g] = true;
        }
        // Deadlines are metered from submission for every request (the
        // only clock reads live inside `budget.rs`).
        let mut meters: Vec<_> = requests.iter().map(|r| r.budget.meter()).collect();
        let mut results: Vec<Option<ServeResult>> = requests
            .iter()
            .map(|r| {
                (r.cols != dims).then(|| {
                    ServeResult::failed(format!(
                        "serve: request dim {} != model dim {dims}",
                        r.cols
                    ))
                })
            })
            .collect();
        for &gi in exec_order {
            let group = &groups[gi];
            // Per-request budget check at execution time. Expired
            // members get their typed outcome now; the rest stay live.
            let mut alive: Vec<usize> = Vec::with_capacity(group.len());
            for &ri in group {
                match meters[ri].check_before_iter() {
                    Some(_) => results[ri] = Some(ServeResult::deadline()),
                    None => alive.push(ri),
                }
            }
            if alive.is_empty() {
                continue;
            }
            // Assemble the super-batch from *all* member rows (expired
            // members included) so its layout stays input-keyed, then
            // zero-pad up to the tile boundary. Row independence makes
            // both choices bit-identical for the live members; keeping
            // the layout input-keyed keeps it auditable.
            let total_rows: usize = group.iter().map(|&ri| requests[ri].rows).sum();
            let mut data = Vec::with_capacity(total_rows * dims);
            for &ri in group {
                data.extend_from_slice(&requests[ri].data);
            }
            let pad_rows = total_rows.div_ceil(self.tile) * self.tile;
            let padded = batch::pad_to(&data, total_rows, dims, pad_rows, dims);
            let pdata = padded.data;
            let outcome = parallel::quarantine("serve.batch", move || {
                failpoint::check(failpoint::SITE_SERVE_BATCH);
                let table = DenseTable::from_vec(pdata, pad_rows, dims)?;
                self.model.serve_batch(ctx, &table)
            });
            match outcome {
                Ok(out) if out.len() == pad_rows * width => {
                    // Fixed-order demux: each request owns the row range
                    // it occupies in the super-batch; the padded tail is
                    // dropped on the floor.
                    let mut offset = 0usize;
                    for &ri in group {
                        let rows = requests[ri].rows;
                        if results[ri].is_none() {
                            let slice = &out[offset * width..(offset + rows) * width];
                            results[ri] = Some(ServeResult::completed(slice.to_vec()));
                        }
                        offset += rows;
                    }
                }
                Ok(out) => {
                    let msg = format!(
                        "serve: model returned {} values for a {pad_rows}-row super-batch \
                         (width {width})",
                        out.len()
                    );
                    for &ri in &alive {
                        results[ri] = Some(ServeResult::failed(msg.clone()));
                    }
                }
                Err(e) => {
                    // Quarantined panic or typed error: fail this
                    // batch's live members only.
                    let msg = e.to_string();
                    for &ri in &alive {
                        results[ri] = Some(ServeResult::failed(msg.clone()));
                    }
                }
            }
        }
        results
            .into_iter()
            .map(|r| {
                r.unwrap_or_else(|| ServeResult::failed("serve: request never scheduled".into()))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Context};
    use std::time::Duration;

    /// Minimal row-independent model: each output is its row's sum.
    struct RowSum {
        d: usize,
    }

    impl ServeModel for RowSum {
        fn serve_dims(&self) -> usize {
            self.d
        }

        fn serve_batch(&self, _ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
            Ok((0..q.rows()).map(|i| q.row(i).iter().sum()).collect())
        }
    }

    fn ctx() -> Context {
        Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .build()
            .unwrap()
    }

    fn req(rows: usize, cols: usize, fill: f64) -> ServeRequest {
        ServeRequest::new(vec![fill; rows * cols], rows, cols).unwrap()
    }

    #[test]
    fn request_shape_validated_at_submission() {
        assert!(ServeRequest::new(vec![0.0; 6], 2, 3).is_ok());
        assert!(ServeRequest::new(vec![0.0; 5], 2, 3).is_err());
        assert!(ServeRequest::new(vec![], 0, 3).is_err());
    }

    #[test]
    fn plan_cuts_are_input_keyed_and_respect_the_row_cap() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).max_super_rows(10);
        let requests: Vec<ServeRequest> =
            [4, 4, 4, 9, 20, 1].iter().map(|&r| req(r, 2, 1.0)).collect();
        let groups = session.plan(&requests);
        // 4+4 fits, +4 would exceed 10; 4+9 exceeds; 9+20 exceeds; the
        // oversized 20 forms its own group; 20+1 exceeds.
        assert_eq!(groups, vec![vec![0, 1], vec![2], vec![3], vec![4], vec![5]]);
        // Same inputs ⇒ same cuts, every time.
        assert_eq!(session.plan(&requests), groups);
    }

    #[test]
    fn coalesced_matches_sequential_bitwise() {
        let model = RowSum { d: 3 };
        let session = InferenceSession::new(&model).tile(4).max_super_rows(8);
        let requests: Vec<ServeRequest> =
            (0..7).map(|i| req(1 + i % 3, 3, 0.5 + i as f64)).collect();
        let c = ctx();
        let coalesced = session.serve(&c, &requests);
        for (r, out) in requests.iter().zip(&coalesced) {
            // Sequential per-request oracle: score the request alone.
            let table = DenseTable::from_vec(r.data.clone(), r.rows, r.cols).unwrap();
            let want = model.serve_batch(&c, &table).unwrap();
            assert_eq!(out.status, ServeStatus::Completed);
            let got = out.output.as_deref().unwrap();
            assert_eq!(got.len(), r.rows, "padded tail must not leak");
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn any_execution_permutation_is_bit_identical() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).tile(4).max_super_rows(4);
        let requests: Vec<ServeRequest> = (0..9).map(|i| req(2, 2, i as f64)).collect();
        let c = ctx();
        let n_groups = session.plan(&requests).len();
        assert!(n_groups >= 3);
        let base = session.serve(&c, &requests);
        let mut order: Vec<usize> = (0..n_groups).collect();
        order.reverse();
        let shuffled = session.serve_in_order(&c, &requests, &order);
        for (a, b) in base.iter().zip(&shuffled) {
            assert_eq!(a.status, b.status);
            match (&a.output, &b.output) {
                (Some(u), Some(v)) => {
                    for (x, y) in u.iter().zip(v) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (None, None) => {}
                _ => panic!("outputs diverged under permutation"),
            }
        }
    }

    #[test]
    fn mis_shaped_requests_fail_without_poisoning_neighbors() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model);
        let requests = vec![req(2, 2, 1.0), req(2, 5, 1.0), req(3, 2, 2.0)];
        let results = session.serve(&ctx(), &requests);
        assert_eq!(results[0].status, ServeStatus::Completed);
        assert_eq!(results[1].status, ServeStatus::Failed);
        assert!(results[1].error.as_deref().is_some_and(|e| e.contains("dim")));
        assert_eq!(results[2].status, ServeStatus::Completed);
        assert_eq!(results[2].output.as_deref().map(<[f64]>::len), Some(3));
    }

    #[test]
    fn expired_budget_yields_typed_outcome_and_leaves_neighbors_clean() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).max_super_rows(8);
        let mut requests: Vec<ServeRequest> = (0..4).map(|i| req(2, 2, i as f64)).collect();
        requests[1] = req(2, 2, 1.0).with_budget(Budget::default().max_wall_time(Duration::ZERO));
        let c = ctx();
        let served = session.serve(&c, &requests);
        assert_eq!(served[1].status, ServeStatus::DeadlineExceeded);
        assert!(served[1].output.is_none());
        // Neighbors complete, bit-identical to an all-unlimited run.
        let unlimited: Vec<ServeRequest> = (0..4).map(|i| req(2, 2, i as f64)).collect();
        let base = session.serve(&c, &unlimited);
        for i in [0usize, 2, 3] {
            assert_eq!(served[i].status, ServeStatus::Completed, "request {i}");
            let (a, b) = (served[i].output.as_deref(), base[i].output.as_deref());
            match (a, b) {
                (Some(u), Some(v)) => {
                    for (x, y) in u.iter().zip(v) {
                        assert_eq!(x.to_bits(), y.to_bits(), "request {i}");
                    }
                }
                _ => panic!("neighbor {i} lost its output"),
            }
        }
    }

    #[test]
    fn model_errors_are_quarantined_per_batch() {
        struct Broken;
        impl ServeModel for Broken {
            fn serve_dims(&self) -> usize {
                2
            }
            fn serve_batch(&self, _ctx: &Context, _q: &DenseTable<f64>) -> Result<Vec<f64>> {
                Err(Error::Numerical("serve-test: synthetic failure".into()))
            }
        }
        let model = Broken;
        let session = InferenceSession::new(&model);
        let results = session.serve(&ctx(), &[req(2, 2, 1.0)]);
        assert_eq!(results[0].status, ServeStatus::Failed);
        assert!(results[0].error.as_deref().is_some_and(|e| e.contains("synthetic")));
    }
}
