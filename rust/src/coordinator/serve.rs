//! Batched inference serving: many small concurrent query batches,
//! coalesced into TILE-aligned super-batches, scored through a fitted
//! model's pack-free entry points, and demuxed back in submission
//! order.
//!
//! ## Session lifecycle
//!
//! 1. Train a model; its corpus is packed **once** into the
//!    model-resident [`crate::primitives::packed::ModelPanel`].
//! 2. Wrap it in an [`InferenceSession`] (borrows the model).
//! 3. Submit a slice of [`ServeRequest`]s — each a small dense query
//!    batch with an optional per-request [`Budget`].
//! 4. [`InferenceSession::serve`] coalesces them into super-batches
//!    ([`InferenceSession::plan`]), pads each to a multiple of the
//!    session tile with zero rows (the [`super::batch`] pad-and-mask
//!    idiom), runs each super-batch **tile by tile** through
//!    [`ServeModel::serve_batch`] under the `serve.batch` panic
//!    quarantine, and returns one [`ServeResult`] per request, in
//!    submission order.
//!
//! Two layers sit on top of the slice-based session:
//!
//! * [`QueuedSession`] — the bounded-queue front end: submissions are
//!   admitted up to a capacity, shed with a typed
//!   [`ServeStatus::Overloaded`] when the queue is full, drained in
//!   submission order (bit-identical to the slice path), and settled
//!   as [`ServeStatus::Cancelled`] if the session shuts down first.
//! * [`super::resilience::ResilientSession`] — deterministic retry,
//!   circuit breaking, and the [`ServeRung`] degradation ladder.
//!
//! ## Determinism rules
//!
//! * **Input-keyed coalescing**: super-batch cuts depend only on the
//!   request sequence (row counts and dims) and the session's
//!   `max_super_rows` — never on timing, worker count, or budget
//!   state. The same request set always produces the same cuts.
//! * **Fixed-order demux**: each request's output is the fixed row
//!   range it occupies in its super-batch, so results demux in
//!   submission order regardless of the order super-batches complete
//!   ([`InferenceSession::serve_in_order`] executes them under an
//!   arbitrary permutation to prove it).
//! * **Row independence**: every served model scores rows
//!   independently (the engine's per-row contract), so a request's
//!   output bits do not depend on which neighbors shared its
//!   super-batch, on the zero padding rows, or on where the per-tile
//!   execution loop cuts — coalesced, tile-wise serving is
//!   bit-identical to sequential per-request calls at any worker
//!   count.
//!
//! ## Typed outcomes
//!
//! Each request's budget is metered from submission and checked
//! **cooperatively**: once at super-batch entry and once per execution
//! tile its rows intersect (so one huge super-batch cannot blow a
//! deadline unobserved; a budget "iteration" here is a checkpoint
//! visit). An expired request gets a [`ServeStatus::DeadlineExceeded`]
//! outcome — its neighbors in the same super-batch still complete,
//! bit-identical to an all-unlimited run, and tiles in which every
//! intersecting request has settled (plus the padded tail) are skipped
//! entirely. A panic or typed error inside a super-batch (see
//! [`crate::failpoint::SITE_SERVE_BATCH`]) is quarantined into
//! [`ServeStatus::Failed`] for that batch's live members only; other
//! super-batches are untouched and a retry runs clean.

use super::batch;
use super::budget::{Budget, BudgetMeter};
use super::Context;
use crate::error::{Error, Result};
use crate::failpoint;
use crate::parallel;
use crate::tables::DenseTable;

/// Default super-batch row alignment — the fused distance engine's
/// query M-tile, so one padded super-batch fills whole engine tiles.
const DEFAULT_TILE: usize = 256;
/// Default cap on rows per coalesced super-batch.
const DEFAULT_MAX_SUPER_ROWS: usize = 1024;

/// Which execution path a super-batch runs on — the resilience layer's
/// degradation ladder, ordered fastest first
/// (`docs/RESILIENCE.md`). The plain session always runs `Packed`;
/// an open circuit breaker walks down.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeRung {
    /// The normal pack-free path: score through the model-resident
    /// packed panel.
    Packed,
    /// Re-pack the corpus per call, bypassing the model-resident panel
    /// — degraded throughput, same bits (the per-call-pack replica of
    /// `tests/serve_property.rs`).
    Repack,
    /// The scalar naive oracle rung ([`super::Backend::Naive`]) —
    /// slowest, and independent of the packed/pooled machinery
    /// entirely.
    Naive,
}

/// A fitted model the serving layer can drive. Implementations route
/// through their quarantined, pack-free inference entry points (the
/// model-resident panel), and score rows independently — the property
/// the coalescing determinism contract rests on.
pub trait ServeModel {
    /// Feature dimension every query row must have.
    fn serve_dims(&self) -> usize;

    /// Output values per query row (all current models emit one).
    fn serve_width(&self) -> usize {
        1
    }

    /// Score one dense batch: `rows × serve_width()` values, row-major.
    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>>;

    /// Score one dense batch on an explicit degradation rung. The
    /// default ignores the rung and runs [`ServeModel::serve_batch`] —
    /// correct for models whose panel is a plain weight vector (there
    /// is nothing to degrade to). Distance-engine models override it:
    /// `Repack` must bypass the model-resident panel, `Naive` must run
    /// the scalar oracle. Every rung returns the same bits (the naive
    /// rung is the established oracle).
    fn serve_batch_rung(
        &self,
        ctx: &Context,
        q: &DenseTable<f64>,
        rung: ServeRung,
    ) -> Result<Vec<f64>> {
        let _ = rung;
        self.serve_batch(ctx, q)
    }
}

/// One client query batch: a small dense `rows × cols` block plus an
/// optional per-request [`Budget`] (deadline metered from submission).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
    budget: Budget,
}

impl ServeRequest {
    /// Validate shape up front so malformed requests are rejected at
    /// submission, not mid-super-batch.
    pub fn new(data: Vec<f64>, rows: usize, cols: usize) -> Result<Self> {
        if rows == 0 || cols == 0 || data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "serve: request buffer len {} != rows {rows} × cols {cols}",
                data.len()
            )));
        }
        Ok(Self { data, rows, cols, budget: Budget::UNLIMITED })
    }

    /// Attach a per-request budget. The deadline is metered from the
    /// moment the request set enters [`InferenceSession::serve`].
    pub fn with_budget(mut self, b: Budget) -> Self {
        self.budget = b;
        self
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }
}

/// How one request ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServeStatus {
    /// Scored; `output` holds `rows × serve_width()` values.
    Completed,
    /// The request's budget expired at a checkpoint — super-batch
    /// entry or a per-tile visit — before its rows finished scoring
    /// (each checkpoint counts as one budget iteration, so an
    /// iteration cap of zero lands here at entry). No output.
    DeadlineExceeded,
    /// Shape mismatch at planning time, or a quarantined panic/error
    /// while this request's super-batch executed. No output.
    Failed,
    /// Shed at admission: the [`QueuedSession`] bounded queue was full
    /// ([`Error::Overloaded`]). No output.
    Overloaded,
    /// Fast-rejected by the resilience layer: the circuit breaker is
    /// open and the whole degradation ladder failed
    /// (`coordinator/resilience.rs`). No output.
    Unavailable,
    /// Cancelled while still queued — [`QueuedSession::shutdown`]
    /// settles queued-but-unexecuted requests with this instead of
    /// silently dropping them ([`Error::Cancelled`]). No output.
    Cancelled,
}

/// Per-request outcome, returned in submission order.
#[derive(Clone, Debug)]
pub struct ServeResult {
    pub status: ServeStatus,
    /// `rows × serve_width()` values for [`ServeStatus::Completed`];
    /// `None` otherwise. Padded-tail rows are never included.
    pub output: Option<Vec<f64>>,
    /// Human-readable cause for the non-completed, non-deadline
    /// statuses.
    pub error: Option<String>,
}

impl ServeResult {
    pub(crate) fn completed(output: Vec<f64>) -> Self {
        Self { status: ServeStatus::Completed, output: Some(output), error: None }
    }

    pub(crate) fn deadline() -> Self {
        Self { status: ServeStatus::DeadlineExceeded, output: None, error: None }
    }

    pub(crate) fn failed(msg: String) -> Self {
        Self { status: ServeStatus::Failed, output: None, error: Some(msg) }
    }

    pub(crate) fn unavailable(msg: String) -> Self {
        Self { status: ServeStatus::Unavailable, output: None, error: Some(msg) }
    }

    fn overloaded(msg: String) -> Self {
        Self { status: ServeStatus::Overloaded, output: None, error: Some(msg) }
    }

    fn cancelled(msg: String) -> Self {
        Self { status: ServeStatus::Cancelled, output: None, error: Some(msg) }
    }

    pub fn is_completed(&self) -> bool {
        self.status == ServeStatus::Completed
    }
}

/// Settle every still-unsettled member of `group` with the result
/// `mk` builds — the caller's verdict after a failed execution attempt
/// (plain path: `Failed`; resilience layer: `Unavailable`).
pub(crate) fn settle_unsettled(
    group: &[usize],
    results: &mut [Option<ServeResult>],
    mk: impl Fn() -> ServeResult,
) {
    for &ri in group {
        if results[ri].is_none() {
            results[ri] = Some(mk());
        }
    }
}

/// Unwrap the per-request slots into the final submission-order
/// result vector.
pub(crate) fn finalize_results(results: Vec<Option<ServeResult>>) -> Vec<ServeResult> {
    results
        .into_iter()
        .map(|r| {
            r.unwrap_or_else(|| ServeResult::failed("serve: request never scheduled".into()))
        })
        .collect()
}

/// A serving session over one fitted model. Cheap to construct (borrows
/// the model; the expensive pack already happened at `train` time).
pub struct InferenceSession<'m, M: ServeModel> {
    model: &'m M,
    tile: usize,
    max_super_rows: usize,
}

impl<'m, M: ServeModel> InferenceSession<'m, M> {
    pub fn new(model: &'m M) -> Self {
        Self { model, tile: DEFAULT_TILE, max_super_rows: DEFAULT_MAX_SUPER_ROWS }
    }

    /// Super-batch row alignment (rows are zero-padded up to a multiple
    /// of this). Also the granularity of the cooperative budget
    /// checkpoints and of deadline-driven tile skipping.
    pub fn tile(mut self, tile: usize) -> Self {
        assert!(tile > 0, "serve: tile must be positive");
        self.tile = tile;
        self
    }

    /// Cap on (unpadded) rows per coalesced super-batch.
    pub fn max_super_rows(mut self, rows: usize) -> Self {
        assert!(rows > 0, "serve: max_super_rows must be positive");
        self.max_super_rows = rows;
        self
    }

    /// Input-keyed coalescing plan: greedy contiguous grouping of the
    /// well-shaped requests (submission order preserved), cutting a new
    /// super-batch when the next request would push the current one
    /// past `max_super_rows`. A single oversized request still forms
    /// its own super-batch. Mis-shaped requests join no group — they
    /// fail without executing.
    pub fn plan(&self, requests: &[ServeRequest]) -> Vec<Vec<usize>> {
        let dims = self.model.serve_dims();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut cur: Vec<usize> = Vec::new();
        let mut cur_rows = 0usize;
        for (i, r) in requests.iter().enumerate() {
            if r.cols != dims {
                continue;
            }
            if !cur.is_empty() && cur_rows + r.rows > self.max_super_rows {
                groups.push(std::mem::take(&mut cur));
                cur_rows = 0;
            }
            cur.push(i);
            cur_rows += r.rows;
        }
        if !cur.is_empty() {
            groups.push(cur);
        }
        groups
    }

    /// Shared run setup for the slice path and the resilience layer:
    /// the coalescing plan, one submission-time [`BudgetMeter`] per
    /// request, and the per-request result slots with mis-shaped
    /// requests pre-settled as [`ServeStatus::Failed`].
    pub(crate) fn init_run(
        &self,
        requests: &[ServeRequest],
    ) -> (Vec<Vec<usize>>, Vec<BudgetMeter>, Vec<Option<ServeResult>>) {
        let dims = self.model.serve_dims();
        let groups = self.plan(requests);
        // Deadlines are metered from submission for every request (the
        // only clock reads live inside `budget.rs`).
        let meters = requests.iter().map(|r| r.budget.meter()).collect();
        let results = requests
            .iter()
            .map(|r| {
                (r.cols != dims).then(|| {
                    ServeResult::failed(format!(
                        "serve: request dim {} != model dim {dims}",
                        r.cols
                    ))
                })
            })
            .collect();
        (groups, meters, results)
    }

    /// Execute one planned super-batch at `rung`: checkpoint budgets,
    /// assemble + pad, score tile by tile under the quarantine, and
    /// demux completed outputs into `results`.
    ///
    /// Budget expirations observed during the attempt are settled
    /// immediately (a deadline verdict is final no matter what happens
    /// to the rest of the batch). On `Err` — a quarantined panic, a
    /// typed model error, or an injected fault — **no live member's
    /// result is written**, so the caller decides: the plain path
    /// settles them [`ServeStatus::Failed`], the resilience layer
    /// retries or walks the degradation ladder. Members already
    /// settled by an earlier attempt stay settled; the super-batch is
    /// always assembled from *all* member rows so its layout stays
    /// input-keyed across attempts and rungs.
    pub(crate) fn execute_group(
        &self,
        ctx: &Context,
        requests: &[ServeRequest],
        group: &[usize],
        meters: &mut [BudgetMeter],
        results: &mut [Option<ServeResult>],
        rung: ServeRung,
    ) -> Result<()> {
        let dims = self.model.serve_dims();
        let width = self.model.serve_width();
        // Entry checkpoint: settle members whose budget has already
        // expired before doing any assembly work.
        let mut any_live = false;
        for &ri in group {
            if results[ri].is_some() {
                continue;
            }
            match meters[ri].check_before_iter() {
                Some(_) => results[ri] = Some(ServeResult::deadline()),
                None => any_live = true,
            }
        }
        if !any_live {
            return Ok(());
        }
        // Assemble from *all* member rows (settled members included) so
        // the layout stays input-keyed, then zero-pad up to the tile
        // boundary. Row independence makes the live members' bits
        // indifferent to their neighbors either way; keeping the
        // layout input-keyed keeps it auditable.
        let total_rows: usize = group.iter().map(|&ri| requests[ri].rows).sum();
        let mut data = Vec::with_capacity(total_rows * dims);
        // (request index, first super-batch row, row count) per member.
        let mut spans: Vec<(usize, usize, usize)> = Vec::with_capacity(group.len());
        let mut row0 = 0usize;
        for &ri in group {
            data.extend_from_slice(&requests[ri].data);
            spans.push((ri, row0, requests[ri].rows));
            row0 += requests[ri].rows;
        }
        let pad_rows = total_rows.div_ceil(self.tile) * self.tile;
        let padded = batch::pad_to(&data, total_rows, dims, pad_rows, dims);
        let pdata = padded.data;
        // The degraded rungs fault-inject and quarantine under their
        // own site: a persistent fault armed at the primary path must
        // leave the fallback rungs working.
        let (fail_site, quar_site) = match rung {
            ServeRung::Packed => (failpoint::SITE_SERVE_BATCH, "serve.batch"),
            ServeRung::Repack | ServeRung::Naive => {
                (failpoint::SITE_SERVE_DEGRADED, "serve.degraded")
            }
        };
        let model = self.model;
        let tile = self.tile;
        let out = parallel::quarantine(quar_site, || {
            // One failpoint visit per execution *attempt*, not per
            // tile — fault accounting stays one count per injected
            // fault (`ResilienceStats::faults`).
            failpoint::check_result(fail_site)?;
            let mut out = vec![0.0f64; pad_rows * width];
            for (t0, tl) in batch::tiles(pad_rows, tile) {
                let t_end = t0 + tl;
                // Cooperative checkpoint: meter every still-live
                // member whose rows intersect this tile.
                let mut tile_live = false;
                for &(ri, r0, rn) in &spans {
                    if r0 >= t_end || r0 + rn <= t0 || results[ri].is_some() {
                        continue;
                    }
                    match meters[ri].check_before_iter() {
                        Some(_) => results[ri] = Some(ServeResult::deadline()),
                        None => tile_live = true,
                    }
                }
                // Tiles owning no live rows — the padded tail, or a
                // stretch whose members all settled — are skipped.
                if !tile_live {
                    continue;
                }
                let table =
                    DenseTable::from_vec(pdata[t0 * dims..t_end * dims].to_vec(), tl, dims)?;
                let t_out = model.serve_batch_rung(ctx, &table, rung)?;
                if t_out.len() != tl * width {
                    return Err(Error::Shape(format!(
                        "serve: model returned {} values for a {tl}-row tile (width {width})",
                        t_out.len()
                    )));
                }
                out[t0 * width..t_end * width].copy_from_slice(&t_out);
            }
            Ok(out)
        })?;
        // Fixed-order demux: each request owns the row range it
        // occupies in the super-batch; the padded tail is dropped on
        // the floor.
        for &(ri, r0, rn) in &spans {
            if results[ri].is_none() {
                results[ri] =
                    Some(ServeResult::completed(out[r0 * width..(r0 + rn) * width].to_vec()));
            }
        }
        Ok(())
    }

    /// Serve a request set: plan, execute every super-batch in
    /// ascending order, demux. One [`ServeResult`] per request, in
    /// submission order.
    pub fn serve(&self, ctx: &Context, requests: &[ServeRequest]) -> Vec<ServeResult> {
        let order: Vec<usize> = (0..self.plan(requests).len()).collect();
        self.serve_in_order(ctx, requests, &order)
    }

    /// [`InferenceSession::serve`] with an explicit super-batch
    /// execution permutation — the shuffled-completion harness. Each
    /// request's output is the fixed row range it occupies in its
    /// super-batch, so any permutation yields bit-identical results;
    /// `tests/serve_property.rs` asserts it.
    ///
    /// # Panics
    ///
    /// If `exec_order` is not a permutation of
    /// `0..self.plan(requests).len()`.
    pub fn serve_in_order(
        &self,
        ctx: &Context,
        requests: &[ServeRequest],
        exec_order: &[usize],
    ) -> Vec<ServeResult> {
        let (groups, mut meters, mut results) = self.init_run(requests);
        assert_eq!(
            exec_order.len(),
            groups.len(),
            "serve: exec_order must permute the planned super-batches"
        );
        let mut seen = vec![false; groups.len()];
        for &g in exec_order {
            assert!(
                g < groups.len() && !seen[g],
                "serve: exec_order must permute the planned super-batches"
            );
            seen[g] = true;
        }
        for &gi in exec_order {
            let group = &groups[gi];
            if let Err(e) =
                self.execute_group(ctx, requests, group, &mut meters, &mut results, ServeRung::Packed)
            {
                // Quarantined panic or typed error: fail this batch's
                // live members only.
                let msg = e.to_string();
                settle_unsettled(group, &mut results, || ServeResult::failed(msg.clone()));
            }
        }
        finalize_results(results)
    }
}

/// Anything that can serve a request slice — the plain
/// [`InferenceSession`] or the resilience-wrapped
/// [`super::resilience::ResilientSession`] — so the [`QueuedSession`]
/// front end composes with either.
pub trait ServeExecutor {
    fn serve_all(&mut self, ctx: &Context, requests: &[ServeRequest]) -> Vec<ServeResult>;
}

impl<M: ServeModel> ServeExecutor for InferenceSession<'_, M> {
    fn serve_all(&mut self, ctx: &Context, requests: &[ServeRequest]) -> Vec<ServeResult> {
        self.serve(ctx, requests)
    }
}

/// Admission counters of a [`QueuedSession`] (monotonic over the
/// session's life, mirroring the SVM `TrainStats` style).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Requests admitted into the queue.
    pub accepted: usize,
    /// Requests shed at admission (queue full ⇒
    /// [`ServeStatus::Overloaded`]).
    pub shed: usize,
    /// Requests executed by [`QueuedSession::drain`].
    pub served: usize,
    /// Queued-but-unexecuted requests settled
    /// [`ServeStatus::Cancelled`] by [`QueuedSession::shutdown`].
    pub cancelled: usize,
}

/// One submission slot, in submission order: still queued, or already
/// settled at admission (shed) / shutdown (cancelled).
enum Slot {
    Queued(ServeRequest),
    Settled(ServeResult),
}

/// The bounded-queue serving front end: **admission control** over any
/// [`ServeExecutor`].
///
/// * [`QueuedSession::submit`] admits up to `capacity` queued requests;
///   beyond that, submissions are **shed** — the caller gets a typed
///   [`Error::Overloaded`] immediately and the slot settles as
///   [`ServeStatus::Overloaded`] — so memory stays bounded under
///   overload instead of growing without limit.
/// * [`QueuedSession::drain`] executes the queued requests **in
///   submission order** as one slice, so its outputs are bit-identical
///   to the slice-based path, and returns one result per *submission*
///   since the last drain (shed slots included), in submission order.
/// * [`QueuedSession::shutdown`] settles queued-but-unexecuted
///   requests as [`ServeStatus::Cancelled`] ([`Error::Cancelled`])
///   instead of silently dropping them.
pub struct QueuedSession<E> {
    exec: E,
    capacity: usize,
    slots: Vec<Slot>,
    queued: usize,
    stats: QueueStats,
}

impl<E: ServeExecutor> QueuedSession<E> {
    /// Front a session (or resilient session) with a bounded queue.
    ///
    /// # Panics
    ///
    /// If `capacity` is zero (a queue that admits nothing serves
    /// nothing).
    pub fn new(exec: E, capacity: usize) -> Self {
        assert!(capacity > 0, "serve: queue capacity must be positive");
        Self { exec, capacity, slots: Vec::new(), queued: 0, stats: QueueStats::default() }
    }

    /// Submit one request. Admitted requests return their slot index;
    /// when `queued() == capacity` the request is shed: its slot
    /// settles as [`ServeStatus::Overloaded`] and the same typed error
    /// is returned to the caller.
    pub fn submit(&mut self, req: ServeRequest) -> Result<usize> {
        let ticket = self.slots.len();
        if self.queued >= self.capacity {
            let err = Error::Overloaded(format!(
                "serve: queue full ({} queued, capacity {})",
                self.queued, self.capacity
            ));
            self.stats.shed += 1;
            self.slots.push(Slot::Settled(ServeResult::overloaded(err.to_string())));
            return Err(err);
        }
        self.queued += 1;
        self.stats.accepted += 1;
        self.slots.push(Slot::Queued(req));
        Ok(ticket)
    }

    /// Requests currently queued (admitted, not yet drained).
    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn stats(&self) -> QueueStats {
        self.stats
    }

    /// Execute everything queued, in submission order, and return one
    /// result per submission since the last drain (shed submissions
    /// surface their [`ServeStatus::Overloaded`] here), in submission
    /// order. Drain order equals submission order, so outputs are
    /// bit-identical to handing the admitted requests to the executor
    /// as one slice.
    pub fn drain(&mut self, ctx: &Context) -> Vec<ServeResult> {
        let slots = std::mem::take(&mut self.slots);
        self.queued = 0;
        let mut reqs: Vec<ServeRequest> = Vec::new();
        // One entry per slot: pre-settled result, or None ⇒ take the
        // next executor result (queued slots, in submission order).
        let mut settled: Vec<Option<ServeResult>> = Vec::with_capacity(slots.len());
        for s in slots {
            match s {
                Slot::Queued(r) => {
                    reqs.push(r);
                    settled.push(None);
                }
                Slot::Settled(res) => settled.push(Some(res)),
            }
        }
        let served = self.exec.serve_all(ctx, &reqs);
        self.stats.served += served.len();
        let mut it = served.into_iter();
        settled
            .into_iter()
            .map(|s| {
                s.or_else(|| it.next()).unwrap_or_else(|| {
                    ServeResult::failed("serve: executor returned too few results".into())
                })
            })
            .collect()
    }

    /// Shut the queue down without executing: every queued request
    /// settles as [`ServeStatus::Cancelled`] (carrying the
    /// [`Error::Cancelled`] text), shed slots keep their
    /// [`ServeStatus::Overloaded`]. Returns one result per submission
    /// since the last drain, in submission order.
    pub fn shutdown(&mut self) -> Vec<ServeResult> {
        let slots = std::mem::take(&mut self.slots);
        self.queued = 0;
        slots
            .into_iter()
            .map(|s| match s {
                Slot::Queued(_) => {
                    self.stats.cancelled += 1;
                    let err =
                        Error::Cancelled("serve: session shut down before execution".into());
                    ServeResult::cancelled(err.to_string())
                }
                Slot::Settled(res) => res,
            })
            .collect()
    }

    /// Unwrap the inner executor (dropping any still-queued requests
    /// is a caller bug — prefer [`QueuedSession::shutdown`] first).
    pub fn into_inner(self) -> E {
        self.exec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Context};
    use std::time::Duration;

    /// Minimal row-independent model: each output is its row's sum.
    struct RowSum {
        d: usize,
    }

    impl ServeModel for RowSum {
        fn serve_dims(&self) -> usize {
            self.d
        }

        fn serve_batch(&self, _ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
            Ok((0..q.rows()).map(|i| q.row(i).iter().sum()).collect())
        }
    }

    fn ctx() -> Context {
        Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .build()
            .unwrap()
    }

    fn req(rows: usize, cols: usize, fill: f64) -> ServeRequest {
        ServeRequest::new(vec![fill; rows * cols], rows, cols).unwrap()
    }

    #[test]
    fn request_shape_validated_at_submission() {
        assert!(ServeRequest::new(vec![0.0; 6], 2, 3).is_ok());
        assert!(ServeRequest::new(vec![0.0; 5], 2, 3).is_err());
        assert!(ServeRequest::new(vec![], 0, 3).is_err());
    }

    #[test]
    fn plan_cuts_are_input_keyed_and_respect_the_row_cap() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).max_super_rows(10);
        let requests: Vec<ServeRequest> =
            [4, 4, 4, 9, 20, 1].iter().map(|&r| req(r, 2, 1.0)).collect();
        let groups = session.plan(&requests);
        // 4+4 fits, +4 would exceed 10; 4+9 exceeds; 9+20 exceeds; the
        // oversized 20 forms its own group; 20+1 exceeds.
        assert_eq!(groups, vec![vec![0, 1], vec![2], vec![3], vec![4], vec![5]]);
        // Same inputs ⇒ same cuts, every time.
        assert_eq!(session.plan(&requests), groups);
    }

    #[test]
    fn coalesced_matches_sequential_bitwise() {
        let model = RowSum { d: 3 };
        let session = InferenceSession::new(&model).tile(4).max_super_rows(8);
        let requests: Vec<ServeRequest> =
            (0..7).map(|i| req(1 + i % 3, 3, 0.5 + i as f64)).collect();
        let c = ctx();
        let coalesced = session.serve(&c, &requests);
        for (r, out) in requests.iter().zip(&coalesced) {
            // Sequential per-request oracle: score the request alone.
            let table = DenseTable::from_vec(r.data.clone(), r.rows, r.cols).unwrap();
            let want = model.serve_batch(&c, &table).unwrap();
            assert_eq!(out.status, ServeStatus::Completed);
            let got = out.output.as_deref().unwrap();
            assert_eq!(got.len(), r.rows, "padded tail must not leak");
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn any_execution_permutation_is_bit_identical() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).tile(4).max_super_rows(4);
        let requests: Vec<ServeRequest> = (0..9).map(|i| req(2, 2, i as f64)).collect();
        let c = ctx();
        let n_groups = session.plan(&requests).len();
        assert!(n_groups >= 3);
        let base = session.serve(&c, &requests);
        let mut order: Vec<usize> = (0..n_groups).collect();
        order.reverse();
        let shuffled = session.serve_in_order(&c, &requests, &order);
        for (a, b) in base.iter().zip(&shuffled) {
            assert_eq!(a.status, b.status);
            match (&a.output, &b.output) {
                (Some(u), Some(v)) => {
                    for (x, y) in u.iter().zip(v) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (None, None) => {}
                _ => panic!("outputs diverged under permutation"),
            }
        }
    }

    #[test]
    fn mis_shaped_requests_fail_without_poisoning_neighbors() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model);
        let requests = vec![req(2, 2, 1.0), req(2, 5, 1.0), req(3, 2, 2.0)];
        let results = session.serve(&ctx(), &requests);
        assert_eq!(results[0].status, ServeStatus::Completed);
        assert_eq!(results[1].status, ServeStatus::Failed);
        assert!(results[1].error.as_deref().is_some_and(|e| e.contains("dim")));
        assert_eq!(results[2].status, ServeStatus::Completed);
        assert_eq!(results[2].output.as_deref().map(<[f64]>::len), Some(3));
    }

    #[test]
    fn expired_budget_yields_typed_outcome_and_leaves_neighbors_clean() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).max_super_rows(8);
        let mut requests: Vec<ServeRequest> = (0..4).map(|i| req(2, 2, i as f64)).collect();
        requests[1] = req(2, 2, 1.0).with_budget(Budget::default().max_wall_time(Duration::ZERO));
        let c = ctx();
        let served = session.serve(&c, &requests);
        assert_eq!(served[1].status, ServeStatus::DeadlineExceeded);
        assert!(served[1].output.is_none());
        // Neighbors complete, bit-identical to an all-unlimited run.
        let unlimited: Vec<ServeRequest> = (0..4).map(|i| req(2, 2, i as f64)).collect();
        let base = session.serve(&c, &unlimited);
        for i in [0usize, 2, 3] {
            assert_eq!(served[i].status, ServeStatus::Completed, "request {i}");
            let (a, b) = (served[i].output.as_deref(), base[i].output.as_deref());
            match (a, b) {
                (Some(u), Some(v)) => {
                    for (x, y) in u.iter().zip(v) {
                        assert_eq!(x.to_bits(), y.to_bits(), "request {i}");
                    }
                }
                _ => panic!("neighbor {i} lost its output"),
            }
        }
    }

    /// The per-tile cooperative checkpoint: an iteration-cap budget is
    /// consumed once at entry plus once per tile the request's rows
    /// intersect, so a request spanning many tiles can expire
    /// *mid-super-batch* — deterministically, since iteration caps
    /// never read the clock.
    #[test]
    fn iteration_cap_expires_mid_super_batch_at_a_tile_boundary() {
        let model = RowSum { d: 2 };
        let session = InferenceSession::new(&model).tile(2).max_super_rows(64);
        // 6 rows ⇒ 3 tiles of 2. Checkpoints: entry + 3 tiles = 4.
        let starved =
            vec![req(6, 2, 1.0).with_budget(Budget::default().max_iters(2)), req(2, 2, 3.0)];
        let c = ctx();
        let served = session.serve(&c, &starved);
        // entry(1) + tile0(2) pass, tile1 check expires ⇒ deadline.
        assert_eq!(served[0].status, ServeStatus::DeadlineExceeded);
        // The neighbor — sharing the super-batch — still completes,
        // bit-identical to an unbudgeted run.
        assert_eq!(served[1].status, ServeStatus::Completed);
        let base = session.serve(&c, &[req(6, 2, 1.0), req(2, 2, 3.0)]);
        let (a, b) = (served[1].output.as_deref(), base[1].output.as_deref());
        match (a, b) {
            (Some(u), Some(v)) => {
                for (x, y) in u.iter().zip(v) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            _ => panic!("neighbor lost its output"),
        }
        // A cap generous enough for every checkpoint completes whole.
        let fed = vec![req(6, 2, 1.0).with_budget(Budget::default().max_iters(8))];
        let served = session.serve(&c, &fed);
        assert_eq!(served[0].status, ServeStatus::Completed);
        let base = session.serve(&c, &[req(6, 2, 1.0)]);
        assert_eq!(
            served[0].output.as_deref().unwrap(),
            base[0].output.as_deref().unwrap(),
            "budgeted-but-unexpired must be bit-identical to unbudgeted"
        );
    }

    #[test]
    fn model_errors_are_quarantined_per_batch() {
        struct Broken;
        impl ServeModel for Broken {
            fn serve_dims(&self) -> usize {
                2
            }
            fn serve_batch(&self, _ctx: &Context, _q: &DenseTable<f64>) -> Result<Vec<f64>> {
                Err(Error::Numerical("serve-test: synthetic failure".into()))
            }
        }
        let model = Broken;
        let session = InferenceSession::new(&model);
        let results = session.serve(&ctx(), &[req(2, 2, 1.0)]);
        assert_eq!(results[0].status, ServeStatus::Failed);
        assert!(results[0].error.as_deref().is_some_and(|e| e.contains("synthetic")));
    }

    #[test]
    fn queued_drain_is_bit_identical_to_the_slice_path() {
        let model = RowSum { d: 2 };
        let requests: Vec<ServeRequest> = (0..5).map(|i| req(2, 2, i as f64)).collect();
        let c = ctx();
        let base = InferenceSession::new(&model).tile(4).max_super_rows(4).serve(&c, &requests);
        let mut q =
            QueuedSession::new(InferenceSession::new(&model).tile(4).max_super_rows(4), 8);
        for r in &requests {
            q.submit(r.clone()).unwrap();
        }
        assert_eq!(q.queued(), 5);
        let drained = q.drain(&c);
        assert_eq!(q.queued(), 0);
        assert_eq!(drained.len(), base.len());
        for (a, b) in drained.iter().zip(&base) {
            assert_eq!(a.status, b.status);
            let (u, v) = (a.output.as_deref().unwrap(), b.output.as_deref().unwrap());
            for (x, y) in u.iter().zip(v) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        assert_eq!(q.stats().accepted, 5);
        assert_eq!(q.stats().served, 5);
        assert_eq!(q.stats().shed, 0);
    }

    #[test]
    fn full_queue_sheds_with_typed_overload() {
        let model = RowSum { d: 2 };
        let mut q = QueuedSession::new(InferenceSession::new(&model), 1);
        assert_eq!(q.submit(req(1, 2, 1.0)).unwrap(), 0);
        // Capacity 1: the second and third submissions shed.
        for _ in 0..2 {
            let e = q.submit(req(1, 2, 2.0)).unwrap_err();
            assert!(matches!(e, Error::Overloaded(_)), "wrong variant: {e:?}");
            assert!(e.to_string().contains("overloaded"));
        }
        assert_eq!(q.queued(), 1);
        assert_eq!(q.stats().shed, 2);
        let c = ctx();
        let results = q.drain(&c);
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].status, ServeStatus::Completed);
        assert_eq!(results[1].status, ServeStatus::Overloaded);
        assert_eq!(results[2].status, ServeStatus::Overloaded);
        assert!(results[1].output.is_none());
        // The queue is usable again after the drain.
        q.submit(req(1, 2, 4.0)).unwrap();
        let again = q.drain(&c);
        assert_eq!(again[0].status, ServeStatus::Completed);
    }

    #[test]
    fn shutdown_cancels_queued_requests_with_typed_outcome() {
        let model = RowSum { d: 2 };
        let mut q = QueuedSession::new(InferenceSession::new(&model), 2);
        q.submit(req(1, 2, 1.0)).unwrap();
        q.submit(req(1, 2, 2.0)).unwrap();
        let _ = q.submit(req(1, 2, 3.0)); // shed
        let results = q.shutdown();
        assert_eq!(results.len(), 3);
        for r in &results[..2] {
            assert_eq!(r.status, ServeStatus::Cancelled);
            assert!(r.output.is_none());
            assert!(r.error.as_deref().is_some_and(|e| e.contains("cancelled")));
        }
        assert_eq!(results[2].status, ServeStatus::Overloaded);
        assert_eq!(q.stats().cancelled, 2);
        assert_eq!(q.queued(), 0);
        // Shutdown empties the queue; new submissions are admitted.
        q.submit(req(1, 2, 4.0)).unwrap();
        assert_eq!(q.queued(), 1);
    }
}
