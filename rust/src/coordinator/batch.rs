//! Fixed-shape batching: XLA executables are shape-monomorphic, so the
//! coordinator tiles dynamic workloads into the padded shapes the AOT
//! artifacts were compiled for. Padding rows are masked out by the
//! kernels themselves (the Pallas kernels carry validity masks — the
//! TPU analogue of SVE's `svwhilelt` loop-tail predication).

use crate::dtype::Float;

/// A zero-padded, fixed-shape copy of a logical `rows × cols` block.
#[derive(Debug, Clone)]
pub struct PaddedBatch<T> {
    /// Padded row-major buffer (`pad_rows × pad_cols`).
    pub data: Vec<T>,
    pub pad_rows: usize,
    pub pad_cols: usize,
    /// Valid (un-padded) extent.
    pub rows: usize,
    pub cols: usize,
}

impl<T: Float> PaddedBatch<T> {
    /// Extract the valid region of a padded row-major result.
    pub fn unpad(result: &[T], pad_cols: usize, rows: usize, cols: usize) -> Vec<T> {
        let mut out = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            out.extend_from_slice(&result[i * pad_cols..i * pad_cols + cols]);
        }
        out
    }
}

/// Pad a row-major `rows × cols` block up to `pad_rows × pad_cols` with
/// zeros (zeros are neutral for the distance/moment kernels; the mask
/// handles the rest).
pub fn pad_to<T: Float>(
    data: &[T],
    rows: usize,
    cols: usize,
    pad_rows: usize,
    pad_cols: usize,
) -> PaddedBatch<T> {
    assert!(pad_rows >= rows && pad_cols >= cols, "padding must grow the block");
    debug_assert_eq!(data.len(), rows * cols);
    let mut out = vec![T::ZERO; pad_rows * pad_cols];
    for i in 0..rows {
        out[i * pad_cols..i * pad_cols + cols].copy_from_slice(&data[i * cols..(i + 1) * cols]);
    }
    PaddedBatch { data: out, pad_rows, pad_cols, rows, cols }
}

/// Split `n` items into tiles of at most `tile` (the row-batching loop
/// that drives artifact execution). Returns `(start, len)` pairs.
pub fn tiles(n: usize, tile: usize) -> Vec<(usize, usize)> {
    assert!(tile > 0);
    let mut out = Vec::with_capacity(n.div_ceil(tile));
    let mut start = 0;
    while start < n {
        let len = tile.min(n - start);
        out.push((start, len));
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_unpad_round_trip() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect(); // 2x3
        let p = pad_to(&data, 2, 3, 4, 8);
        assert_eq!(p.data.len(), 32);
        assert_eq!(p.data[0..3], [0.0, 1.0, 2.0]);
        assert_eq!(p.data[3], 0.0); // padding
        assert_eq!(p.data[8..11], [3.0, 4.0, 5.0]);
        let back = PaddedBatch::unpad(&p.data, 8, 2, 3);
        assert_eq!(back, data);
    }

    #[test]
    fn pad_identity_when_shapes_match() {
        let data = vec![1.0f64, 2.0, 3.0, 4.0];
        let p = pad_to(&data, 2, 2, 2, 2);
        assert_eq!(p.data, data);
    }

    #[test]
    #[should_panic]
    fn pad_cannot_shrink() {
        pad_to(&[1.0f64; 4], 2, 2, 1, 2);
    }

    #[test]
    fn tiles_cover_exactly() {
        assert_eq!(tiles(10, 4), vec![(0, 4), (4, 4), (8, 2)]);
        assert_eq!(tiles(8, 4), vec![(0, 4), (4, 4)]);
        assert_eq!(tiles(3, 10), vec![(0, 3)]);
        assert_eq!(tiles(0, 4), Vec::<(usize, usize)>::new());
    }
}
