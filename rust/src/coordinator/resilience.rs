//! Resilient serving: deterministic retry, a per-model circuit
//! breaker, and the graceful-degradation ladder over
//! [`InferenceSession`] — the layer that turns the fault primitives of
//! the fail-safe PR (typed errors, panic quarantine, budgets,
//! failpoints) into a serving runtime that degrades instead of failing
//! open (`docs/RESILIENCE.md`).
//!
//! ## Retry
//!
//! A super-batch that fails with [`Error::Internal`] — the panic
//! quarantine's verdict, i.e. "a worker blew up, not the input" — is
//! retried against the respawned pool up to
//! [`RetryPolicy::max_attempts`]. Any other error is deterministic
//! (shape, numerical, ...) and is **not** retried. Backoff between
//! attempts is expressed in *budget time*: attempt `k` spins the
//! backoff [`Budget`] `k` times ([`Budget::spin`]), so this module
//! never reads the clock (PAL-CLOCK) and an iteration-cap backoff is
//! fully deterministic. A retried run is bit-identical to an unfaulted
//! run: super-batch cuts are input-keyed, and
//! `InferenceSession::execute_group` writes no live-member result on
//! failure.
//!
//! ## Circuit breaker
//!
//! Classed Closed → Open → HalfOpen, keyed on **consecutive**
//! primary-path super-batch failures (after retries). Count- and
//! budget-driven, never wall-clock in this file: the Open state holds
//! a cooldown [`BudgetMeter`] consumed one checkpoint per arriving
//! super-batch — an iteration-cap cooldown half-opens after exactly
//! `k` degraded batches; a wall-time cooldown half-opens at the first
//! batch past the deadline (the clock read lives in `budget.rs`); an
//! unlimited cooldown never half-opens. The half-open probe runs one
//! primary attempt: success closes the breaker, an `Internal` failure
//! re-opens it with a fresh cooldown.
//!
//! ## Degradation ladder
//!
//! While open, super-batches route down the [`ServeRung`] ladder
//! instead of being rejected outright:
//!
//! ```text
//! Packed (broken) → Repack (per-call pack) → Naive (scalar oracle)
//!                 → fast-reject ServeStatus::Unavailable
//! ```
//!
//! Every rung returns the same bits (the naive rung is the crate's
//! oracle), so degraded service is slower, never different. The
//! degraded rungs execute under their own failpoint site
//! ([`crate::failpoint::SITE_SERVE_DEGRADED`]) and quarantine label,
//! so a persistent fault in the primary path cannot poison the
//! fallbacks. Each hop is counted in [`ResilienceStats`].

use super::budget::{Budget, BudgetMeter};
use super::serve::{
    self, InferenceSession, ServeExecutor, ServeModel, ServeRequest, ServeResult, ServeRung,
};
use super::Context;
use crate::error::Error;

/// Retry policy for quarantined super-batch faults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total primary-path attempts per super-batch (1 ⇒ no retry).
    pub max_attempts: usize,
    /// Backoff between attempts, expressed as a [`Budget`] spun to
    /// expiry; attempt `k` spins it `k` times (linear backoff). The
    /// default unlimited budget waits zero time ([`Budget::spin`]).
    pub backoff: Budget,
}

impl RetryPolicy {
    /// `n` total attempts, no backoff.
    pub fn attempts(n: usize) -> Self {
        Self { max_attempts: n.max(1), backoff: Budget::UNLIMITED }
    }

    pub fn with_backoff(mut self, b: Budget) -> Self {
        self.backoff = b;
        self
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::attempts(1)
    }
}

/// Circuit-breaker policy, keyed on consecutive primary-path failures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BreakerPolicy {
    /// Consecutive failed super-batches (retries exhausted) that trip
    /// Closed → Open.
    pub failure_threshold: usize,
    /// Cooldown before a half-open probe, metered one checkpoint per
    /// super-batch arriving while open. `max_iters(k)` ⇒ exactly `k`
    /// degraded batches before the probe (deterministic);
    /// `max_wall_time` ⇒ first batch past the deadline probes;
    /// unlimited ⇒ the breaker never half-opens.
    pub cooldown: Budget,
}

impl BreakerPolicy {
    /// Trip after `n` consecutive failures; probe after one degraded
    /// batch.
    pub fn threshold(n: usize) -> Self {
        Self { failure_threshold: n.max(1), cooldown: Budget::default().max_iters(1) }
    }

    pub fn with_cooldown(mut self, b: Budget) -> Self {
        self.cooldown = b;
        self
    }
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        Self::threshold(3)
    }
}

/// Observable breaker position (the internal state also carries the
/// cooldown meter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerSnapshot {
    Closed,
    Open,
    HalfOpen,
}

enum BreakerState {
    Closed { consecutive_failures: usize },
    Open { cooldown: BudgetMeter },
    HalfOpen,
}

/// Per-session resilience counters (mirroring the SVM `TrainStats`
/// style): every retry, trip, probe, and degradation hop is counted,
/// so tests assert exact fault accounting instead of sleeping and
/// guessing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResilienceStats {
    /// Super-batches that entered the primary (packed) path.
    pub batches: usize,
    /// Primary-path attempts that failed with a quarantined
    /// [`Error::Internal`] — exactly the injected fault count under
    /// fault injection.
    pub faults: usize,
    /// Re-attempts made after a fault.
    pub retries: usize,
    /// Super-batches that completed on a retry after ≥ 1 fault.
    pub retry_successes: usize,
    /// Closed → Open transitions.
    pub breaker_trips: usize,
    /// Half-open probe attempts.
    pub half_open_probes: usize,
    /// HalfOpen → Closed recoveries.
    pub recoveries: usize,
    /// Super-batches served by the per-call-pack rung while open.
    pub degraded_repack: usize,
    /// Super-batches served by the naive rung while open.
    pub degraded_naive: usize,
    /// Super-batches fast-rejected after the whole ladder failed.
    pub unavailable_batches: usize,
}

/// [`InferenceSession`] wrapped with retry, circuit breaking, and the
/// degradation ladder. Breaker state and counters persist across
/// [`ResilientSession::serve`] calls — the breaker is per model
/// session, like the panel it guards.
pub struct ResilientSession<'m, M: ServeModel> {
    session: InferenceSession<'m, M>,
    retry: RetryPolicy,
    breaker: BreakerPolicy,
    state: BreakerState,
    stats: ResilienceStats,
}

/// Which path the breaker gate routed a super-batch to.
enum Gate {
    Primary,
    Probe,
    Degraded,
}

impl<'m, M: ServeModel> ResilientSession<'m, M> {
    pub fn new(session: InferenceSession<'m, M>) -> Self {
        Self {
            session,
            retry: RetryPolicy::default(),
            breaker: BreakerPolicy::default(),
            state: BreakerState::Closed { consecutive_failures: 0 },
            stats: ResilienceStats::default(),
        }
    }

    pub fn retry(mut self, p: RetryPolicy) -> Self {
        self.retry = p;
        self
    }

    pub fn breaker(mut self, p: BreakerPolicy) -> Self {
        self.breaker = p;
        self
    }

    pub fn stats(&self) -> ResilienceStats {
        self.stats
    }

    pub fn breaker_state(&self) -> BreakerSnapshot {
        match self.state {
            BreakerState::Closed { .. } => BreakerSnapshot::Closed,
            BreakerState::Open { .. } => BreakerSnapshot::Open,
            BreakerState::HalfOpen => BreakerSnapshot::HalfOpen,
        }
    }

    /// The wrapped session (e.g. for planning introspection).
    pub fn session(&self) -> &InferenceSession<'m, M> {
        &self.session
    }

    /// Serve a request set with retry, breaker, and ladder semantics.
    /// Identical coalescing plan and demux order as
    /// [`InferenceSession::serve`]; in the absence of faults the
    /// results are bit-identical to the plain path.
    pub fn serve(&mut self, ctx: &Context, requests: &[ServeRequest]) -> Vec<ServeResult> {
        let (groups, mut meters, mut results) = self.session.init_run(requests);
        for group in &groups {
            self.serve_group(ctx, requests, group, &mut meters, &mut results);
        }
        serve::finalize_results(results)
    }

    fn serve_group(
        &mut self,
        ctx: &Context,
        requests: &[ServeRequest],
        group: &[usize],
        meters: &mut [BudgetMeter],
        results: &mut [Option<ServeResult>],
    ) {
        let gate = match &mut self.state {
            BreakerState::Closed { .. } => Gate::Primary,
            BreakerState::Open { cooldown } => {
                // One cooldown checkpoint per arriving super-batch —
                // count-/budget-driven, never a clock read here.
                if cooldown.check_before_iter().is_some() {
                    self.state = BreakerState::HalfOpen;
                    Gate::Probe
                } else {
                    Gate::Degraded
                }
            }
            BreakerState::HalfOpen => Gate::Probe,
        };
        match gate {
            Gate::Primary => self.serve_primary(ctx, requests, group, meters, results),
            Gate::Probe => self.serve_probe(ctx, requests, group, meters, results),
            Gate::Degraded => self.serve_degraded(ctx, requests, group, meters, results),
        }
    }

    /// Closed breaker: primary path with deterministic retry.
    fn serve_primary(
        &mut self,
        ctx: &Context,
        requests: &[ServeRequest],
        group: &[usize],
        meters: &mut [BudgetMeter],
        results: &mut [Option<ServeResult>],
    ) {
        self.stats.batches += 1;
        let mut attempt = 0usize;
        loop {
            attempt += 1;
            let outcome = self.session.execute_group(
                ctx,
                requests,
                group,
                meters,
                results,
                ServeRung::Packed,
            );
            match outcome {
                Ok(()) => {
                    if attempt > 1 {
                        self.stats.retry_successes += 1;
                    }
                    if let BreakerState::Closed { consecutive_failures } = &mut self.state {
                        *consecutive_failures = 0;
                    }
                    return;
                }
                Err(Error::Internal(_)) if attempt < self.retry.max_attempts => {
                    // Quarantined fault: the pool respawns lazily at
                    // the next batch, so the retry runs against a
                    // healthy pool. Back off in budget time, then go
                    // again.
                    self.stats.faults += 1;
                    self.stats.retries += 1;
                    self.backoff(attempt);
                }
                Err(e) => {
                    let is_fault = matches!(e, Error::Internal(_));
                    if is_fault {
                        self.stats.faults += 1;
                    }
                    if is_fault && self.note_failure() {
                        // Retries exhausted AND the trip threshold hit:
                        // this batch already rides the ladder down.
                        self.serve_degraded(ctx, requests, group, meters, results);
                    } else {
                        // Deterministic (non-Internal) errors fail
                        // immediately and never count toward the
                        // breaker — retrying a shape mismatch cannot
                        // help.
                        let msg = e.to_string();
                        serve::settle_unsettled(group, results, || {
                            ServeResult::failed(msg.clone())
                        });
                    }
                    return;
                }
            }
        }
    }

    /// Half-open breaker: one unretried primary probe.
    fn serve_probe(
        &mut self,
        ctx: &Context,
        requests: &[ServeRequest],
        group: &[usize],
        meters: &mut [BudgetMeter],
        results: &mut [Option<ServeResult>],
    ) {
        self.stats.half_open_probes += 1;
        self.stats.batches += 1;
        let outcome =
            self.session.execute_group(ctx, requests, group, meters, results, ServeRung::Packed);
        match outcome {
            Ok(()) => {
                self.state = BreakerState::Closed { consecutive_failures: 0 };
                self.stats.recoveries += 1;
            }
            Err(Error::Internal(_)) => {
                // Probe failed: re-open with a fresh cooldown; this
                // batch still gets degraded service.
                self.stats.faults += 1;
                self.state = BreakerState::Open { cooldown: self.breaker.cooldown.meter() };
                self.serve_degraded(ctx, requests, group, meters, results);
            }
            Err(e) => {
                // Deterministic error: not a breaker signal. Fail the
                // batch; the next one probes again.
                let msg = e.to_string();
                serve::settle_unsettled(group, results, || ServeResult::failed(msg.clone()));
            }
        }
    }

    /// Open breaker: walk the degradation ladder —
    /// per-call-pack → naive → fast-reject.
    fn serve_degraded(
        &mut self,
        ctx: &Context,
        requests: &[ServeRequest],
        group: &[usize],
        meters: &mut [BudgetMeter],
        results: &mut [Option<ServeResult>],
    ) {
        if self
            .session
            .execute_group(ctx, requests, group, meters, results, ServeRung::Repack)
            .is_ok()
        {
            self.stats.degraded_repack += 1;
            return;
        }
        match self.session.execute_group(ctx, requests, group, meters, results, ServeRung::Naive)
        {
            Ok(()) => self.stats.degraded_naive += 1,
            Err(e) => {
                // Ladder exhausted: fast-reject with a typed outcome
                // instead of burning more attempts.
                self.stats.unavailable_batches += 1;
                let msg = format!("serve: circuit open, degradation ladder exhausted ({e})");
                serve::settle_unsettled(group, results, || {
                    ServeResult::unavailable(msg.clone())
                });
            }
        }
    }

    /// Record an exhausted-retries primary failure; returns true iff
    /// the breaker just tripped.
    fn note_failure(&mut self) -> bool {
        if let BreakerState::Closed { consecutive_failures } = &mut self.state {
            *consecutive_failures += 1;
            if *consecutive_failures >= self.breaker.failure_threshold {
                self.state = BreakerState::Open { cooldown: self.breaker.cooldown.meter() };
                self.stats.breaker_trips += 1;
                return true;
            }
        }
        false
    }

    /// Linear budget-time backoff before re-attempt `attempt + 1`.
    fn backoff(&self, attempt: usize) {
        for _ in 0..attempt {
            self.retry.backoff.spin();
        }
    }
}

impl<M: ServeModel> ServeExecutor for ResilientSession<'_, M> {
    fn serve_all(&mut self, ctx: &Context, requests: &[ServeRequest]) -> Vec<ServeResult> {
        self.serve(ctx, requests)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Context, ServeStatus};
    use crate::error::Result;
    use crate::tables::DenseTable;
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// Row-sum model that fails its first `fail_first` calls on the
    /// given rungs with `Error::Internal` — a deterministic stand-in
    /// for the panic quarantine that needs no process-global failpoint
    /// (those are exercised in `tests/chaos.rs`).
    struct Flaky {
        d: usize,
        fail_packed: usize,
        fail_repack_always: bool,
        fail_naive_always: bool,
        packed_calls: AtomicUsize,
    }

    impl Flaky {
        fn new(d: usize, fail_packed: usize) -> Self {
            Self {
                d,
                fail_packed,
                fail_repack_always: false,
                fail_naive_always: false,
                packed_calls: AtomicUsize::new(0),
            }
        }

        fn rowsum(q: &DenseTable<f64>) -> Vec<f64> {
            (0..q.rows()).map(|i| q.row(i).iter().sum()).collect()
        }
    }

    impl ServeModel for Flaky {
        fn serve_dims(&self) -> usize {
            self.d
        }

        fn serve_batch(&self, _ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
            let n = self.packed_calls.fetch_add(1, Ordering::Relaxed);
            if n < self.fail_packed {
                return Err(Error::Internal("flaky: injected packed fault".into()));
            }
            Ok(Self::rowsum(q))
        }

        fn serve_batch_rung(
            &self,
            ctx: &Context,
            q: &DenseTable<f64>,
            rung: ServeRung,
        ) -> Result<Vec<f64>> {
            match rung {
                ServeRung::Packed => self.serve_batch(ctx, q),
                ServeRung::Repack => {
                    if self.fail_repack_always {
                        Err(Error::Internal("flaky: injected repack fault".into()))
                    } else {
                        Ok(Self::rowsum(q))
                    }
                }
                ServeRung::Naive => {
                    if self.fail_naive_always {
                        Err(Error::Internal("flaky: injected naive fault".into()))
                    } else {
                        Ok(Self::rowsum(q))
                    }
                }
            }
        }
    }

    fn ctx() -> Context {
        Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .build()
            .unwrap()
    }

    fn req(rows: usize, cols: usize, fill: f64) -> ServeRequest {
        ServeRequest::new(vec![fill; rows * cols], rows, cols).unwrap()
    }

    fn assert_bitwise(a: &[ServeResult], b: &[ServeResult]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.status, y.status);
            match (&x.output, &y.output) {
                (Some(u), Some(v)) => {
                    assert_eq!(u.len(), v.len());
                    for (p, q) in u.iter().zip(v) {
                        assert_eq!(p.to_bits(), q.to_bits());
                    }
                }
                (None, None) => {}
                _ => panic!("outputs diverged"),
            }
        }
    }

    #[test]
    fn faulted_then_retried_is_bit_identical_to_unfaulted() {
        let requests: Vec<ServeRequest> = (0..4).map(|i| req(2, 3, i as f64)).collect();
        let c = ctx();
        let clean = Flaky::new(3, 0);
        let baseline = InferenceSession::new(&clean).tile(4).serve(&c, &requests);
        // One fault on the first packed call; two attempts allowed.
        let flaky = Flaky::new(3, 1);
        let mut rs = ResilientSession::new(InferenceSession::new(&flaky).tile(4))
            .retry(RetryPolicy::attempts(2).with_backoff(Budget::default().max_iters(4)));
        let served = rs.serve(&c, &requests);
        assert_bitwise(&served, &baseline);
        let st = rs.stats();
        assert_eq!(st.faults, 1, "exactly the injected fault count");
        assert_eq!(st.retries, 1);
        assert_eq!(st.retry_successes, 1);
        assert_eq!(st.breaker_trips, 0);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Closed);
    }

    #[test]
    fn non_internal_errors_are_not_retried_and_do_not_trip() {
        struct Deterministic;
        impl ServeModel for Deterministic {
            fn serve_dims(&self) -> usize {
                2
            }
            fn serve_batch(&self, _ctx: &Context, _q: &DenseTable<f64>) -> Result<Vec<f64>> {
                Err(Error::Numerical("always".into()))
            }
        }
        let model = Deterministic;
        let mut rs = ResilientSession::new(InferenceSession::new(&model))
            .retry(RetryPolicy::attempts(5))
            .breaker(BreakerPolicy::threshold(1));
        let served = rs.serve(&ctx(), &[req(1, 2, 1.0)]);
        assert_eq!(served[0].status, ServeStatus::Failed);
        let st = rs.stats();
        assert_eq!(st.faults, 0);
        assert_eq!(st.retries, 0);
        assert_eq!(st.breaker_trips, 0);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Closed);
    }

    #[test]
    fn breaker_trips_after_threshold_and_ladder_serves_repack() {
        // Packed path always fails; repack works. Threshold 2, no
        // retries.
        let flaky = Flaky { fail_packed: usize::MAX, ..Flaky::new(2, 0) };
        let requests: Vec<ServeRequest> = (0..4).map(|i| req(1, 2, i as f64)).collect();
        let c = ctx();
        // One request per super-batch so each is one breaker event.
        let mut rs = ResilientSession::new(InferenceSession::new(&flaky).max_super_rows(1))
            .breaker(BreakerPolicy::threshold(2).with_cooldown(Budget::default().max_iters(99)));
        let served = rs.serve(&c, &requests);
        // Batch 0: fail (1/2). Batch 1: fail → trip → rides ladder.
        // Batches 2, 3: open → degraded repack.
        assert_eq!(served[0].status, ServeStatus::Failed);
        assert_eq!(served[1].status, ServeStatus::Completed);
        assert_eq!(served[2].status, ServeStatus::Completed);
        assert_eq!(served[3].status, ServeStatus::Completed);
        let st = rs.stats();
        assert_eq!(st.breaker_trips, 1);
        assert_eq!(st.degraded_repack, 3);
        assert_eq!(st.faults, 2);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Open);
        // Degraded outputs carry the same bits as a healthy run.
        let clean = Flaky::new(2, 0);
        let baseline = InferenceSession::new(&clean).max_super_rows(1).serve(&c, &requests);
        for i in 1..4 {
            assert_eq!(
                served[i].output.as_deref().unwrap(),
                baseline[i].output.as_deref().unwrap()
            );
        }
    }

    #[test]
    fn ladder_escalates_to_naive_then_unavailable() {
        // Packed and repack both fail; naive works.
        let mut flaky = Flaky { fail_packed: usize::MAX, ..Flaky::new(2, 0) };
        flaky.fail_repack_always = true;
        let c = ctx();
        let requests: Vec<ServeRequest> = (0..2).map(|i| req(1, 2, i as f64)).collect();
        let mut rs = ResilientSession::new(InferenceSession::new(&flaky).max_super_rows(1))
            .breaker(BreakerPolicy::threshold(1).with_cooldown(Budget::default().max_iters(99)));
        let served = rs.serve(&c, &requests);
        assert_eq!(served[0].status, ServeStatus::Completed, "trip batch rides the ladder");
        assert_eq!(served[1].status, ServeStatus::Completed);
        assert_eq!(rs.stats().degraded_naive, 2);
        assert_eq!(rs.stats().degraded_repack, 0);
        // Now break the whole ladder: fast-reject with Unavailable.
        let mut dead = Flaky { fail_packed: usize::MAX, ..Flaky::new(2, 0) };
        dead.fail_repack_always = true;
        dead.fail_naive_always = true;
        let mut rs = ResilientSession::new(InferenceSession::new(&dead).max_super_rows(1))
            .breaker(BreakerPolicy::threshold(1).with_cooldown(Budget::default().max_iters(99)));
        let served = rs.serve(&c, &requests);
        assert_eq!(served[0].status, ServeStatus::Unavailable);
        assert_eq!(served[1].status, ServeStatus::Unavailable);
        assert!(served[1].error.as_deref().is_some_and(|e| e.contains("ladder")));
        assert_eq!(rs.stats().unavailable_batches, 2);
    }

    #[test]
    fn half_open_probe_recovers_after_cooldown() {
        // Packed fails for the first 2 calls, then heals.
        let flaky = Flaky::new(2, 2);
        let c = ctx();
        let one = |fill: f64| vec![req(1, 2, fill)];
        let mut rs = ResilientSession::new(InferenceSession::new(&flaky).max_super_rows(1))
            .breaker(BreakerPolicy::threshold(2).with_cooldown(Budget::default().max_iters(1)));
        // Two failures trip the breaker (second batch rides the ladder).
        assert_eq!(rs.serve(&c, &one(1.0))[0].status, ServeStatus::Failed);
        assert_eq!(rs.serve(&c, &one(2.0))[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Open);
        // Cooldown max_iters(1): exactly one degraded batch, then the
        // next one probes.
        assert_eq!(rs.serve(&c, &one(3.0))[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Open);
        assert_eq!(rs.stats().degraded_repack, 2);
        // Probe batch: the model has healed; primary path serves it.
        let probed = rs.serve(&c, &one(4.0));
        assert_eq!(probed[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Closed);
        let st = rs.stats();
        assert_eq!(st.half_open_probes, 1);
        assert_eq!(st.recoveries, 1);
        assert_eq!(st.breaker_trips, 1);
        assert_eq!(st.faults, 2, "exactly the injected fault count");
        // Closed again: clean primary service.
        assert_eq!(rs.serve(&c, &one(5.0))[0].status, ServeStatus::Completed);
    }

    #[test]
    fn failed_probe_reopens_with_fresh_cooldown() {
        // Packed fails for the first 3 calls: the trip batch consumes
        // one, the two failed probes the rest; the fourth call heals.
        let flaky = Flaky::new(2, 3);
        let c = ctx();
        let one = |fill: f64| vec![req(1, 2, fill)];
        let mut rs = ResilientSession::new(InferenceSession::new(&flaky).max_super_rows(1))
            .breaker(BreakerPolicy::threshold(1).with_cooldown(Budget::default().max_iters(0)));
        // Trip on the first batch (rides the ladder down).
        assert_eq!(rs.serve(&c, &one(1.0))[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Open);
        // Cooldown max_iters(0) expires immediately ⇒ next batch is a
        // probe; the model still fails ⇒ re-open, batch degrades.
        assert_eq!(rs.serve(&c, &one(2.0))[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Open);
        // Second probe consumes the third (last) fault and re-opens;
        // the probe after it runs against a healed model.
        assert_eq!(rs.serve(&c, &one(3.0))[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Open, "probe 2 failed: reopen");
        assert_eq!(rs.serve(&c, &one(4.0))[0].status, ServeStatus::Completed);
        assert_eq!(rs.breaker_state(), BreakerSnapshot::Closed, "probe 3 heals");
        let st = rs.stats();
        assert_eq!(st.half_open_probes, 3);
        assert_eq!(st.recoveries, 1);
        assert_eq!(st.faults, 3);
    }

    #[test]
    fn queued_front_end_composes_with_the_resilient_session() {
        use crate::coordinator::serve::QueuedSession;
        let flaky = Flaky::new(2, 1);
        let c = ctx();
        let rs = ResilientSession::new(InferenceSession::new(&flaky))
            .retry(RetryPolicy::attempts(2));
        let mut q = QueuedSession::new(rs, 4);
        for i in 0..4 {
            q.submit(req(1, 2, i as f64)).unwrap();
        }
        let results = q.drain(&c);
        assert!(results.iter().all(|r| r.status == ServeStatus::Completed));
        assert_eq!(q.into_inner().stats().faults, 1);
    }
}
