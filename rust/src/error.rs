//! Crate-wide error type (hand-rolled `Display`/`Error` impls — the
//! default build carries zero external dependencies so it compiles in
//! the offline image).

use std::fmt;

/// Errors surfaced by the public API.
///
/// Marked `#[non_exhaustive]`: downstream matches must carry a
/// wildcard arm, so future fault categories can be added without a
/// breaking release.
#[derive(Debug)]
#[non_exhaustive]
pub enum Error {
    /// Shape or dimension mismatch between inputs.
    Shape(String),
    /// Invalid algorithm parameter.
    Param(String),
    /// Numerical failure (singular matrix, non-convergence, ...).
    Numerical(String),
    /// I/O failure (CSV load, artifact read, ...).
    Io(std::io::Error),
    /// CSV parse failure.
    Parse(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Requested artifact missing from the registry (run `make artifacts`).
    MissingArtifact(String),
    /// A panic escaped an internal kernel and was quarantined at the
    /// public boundary ([`crate::parallel::quarantine`]); carries the
    /// fan-out site and the panic payload message.
    Internal(String),
    /// A [`crate::coordinator::Budget`] wall-time deadline expired in a
    /// context where no partial result could be returned. Iterative
    /// trainers do NOT return this — they return a best-so-far model
    /// tagged [`crate::coordinator::ConvergenceStatus::DeadlineExceeded`].
    DeadlineExceeded(String),
    /// The operation was cancelled before producing a result.
    Cancelled(String),
    /// Rejected at admission: the serving front end's bounded queue
    /// was full ([`crate::coordinator::serve::QueuedSession`]). The
    /// typed form of load shedding — callers should back off and
    /// resubmit rather than treat this as a model failure.
    Overloaded(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Param(s) => write!(f, "invalid parameter: {s}"),
            Error::Numerical(s) => write!(f, "numerical error: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Parse(s) => write!(f, "parse error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::MissingArtifact(s) => write!(f, "missing artifact: {s} (run `make artifacts`)"),
            Error::Internal(s) => write!(f, "internal error: {s}"),
            Error::DeadlineExceeded(s) => write!(f, "deadline exceeded: {s}"),
            Error::Cancelled(s) => write!(f, "cancelled: {s}"),
            Error::Overloaded(s) => write!(f, "overloaded: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(feature = "runtime-xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_match_contract() {
        assert_eq!(Error::Shape("a".into()).to_string(), "shape mismatch: a");
        assert_eq!(Error::Param("b".into()).to_string(), "invalid parameter: b");
        assert!(Error::MissingArtifact("k".into()).to_string().contains("make artifacts"));
        assert_eq!(Error::Internal("site: boom".into()).to_string(), "internal error: site: boom");
        assert_eq!(Error::DeadlineExceeded("x".into()).to_string(), "deadline exceeded: x");
        assert_eq!(Error::Cancelled("y".into()).to_string(), "cancelled: y");
        assert_eq!(Error::Overloaded("z".into()).to_string(), "overloaded: z");
    }

    #[test]
    fn new_variants_have_no_source() {
        for e in [
            Error::Internal("a".into()),
            Error::DeadlineExceeded("b".into()),
            Error::Cancelled("c".into()),
            Error::Overloaded("d".into()),
        ] {
            assert!(std::error::Error::source(&e).is_none());
        }
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
