//! Crate-wide error type.

/// Errors surfaced by the public API.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape or dimension mismatch between inputs.
    #[error("shape mismatch: {0}")]
    Shape(String),
    /// Invalid algorithm parameter.
    #[error("invalid parameter: {0}")]
    Param(String),
    /// Numerical failure (singular matrix, non-convergence, ...).
    #[error("numerical error: {0}")]
    Numerical(String),
    /// I/O failure (CSV load, artifact read, ...).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    /// CSV parse failure.
    #[error("parse error: {0}")]
    Parse(String),
    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// Requested artifact missing from the registry (run `make artifacts`).
    #[error("missing artifact: {0} (run `make artifacts`)")]
    MissingArtifact(String),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}
