//! Vector Statistical Library (VSL) substrate — paper §IV-C.
//!
//! oneDAL's summary-statistics kernels were MKL-VSL calls; on ARM the
//! paper reimplements the two routines oneDAL actually needs:
//!
//! * [`x2c_mom`] — per-coordinate variance through raw moments
//!   (eq. 3: `v = S²/(n−1) − (S¹)²/(n(n−1))`), replacing the two-pass
//!   mean-then-variance formulation (eqs. 1–2) kept here as
//!   [`x2c_mom_naive`] for the ablation benches;
//! * [`XcpState`] — the batched cross-product matrix update of eq. 6:
//!   `C ← C' + S'(S')ᵀ/n' − S·Sᵀ/n + X·Xᵀ`, the streaming kernel behind
//!   oneDAL's online covariance / PCA / linear-regression pipelines.
//!
//! Data layout matches the paper: `X ∈ ℝ^{p×n}` with each **column** a
//! p-dimensional observation (row-major storage, so row `i` holds
//! coordinate `i` of every observation — unit-stride reductions).

pub mod moments;
pub mod xcp;

pub use moments::{
    x2c_mom, x2c_mom_csr, x2c_mom_csr_threads, x2c_mom_naive, x2c_mom_threads, Moments,
};
pub use xcp::{xcp_full, XcpState};
