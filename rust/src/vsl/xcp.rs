//! `xcp` — batched cross-product matrix, §IV-C-2.
//!
//! The cross-product matrix `C ∈ ℝ^{p×p}` of a `p×n` dataset is
//! `Cᵢⱼ = Σₖ (Xᵢₖ − μᵢ)(Xⱼₖ − μⱼ)` (eq. 4). The paper's streaming form
//! (eq. 6) updates a previously computed `C'` with a new batch `X`
//! without re-centering old data:
//!
//! ```text
//!   C ← C' + S'·(S')ᵀ/n'  −  S·Sᵀ/n  +  X·Xᵀ
//! ```
//!
//! where `S'` is the raw sum before the batch, `S` the cumulative raw
//! sum after it. `X·Xᵀ` is a rank-k update delegated to BLAS
//! ([`crate::blas::syrk`]) — "Leveraging BLAS routines … memory-efficient
//! computation" — which is exactly the MXU contraction our Pallas `xcp`
//! kernel performs on the artifact path.

use crate::blas::{ger, syrk_threads};
use crate::dtype::Float;
use crate::error::{Error, Result};
use crate::tables::DenseTable;

/// Streaming cross-product accumulator (the VSL "task object" analogue:
/// it owns the operation state across `update` calls).
#[derive(Clone, Debug)]
pub struct XcpState<T> {
    p: usize,
    n: usize,
    /// Cumulative raw sum `S` (length p).
    sum: Vec<T>,
    /// Centered cross-product matrix `C` (p×p, row-major, symmetric).
    cross: Vec<T>,
}

impl<T: Float> XcpState<T> {
    /// Fresh state for `p` coordinates.
    pub fn new(p: usize) -> Self {
        Self { p, n: 0, sum: vec![T::ZERO; p], cross: vec![T::ZERO; p * p] }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    /// Observations folded in so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Cumulative raw sum `S`.
    pub fn sum(&self) -> &[T] {
        &self.sum
    }

    /// The centered cross-product matrix `C` (valid once `n ≥ 1`).
    pub fn cross_product(&self) -> &[T] {
        &self.cross
    }

    /// Fold a batch `X ∈ ℝ^{p×n_b}` (columns = observations) via eq. 6,
    /// on the process-default worker count. Callers holding a `Context`
    /// should prefer [`XcpState::update_threads`].
    pub fn update(&mut self, batch: &DenseTable<T>) -> Result<()> {
        self.update_threads(batch, crate::parallel::default_threads())
    }

    /// [`XcpState::update`] with an explicit worker count — the `X·Xᵀ`
    /// rank-k term is the dominant cost and runs on the parallel packed
    /// SYRK engine.
    pub fn update_threads(&mut self, batch: &DenseTable<T>, threads: usize) -> Result<()> {
        if batch.rows() != self.p {
            return Err(Error::Shape(format!(
                "xcp: batch has {} coordinates, state has {}",
                batch.rows(),
                self.p
            )));
        }
        let nb = batch.cols();
        if nb == 0 {
            return Ok(());
        }
        let n_old = self.n;
        let n_new = n_old + nb;

        // C += S'·(S')ᵀ/n'   (skipped on the first batch: n' = 0)
        if n_old > 0 {
            let inv = T::ONE / T::from_usize(n_old);
            let s_old = self.sum.clone();
            ger(self.p, self.p, inv, &s_old, &s_old, &mut self.cross);
        }

        // C += X·Xᵀ  (batch raw cross-product; BLAS rank-nb update —
        // `cross` is symmetric by invariant, so the accumulate-and-mirror
        // contract of the packed syrk holds). Streaming state carries no
        // `Context`, so the syrk runs at the process-default lane
        // profile — fine for determinism: every batch of one state sees
        // the same profile, and the state never mixes packed buffers.
        syrk_threads(self.p, nb, T::ONE, batch.data(), T::ONE, &mut self.cross, threads);

        // S ← S' + row-sums(X)
        for i in 0..self.p {
            let mut s = T::ZERO;
            for &v in batch.row(i) {
                s += v;
            }
            self.sum[i] += s;
        }

        // C −= S·Sᵀ/n
        let inv = T::ONE / T::from_usize(n_new);
        let s_new = self.sum.clone();
        ger(self.p, self.p, -inv, &s_new, &s_new, &mut self.cross);

        self.n = n_new;
        Ok(())
    }

    /// Sample covariance `C/(n−1)`.
    pub fn covariance(&self) -> Result<DenseTable<T>> {
        if self.n < 2 {
            return Err(Error::Numerical("xcp: need ≥ 2 observations for covariance".into()));
        }
        let inv = T::ONE / T::from_usize(self.n - 1);
        let data = self.cross.iter().map(|&v| v * inv).collect();
        DenseTable::from_vec(data, self.p, self.p)
    }

    /// Pearson correlation matrix derived from the cross-product.
    pub fn correlation(&self) -> Result<DenseTable<T>> {
        let cov = self.covariance()?;
        let mut out = DenseTable::zeros(self.p, self.p);
        for i in 0..self.p {
            for j in 0..self.p {
                let d = (cov.get(i, i) * cov.get(j, j)).sqrt();
                let v = if d > T::ZERO { cov.get(i, j) / d } else { T::ZERO };
                out.set(i, j, v);
            }
        }
        Ok(out)
    }
}

/// One-shot centered cross-product of a full `p×n` dataset (the
/// non-streaming entry point; also the test oracle for the batched path).
pub fn xcp_full<T: Float>(x: &DenseTable<T>) -> Result<DenseTable<T>> {
    let mut st = XcpState::new(x.rows());
    st.update(x)?;
    DenseTable::from_vec(st.cross.clone(), x.rows(), x.rows())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Engine, Gaussian, Mt19937};

    fn dataset(seed: u32, p: usize, n: usize) -> DenseTable<f64> {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::new(-1.0, 2.0);
        let mut d = vec![0.0; p * n];
        g.fill(&mut e, &mut d);
        DenseTable::from_vec(d, p, n).unwrap()
    }

    /// Direct eq. 4 oracle.
    fn direct_xcp(x: &DenseTable<f64>) -> Vec<f64> {
        let p = x.rows();
        let n = x.cols();
        let mu: Vec<f64> = (0..p).map(|i| x.row(i).iter().sum::<f64>() / n as f64).collect();
        let mut c = vec![0.0; p * p];
        for i in 0..p {
            for j in 0..p {
                let mut acc = 0.0;
                for k in 0..n {
                    acc += (x.get(i, k) - mu[i]) * (x.get(j, k) - mu[j]);
                }
                c[i * p + j] = acc;
            }
        }
        c
    }

    fn col_split(x: &DenseTable<f64>, cuts: &[usize]) -> Vec<DenseTable<f64>> {
        let p = x.rows();
        let mut out = Vec::new();
        let mut lo = 0;
        for &hi in cuts.iter().chain(std::iter::once(&x.cols())) {
            let mut t = DenseTable::zeros(p, hi - lo);
            for i in 0..p {
                t.row_mut(i).copy_from_slice(&x.row(i)[lo..hi]);
            }
            out.push(t);
            lo = hi;
        }
        out
    }

    #[test]
    fn single_batch_matches_direct() {
        let x = dataset(1, 6, 200);
        let c = xcp_full(&x).unwrap();
        let cref = direct_xcp(&x);
        for (u, v) in c.data().iter().zip(&cref) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn two_batches_match_whole_eq6() {
        let x = dataset(2, 5, 300);
        let whole = direct_xcp(&x);
        let parts = col_split(&x, &[120]);
        let mut st = XcpState::new(5);
        for part in &parts {
            st.update(part).unwrap();
        }
        assert_eq!(st.n(), 300);
        for (u, v) in st.cross_product().iter().zip(&whole) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    /// Property: any random batch partition yields the same C (the eq. 6
    /// invariant the paper's online mode depends on).
    #[test]
    fn property_batching_invariance() {
        let mut e = Mt19937::new(55);
        for trial in 0..10u32 {
            let p = 2 + (e.next_u32() % 6) as usize;
            let n = 50 + (e.next_u32() % 200) as usize;
            let x = dataset(300 + trial, p, n);
            let whole = direct_xcp(&x);
            // random cut points
            let mut cuts: Vec<usize> = (0..(e.next_u32() % 4))
                .map(|_| 1 + (e.next_u32() as usize) % (n - 1))
                .collect();
            cuts.sort_unstable();
            cuts.dedup();
            let mut st = XcpState::new(p);
            for part in col_split(&x, &cuts) {
                st.update(&part).unwrap();
            }
            for (u, v) in st.cross_product().iter().zip(&whole) {
                assert!((u - v).abs() < 1e-7 * (1.0 + v.abs()), "p={p} n={n} cuts={cuts:?}");
            }
        }
    }

    #[test]
    fn covariance_and_correlation() {
        let x = dataset(3, 4, 500);
        let mut st = XcpState::new(4);
        st.update(&x).unwrap();
        let cov = st.covariance().unwrap();
        // Diagonal of covariance == per-coordinate variance from x2c_mom.
        let m = crate::vsl::x2c_mom(&x).unwrap();
        for i in 0..4 {
            assert!((cov.get(i, i) - m.variance[i]).abs() < 1e-8);
        }
        let corr = st.correlation().unwrap();
        for i in 0..4 {
            assert!((corr.get(i, i) - 1.0).abs() < 1e-10);
            for j in 0..4 {
                assert!(corr.get(i, j).abs() <= 1.0 + 1e-12);
                assert!((corr.get(i, j) - corr.get(j, i)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn empty_batch_is_noop() {
        let x = dataset(4, 3, 100);
        let mut a = XcpState::new(3);
        a.update(&x).unwrap();
        let before = a.cross_product().to_vec();
        a.update(&DenseTable::zeros(3, 0)).unwrap();
        assert_eq!(a.cross_product(), &before[..]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let mut st = XcpState::<f64>::new(3);
        assert!(st.update(&DenseTable::zeros(4, 10)).is_err());
    }

    #[test]
    fn covariance_needs_two_observations() {
        let mut st = XcpState::<f64>::new(2);
        st.update(&DenseTable::zeros(2, 1)).unwrap();
        assert!(st.covariance().is_err());
    }
}
