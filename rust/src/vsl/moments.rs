//! `x2c_mom` — central second moment (variance) per coordinate, §IV-C-1.
//!
//! CSR tables are first-class: [`x2c_mom_csr`] reduces the two raw
//! moments over the **stored** values only (an implicit zero adds
//! nothing to `S¹` or `S²`), with the full observation count `n`
//! supplying the implicit-zero correction when the raw sums are
//! finalized into mean and variance — exact moments of the densified
//! table from one sweep of the nnz entries.

use crate::dtype::Float;
use crate::error::{Error, Result};
use crate::sparse::CsrMatrix;
use crate::tables::DenseTable;

/// Raw + central moments of a `p×n` dataset (columns = observations).
#[derive(Clone, Debug)]
pub struct Moments<T> {
    /// Observation count `n`.
    pub n: usize,
    /// First raw moment per coordinate: `S¹ᵢ = Σⱼ Xᵢⱼ`.
    pub sum: Vec<T>,
    /// Second raw moment per coordinate: `S²ᵢ = Σⱼ Xᵢⱼ²`.
    pub sumsq: Vec<T>,
    /// Sample mean `μᵢ = S¹ᵢ / n`.
    pub mean: Vec<T>,
    /// Sample variance `vᵢ` (unbiased, `n−1` denominator).
    pub variance: Vec<T>,
}

impl<T: Float> Moments<T> {
    /// Merge partial moments from a second batch (the online pattern the
    /// raw-moment formulation enables — recomputation-free, §IV-C-1).
    pub fn merge(&mut self, other: &Moments<T>) {
        assert_eq!(self.sum.len(), other.sum.len());
        self.n += other.n;
        for (a, &b) in self.sum.iter_mut().zip(&other.sum) {
            *a += b;
        }
        for (a, &b) in self.sumsq.iter_mut().zip(&other.sumsq) {
            *a += b;
        }
        finalize(self.n, &self.sum, &self.sumsq, &mut self.mean, &mut self.variance);
    }
}

/// Derive mean/variance from raw moments via eq. 3.
fn finalize<T: Float>(n: usize, sum: &[T], sumsq: &[T], mean: &mut Vec<T>, variance: &mut Vec<T>) {
    let nf = T::from_usize(n);
    mean.clear();
    mean.extend(sum.iter().map(|&s| s / nf));
    variance.clear();
    if n < 2 {
        variance.resize(sum.len(), T::ZERO);
        return;
    }
    let inv_nm1 = T::ONE / T::from_usize(n - 1);
    let inv_n_nm1 = T::ONE / (nf * T::from_usize(n - 1));
    variance.extend(
        sum.iter()
            .zip(sumsq)
            .map(|(&s1, &s2)| s2 * inv_nm1 - s1 * s1 * inv_n_nm1),
    );
}

/// Raw-moment variance kernel (eq. 3): one pass, two running sums per
/// coordinate, 4-way unrolled over observations — the shape the paper
/// vectorizes with SVE (and our Pallas `moments` kernel mirrors).
/// Runs on the process-default worker count; callers holding a
/// `Context` should prefer [`x2c_mom_threads`].
pub fn x2c_mom<T: Float>(x: &DenseTable<T>) -> Result<Moments<T>> {
    x2c_mom_threads(x, crate::parallel::default_threads())
}

/// [`x2c_mom`] with an explicit worker count: coordinates (rows of the
/// p×n layout) are independent, so workers each reduce a contiguous
/// coordinate range. Every coordinate's two running sums are computed
/// whole by one worker in the same order, so results are bit-identical
/// at any worker count.
pub fn x2c_mom_threads<T: Float>(x: &DenseTable<T>, threads: usize) -> Result<Moments<T>> {
    let p = x.rows();
    let n = x.cols();
    if n == 0 {
        return Err(Error::Shape("x2c_mom: empty dataset".into()));
    }
    let mut sum = vec![T::ZERO; p];
    let mut sumsq = vec![T::ZERO; p];
    let workers = crate::parallel::effective_threads(threads, p.saturating_mul(n), 1 << 14);
    let bounds = crate::parallel::even_bounds(p, workers);
    let partials = crate::parallel::par_map(&bounds, |lo, hi| {
        let mut psum = vec![T::ZERO; hi - lo];
        let mut psumsq = vec![T::ZERO; hi - lo];
        for i in lo..hi {
            let row = x.row(i);
            // Dual accumulators per moment break the dependence chain.
            let (mut s0, mut s1, mut q0, mut q1) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
            let chunks = n / 2;
            for c in 0..chunks {
                let a = row[2 * c];
                let b = row[2 * c + 1];
                s0 += a;
                s1 += b;
                q0 = a.mul_add(a, q0);
                q1 = b.mul_add(b, q1);
            }
            if n % 2 == 1 {
                let a = row[n - 1];
                s0 += a;
                q0 = a.mul_add(a, q0);
            }
            psum[i - lo] = s0 + s1;
            psumsq[i - lo] = q0 + q1;
        }
        (lo, psum, psumsq)
    });
    for (lo, psum, psumsq) in partials {
        sum[lo..lo + psum.len()].copy_from_slice(&psum);
        sumsq[lo..lo + psumsq.len()].copy_from_slice(&psumsq);
    }
    let mut mean = Vec::new();
    let mut variance = Vec::new();
    finalize(n, &sum, &sumsq, &mut mean, &mut variance);
    Ok(Moments { n, sum, sumsq, mean, variance })
}

/// [`x2c_mom`] for a CSR table in the same `p × n` orientation (rows =
/// coordinates, columns = observations), on the process-default worker
/// count.
pub fn x2c_mom_csr<T: Float>(x: &CsrMatrix<T>) -> Result<Moments<T>> {
    x2c_mom_csr_threads(x, crate::parallel::default_threads())
}

/// [`x2c_mom_csr`] with an explicit worker count: each coordinate's two
/// raw sums reduce over its **stored** values only (single accumulator
/// per moment, ascending stored order — implicit zeros are exact
/// no-ops), then [`finalize`] applies the observation count `n` of the
/// full table, which is the entire implicit-zero correction the
/// raw-moment formulation needs. Coordinates partition whole per
/// worker — bit-identical at any worker count.
pub fn x2c_mom_csr_threads<T: Float>(x: &CsrMatrix<T>, threads: usize) -> Result<Moments<T>> {
    let p = x.rows();
    let n = x.cols();
    if n == 0 {
        return Err(Error::Shape("x2c_mom: empty dataset".into()));
    }
    let mut sum = vec![T::ZERO; p];
    let mut sumsq = vec![T::ZERO; p];
    let workers = crate::parallel::effective_threads(threads, x.nnz().max(p), 1 << 14);
    let bounds = crate::parallel::even_bounds(p, workers);
    let partials = crate::parallel::par_map(&bounds, |lo, hi| {
        let pairs: Vec<(T, T)> = (lo..hi)
            .map(|i| {
                let (mut s, mut q) = (T::ZERO, T::ZERO);
                for (_, v) in x.row_entries(i) {
                    s += v;
                    q = v.mul_add(v, q);
                }
                (s, q)
            })
            .collect();
        (lo, pairs)
    });
    for (lo, pairs) in partials {
        for (off, (s, q)) in pairs.into_iter().enumerate() {
            sum[lo + off] = s;
            sumsq[lo + off] = q;
        }
    }
    let mut mean = Vec::new();
    let mut variance = Vec::new();
    finalize(n, &sum, &sumsq, &mut mean, &mut variance);
    Ok(Moments { n, sum, sumsq, mean, variance })
}

/// Two-pass textbook variance (eqs. 1–2): compute means, then sum squared
/// deviations. The pre-optimization baseline the ablation bench compares
/// against (two memory sweeps instead of one).
pub fn x2c_mom_naive<T: Float>(x: &DenseTable<T>) -> Result<Moments<T>> {
    let p = x.rows();
    let n = x.cols();
    if n == 0 {
        return Err(Error::Shape("x2c_mom: empty dataset".into()));
    }
    let nf = T::from_usize(n);
    let mut mean = vec![T::ZERO; p];
    let mut sum = vec![T::ZERO; p];
    for i in 0..p {
        let mut s = T::ZERO;
        for &v in x.row(i) {
            s += v;
        }
        sum[i] = s;
        mean[i] = s / nf;
    }
    let mut variance = vec![T::ZERO; p];
    let mut sumsq = vec![T::ZERO; p];
    for i in 0..p {
        let mu = mean[i];
        let mut acc = T::ZERO;
        let mut raw = T::ZERO;
        for &v in x.row(i) {
            let d = v - mu;
            acc = d.mul_add(d, acc);
            raw = v.mul_add(v, raw);
        }
        sumsq[i] = raw;
        variance[i] = if n > 1 { acc / T::from_usize(n - 1) } else { T::ZERO };
    }
    Ok(Moments { n, sum, sumsq, mean, variance })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Engine, Gaussian, Mt19937};

    fn random_dataset(seed: u32, p: usize, n: usize) -> DenseTable<f64> {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::new(2.0, 3.0);
        let mut data = vec![0.0; p * n];
        g.fill(&mut e, &mut data);
        DenseTable::from_vec(data, p, n).unwrap()
    }

    #[test]
    fn raw_moment_matches_two_pass() {
        let x = random_dataset(1, 8, 1001);
        let a = x2c_mom(&x).unwrap();
        let b = x2c_mom_naive(&x).unwrap();
        for i in 0..8 {
            assert!((a.mean[i] - b.mean[i]).abs() < 1e-10);
            assert!((a.variance[i] - b.variance[i]).abs() < 1e-8, "coord {i}");
        }
    }

    #[test]
    fn known_values() {
        // X row 0: [1,2,3,4] → mean 2.5, var 5/3
        let x = DenseTable::from_vec(vec![1.0, 2.0, 3.0, 4.0], 1, 4).unwrap();
        let m = x2c_mom(&x).unwrap();
        assert!((m.mean[0] - 2.5).abs() < 1e-12);
        assert!((m.variance[0] - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.sum[0], 10.0);
        assert_eq!(m.sumsq[0], 30.0);
    }

    #[test]
    fn constant_rows_zero_variance() {
        let x = DenseTable::from_vec(vec![7.0; 3 * 50], 3, 50).unwrap();
        let m = x2c_mom(&x).unwrap();
        for i in 0..3 {
            assert!(m.variance[i].abs() < 1e-9);
            assert!((m.mean[i] - 7.0).abs() < 1e-12);
        }
    }

    #[test]
    fn single_observation() {
        let x = DenseTable::from_vec(vec![3.0, 4.0], 2, 1).unwrap();
        let m = x2c_mom(&x).unwrap();
        assert_eq!(m.variance, vec![0.0, 0.0]);
        assert_eq!(m.mean, vec![3.0, 4.0]);
    }

    #[test]
    fn empty_rejected() {
        let x = DenseTable::<f64>::zeros(3, 0);
        assert!(x2c_mom(&x).is_err());
    }

    #[test]
    fn merge_equals_whole() {
        let x = random_dataset(2, 5, 400);
        let whole = x2c_mom(&x).unwrap();
        // Column split: columns 0..150 and 150..400. Row-major p×n layout
        // means a column split needs per-row copies.
        let split = 150;
        let mut left = DenseTable::zeros(5, split);
        let mut right = DenseTable::zeros(5, 400 - split);
        for i in 0..5 {
            left.row_mut(i).copy_from_slice(&x.row(i)[..split]);
            right.row_mut(i).copy_from_slice(&x.row(i)[split..]);
        }
        let mut a = x2c_mom(&left).unwrap();
        let b = x2c_mom(&right).unwrap();
        a.merge(&b);
        assert_eq!(a.n, 400);
        for i in 0..5 {
            assert!((a.variance[i] - whole.variance[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn thread_counts_bit_identical() {
        let x = random_dataset(9, 13, 777);
        let base = x2c_mom_threads(&x, 1).unwrap();
        for threads in 2..=4 {
            let m = x2c_mom_threads(&x, threads).unwrap();
            for i in 0..13 {
                assert_eq!(base.sum[i].to_bits(), m.sum[i].to_bits(), "threads={threads}");
                assert_eq!(base.sumsq[i].to_bits(), m.sumsq[i].to_bits(), "threads={threads}");
            }
        }
    }

    /// CSR moments equal the densified-table moments (including zero
    /// columns and empty rows) and are bit-identical across workers.
    #[test]
    fn csr_moments_match_densified_oracle() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut xd = random_dataset(5, 9, 301);
        // Sparsify: zero out two thirds of the entries, plus one whole
        // coordinate row (all-zero → nnz = 0 for that row) and one
        // all-zero observation column.
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        for j in 0..301 {
            xd.set(4, j, 0.0);
        }
        for i in 0..9 {
            xd.set(i, 77, 0.0);
        }
        for base in [IndexBase::Zero, IndexBase::One] {
            let xs = CsrMatrix::from_dense(&xd, 0.0, base);
            let a = x2c_mom_csr(&xs).unwrap();
            let b = x2c_mom(&xd).unwrap();
            assert_eq!(a.n, b.n);
            for i in 0..9 {
                let tol = |r: f64| 1e-9 * (1.0 + r.abs());
                assert!((a.sum[i] - b.sum[i]).abs() < tol(b.sum[i]), "{base:?} coord {i}");
                assert!((a.sumsq[i] - b.sumsq[i]).abs() < tol(b.sumsq[i]), "{base:?} coord {i}");
                assert!((a.mean[i] - b.mean[i]).abs() < tol(b.mean[i]), "{base:?} coord {i}");
                assert!(
                    (a.variance[i] - b.variance[i]).abs() < 1e-9,
                    "{base:?} coord {i}: {} vs {}",
                    a.variance[i],
                    b.variance[i]
                );
            }
            assert_eq!(a.sum[4], 0.0, "all-zero coordinate");
            assert_eq!(a.variance[4], 0.0);
            let base1 = x2c_mom_csr_threads(&xs, 1).unwrap();
            for threads in 2..=4 {
                let m = x2c_mom_csr_threads(&xs, threads).unwrap();
                for i in 0..9 {
                    assert_eq!(base1.sum[i].to_bits(), m.sum[i].to_bits(), "threads={threads}");
                    assert_eq!(
                        base1.sumsq[i].to_bits(),
                        m.sumsq[i].to_bits(),
                        "threads={threads}"
                    );
                }
            }
        }
    }

    /// Property sweep: random shapes, raw-moment and two-pass agree.
    #[test]
    fn property_shapes_agree() {
        let mut e = Mt19937::new(77);
        for trial in 0..20u32 {
            let p = 1 + (e.next_u32() % 16) as usize;
            let n = 2 + (e.next_u32() % 300) as usize;
            let x = random_dataset(100 + trial, p, n);
            let a = x2c_mom(&x).unwrap();
            let b = x2c_mom_naive(&x).unwrap();
            for i in 0..p {
                assert!(
                    (a.variance[i] - b.variance[i]).abs() < 1e-7 * (1.0 + b.variance[i].abs()),
                    "p={p} n={n} i={i}"
                );
            }
        }
    }
}
