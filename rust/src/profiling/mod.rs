//! Profiling + micro-benchmark harness.
//!
//! criterion is unavailable in this offline environment, so `bench.rs`
//! provides a small statistically honest harness (warmup, N samples,
//! median/mean/σ, throughput) that every `rust/benches/*.rs` target uses
//! under `harness = false`. `timer.rs` is the scoped-timer used by the
//! examples and the per-stage counters of the coordinator.

pub mod bench;
pub mod timer;

pub use bench::{BenchResult, Bencher};
pub use timer::ScopedTimer;
