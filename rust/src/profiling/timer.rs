//! Scoped wall-clock timers and a lightweight stage-metrics registry.

use std::collections::BTreeMap;
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

/// RAII timer that records its elapsed time into [`Metrics`] on drop.
pub struct ScopedTimer {
    label: &'static str,
    start: Instant,
}

impl ScopedTimer {
    pub fn new(label: &'static str) -> Self {
        Self { label, start: Instant::now() }
    }

    /// Elapsed time so far without stopping.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        Metrics::global().record(self.label, self.start.elapsed());
    }
}

/// Process-wide stage metrics (label → total time + hit count), used by
/// the coordinator to attribute time to dispatch / batching / execute.
pub struct Metrics {
    inner: Mutex<BTreeMap<&'static str, (Duration, u64)>>,
}

static GLOBAL: Metrics = Metrics { inner: Mutex::new(BTreeMap::new()) };

impl Metrics {
    pub fn global() -> &'static Metrics {
        &GLOBAL
    }

    pub fn record(&self, label: &'static str, d: Duration) {
        let mut m = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        let e = m.entry(label).or_insert((Duration::ZERO, 0));
        e.0 += d;
        e.1 += 1;
    }

    /// Snapshot of (label, total, count) rows.
    pub fn snapshot(&self) -> Vec<(&'static str, Duration, u64)> {
        self.inner
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .map(|(k, (d, c))| (*k, *d, *c))
            .collect()
    }

    pub fn reset(&self) {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).clear();
    }

    /// Render a report table (used by `onedal-sve metrics` and examples).
    pub fn report(&self) -> String {
        let mut out = String::from("stage                          total_ms    calls\n");
        for (label, d, c) in self.snapshot() {
            out.push_str(&format!("{label:<30} {:>9.3} {c:>8}\n", d.as_secs_f64() * 1e3));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scoped_timer_records() {
        Metrics::global().reset();
        {
            let _t = ScopedTimer::new("test-stage");
            std::thread::sleep(Duration::from_millis(2));
        }
        let snap = Metrics::global().snapshot();
        let row = snap.iter().find(|(l, _, _)| *l == "test-stage").unwrap();
        assert!(row.1 >= Duration::from_millis(1));
        assert_eq!(row.2, 1);
        Metrics::global().reset();
    }

    #[test]
    fn report_formats() {
        Metrics::global().reset();
        Metrics::global().record("alpha", Duration::from_millis(5));
        let r = Metrics::global().report();
        assert!(r.contains("alpha"));
        Metrics::global().reset();
    }
}
