//! Minimal criterion-style benchmark harness (criterion itself is not
//! vendored in this offline image). Provides warmup, fixed-sample timing,
//! robust statistics and a stable one-line report format that the
//! EXPERIMENTS.md tables are generated from:
//!
//! ```text
//! fig8/kmeans/optimized        median 12.345 ms   mean 12.400 ms ± 0.210   n=20
//! ```

use std::time::{Duration, Instant};

/// Result statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median: Duration,
    pub mean: Duration,
    pub stddev: Duration,
    pub samples: usize,
}

impl BenchResult {
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }

    /// One-line report row.
    pub fn row(&self) -> String {
        format!(
            "{:<44} median {:>10.3} ms   mean {:>10.3} ms ± {:>8.3}   n={}",
            self.name,
            self.median.as_secs_f64() * 1e3,
            self.mean.as_secs_f64() * 1e3,
            self.stddev.as_secs_f64() * 1e3,
            self.samples
        )
    }
}

/// Harness configuration: time-budgeted warmup + fixed sample count.
pub struct Bencher {
    pub warmup: Duration,
    pub samples: usize,
    /// Hard cap on total measurement time for slow cases.
    pub max_total: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            samples: 15,
            max_total: Duration::from_secs(20),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn new(warmup_ms: u64, samples: usize) -> Self {
        Self {
            warmup: Duration::from_millis(warmup_ms),
            samples,
            ..Default::default()
        }
    }

    /// Run one case. `f` must perform the full measured operation; use
    /// `std::hint::black_box` inside to defeat dead-code elimination.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        // Warmup until the budget is spent (at least one call).
        let w0 = Instant::now();
        loop {
            f();
            if w0.elapsed() >= self.warmup {
                break;
            }
        }
        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        let total0 = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed());
            if total0.elapsed() > self.max_total {
                break;
            }
        }
        times.sort_unstable();
        let n = times.len();
        let median = times[n / 2];
        let mean_ns = times.iter().map(|d| d.as_nanos()).sum::<u128>() / n as u128;
        let mean = Duration::from_nanos(mean_ns as u64);
        let var = times
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns as f64;
                x * x
            })
            .sum::<f64>()
            / n.max(1) as f64;
        let stddev = Duration::from_nanos(var.sqrt() as u64);
        let r = BenchResult { name: name.to_string(), median, mean, stddev, samples: n };
        println!("{}", r.row());
        self.results.push(r.clone());
        r
    }

    /// All results so far (for speedup tables).
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Print a paper-style speedup table: each case vs a baseline case.
    pub fn speedup_table(&self, title: &str, baseline_suffix: &str) {
        println!("\n== {title} (speedup vs `{baseline_suffix}`) ==");
        // Group rows by prefix before the final '/'.
        for r in &self.results {
            if let Some(prefix) = r.name.rfind('/').map(|i| &r.name[..i]) {
                if r.name.ends_with(baseline_suffix) {
                    continue;
                }
                let base_name = format!("{prefix}/{baseline_suffix}");
                if let Some(base) = self.results.iter().find(|b| b.name == base_name) {
                    let speedup = base.median.as_secs_f64() / r.median.as_secs_f64();
                    println!("{:<44} {speedup:>8.2}x", r.name);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher::new(5, 7);
        let r = b.bench("sleep/1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.median >= Duration::from_millis(1));
        assert!(r.median < Duration::from_millis(50));
        assert_eq!(r.samples, 7);
    }

    #[test]
    fn speedup_table_finds_baseline() {
        let mut b = Bencher::new(1, 3);
        b.bench("case/naive", || std::thread::sleep(Duration::from_millis(2)));
        b.bench("case/optimized", || std::thread::sleep(Duration::from_micros(100)));
        // Just exercise the formatting path.
        b.speedup_table("test", "naive");
        assert_eq!(b.results().len(), 2);
    }

    #[test]
    fn row_format_stable() {
        let r = BenchResult {
            name: "x/y".into(),
            median: Duration::from_millis(1),
            mean: Duration::from_millis(1),
            stddev: Duration::ZERO,
            samples: 3,
        };
        let row = r.row();
        assert!(row.contains("median"));
        assert!(row.contains("n=3"));
    }
}
