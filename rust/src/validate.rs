//! Shared boundary validation for the public algorithm API.
//!
//! Every public `train`/`infer`/`predict` in [`crate::algorithms`] (and
//! the VSL / distance-primitive entry points) runs these checks **before
//! touching a kernel**, so malformed input surfaces as a typed
//! [`Error::Shape`] / [`Error::Param`] with an actionable message and
//! the deep kernel `assert!`s become unreachable from the public API.
//!
//! Conventions:
//!
//! * Every message is prefixed with the algorithm name (`"kmeans: ..."`)
//!   so a caller holding only the error string can locate the boundary.
//! * Non-finite hyperparameters (NaN, ±inf) are rejected explicitly —
//!   a comparison like `eps <= 0.0` silently passes NaN, so the checks
//!   here use `is_finite()` composed with the range test.
//! * Helpers return `Result<()>` and are cheap (no allocation on the
//!   success path), so boundaries can chain them with `?`.

use crate::error::{Error, Result};

/// Reject empty tables (0 rows) and degenerate tables (0 features).
pub fn non_empty(rows: usize, cols: usize, algo: &str) -> Result<()> {
    if rows == 0 {
        return Err(Error::Shape(format!(
            "{algo}: input table has 0 rows; provide at least one observation"
        )));
    }
    if cols == 0 {
        return Err(Error::Shape(format!(
            "{algo}: input table has 0 features; provide at least one column"
        )));
    }
    Ok(())
}

/// Require one label per row.
pub fn labels_match(rows: usize, labels: usize, algo: &str) -> Result<()> {
    if rows != labels {
        return Err(Error::Shape(format!(
            "{algo}: label count mismatch: {rows} rows but {labels} labels"
        )));
    }
    Ok(())
}

/// Require a strictly positive, finite hyperparameter. NaN and ±inf are
/// rejected (a bare `v <= 0.0` comparison lets NaN through).
pub fn positive_finite(value: f64, name: &str, algo: &str) -> Result<()> {
    if !value.is_finite() || value <= 0.0 {
        return Err(Error::Param(format!(
            "{algo}: {name} must be a positive finite number, got {value}"
        )));
    }
    Ok(())
}

/// Require a non-negative, finite hyperparameter (0 allowed).
pub fn non_negative_finite(value: f64, name: &str, algo: &str) -> Result<()> {
    if !value.is_finite() || value < 0.0 {
        return Err(Error::Param(format!(
            "{algo}: {name} must be a non-negative finite number, got {value}"
        )));
    }
    Ok(())
}

/// Require `1 <= k <= n` (cluster count, neighbor count, component
/// count against the observation count).
pub fn k_in_range(k: usize, n: usize, name: &str, algo: &str) -> Result<()> {
    if k == 0 || k > n {
        return Err(Error::Param(format!(
            "{algo}: {name}={k} out of range; need 1 <= {name} <= n_rows ({n})"
        )));
    }
    Ok(())
}

/// Require a query/infer table to match the trained feature width.
pub fn dims_match(expected: usize, got: usize, algo: &str) -> Result<()> {
    if expected != got {
        return Err(Error::Shape(format!(
            "{algo}: feature dim mismatch: model trained on {expected} features, input has {got}"
        )));
    }
    Ok(())
}

/// Require a strictly positive integer hyperparameter.
pub fn positive_int(value: usize, name: &str, algo: &str) -> Result<()> {
    if value == 0 {
        return Err(Error::Param(format!("{algo}: {name} must be >= 1, got 0")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_empty_rejects_both_axes() {
        assert!(non_empty(10, 3, "t").is_ok());
        let e = non_empty(0, 3, "kmeans").unwrap_err();
        assert!(matches!(e, Error::Shape(ref m) if m.contains("kmeans") && m.contains("0 rows")));
        let e = non_empty(10, 0, "pca").unwrap_err();
        assert!(matches!(e, Error::Shape(ref m) if m.contains("0 features")));
    }

    #[test]
    fn labels_match_names_both_counts() {
        assert!(labels_match(5, 5, "t").is_ok());
        let e = labels_match(5, 4, "svm").unwrap_err();
        assert!(matches!(e, Error::Shape(ref m) if m.contains("5 rows") && m.contains("4 labels")));
    }

    #[test]
    fn positive_finite_rejects_nan_inf_zero_negative() {
        assert!(positive_finite(1e-9, "eps", "t").is_ok());
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let e = positive_finite(bad, "eps", "dbscan").unwrap_err();
            assert!(matches!(e, Error::Param(ref m) if m.contains("dbscan: eps")));
        }
    }

    #[test]
    fn non_negative_finite_allows_zero() {
        assert!(non_negative_finite(0.0, "alpha", "t").is_ok());
        for bad in [-1e-12, f64::NAN, f64::INFINITY] {
            assert!(non_negative_finite(bad, "alpha", "linreg").is_err());
        }
    }

    #[test]
    fn k_in_range_bounds() {
        assert!(k_in_range(1, 1, "k", "t").is_ok());
        assert!(k_in_range(0, 5, "k", "knn").is_err());
        let e = k_in_range(6, 5, "k", "knn").unwrap_err();
        assert!(matches!(e, Error::Param(ref m) if m.contains("k=6") && m.contains("(5)")));
    }

    #[test]
    fn dims_match_message_names_both() {
        assert!(dims_match(8, 8, "t").is_ok());
        let e = dims_match(8, 7, "knn").unwrap_err();
        assert!(
            matches!(e, Error::Shape(ref m) if m.contains("trained on 8") && m.contains("has 7"))
        );
    }

    #[test]
    fn positive_int_rejects_zero() {
        assert!(positive_int(1, "min_pts", "t").is_ok());
        assert!(positive_int(0, "min_pts", "dbscan").is_err());
    }
}
