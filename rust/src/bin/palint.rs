//! `palint` — CLI front end of `onedal_sve::lint`, the in-repo
//! determinism & fault-contract static analyzer (zero dependencies,
//! like everything else in this crate).
//!
//! ```text
//! palint [--root <dir>] [--json] [--list-rules]
//! ```
//!
//! Walks the source tree (default: `src` from the crate root, `rust/src`
//! from the repo root), enforces the PAL-* rules, and prints findings as
//! `path:line: RULE message` or as the versioned JSON report. Exit
//! status: 0 clean, 1 findings, 2 usage or I/O error. CI runs
//! `cargo run --release --bin palint -- --json` as a required gate.

use onedal_sve::lint;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const USAGE: &str = "\
palint — determinism & fault-contract static analyzer

USAGE:
    palint [--root <dir>] [--json] [--list-rules]

OPTIONS:
    --root <dir>   source tree to scan (default: src, else rust/src)
    --json         emit the versioned JSON findings report
    --list-rules   print every rule id with its one-line contract
    -h, --help     this text

Suppress a single finding with a reasoned directive on the same line
or the line above: `// palint: allow(PAL-XXX, why this is sound)`.
Reason-less, unknown-rule or stale directives are PAL-META findings.

EXIT STATUS: 0 clean · 1 findings · 2 usage or I/O error
";

struct Options {
    root: Option<PathBuf>,
    json: bool,
    list_rules: bool,
}

fn parse_args(args: &[String]) -> Result<Options, String> {
    let mut opts = Options { root: None, json: false, list_rules: false };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => opts.json = true,
            "--list-rules" => opts.list_rules = true,
            "--root" => {
                i += 1;
                let dir = args.get(i).ok_or("--root needs a directory argument")?;
                opts.root = Some(PathBuf::from(dir));
            }
            "-h" | "--help" => return Err(String::new()),
            other => return Err(format!("unknown argument {other:?}")),
        }
        i += 1;
    }
    Ok(opts)
}

fn default_root() -> Option<PathBuf> {
    for candidate in ["src", "rust/src"] {
        let path = Path::new(candidate);
        if path.is_dir() {
            return Some(path.to_path_buf());
        }
    }
    None
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = match parse_args(&args) {
        Ok(opts) => opts,
        Err(msg) => {
            if msg.is_empty() {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            eprintln!("palint: {msg}");
            eprint!("{USAGE}");
            return ExitCode::from(2);
        }
    };
    if opts.list_rules {
        for (id, what) in lint::RULE_DESCRIPTIONS {
            println!("{id:<11} {what}");
        }
        return ExitCode::SUCCESS;
    }
    let Some(root) = opts.root.or_else(default_root) else {
        eprintln!("palint: no source tree found (tried src, rust/src); use --root <dir>");
        return ExitCode::from(2);
    };
    let findings = match lint::scan_tree(&root) {
        Ok(findings) => findings,
        Err(err) => {
            eprintln!("palint: failed to scan {}: {err}", root.display());
            return ExitCode::from(2);
        }
    };
    if opts.json {
        print!("{}", lint::json::emit(&findings));
    } else if findings.is_empty() {
        println!("palint: clean ({} ok)", root.display());
    } else {
        print!("{}", lint::render_human(&findings));
        eprintln!("palint: {} finding(s)", findings.len());
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
