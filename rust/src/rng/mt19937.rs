//! MT19937 Mersenne Twister (Matsumoto & Nishimura 1998), the engine
//! shared by stdc++ and OpenRNG and the reference generator for the
//! paper's Fig. 3 RNG comparison.
//!
//! The implementation is the standard 624-word twist with the canonical
//! tempering sequence; `Mt19937::new(5489)` reproduces the reference
//! test vector (10000th draw = 4123659995).

use super::Engine;
use crate::error::{Error, Result};

const N: usize = 624;
const M: usize = 397;
const MATRIX_A: u32 = 0x9908_b0df;
const UPPER_MASK: u32 = 0x8000_0000;
const LOWER_MASK: u32 = 0x7fff_ffff;

/// Mersenne Twister engine with 19937-bit state.
#[derive(Clone)]
pub struct Mt19937 {
    state: [u32; N],
    idx: usize,
}

impl Mt19937 {
    /// Seed with the standard Knuth-multiplier initialization.
    pub fn new(seed: u32) -> Self {
        let mut state = [0u32; N];
        state[0] = seed;
        for i in 1..N {
            state[i] = 1_812_433_253u32
                .wrapping_mul(state[i - 1] ^ (state[i - 1] >> 30))
                .wrapping_add(i as u32);
        }
        Self { state, idx: N }
    }

    /// One full twist of the 624-word state.
    #[inline]
    fn twist(&mut self) {
        for i in 0..N {
            let y = (self.state[i] & UPPER_MASK) | (self.state[(i + 1) % N] & LOWER_MASK);
            let mut next = self.state[(i + M) % N] ^ (y >> 1);
            if y & 1 != 0 {
                next ^= MATRIX_A;
            }
            self.state[i] = next;
        }
        self.idx = 0;
    }

    /// Advance the state by whole 624-word blocks without tempering.
    ///
    /// MKL/OpenRNG implement MT19937 SkipAhead with GF(2) polynomial
    /// jumps; block replay has the same observable semantics (the stream
    /// continues at element `pos + n`) at O(n/624) twists. For the skip
    /// distances oneDAL uses (per-thread partitioning of ≤ 10⁸ draws)
    /// this is a few milliseconds, which the `ablate_rng` bench measures.
    fn skip_blocks(&mut self, blocks: u64) {
        for _ in 0..blocks {
            self.twist();
            self.idx = N; // consume the entire block
        }
    }
}

impl Engine for Mt19937 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.idx >= N {
            self.twist();
        }
        let mut y = self.state[self.idx];
        self.idx += 1;
        // Canonical tempering.
        y ^= y >> 11;
        y ^= (y << 7) & 0x9d2c_5680;
        y ^= (y << 15) & 0xefc6_0000;
        y ^= y >> 18;
        y
    }

    fn skip_ahead(&mut self, n: u64) -> Result<()> {
        // Consume the tail of the current block one word at a time, then
        // replay whole blocks, then position within the final block.
        let mut remaining = n;
        let tail = (N - self.idx.min(N)) as u64;
        if remaining <= tail {
            self.idx += remaining as usize;
            return Ok(());
        }
        remaining -= tail;
        self.idx = N;
        self.skip_blocks(remaining / N as u64);
        self.twist();
        self.idx = (remaining % N as u64) as usize;
        Ok(())
    }

    fn leapfrog(&mut self, _k: u64, _s: u64) -> Result<()> {
        // Faithful to MKL VSL / OpenRNG: MT19937 does not support LeapFrog.
        Err(Error::Param("MT19937 does not support LeapFrog (use MCG59)".into()))
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "mt19937"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vector_seed_5489() {
        // Canonical MT19937 test vector: with the default seed 5489 the
        // 10000th output is 4123659995.
        let mut e = Mt19937::new(5489);
        let mut last = 0;
        for _ in 0..10_000 {
            last = e.next_u32();
        }
        assert_eq!(last, 4_123_659_995);
    }

    #[test]
    fn first_draws_seed_1() {
        let mut e = Mt19937::new(1);
        // Reference values from the original mt19937ar.c with init_genrand(1).
        assert_eq!(e.next_u32(), 1_791_095_845);
        assert_eq!(e.next_u32(), 4_282_876_139);
    }

    #[test]
    fn skip_ahead_matches_sequential() {
        for skip in [0u64, 1, 7, 623, 624, 625, 5000, 12_480] {
            let mut seq = Mt19937::new(99);
            for _ in 0..skip {
                seq.next_u32();
            }
            let mut jump = Mt19937::new(99);
            jump.skip_ahead(skip).unwrap();
            for _ in 0..100 {
                assert_eq!(seq.next_u32(), jump.next_u32(), "skip={skip}");
            }
        }
    }

    #[test]
    fn skip_ahead_composes() {
        let mut a = Mt19937::new(3);
        a.skip_ahead(1000).unwrap();
        a.skip_ahead(2345).unwrap();
        let mut b = Mt19937::new(3);
        b.skip_ahead(3345).unwrap();
        assert_eq!(a.next_u32(), b.next_u32());
    }

    #[test]
    fn leapfrog_unsupported() {
        assert!(Mt19937::new(1).leapfrog(0, 2).is_err());
    }
}
