//! Distribution generators layered on any [`Engine`], mirroring the MKL
//! VSL `vdRng*` continuous/discrete families oneDAL consumes (uniform,
//! gaussian, bernoulli, uniform integers) with bulk `fill` entry points —
//! the block-generation style OpenRNG optimizes for.

use super::Engine;
use crate::dtype::Float;

/// A distribution that samples values of type `T` from an engine.
pub trait Distribution<T> {
    fn sample(&mut self, e: &mut dyn Engine) -> T;

    /// Bulk generation (`vdRngUniform`-style); the default loops, engines
    /// with cheaper block paths can override at the call site.
    fn fill(&mut self, e: &mut dyn Engine, out: &mut [T]) {
        for v in out.iter_mut() {
            *v = self.sample(e);
        }
    }
}

/// Uniform on `[a, b)`.
pub struct Uniform<T: Float> {
    a: T,
    span: T,
}

impl<T: Float> Uniform<T> {
    pub fn new(a: T, b: T) -> Self {
        Self { a, span: b - a }
    }
}

impl<T: Float> Distribution<T> for Uniform<T> {
    #[inline]
    fn sample(&mut self, e: &mut dyn Engine) -> T {
        self.a + self.span * T::from_f64(e.next_f64())
    }
}

/// Gaussian via Box–Muller with second-value caching (the VSL
/// `VSL_RNG_METHOD_GAUSSIAN_BOXMULLER2` analogue).
pub struct Gaussian<T: Float> {
    mean: T,
    sigma: T,
    cached: Option<T>,
}

impl<T: Float> Gaussian<T> {
    pub fn new(mean: T, sigma: T) -> Self {
        Self { mean, sigma, cached: None }
    }

    /// Standard normal.
    pub fn standard() -> Self {
        Self::new(T::ZERO, T::ONE)
    }
}

impl<T: Float> Distribution<T> for Gaussian<T> {
    fn sample(&mut self, e: &mut dyn Engine) -> T {
        if let Some(z) = self.cached.take() {
            return self.mean + self.sigma * z;
        }
        // Box–Muller: two uniforms -> two normals.
        let mut u1 = e.next_f64();
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let u2 = e.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached = Some(T::from_f64(r * theta.sin()));
        self.mean + self.sigma * T::from_f64(r * theta.cos())
    }
}

/// Bernoulli(p) over `{0, 1}`.
pub struct Bernoulli {
    p: f64,
}

impl Bernoulli {
    pub fn new(p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "p must be in [0,1]");
        Self { p }
    }
}

impl Distribution<u8> for Bernoulli {
    #[inline]
    fn sample(&mut self, e: &mut dyn Engine) -> u8 {
        u8::from(e.next_f64() < self.p)
    }
}

/// Uniform integers on `[lo, hi)` (rejection-free Lemire reduction).
pub struct UniformInt {
    lo: u64,
    span: u64,
}

impl UniformInt {
    pub fn new(lo: u64, hi: u64) -> Self {
        assert!(hi > lo, "empty integer range");
        Self { lo, span: hi - lo }
    }
}

impl Distribution<u64> for UniformInt {
    #[inline]
    fn sample(&mut self, e: &mut dyn Engine) -> u64 {
        // Lemire multiply-shift; bias is < 2^-64·span, negligible here.
        self.lo + ((e.next_u64() as u128 * self.span as u128) >> 64) as u64
    }
}

/// Fisher–Yates shuffle driven by an engine (used by kmeans++ seeding,
/// random-forest bootstrap and the dataset generators).
pub fn shuffle<T>(e: &mut dyn Engine, xs: &mut [T]) {
    let n = xs.len();
    if n < 2 {
        return;
    }
    let mut d = UniformInt::new(0, 1);
    for i in (1..n).rev() {
        d.span = i as u64 + 1;
        let j = d.sample(e) as usize;
        xs.swap(i, j);
    }
}

/// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
pub fn sample_indices(e: &mut dyn Engine, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n);
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + (UniformInt::new(0, (n - i) as u64).sample(e) as usize);
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Mcg59, Mt19937};

    #[test]
    fn uniform_bounds_and_mean() {
        let mut e = Mt19937::new(1);
        let mut d = Uniform::<f64>::new(-2.0, 3.0);
        let n = 40_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = d.sample(&mut e);
            assert!((-2.0..3.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.05);
    }

    #[test]
    fn gaussian_moments() {
        let mut e = Mcg59::new(2);
        let mut d = Gaussian::<f64>::new(1.0, 2.0);
        let n = 60_000;
        let xs: Vec<f64> = (0..n).map(|_| d.sample(&mut e)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
        assert!((var - 4.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut e = Mt19937::new(3);
        let mut d = Bernoulli::new(0.2);
        let n = 50_000;
        let ones: u32 = (0..n).map(|_| u32::from(d.sample(&mut e))).sum();
        let rate = f64::from(ones) / f64::from(n);
        assert!((rate - 0.2).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn uniform_int_in_range_and_covers() {
        let mut e = Mt19937::new(4);
        let mut d = UniformInt::new(3, 10);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = d.sample(&mut e) as usize;
            assert!((3..10).contains(&v));
            seen[v] = true;
        }
        assert!(seen[3..10].iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut e = Mt19937::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        shuffle(&mut e, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut e = Mcg59::new(6);
        let idx = sample_indices(&mut e, 50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn fill_matches_repeated_sample() {
        let mut e1 = Mt19937::new(7);
        let mut e2 = Mt19937::new(7);
        let mut d1 = Uniform::<f32>::new(0.0, 1.0);
        let mut d2 = Uniform::<f32>::new(0.0, 1.0);
        let mut buf = [0f32; 64];
        d1.fill(&mut e1, &mut buf);
        for v in buf {
            assert_eq!(v, d2.sample(&mut e2));
        }
    }
}
