//! MCG31m1 — the third engine of the MKL VSL / OpenRNG family:
//!
//! ```text
//!   x_{n+1} = a · x_n  mod (2^31 − 1),   a = 1 132 489 760
//! ```
//!
//! A Lehmer generator over the Mersenne prime m = 2³¹−1. Like MCG59 it
//! has closed-form SkipAhead and LeapFrog (modular exponentiation over a
//! *prime* modulus, so every nonzero state is invertible via Fermat);
//! MKL VSL lists it alongside MCG59 as the LeapFrog-capable pair.

use super::Engine;
use crate::error::Result;

/// Modulus 2^31 − 1 (Mersenne prime).
pub const M31: u64 = (1u64 << 31) - 1;
/// MKL VSL multiplier for MCG31m1.
pub const MCG31_A: u64 = 1_132_489_760;

#[inline(always)]
fn mul_mod31(a: u64, b: u64) -> u64 {
    (a * b) % M31
}

/// `base^exp mod (2^31 − 1)`.
#[inline]
pub fn pow_mod31(mut base: u64, mut exp: u64) -> u64 {
    let mut acc = 1u64;
    base %= M31;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul_mod31(acc, base);
        }
        base = mul_mod31(base, base);
        exp >>= 1;
    }
    acc
}

/// Inverse by Fermat's little theorem: `x^(m−2) mod m`.
#[inline]
pub fn inv_mod31(x: u64) -> u64 {
    pow_mod31(x, M31 - 2)
}

/// 31-bit Lehmer engine.
#[derive(Clone)]
pub struct Mcg31 {
    state: u64,
    mult: u64,
}

impl Mcg31 {
    pub fn new(seed: u64) -> Self {
        let mut s = seed % M31;
        if s == 0 {
            s = 1; // zero is absorbing; MKL nudges to 1
        }
        Self { state: s, mult: MCG31_A }
    }

    /// Raw draw in `[1, 2^31 − 1)`.
    #[inline]
    pub fn next_raw(&mut self) -> u64 {
        self.state = mul_mod31(self.state, self.mult);
        self.state
    }
}

impl Engine for Mcg31 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        // One draw = one output element (the VSL stream-position
        // contract SkipAhead/LeapFrog are defined over). 31 bits are
        // placed in the high half; bit 0 is constant-zero, as in MKL's
        // 31-bit integer outputs.
        (self.next_raw() as u32) << 1
    }

    fn next_f64(&mut self) -> f64 {
        // MKL semantics: one draw → one double in [0, 1).
        self.next_raw() as f64 * (1.0 / M31 as f64)
    }

    fn skip_ahead(&mut self, n: u64) -> Result<()> {
        self.state = mul_mod31(self.state, pow_mod31(self.mult, n));
        Ok(())
    }

    fn leapfrog(&mut self, k: u64, s: u64) -> Result<()> {
        // Same positioning algebra as MCG59 (see mcg59.rs): stream k of
        // s starts at state·a^{k+1}·a^{−s} with stride multiplier a^s.
        let a_s = pow_mod31(self.mult, s);
        let pos = mul_mod31(pow_mod31(self.mult, k + 1), inv_mod31(a_s));
        self.state = mul_mod31(self.state, pos);
        self.mult = a_s;
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "mcg31m1"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_ahead_matches_sequential() {
        for skip in [0u64, 1, 5, 1000, 1 << 20] {
            let mut seq = Mcg31::new(2024);
            for _ in 0..skip {
                seq.next_raw();
            }
            let mut jump = Mcg31::new(2024);
            jump.skip_ahead(skip).unwrap();
            assert_eq!(seq.next_raw(), jump.next_raw(), "skip={skip}");
        }
    }

    #[test]
    fn leapfrog_partitions_base_sequence() {
        let mut base = Mcg31::new(31);
        let whole: Vec<u64> = (0..40).map(|_| base.next_raw()).collect();
        for k in 0..4u64 {
            let mut s = Mcg31::new(31);
            s.leapfrog(k, 4).unwrap();
            for i in 0..10 {
                assert_eq!(s.next_raw(), whole[k as usize + 4 * i], "stream {k} elem {i}");
            }
        }
    }

    #[test]
    fn fermat_inverse() {
        for x in [1u64, 2, MCG31_A, M31 - 1] {
            assert_eq!(mul_mod31(x, inv_mod31(x)), 1, "x={x}");
        }
    }

    #[test]
    fn zero_seed_nudged() {
        let mut e = Mcg31::new(0);
        assert_ne!(e.next_raw(), 0);
    }

    #[test]
    fn uniform_mean() {
        let mut e = Mcg31::new(7);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| e.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn full_period_never_zero() {
        let mut e = Mcg31::new(123);
        for _ in 0..10_000 {
            assert_ne!(e.next_raw(), 0);
        }
    }
}
