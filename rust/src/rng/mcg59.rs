//! MCG59 — the 59-bit multiplicative congruential generator OpenRNG adds
//! over the stdc++ backend (paper §IV-D):
//!
//! ```text
//!   x_{n+1} = a · x_n  mod 2^59,     a = 13^13
//! ```
//!
//! Unlike MT19937, MCG59's linear structure gives *closed-form* stream
//! partitioning — the property the paper's SkipAhead and LeapFrog methods
//! rely on:
//!
//! * **SkipAhead(n)**: `x ← a^n·x mod 2^59` via O(log n) square-and-multiply.
//! * **LeapFrog(k, s)**: stream k of s emits elements `k, k+s, k+2s, …`,
//!   realized by re-tuning the multiplier to `a^s` after advancing to `x_k`.

use super::Engine;
use crate::error::Result;

/// Modulus mask: 2^59 − 1 (reduction mod 2^59 is a mask).
const M59: u64 = (1u64 << 59) - 1;
/// Default multiplier a = 13^13 (MKL VSL / OpenRNG constant).
pub const MCG59_A: u64 = 302_875_106_592_253;

/// 59-bit multiplicative congruential engine.
#[derive(Clone)]
pub struct Mcg59 {
    state: u64,
    /// Current multiplier — `a` for a base stream, `a^s` after LeapFrog.
    mult: u64,
}

#[inline(always)]
fn mul_mod59(a: u64, b: u64) -> u64 {
    // 59+59 bits overflows u64; go through u128 and mask.
    ((a as u128 * b as u128) & M59 as u128) as u64
}

/// Multiplicative inverse of an odd `x` mod 2^59 (Newton iteration —
/// each step doubles the number of correct low bits).
#[inline]
pub fn inv_mod59(x: u64) -> u64 {
    debug_assert!(x & 1 == 1, "only odd residues are invertible mod 2^59");
    let mut y: u64 = x; // 3 correct bits to start (x·x ≡ 1 mod 8)
    for _ in 0..6 {
        y = y.wrapping_mul(2u64.wrapping_sub(x.wrapping_mul(y)));
    }
    y & M59
}

/// `base^exp mod 2^59` by square-and-multiply.
#[inline]
pub fn pow_mod59(mut base: u64, mut exp: u64) -> u64 {
    let mut acc: u64 = 1;
    base &= M59;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = mul_mod59(acc, base);
        }
        base = mul_mod59(base, base);
        exp >>= 1;
    }
    acc
}

impl Mcg59 {
    /// Seed the engine. A zero (or even) seed is nudged to the canonical
    /// odd starting point so the multiplicative sequence has full period.
    pub fn new(seed: u64) -> Self {
        let mut s = seed & M59;
        if s == 0 {
            s = 1;
        }
        if s & 1 == 0 {
            s |= 1; // keep the state in the odd residues (period 2^57)
        }
        Self { state: s, mult: MCG59_A }
    }

    /// Raw 59-bit state draw (the value MKL scales into doubles).
    #[inline]
    pub fn next_raw(&mut self) -> u64 {
        self.state = mul_mod59(self.state, self.mult);
        self.state
    }
}

impl Engine for Mcg59 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        // Top 32 of the 59 bits: the low bits of an MCG are weak.
        (self.next_raw() >> 27) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    fn next_f64(&mut self) -> f64 {
        // MKL semantics: one draw maps to one double in [0,1) as x / 2^59.
        self.next_raw() as f64 * (1.0 / (1u64 << 59) as f64)
    }

    fn skip_ahead(&mut self, n: u64) -> Result<()> {
        self.state = mul_mod59(self.state, pow_mod59(self.mult, n));
        Ok(())
    }

    fn leapfrog(&mut self, k: u64, s: u64) -> Result<()> {
        // Remaining outputs are state·a, state·a², …; stream k must emit
        // elements k, k+s, … of that sequence. With the stride multiplier
        // a^s applied *before* each draw, the state is positioned at
        // state·a^{k+1}·a^{−s} (modular inverse — a is odd, so invertible).
        let a_s = pow_mod59(self.mult, s);
        let pos = mul_mod59(pow_mod59(self.mult, k + 1), inv_mod59(a_s));
        self.state = mul_mod59(self.state, pos);
        self.mult = a_s;
        Ok(())
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "mcg59"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplier_is_13_pow_13() {
        let mut a: u64 = 1;
        for _ in 0..13 {
            a *= 13;
        }
        assert_eq!(a, MCG59_A);
    }

    #[test]
    fn skip_ahead_matches_sequential() {
        for skip in [0u64, 1, 2, 100, 12_345, 1 << 20] {
            let mut seq = Mcg59::new(77);
            for _ in 0..skip {
                seq.next_raw();
            }
            let mut jump = Mcg59::new(77);
            jump.skip_ahead(skip).unwrap();
            assert_eq!(seq.next_raw(), jump.next_raw(), "skip={skip}");
        }
    }

    #[test]
    fn leapfrog_partitions_base_sequence() {
        // 3 leapfrog streams must interleave into the base sequence.
        let mut base = Mcg59::new(42);
        let base_seq: Vec<u64> = (0..30).map(|_| base.next_raw()).collect();
        for k in 0..3u64 {
            let mut s = Mcg59::new(42);
            s.leapfrog(k, 3).unwrap();
            for i in 0..10 {
                assert_eq!(s.next_raw(), base_seq[k as usize + 3 * i], "stream {k} elem {i}");
            }
        }
    }

    #[test]
    fn skipahead_then_leapfrog_compose() {
        let mut base = Mcg59::new(9);
        let seq: Vec<u64> = (0..40).map(|_| base.next_raw()).collect();
        let mut s = Mcg59::new(9);
        s.skip_ahead(10).unwrap();
        s.leapfrog(1, 2).unwrap(); // elements 11, 13, 15, ... of the base
        assert_eq!(s.next_raw(), seq[11]);
        assert_eq!(s.next_raw(), seq[13]);
    }

    #[test]
    fn pow_mod59_identities() {
        assert_eq!(pow_mod59(MCG59_A, 0), 1);
        assert_eq!(pow_mod59(MCG59_A, 1), MCG59_A);
        let a2 = pow_mod59(MCG59_A, 2);
        assert_eq!(a2, ((MCG59_A as u128 * MCG59_A as u128) & ((1u128 << 59) - 1)) as u64);
    }

    #[test]
    fn uniform_doubles_cover_unit_interval() {
        let mut e = Mcg59::new(123);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| e.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
