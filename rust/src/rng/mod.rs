//! Random-number-generation substrate.
//!
//! The paper replaces oneDAL's stdc++ RNG backend on ARM with **OpenRNG**
//! (Arm Performance Libraries 24.04), an MKL-VSL-compatible engine
//! library. This module rebuilds that substrate natively:
//!
//! * [`Mt19937`] — Mersenne Twister, the engine both stdc++ and OpenRNG
//!   provide. SkipAhead is supported (by fast block replay); LeapFrog is
//!   *not* (neither MKL VSL nor OpenRNG support LeapFrog for MT19937 —
//!   we faithfully return an error).
//! * [`Mcg59`] — 59-bit multiplicative congruential generator
//!   (`x_{n+1} = a·x_n mod 2^59`, `a = 13^13`), the second engine OpenRNG
//!   adds over stdc++. Supports O(log n) SkipAhead via modular
//!   exponentiation and true LeapFrog via multiplier retuning.
//! * [`StdCxxRng`] — the "libcpp" baseline of Fig. 3: MT19937 with the
//!   parallel-stream entry points disabled, mirroring what plain
//!   `std::mt19937` offers oneDAL.
//! * [`partition`] — the three parallel generation methods the paper
//!   lists (§IV-D): **Family**, **SkipAhead**, **LeapFrog**.
//! * [`distributions`] — uniform / gaussian / bernoulli / randint bulk
//!   generators layered on any engine.

pub mod distributions;
pub mod mcg31;
pub mod mcg59;
pub mod mt19937;
pub mod partition;

pub use distributions::{Bernoulli, Distribution, Gaussian, Uniform, UniformInt};
pub use mcg31::Mcg31;
pub use mcg59::Mcg59;
pub use mt19937::Mt19937;
pub use partition::{family_streams, leapfrog_streams, skipahead_streams};

use crate::error::{Error, Result};

/// A uniform pseudo-random engine in the MKL-VSL / OpenRNG mould.
///
/// Engines yield raw `u32`/`u64` words plus canonical `[0, 1)` doubles;
/// distributions ([`distributions`]) are layered on top. The two
/// stream-partitioning entry points mirror `vslSkipAheadStream` /
/// `vslLeapfrogStream` including *which engines support which method*.
pub trait Engine: Send {
    /// Next raw 32-bit word.
    fn next_u32(&mut self) -> u32;

    /// Next raw 64-bit word (two 32-bit draws by default).
    fn next_u64(&mut self) -> u64 {
        (u64::from(self.next_u32()) << 32) | u64::from(self.next_u32())
    }

    /// Canonical uniform in `[0, 1)` with 53 random bits.
    fn next_f64(&mut self) -> f64 {
        // 53-bit mantissa path, engine-agnostic.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Skip the stream forward by `n` draws (`vslSkipAheadStream`).
    fn skip_ahead(&mut self, n: u64) -> Result<()>;

    /// Re-tune the engine to emit elements `k, k+s, k+2s, …` of the base
    /// sequence (`vslLeapfrogStream` with stream index `k` of `s`).
    fn leapfrog(&mut self, k: u64, s: u64) -> Result<()>;

    /// Clone into a boxed engine (object-safe `Clone`).
    fn clone_box(&self) -> Box<dyn Engine>;

    /// Engine name for diagnostics / metrics.
    fn name(&self) -> &'static str;
}

impl Clone for Box<dyn Engine> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// The stdc++ baseline backend (Fig. 3 "libcpp"): MT19937 stripped of
/// the VSL parallel-stream entry points, exactly the feature set oneDAL
/// had on ARM before OpenRNG was integrated.
#[derive(Clone)]
pub struct StdCxxRng(Mt19937);

impl StdCxxRng {
    pub fn new(seed: u32) -> Self {
        Self(Mt19937::new(seed))
    }
}

impl Engine for StdCxxRng {
    fn next_u32(&mut self) -> u32 {
        self.0.next_u32()
    }

    fn skip_ahead(&mut self, _n: u64) -> Result<()> {
        Err(Error::Param(
            "stdc++ backend: SkipAhead unsupported (upgrade to OpenRNG backend)".into(),
        ))
    }

    fn leapfrog(&mut self, _k: u64, _s: u64) -> Result<()> {
        Err(Error::Param(
            "stdc++ backend: LeapFrog unsupported (upgrade to OpenRNG backend)".into(),
        ))
    }

    fn clone_box(&self) -> Box<dyn Engine> {
        Box::new(self.clone())
    }

    fn name(&self) -> &'static str {
        "stdc++-mt19937"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stdcxx_matches_mt19937_sequence() {
        let mut a = StdCxxRng::new(5489);
        let mut b = Mt19937::new(5489);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn stdcxx_rejects_parallel_methods() {
        let mut e = StdCxxRng::new(1);
        assert!(e.skip_ahead(10).is_err());
        assert!(e.leapfrog(0, 4).is_err());
    }

    #[test]
    fn canonical_double_in_unit_interval() {
        let mut e = Mt19937::new(7);
        for _ in 0..10_000 {
            let u = e.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }
}
