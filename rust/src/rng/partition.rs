//! The three parallel generation methods the paper lists for OpenRNG
//! (§IV-D): **Family**, **SkipAhead** and **LeapFrog**. Each turns one
//! logical stream into `s` disjoint per-thread streams; the random forest
//! trainer and the synthetic-data generators consume these.

use super::{Engine, Mcg59, Mt19937};
use crate::error::Result;

/// SplitMix64 finalizer, used only to derive well-separated family seeds.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// **Family method** — independent streams from a family of generators.
///
/// OpenRNG realizes this with parameterized families (mt2203); with a
/// single-parameter engine the family is derived by decorrelated seeding,
/// which preserves the method's contract: streams share no state and can
/// be handed to threads with zero coordination.
pub fn family_streams(seed: u64, s: usize) -> Vec<Box<dyn Engine>> {
    (0..s)
        .map(|k| {
            let derived = splitmix64(seed ^ splitmix64(k as u64 + 1));
            Box::new(Mt19937::new(derived as u32)) as Box<dyn Engine>
        })
        .collect()
}

/// **SkipAhead method** — stream `k` starts at element `k·block` of the
/// base sequence; each thread owns a disjoint contiguous block.
pub fn skipahead_streams<E>(base: &E, s: usize, block: u64) -> Result<Vec<Box<dyn Engine>>>
where
    E: Engine + Clone + 'static,
{
    let mut out: Vec<Box<dyn Engine>> = Vec::with_capacity(s);
    for k in 0..s {
        let mut e = base.clone();
        e.skip_ahead(k as u64 * block)?;
        out.push(Box::new(e));
    }
    Ok(out)
}

/// **LeapFrog method** — stream `k` gets elements `k, k+s, k+2s, …` of
/// the base sequence (only engines with closed-form striding, i.e.
/// [`Mcg59`], support this — matching MKL VSL).
pub fn leapfrog_streams(base: &Mcg59, s: usize) -> Result<Vec<Box<dyn Engine>>> {
    let mut out: Vec<Box<dyn Engine>> = Vec::with_capacity(s);
    for k in 0..s {
        let mut e = base.clone();
        e.leapfrog(k as u64, s as u64)?;
        out.push(Box::new(e));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_streams_are_decorrelated() {
        let streams = family_streams(7, 4);
        let firsts: Vec<u32> = streams.into_iter().map(|mut e| e.next_u32()).collect();
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_ne!(firsts[i], firsts[j]);
            }
        }
    }

    #[test]
    fn family_is_deterministic() {
        let a: Vec<u32> = family_streams(9, 3).into_iter().map(|mut e| e.next_u32()).collect();
        let b: Vec<u32> = family_streams(9, 3).into_iter().map(|mut e| e.next_u32()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn skipahead_streams_tile_base_sequence() {
        let base = Mt19937::new(11);
        let mut seq = Mt19937::new(11);
        let whole: Vec<u32> = (0..4 * 100).map(|_| seq.next_u32()).collect();
        let streams = skipahead_streams(&base, 4, 100).unwrap();
        for (k, mut e) in streams.into_iter().enumerate() {
            for i in 0..100 {
                assert_eq!(e.next_u32(), whole[k * 100 + i]);
            }
        }
    }

    #[test]
    fn leapfrog_streams_interleave_base_sequence() {
        let base = Mcg59::new(13);
        let mut seq = Mcg59::new(13);
        let whole: Vec<u64> = (0..3 * 50).map(|_| seq.next_raw()).collect();
        let streams = leapfrog_streams(&base, 3).unwrap();
        for (k, mut e) in streams.into_iter().enumerate() {
            // Engine::next_u32 maps one raw draw to one output word.
            for i in 0..50 {
                assert_eq!(e.next_u32(), (whole[k + 3 * i] >> 27) as u32);
            }
        }
    }
}
