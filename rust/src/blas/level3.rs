//! Level-3 matrix–matrix kernels (row-major).
//!
//! `gemm_naive` is the deliberately unoptimized baseline (the "stock
//! scikit-learn on ARM" rung). `gemm` is the cache-blocked, register-tiled
//! kernel playing the OpenBLAS role: i-k-j loop order for unit-stride
//! inner loops, 64×64×64 L1 blocks, 4-row micro-tiles.

use crate::dtype::Float;

/// Operation applied to an operand, mirroring the `op(A)` of the paper's
/// sparse-routine definitions (§IV-B): identity or transpose.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Transpose {
    No,
    Yes,
}

/// Textbook i-j-k triple loop, kept as the naive-backend baseline and as
/// the oracle for the blocked kernel's tests.
pub fn gemm_naive<T: Float>(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
) {
    debug_assert_eq!(c.len(), m * n);
    let at = |i: usize, l: usize| match ta {
        Transpose::No => a[i * k + l],
        Transpose::Yes => a[l * m + i],
    };
    let bt = |l: usize, j: usize| match tb {
        Transpose::No => b[l * n + j],
        Transpose::Yes => b[j * k + l],
    };
    for i in 0..m {
        for j in 0..n {
            let mut acc = T::ZERO;
            for l in 0..k {
                acc += at(i, l) * bt(l, j);
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

const BLOCK: usize = 64;

/// Blocked `C ← α·op(A)·op(B) + β·C` for row-major operands.
///
/// op(A) is `m×k`, op(B) is `k×n`, C is `m×n`. Transposed operands are
/// packed into row-major scratch once (O(mk)/O(kn)) so the hot loop is
/// always unit-stride — the same "copy into a vector-friendly layout"
/// strategy OpenBLAS uses on ARM.
pub fn gemm<T: Float>(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
) {
    debug_assert_eq!(c.len(), m * n);
    // Pack transposed operands (cheap relative to the O(mnk) multiply).
    let a_packed;
    let a_rm: &[T] = match ta {
        Transpose::No => a,
        Transpose::Yes => {
            let mut p = vec![T::ZERO; m * k];
            for l in 0..k {
                for i in 0..m {
                    p[i * k + l] = a[l * m + i];
                }
            }
            a_packed = p;
            &a_packed
        }
    };
    let b_packed;
    let b_rm: &[T] = match tb {
        Transpose::No => b,
        Transpose::Yes => {
            let mut p = vec![T::ZERO; k * n];
            for j in 0..n {
                for l in 0..k {
                    p[l * n + j] = b[j * k + l];
                }
            }
            b_packed = p;
            &b_packed
        }
    };

    // β-scale once up front.
    if beta == T::ZERO {
        c.fill(T::ZERO);
    } else if beta != T::ONE {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }

    // i-k-j blocked loops: C[i] += alpha*A[i,l] * B[l], unit stride in j.
    for i0 in (0..m).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(m);
        for l0 in (0..k).step_by(BLOCK) {
            let l1 = (l0 + BLOCK).min(k);
            for j0 in (0..n).step_by(BLOCK) {
                let j1 = (j0 + BLOCK).min(n);
                for i in i0..i1 {
                    let crow = &mut c[i * n..i * n + n];
                    for l in l0..l1 {
                        let aval = alpha * a_rm[i * k + l];
                        if aval == T::ZERO {
                            continue;
                        }
                        let brow = &b_rm[l * n..l * n + n];
                        for j in j0..j1 {
                            crow[j] = aval.mul_add(brow[j], crow[j]);
                        }
                    }
                }
            }
        }
    }
}

/// Symmetric rank-k update `C ← α·A·Aᵀ + β·C` for row-major `A (m×k)`,
/// `C (m×m)` — the workhorse of the VSL cross-product kernel (eq. 6's
/// `X·Xᵀ` term). Only the full square is written (oneDAL consumes full
/// symmetric storage).
pub fn syrk<T: Float>(m: usize, k: usize, alpha: T, a: &[T], beta: T, c: &mut [T]) {
    debug_assert_eq!(c.len(), m * m);
    if beta == T::ZERO {
        c.fill(T::ZERO);
    } else if beta != T::ONE {
        for v in c.iter_mut() {
            *v *= beta;
        }
    }
    // Upper triangle via dot products on contiguous rows, then mirror.
    for i in 0..m {
        let ri = &a[i * k..(i + 1) * k];
        for j in i..m {
            let rj = &a[j * k..(j + 1) * k];
            let v = alpha * super::level1::dot(ri, rj);
            c[i * m + j] += v;
            if i != j {
                c[j * m + i] += v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Mt19937, Uniform};

    fn rand_mat(e: &mut Mt19937, n: usize) -> Vec<f64> {
        let mut d = Uniform::new(-1.0, 1.0);
        (0..n).map(|_| d.sample(e)).collect()
    }

    #[test]
    fn blocked_matches_naive_all_transposes() {
        let mut e = Mt19937::new(42);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (3, 5, 7), (64, 64, 64), (65, 33, 70), (128, 17, 96)] {
            for ta in [Transpose::No, Transpose::Yes] {
                for tb in [Transpose::No, Transpose::Yes] {
                    let a = rand_mat(&mut e, m * k);
                    let b = rand_mat(&mut e, k * n);
                    let c0 = rand_mat(&mut e, m * n);
                    let mut c1 = c0.clone();
                    let mut c2 = c0.clone();
                    gemm_naive(ta, tb, m, n, k, 1.3, &a, &b, 0.7, &mut c1);
                    gemm(ta, tb, m, n, k, 1.3, &a, &b, 0.7, &mut c2);
                    for (u, v) in c1.iter().zip(&c2) {
                        assert!((u - v).abs() < 1e-9, "m={m} n={n} k={k} ta={ta:?} tb={tb:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_identity() {
        let n = 8;
        let mut eye = vec![0.0f64; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut e = Mt19937::new(7);
        let a = rand_mat(&mut e, n * n);
        let mut c = vec![0.0f64; n * n];
        gemm(Transpose::No, Transpose::No, n, n, n, 1.0, &a, &eye, 0.0, &mut c);
        for (u, v) in a.iter().zip(&c) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn syrk_symmetric() {
        let mut e = Mt19937::new(11);
        let a = rand_mat(&mut e, 9 * 5);
        let mut c = vec![0.0f64; 81];
        syrk(9, 5, 1.0, &a, 0.0, &mut c);
        for i in 0..9 {
            for j in 0..9 {
                assert_eq!(c[i * 9 + j], c[j * 9 + i]);
            }
        }
    }

    #[test]
    fn gemm_beta_accumulates() {
        let a = [2.0f64];
        let b = [3.0f64];
        let mut c = [10.0f64];
        gemm(Transpose::No, Transpose::No, 1, 1, 1, 1.0, &a, &b, 1.0, &mut c);
        assert_eq!(c[0], 16.0);
    }
}
