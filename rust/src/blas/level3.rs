//! Level-3 matrix–matrix kernels (row-major).
//!
//! `gemm_naive` is the deliberately unoptimized baseline (the "stock
//! scikit-learn on ARM" rung). `gemm`/`syrk` are the packed-panel,
//! register-tiled, multithreaded engine playing the OpenBLAS role the
//! paper swaps in for MKL:
//!
//! 1. **Pack once** — `op(A)` is packed into `MR`-row micro-panels and
//!    `op(B)` into `NR`-column micro-panels (transpose is absorbed by
//!    the packing, so the hot loop never strides), exactly the
//!    "copy into a vector-friendly layout" step OpenBLAS performs on ARM
//!    and the packed-layout codegen literature formalizes.
//! 2. **Register-tiled microkernel** — an `MR×NR` block of accumulators
//!    marches down the shared `k` dimension with `mul_add`, branch-free:
//!    the zero-skip branch of the old kernel is gone, so NaN/Inf in
//!    either operand propagates exactly like the naive oracle.
//! 3. **Row-panel threading** — C's row panels are handed to scoped
//!    workers by [`crate::parallel`]; cuts land only on `MR` boundaries,
//!    so every tile is computed whole by one worker and the result is
//!    bit-identical at any worker count.
//!
//! The panel geometry is vector-length-agnostic: `NR` (one vector of
//! output columns) and `KC` (the k-block keeping a `KC×NR` B-panel
//! slice resident) come from the active
//! [`LaneProfile`](crate::primitives::lanes::LaneProfile) — `NR = lanes`
//! and `KC = 2048/NR`, so the B-panel footprint is constant across
//! profiles and the `sve512` default reproduces the historical
//! `NR=8/KC=256` engine bit-for-bit. The microkernel monomorphizes per
//! profile (`const NR`) and is selected once per entry call via
//! [`crate::with_lane_count!`], never per element. `MR` (the A-side
//! unroll) is profile-independent. Because every C element accumulates
//! its own dot product in ascending-`k` order regardless of which
//! `MR×NR` tile covers it, gemm/syrk values are bit-identical **across
//! profiles** whenever `k` fits one KC block, and agree to roundoff
//! (the naive rung is the oracle) when KC regroups the k sweep.

use crate::dtype::Float;
use crate::parallel;
use crate::primitives::lanes::{default_profile, LaneProfile};

/// Operation applied to an operand, mirroring the `op(A)` of the paper's
/// sparse-routine definitions (§IV-B): identity or transpose.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Transpose {
    No,
    Yes,
}

/// Textbook i-j-k triple loop, kept as the naive-backend baseline and as
/// the oracle for the packed kernel's tests.
pub fn gemm_naive<T: Float>(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
) {
    debug_assert_eq!(c.len(), m * n);
    let at = |i: usize, l: usize| match ta {
        Transpose::No => a[i * k + l],
        Transpose::Yes => a[l * m + i],
    };
    let bt = |l: usize, j: usize| match tb {
        Transpose::No => b[l * n + j],
        Transpose::Yes => b[j * k + l],
    };
    for i in 0..m {
        for j in 0..n {
            let mut acc = T::ZERO;
            for l in 0..k {
                acc += at(i, l) * bt(l, j);
            }
            c[i * n + j] = alpha * acc + beta * c[i * n + j];
        }
    }
}

/// Micro-panel height: rows of `op(A)` / C per register tile —
/// re-exported from the lane-profile layer (profile-independent).
pub(crate) use crate::primitives::lanes::MR;
// Micro-panel width NR (columns of `op(B)` / C per register tile, one
// vector's worth) and the k-dimension block KC of the panel sweep both
// come from the active `LaneProfile`: full-`k` panels stop being
// L2-resident past ~2K values, so the compute loops walk `k` in
// `KC = 2048/NR`-sized blocks; within a block the `KC×NR` B-panel slice
// stays hot while the worker's `KC×MR` A-panel slices stream through
// it. Each C tile accumulates its α-scaled block partials in
// ascending-`k` order, so the k-blocking is identical at every worker
// count (bit-identity is preserved) and a single block (`k ≤ KC`)
// reproduces the unblocked sweep exactly.
/// Minimum multiply-adds per worker before fan-out pays for itself.
const PAR_MIN_FLOP: usize = 1 << 16;

// β-scale C once up front (shared by gemm/syrk; β == 0 overwrites).
use super::beta_scale as scale_c;

/// Pack `op(A)` (`m×k`) into `⌈m/MR⌉` micro-panels of `k×MR` scalars:
/// panel `ip` holds rows `ip·MR ..` in k-major order (`dst[l·MR + ii]`),
/// zero-padded in the row direction so the microkernel never branches
/// on the fringe.
fn pack_a<T: Float>(ta: Transpose, m: usize, k: usize, a: &[T]) -> Vec<T> {
    let panels = m.div_ceil(MR);
    let mut out = vec![T::ZERO; panels * k * MR];
    for ip in 0..panels {
        let i0 = ip * MR;
        let mr = MR.min(m - i0);
        let dst = &mut out[ip * k * MR..(ip + 1) * k * MR];
        match ta {
            Transpose::No => {
                for ii in 0..mr {
                    let row = &a[(i0 + ii) * k..(i0 + ii + 1) * k];
                    for (l, &v) in row.iter().enumerate() {
                        dst[l * MR + ii] = v;
                    }
                }
            }
            Transpose::Yes => {
                // A stored k×m: element (i, l) lives at a[l·m + i].
                for l in 0..k {
                    let src = &a[l * m + i0..l * m + i0 + mr];
                    for (ii, &v) in src.iter().enumerate() {
                        dst[l * MR + ii] = v;
                    }
                }
            }
        }
    }
    out
}

/// Pack `op(B)` (`k×n`) into `⌈n/nr⌉` micro-panels of `k×nr` scalars
/// (`dst[l·nr + jj]`), zero-padded in the column direction. `nr` is the
/// active profile's micro-panel width; packing is data movement only,
/// so a runtime width costs nothing over a const one.
fn pack_b<T: Float>(tb: Transpose, k: usize, n: usize, b: &[T], nr_w: usize) -> Vec<T> {
    let panels = n.div_ceil(nr_w);
    let mut out = vec![T::ZERO; panels * k * nr_w];
    for jp in 0..panels {
        let j0 = jp * nr_w;
        let nr = nr_w.min(n - j0);
        let dst = &mut out[jp * k * nr_w..(jp + 1) * k * nr_w];
        match tb {
            Transpose::No => {
                for l in 0..k {
                    let src = &b[l * n + j0..l * n + j0 + nr];
                    for (jj, &v) in src.iter().enumerate() {
                        dst[l * nr_w + jj] = v;
                    }
                }
            }
            Transpose::Yes => {
                // B stored n×k: element (l, j) lives at b[j·k + l].
                for jj in 0..nr {
                    let col = &b[(j0 + jj) * k..(j0 + jj + 1) * k];
                    for (l, &v) in col.iter().enumerate() {
                        dst[l * nr_w + jj] = v;
                    }
                }
            }
        }
    }
    out
}

/// The `MR×NR` register tile: `MR·NR` independent accumulators march
/// down `k` with `mul_add` on two unit-stride panel streams — no
/// branches, no writes until the caller stores the tile. `NR` is a
/// const generic so each lane profile gets its own fully-unrolled
/// monomorphization (2/4/8 columns ≙ one SVE vector at 128/256/512
/// bits), selected once per entry call by [`crate::with_lane_count!`].
#[inline]
fn microkernel<T: Float, const NR: usize>(k: usize, apanel: &[T], bpanel: &[T]) -> [[T; NR]; MR] {
    let mut acc = [[T::ZERO; NR]; MR];
    for l in 0..k {
        let av = &apanel[l * MR..l * MR + MR];
        let bv = &bpanel[l * NR..l * NR + NR];
        for (accr, &a) in acc.iter_mut().zip(av) {
            for (dst, &b) in accr.iter_mut().zip(bv) {
                *dst = a.mul_add(b, *dst);
            }
        }
    }
    acc
}

/// Pre-packed `op(B)` micro-panels, reusable across many `gemm` calls
/// against the same right-hand operand. The SVM gram-tile engine packs
/// the active-set panel once per shrink generation and then issues one
/// small-`m` tile multiply per working set; re-packing B on every call
/// would dominate those thin multiplies. Produced by [`pack_b_panels`],
/// consumed by [`gemm_prepacked_threads`] — which is bit-identical to
/// [`gemm_threads`] on the same operands because both run the same
/// panel sweep over the same packed bytes. `Clone` so fitted models can
/// own a panel (`primitives::packed::ModelPanel`) and stay `Clone`.
#[derive(Clone, Debug)]
pub struct PackedB<T> {
    panels: Vec<T>,
    k: usize,
    n: usize,
    profile: LaneProfile,
}

impl<T: Float> PackedB<T> {
    /// Shared `k` dimension the panels were packed with.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Column count of `op(B)`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Lane profile the panels were packed under. The packed layout is
    /// profile-specific (`NR = lanes` columns per micro-panel), so
    /// consumers ([`gemm_prepacked_threads`], the distances engine)
    /// read the geometry from the panel itself — a panel can never be
    /// swept at the wrong width.
    pub fn profile(&self) -> LaneProfile {
        self.profile
    }
}

/// Pack `op(B)` (`k×n`) once into the micro-panel layout for reuse
/// across [`gemm_prepacked_threads`] calls, under the process-default
/// lane profile.
pub fn pack_b_panels<T: Float>(tb: Transpose, k: usize, n: usize, b: &[T]) -> PackedB<T> {
    pack_b_panels_profile(tb, k, n, b, default_profile())
}

/// [`pack_b_panels`] under an explicit [`LaneProfile`] — the entry the
/// `Context`-aware layers use so builder-selected profiles reach the
/// packed layout.
pub fn pack_b_panels_profile<T: Float>(
    tb: Transpose,
    k: usize,
    n: usize,
    b: &[T],
    profile: LaneProfile,
) -> PackedB<T> {
    PackedB { panels: pack_b(tb, k, n, b, profile.nr()), k, n, profile }
}

/// The KC-blocked panel sweep shared by every gemm entry point: compute
/// C rows `[r0, r1)` from packed-A panels `ap` and packed-B panels `bp`.
/// Within a KC block the `KC×NR` B-panel slice stays hot in L1/L2 while
/// the worker's A-panel slices stream through it. Each C tile
/// accumulates its α-scaled block partials in ascending-`k` order, so
/// the result is bit-identical at every worker count and to the
/// unblocked sweep when `k ≤ KC`. `NR` is the profile's lane count
/// (const-generic, so each profile's sweep is a separate fully-unrolled
/// monomorphization); `kc` must be the same profile's k-block.
#[allow(clippy::too_many_arguments)]
fn panel_sweep<T: Float, const NR: usize>(
    m: usize,
    n: usize,
    k: usize,
    kc: usize,
    alpha: T,
    ap: &[T],
    bp: &[T],
    r0: usize,
    r1: usize,
    block: &mut [T],
) {
    let npanels = n.div_ceil(NR);
    let p0 = r0 / MR;
    let p1 = r1.div_ceil(MR);
    let mut l0 = 0usize;
    while l0 < k {
        let lb = kc.min(k - l0);
        for jp in 0..npanels {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let bpanel = &bp[jp * k * NR + l0 * NR..jp * k * NR + (l0 + lb) * NR];
            for ip in p0..p1 {
                let i0 = ip * MR;
                let mr = MR.min(m - i0);
                let apanel = &ap[ip * k * MR + l0 * MR..ip * k * MR + (l0 + lb) * MR];
                let acc = microkernel::<T, NR>(lb, apanel, bpanel);
                for ii in 0..mr {
                    let at = (i0 - r0 + ii) * n + j0;
                    let row = &mut block[at..at + nr];
                    for (jj, dst) in row.iter_mut().enumerate() {
                        *dst = alpha.mul_add(acc[ii][jj], *dst);
                    }
                }
            }
        }
        l0 += lb;
    }
}

/// `C ← α·op(A)·op(B) + β·C` with an explicit worker count — the entry
/// the algorithm layer routes `Context::threads()` into. Runs under the
/// process-default lane profile; see [`gemm_threads_profile`].
///
/// op(A) is `m×k`, op(B) is `k×n`, C is `m×n`, all row-major.
#[allow(clippy::too_many_arguments)]
pub fn gemm_threads<T: Float>(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
    threads: usize,
) {
    gemm_threads_profile(ta, tb, m, n, k, alpha, a, b, beta, c, threads, default_profile());
}

/// [`gemm_threads`] under an explicit [`LaneProfile`]: the profile
/// fixes `NR`/`KC`, the dispatch happens here (once per call, not per
/// element) via [`crate::with_lane_count!`].
#[allow(clippy::too_many_arguments)]
pub fn gemm_threads_profile<T: Float>(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
    threads: usize,
    profile: LaneProfile,
) {
    debug_assert_eq!(c.len(), m * n);
    scale_c(beta, c);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let ap = pack_a(ta, m, k, a);
    let bp = pack_b(tb, k, n, b, profile.nr());
    let kc = profile.kc();
    let work = m.saturating_mul(n).saturating_mul(k);
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, MR);
    let (ap, bp) = (&ap, &bp);
    parallel::scope_rows(c, n, &bounds, |r0, r1, block| {
        crate::with_lane_count!(profile, L, {
            panel_sweep::<T, L>(m, n, k, kc, alpha, ap, bp, r0, r1, block);
        });
    });
}

/// `C ← α·op(A)·B + β·C` against a pre-packed `B` — the gram-tile entry:
/// pack the stationary operand once with [`pack_b_panels`], then issue
/// many thin row-tile multiplies without re-packing. Runs the exact
/// panel sweep of [`gemm_threads`], so results are bit-identical to the
/// pack-every-call path at every worker count.
///
/// Because A-panels cover disjoint `MR`-row groups and each C tile
/// accumulates independently, computing an `MR`-aligned **row slice**
/// of C with its own call (A sliced to the same rows) is bit-identical
/// to the corresponding rows of the full-`m` call — the contract the
/// fused distance engine ([`crate::primitives::distances`]) builds its
/// per-worker tile sweep on.
#[allow(clippy::too_many_arguments)]
pub fn gemm_prepacked_threads<T: Float>(
    ta: Transpose,
    m: usize,
    alpha: T,
    a: &[T],
    bp: &PackedB<T>,
    beta: T,
    c: &mut [T],
    threads: usize,
) {
    let (n, k) = (bp.n, bp.k);
    debug_assert_eq!(c.len(), m * n);
    scale_c(beta, c);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    // The packed layout fixes the geometry: sweep at the profile the
    // panels were packed under, whatever the process default is now.
    let profile = bp.profile;
    let kc = profile.kc();
    let ap = pack_a(ta, m, k, a);
    let work = m.saturating_mul(n).saturating_mul(k);
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, MR);
    let (ap, bpanels) = (&ap, bp.panels.as_slice());
    parallel::scope_rows(c, n, &bounds, |r0, r1, block| {
        crate::with_lane_count!(profile, L, {
            panel_sweep::<T, L>(m, n, k, kc, alpha, ap, bpanels, r0, r1, block);
        });
    });
}

/// `C ← α·op(A)·op(B) + β·C` on the process-default worker count
/// (callers holding a [`crate::coordinator::Context`] should prefer
/// [`gemm_threads`] with `ctx.threads()`).
pub fn gemm<T: Float>(
    ta: Transpose,
    tb: Transpose,
    m: usize,
    n: usize,
    k: usize,
    alpha: T,
    a: &[T],
    b: &[T],
    beta: T,
    c: &mut [T],
) {
    gemm_threads(ta, tb, m, n, k, alpha, a, b, beta, c, parallel::default_threads());
}

/// Symmetric rank-k update `C ← α·A·Aᵀ + β·C` with an explicit worker
/// count, for row-major `A (m×k)`, `C (m×m)`.
///
/// The packed engine computes only upper-triangle panel blocks (workers
/// get triangle-balanced row ranges) and mirrors once at the end, so
/// the full square is written — the storage oneDAL consumes. When
/// `β ≠ 0`, `C` must be symmetric on entry (the standard BLAS contract,
/// which only defines one triangle; every in-tree caller accumulates
/// onto a symmetric cross-product).
pub fn syrk_threads<T: Float>(
    m: usize,
    k: usize,
    alpha: T,
    a: &[T],
    beta: T,
    c: &mut [T],
    threads: usize,
) {
    syrk_threads_profile(m, k, alpha, a, beta, c, threads, default_profile());
}

/// Upper-triangle panel sweep of one worker's row range — the syrk
/// counterpart of [`panel_sweep`], monomorphized per lane profile.
#[allow(clippy::too_many_arguments)]
fn syrk_sweep<T: Float, const NR: usize>(
    m: usize,
    k: usize,
    kc: usize,
    alpha: T,
    ap: &[T],
    bp: &[T],
    r0: usize,
    r1: usize,
    block: &mut [T],
) {
    let npanels = m.div_ceil(NR);
    let p0 = r0 / MR;
    let p1 = r1.div_ceil(MR);
    // Same KC-blocked k sweep as the GEMM engine.
    let mut l0 = 0usize;
    while l0 < k {
        let lb = kc.min(k - l0);
        for ip in p0..p1 {
            let i0 = ip * MR;
            let mr = MR.min(m - i0);
            let apanel = &ap[ip * k * MR + l0 * MR..ip * k * MR + (l0 + lb) * MR];
            // First column panel that can reach j ≥ i0: its column range
            // [j0, j0+NR) always straddles i0 when j0 = ⌊i0/NR⌋·NR.
            for jp in i0 / NR..npanels {
                let j0 = jp * NR;
                let nr = NR.min(m - j0);
                let bpanel = &bp[jp * k * NR + l0 * NR..jp * k * NR + (l0 + lb) * NR];
                let acc = microkernel::<T, NR>(lb, apanel, bpanel);
                for ii in 0..mr {
                    let i = i0 + ii;
                    let row = &mut block[(i - r0) * m..(i - r0 + 1) * m];
                    for j in j0.max(i)..j0 + nr {
                        row[j] = alpha.mul_add(acc[ii][j - j0], row[j]);
                    }
                }
            }
        }
        l0 += lb;
    }
}

/// [`syrk_threads`] under an explicit [`LaneProfile`].
#[allow(clippy::too_many_arguments)]
pub fn syrk_threads_profile<T: Float>(
    m: usize,
    k: usize,
    alpha: T,
    a: &[T],
    beta: T,
    c: &mut [T],
    threads: usize,
    profile: LaneProfile,
) {
    debug_assert_eq!(c.len(), m * m);
    scale_c(beta, c);
    if m == 0 || k == 0 {
        return;
    }
    let ap = pack_a(Transpose::No, m, k, a);
    // Aᵀ is k×m stored as the m×k buffer — exactly the Transpose::Yes
    // packing of a k×m operand.
    let bp = pack_b(Transpose::Yes, k, m, a, profile.nr());
    let kc = profile.kc();
    let work = m.saturating_mul(m).saturating_mul(k) / 2 + 1;
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::triangle_bounds(m, workers, MR);
    let (ap, bp) = (&ap, &bp);
    parallel::scope_rows(c, m, &bounds, |r0, r1, block| {
        crate::with_lane_count!(profile, L, {
            syrk_sweep::<T, L>(m, k, kc, alpha, ap, bp, r0, r1, block);
        });
    });
    // Mirror the upper triangle into the lower once.
    for i in 0..m {
        for j in i + 1..m {
            c[j * m + i] = c[i * m + j];
        }
    }
}

/// `C ← α·A·Aᵀ + β·C` on the process-default worker count — the
/// workhorse of the VSL cross-product kernel (eq. 6's `X·Xᵀ` term).
pub fn syrk<T: Float>(m: usize, k: usize, alpha: T, a: &[T], beta: T, c: &mut [T]) {
    syrk_threads(m, k, alpha, a, beta, c, parallel::default_threads());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Mt19937, Uniform};

    fn rand_mat(e: &mut Mt19937, n: usize) -> Vec<f64> {
        let mut d = Uniform::new(-1.0, 1.0);
        (0..n).map(|_| d.sample(e)).collect()
    }

    #[test]
    fn packed_matches_naive_all_transposes() {
        let mut e = Mt19937::new(42);
        // 300 and 613 straddle the KC=256 block edge (1 full block +
        // fringe, 2 blocks + fringe) to exercise the blocked k sweep.
        for &(m, n, k) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (64, 64, 64),
            (65, 33, 70),
            (128, 17, 96),
            (9, 11, 300),
            (17, 7, 613),
        ] {
            for ta in [Transpose::No, Transpose::Yes] {
                for tb in [Transpose::No, Transpose::Yes] {
                    let a = rand_mat(&mut e, m * k);
                    let b = rand_mat(&mut e, k * n);
                    let c0 = rand_mat(&mut e, m * n);
                    let mut c1 = c0.clone();
                    let mut c2 = c0.clone();
                    gemm_naive(ta, tb, m, n, k, 1.3, &a, &b, 0.7, &mut c1);
                    gemm(ta, tb, m, n, k, 1.3, &a, &b, 0.7, &mut c2);
                    for (u, v) in c1.iter().zip(&c2) {
                        assert!((u - v).abs() < 1e-9, "m={m} n={n} k={k} ta={ta:?} tb={tb:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn gemm_identity() {
        let n = 8;
        let mut eye = vec![0.0f64; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut e = Mt19937::new(7);
        let a = rand_mat(&mut e, n * n);
        let mut c = vec![0.0f64; n * n];
        gemm(Transpose::No, Transpose::No, n, n, n, 1.0, &a, &eye, 0.0, &mut c);
        for (u, v) in a.iter().zip(&c) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    /// The old kernel's `aval == 0 → continue` skip silently dropped
    /// NaN/Inf from the corresponding B row. The packed microkernel is
    /// branch-free, so contamination must match the naive oracle
    /// bit-for-bit in NaN placement.
    #[test]
    fn gemm_propagates_nan_and_inf_like_naive() {
        let (m, n, k) = (5usize, 9usize, 6usize);
        let mut e = Mt19937::new(33);
        let mut a = rand_mat(&mut e, m * k);
        let mut b = rand_mat(&mut e, k * n);
        // A zero in A aligned with a NaN row of B: the zero-skip would
        // have erased the NaN.
        a[2 * k + 3] = 0.0;
        b[3 * n + 4] = f64::NAN;
        b[n + 7] = f64::INFINITY;
        let mut c1 = vec![0.25f64; m * n];
        let mut c2 = c1.clone();
        gemm_naive(Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 1.0, &mut c1);
        gemm(Transpose::No, Transpose::No, m, n, k, 1.0, &a, &b, 1.0, &mut c2);
        for (i, (u, v)) in c1.iter().zip(&c2).enumerate() {
            assert_eq!(u.is_nan(), v.is_nan(), "NaN placement differs at {i}");
            if !u.is_nan() {
                assert!((u - v).abs() < 1e-9, "at {i}: {u} vs {v}");
            }
        }
        // Column 4 must be NaN in every row (each row of A meets B row 3).
        for i in 0..m {
            assert!(c2[i * n + 4].is_nan(), "row {i} lost NaN propagation");
        }
    }

    #[test]
    fn gemm_thread_counts_bit_identical() {
        let (m, n, k) = (67usize, 41usize, 53usize);
        let mut e = Mt19937::new(55);
        let a = rand_mat(&mut e, m * k);
        let b = rand_mat(&mut e, k * n);
        let c0 = rand_mat(&mut e, m * n);
        let mut base = c0.clone();
        gemm_threads(Transpose::No, Transpose::No, m, n, k, 1.1, &a, &b, 0.4, &mut base, 1);
        for threads in 2..=4 {
            let mut c = c0.clone();
            gemm_threads(Transpose::No, Transpose::No, m, n, k, 1.1, &a, &b, 0.4, &mut c, threads);
            for (u, v) in base.iter().zip(&c) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    /// Packing B once and reusing it across calls must be bit-identical
    /// to the pack-every-call path — the SVM gram-tile engine relies on
    /// this to keep tile results independent of cache state.
    #[test]
    fn gemm_prepacked_matches_gemm_bitwise() {
        let mut e = Mt19937::new(61);
        for &(m, n, k) in &[(1usize, 1usize, 1usize), (5, 9, 4), (33, 41, 28), (7, 23, 300)] {
            for tb in [Transpose::No, Transpose::Yes] {
                let b = rand_mat(&mut e, k * n);
                let packed = pack_b_panels(tb, k, n, &b);
                assert_eq!(packed.k(), k);
                assert_eq!(packed.n(), n);
                // Several A operands against the same packed B.
                for ta in [Transpose::No, Transpose::Yes] {
                    for threads in 1..=3usize {
                        let a = rand_mat(&mut e, m * k);
                        let c0 = rand_mat(&mut e, m * n);
                        let mut c1 = c0.clone();
                        let mut c2 = c0.clone();
                        gemm_threads(ta, tb, m, n, k, 1.2, &a, &b, 0.3, &mut c1, threads);
                        gemm_prepacked_threads(ta, m, 1.2, &a, &packed, 0.3, &mut c2, threads);
                        for (u, v) in c1.iter().zip(&c2) {
                            assert_eq!(u.to_bits(), v.to_bits(), "m={m} n={n} k={k} tb={tb:?}");
                        }
                    }
                }
            }
        }
    }

    /// MR-aligned row slices computed by separate prepacked calls must
    /// be bit-identical to the one-call full sweep — the fused distance
    /// engine's workers each multiply their own query tile against the
    /// shared packed corpus and rely on this to stay worker-count
    /// invariant.
    #[test]
    fn gemm_prepacked_row_slices_match_full_call_bitwise() {
        let mut e = Mt19937::new(71);
        // k = 300 straddles the KC = 256 block edge.
        let (m, n, k) = (37usize, 29usize, 300usize);
        let a = rand_mat(&mut e, m * k);
        let b = rand_mat(&mut e, k * n);
        let packed = pack_b_panels(Transpose::No, k, n, &b);
        let mut full = vec![0.0f64; m * n];
        gemm_prepacked_threads(Transpose::No, m, 1.0, &a, &packed, 0.0, &mut full, 3);
        let mut sliced = vec![0.0f64; m * n];
        for r0 in (0..m).step_by(MR * 2) {
            let r1 = (r0 + MR * 2).min(m);
            gemm_prepacked_threads(
                Transpose::No,
                r1 - r0,
                1.0,
                &a[r0 * k..r1 * k],
                &packed,
                0.0,
                &mut sliced[r0 * n..r1 * n],
                1,
            );
        }
        for (i, (u, v)) in full.iter().zip(&sliced).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "element {i}");
        }
    }

    #[test]
    fn syrk_symmetric() {
        let mut e = Mt19937::new(11);
        let a = rand_mat(&mut e, 9 * 5);
        let mut c = vec![0.0f64; 81];
        syrk(9, 5, 1.0, &a, 0.0, &mut c);
        for i in 0..9 {
            for j in 0..9 {
                assert_eq!(c[i * 9 + j], c[j * 9 + i]);
            }
        }
    }

    #[test]
    fn syrk_matches_gemm_oracle_odd_shapes() {
        let mut e = Mt19937::new(19);
        for &(m, k) in &[(1usize, 1usize), (7, 3), (33, 17), (64, 64), (129, 65), (21, 530)] {
            let a = rand_mat(&mut e, m * k);
            let mut c1 = vec![0.0f64; m * m];
            syrk(m, k, 1.4, &a, 0.0, &mut c1);
            let mut c2 = vec![0.0f64; m * m];
            gemm_naive(Transpose::No, Transpose::Yes, m, m, k, 1.4, &a, &a, 0.0, &mut c2);
            for (u, v) in c1.iter().zip(&c2) {
                assert!((u - v).abs() < 1e-9, "m={m} k={k}");
            }
        }
    }

    #[test]
    fn syrk_thread_counts_bit_identical() {
        let (m, k) = (70usize, 31usize);
        let mut e = Mt19937::new(23);
        let a = rand_mat(&mut e, m * k);
        let mut base = vec![0.0f64; m * m];
        syrk_threads(m, k, 0.9, &a, 0.0, &mut base, 1);
        for threads in 2..=4 {
            let mut c = vec![0.0f64; m * m];
            syrk_threads(m, k, 0.9, &a, 0.0, &mut c, threads);
            for (u, v) in base.iter().zip(&c) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn syrk_beta_accumulates_on_symmetric_c() {
        let mut e = Mt19937::new(29);
        let a = rand_mat(&mut e, 6 * 4);
        // Symmetric starting C.
        let mut c = vec![0.0f64; 36];
        syrk(6, 4, 1.0, &a, 0.0, &mut c);
        let snapshot = c.clone();
        syrk(6, 4, 1.0, &a, 1.0, &mut c);
        for (u, v) in c.iter().zip(&snapshot) {
            assert!((u - 2.0 * v).abs() < 1e-9);
        }
    }

    #[test]
    fn gemm_beta_accumulates() {
        let a = [2.0f64];
        let b = [3.0f64];
        let mut c = [10.0f64];
        gemm(Transpose::No, Transpose::No, 1, 1, 1, 1.0, &a, &b, 1.0, &mut c);
        assert_eq!(c[0], 16.0);
    }

    /// Every lane profile must agree with the naive oracle and stay
    /// bit-identical across worker counts; the shapes put fringe
    /// columns at every width and straddle each profile's KC edge
    /// (1024/512/256).
    #[test]
    fn all_profiles_match_naive_and_stay_thread_invariant() {
        let mut e = Mt19937::new(101);
        for &(m, n, k) in &[(5usize, 3usize, 9usize), (17, 13, 300), (9, 7, 1030)] {
            let a = rand_mat(&mut e, m * k);
            let b = rand_mat(&mut e, k * n);
            let c0 = rand_mat(&mut e, m * n);
            let mut oracle = c0.clone();
            gemm_naive(Transpose::No, Transpose::No, m, n, k, 1.2, &a, &b, 0.3, &mut oracle);
            for p in LaneProfile::ALL {
                let mut base = c0.clone();
                gemm_threads_profile(
                    Transpose::No,
                    Transpose::No,
                    m,
                    n,
                    k,
                    1.2,
                    &a,
                    &b,
                    0.3,
                    &mut base,
                    1,
                    p,
                );
                for (u, v) in oracle.iter().zip(&base) {
                    assert!((u - v).abs() < 1e-9, "{} m={m} n={n} k={k}", p.name());
                }
                for threads in 2..=4 {
                    let mut c = c0.clone();
                    gemm_threads_profile(
                        Transpose::No,
                        Transpose::No,
                        m,
                        n,
                        k,
                        1.2,
                        &a,
                        &b,
                        0.3,
                        &mut c,
                        threads,
                        p,
                    );
                    for (u, v) in base.iter().zip(&c) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{} threads={threads}", p.name());
                    }
                }
            }
        }
    }

    /// A `PackedB` carries its packing profile and the prepacked sweep
    /// reads the geometry from the panel, so mixed-profile processes
    /// can never sweep a panel at the wrong width.
    #[test]
    fn prepacked_profile_flows_from_the_panel() {
        let mut e = Mt19937::new(103);
        let (m, n, k) = (13usize, 11usize, 37usize);
        let a = rand_mat(&mut e, m * k);
        let b = rand_mat(&mut e, k * n);
        assert_eq!(pack_b_panels(Transpose::No, k, n, &b).profile(), default_profile());
        for p in LaneProfile::ALL {
            let packed = pack_b_panels_profile(Transpose::No, k, n, &b, p);
            assert_eq!(packed.profile(), p);
            let mut c1 = vec![0.0f64; m * n];
            gemm_threads_profile(
                Transpose::No,
                Transpose::No,
                m,
                n,
                k,
                1.0,
                &a,
                &b,
                0.0,
                &mut c1,
                2,
                p,
            );
            let mut c2 = vec![0.0f64; m * n];
            gemm_prepacked_threads(Transpose::No, m, 1.0, &a, &packed, 0.0, &mut c2, 2);
            for (u, v) in c1.iter().zip(&c2) {
                assert_eq!(u.to_bits(), v.to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn syrk_profiles_match_oracle_and_stay_thread_invariant() {
        let mut e = Mt19937::new(107);
        for &(m, k) in &[(7usize, 3usize), (21, 300), (9, 1030)] {
            let a = rand_mat(&mut e, m * k);
            let mut oracle = vec![0.0f64; m * m];
            gemm_naive(Transpose::No, Transpose::Yes, m, m, k, 1.4, &a, &a, 0.0, &mut oracle);
            for p in LaneProfile::ALL {
                let mut base = vec![0.0f64; m * m];
                syrk_threads_profile(m, k, 1.4, &a, 0.0, &mut base, 1, p);
                for (u, v) in oracle.iter().zip(&base) {
                    assert!((u - v).abs() < 1e-9, "{} m={m} k={k}", p.name());
                }
                for threads in 2..=4 {
                    let mut c = vec![0.0f64; m * m];
                    syrk_threads_profile(m, k, 1.4, &a, 0.0, &mut c, threads, p);
                    for (u, v) in base.iter().zip(&c) {
                        assert_eq!(u.to_bits(), v.to_bits(), "{} threads={threads}", p.name());
                    }
                }
            }
        }
    }

    #[test]
    fn degenerate_dims_are_noops_or_beta_scale() {
        let a: Vec<f64> = vec![];
        let b: Vec<f64> = vec![];
        let mut c = vec![3.0f64; 4];
        // k = 0: C ← β·C.
        gemm(Transpose::No, Transpose::No, 2, 2, 0, 1.0, &a, &b, 0.5, &mut c);
        assert_eq!(c, vec![1.5; 4]);
        let mut empty: Vec<f64> = vec![];
        let b15 = vec![0.0f64; 15];
        gemm(Transpose::No, Transpose::No, 0, 5, 3, 1.0, &a, &b15, 1.0, &mut empty);
        assert!(empty.is_empty());
    }
}
