//! Level-1 vector kernels: unit-stride loops written so the compiler's
//! auto-vectorizer produces the SIMD code the paper gets from
//! OpenBLAS/NEON — the lane-parallel shape is the same, only the ISA
//! differs.

use crate::dtype::Float;

/// Dot product `x · y` with 4-way unrolled accumulators (breaks the
/// sequential-dependence chain the same way SVE's multi-accumulator
/// reductions do).
#[inline]
pub fn dot<T: Float>(x: &[T], y: &[T]) -> T {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
    for i in 0..chunks {
        let b = i * 4;
        s0 = x[b].mul_add(y[b], s0);
        s1 = x[b + 1].mul_add(y[b + 1], s1);
        s2 = x[b + 2].mul_add(y[b + 2], s2);
        s3 = x[b + 3].mul_add(y[b + 3], s3);
    }
    let mut tail = T::ZERO;
    for i in chunks * 4..n {
        tail = x[i].mul_add(y[i], tail);
    }
    ((s0 + s1) + (s2 + s3)) + tail
}

/// `y ← αx + y`.
#[inline]
pub fn axpy<T: Float>(alpha: T, x: &[T], y: &mut [T]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = alpha.mul_add(xi, *yi);
    }
}

/// `x ← αx`.
#[inline]
pub fn scal<T: Float>(alpha: T, x: &mut [T]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm `‖x‖₂`.
#[inline]
pub fn nrm2<T: Float>(x: &[T]) -> T {
    dot(x, x).sqrt()
}

/// Squared Euclidean distance between two equal-length slices — the
/// inner kernel of KMeans/KNN/DBSCAN distance computations.
#[inline]
pub fn sqdist<T: Float>(x: &[T], y: &[T]) -> T {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = T::ZERO;
    for (&a, &b) in x.iter().zip(y) {
        let d = a - b;
        acc = d.mul_add(d, acc);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic_and_tail_lengths() {
        // Exercise every remainder class of the 4-way unroll.
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 17] {
            let x: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
            let y: Vec<f64> = (0..n).map(|i| 0.5 * i as f64 - 1.0).collect();
            let expect: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!((dot(&x, &y) - expect).abs() < 1e-10, "n={n}");
        }
    }

    #[test]
    fn axpy_and_scal() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scal(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn nrm2_pythagorean() {
        assert!((nrm2(&[3.0f64, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn sqdist_matches_expanded_form() {
        let x = vec![1.0f64, -2.0, 0.5];
        let y = vec![0.0f64, 1.0, 2.5];
        // ‖x−y‖² = ‖x‖² + ‖y‖² − 2x·y
        let expect = dot(&x, &x) + dot(&y, &y) - 2.0 * dot(&x, &y);
        assert!((sqdist(&x, &y) - expect).abs() < 1e-12);
    }
}
