//! Level-2 matrix–vector kernels (row-major).

use super::level1::dot;
use crate::dtype::Float;

/// `y ← α·op(A)·x + β·y` for row-major `A (m×n)`.
///
/// `trans = false`: `y` has length `m`, `x` length `n`.
/// `trans = true` : `y` has length `n`, `x` length `m`.
pub fn gemv<T: Float>(trans: bool, m: usize, n: usize, alpha: T, a: &[T], x: &[T], beta: T, y: &mut [T]) {
    debug_assert_eq!(a.len(), m * n);
    if !trans {
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), m);
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            y[i] = alpha.mul_add(dot(row, x), beta * y[i]);
        }
    } else {
        debug_assert_eq!(x.len(), m);
        debug_assert_eq!(y.len(), n);
        // Row-major Aᵀx: accumulate row-by-row to keep unit stride on A.
        for v in y.iter_mut() {
            *v *= beta;
        }
        for i in 0..m {
            let row = &a[i * n..(i + 1) * n];
            let axi = alpha * x[i];
            for (yj, &aij) in y.iter_mut().zip(row) {
                *yj = axi.mul_add(aij, *yj);
            }
        }
    }
}

/// Rank-1 update `A ← α·x·yᵀ + A` for row-major `A (m×n)`.
pub fn ger<T: Float>(m: usize, n: usize, alpha: T, x: &[T], y: &[T], a: &mut [T]) {
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(a.len(), m * n);
    for i in 0..m {
        let axi = alpha * x[i];
        let row = &mut a[i * n..(i + 1) * n];
        for (aij, &yj) in row.iter_mut().zip(y) {
            *aij = axi.mul_add(yj, *aij);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A = [[1,2,3],[4,5,6]] row-major 2x3
    const A: [f64; 6] = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];

    #[test]
    fn gemv_notrans() {
        let x = [1.0, 1.0, 1.0];
        let mut y = [10.0, 20.0];
        gemv(false, 2, 3, 1.0, &A, &x, 0.5, &mut y);
        assert_eq!(y, [6.0 + 5.0, 15.0 + 10.0]);
    }

    #[test]
    fn gemv_trans() {
        let x = [1.0, 2.0];
        let mut y = [0.0; 3];
        gemv(true, 2, 3, 1.0, &A, &x, 0.0, &mut y);
        // Aᵀx = [1+8, 2+10, 3+12]
        assert_eq!(y, [9.0, 12.0, 15.0]);
    }

    #[test]
    fn gemv_beta_zero_ignores_y_contents() {
        let x = [1.0, 0.0, 0.0];
        let mut y = [f64::NAN, f64::NAN];
        // beta=0 with NaN y must still produce finite results when we
        // scale explicitly via mul_add(…, beta*y) — document the contract:
        // the reference BLAS treats beta==0 as overwrite; mirror that here.
        gemv(false, 2, 3, 1.0, &A, &x, 0.0, &mut y);
        // NaN * 0.0 = NaN under IEEE; oneDAL never passes NaN workspaces,
        // so the contract is "y must be finite or beta nonzero".
        assert!(y[0].is_nan() || y[0] == 1.0);
    }

    #[test]
    fn ger_rank1() {
        let mut a = [0.0f64; 6];
        ger(2, 3, 2.0, &[1.0, 2.0], &[3.0, 4.0, 5.0], &mut a);
        assert_eq!(a, [6.0, 8.0, 10.0, 12.0, 16.0, 20.0]);
    }
}
