//! Level-2 matrix–vector kernels (row-major).

use super::level1::dot;
use crate::dtype::Float;
use crate::parallel;

/// Minimum multiply-adds before a gemv fan-out pays for itself (the
/// kernel is memory-bound, so the bar sits below the level-3 one).
const PAR_MIN_WORK: usize = 1 << 14;

/// `y ← α·op(A)·x + β·y` for row-major `A (m×n)` with an explicit
/// worker count — the tall-skinny inference entry the algorithm layer
/// routes `Context::threads()` into.
///
/// `trans = false`: `y` has length `m`, `x` length `n`.
/// `trans = true` : `y` has length `n`, `x` length `m`.
///
/// `β == 0` **overwrites** `y` (the reference BLAS contract): the
/// existing contents — including NaN or uninitialized storage — are
/// never read on either transpose path.
///
/// Workers own disjoint contiguous slices of `y` (output rows on the
/// no-transpose path, output columns on the transpose path) and every
/// element accumulates its terms in the same order at any worker count,
/// so results are bit-identical across 1–N workers *and* to the
/// sequential sweep.
#[allow(clippy::too_many_arguments)]
pub fn gemv_threads<T: Float>(
    trans: bool,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    x: &[T],
    beta: T,
    y: &mut [T],
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * n);
    let workers = parallel::effective_threads(threads, m.saturating_mul(n), PAR_MIN_WORK);
    if !trans {
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), m);
        if workers <= 1 {
            // Sequential fast path: no partition allocation — this is
            // the inner loop of SMO/SGD-style callers that pin one
            // worker. Element-for-element identical to the fan-out.
            notrans_rows(0, m, n, alpha, a, x, beta, y);
            return;
        }
        let bounds = parallel::even_bounds(m, workers);
        parallel::scope_rows(y, 1, &bounds, |lo, hi, block| {
            notrans_rows(lo, hi, n, alpha, a, x, beta, block);
        });
    } else {
        debug_assert_eq!(x.len(), m);
        debug_assert_eq!(y.len(), n);
        if workers <= 1 {
            trans_cols(0, n, m, n, alpha, a, x, beta, y);
            return;
        }
        let bounds = parallel::even_bounds(n, workers);
        parallel::scope_rows(y, 1, &bounds, |lo, hi, block| {
            trans_cols(lo, hi, m, n, alpha, a, x, beta, block);
        });
    }
}

/// No-transpose worker body: rows `[lo, hi)` of `α·A·x (+ β·y)` into
/// `block` (`block[0]` is row `lo`). β == 0 never reads `block`.
#[allow(clippy::too_many_arguments)]
fn notrans_rows<T: Float>(
    lo: usize,
    hi: usize,
    n: usize,
    alpha: T,
    a: &[T],
    x: &[T],
    beta: T,
    block: &mut [T],
) {
    for i in lo..hi {
        let row = &a[i * n..(i + 1) * n];
        let acc = dot(row, x);
        block[i - lo] = if beta == T::ZERO {
            alpha * acc
        } else {
            alpha.mul_add(acc, beta * block[i - lo])
        };
    }
}

/// Transpose worker body: output columns `[lo, hi)` of `α·Aᵀ·x (+ β·y)`
/// into `block`. Row-major Aᵀx accumulates row-by-row over the column
/// slice to keep unit stride on A; β == 0 overwrites the slice.
#[allow(clippy::too_many_arguments)]
fn trans_cols<T: Float>(
    lo: usize,
    hi: usize,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    x: &[T],
    beta: T,
    block: &mut [T],
) {
    super::beta_scale(beta, block);
    for i in 0..m {
        let row = &a[i * n + lo..i * n + hi];
        let axi = alpha * x[i];
        for (yj, &aij) in block.iter_mut().zip(row) {
            *yj = axi.mul_add(aij, *yj);
        }
    }
}

/// `y ← α·op(A)·x + β·y` on the process-default worker count (callers
/// holding a [`crate::coordinator::Context`] should prefer
/// [`gemv_threads`] with `ctx.threads()`). `β == 0` overwrites `y`
/// without reading it — see [`gemv_threads`].
#[allow(clippy::too_many_arguments)]
pub fn gemv<T: Float>(
    trans: bool,
    m: usize,
    n: usize,
    alpha: T,
    a: &[T],
    x: &[T],
    beta: T,
    y: &mut [T],
) {
    gemv_threads(trans, m, n, alpha, a, x, beta, y, parallel::default_threads());
}

/// Rank-1 update `A ← α·x·yᵀ + A` for row-major `A (m×n)`.
pub fn ger<T: Float>(m: usize, n: usize, alpha: T, x: &[T], y: &[T], a: &mut [T]) {
    debug_assert_eq!(x.len(), m);
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(a.len(), m * n);
    for i in 0..m {
        let axi = alpha * x[i];
        let row = &mut a[i * n..(i + 1) * n];
        for (aij, &yj) in row.iter_mut().zip(y) {
            *aij = axi.mul_add(yj, *aij);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A = [[1,2,3],[4,5,6]] row-major 2x3
    const A: [f64; 6] = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];

    #[test]
    fn gemv_notrans() {
        let x = [1.0, 1.0, 1.0];
        let mut y = [10.0, 20.0];
        gemv(false, 2, 3, 1.0, &A, &x, 0.5, &mut y);
        assert_eq!(y, [6.0 + 5.0, 15.0 + 10.0]);
    }

    #[test]
    fn gemv_trans() {
        let x = [1.0, 2.0];
        let mut y = [0.0; 3];
        gemv(true, 2, 3, 1.0, &A, &x, 0.0, &mut y);
        // Aᵀx = [1+8, 2+10, 3+12]
        assert_eq!(y, [9.0, 12.0, 15.0]);
    }

    /// The reference BLAS contract: β == 0 means *overwrite* — `y` is
    /// never read, so NaN (or uninitialized) contents must not poison
    /// the output on either transpose path.
    #[test]
    fn gemv_beta_zero_overwrites_nan_y_both_paths() {
        let x = [1.0, 0.0, 0.0];
        let mut y = [f64::NAN, f64::NAN];
        gemv(false, 2, 3, 1.0, &A, &x, 0.0, &mut y);
        assert!(y.iter().all(|v| v.is_finite()), "no-trans left NaN: {y:?}");
        assert_eq!(y, [1.0, 4.0]);

        let xt = [1.0, 2.0];
        let mut yt = [f64::NAN; 3];
        gemv(true, 2, 3, 1.0, &A, &xt, 0.0, &mut yt);
        assert!(yt.iter().all(|v| v.is_finite()), "trans left NaN: {yt:?}");
        assert_eq!(yt, [9.0, 12.0, 15.0]);
    }

    /// β == 0 with NaN workspace stays finite through the threaded entry
    /// at every worker count, on shapes large enough to really fan out.
    #[test]
    fn gemv_threads_beta_zero_nan_safe_and_bit_identical() {
        // m·n ≥ 4·2^14 so effective_threads really grants 4 workers.
        let (m, n) = (300usize, 240usize);
        let a: Vec<f64> = (0..m * n).map(|i| ((i * 19 + 3) % 23) as f64 * 0.17 - 1.5).collect();
        for trans in [false, true] {
            let (xin, yout) = if trans { (m, n) } else { (n, m) };
            let x: Vec<f64> = (0..xin).map(|i| (i % 11) as f64 * 0.3 - 1.0).collect();
            let mut base = vec![f64::NAN; yout];
            gemv_threads(trans, m, n, 1.3, &a, &x, 0.0, &mut base, 1);
            assert!(base.iter().all(|v| v.is_finite()), "trans={trans}");
            for threads in 2..=4 {
                let mut y = vec![f64::NAN; yout];
                gemv_threads(trans, m, n, 1.3, &a, &x, 0.0, &mut y, threads);
                for (u, v) in base.iter().zip(&y) {
                    assert_eq!(u.to_bits(), v.to_bits(), "trans={trans} threads={threads}");
                }
            }
        }
    }

    /// Nonzero β accumulates bit-identically across worker counts too.
    #[test]
    fn gemv_threads_beta_accumulate_bit_identical() {
        let (m, n) = (310usize, 230usize);
        let a: Vec<f64> = (0..m * n).map(|i| ((i * 7 + 5) % 31) as f64 * 0.11 - 1.7).collect();
        for trans in [false, true] {
            let (xin, yout) = if trans { (m, n) } else { (n, m) };
            let x: Vec<f64> = (0..xin).map(|i| (i % 13) as f64 * 0.21 - 1.2).collect();
            let y0: Vec<f64> = (0..yout).map(|i| (i % 7) as f64 * 0.4 - 1.0).collect();
            let mut base = y0.clone();
            gemv_threads(trans, m, n, 0.9, &a, &x, 0.6, &mut base, 1);
            for threads in 2..=4 {
                let mut y = y0.clone();
                gemv_threads(trans, m, n, 0.9, &a, &x, 0.6, &mut y, threads);
                for (u, v) in base.iter().zip(&y) {
                    assert_eq!(u.to_bits(), v.to_bits(), "trans={trans} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn ger_rank1() {
        let mut a = [0.0f64; 6];
        ger(2, 3, 2.0, &[1.0, 2.0], &[3.0, 4.0, 5.0], &mut a);
        assert_eq!(a, [6.0, 8.0, 10.0, 12.0, 16.0, 20.0]);
    }
}
