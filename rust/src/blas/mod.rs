//! Native dense BLAS fallback — the "reference backend" rung of the
//! dispatch ladder.
//!
//! In the paper, OpenBLAS replaces MKL as oneDAL's dense engine. In this
//! reproduction the *optimized* dense path is XLA-CPU via PJRT artifacts;
//! this module is the open, self-contained fallback that (a) plays the
//! OpenBLAS role for the `Backend::Reference` rung and (b) provides the
//! primitives the algorithms use directly when shapes are too small or
//! too dynamic to batch into a fixed-shape artifact.
//!
//! Two variants exist for the level-3 kernels:
//! * `*_naive` — textbook triple loop, the "stock scikit-learn on ARM"
//!   analogue used by the baseline backend;
//! * the packed-panel engine (`gemm`, `syrk`) — operands packed once
//!   into `MR`-row / `NR`-column micro-panels, a register-tiled
//!   `mul_add` microkernel over the panels, and row-panel threading via
//!   [`crate::parallel`], playing the role of the paper's multicore
//!   NEON/SVE-optimized OpenBLAS kernels.
//!
//! The `*_threads` entry points take an explicit worker count (the
//! algorithm layer routes `Context::threads()` here); the bare names
//! use [`crate::parallel::default_threads`] so the BLAS stays callable
//! without a `Context`. Every parallel entry runs on the persistent
//! worker pool ([`crate::parallel::WorkerPool`]) and is bit-identical
//! across worker counts.
//!
//! **β == 0 contract:** all scaled-output kernels (`gemm`, `syrk`,
//! `gemv`) treat `β == 0` as *overwrite* — the output operand is never
//! read, so NaN or uninitialized workspaces cannot poison results. This
//! mirrors the reference BLAS (and the sparse routines' `fill(0)`), and
//! it is what makes OpenBLAS a drop-in for MKL in the paper's port.
//!
//! All matrices are **row-major**, matching [`crate::tables::DenseTable`].

pub mod level1;
pub mod level2;
pub mod level3;

use crate::dtype::Float;

/// β-scale an output buffer in place; `β == 0` **overwrites** (never
/// reads) — the single implementation of the contract documented above,
/// shared by the dense level-2/3 kernels and the sparse routines.
pub(crate) fn beta_scale<T: Float>(beta: T, out: &mut [T]) {
    if beta == T::ZERO {
        out.fill(T::ZERO);
    } else if beta != T::ONE {
        for v in out.iter_mut() {
            *v *= beta;
        }
    }
}

pub use level1::{axpy, dot, nrm2, scal, sqdist};
pub use level2::{gemv, gemv_threads, ger};
pub use level3::{
    gemm, gemm_naive, gemm_prepacked_threads, gemm_threads, gemm_threads_profile, pack_b_panels,
    pack_b_panels_profile, syrk, syrk_threads, syrk_threads_profile, PackedB, Transpose,
};

#[cfg(test)]
mod tests {
    use super::*;

    /// Cross-level identity: `x·y == (1×n · n×1) gemm`.
    #[test]
    fn dot_agrees_with_gemm() {
        let x = vec![1.0f64, 2.0, 3.0, 4.0];
        let y = vec![0.5f64, -1.0, 2.0, 0.25];
        let d = dot(&x, &y);
        let mut c = vec![0.0f64];
        gemm(Transpose::No, Transpose::No, 1, 1, 4, 1.0, &x, &y, 0.0, &mut c);
        assert!((d - c[0]).abs() < 1e-12);
    }

    /// `syrk` must equal explicit `A·Aᵀ` via gemm.
    #[test]
    fn syrk_agrees_with_gemm() {
        let a: Vec<f64> = (0..12).map(|i| i as f64 * 0.3 - 1.0).collect(); // 3x4
        let mut c1 = vec![0.0f64; 9];
        syrk(3, 4, 1.0, &a, 0.0, &mut c1);
        // A·Aᵀ through gemm with B = Aᵀ handled by Transpose::Yes
        let mut c2 = vec![0.0f64; 9];
        gemm(Transpose::No, Transpose::Yes, 3, 3, 4, 1.0, &a, &a, 0.0, &mut c2);
        for (u, v) in c1.iter().zip(&c2) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}
