//! `onedal-sve` launcher — the CLI front end of the library (clap is not
//! vendored offline; the parser is a small hand-rolled subcommand
//! dispatcher).
//!
//! ```text
//! onedal-sve info                         # dispatch ladder + artifact status
//! onedal-sve train  <algo> [options]      # train on synthetic or CSV data
//! onedal-sve bench-all                    # quick smoke across the suite
//! ```

use onedal_sve::coordinator::{Backend, Context};
use onedal_sve::prelude::*;
use onedal_sve::profiling::ScopedTimer;
use onedal_sve::tables::synth;
use std::collections::HashMap;
use std::time::Instant;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_ctx(flags: &HashMap<String, String>) -> Context {
    let backend = flags
        .get("backend")
        .map(|b| Backend::parse(b).expect("bad --backend"))
        .unwrap_or(Backend::Auto);
    Context::builder()
        .backend(backend)
        .artifact_dir(flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()))
        .build()
        .expect("context build failed")
}

fn cmd_info(flags: &HashMap<String, String>) {
    let ctx = build_ctx(flags);
    println!("onedal-sve — ARM-SVE-optimized oneDAL reproduction (Rust+JAX+Pallas)");
    println!("resolved backend : {}", ctx.backend().name());
    println!("threads          : {}", ctx.threads());
    println!("artifacts        : {} variants registered", ctx.registry().len());
    let kernels =
        ["kmeans_assign", "logreg_step", "wss_select", "pairwise_sqdist", "x2c_mom", "xcp_update"];
    for kernel in kernels {
        let n = ctx.registry().variants(kernel).len();
        println!("  {kernel:<18} {n} variant(s)");
    }
    let rt = if ctx.runtime().is_some() { "PJRT CPU client up" } else { "native only" };
    println!("runtime          : {rt}");
}

fn cmd_train(algo: &str, flags: &HashMap<String, String>) {
    let ctx = build_ctx(flags);
    let n: usize = get(flags, "n", 10_000);
    let d: usize = get(flags, "d", 16);
    let seed: u32 = get(flags, "seed", 42);
    let mut e = Mt19937::new(seed);
    let t0 = Instant::now();
    match algo {
        "kmeans" => {
            let k = get(flags, "k", 8);
            let x = if let Some(path) = flags.get("csv") {
                DenseTable::from_csv(path).expect("csv load")
            } else {
                synth::make_blobs(&mut e, n, d, k, 1.0).0
            };
            let iters = get(flags, "iters", 50);
            let m = KMeans::params().k(k).max_iter(iters).train(&ctx, &x).unwrap();
            let (inertia, it) = (m.inertia, m.iterations);
            println!("kmeans: inertia={inertia:.3} iterations={it} [{:?}]", t0.elapsed());
        }
        "svm" => {
            let (x, y) = synth::make_classification(&mut e, n.min(5000), d, 1.5);
            let solver = match flags.get("solver").map(String::as_str) {
                Some("boser") => SvmSolver::Boser,
                _ => SvmSolver::Thunder,
            };
            let m = Svc::params().solver(solver).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            let (sv, iters) = (m.n_support(), m.iterations);
            println!("svm({solver:?}): sv={sv} iters={iters} acc={acc:.4} [{:?}]", t0.elapsed());
        }
        "logreg" => {
            let (x, y) = synth::make_classification(&mut e, n, d, 1.5);
            let epochs = get(flags, "epochs", 30);
            let m = LogisticRegression::params().epochs(epochs).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            println!("logreg: acc={acc:.4} [{:?}]", t0.elapsed());
        }
        "forest" => {
            let (x, y) = synth::make_classification(&mut e, n, d, 1.0);
            let trees = get(flags, "trees", 30);
            let m =
                RandomForestClassifier::params().n_trees(trees).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            println!("forest: trees={} acc={acc:.4} [{:?}]", m.n_trees(), t0.elapsed());
        }
        "pca" => {
            let x = synth::make_segmentation(&mut e, n, d, 6);
            let comps = get(flags, "components", 2);
            let m = Pca::params().n_components(comps).train(&ctx, &x).unwrap();
            println!("pca: explained={:?} [{:?}]", m.explained_variance, t0.elapsed());
        }
        "linreg" => {
            let (x, y, _) = synth::make_regression(&mut e, n, d, 0.1);
            let m = LinearRegression::params().train(&ctx, &x, &y).unwrap();
            let r2 = onedal_sve::metrics::r2(&m.infer(&ctx, &x).unwrap(), &y);
            println!("linreg: r2={r2:.4} [{:?}]", t0.elapsed());
        }
        "dbscan" => {
            let (x, _) = synth::make_blobs(&mut e, n.min(5000), d.min(8), 5, 0.4);
            let m = Dbscan::params().eps(1.5).min_pts(5).train(&ctx, &x).unwrap();
            println!("dbscan: clusters={} [{:?}]", m.n_clusters, t0.elapsed());
        }
        "knn" => {
            let (x, labels) = synth::make_blobs(&mut e, n.min(20_000), d, 5, 1.0);
            let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
            let m = KnnClassifier::params().k(get(flags, "k", 5)).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            println!("knn: acc={acc:.4} [{:?}]", t0.elapsed());
        }
        other => {
            eprintln!("unknown algorithm {other:?}; see `onedal-sve help`");
            std::process::exit(2);
        }
    }
}

fn cmd_bench_all(flags: &HashMap<String, String>) {
    let _t = ScopedTimer::new("bench-all");
    for algo in ["kmeans", "logreg", "linreg", "pca", "knn", "dbscan", "forest", "svm"] {
        cmd_train(algo, flags);
    }
    println!("\n{}", onedal_sve::profiling::timer::Metrics::global().report());
}

fn help() {
    println!(
        "usage: onedal-sve <command> [--flags]\n\
         commands:\n\
         \x20 info                     dispatch ladder + artifact status\n\
         \x20 train <algo>             kmeans|svm|logreg|forest|pca|linreg|dbscan|knn\n\
         \x20 bench-all                smoke the whole suite\n\
         flags: --backend naive|reference|vectorized|artifact|auto\n\
         \x20      --n <rows> --d <features> --k <clusters> --seed <s>\n\
         \x20      --csv <path> --artifacts <dir> --solver boser|thunder"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = parse_flags(&args);
    match args.first().map(String::as_str) {
        Some("info") => cmd_info(&flags),
        Some("train") => {
            let algo = args.get(1).cloned().unwrap_or_default();
            cmd_train(&algo, &flags);
        }
        Some("bench-all") => cmd_bench_all(&flags),
        _ => help(),
    }
}
