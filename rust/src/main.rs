//! `onedal-sve` launcher — the CLI front end of the library (clap is not
//! vendored offline; the parser is a small hand-rolled subcommand
//! dispatcher).
//!
//! ```text
//! onedal-sve info                         # dispatch ladder + artifact status
//! onedal-sve train  <algo> [options]      # train on synthetic or CSV data
//! onedal-sve bench-all                    # quick smoke across the suite
//! onedal-sve bench serve                  # batched serving: coalesced vs naive
//! onedal-sve bench serve --faults         # resilience: retry/degrade under injection
//! onedal-sve bench lanes                  # predicated kernels at each SVE lane profile
//! ```

use onedal_sve::coordinator::{Backend, Context};
use onedal_sve::prelude::*;
use onedal_sve::profiling::ScopedTimer;
use onedal_sve::tables::synth;
use std::collections::HashMap;
use std::time::Instant;

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn build_ctx(flags: &HashMap<String, String>) -> Context {
    let backend = flags
        .get("backend")
        .map(|b| Backend::parse(b).expect("bad --backend"))
        .unwrap_or(Backend::Auto);
    Context::builder()
        .backend(backend)
        .artifact_dir(flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into()))
        .build()
        .expect("context build failed")
}

fn cmd_info(flags: &HashMap<String, String>) {
    let ctx = build_ctx(flags);
    println!("onedal-sve — ARM-SVE-optimized oneDAL reproduction (Rust+JAX+Pallas)");
    println!("resolved backend : {}", ctx.backend().name());
    println!("threads          : {}", ctx.threads());
    println!("artifacts        : {} variants registered", ctx.registry().len());
    let kernels =
        ["kmeans_assign", "logreg_step", "wss_select", "pairwise_sqdist", "x2c_mom", "xcp_update"];
    for kernel in kernels {
        let n = ctx.registry().variants(kernel).len();
        println!("  {kernel:<18} {n} variant(s)");
    }
    let rt = if ctx.runtime().is_some() { "PJRT CPU client up" } else { "native only" };
    println!("runtime          : {rt}");
}

fn cmd_train(algo: &str, flags: &HashMap<String, String>) {
    let ctx = build_ctx(flags);
    let n: usize = get(flags, "n", 10_000);
    let d: usize = get(flags, "d", 16);
    let seed: u32 = get(flags, "seed", 42);
    let mut e = Mt19937::new(seed);
    let t0 = Instant::now();
    match algo {
        "kmeans" => {
            let k = get(flags, "k", 8);
            let x = if let Some(path) = flags.get("csv") {
                DenseTable::from_csv(path).expect("csv load")
            } else {
                synth::make_blobs(&mut e, n, d, k, 1.0).0
            };
            let iters = get(flags, "iters", 50);
            let m = KMeans::params().k(k).max_iter(iters).train(&ctx, &x).unwrap();
            let (inertia, it) = (m.inertia, m.iterations);
            println!("kmeans: inertia={inertia:.3} iterations={it} [{:?}]", t0.elapsed());
        }
        "svm" => {
            let (x, y) = synth::make_classification(&mut e, n.min(5000), d, 1.5);
            let solver = match flags.get("solver").map(String::as_str) {
                Some("boser") => SvmSolver::Boser,
                _ => SvmSolver::Thunder,
            };
            let m = Svc::params().solver(solver).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            let (sv, iters) = (m.n_support(), m.iterations);
            println!("svm({solver:?}): sv={sv} iters={iters} acc={acc:.4} [{:?}]", t0.elapsed());
        }
        "logreg" => {
            let (x, y) = synth::make_classification(&mut e, n, d, 1.5);
            let epochs = get(flags, "epochs", 30);
            let m = LogisticRegression::params().epochs(epochs).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            println!("logreg: acc={acc:.4} [{:?}]", t0.elapsed());
        }
        "forest" => {
            let (x, y) = synth::make_classification(&mut e, n, d, 1.0);
            let trees = get(flags, "trees", 30);
            let m =
                RandomForestClassifier::params().n_trees(trees).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            println!("forest: trees={} acc={acc:.4} [{:?}]", m.n_trees(), t0.elapsed());
        }
        "pca" => {
            let x = synth::make_segmentation(&mut e, n, d, 6);
            let comps = get(flags, "components", 2);
            let m = Pca::params().n_components(comps).train(&ctx, &x).unwrap();
            println!("pca: explained={:?} [{:?}]", m.explained_variance, t0.elapsed());
        }
        "linreg" => {
            let (x, y, _) = synth::make_regression(&mut e, n, d, 0.1);
            let m = LinearRegression::params().train(&ctx, &x, &y).unwrap();
            let r2 = onedal_sve::metrics::r2(&m.infer(&ctx, &x).unwrap(), &y);
            println!("linreg: r2={r2:.4} [{:?}]", t0.elapsed());
        }
        "dbscan" => {
            let (x, _) = synth::make_blobs(&mut e, n.min(5000), d.min(8), 5, 0.4);
            let m = Dbscan::params().eps(1.5).min_pts(5).train(&ctx, &x).unwrap();
            println!("dbscan: clusters={} [{:?}]", m.n_clusters, t0.elapsed());
        }
        "knn" => {
            let (x, labels) = synth::make_blobs(&mut e, n.min(20_000), d, 5, 1.0);
            let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
            let m = KnnClassifier::params().k(get(flags, "k", 5)).train(&ctx, &x, &y).unwrap();
            let acc = onedal_sve::metrics::accuracy(&m.infer(&ctx, &x).unwrap(), &y);
            println!("knn: acc={acc:.4} [{:?}]", t0.elapsed());
        }
        other => {
            eprintln!("unknown algorithm {other:?}; see `onedal-sve help`");
            std::process::exit(2);
        }
    }
}

fn percentile(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

/// `bench serve` — the serving-layer scenario: many small query batches
/// against one fitted model, coalesced through an [`InferenceSession`]
/// vs served naively one request at a time. Reports throughput and
/// p50/p99 latency for both. Naive latencies are true per-request
/// timings; under coalescing every request in a round completes with
/// its super-batch, so each request's latency is its round's wall time.
fn cmd_bench_serve(flags: &HashMap<String, String>) {
    if flags.contains_key("faults") {
        cmd_bench_serve_faults(flags);
        return;
    }
    let ctx = build_ctx(flags);
    let n: usize = get(flags, "n", 2000);
    let d: usize = get(flags, "d", 16);
    let n_requests: usize = get(flags, "requests", 64);
    let rows_per: usize = get(flags, "rows", 3);
    let reps: usize = get(flags, "reps", 5);
    let seed: u32 = get(flags, "seed", 42);
    let mut e = Mt19937::new(seed);
    let (x, _) = synth::make_blobs(&mut e, n.max(rows_per + 1), d, 8, 1.0);
    let n = n.max(rows_per + 1);
    let model = KMeans::params().k(8).max_iter(20).train(&ctx, &x).expect("train");
    let session = InferenceSession::new(&model);

    // Small query batches carved from the corpus (submission order fixed).
    let raw: Vec<Vec<f64>> = (0..n_requests)
        .map(|i| {
            let start = (i * rows_per) % (n - rows_per);
            x.data()[start * d..(start + rows_per) * d].to_vec()
        })
        .collect();
    let requests: Vec<ServeRequest> = raw
        .iter()
        .map(|data| ServeRequest::new(data.clone(), rows_per, d).expect("request shape"))
        .collect();

    // Naive baseline: one pack-free model call per request.
    let mut naive_us: Vec<f64> = Vec::with_capacity(reps * n_requests);
    let mut naive_total = 0.0f64;
    let mut naive_first: Vec<Vec<f64>> = Vec::new();
    for rep in 0..reps {
        let r0 = Instant::now();
        let mut outs = Vec::with_capacity(n_requests);
        for data in &raw {
            let buf = data.clone();
            let t0 = Instant::now();
            let q = DenseTable::from_vec(buf, rows_per, d).expect("query shape");
            let out = ServeModel::serve_batch(&model, &ctx, &q).expect("naive serve");
            naive_us.push(t0.elapsed().as_secs_f64() * 1e6);
            outs.push(out);
        }
        naive_total += r0.elapsed().as_secs_f64();
        if rep == 0 {
            naive_first = outs;
        }
    }

    // Coalesced: the whole request set through the session per round.
    let mut serve_us: Vec<f64> = Vec::with_capacity(reps * n_requests);
    let mut serve_total = 0.0f64;
    let mut serve_first: Vec<ServeResult> = Vec::new();
    for rep in 0..reps {
        let t0 = Instant::now();
        let results = session.serve(&ctx, &requests);
        let round = t0.elapsed().as_secs_f64();
        serve_total += round;
        for _ in 0..n_requests {
            serve_us.push(round * 1e6);
        }
        if rep == 0 {
            serve_first = results;
        }
    }

    // Sanity: coalesced output must be bit-identical to the naive path.
    for (i, (res, want)) in serve_first.iter().zip(&naive_first).enumerate() {
        let got = res.output.as_deref().expect("coalesced request must complete");
        assert_eq!(got.len(), want.len(), "request {i}: output length");
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits(), "request {i}: coalesced != naive");
        }
    }

    naive_us.sort_by(|a, b| a.total_cmp(b));
    serve_us.sort_by(|a, b| a.total_cmp(b));
    let served = (reps * n_requests) as f64;
    let naive_thr = served / naive_total;
    let serve_thr = served / serve_total;
    println!("serve: corpus={n}x{d} requests={n_requests} rows/req={rows_per} reps={reps}");
    println!(
        "  naive     : {naive_thr:9.0} req/s   p50={:8.1}us  p99={:8.1}us",
        percentile(&naive_us, 0.50),
        percentile(&naive_us, 0.99)
    );
    println!(
        "  coalesced : {serve_thr:9.0} req/s   p50={:8.1}us  p99={:8.1}us",
        percentile(&serve_us, 0.50),
        percentile(&serve_us, 0.99)
    );
    println!("  throughput speedup: {:.2}x  (outputs bit-identical)", serve_thr / naive_thr);
}

/// `bench serve --faults [spec]` — the resilience scenario: the same
/// request set served twice, once clean through a plain session and
/// once with a failpoint armed and a [`ResilientSession`] retrying and
/// degrading around it. Asserts bit-identity between the two runs and
/// prints the `ResilienceStats` fault accounting. `--faults` alone
/// injects a typed fault on every third super-batch attempt; pass a
/// full `site[:mode][:payload]` spec to override.
fn cmd_bench_serve_faults(flags: &HashMap<String, String>) {
    let ctx = build_ctx(flags);
    let n: usize = get(flags, "n", 2000);
    let d: usize = get(flags, "d", 16);
    let n_requests: usize = get(flags, "requests", 64);
    let rows_per: usize = get(flags, "rows", 3);
    let attempts: usize = get(flags, "attempts", 3);
    let seed: u32 = get(flags, "seed", 42);
    let spec = match flags.get("faults").map(String::as_str) {
        None | Some("true") => {
            format!("{}:every:3:error", onedal_sve::failpoint::SITE_SERVE_BATCH)
        }
        Some(s) => s.to_string(),
    };
    let mut e = Mt19937::new(seed);
    let n = n.max(rows_per + 1);
    let (x, _) = synth::make_blobs(&mut e, n, d, 8, 1.0);
    let model = KMeans::params().k(8).max_iter(20).train(&ctx, &x).expect("train");
    let requests: Vec<ServeRequest> = (0..n_requests)
        .map(|i| {
            let start = (i * rows_per) % (n - rows_per);
            let data = x.data()[start * d..(start + rows_per) * d].to_vec();
            ServeRequest::new(data, rows_per, d).expect("request shape")
        })
        .collect();

    // Clean baseline through the plain session.
    let baseline = InferenceSession::new(&model).serve(&ctx, &requests);

    // Faulted run through the resilient session.
    onedal_sve::failpoint::arm(&spec);
    let t0 = Instant::now();
    let mut rs = ResilientSession::new(InferenceSession::new(&model))
        .retry(RetryPolicy::attempts(attempts));
    let served = rs.serve(&ctx, &requests);
    let wall = t0.elapsed().as_secs_f64();
    onedal_sve::failpoint::disarm();

    for (i, (res, want)) in served.iter().zip(&baseline).enumerate() {
        let got = res.output.as_deref().expect("faulted request must complete");
        let want = want.output.as_deref().expect("baseline request must complete");
        assert_eq!(got.len(), want.len(), "request {i}: output length");
        for (a, b) in got.iter().zip(want) {
            assert_eq!(a.to_bits(), b.to_bits(), "request {i}: faulted != clean");
        }
    }
    let st = rs.stats();
    println!("serve --faults: corpus={n}x{d} requests={n_requests} spec={spec}");
    println!("  outputs bit-identical to the unfaulted baseline");
    println!(
        "  batches={} faults={} retries={} retry_successes={} trips={} probes={} \
         recoveries={} repack={} naive={} unavailable={}",
        st.batches,
        st.faults,
        st.retries,
        st.retry_successes,
        st.breaker_trips,
        st.half_open_probes,
        st.recoveries,
        st.degraded_repack,
        st.degraded_naive,
        st.unavailable_batches
    );
    println!("  served {n_requests} requests in {:.1}ms under injection", wall * 1e3);
}

/// `bench lanes` — the lane-profile scenario (ISSUE 10): the predicated
/// argmin and WSS scans monomorphized at each SVE vector length the
/// dispatcher can resolve, timed side by side, with the cross-width
/// discrete-output identity asserted as it goes. The full sweep (top-k,
/// ε-scan, JSON record) lives in `cargo bench --bench ablate_lanes`.
fn cmd_bench_lanes(flags: &HashMap<String, String>) {
    use onedal_sve::algorithms::svm::simd;
    use onedal_sve::algorithms::svm::wss::{LOW, SIGN_ANY, SIGN_NEG, SIGN_POS, UP};
    use onedal_sve::primitives::distances;
    use onedal_sve::primitives::lanes::LaneProfile;
    use onedal_sve::rng::{Distribution, Gaussian, Uniform};

    let ctx = build_ctx(flags);
    let threads = ctx.threads();
    let n: usize = get(flags, "n", 4096);
    let d: usize = get(flags, "d", 32);
    let k: usize = get(flags, "k", 16);
    let wss_n: usize = get(flags, "wss", 100_000);
    let reps: usize = get(flags, "reps", 5);
    let seed: u32 = get(flags, "seed", 42);
    let m = (n / 4).max(1);

    let mut e = Mt19937::new(seed);
    let (x, _) = synth::make_blobs(&mut e, n, d, k, 1.0);
    let (c, _) = synth::make_blobs(&mut e, k, d, k, 1.0);
    let q = &x.data()[..m * d];
    let mut u = Uniform::<f64>::new(0.0, 1.0);
    let mut gs = Gaussian::<f64>::standard();
    let grad: Vec<f64> = (0..wss_n).map(|_| gs.sample(&mut e)).collect();
    let flags_v: Vec<u8> = (0..wss_n)
        .map(|_| {
            let mut f = if u.sample(&mut e) < 0.5 { SIGN_POS } else { SIGN_NEG };
            if u.sample(&mut e) < 0.7 {
                f |= LOW;
            }
            if u.sample(&mut e) < 0.7 {
                f |= UP;
            }
            f
        })
        .collect();
    let diag: Vec<f64> = (0..wss_n).map(|_| 1.0 + u.sample(&mut e)).collect();
    let ki: Vec<f64> = (0..wss_n).map(|_| 0.5 * gs.sample(&mut e)).collect();

    println!("lanes: corpus={k}x{d} queries={m} wss={wss_n} threads={threads} reps={reps}");
    let mut base: Option<(Vec<usize>, Option<usize>, Option<usize>)> = None;
    for profile in LaneProfile::ALL {
        let corpus = distances::pack_corpus_table_profile(&c, profile, threads);
        let mut assign = vec![0usize; m];
        let mut best_argmin = f64::INFINITY;
        for _ in 0..reps {
            let t0 = Instant::now();
            let inertia = distances::argmin_assign(q, m, &corpus, true, &mut assign, threads);
            best_argmin = best_argmin.min(t0.elapsed().as_secs_f64());
            std::hint::black_box(inertia);
        }
        let ex = simd::wss_extrema_par(profile, &grad, &flags_v, threads);
        let mut best_wssj = f64::INFINITY;
        let mut bj = None;
        for _ in 0..reps {
            let t0 = Instant::now();
            let j = simd::wss_j_par(
                profile, &grad, &flags_v, SIGN_ANY, LOW, ex.gmin, 1.5, &diag, &ki, 1e-12,
                true, threads,
            );
            best_wssj = best_wssj.min(t0.elapsed().as_secs_f64());
            bj = j.bj;
        }
        match &base {
            None => base = Some((assign.clone(), ex.bi, bj)),
            Some((a0, bi0, bj0)) => {
                assert_eq!(&assign, a0, "{}: argmin winners diverged", profile.name());
                assert_eq!(ex.bi, *bi0, "{}: WSSi pick diverged", profile.name());
                assert_eq!(bj, *bj0, "{}: WSSj pick diverged", profile.name());
            }
        }
        println!(
            "  {:<7} ({:>3}-bit, {}xf64): argmin {:8.3} ms   wssj {:8.3} ms",
            profile.name(),
            profile.bits(),
            profile.lanes(),
            best_argmin * 1e3,
            best_wssj * 1e3
        );
    }
    println!("  discrete outputs identical across all three profiles");
}

fn cmd_bench_all(flags: &HashMap<String, String>) {
    let _t = ScopedTimer::new("bench-all");
    for algo in ["kmeans", "logreg", "linreg", "pca", "knn", "dbscan", "forest", "svm"] {
        cmd_train(algo, flags);
    }
    // Serving-layer smoke: small fixture so the suite stays quick.
    let mut serve_flags = flags.clone();
    for (key, val) in [("n", "500"), ("requests", "16"), ("reps", "2")] {
        serve_flags.entry(key.to_string()).or_insert_with(|| val.to_string());
    }
    cmd_bench_serve(&serve_flags);
    println!("\n{}", onedal_sve::profiling::timer::Metrics::global().report());
}

fn help() {
    println!(
        "usage: onedal-sve <command> [--flags]\n\
         commands:\n\
         \x20 info                     dispatch ladder + artifact status\n\
         \x20 train <algo>             kmeans|svm|logreg|forest|pca|linreg|dbscan|knn\n\
         \x20 bench-all                smoke the whole suite\n\
         \x20 bench serve              batched serving: coalesced vs naive\n\
         \x20 bench serve --faults [spec]   resilience: retry/degrade under injection\n\
         \x20 bench lanes              predicated kernels at each SVE lane profile\n\
         flags: --backend naive|reference|vectorized|artifact|auto\n\
         \x20      --n <rows> --d <features> --k <clusters> --seed <s>\n\
         \x20      --csv <path> --artifacts <dir> --solver boser|thunder\n\
         \x20      --requests <n> --rows <rows/request> --reps <r>  (bench serve)\n\
         \x20      --attempts <n>  retry attempts  (bench serve --faults)"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = parse_flags(&args);
    match args.first().map(String::as_str) {
        Some("info") => cmd_info(&flags),
        Some("train") => {
            let algo = args.get(1).cloned().unwrap_or_default();
            cmd_train(&algo, &flags);
        }
        Some("bench-all") => cmd_bench_all(&flags),
        Some("bench") => match args.get(1).map(String::as_str) {
            Some("serve") => cmd_bench_serve(&flags),
            Some("lanes") => cmd_bench_lanes(&flags),
            _ => help(),
        },
        _ => help(),
    }
}
