//! PJRT runtime — loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Python runs only at build time — this
//! module is the entire request-path dependency on the compiled kernels.

pub mod artifact;
pub mod client;

pub use artifact::{Artifact, ArtifactRegistry};
pub use client::PjRtRuntime;
