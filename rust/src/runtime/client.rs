//! PJRT CPU client wrapper: compile-once, execute-many over HLO text
//! artifacts (adapted from /opt/xla-example/load_hlo).
//!
//! The real client needs the `xla` crate (PJRT C API bindings), which is
//! not part of the offline default build — it sits behind the
//! off-by-default `runtime-xla` cargo feature. Without the feature a
//! stub [`PjRtRuntime`] with the same surface is compiled instead; its
//! constructor reports the runtime as unavailable, which the
//! coordinator's dispatch ladder already treats as "degrade to the
//! vectorized rung" (an explicit `Backend::Artifact` request still
//! surfaces the error instead of silently downgrading).

#[cfg(feature = "runtime-xla")]
mod pjrt {
    use crate::error::{Error, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// A compiled executable plus its artifact name (for diagnostics).
    struct CompiledEntry {
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT CPU runtime: one client, a compile cache keyed by artifact
    /// name, and typed f32 execute helpers.
    ///
    /// All PJRT calls are serialized behind a mutex — the CPU client is not
    /// documented thread-safe through the C API, and oneDAL's execution model
    /// (one compute context per algorithm run) matches a single-owner design.
    pub struct PjRtRuntime {
        inner: Mutex<RuntimeInner>,
        artifact_dir: PathBuf,
    }

    struct RuntimeInner {
        client: xla::PjRtClient,
        cache: HashMap<String, CompiledEntry>,
    }

    impl PjRtRuntime {
        /// Create a CPU PJRT client rooted at an artifact directory.
        pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
            let client = xla::PjRtClient::cpu()?;
            Ok(Self {
                inner: Mutex::new(RuntimeInner { client, cache: HashMap::new() }),
                artifact_dir: artifact_dir.as_ref().to_path_buf(),
            })
        }

        pub fn artifact_dir(&self) -> &Path {
            &self.artifact_dir
        }

        /// True when the named artifact file exists (dispatch probes this).
        pub fn has_artifact(&self, name: &str) -> bool {
            self.artifact_path(name).exists()
        }

        fn artifact_path(&self, name: &str) -> PathBuf {
            self.artifact_dir.join(format!("{name}.hlo.txt"))
        }

        /// Compile (or fetch from cache) the named artifact.
        fn ensure_compiled<'a>(
            &self,
            inner: &'a mut RuntimeInner,
            name: &str,
        ) -> Result<&'a CompiledEntry> {
            if !inner.cache.contains_key(name) {
                let path = self.artifact_path(name);
                if !path.exists() {
                    return Err(Error::MissingArtifact(name.to_string()));
                }
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = inner.client.compile(&comp)?;
                inner.cache.insert(name.to_string(), CompiledEntry { exe });
            }
            Ok(inner.cache.get(name).unwrap())
        }

        /// Pre-compile an artifact (warmup; keeps compile jitter out of the
        /// measured hot path).
        pub fn warmup(&self, name: &str) -> Result<()> {
            let mut inner = self.inner.lock().unwrap();
            self.ensure_compiled(&mut inner, name).map(|_| ())
        }

        /// Execute artifact `name` on f32 row-major inputs `(data, dims)`.
        ///
        /// The jax side lowers with `return_tuple=True`, so the single output
        /// is a tuple; each element is returned as a flat f32 vector.
        pub fn execute_f32(
            &self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            let mut inner = self.inner.lock().unwrap();
            let entry = self.ensure_compiled(&mut inner, name)?;
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, dims) in inputs {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data).reshape(&dims_i64)?;
                literals.push(lit);
            }
            let result = entry.exe.execute::<xla::Literal>(&literals)?;
            let mut out_lit = result[0][0].to_literal_sync()?;
            let elems = out_lit.decompose_tuple()?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>()?);
            }
            Ok(out)
        }

        /// Number of artifacts compiled so far (metrics).
        pub fn compiled_count(&self) -> usize {
            self.inner.lock().unwrap().cache.len()
        }
    }
}

#[cfg(feature = "runtime-xla")]
pub use pjrt::PjRtRuntime;

#[cfg(not(feature = "runtime-xla"))]
mod stub {
    use crate::error::{Error, Result};
    use std::path::Path;

    /// Stub runtime client compiled when `runtime-xla` is off: the same
    /// surface as the PJRT wrapper, but never constructible — `new`
    /// reports the runtime unavailable so the dispatch ladder degrades
    /// to the native vectorized rung.
    pub struct PjRtRuntime {
        _unconstructible: std::convert::Infallible,
    }

    impl PjRtRuntime {
        pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Self> {
            let _ = artifact_dir.as_ref();
            Err(Error::Runtime(
                "PJRT runtime unavailable: built without the `runtime-xla` feature".into(),
            ))
        }

        pub fn artifact_dir(&self) -> &Path {
            unreachable!("stub PjRtRuntime cannot be constructed")
        }

        pub fn has_artifact(&self, _name: &str) -> bool {
            unreachable!("stub PjRtRuntime cannot be constructed")
        }

        pub fn warmup(&self, _name: &str) -> Result<()> {
            unreachable!("stub PjRtRuntime cannot be constructed")
        }

        pub fn execute_f32(
            &self,
            _name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            unreachable!("stub PjRtRuntime cannot be constructed")
        }

        pub fn compiled_count(&self) -> usize {
            unreachable!("stub PjRtRuntime cannot be constructed")
        }
    }
}

#[cfg(not(feature = "runtime-xla"))]
pub use stub::PjRtRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests need built artifacts; they are exercised through the
    /// integration suite (rust/tests/runtime_integration.rs) which skips
    /// gracefully when `make artifacts` has not run.
    #[test]
    fn missing_artifact_is_reported() {
        let rt = match PjRtRuntime::new("artifacts") {
            Ok(rt) => rt,
            Err(_) => return, // stub build, or no PJRT plugin in this environment
        };
        let err = rt.execute_f32("definitely_not_there", &[]).unwrap_err();
        match err {
            crate::error::Error::MissingArtifact(name) => {
                assert!(name.contains("definitely_not_there"))
            }
            other => panic!("unexpected error {other}"),
        }
    }

    #[cfg(not(feature = "runtime-xla"))]
    #[test]
    fn stub_constructor_reports_missing_feature() {
        let err = PjRtRuntime::new("artifacts").err().expect("stub must not construct");
        assert!(err.to_string().contains("runtime-xla"), "{err}");
    }
}
