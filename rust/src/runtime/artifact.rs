//! Artifact registry: the catalogue of AOT-compiled kernels.
//!
//! `python/compile/aot.py` writes one `<name>.hlo.txt` per (kernel,
//! shape-variant) plus a `manifest.txt` describing them. Shapes are fixed
//! at AOT time (XLA executables are shape-monomorphic), so the registry's
//! job is *variant selection*: given a request's logical dimensions, pick
//! the smallest compiled variant that fits and let the coordinator pad —
//! the reproduction's analogue of the paper's runtime NEON/SVE dispatch
//! (pick the widest vector unit the hardware offers, mask the rest).

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One compiled kernel variant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    /// File stem: `<kernel>__<variant>`, loaded from `<stem>.hlo.txt`.
    pub name: String,
    /// Logical kernel id (`kmeans_assign`, `wss_select`, …).
    pub kernel: String,
    /// The variant's padded dimensions (kernel-specific meaning).
    pub dims: Vec<usize>,
}

impl Artifact {
    /// Total padded element count (used to rank variants by cost).
    fn volume(&self) -> usize {
        self.dims.iter().product()
    }

    /// True when every requested dimension fits into this variant.
    fn fits(&self, need: &[usize]) -> bool {
        need.len() == self.dims.len() && need.iter().zip(&self.dims).all(|(n, d)| n <= d)
    }
}

/// Parsed `manifest.txt`: kernel id → available variants.
///
/// The index is a `BTreeMap` (PAL-HASH, docs/INVARIANTS.md): [`len`],
/// [`kernels`] and any future aggregate traverse it, and sorted-key
/// order keeps those traversals independent of manifest line order.
/// Within one kernel, variants keep their manifest order — variant
/// selection tie-breaks by position, so that order is part of the
/// dispatch contract.
///
/// [`len`]: ArtifactRegistry::len
/// [`kernels`]: ArtifactRegistry::kernels
#[derive(Default, Debug)]
pub struct ArtifactRegistry {
    by_kernel: BTreeMap<String, Vec<Artifact>>,
}

impl ArtifactRegistry {
    /// Parse a manifest file. Each non-comment line:
    /// `kernel variant dim0 dim1 …` (whitespace-separated).
    pub fn parse(text: &str) -> Result<Self> {
        let mut by_kernel: BTreeMap<String, Vec<Artifact>> = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let kernel = it
                .next()
                .ok_or_else(|| Error::Parse(format!("manifest line {}", lineno + 1)))?
                .to_string();
            let variant = it.next().ok_or_else(|| {
                Error::Parse(format!("manifest line {}: missing variant", lineno + 1))
            })?;
            let dims: Vec<usize> = it
                .map(|t| {
                    t.parse().map_err(|_| {
                        Error::Parse(format!("manifest line {}: bad dim {t:?}", lineno + 1))
                    })
                })
                .collect::<Result<_>>()?;
            by_kernel.entry(kernel.clone()).or_default().push(Artifact {
                name: format!("{kernel}__{variant}"),
                kernel,
                dims,
            });
        }
        Ok(Self { by_kernel })
    }

    /// Load `manifest.txt` from the artifact directory; an absent
    /// manifest yields an empty registry (dispatch then avoids the
    /// artifact backend entirely).
    pub fn load<P: AsRef<Path>>(dir: P) -> Self {
        let path = dir.as_ref().join("manifest.txt");
        match std::fs::read_to_string(&path) {
            Ok(text) => Self::parse(&text).unwrap_or_default(),
            Err(_) => Self::default(),
        }
    }

    /// Number of registered variants.
    pub fn len(&self) -> usize {
        self.by_kernel.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Registered kernel ids, in sorted order (a pure function of the
    /// manifest's *contents*, not its line order).
    pub fn kernels(&self) -> Vec<&str> {
        self.by_kernel.keys().map(String::as_str).collect()
    }

    /// All variants of a kernel.
    pub fn variants(&self, kernel: &str) -> &[Artifact] {
        self.by_kernel.get(kernel).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Smallest variant whose padded dims cover `need` (the dispatch
    /// decision). `None` when nothing fits — the coordinator then falls
    /// back down the ladder.
    pub fn best_fit(&self, kernel: &str, need: &[usize]) -> Option<&Artifact> {
        self.variants(kernel)
            .iter()
            .filter(|a| a.fits(need))
            .min_by_key(|a| a.volume())
    }

    /// Throughput-oriented selection: among variants whose *trailing*
    /// dims cover `need[1..]`, pick the one with the largest leading
    /// (row-tile) dim. Streaming loops prefer this — fewer, larger PJRT
    /// dispatches amortize the per-call overhead (§Perf).
    pub fn largest_tile_fit(&self, kernel: &str, need: &[usize]) -> Option<&Artifact> {
        self.variants(kernel)
            .iter()
            .filter(|a| {
                a.dims.len() == need.len()
                    && need[1..].iter().zip(&a.dims[1..]).all(|(n, d)| n <= d)
            })
            .max_by_key(|a| (a.dims[0], std::cmp::Reverse(a.volume())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = "\
# kernel variant dims...
kmeans_assign n256_d64_k16 256 64 16
kmeans_assign n1024_d64_k16 1024 64 16
kmeans_assign n1024_d128_k32 1024 128 32
wss_select n1024 1024
";

    #[test]
    fn parse_counts_and_names() {
        let r = ArtifactRegistry::parse(MANIFEST).unwrap();
        assert_eq!(r.len(), 4);
        assert_eq!(r.variants("kmeans_assign").len(), 3);
        assert_eq!(r.variants("wss_select")[0].name, "wss_select__n1024");
    }

    #[test]
    fn best_fit_picks_smallest_cover() {
        let r = ArtifactRegistry::parse(MANIFEST).unwrap();
        let a = r.best_fit("kmeans_assign", &[200, 50, 10]).unwrap();
        assert_eq!(a.dims, vec![256, 64, 16]);
        let b = r.best_fit("kmeans_assign", &[500, 64, 16]).unwrap();
        assert_eq!(b.dims, vec![1024, 64, 16]);
        let c = r.best_fit("kmeans_assign", &[500, 100, 20]).unwrap();
        assert_eq!(c.dims, vec![1024, 128, 32]);
    }

    #[test]
    fn best_fit_none_when_too_big() {
        let r = ArtifactRegistry::parse(MANIFEST).unwrap();
        assert!(r.best_fit("kmeans_assign", &[5000, 64, 16]).is_none());
        assert!(r.best_fit("unknown_kernel", &[1]).is_none());
    }

    #[test]
    fn largest_tile_fit_prefers_big_row_tiles() {
        let r = ArtifactRegistry::parse(MANIFEST).unwrap();
        let a = r.largest_tile_fit("kmeans_assign", &[5000, 50, 10]).unwrap();
        assert_eq!(a.dims[0], 1024); // biggest row tile with d/k fitting
        assert!(r.largest_tile_fit("kmeans_assign", &[10, 500, 10]).is_none());
    }

    /// Regression (ISSUE 7, PAL-HASH): the kernel index traversals
    /// (`len`, `kernels`) must be a pure function of the manifest's
    /// contents — reordering its lines may not change any aggregate,
    /// and within one kernel the variant order (a dispatch tie-break)
    /// must follow the manifest.
    #[test]
    fn registry_traversal_is_line_order_independent() {
        let reordered = "\
wss_select n1024 1024
kmeans_assign n1024_d128_k32 1024 128 32
kmeans_assign n256_d64_k16 256 64 16
kmeans_assign n1024_d64_k16 1024 64 16
";
        let a = ArtifactRegistry::parse(MANIFEST).unwrap();
        let b = ArtifactRegistry::parse(reordered).unwrap();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.kernels(), vec!["kmeans_assign", "wss_select"]);
        assert_eq!(a.kernels(), b.kernels());
        // Within-kernel variant order follows each manifest.
        assert_eq!(a.variants("kmeans_assign")[0].name, "kmeans_assign__n256_d64_k16");
        assert_eq!(b.variants("kmeans_assign")[0].name, "kmeans_assign__n1024_d128_k32");
    }

    #[test]
    fn missing_manifest_is_empty() {
        let r = ArtifactRegistry::load("/nonexistent/dir");
        assert!(r.is_empty());
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(ArtifactRegistry::parse("kernel").is_err());
        assert!(ArtifactRegistry::parse("kernel var notanumber").is_err());
    }
}
