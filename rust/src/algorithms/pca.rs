//! PCA by the correlation/covariance method (oneDAL's default), built on
//! the VSL `xcp` kernel + the Jacobi eigensolver — one of the algorithms
//! the paper lists as enabled by the sparse/VSL substrates.

use crate::coordinator::{Context, ConvergenceStatus};
use crate::error::{Error, Result};
use crate::linalg::jacobi_eigen_budgeted;
use crate::tables::DenseTable;
use crate::validate;
use crate::vsl::XcpState;

#[derive(Clone, Debug)]
pub struct PcaParams {
    pub n_components: usize,
    /// Use correlation (scale-invariant) instead of covariance.
    pub correlation: bool,
}

pub struct Pca;

impl Pca {
    pub fn params() -> PcaParams {
        PcaParams { n_components: 2, correlation: false }
    }
}

#[derive(Clone, Debug)]
pub struct PcaModel {
    /// `n_components × p` row-major loading matrix (rows = components).
    pub components: DenseTable<f64>,
    pub explained_variance: Vec<f64>,
    pub means: Vec<f64>,
    /// Outcome of the Jacobi eigensolve: `Converged` normally;
    /// `IterLimit` / `DeadlineExceeded` when the context's budget cut
    /// the sweeps short (the loadings are the partially diagonalized
    /// iterate — still orthonormal, approximately principal).
    pub status: ConvergenceStatus,
}

impl PcaParams {
    pub fn n_components(mut self, c: usize) -> Self {
        self.n_components = c;
        self
    }

    pub fn correlation(mut self, c: bool) -> Self {
        self.correlation = c;
        self
    }

    /// Train on an `n×p` observations-in-rows table.
    pub fn train(&self, ctx: &Context, x: &DenseTable<f64>) -> Result<PcaModel> {
        let p = x.cols();
        validate::non_empty(x.rows(), p, "pca")?;
        if self.n_components == 0 || self.n_components > p {
            return Err(Error::Param(format!(
                "pca: n_components={} out of 1..={p}",
                self.n_components
            )));
        }
        if x.rows() < 2 {
            return Err(Error::Param("pca: need ≥ 2 observations".into()));
        }
        crate::parallel::quarantine("pca.train", || {
            let mut st = XcpState::new(p);
            st.update_threads(&x.transposed(), ctx.threads())?;
            let mat = if self.correlation { st.correlation()? } else { st.covariance()? };
            let mut meter = ctx.budget().meter();
            let (vals, vecs, status) = jacobi_eigen_budgeted(mat.data(), p, &mut meter)?;
            let mut comp = DenseTable::zeros(self.n_components, p);
            for c in 0..self.n_components {
                comp.row_mut(c).copy_from_slice(&vecs[c * p..(c + 1) * p]);
            }
            let means = st.sum().iter().map(|&s| s / st.n() as f64).collect();
            Ok(PcaModel {
                components: comp,
                explained_variance: vals[..self.n_components].to_vec(),
                means,
                status,
            })
        })
    }
}

impl PcaModel {
    /// Project rows of `x` onto the principal components.
    pub fn transform(&self, _ctx: &Context, x: &DenseTable<f64>) -> Result<DenseTable<f64>> {
        let p = self.components.cols();
        validate::dims_match(p, x.cols(), "pca")?;
        // Quarantined past validation (PAL-QUAR): a panic in the
        // projection loop surfaces as Error::Internal like every other
        // entry-point body.
        crate::parallel::quarantine("pca.transform", || {
            let k = self.components.rows();
            let mut out = DenseTable::zeros(x.rows(), k);
            let mut centered = vec![0.0f64; p];
            for i in 0..x.rows() {
                for (c, (&v, &m)) in centered.iter_mut().zip(x.row(i).iter().zip(&self.means)) {
                    *c = v - m;
                }
                for j in 0..k {
                    out.set(i, j, crate::blas::dot(&centered, self.components.row(j)));
                }
            }
            Ok(out)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::rng::{Distribution, Gaussian, Mt19937};

    fn ctx() -> Context {
        Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .build()
            .unwrap()
    }

    /// Data stretched along a known direction: PCA must find it.
    #[test]
    fn finds_dominant_direction() {
        let mut e = Mt19937::new(1);
        let mut g = Gaussian::<f64>::standard();
        let n = 800;
        let mut data = vec![0.0; n * 3];
        for i in 0..n {
            let t = 10.0 * g.sample(&mut e); // dominant axis = (1,1,0)/√2
            data[i * 3] = t + 0.1 * g.sample(&mut e);
            data[i * 3 + 1] = t + 0.1 * g.sample(&mut e);
            data[i * 3 + 2] = 0.1 * g.sample(&mut e);
        }
        let x = DenseTable::from_vec(data, n, 3).unwrap();
        let m = Pca::params().n_components(1).train(&ctx(), &x).unwrap();
        let c = m.components.row(0);
        let inv_sqrt2 = 1.0 / 2.0f64.sqrt();
        // Component is ±(1,1,0)/√2.
        assert!((c[0].abs() - inv_sqrt2).abs() < 0.02, "c={c:?}");
        assert!((c[1].abs() - inv_sqrt2).abs() < 0.02);
        assert!(c[2].abs() < 0.05);
        // Explained variance ≈ var(2t)/... dominant eigenvalue ≈ 200.
        assert!(m.explained_variance[0] > 100.0);
    }

    #[test]
    fn transform_decorrelates() {
        let mut e = Mt19937::new(2);
        let mut g = Gaussian::<f64>::standard();
        let n = 500;
        let mut data = vec![0.0; n * 4];
        g.fill(&mut e, &mut data);
        // Introduce correlation between features 0 and 1.
        for i in 0..n {
            data[i * 4 + 1] = 0.9 * data[i * 4] + 0.1 * data[i * 4 + 1];
        }
        let x = DenseTable::from_vec(data, n, 4).unwrap();
        let m = Pca::params().n_components(4).train(&ctx(), &x).unwrap();
        let z = m.transform(&ctx(), &x).unwrap();
        // Projected covariance must be ~diagonal.
        let cov = crate::algorithms::covariance::Covariance::params().train(&ctx(), &z).unwrap();
        for i in 0..4 {
            for j in 0..4 {
                if i != j {
                    assert!(cov.matrix.get(i, j).abs() < 0.05, "off-diag {i}{j}");
                }
            }
        }
    }

    #[test]
    fn explained_variance_descending() {
        let mut e = Mt19937::new(3);
        let mut g = Gaussian::<f64>::standard();
        let mut data = vec![0.0; 300 * 5];
        g.fill(&mut e, &mut data);
        let x = DenseTable::from_vec(data, 300, 5).unwrap();
        let m = Pca::params().n_components(5).train(&ctx(), &x).unwrap();
        for w in m.explained_variance.windows(2) {
            assert!(w[0] >= w[1] - 1e-10);
        }
    }

    /// NaN feature values must not panic PCA (regression: the Jacobi
    /// eigen-sort used `partial_cmp(..).unwrap()`): training terminates
    /// — the sweep loop is bounded — and degrades to deterministic
    /// NaN-laden eigenpairs.
    #[test]
    fn nan_input_degrades_without_panic() {
        let mut e = Mt19937::new(5);
        let mut g = Gaussian::<f64>::standard();
        let mut data = vec![0.0; 100 * 4];
        g.fill(&mut e, &mut data);
        data[17] = f64::NAN;
        let x = DenseTable::from_vec(data, 100, 4).unwrap();
        let m = Pca::params().n_components(2).train(&ctx(), &x).unwrap();
        let m2 = Pca::params().n_components(2).train(&ctx(), &x).unwrap();
        for (a, b) in m.explained_variance.iter().zip(&m2.explained_variance) {
            assert_eq!(a.to_bits(), b.to_bits(), "NaN degradation must be deterministic");
        }
        for (a, b) in m.components.data().iter().zip(m2.components.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn param_validation() {
        let x = DenseTable::<f64>::zeros(10, 3);
        assert!(Pca::params().n_components(0).train(&ctx(), &x).is_err());
        assert!(Pca::params().n_components(4).train(&ctx(), &x).is_err());
    }
}
