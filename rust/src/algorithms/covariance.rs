//! Covariance / correlation estimation — the first consumer of the VSL
//! substrate (§IV-C): batch and online modes both reduce to the `xcp`
//! streaming cross-product.

use crate::coordinator::Context;
use crate::error::{Error, Result};
use crate::tables::DenseTable;
use crate::vsl::XcpState;

/// Result type selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CovarianceOutput {
    Covariance,
    Correlation,
}

#[derive(Clone, Debug)]
pub struct CovarianceParams {
    pub output: CovarianceOutput,
}

pub struct Covariance;

impl Covariance {
    pub fn params() -> CovarianceParams {
        CovarianceParams { output: CovarianceOutput::Covariance }
    }
}

/// Trained (computed) result.
#[derive(Clone, Debug)]
pub struct CovarianceModel {
    /// p×p covariance or correlation matrix.
    pub matrix: DenseTable<f64>,
    /// Per-coordinate means.
    pub means: Vec<f64>,
    pub n: usize,
}

impl CovarianceParams {
    pub fn output(mut self, o: CovarianceOutput) -> Self {
        self.output = o;
        self
    }

    /// Batch mode over an `n×p` observations-in-rows table (the oneDAL
    /// convention; internally transposed to the VSL p×n layout).
    pub fn train(&self, ctx: &Context, x: &DenseTable<f64>) -> Result<CovarianceModel> {
        crate::validate::non_empty(x.rows(), x.cols(), "covariance")?;
        if x.rows() < 2 {
            return Err(Error::Param("covariance: need ≥ 2 observations".into()));
        }
        crate::parallel::quarantine("covariance.train", || {
            let mut st = OnlineCovariance::new(x.cols());
            st.partial_fit_threads(x, ctx.threads())?;
            st.finalize(self.output)
        })
    }
}

/// Online mode (oneDAL `covariance::Online` analogue) — feed row batches,
/// finalize once. Internally this is exactly eq. 6's streaming update.
pub struct OnlineCovariance {
    state: XcpState<f64>,
}

impl OnlineCovariance {
    pub fn new(p: usize) -> Self {
        Self { state: XcpState::new(p) }
    }

    /// Fold a batch of observations (rows) on the process-default
    /// worker count.
    pub fn partial_fit(&mut self, x: &DenseTable<f64>) -> Result<()> {
        self.partial_fit_threads(x, crate::parallel::default_threads())
    }

    /// [`OnlineCovariance::partial_fit`] with an explicit worker count
    /// (the batch entry point routes `Context::threads()` here).
    pub fn partial_fit_threads(&mut self, x: &DenseTable<f64>, threads: usize) -> Result<()> {
        // VSL layout is p×n (coordinates × observations).
        let xt = x.transposed();
        self.state.update_threads(&xt, threads)
    }

    pub fn n(&self) -> usize {
        self.state.n()
    }

    pub fn finalize(&self, output: CovarianceOutput) -> Result<CovarianceModel> {
        let matrix = match output {
            CovarianceOutput::Covariance => self.state.covariance()?,
            CovarianceOutput::Correlation => self.state.correlation()?,
        };
        let n = self.state.n();
        let means = self.state.sum().iter().map(|&s| s / n as f64).collect();
        Ok(CovarianceModel { matrix, means, n })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::rng::{Distribution, Gaussian, Mt19937};

    fn ctx() -> Context {
        Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .build()
            .unwrap()
    }

    fn dataset(seed: u32, n: usize, p: usize) -> DenseTable<f64> {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::new(0.5, 1.5);
        let mut d = vec![0.0; n * p];
        g.fill(&mut e, &mut d);
        DenseTable::from_vec(d, n, p).unwrap()
    }

    #[test]
    fn batch_matches_textbook() {
        let x = dataset(1, 300, 4);
        let m = Covariance::params().train(&ctx(), &x).unwrap();
        // Textbook covariance.
        let means = x.col_means();
        for i in 0..4 {
            for j in 0..4 {
                let mut acc = 0.0;
                for r in 0..300 {
                    acc += (x.get(r, i) - means[i]) * (x.get(r, j) - means[j]);
                }
                acc /= 299.0;
                assert!((m.matrix.get(i, j) - acc).abs() < 1e-9);
            }
        }
        assert_eq!(m.n, 300);
    }

    #[test]
    fn online_equals_batch() {
        let x = dataset(2, 500, 6);
        let batch = Covariance::params().train(&ctx(), &x).unwrap();
        let mut online = OnlineCovariance::new(6);
        online.partial_fit(&x.slice_rows(0, 123).unwrap()).unwrap();
        online.partial_fit(&x.slice_rows(123, 345).unwrap()).unwrap();
        online.partial_fit(&x.slice_rows(345, 500).unwrap()).unwrap();
        let m = online.finalize(CovarianceOutput::Covariance).unwrap();
        for (a, b) in m.matrix.data().iter().zip(batch.matrix.data()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn correlation_unit_diagonal() {
        let x = dataset(3, 200, 5);
        let m = Covariance::params()
            .output(CovarianceOutput::Correlation)
            .train(&ctx(), &x)
            .unwrap();
        for i in 0..5 {
            assert!((m.matrix.get(i, i) - 1.0).abs() < 1e-10);
        }
    }

    #[test]
    fn too_few_rows_rejected() {
        let x = dataset(4, 1, 3);
        assert!(Covariance::params().train(&ctx(), &x).is_err());
    }
}
