//! CART decision-tree classifier — the base learner of the random
//! forest (Fig. 9's 31× fraud-detection workload). Gini-impurity splits
//! on sorted feature scans, depth/leaf-size limited, with optional
//! per-node feature subsampling driven by an RNG engine (the hook the
//! forest uses with its Family-method streams).

use crate::error::{Error, Result};
use crate::rng::{distributions::sample_indices, Engine};
use crate::tables::DenseTable;

#[derive(Clone, Debug)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features inspected per node; 0 = all (single trees) or √p (forest).
    pub max_features: usize,
    pub n_classes: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self { max_depth: 16, min_samples_split: 2, max_features: 0, n_classes: 2 }
    }
}

/// Flattened tree node.
#[derive(Clone, Debug)]
enum Node {
    Leaf {
        /// Class-probability vector.
        proba: Vec<f64>,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A trained decision tree.
#[derive(Clone, Debug)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    pub n_classes: usize,
}

impl DecisionTree {
    /// Fit on the rows of `x` indexed by `idx` (bootstrap support).
    pub fn fit(
        params: &TreeParams,
        x: &DenseTable<f64>,
        y: &[f64],
        idx: &[usize],
        engine: &mut dyn Engine,
    ) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(Error::Shape("tree: label count mismatch".into()));
        }
        if idx.is_empty() {
            return Err(Error::Param("tree: empty training subset".into()));
        }
        let mut t = DecisionTree { nodes: Vec::new(), n_classes: params.n_classes };
        let mut indices = idx.to_vec();
        t.build(params, x, y, &mut indices, 0, engine)?;
        Ok(t)
    }

    fn leaf(&mut self, y: &[f64], idx: &[usize], n_classes: usize) -> usize {
        let mut proba = vec![0.0; n_classes];
        for &i in idx {
            proba[y[i] as usize] += 1.0;
        }
        let total: f64 = proba.iter().sum();
        for p in proba.iter_mut() {
            *p /= total;
        }
        self.nodes.push(Node::Leaf { proba });
        self.nodes.len() - 1
    }

    /// Recursive builder; `idx` is reordered in place (partition).
    fn build(
        &mut self,
        params: &TreeParams,
        x: &DenseTable<f64>,
        y: &[f64],
        idx: &mut [usize],
        depth: usize,
        engine: &mut dyn Engine,
    ) -> Result<usize> {
        let n = idx.len();
        // Stop conditions: depth, size, purity.
        let first_class = y[idx[0]];
        let pure = idx.iter().all(|&i| y[i] == first_class);
        if depth >= params.max_depth || n < params.min_samples_split || pure {
            return Ok(self.leaf(y, idx, params.n_classes));
        }
        // Candidate features.
        let p = x.cols();
        let m = if params.max_features == 0 { p } else { params.max_features.min(p) };
        let feats: Vec<usize> =
            if m == p { (0..p).collect() } else { sample_indices(engine, p, m) };
        // Best Gini split across candidates.
        let mut best: Option<(usize, f64, f64)> = None; // (feature, threshold, score)
        let mut col: Vec<(f64, usize)> = Vec::with_capacity(n);
        let parent_counts = class_counts(y, idx, params.n_classes);
        for &f in &feats {
            col.clear();
            col.extend(idx.iter().map(|&i| (x.get(i, f), y[i] as usize)));
            // `total_cmp`: NaN feature values sort deterministically
            // last (never a split gain — `next_v <= v` rejects them),
            // so a poisoned column degrades to "no split on it"
            // instead of panicking the candidate sort.
            col.sort_by(|a, b| a.0.total_cmp(&b.0));
            let mut left = vec![0.0f64; params.n_classes];
            let mut right = parent_counts.clone();
            for w in 0..n - 1 {
                let (v, c) = col[w];
                left[c] += 1.0;
                right[c] -= 1.0;
                let next_v = col[w + 1].0;
                if next_v <= v || v.is_nan() || next_v.is_nan() {
                    // Cannot split between equal values — nor against a
                    // NaN on either side (totalOrder parks -NaN at the
                    // *front* and +NaN at the back; a NaN midpoint
                    // would make a meaningless threshold).
                    continue;
                }
                let nl = (w + 1) as f64;
                let nr = (n - w - 1) as f64;
                let score = nl * gini(&left, nl) + nr * gini(&right, nr);
                if best.map(|(_, _, s)| score < s).unwrap_or(true) {
                    best = Some((f, 0.5 * (v + next_v), score));
                }
            }
        }
        let Some((feature, threshold, _)) = best else {
            return Ok(self.leaf(y, idx, params.n_classes));
        };
        // Partition idx.
        let mid = partition(idx, |&i| x.get(i, feature) <= threshold);
        if mid == 0 || mid == n {
            return Ok(self.leaf(y, idx, params.n_classes));
        }
        // Reserve the split slot, then build children.
        let slot = self.nodes.len();
        self.nodes.push(Node::Leaf { proba: Vec::new() }); // placeholder
        let (li, ri) = idx.split_at_mut(mid);
        let left = self.build(params, x, y, li, depth + 1, engine)?;
        let right = self.build(params, x, y, ri, depth + 1, engine)?;
        self.nodes[slot] = Node::Split { feature, threshold, left, right };
        Ok(slot)
    }

    /// Class-probability prediction for one row.
    pub fn predict_proba_row(&self, row: &[f64]) -> &[f64] {
        let mut cur = 0usize;
        loop {
            match &self.nodes[cur] {
                Node::Leaf { proba } => return proba,
                Node::Split { feature, threshold, left, right } => {
                    cur = if row[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }
}

fn class_counts(y: &[f64], idx: &[usize], n_classes: usize) -> Vec<f64> {
    let mut c = vec![0.0; n_classes];
    for &i in idx {
        c[y[i] as usize] += 1.0;
    }
    c
}

#[inline]
fn gini(counts: &[f64], n: f64) -> f64 {
    let mut g = 1.0;
    for &c in counts {
        let p = c / n;
        g -= p * p;
    }
    g
}

/// Stable-ish in-place partition; returns the split point.
fn partition<F: Fn(&usize) -> bool>(idx: &mut [usize], pred: F) -> usize {
    let mut next = 0usize;
    for i in 0..idx.len() {
        if pred(&idx[i]) {
            idx.swap(next, i);
            next += 1;
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_classification;

    #[test]
    fn fits_axis_aligned_split() {
        // 1-D threshold task: x<0 → class 0, x≥0 → class 1.
        let data: Vec<f64> = (-50..50).map(|i| i as f64 / 10.0).collect();
        let y: Vec<f64> = data.iter().map(|&v| f64::from(v >= 0.0)).collect();
        let x = DenseTable::from_vec(data, 100, 1).unwrap();
        let idx: Vec<usize> = (0..100).collect();
        let mut e = Mt19937::new(1);
        let t = DecisionTree::fit(&TreeParams::default(), &x, &y, &idx, &mut e).unwrap();
        for i in 0..100 {
            let proba = t.predict_proba_row(x.row(i));
            let pred = f64::from(proba[1] >= 0.5);
            assert_eq!(pred, y[i], "row {i}");
        }
    }

    #[test]
    fn respects_max_depth() {
        let mut e = Mt19937::new(2);
        let (x, y) = make_classification(&mut e, 400, 6, 0.8);
        let idx: Vec<usize> = (0..400).collect();
        let shallow = DecisionTree::fit(
            &TreeParams { max_depth: 1, ..Default::default() },
            &x,
            &y,
            &idx,
            &mut e,
        )
        .unwrap();
        // Depth-1 tree = 1 split + 2 leaves max.
        assert!(shallow.node_count() <= 3);
    }

    #[test]
    fn pure_subset_is_single_leaf() {
        let x = DenseTable::from_vec(vec![1.0, 2.0, 3.0, 4.0], 4, 1).unwrap();
        let y = vec![1.0, 1.0, 1.0, 1.0];
        let idx = vec![0, 1, 2, 3];
        let mut e = Mt19937::new(3);
        let t = DecisionTree::fit(&TreeParams::default(), &x, &y, &idx, &mut e).unwrap();
        assert_eq!(t.node_count(), 1);
        assert_eq!(t.predict_proba_row(&[2.0])[1], 1.0);
    }

    /// A NaN feature value must not panic the split-candidate sort
    /// (regression: the old `partial_cmp(..).unwrap()` aborted). The
    /// poisoned column sorts NaNs last under `total_cmp`, NaN-boundary
    /// splits are rejected, and the clean columns still classify.
    #[test]
    fn nan_feature_degrades_without_panic() {
        let mut e = Mt19937::new(9);
        let (mut x, y) = make_classification(&mut e, 120, 4, 2.0);
        for i in (0..120).step_by(7) {
            x.row_mut(i)[2] = f64::NAN;
        }
        let idx: Vec<usize> = (0..120).collect();
        let t = DecisionTree::fit(&TreeParams::default(), &x, &y, &idx, &mut e).unwrap();
        // Deterministic: refitting gives the same tree shape.
        let mut e2 = Mt19937::new(9);
        let (mut x2, _) = make_classification(&mut e2, 120, 4, 2.0);
        for i in (0..120).step_by(7) {
            x2.row_mut(i)[2] = f64::NAN;
        }
        let t2 = DecisionTree::fit(&TreeParams::default(), &x2, &y, &idx, &mut e2).unwrap();
        assert_eq!(t.node_count(), t2.node_count());
        // Clean rows on separable data still classify well.
        let mut correct = 0usize;
        let mut clean = 0usize;
        for i in 0..120 {
            if x.row(i).iter().all(|v| v.is_finite()) {
                clean += 1;
                let proba = t.predict_proba_row(x.row(i));
                if f64::from(proba[1] >= 0.5) == y[i] {
                    correct += 1;
                }
            }
        }
        assert!(correct as f64 / clean as f64 > 0.9, "{correct}/{clean}");
        // All-NaN column: fitting must still terminate without panic.
        let mut xa = x.clone();
        for i in 0..120 {
            xa.row_mut(i)[0] = f64::NAN;
        }
        DecisionTree::fit(&TreeParams::default(), &xa, &y, &idx, &mut e).unwrap();
    }

    #[test]
    fn probabilities_sum_to_one() {
        let mut e = Mt19937::new(4);
        let (x, y) = make_classification(&mut e, 200, 4, 0.5);
        let idx: Vec<usize> = (0..200).collect();
        let t = DecisionTree::fit(&TreeParams::default(), &x, &y, &idx, &mut e).unwrap();
        for i in 0..200 {
            let s: f64 = t.predict_proba_row(x.row(i)).iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn empty_subset_rejected() {
        let x = DenseTable::<f64>::zeros(3, 1);
        let y = vec![0.0; 3];
        let mut e = Mt19937::new(5);
        assert!(DecisionTree::fit(&TreeParams::default(), &x, &y, &[], &mut e).is_err());
    }
}
