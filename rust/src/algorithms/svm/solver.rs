//! SMO dual solver with the paper's two training methods (§IV-E):
//! **Boser** — classic 2-index SMO, a full WSS scan + two kernel rows per
//! iteration; **Thunder** — working-set batches: one global WSS scan
//! selects a block of violators, the inner SMO runs entirely on cached
//! rows, and the global gradient is reconciled once per block.
//!
//! Both methods call the same `WSSj` function; the context backend picks
//! the scalar or vectorized implementation — reproducing exactly the
//! Fig. 4 comparison (Boser gains more because WSS is a larger fraction
//! of its iteration).

use super::kernel::{RowCache, SvmKernel};
use super::wss::{self, WssJResult, LOW, SIGN_ANY, SIGN_NEG, SIGN_POS, UP};
use crate::blas::dot;
use crate::coordinator::{Backend, Context};
use crate::error::{Error, Result};
use crate::tables::DenseTable;

/// Training method (oneDAL `svm::training::Method`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmSolver {
    Boser,
    Thunder,
}

#[derive(Clone, Debug)]
pub struct SvmParams {
    pub c: f64,
    pub kernel: SvmKernel,
    pub solver: SvmSolver,
    pub eps: f64,
    pub max_iter: usize,
    /// Thunder working-set size.
    pub ws_size: usize,
    /// Gram-row cache capacity (rows).
    pub cache_rows: usize,
}

pub struct Svc;

impl Svc {
    pub fn params() -> SvmParams {
        SvmParams {
            c: 1.0,
            kernel: SvmKernel::Rbf { gamma: 0.1 },
            solver: SvmSolver::Thunder,
            eps: 1e-3,
            max_iter: 100_000,
            ws_size: 64,
            cache_rows: 512,
        }
    }
}

/// Trained binary SVC. Labels are {0, 1} at the API boundary, {−1, +1}
/// internally.
#[derive(Clone, Debug)]
pub struct SvcModel {
    pub support_vectors: DenseTable<f64>,
    /// `α_s·y_s` per support vector.
    pub dual_coef: Vec<f64>,
    pub bias: f64,
    pub kernel: SvmKernel,
    pub iterations: usize,
}

/// Solver state shared by both methods.
struct SolverState {
    /// Signed gradient `g[t] = (K·(αy))_t − y_t`.
    grad: Vec<f64>,
    alpha: Vec<f64>,
    y: Vec<f64>, // ±1
    flags: Vec<u8>,
    c: f64,
}

impl SolverState {
    fn new(y: Vec<f64>, c: f64) -> Self {
        let n = y.len();
        let grad: Vec<f64> = y.iter().map(|&yi| -yi).collect();
        let mut st = Self { grad, alpha: vec![0.0; n], y, flags: vec![0; n], c };
        for t in 0..n {
            st.update_flags(t);
        }
        st
    }

    /// Recompute `I[]` bits for index t (paper's set-membership flags).
    #[inline]
    fn update_flags(&mut self, t: usize) {
        let a = self.alpha[t];
        let pos = self.y[t] > 0.0;
        let mut f = if pos { SIGN_POS } else { SIGN_NEG };
        // I_up: (y=+1, α<C) or (y=−1, α>0); I_low: mirrored.
        let in_up = if pos { a < self.c } else { a > 0.0 };
        let in_low = if pos { a > 0.0 } else { a < self.c };
        if in_up {
            f |= UP;
        }
        if in_low {
            f |= LOW;
        }
        self.flags[t] = f;
    }

    /// Clip the raw step `delta` to the box constraints of pair (i, j)
    /// and apply the α update. Returns the applied step τ.
    fn apply_step(&mut self, i: usize, j: usize, delta: f64) -> f64 {
        let mut tau = delta;
        // α_i ← α_i + y_i·τ ∈ [0, C]
        tau = if self.y[i] > 0.0 {
            tau.min(self.c - self.alpha[i])
        } else {
            tau.min(self.alpha[i])
        };
        // α_j ← α_j − y_j·τ ∈ [0, C]
        tau = if self.y[j] > 0.0 {
            tau.min(self.alpha[j])
        } else {
            tau.min(self.c - self.alpha[j])
        };
        let tau = tau.max(0.0);
        self.alpha[i] += self.y[i] * tau;
        self.alpha[j] -= self.y[j] * tau;
        self.update_flags(i);
        self.update_flags(j);
        tau
    }
}

impl SvmParams {
    pub fn c(mut self, c: f64) -> Self {
        self.c = c;
        self
    }

    pub fn kernel(mut self, k: SvmKernel) -> Self {
        self.kernel = k;
        self
    }

    pub fn solver(mut self, s: SvmSolver) -> Self {
        self.solver = s;
        self
    }

    pub fn eps(mut self, e: f64) -> Self {
        self.eps = e;
        self
    }

    pub fn max_iter(mut self, m: usize) -> Self {
        self.max_iter = m;
        self
    }

    pub fn ws_size(mut self, w: usize) -> Self {
        self.ws_size = w.max(4);
        self
    }

    /// Gram-row cache capacity. oneDAL sizes this from
    /// `cacheSizeInBytes` (default 8 MB ≈ the whole gram block for the
    /// Fig. 4 workloads); sizing it ≥ n makes WSS the dominant
    /// per-iteration cost, which is the regime the paper measures.
    pub fn cache_rows(mut self, r: usize) -> Self {
        self.cache_rows = r.max(2);
        self
    }

    pub fn train(&self, ctx: &Context, x: &DenseTable<f64>, y01: &[f64]) -> Result<SvcModel> {
        let n = x.rows();
        if n != y01.len() {
            return Err(Error::Shape("svm: label count mismatch".into()));
        }
        if self.c <= 0.0 {
            return Err(Error::Param("svm: C must be > 0".into()));
        }
        let y: Vec<f64> = y01.iter().map(|&v| if v > 0.5 { 1.0 } else { -1.0 }).collect();
        if !y.iter().any(|&v| v > 0.0) || !y.iter().any(|&v| v < 0.0) {
            return Err(Error::Param("svm: need both classes present".into()));
        }
        // The WSS implementation is the ladder's branch point (Fig. 4).
        let vectorized = !matches!(ctx.backend(), Backend::Naive | Backend::Reference);
        let mut state = SolverState::new(y, self.c);
        let norms: Vec<f64> = (0..n).map(|i| dot(x.row(i), x.row(i))).collect();
        let diag = self.kernel.diag(x, &norms);
        let threads = ctx.threads();
        let iterations = match self.solver {
            SvmSolver::Boser => self.solve_boser(x, &norms, &diag, &mut state, vectorized, threads),
            SvmSolver::Thunder => {
                self.solve_thunder(x, &norms, &diag, &mut state, vectorized, threads)
            }
        };
        // Bias: midpoint of the optimality interval.
        let up_min = state
            .grad
            .iter()
            .zip(&state.flags)
            .filter(|(_, &f)| f & UP != 0)
            .map(|(&g, _)| g)
            .fold(f64::INFINITY, f64::min);
        let low_max = state
            .grad
            .iter()
            .zip(&state.flags)
            .filter(|(_, &f)| f & LOW != 0)
            .map(|(&g, _)| g)
            .fold(f64::NEG_INFINITY, f64::max);
        let bias = -(up_min + low_max) / 2.0;
        // Extract support vectors.
        let sv_idx: Vec<usize> = (0..n).filter(|&t| state.alpha[t] > 1e-12).collect();
        let support_vectors = x.gather_rows(&sv_idx);
        let dual_coef: Vec<f64> = sv_idx.iter().map(|&t| state.alpha[t] * state.y[t]).collect();
        Ok(SvcModel { support_vectors, dual_coef, bias, kernel: self.kernel, iterations })
    }

    /// One WSSj call through the selected implementation.
    #[allow(clippy::too_many_arguments)]
    fn wss_j(
        vectorized: bool,
        grad: &[f64],
        flags: &[u8],
        gmin: f64,
        kii: f64,
        diag: &[f64],
        ki_signed: &[f64],
        j_start: usize,
        j_end: usize,
    ) -> WssJResult {
        let f = if vectorized { wss::wss_j_vectorized } else { wss::wss_j_scalar };
        let tau = f64::EPSILON.sqrt() * 1e-3;
        f(grad, flags, SIGN_ANY, LOW, gmin, kii, diag, ki_signed, j_start, j_end, tau)
    }

    /// Boser method: full WSS + two fresh kernel rows per iteration.
    #[allow(clippy::too_many_arguments)]
    fn solve_boser(
        &self,
        x: &DenseTable<f64>,
        norms: &[f64],
        diag: &[f64],
        state: &mut SolverState,
        vectorized: bool,
        threads: usize,
    ) -> usize {
        let n = x.rows();
        let mut cache = RowCache::new(self.cache_rows);
        let mut iter = 0usize;
        while iter < self.max_iter {
            iter += 1;
            let Some((bi, gmin)) = wss::wss_i(&state.grad, &state.flags) else { break };
            let kernel = &self.kernel;
            let row_i = cache.get(bi, n, |buf| kernel.gram_row_threads(x, bi, norms, buf, threads));
            // The curvature along the feasible direction (αᵢ += yᵢτ,
            // αⱼ −= yⱼτ) is the *plain* Kii + Kjj − 2·Kij — exactly the
            // `KiBlock` form of the paper's listing.
            let (grad, flags) = (&state.grad, &state.flags);
            let res = Self::wss_j(vectorized, grad, flags, gmin, diag[bi], diag, &row_i, 0, n);
            // Stopping: duality gap Gmax + GMax2 = −GMin + GMax2.
            if -gmin + res.gmax2 < self.eps || res.bj.is_none() {
                break;
            }
            let bj = res.bj.unwrap();
            let tau = state.apply_step(bi, bj, res.delta);
            if tau <= 0.0 {
                break; // numerically stuck
            }
            let row_j = cache.get(bj, n, |buf| kernel.gram_row_threads(x, bj, norms, buf, threads));
            // grad[s] += τ·(K_si − K_sj) — the label-free update.
            for ((g, &ki), &kj) in state.grad.iter_mut().zip(row_i.iter()).zip(row_j.iter()) {
                *g += tau * (ki - kj);
            }
        }
        iter
    }

    /// Thunder method: block working sets on cached rows.
    #[allow(clippy::too_many_arguments)]
    fn solve_thunder(
        &self,
        x: &DenseTable<f64>,
        norms: &[f64],
        diag: &[f64],
        state: &mut SolverState,
        vectorized: bool,
        threads: usize,
    ) -> usize {
        let n = x.rows();
        let q = self.ws_size.min(n);
        let mut cache = RowCache::new(self.cache_rows.max(2 * q));
        let mut iter = 0usize;
        let mut ki_sub = vec![0.0f64; q];
        loop {
            // ---- global selection: top violators from each side ----
            let Some((_, gmin_global)) = wss::wss_i(&state.grad, &state.flags) else { break };
            let gmax2_global = state
                .grad
                .iter()
                .zip(&state.flags)
                .filter(|(_, &f)| f & LOW != 0)
                .map(|(&g, _)| g)
                .fold(f64::NEG_INFINITY, f64::max);
            if -gmin_global + gmax2_global < self.eps {
                break;
            }
            // Working set: q/2 smallest grads in UP + q/2 largest in LOW.
            let mut ups: Vec<usize> =
                (0..n).filter(|&t| state.flags[t] & UP != 0).collect();
            ups.sort_by(|&a, &b| state.grad[a].partial_cmp(&state.grad[b]).unwrap());
            let mut lows: Vec<usize> =
                (0..n).filter(|&t| state.flags[t] & LOW != 0).collect();
            lows.sort_by(|&a, &b| state.grad[b].partial_cmp(&state.grad[a]).unwrap());
            let mut ws: Vec<usize> = Vec::with_capacity(q);
            let (mut iu, mut il) = (0usize, 0usize);
            while ws.len() < q && (iu < ups.len() || il < lows.len()) {
                if iu < ups.len() {
                    let c = ups[iu];
                    iu += 1;
                    if !ws.contains(&c) {
                        ws.push(c);
                    }
                }
                if ws.len() < q && il < lows.len() {
                    let c = lows[il];
                    il += 1;
                    if !ws.contains(&c) {
                        ws.push(c);
                    }
                }
            }
            if ws.len() < 2 {
                break;
            }
            // ---- fetch kernel rows for the block (the cache pays off) ----
            let kernel = &self.kernel;
            let rows: Vec<std::sync::Arc<Vec<f64>>> = ws
                .iter()
                .map(|&t| cache.get(t, n, |buf| kernel.gram_row_threads(x, t, norms, buf, threads)))
                .collect();
            // Sub-views for the q×q inner problem.
            let sub_diag: Vec<f64> = ws.iter().map(|&t| diag[t]).collect();
            let mut sub_grad: Vec<f64> = ws.iter().map(|&t| state.grad[t]).collect();
            let mut sub_flags: Vec<u8> = ws.iter().map(|&t| state.flags[t]).collect();
            let mut delta_ay = vec![0.0f64; ws.len()];
            // ---- inner SMO on the cached block ----
            let inner_max = ws.len() * 8;
            let mut inner = 0usize;
            while inner < inner_max {
                inner += 1;
                iter += 1;
                let Some((li, gmin)) = wss::wss_i(&sub_grad, &sub_flags) else { break };
                let gi = ws[li];
                // Plain kernel sub-row K(i, ·) gathered over the block.
                for (l, &t) in ws.iter().enumerate() {
                    ki_sub[l] = rows[li][t];
                }
                let res = Self::wss_j(
                    vectorized,
                    &sub_grad,
                    &sub_flags,
                    gmin,
                    diag[gi],
                    &sub_diag,
                    &ki_sub[..ws.len()],
                    0,
                    ws.len(),
                );
                if -gmin + res.gmax2 < self.eps || res.bj.is_none() {
                    break;
                }
                let lj = res.bj.unwrap();
                let gj = ws[lj];
                let tau = state.apply_step(gi, gj, res.delta);
                if tau <= 0.0 {
                    break;
                }
                delta_ay[li] += tau;
                delta_ay[lj] -= tau;
                // Local gradient update on the block only.
                for (l, &t) in ws.iter().enumerate() {
                    sub_grad[l] += tau * (rows[li][t] - rows[lj][t]);
                    sub_flags[l] = state.flags[t];
                }
            }
            // ---- reconcile the global gradient once per block ----
            let mut progressed = false;
            for (l, &d) in delta_ay.iter().enumerate() {
                if d != 0.0 {
                    progressed = true;
                    crate::blas::axpy(d, &rows[l], &mut state.grad);
                }
            }
            if !progressed || iter >= self.max_iter {
                break;
            }
        }
        iter
    }
}

impl SvcModel {
    /// Decision values `f(x) = Σ (α·y)ₛ K(x, sᵥ) + b`. Query rows are
    /// independent, so they fan out over the context's worker count
    /// (each row is scored whole by one worker — bit-stable at any
    /// count).
    pub fn decision_function(&self, ctx: &Context, x: &DenseTable<f64>) -> Result<Vec<f64>> {
        if x.cols() != self.support_vectors.cols() {
            return Err(Error::Shape("svm: dim mismatch".into()));
        }
        let n = x.rows();
        let work = n
            .saturating_mul(self.dual_coef.len())
            .saturating_mul(self.support_vectors.cols().max(1));
        let workers = crate::parallel::effective_threads(ctx.threads(), work, 1 << 14);
        let bounds = crate::parallel::even_bounds(n, workers);
        let mut out = vec![self.bias; n];
        crate::parallel::scope_rows(&mut out, 1, &bounds, |r0, _r1, block| {
            for (r, f) in block.iter_mut().enumerate() {
                let row = x.row(r0 + r);
                for (s, &coef) in self.dual_coef.iter().enumerate() {
                    *f += coef * self.kernel.eval(row, self.support_vectors.row(s));
                }
            }
        });
        Ok(out)
    }

    /// 0/1 class prediction.
    pub fn infer(&self, ctx: &Context, x: &DenseTable<f64>) -> Result<Vec<f64>> {
        Ok(self
            .decision_function(ctx, x)?
            .into_iter()
            .map(|f| f64::from(f >= 0.0))
            .collect())
    }

    pub fn n_support(&self) -> usize {
        self.dual_coef.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_classification;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    fn task(seed: u32, n: usize, d: usize, sep: f64) -> (DenseTable<f64>, Vec<f64>) {
        let mut e = Mt19937::new(seed);
        make_classification(&mut e, n, d, sep)
    }

    #[test]
    fn boser_separable_high_accuracy() {
        let (x, y) = task(1, 400, 6, 2.0);
        let c = ctx(Backend::Vectorized);
        let m = Svc::params()
            .solver(SvmSolver::Boser)
            .kernel(SvmKernel::Linear)
            .c(1.0)
            .train(&c, &x, &y)
            .unwrap();
        let acc = crate::metrics::accuracy(&m.infer(&c, &x).unwrap(), &y);
        assert!(acc > 0.97, "acc={acc}");
        assert!(m.n_support() > 0);
    }

    #[test]
    fn thunder_separable_high_accuracy() {
        let (x, y) = task(2, 400, 6, 2.0);
        let c = ctx(Backend::Vectorized);
        let m = Svc::params()
            .solver(SvmSolver::Thunder)
            .kernel(SvmKernel::Rbf { gamma: 0.2 })
            .train(&c, &x, &y)
            .unwrap();
        let acc = crate::metrics::accuracy(&m.infer(&c, &x).unwrap(), &y);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn scalar_and_vectorized_wss_same_model() {
        // Fig. 4's fidelity claim at the whole-solver level: identical
        // support sets and bias through either WSS implementation.
        let (x, y) = task(3, 250, 5, 1.0);
        for solver in [SvmSolver::Boser, SvmSolver::Thunder] {
            let cs = ctx(Backend::Naive); // scalar WSS
            let cv = ctx(Backend::Vectorized); // masked WSS
            let ms = Svc::params().solver(solver).train(&cs, &x, &y).unwrap();
            let mv = Svc::params().solver(solver).train(&cv, &x, &y).unwrap();
            assert_eq!(ms.n_support(), mv.n_support(), "{solver:?}");
            assert!((ms.bias - mv.bias).abs() < 1e-9, "{solver:?}");
            assert_eq!(ms.iterations, mv.iterations, "{solver:?}");
            for (a, b) in ms.dual_coef.iter().zip(&mv.dual_coef) {
                assert_eq!(a.to_bits(), b.to_bits(), "{solver:?}");
            }
        }
    }

    #[test]
    fn boser_and_thunder_agree_on_predictions() {
        let (x, y) = task(4, 300, 4, 1.5);
        let c = ctx(Backend::Vectorized);
        let mb = Svc::params().solver(SvmSolver::Boser).train(&c, &x, &y).unwrap();
        let mt = Svc::params().solver(SvmSolver::Thunder).train(&c, &x, &y).unwrap();
        let pb = mb.infer(&c, &x).unwrap();
        let pt = mt.infer(&c, &x).unwrap();
        let agree = pb.iter().zip(&pt).filter(|(a, b)| a == b).count();
        assert!(agree as f64 / 300.0 > 0.97, "agree={agree}");
    }

    #[test]
    fn rbf_solves_xor_like_task() {
        // XOR: linearly inseparable, RBF must handle it.
        let mut data = Vec::new();
        let mut y = Vec::new();
        let mut e = Mt19937::new(5);
        let mut g = crate::rng::Gaussian::<f64>::new(0.0, 0.15);
        use crate::rng::Distribution;
        for _ in 0..50 {
            let corners = [(0.0, 0.0, 0.0), (1.0, 1.0, 0.0), (0.0, 1.0, 1.0), (1.0, 0.0, 1.0)];
            for (cx, cy, label) in corners {
                data.push(cx + g.sample(&mut e));
                data.push(cy + g.sample(&mut e));
                y.push(label);
            }
        }
        let x = DenseTable::from_vec(data, 200, 2).unwrap();
        let c = ctx(Backend::Vectorized);
        let m = Svc::params()
            .kernel(SvmKernel::Rbf { gamma: 2.0 })
            .c(10.0)
            .train(&c, &x, &y)
            .unwrap();
        let acc = crate::metrics::accuracy(&m.infer(&c, &x).unwrap(), &y);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn alpha_box_constraints_hold() {
        let (x, y) = task(6, 200, 4, 0.5); // noisy → bounded SVs
        let c = ctx(Backend::Vectorized);
        let cval = 0.7;
        let m = Svc::params().c(cval).solver(SvmSolver::Boser).train(&c, &x, &y).unwrap();
        for &coef in &m.dual_coef {
            assert!(coef.abs() <= cval + 1e-9, "coef={coef}");
        }
    }

    #[test]
    fn validation_errors() {
        let c = ctx(Backend::Vectorized);
        let x = DenseTable::<f64>::zeros(4, 2);
        assert!(Svc::params().train(&c, &x, &[0.0, 0.0, 0.0, 0.0]).is_err()); // one class
        assert!(Svc::params().c(0.0).train(&c, &x, &[0.0, 1.0, 0.0, 1.0]).is_err());
        assert!(Svc::params().train(&c, &x, &[0.0, 1.0]).is_err());
    }
}
