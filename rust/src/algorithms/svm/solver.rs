//! SMO dual solver with the paper's two training methods (§IV-E):
//! **Boser** — classic 2-index SMO, a full WSS scan + two kernel rows per
//! iteration; **Thunder** — working-set batches: one global WSS scan
//! selects a block of violators, the inner SMO runs entirely on cached
//! rows, and the global gradient is reconciled once per block.
//!
//! Both methods now run on the same **shrinking engine**: a compacted
//! active index set that periodically sheds bound-pinned non-violators
//! (with the standard unshrink-and-recheck pass before convergence is
//! declared), gram rows computed as blocked tiles over the active set by
//! one packed GEMM call per working set ([`super::kernel::TileCache`]),
//! and every per-iteration scan running through the predicated parallel
//! reductions of [`super::simd`]. The scalar-vs-vectorized WSS branch of
//! the Fig. 4 comparison survives inside [`super::simd::wss_j_par`].

use super::kernel::{SvmKernel, TileCache};
use super::simd::{self, WssExtrema};
use super::wss::{self, LOW, SIGN_ANY, SIGN_NEG, SIGN_POS, UP};
use crate::blas::{dot, pack_b_panels_profile, PackedB, Transpose};
use crate::coordinator::{batch, Backend, BudgetMeter, Context, ConvergenceStatus};
use crate::error::{Error, Result};
use crate::primitives::distances;
use crate::primitives::lanes::LaneProfile;
use crate::primitives::packed::ModelPanel;
use crate::sparse::{csrmm_threads, CsrMatrix, SparseOp};
use crate::tables::{DenseTable, TableRef};
use std::sync::Arc;

/// Training method (oneDAL `svm::training::Method`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SvmSolver {
    Boser,
    Thunder,
}

#[derive(Clone, Debug)]
pub struct SvmParams {
    pub c: f64,
    pub kernel: SvmKernel,
    pub solver: SvmSolver,
    pub eps: f64,
    pub max_iter: usize,
    /// Thunder working-set size.
    pub ws_size: usize,
    /// Gram cache floor in rows (legacy knob; the byte budget below
    /// usually dominates).
    pub cache_rows: usize,
    /// Gram tile-cache budget in bytes (oneDAL `cacheSizeInBytes`).
    pub cache_bytes: usize,
    /// Enable active-set shrinking.
    pub shrinking: bool,
    /// Inner iterations between shrink passes; 0 = auto
    /// (`clamp(n, 8, 1000)` — LIBSVM's `min(n, 1000)` with a floor of
    /// 8 so tiny problems do not shrink on every iteration).
    pub shrink_period: usize,
}

pub struct Svc;

impl Svc {
    pub fn params() -> SvmParams {
        SvmParams {
            c: 1.0,
            kernel: SvmKernel::Rbf { gamma: 0.1 },
            solver: SvmSolver::Thunder,
            eps: 1e-3,
            max_iter: 100_000,
            ws_size: 64,
            cache_rows: 512,
            cache_bytes: 8 << 20,
            shrinking: true,
            shrink_period: 0,
        }
    }
}

/// Per-training instrumentation the acceptance criteria key on: the
/// kernel-evaluation counters prove shrinking computes strictly fewer
/// gram entries, and the event counters expose the shrink/unshrink
/// schedule to tests and the `ablate_svm` bench.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TrainStats {
    pub iterations: usize,
    /// Gram tile rows computed (each `width` entries wide at the time).
    pub tile_rows: u64,
    /// Gram entries computed — Σ of tile areas, the true kernel cost.
    pub kernel_entries: u64,
    pub shrink_events: u32,
    pub unshrink_events: u32,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Active-set size when the solver stopped (before the final
    /// reconstruction pass, if one ran).
    pub final_active: usize,
}

/// Trained binary SVC. Labels are {0, 1} at the API boundary, {−1, +1}
/// internally.
#[derive(Clone, Debug)]
pub struct SvcModel {
    pub support_vectors: DenseTable<f64>,
    /// Training-set row index of each support vector.
    pub support_idx: Vec<usize>,
    /// `α_s·y_s` per support vector.
    pub dual_coef: Vec<f64>,
    pub bias: f64,
    pub kernel: SvmKernel,
    pub iterations: usize,
    pub stats: TrainStats,
    /// `Converged` when the full-set optimality certificate held (or
    /// the solver went numerically stuck at an eps-optimal point);
    /// `IterLimit` / `DeadlineExceeded` when `max_iter` or the
    /// context's budget stopped training first — the model is then the
    /// last completed iterate (bias reconstructed over the full set).
    pub status: ConvergenceStatus,
    /// Support panel prepacked at `train` time (transposed view +
    /// pooled norms), so [`SvcModel::decision_function`] never
    /// re-transposes the support set or recomputes its norms per call.
    panel: ModelPanel,
}

/// Solver state shared by both methods (full-length; the gradient lives
/// compacted in [`ActiveSet`]).
struct SolverState {
    alpha: Vec<f64>,
    y: Vec<f64>, // ±1
    flags: Vec<u8>,
    c: f64,
}

impl SolverState {
    fn new(y: Vec<f64>, c: f64) -> Self {
        let n = y.len();
        let mut st = Self { alpha: vec![0.0; n], y, flags: vec![0; n], c };
        for t in 0..n {
            st.update_flags(t);
        }
        st
    }

    /// Recompute `I[]` bits for index t (paper's set-membership flags).
    #[inline]
    fn update_flags(&mut self, t: usize) {
        let a = self.alpha[t];
        let pos = self.y[t] > 0.0;
        let mut f = if pos { SIGN_POS } else { SIGN_NEG };
        // I_up: (y=+1, α<C) or (y=−1, α>0); I_low: mirrored.
        let in_up = if pos { a < self.c } else { a > 0.0 };
        let in_low = if pos { a > 0.0 } else { a < self.c };
        if in_up {
            f |= UP;
        }
        if in_low {
            f |= LOW;
        }
        self.flags[t] = f;
    }

    /// Clip the raw step `delta` to the box constraints of pair (i, j)
    /// and apply the α update. Returns the applied step τ.
    fn apply_step(&mut self, i: usize, j: usize, delta: f64) -> f64 {
        let mut tau = delta;
        // α_i ← α_i + y_i·τ ∈ [0, C]
        tau = if self.y[i] > 0.0 {
            tau.min(self.c - self.alpha[i])
        } else {
            tau.min(self.alpha[i])
        };
        // α_j ← α_j − y_j·τ ∈ [0, C]
        tau = if self.y[j] > 0.0 {
            tau.min(self.alpha[j])
        } else {
            tau.min(self.c - self.alpha[j])
        };
        let tau = tau.max(0.0);
        self.alpha[i] += self.y[i] * tau;
        self.alpha[j] -= self.y[j] * tau;
        self.update_flags(i);
        self.update_flags(j);
        tau
    }
}

/// The training data in whichever layout it arrived — the engine is
/// layout-polymorphic through this handle: panel packing, gram blocks
/// and row-norm reductions each have a dense and a CSR implementation,
/// and everything else in the solver (WSS scans, gradient updates,
/// shrinking schedule) never touches the raw rows.
#[derive(Clone, Copy)]
enum TrainData<'a> {
    Dense(&'a DenseTable<f64>),
    Csr(&'a CsrMatrix<f64>),
}

/// The active-row panel the gram tiles multiply against, in the layout
/// matching the training data: prepacked `op(B)` micro-panels for dense
/// rows, the densified-transposed `d × na` buffer (the dense operand of
/// the threaded CSR multiply) for sparse rows. Either way it is packed
/// once per shrink generation and reused by every tile.
enum ActivePanel {
    Packed(PackedB<f64>),
    Densified(Vec<f64>),
}

impl<'a> TrainData<'a> {
    fn rows(&self) -> usize {
        match self {
            TrainData::Dense(x) => x.rows(),
            TrainData::Csr(s) => s.rows(),
        }
    }

    /// Squared row norms (single pass; the CSR side sweeps only the
    /// stored values).
    fn row_norms(&self) -> Vec<f64> {
        match self {
            TrainData::Dense(x) => (0..x.rows()).map(|i| dot(x.row(i), x.row(i))).collect(),
            TrainData::Csr(s) => distances::csr_row_norms(s, 1),
        }
    }

    /// Pack rows `idx` as the gram panel in the native layout, at the
    /// engine's lane profile.
    fn pack_panel(&self, idx: &[usize], profile: LaneProfile) -> ActivePanel {
        match self {
            TrainData::Dense(x) => ActivePanel::Packed(pack_active_panel(x, idx, profile)),
            TrainData::Csr(s) => {
                let na = idx.len();
                let mut bt = vec![0.0f64; s.cols() * na];
                for (r, &g) in idx.iter().enumerate() {
                    for (j, v) in s.row_entries(g) {
                        bt[j * na + r] = v;
                    }
                }
                ActivePanel::Densified(bt)
            }
        }
    }

    /// One blocked gram tile `K(rows × panel)`: gather the working rows
    /// in the native layout and run the kernel's blocked multiply +
    /// epilogue ([`SvmKernel::gram_tile`] / [`SvmKernel::gram_tile_csr`]).
    #[allow(clippy::too_many_arguments)]
    fn gram_block(
        &self,
        kernel: &SvmKernel,
        rows: &[usize],
        norms: &[f64],
        panel_norms: &[f64],
        panel: &ActivePanel,
        out: &mut [f64],
        profile: LaneProfile,
        threads: usize,
    ) {
        match (self, panel) {
            (TrainData::Dense(x), ActivePanel::Packed(pb)) => {
                let d = x.cols();
                let mut w = vec![0.0f64; rows.len() * d];
                let mut wn = vec![0.0f64; rows.len()];
                for (r, &g) in rows.iter().enumerate() {
                    w[r * d..(r + 1) * d].copy_from_slice(x.row(g));
                    wn[r] = norms[g];
                }
                // The packed panel carries its profile; `gram_tile`
                // reads the geometry from it.
                kernel.gram_tile(&w, &wn, panel_norms, pb, out, threads);
            }
            (TrainData::Csr(s), ActivePanel::Densified(bt)) => {
                let wcsr = s.gather_rows(rows);
                let wn: Vec<f64> = rows.iter().map(|&g| norms[g]).collect();
                kernel.gram_tile_csr(&wcsr, &wn, panel_norms, bt, out, profile, threads);
            }
            _ => unreachable!("panel layout always matches the data layout"),
        }
    }
}

/// The compacted active set: every per-iteration array the WSS scans
/// and gradient updates touch, gathered down to the surviving indices,
/// plus the packed active-row panel the gram tiles multiply against
/// (re-packed once per shrink generation, reused across every tile; the
/// un-packed gather is a transient — active rows stay reachable through
/// the training data and `idx`, so only the panel layout is kept
/// resident).
struct ActiveSet {
    /// Surviving global indices, ascending.
    idx: Vec<usize>,
    /// The active-row gram panel in the data's native layout.
    panel: ActivePanel,
    norms: Vec<f64>,
    diag: Vec<f64>,
    /// Signed gradient, compacted — the source of truth while a point
    /// is active (inactive gradients go stale and are reconstructed on
    /// unshrink).
    grad: Vec<f64>,
    flags: Vec<u8>,
}

/// Gather rows `idx` of `x` into a dense `|idx| × d` buffer and pack it
/// as the tile GEMM's `op(B)` panel at the engine's lane profile.
fn pack_active_panel(x: &DenseTable<f64>, idx: &[usize], profile: LaneProfile) -> PackedB<f64> {
    let d = x.cols();
    let mut gathered = vec![0.0f64; idx.len() * d];
    for (r, &g) in idx.iter().enumerate() {
        gathered[r * d..(r + 1) * d].copy_from_slice(x.row(g));
    }
    pack_b_panels_profile(Transpose::Yes, d, idx.len(), &gathered, profile)
}

impl ActiveSet {
    fn full(
        data: TrainData,
        norms: &[f64],
        diag: &[f64],
        grad: Vec<f64>,
        flags: &[u8],
        profile: LaneProfile,
    ) -> Self {
        let n = data.rows();
        let idx: Vec<usize> = (0..n).collect();
        let panel = data.pack_panel(&idx, profile);
        let (norms, diag, flags) = (norms.to_vec(), diag.to_vec(), flags.to_vec());
        Self { idx, panel, norms, diag, grad, flags }
    }

    fn len(&self) -> usize {
        self.idx.len()
    }

    /// Keep only the local positions in `keep` (ascending) and re-pack
    /// the tile panel.
    fn retain(&mut self, keep: &[usize], data: TrainData, profile: LaneProfile) {
        let gather = |src: &[f64]| keep.iter().map(|&l| src[l]).collect::<Vec<f64>>();
        self.idx = keep.iter().map(|&l| self.idx[l]).collect();
        self.norms = gather(&self.norms);
        self.diag = gather(&self.diag);
        self.grad = gather(&self.grad);
        self.flags = keep.iter().map(|&l| self.flags[l]).collect();
        self.panel = data.pack_panel(&self.idx, profile);
    }
}

/// The shrinking training engine both methods run on (either data
/// layout, through [`TrainData`]).
struct Engine<'a> {
    params: &'a SvmParams,
    data: TrainData<'a>,
    norms: &'a [f64],
    diag: &'a [f64],
    state: SolverState,
    active: ActiveSet,
    tiles: TileCache,
    vectorized: bool,
    /// The lane profile the owning `Context` resolved — every WSS scan,
    /// gradient update and panel pack in this engine runs at its width.
    profile: LaneProfile,
    threads: usize,
    stats: TrainStats,
    shrink_period: usize,
    since_shrink: usize,
    tau: f64,
    meter: BudgetMeter,
    status: ConvergenceStatus,
}

impl<'a> Engine<'a> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        params: &'a SvmParams,
        data: TrainData<'a>,
        norms: &'a [f64],
        diag: &'a [f64],
        y: Vec<f64>,
        vectorized: bool,
        profile: LaneProfile,
        threads: usize,
        meter: BudgetMeter,
    ) -> Self {
        let n = data.rows();
        let state = SolverState::new(y, params.c);
        let grad0: Vec<f64> = state.y.iter().map(|&yi| -yi).collect();
        let active = ActiveSet::full(data, norms, diag, grad0, &state.flags, profile);
        let tiles = TileCache::new(params.tile_capacity(n), n);
        let shrink_period = if params.shrink_period > 0 {
            params.shrink_period
        } else {
            n.min(1000).max(8)
        };
        Self {
            params,
            data,
            norms,
            diag,
            state,
            active,
            tiles,
            vectorized,
            profile,
            threads,
            stats: TrainStats::default(),
            shrink_period,
            since_shrink: 0,
            tau: f64::EPSILON.sqrt() * 1e-3,
            meter,
            status: ConvergenceStatus::Converged,
        }
    }

    /// Budget/max-iter gate at the top of each outer solver iteration.
    /// `true` ⇒ stop now; `self.status` records why.
    fn out_of_budget(&mut self) -> bool {
        if self.stats.iterations >= self.params.max_iter {
            self.status = ConvergenceStatus::IterLimit;
            return true;
        }
        if let Some(expired) = self.meter.check_before_iter() {
            self.status = expired;
            return true;
        }
        false
    }

    /// Fetch gram rows (over the active set) for the active-local
    /// working set `locals`; all misses are computed as **one** blocked
    /// tile through the packed panel.
    fn fetch_rows(&mut self, locals: &[usize]) -> Vec<Arc<Vec<f64>>> {
        let globals: Vec<usize> = locals.iter().map(|&l| self.active.idx[l]).collect();
        let (data, norms, threads) = (self.data, self.norms, self.threads);
        let profile = self.profile;
        let kernel = &self.params.kernel;
        let active = &self.active;
        let stats = &mut self.stats;
        let na = active.idx.len();
        self.tiles.fetch_block(&globals, |miss, tile| {
            data.gram_block(
                kernel,
                miss,
                norms,
                &active.norms,
                &active.panel,
                tile,
                profile,
                threads,
            );
            stats.tile_rows += miss.len() as u64;
            stats.kernel_entries += (miss.len() * na) as u64;
        })
    }

    /// One fused extrema scan over the active set.
    fn extrema(&self) -> WssExtrema {
        simd::wss_extrema_par(self.profile, &self.active.grad, &self.active.flags, self.threads)
    }

    /// LIBSVM's shrink rule on the compacted arrays: drop bound-pinned
    /// points whose gradient cannot re-enter the violating pair — out
    /// of `I_up` with `g < GMin`, or out of `I_low` with `g > GMax2`.
    /// Free points are never shrunk.
    fn shrink(&mut self, ex: &WssExtrema) {
        self.since_shrink = 0;
        let na = self.active.len();
        if na <= 2 {
            return;
        }
        let keep: Vec<usize> = (0..na)
            .filter(|&l| {
                let fl = self.active.flags[l];
                let in_up = fl & UP != 0;
                let in_low = fl & LOW != 0;
                if in_up && in_low {
                    return true;
                }
                let g = self.active.grad[l];
                let pinned = (!in_up && g < ex.gmin) || (!in_low && g > ex.gmax2);
                !pinned
            })
            .collect();
        if keep.len() < 2 || keep.len() == na {
            return;
        }
        self.active.retain(&keep, self.data, self.profile);
        self.tiles.compact(&keep);
        self.tiles.purge_missing(&self.active.idx);
        self.tiles.set_capacity(self.params.tile_capacity(keep.len()));
        self.stats.shrink_events += 1;
    }

    /// Reconstruct the gradients of every shrunk-out point and
    /// reactivate the full index set. The reconstruction is one blocked
    /// gram tile `K(inactive × SV)` — `g[t] = Σ_s K(t,s)·α_s·y_s − y_t`
    /// only needs the support columns. `count_event` distinguishes the
    /// mid-training unshrink-and-recheck passes (counted in
    /// `unshrink_events`) from the bias-only reconstruction after a
    /// max-iter/stuck stop, so the counter certifies genuine rechecks.
    fn unshrink(&mut self, count_event: bool) {
        let n = self.data.rows();
        if self.active.len() == n {
            return;
        }
        if count_event {
            self.stats.unshrink_events += 1;
        }
        let mut inactive = Vec::with_capacity(n - self.active.len());
        {
            let mut it = self.active.idx.iter().peekable();
            for t in 0..n {
                if it.peek() == Some(&&t) {
                    it.next();
                } else {
                    inactive.push(t);
                }
            }
        }
        let sv: Vec<usize> = (0..n).filter(|&s| self.state.alpha[s] > 0.0).collect();
        let mut grad_full = vec![0.0f64; n];
        for (l, &t) in self.active.idx.iter().enumerate() {
            grad_full[t] = self.active.grad[l];
        }
        if sv.is_empty() {
            for &t in &inactive {
                grad_full[t] = -self.state.y[t];
            }
        } else {
            let pn: Vec<f64> = sv.iter().map(|&s| self.norms[s]).collect();
            let panel = self.data.pack_panel(&sv, self.profile);
            let mut tile = vec![0.0f64; inactive.len() * sv.len()];
            self.data.gram_block(
                &self.params.kernel,
                &inactive,
                self.norms,
                &pn,
                &panel,
                &mut tile,
                self.profile,
                self.threads,
            );
            self.stats.tile_rows += inactive.len() as u64;
            self.stats.kernel_entries += (inactive.len() * sv.len()) as u64;
            let coef: Vec<f64> =
                sv.iter().map(|&s| self.state.alpha[s] * self.state.y[s]).collect();
            for (r, &t) in inactive.iter().enumerate() {
                let row = &tile[r * sv.len()..(r + 1) * sv.len()];
                grad_full[t] = dot(row, &coef) - self.state.y[t];
            }
        }
        self.active = ActiveSet::full(
            self.data,
            self.norms,
            self.diag,
            grad_full,
            &self.state.flags,
            self.profile,
        );
        self.tiles.reset(n);
        self.tiles.set_capacity(self.params.tile_capacity(n));
        self.since_shrink = 0;
    }

    /// The unshrink-and-recheck gate every convergence path goes
    /// through: with a full active set the optimality certificate is
    /// genuine (return `true`, stop); with a shrunk set it only proves
    /// optimality *over the active subset*, so reconstruct, reactivate
    /// and keep training (return `false`).
    fn converged_or_unshrink(&mut self) -> bool {
        if self.active.len() == self.data.rows() {
            return true;
        }
        self.unshrink(true);
        false
    }

    fn maybe_shrink(&mut self) {
        if self.params.shrinking && self.since_shrink >= self.shrink_period {
            let ex = self.extrema();
            self.shrink(&ex);
        }
    }

    /// Boser method: full WSS + (up to) two kernel tile rows per
    /// iteration, all scans over the compacted active set.
    fn solve_boser(&mut self) {
        loop {
            if self.out_of_budget() {
                break;
            }
            self.stats.iterations += 1;
            self.maybe_shrink();
            let ex = self.extrema();
            let Some(li) = ex.bi else {
                if self.converged_or_unshrink() {
                    break;
                }
                continue;
            };
            // Stopping: duality gap Gmax + GMax2 = −GMin + GMax2.
            if -ex.gmin + ex.gmax2 < self.params.eps {
                if self.converged_or_unshrink() {
                    break;
                }
                continue;
            }
            let gi = self.active.idx[li];
            let row_i = self.fetch_rows(&[li]).remove(0);
            let res = simd::wss_j_par(
                self.profile,
                &self.active.grad,
                &self.active.flags,
                SIGN_ANY,
                LOW,
                ex.gmin,
                self.diag[gi],
                &self.active.diag,
                &row_i,
                self.tau,
                self.vectorized,
                self.threads,
            );
            let Some(lj) = res.bj else {
                if self.converged_or_unshrink() {
                    break;
                }
                continue;
            };
            let gj = self.active.idx[lj];
            let tau_step = self.state.apply_step(gi, gj, res.delta);
            if tau_step <= 0.0 {
                break; // numerically stuck
            }
            self.active.flags[li] = self.state.flags[gi];
            self.active.flags[lj] = self.state.flags[gj];
            let row_j = self.fetch_rows(&[lj]).remove(0);
            // grad[s] += τ·(K_si − K_sj) — the label-free update,
            // predicated at the profile's lane width, parallel over
            // disjoint chunks.
            simd::update_grad_pair(
                self.profile,
                &mut self.active.grad,
                &row_i,
                &row_j,
                tau_step,
                self.threads,
            );
            self.since_shrink += 1;
        }
    }

    /// Thunder method: block working sets on one cached gram tile.
    fn solve_thunder(&mut self) {
        loop {
            if self.out_of_budget() {
                break;
            }
            self.maybe_shrink();
            // ---- global selection: top violators from each side ----
            let ex = self.extrema();
            if ex.bi.is_none() || -ex.gmin + ex.gmax2 < self.params.eps {
                if self.converged_or_unshrink() {
                    break;
                }
                continue;
            }
            let na = self.active.len();
            let q = self.params.ws_size.min(na);
            // Working set: q/2 smallest grads in UP + q/2 largest in
            // LOW (active-local indices), via deterministic partial
            // selection instead of full sorts.
            let ws = select_working_set(&self.active.grad, &self.active.flags, q);
            if ws.len() < 2 {
                if self.converged_or_unshrink() {
                    break;
                }
                continue;
            }
            // ---- one blocked tile for the whole working set ----
            let rows = self.fetch_rows(&ws);
            // Sub-views for the q×q inner problem.
            let sub_diag: Vec<f64> = ws.iter().map(|&l| self.active.diag[l]).collect();
            let mut sub_grad: Vec<f64> = ws.iter().map(|&l| self.active.grad[l]).collect();
            let mut sub_flags: Vec<u8> = ws.iter().map(|&l| self.active.flags[l]).collect();
            let mut delta_ay = vec![0.0f64; ws.len()];
            let mut ki_sub = vec![0.0f64; ws.len()];
            // ---- inner SMO on the cached block ----
            let inner_max = ws.len() * 8;
            let mut inner = 0usize;
            while inner < inner_max && self.stats.iterations < self.params.max_iter {
                inner += 1;
                self.stats.iterations += 1;
                let exi = simd::extrema_range(self.profile, &sub_grad, &sub_flags, 0, ws.len());
                let Some(wi) = exi.bi else { break };
                let li = ws[wi];
                let gi = self.active.idx[li];
                // Kernel sub-row K(i, ·) gathered over the block
                // (tile rows are active-local, so columns are `ws`).
                for (l, &wl) in ws.iter().enumerate() {
                    ki_sub[l] = rows[wi][wl];
                }
                let res = simd::wss_j_par(
                    self.profile,
                    &sub_grad,
                    &sub_flags,
                    SIGN_ANY,
                    LOW,
                    exi.gmin,
                    self.diag[gi],
                    &sub_diag,
                    &ki_sub,
                    self.tau,
                    self.vectorized,
                    1, // q is tiny: never fan out the inner scan
                );
                if -exi.gmin + res.gmax2 < self.params.eps {
                    break;
                }
                let Some(wj) = res.bj else { break };
                let lj = ws[wj];
                let gj = self.active.idx[lj];
                let tau_step = self.state.apply_step(gi, gj, res.delta);
                if tau_step <= 0.0 {
                    break;
                }
                delta_ay[wi] += tau_step;
                delta_ay[wj] -= tau_step;
                self.active.flags[li] = self.state.flags[gi];
                self.active.flags[lj] = self.state.flags[gj];
                // Local gradient update on the block only.
                for (l, &wl) in ws.iter().enumerate() {
                    sub_grad[l] += tau_step * (rows[wi][wl] - rows[wj][wl]);
                    sub_flags[l] = self.active.flags[wl];
                }
            }
            self.since_shrink += inner;
            // ---- reconcile the global gradient once per block ----
            let progressed = delta_ay.iter().any(|&d| d != 0.0);
            if progressed {
                simd::reconcile_grad(&mut self.active.grad, &delta_ay, &rows, self.threads);
            } else {
                // Selected block could not move: either genuinely
                // converged or converged-on-the-shrunk-set.
                if self.converged_or_unshrink() {
                    break;
                }
            }
        }
    }

    fn solve(&mut self) {
        match self.params.solver {
            SvmSolver::Boser => self.solve_boser(),
            SvmSolver::Thunder => self.solve_thunder(),
        }
        self.stats.final_active = self.active.len();
        self.stats.cache_hits = self.tiles.hits;
        self.stats.cache_misses = self.tiles.misses;
        // Bias needs the full gradient: reconstruct if the solver
        // stopped (max_iter / stuck) while shrunk. Not counted as an
        // unshrink *event* — it is not a convergence recheck.
        if self.active.len() < self.data.rows() {
            self.unshrink(false);
        }
    }
}

/// Thunder working-set selection: interleave the top violators from
/// each side — smallest gradients in `I_up` with largest in `I_low` —
/// deduplicating free points that appear in both, until `q` indices are
/// chosen. Candidate ranking runs [`wss::partial_select_by`]
/// (deterministic quickselect under the `(gradient, index)` total
/// order, ties to the lower index) over a `q`-deep prefix per side
/// instead of fully sorting both lists: the interleave consumes at most
/// `q` candidates per side (every consumed candidate is either pushed —
/// at most `q` pushes in total — or skipped as a duplicate of a push
/// from the *other* side, of which there are at most `q`−pushes), so
/// the `q`-deep prefixes reproduce the full-sort selection exactly —
/// the block-set equality the oracle test below asserts.
fn select_working_set(grad: &[f64], flags: &[u8], q: usize) -> Vec<usize> {
    let na = grad.len();
    // `total_cmp` keys: a NaN gradient (NaN feature values reaching the
    // kernel) sorts deterministically last/first instead of panicking
    // the quickselect mid-train.
    let mut ups: Vec<usize> = (0..na).filter(|&l| flags[l] & UP != 0).collect();
    wss::partial_select_by(&mut ups, q.min(ups.len()), |a, b| {
        grad[a].total_cmp(&grad[b]).then(a.cmp(&b))
    });
    let mut lows: Vec<usize> = (0..na).filter(|&l| flags[l] & LOW != 0).collect();
    wss::partial_select_by(&mut lows, q.min(lows.len()), |a, b| {
        grad[b].total_cmp(&grad[a]).then(a.cmp(&b))
    });
    let mut ws: Vec<usize> = Vec::with_capacity(q);
    let (mut iu, mut il) = (0usize, 0usize);
    while ws.len() < q && (iu < ups.len() || il < lows.len()) {
        if iu < ups.len() {
            let c = ups[iu];
            iu += 1;
            if !ws.contains(&c) {
                ws.push(c);
            }
        }
        if ws.len() < q && il < lows.len() {
            let c = lows[il];
            il += 1;
            if !ws.contains(&c) {
                ws.push(c);
            }
        }
    }
    ws
}

impl SvmParams {
    pub fn c(mut self, c: f64) -> Self {
        self.c = c;
        self
    }

    pub fn kernel(mut self, k: SvmKernel) -> Self {
        self.kernel = k;
        self
    }

    pub fn solver(mut self, s: SvmSolver) -> Self {
        self.solver = s;
        self
    }

    pub fn eps(mut self, e: f64) -> Self {
        self.eps = e;
        self
    }

    pub fn max_iter(mut self, m: usize) -> Self {
        self.max_iter = m;
        self
    }

    pub fn ws_size(mut self, w: usize) -> Self {
        self.ws_size = w.max(4);
        self
    }

    /// Gram cache floor in rows. oneDAL sizes the cache from
    /// `cacheSizeInBytes` (see [`SvmParams::cache_bytes`]); this knob
    /// survives as a row-count floor so callers that sized the cache
    /// `≥ n` keep the whole-gram regime the paper measures.
    pub fn cache_rows(mut self, r: usize) -> Self {
        self.cache_rows = r.max(2);
        self
    }

    /// Gram tile-cache budget in bytes (oneDAL's `cacheSizeInBytes`,
    /// default 8 MB). Rows narrow as the active set shrinks, so the
    /// same budget holds more rows late in training.
    pub fn cache_bytes(mut self, b: usize) -> Self {
        self.cache_bytes = b;
        self
    }

    /// Enable/disable active-set shrinking (on by default).
    pub fn shrinking(mut self, s: bool) -> Self {
        self.shrinking = s;
        self
    }

    /// Inner iterations between shrink passes (0 = the LIBSVM-style
    /// `min(n, 1000)` auto schedule, floored at 8). Exposed mostly for
    /// tests: a period of 1 shrinks maximally aggressively, which the
    /// unshrink-recheck pass must correct.
    pub fn shrink_period(mut self, p: usize) -> Self {
        self.shrink_period = p;
        self
    }

    /// Tile-cache row capacity for an active set of `width` columns:
    /// the byte budget divided by the row footprint, floored by the
    /// legacy row knob and by two working sets (so one block fetch can
    /// never evict its own rows).
    fn tile_capacity(&self, width: usize) -> usize {
        let by_bytes = self.cache_bytes / (width.max(1) * std::mem::size_of::<f64>());
        by_bytes.max(self.cache_rows).max(2 * self.ws_size.min(width.max(2)))
    }

    pub fn train<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
        y01: &[f64],
    ) -> Result<SvcModel> {
        let table = x.into();
        // Densified naive rung — the same contract as every other CSR
        // consumer: under `Backend::Naive` a CSR input densifies and
        // trains the dense path, which is the sparse path's oracle.
        if let (TableRef::Csr(s), Backend::Naive) = (table, ctx.backend()) {
            return self.train(ctx, &s.to_dense(), y01);
        }
        let data = match table {
            TableRef::Dense(d) => TrainData::Dense(d),
            TableRef::Csr(s) => TrainData::Csr(s),
        };
        let n = data.rows();
        crate::validate::non_empty(n, table.cols(), "svm")?;
        crate::validate::labels_match(n, y01.len(), "svm")?;
        crate::validate::positive_finite(self.c, "C", "svm")?;
        crate::validate::positive_finite(self.eps, "eps", "svm")?;
        if let SvmKernel::Rbf { gamma } = self.kernel {
            crate::validate::positive_finite(gamma, "gamma", "svm")?;
        }
        let y: Vec<f64> = y01.iter().map(|&v| if v > 0.5 { 1.0 } else { -1.0 }).collect();
        if !y.iter().any(|&v| v > 0.0) || !y.iter().any(|&v| v < 0.0) {
            return Err(Error::Param("svm: need both classes present".into()));
        }
        crate::parallel::quarantine("svm.train", || {
            // The WSS implementation is the ladder's branch point (Fig. 4).
            let vectorized = !matches!(ctx.backend(), Backend::Naive | Backend::Reference);
            let norms = data.row_norms();
            let diag = self.kernel.diag_from_norms(&norms);
            let threads = ctx.threads();
            let profile = ctx.lane_profile();
            let meter = ctx.budget().meter();
            let mut engine =
                Engine::new(self, data, &norms, &diag, y, vectorized, profile, threads, meter);
            engine.solve();
            // Bias: midpoint of the optimality interval, over the full
            // (post-reconstruction) gradient.
            let ex = simd::extrema_range(profile, &engine.active.grad, &engine.active.flags, 0, n);
            let bias = -(ex.gmin + ex.gmax2) / 2.0;
            // Extract support vectors (densified for CSR training data —
            // the support set is small and inference consumes dense rows).
            let state = &engine.state;
            let sv_idx: Vec<usize> = (0..n).filter(|&t| state.alpha[t] > 1e-12).collect();
            let support_vectors = match table {
                TableRef::Dense(d) => d.gather_rows(&sv_idx),
                TableRef::Csr(s) => s.gather_rows_dense(&sv_idx),
            };
            let dual_coef: Vec<f64> =
                sv_idx.iter().map(|&t| state.alpha[t] * state.y[t]).collect();
            // Pack the support panel once; inference borrows it (and
            // inherits the training profile through the panel).
            let panel = ModelPanel::from_dense_table_profile(&support_vectors, profile, threads);
            Ok(SvcModel {
                support_vectors,
                support_idx: sv_idx,
                dual_coef,
                bias,
                kernel: self.kernel,
                iterations: engine.stats.iterations,
                stats: engine.stats,
                status: engine.status,
                panel,
            })
        })
    }
}

impl SvcModel {
    /// Decision values `f(x) = Σ (α·y)ₛ K(x, sᵥ) + b`, for either query
    /// layout.
    pub fn decision_function<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
    ) -> Result<Vec<f64>> {
        let x = x.into();
        crate::validate::dims_match(self.support_vectors.cols(), x.cols(), "svm")?;
        crate::parallel::quarantine("svm.decision_function", || match x {
            TableRef::Dense(d) => Ok(self.decision_dense(ctx, d)),
            TableRef::Csr(s) => self.decision_csr(ctx, s),
        })
    }

    /// Dense queries: query rows are independent, so they fan out over
    /// the context's worker count (each row is scored whole by one
    /// worker — bit-stable at any count).
    fn decision_dense(&self, ctx: &Context, x: &DenseTable<f64>) -> Vec<f64> {
        let n = x.rows();
        let work = n
            .saturating_mul(self.dual_coef.len())
            .saturating_mul(self.support_vectors.cols().max(1));
        let workers = crate::parallel::effective_threads(ctx.threads(), work, 1 << 14);
        let bounds = crate::parallel::even_bounds(n, workers);
        let mut out = vec![self.bias; n];
        crate::parallel::scope_rows(&mut out, 1, &bounds, |r0, _r1, block| {
            for (r, f) in block.iter_mut().enumerate() {
                let row = x.row(r0 + r);
                for (s, &coef) in self.dual_coef.iter().enumerate() {
                    *f += coef * self.kernel.eval(row, self.support_vectors.row(s));
                }
            }
        });
        out
    }

    /// CSR queries: kernel blocks `K(Q_tile × SV)` against the
    /// model-resident support panel (the transposed view + pooled
    /// norms packed once at `train` time — this path re-transposes and
    /// re-reduces nothing per call) — one threaded CSR multiply per
    /// tile for linear, the shared [`distances::rbf_gram_csr`] (csrmm
    /// + the fused `exp(−γ·d²)` transform) for RBF — then one
    /// dual-coef dot per row. Query rows stream in `tile()`-row tiles
    /// (derived from the panel's lane profile, 256 at the default
    /// sve512) so the kernel-block scratch stays `O(tile·nsv)` whatever
    /// the query count (the dense path streams per row the same way).
    /// Tile boundaries are input-keyed and every stage is bit-identical
    /// at any worker count, so scores are bit-stable across
    /// `Context::threads()` settings.
    fn decision_csr(&self, ctx: &Context, q: &CsrMatrix<f64>) -> Result<Vec<f64>> {
        let m = q.rows();
        let nsv = self.dual_coef.len();
        let mut out = vec![self.bias; m];
        if nsv == 0 || m == 0 {
            return Ok(out);
        }
        let t = ctx.threads();
        let view = self
            .panel
            .csr_corpus()
            .ok_or_else(|| Error::Internal("svm: support panel missing transposed view".into()))?;
        let qn = match self.kernel {
            SvmKernel::Linear => Vec::new(),
            SvmKernel::Rbf { .. } => distances::csr_row_norms(q, t),
        };
        let tile_rows = view.profile().tile();
        let mut cross = vec![0.0f64; tile_rows.min(m) * nsv];
        for (start, len) in batch::tiles(m, tile_rows) {
            let tile = q.slice_rows(start, start + len)?;
            let ctile = &mut cross[..len * nsv];
            match self.kernel {
                SvmKernel::Linear => {
                    let b = view.bt();
                    csrmm_threads(SparseOp::NoTranspose, 1.0, &tile, b, nsv, 0.0, ctile, t)?;
                }
                SvmKernel::Rbf { gamma } => {
                    let wn = &qn[start..start + len];
                    distances::rbf_gram_csr_profile(
                        &tile,
                        wn,
                        view.norms(),
                        view.bt(),
                        gamma,
                        ctile,
                        view.profile(),
                        t,
                    );
                }
            }
            for (i, f) in out[start..start + len].iter_mut().enumerate() {
                *f += dot(&ctile[i * nsv..(i + 1) * nsv], &self.dual_coef);
            }
        }
        Ok(out)
    }

    /// 0/1 class prediction.
    pub fn infer<'a>(&self, ctx: &Context, x: impl Into<TableRef<'a>>) -> Result<Vec<f64>> {
        Ok(self
            .decision_function(ctx, x)?
            .into_iter()
            .map(|f| f64::from(f >= 0.0))
            .collect())
    }

    pub fn n_support(&self) -> usize {
        self.dual_coef.len()
    }

    /// The model-resident packed support panel.
    pub fn panel(&self) -> &ModelPanel {
        &self.panel
    }
}

impl crate::coordinator::serve::ServeModel for SvcModel {
    fn serve_dims(&self) -> usize {
        self.support_vectors.cols()
    }

    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
        // Decision values per row (callers threshold at 0 themselves);
        // `decision_function` is quarantined and pack-free.
        self.decision_function(ctx, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_classification;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    fn task(seed: u32, n: usize, d: usize, sep: f64) -> (DenseTable<f64>, Vec<f64>) {
        let mut e = Mt19937::new(seed);
        make_classification(&mut e, n, d, sep)
    }

    #[test]
    fn boser_separable_high_accuracy() {
        let (x, y) = task(1, 400, 6, 2.0);
        let c = ctx(Backend::Vectorized);
        let m = Svc::params()
            .solver(SvmSolver::Boser)
            .kernel(SvmKernel::Linear)
            .c(1.0)
            .train(&c, &x, &y)
            .unwrap();
        let acc = crate::metrics::accuracy(&m.infer(&c, &x).unwrap(), &y);
        assert!(acc > 0.97, "acc={acc}");
        assert!(m.n_support() > 0);
    }

    #[test]
    fn thunder_separable_high_accuracy() {
        let (x, y) = task(2, 400, 6, 2.0);
        let c = ctx(Backend::Vectorized);
        let m = Svc::params()
            .solver(SvmSolver::Thunder)
            .kernel(SvmKernel::Rbf { gamma: 0.2 })
            .train(&c, &x, &y)
            .unwrap();
        let acc = crate::metrics::accuracy(&m.infer(&c, &x).unwrap(), &y);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn scalar_and_vectorized_wss_same_model() {
        // Fig. 4's fidelity claim at the whole-solver level: identical
        // support sets and bias through either WSS implementation —
        // including identical shrink/unshrink schedules, since those
        // key off bit-identical gradients and flags.
        let (x, y) = task(3, 250, 5, 1.0);
        for solver in [SvmSolver::Boser, SvmSolver::Thunder] {
            let cs = ctx(Backend::Naive); // scalar WSS
            let cv = ctx(Backend::Vectorized); // masked WSS
            let ms = Svc::params().solver(solver).train(&cs, &x, &y).unwrap();
            let mv = Svc::params().solver(solver).train(&cv, &x, &y).unwrap();
            assert_eq!(ms.n_support(), mv.n_support(), "{solver:?}");
            assert!((ms.bias - mv.bias).abs() < 1e-9, "{solver:?}");
            assert_eq!(ms.iterations, mv.iterations, "{solver:?}");
            assert_eq!(ms.stats, mv.stats, "{solver:?}");
            for (a, b) in ms.dual_coef.iter().zip(&mv.dual_coef) {
                assert_eq!(a.to_bits(), b.to_bits(), "{solver:?}");
            }
        }
    }

    #[test]
    fn boser_and_thunder_agree_on_predictions() {
        let (x, y) = task(4, 300, 4, 1.5);
        let c = ctx(Backend::Vectorized);
        let mb = Svc::params().solver(SvmSolver::Boser).train(&c, &x, &y).unwrap();
        let mt = Svc::params().solver(SvmSolver::Thunder).train(&c, &x, &y).unwrap();
        let pb = mb.infer(&c, &x).unwrap();
        let pt = mt.infer(&c, &x).unwrap();
        let agree = pb.iter().zip(&pt).filter(|(a, b)| a == b).count();
        assert!(agree as f64 / 300.0 > 0.97, "agree={agree}");
    }

    #[test]
    fn rbf_solves_xor_like_task() {
        // XOR: linearly inseparable, RBF must handle it.
        let mut data = Vec::new();
        let mut y = Vec::new();
        let mut e = Mt19937::new(5);
        let mut g = crate::rng::Gaussian::<f64>::new(0.0, 0.15);
        use crate::rng::Distribution;
        for _ in 0..50 {
            let corners = [(0.0, 0.0, 0.0), (1.0, 1.0, 0.0), (0.0, 1.0, 1.0), (1.0, 0.0, 1.0)];
            for (cx, cy, label) in corners {
                data.push(cx + g.sample(&mut e));
                data.push(cy + g.sample(&mut e));
                y.push(label);
            }
        }
        let x = DenseTable::from_vec(data, 200, 2).unwrap();
        let c = ctx(Backend::Vectorized);
        let m = Svc::params()
            .kernel(SvmKernel::Rbf { gamma: 2.0 })
            .c(10.0)
            .train(&c, &x, &y)
            .unwrap();
        let acc = crate::metrics::accuracy(&m.infer(&c, &x).unwrap(), &y);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn alpha_box_constraints_hold() {
        let (x, y) = task(6, 200, 4, 0.5); // noisy → bounded SVs
        let c = ctx(Backend::Vectorized);
        let cval = 0.7;
        let m = Svc::params().c(cval).solver(SvmSolver::Boser).train(&c, &x, &y).unwrap();
        for &coef in &m.dual_coef {
            assert!(coef.abs() <= cval + 1e-9, "coef={coef}");
        }
    }

    /// The two models must describe the same decision function: equal
    /// support-vector *sets* once sub-1e-6 coefficients are dropped
    /// (two eps-converged SMO runs may disagree on SVs whose α is below
    /// the tolerance), bias within 1e-6, and coefficient agreement
    /// within `coef_tol` on the shared set (a hair looser than the set
    /// threshold: two different eps-optimal trajectories bound each α
    /// only through the duality gap).
    fn assert_same_decision(m1: &SvcModel, m2: &SvcModel, coef_tol: f64, label: &str) {
        let significant = |m: &SvcModel| -> std::collections::HashMap<usize, f64> {
            m.support_idx
                .iter()
                .zip(&m.dual_coef)
                .filter(|(_, &c)| c.abs() >= 1e-6)
                .map(|(&i, &c)| (i, c))
                .collect()
        };
        let (s1, s2) = (significant(m1), significant(m2));
        assert_eq!(
            {
                let mut k: Vec<_> = s1.keys().copied().collect();
                k.sort_unstable();
                k
            },
            {
                let mut k: Vec<_> = s2.keys().copied().collect();
                k.sort_unstable();
                k
            },
            "{label}: support-vector sets differ"
        );
        for (i, c1) in &s1 {
            let c2 = s2[i];
            assert!((c1 - c2).abs() < coef_tol, "{label}: coef[{i}] {c1} vs {c2}");
        }
        assert!((m1.bias - m2.bias).abs() < 1e-6, "{label}: bias {} vs {}", m1.bias, m2.bias);
    }

    /// Shrinking must not change the learned decision function — same
    /// support-vector set and bias within 1e-6 — while computing
    /// strictly fewer gram entries (the `kernel_entries` counter the
    /// trainer exposes). The fixture constrains the tile cache
    /// (`cache_rows(2)`, 1-byte budget → the 2·ws floor of 16 rows) so
    /// rows are recomputed as training proceeds — the regime where the
    /// gram does not fit the cache, which is exactly where the paper's
    /// shrinking win lives (with an unbounded cache every row is
    /// computed once and shrinking instead wins on the O(active) scan
    /// and update costs). `eps` is tightened so both runs sit well
    /// inside the comparison tolerance of the unique RBF optimum.
    #[test]
    fn shrinking_matches_nonshrinking_with_fewer_kernel_entries() {
        let c = ctx(Backend::Vectorized);
        for (seed, solver) in
            [(7u32, SvmSolver::Boser), (8, SvmSolver::Thunder), (9, SvmSolver::Boser)]
        {
            let (x, y) = task(seed, 250, 4, 1.2);
            let base = Svc::params()
                .solver(solver)
                .kernel(SvmKernel::Rbf { gamma: 0.5 })
                .eps(1e-7)
                .ws_size(8)
                .cache_rows(2)
                .cache_bytes(1)
                .shrink_period(25);
            let m_on = base.clone().shrinking(true).train(&c, &x, &y).unwrap();
            let m_off = base.clone().shrinking(false).train(&c, &x, &y).unwrap();
            assert!(m_on.stats.shrink_events > 0, "{solver:?}: shrinking never engaged");
            assert_eq!(m_off.stats.shrink_events, 0, "{solver:?}");
            assert!(
                m_on.stats.kernel_entries < m_off.stats.kernel_entries,
                "{solver:?}: shrinking computed {} gram entries vs {} without",
                m_on.stats.kernel_entries,
                m_off.stats.kernel_entries
            );
            assert_same_decision(&m_on, &m_off, 5e-6, &format!("{solver:?} seed={seed}"));
        }
    }

    /// CSR training lights up both kernels through the sparse gram
    /// path (linear = threaded CSR multiply, RBF = fused `exp(−γ·d²)`
    /// over the sparse cross term), landing on the densified run's
    /// decision function; sparse training and inference are
    /// bit-identical across worker counts.
    #[test]
    fn csr_training_matches_densified_and_threads() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let (mut xd, y) = task(11, 220, 5, 1.5);
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 2 == 1 {
                *v = 0.0;
            }
        }
        let xs = CsrMatrix::from_dense(&xd, 0.0, IndexBase::One);
        let c = ctx(Backend::Vectorized);
        let mk = |t: usize| {
            Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Vectorized)
                .threads(t)
                .build()
                .unwrap()
        };
        for kernel in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.4 }] {
            let params = Svc::params().kernel(kernel).eps(1e-7).solver(SvmSolver::Thunder);
            let ms = params.train(&c, &xs, &y).unwrap();
            let md = params.train(&c, &xd, &y).unwrap();
            assert_same_decision(&ms, &md, 5e-6, &format!("csr {kernel:?}"));
            // Sparse scoring ≈ dense scoring of the same model.
            let fs = ms.decision_function(&c, &xs).unwrap();
            let fd = ms.decision_function(&c, &xd).unwrap();
            for (a, b) in fs.iter().zip(&fd) {
                assert!((a - b).abs() < 1e-8, "{kernel:?}: {a} vs {b}");
            }
            let acc = crate::metrics::accuracy(&ms.infer(&c, &xs).unwrap(), &y);
            assert!(acc > 0.9, "{kernel:?} acc={acc}");
            // 1–4-worker bit-identity of sparse training + scoring.
            let m1 = params.train(&mk(1), &xs, &y).unwrap();
            let f1 = m1.decision_function(&mk(1), &xs).unwrap();
            for threads in 2..=4 {
                let m = params.train(&mk(threads), &xs, &y).unwrap();
                assert_eq!(m1.support_idx, m.support_idx, "{kernel:?} threads={threads}");
                for (a, b) in m1.dual_coef.iter().zip(&m.dual_coef) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{kernel:?} threads={threads}");
                }
                assert_eq!(m1.bias.to_bits(), m.bias.to_bits(), "{kernel:?} threads={threads}");
                let f = m.decision_function(&mk(threads), &xs).unwrap();
                for (a, b) in f1.iter().zip(&f) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{kernel:?} threads={threads}");
                }
            }
        }
    }

    /// Regression for the unshrink-recheck pass: with a maximally
    /// aggressive schedule (shrink every iteration) the active set
    /// collapses early and the solver *would* declare convergence on
    /// the shrunk subset; the recheck must reconstruct, reactivate and
    /// keep training until the full-set certificate holds — landing on
    /// the same decision function as the non-shrinking run.
    #[test]
    fn aggressive_shrinking_is_corrected_by_unshrink_recheck() {
        let c = ctx(Backend::Vectorized);
        for solver in [SvmSolver::Boser, SvmSolver::Thunder] {
            let (x, y) = task(10, 250, 4, 0.8);
            let base =
                Svc::params().solver(solver).kernel(SvmKernel::Rbf { gamma: 0.5 }).eps(1e-7);
            let m_off = base.clone().shrinking(false).train(&c, &x, &y).unwrap();
            let m_on = base.clone().shrinking(true).shrink_period(1).train(&c, &x, &y).unwrap();
            assert!(m_on.stats.shrink_events > 0, "{solver:?}");
            assert!(
                m_on.stats.unshrink_events > 0,
                "{solver:?}: aggressive shrinking never triggered the recheck"
            );
            assert_same_decision(&m_on, &m_off, 5e-6, &format!("{solver:?} aggressive"));
        }
    }

    /// The quickselect-based Thunder working-set selection must pick
    /// exactly the block the PR 3 full-sort implementation picked —
    /// same indices in the same order — across random gradients (with
    /// forced ties), random flag mixes and working-set sizes, including
    /// q larger than either side.
    #[test]
    fn working_set_selection_matches_sort_oracle() {
        use crate::rng::{Distribution, Gaussian, Uniform};
        let sort_oracle = |grad: &[f64], flags: &[u8], q: usize| -> Vec<usize> {
            let na = grad.len();
            let mut ups: Vec<usize> = (0..na).filter(|&l| flags[l] & UP != 0).collect();
            ups.sort_by(|&a, &b| grad[a].total_cmp(&grad[b]));
            let mut lows: Vec<usize> = (0..na).filter(|&l| flags[l] & LOW != 0).collect();
            lows.sort_by(|&a, &b| grad[b].total_cmp(&grad[a]));
            let mut ws: Vec<usize> = Vec::with_capacity(q);
            let (mut iu, mut il) = (0usize, 0usize);
            while ws.len() < q && (iu < ups.len() || il < lows.len()) {
                if iu < ups.len() {
                    let c = ups[iu];
                    iu += 1;
                    if !ws.contains(&c) {
                        ws.push(c);
                    }
                }
                if ws.len() < q && il < lows.len() {
                    let c = lows[il];
                    il += 1;
                    if !ws.contains(&c) {
                        ws.push(c);
                    }
                }
            }
            ws
        };
        let mut e = Mt19937::new(77);
        let mut g = Gaussian::<f64>::standard();
        let mut u = Uniform::new(0.0, 1.0);
        for trial in 0..30u32 {
            let na = 3 + (u.sample(&mut e) * 500.0) as usize;
            // Quantized gradients force index tie-breaks through the
            // quickselect; mixed flags give free points in both sides.
            let grad: Vec<f64> =
                (0..na).map(|_| (g.sample(&mut e) * 8.0).round() / 8.0).collect();
            let flags: Vec<u8> = (0..na)
                .map(|_| {
                    let mut f = 0u8;
                    if u.sample(&mut e) < 0.6 {
                        f |= UP;
                    }
                    if u.sample(&mut e) < 0.6 {
                        f |= LOW;
                    }
                    f
                })
                .collect();
            for q in [2usize, 4, 8, 64, na, 2 * na] {
                assert_eq!(
                    select_working_set(&grad, &flags, q),
                    sort_oracle(&grad, &flags, q),
                    "trial={trial} na={na} q={q}"
                );
            }
        }
    }

    #[test]
    fn validation_errors() {
        let c = ctx(Backend::Vectorized);
        let x = DenseTable::<f64>::zeros(4, 2);
        assert!(Svc::params().train(&c, &x, &[0.0, 0.0, 0.0, 0.0]).is_err()); // one class
        assert!(Svc::params().c(0.0).train(&c, &x, &[0.0, 1.0, 0.0, 1.0]).is_err());
        assert!(Svc::params().train(&c, &x, &[0.0, 1.0]).is_err());
    }
}
