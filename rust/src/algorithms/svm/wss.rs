//! WSS3 working-set selection (§IV-E).
//!
//! The solver maintains `grad[t] = y_t·G_t` (the label-signed dual
//! gradient — with it the i/j optimality conditions and the gradient
//! update are label-free) and per-point membership flags. The `WSSj`
//! selection of the paper's Listing 1/2 picks the second index of the
//! violating pair by maximizing the second-order objective `b²/a`.
//!
//! Two implementations with **identical results** (the paper validated
//! its SVE loop bitwise against the scalar one):
//!
//! * [`wss_j_scalar`] — the branchy Listing 1 loop: two flag guards and
//!   a threshold guard, each a `continue` that defeats compiler
//!   auto-vectorization;
//! * [`wss_j_vectorized`] — Listing 2 restructured for masked lanes:
//!   const-generic `L`-wide blocks (instantiated at the active
//!   [`crate::primitives::lanes::LaneProfile`]'s `wss_lanes()` width
//!   by the dispatch layer), every condition evaluated as a lane mask
//!   (the Pallas/SVE predicate analogue), arithmetic executed
//!   unconditionally on all lanes with neutral values (−∞) for dead
//!   lanes, then a block-local reduction with first-index tie-breaking
//!   to preserve the scalar loop's semantics exactly — at every `L`.

use std::cmp::Ordering;

/// Flag bits (the paper's `I[]` array).
pub const SIGN_POS: u8 = 0b0001;
/// Negative-class sign bit.
pub const SIGN_NEG: u8 = 0b0010;
/// Membership in the "up" set `I_up`.
pub const UP: u8 = 0b0100;
/// Membership in the "low" set `I_low`.
pub const LOW: u8 = 0b1000;
/// `sign` mask accepting both classes (the solver selects per-class
/// subsets only during shrinking, which oneDAL enables separately).
pub const SIGN_ANY: u8 = SIGN_POS | SIGN_NEG;

/// Result of a `WSSj` scan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WssJResult {
    /// Selected second index (`Bj`), or `None` when no candidate passed.
    pub bj: Option<usize>,
    /// Best second-order objective value (`GMax`).
    pub obj: f64,
    /// `GMax2`: max gradient over the low set — the stopping-gap term.
    pub gmax2: f64,
    /// Unclipped step `delta = −b/a` for the selected pair.
    pub delta: f64,
}

/// First-index selection (`WSSi`): the most violating index in `I_up`,
/// i.e. argmin of the signed gradient. Returns `(Bi, GMin)`.
pub fn wss_i(grad: &[f64], flags: &[u8]) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for (t, (&g, &fl)) in grad.iter().zip(flags).enumerate() {
        if fl & UP == 0 {
            continue;
        }
        if best.map(|(_, bg)| g < bg).unwrap_or(true) {
            best = Some((t, g));
        }
    }
    best
}

/// Paper Listing 1: the scalar branchy `WSSj` loop, verbatim semantics.
///
/// * `grad`        — signed gradient, full length;
/// * `flags`       — `I[]` bit array, full length;
/// * `sign`/`low`  — the two guard masks of the listing;
/// * `gmin`        — `GMin` from [`wss_i`] (= −Gmax);
/// * `kii`         — `K(i, i)`;
/// * `kernel_diag` — `K(j, j)` for all j, full length;
/// * `ki_block`    — plain kernel row `K(i, j)` (the curvature along the
///   feasible direction (αᵢ += yᵢτ, αⱼ −= yⱼτ) is `Kii + Kjj − 2Kij`) for
///   `j ∈ [j_start, j_end)`, indexed `j − j_start` (the `KiBlock` of
///   the listing);
/// * `tau`         — denominator guard.
#[allow(clippy::too_many_arguments)]
pub fn wss_j_scalar(
    grad: &[f64],
    flags: &[u8],
    sign: u8,
    low: u8,
    gmin: f64,
    kii: f64,
    kernel_diag: &[f64],
    ki_block: &[f64],
    j_start: usize,
    j_end: usize,
    tau: f64,
) -> WssJResult {
    let two = 2.0f64;
    let zero = 0.0f64;
    let mut gmax = f64::NEG_INFINITY;
    let mut gmax2 = f64::NEG_INFINITY;
    let mut bj: Option<usize> = None;
    let mut delta = 0.0f64;
    for j in j_start..j_end {
        let gradj = grad[j];
        if flags[j] & sign == 0 {
            continue;
        }
        if (flags[j] & low) != low {
            continue;
        }
        if gradj > gmax2 {
            gmax2 = gradj;
        }
        if gradj < gmin {
            continue;
        }
        let b = gmin - gradj;
        let mut a = kii + kernel_diag[j] - two * ki_block[j - j_start];
        if a <= zero {
            a = tau;
        }
        let dt = b / a;
        let obj_func = b * dt;
        if obj_func > gmax {
            gmax = obj_func;
            bj = Some(j);
            delta = -dt;
        }
    }
    WssJResult { bj, obj: gmax, gmax2, delta }
}

/// Deterministic in-place partial selection: keep the `h` smallest
/// elements of `items` under the **total** order `cmp`, sorted
/// ascending, and drop the rest. The Thunder working-set selection
/// calls this with `(gradient, index)` lexicographic orders — ties
/// always break to the lower (global) index — so the selection is
/// deterministic while replacing the solver's full `O(na·log na)`
/// UP/LOW sorts with an expected `O(na + h·log h)` quickselect.
///
/// `cmp` must be a total order with no equal pairs (the index
/// tie-break guarantees this for finite keys), so the Lomuto partition
/// below cannot degenerate on duplicate keys, and the pivot walk —
/// median-of-three probes at fixed positions — is fully deterministic:
/// the same input always yields the same comparison sequence and the
/// same result as sort-then-truncate.
pub fn partial_select_by<F>(items: &mut Vec<usize>, h: usize, cmp: F)
where
    F: Fn(usize, usize) -> Ordering,
{
    if h == 0 {
        items.clear();
        return;
    }
    if h < items.len() {
        // Quickselect: shrink the unresolved range [lo, hi) around the
        // selection boundary `h` until every element left of `h` is one
        // of the `h` smallest.
        let (mut lo, mut hi) = (0usize, items.len());
        while hi - lo > 1 {
            // Median-of-three pivot from fixed probe positions.
            let mid = lo + (hi - lo) / 2;
            if cmp(items[mid], items[lo]) == Ordering::Less {
                items.swap(mid, lo);
            }
            if cmp(items[hi - 1], items[lo]) == Ordering::Less {
                items.swap(hi - 1, lo);
            }
            if cmp(items[hi - 1], items[mid]) == Ordering::Less {
                items.swap(hi - 1, mid);
            }
            items.swap(mid, hi - 1);
            let pivot = items[hi - 1];
            let mut store = lo;
            for i in lo..hi - 1 {
                if cmp(items[i], pivot) == Ordering::Less {
                    items.swap(i, store);
                    store += 1;
                }
            }
            items.swap(store, hi - 1);
            match store.cmp(&h) {
                Ordering::Less => lo = store + 1,
                Ordering::Greater => hi = store,
                Ordering::Equal => break,
            }
        }
        items.truncate(h);
    }
    items.sort_unstable_by(|&a, &b| cmp(a, b));
}

// The scan width is no longer a module constant: `L` is a const
// generic, bound by the dispatch layer to the active profile's
// `wss_lanes()` (two vectors of autovectorizer headroom per profile —
// see `crate::primitives::lanes`).

/// Paper Listing 2: branch-free masked `WSSj`, `L` lanes per block.
///
/// All guards become one boolean mask per lane; arithmetic runs on every
/// lane with dead lanes forced to the neutral element; the final
/// reduction scans each block in index order so ties resolve exactly as
/// in the scalar loop (strict `>` keeps the earliest maximizer) — the
/// result is therefore independent of `L`.
#[allow(clippy::too_many_arguments)]
pub fn wss_j_vectorized<const L: usize>(
    grad: &[f64],
    flags: &[u8],
    sign: u8,
    low: u8,
    gmin: f64,
    kii: f64,
    kernel_diag: &[f64],
    ki_block: &[f64],
    j_start: usize,
    j_end: usize,
    tau: f64,
) -> WssJResult {
    let mut gmax = f64::NEG_INFINITY;
    let mut gmax2 = f64::NEG_INFINITY;
    let mut bj: Option<usize> = None;
    let mut delta = 0.0f64;

    let mut obj_lane = [f64::NEG_INFINITY; L];
    let mut dt_lane = [0.0f64; L];

    let mut base = j_start;
    while base < j_end {
        let len = L.min(j_end - base);
        // --- predicated block body (every lane, no branches) ---
        let mut block_gmax2 = f64::NEG_INFINITY;
        for l in 0..len {
            let j = base + l;
            let gradj = grad[j];
            let fl = flags[j];
            // svwhilelt is implicit in `len`; the two guards fuse into
            // one predicate exactly as Listing 2's svand/svcmpeq pair.
            let pass = (fl & sign != 0) & ((fl & low) == low);
            // GMax2 update counts every `pass` lane (pre-threshold).
            let g2 = if pass { gradj } else { f64::NEG_INFINITY };
            block_gmax2 = if g2 > block_gmax2 { g2 } else { block_gmax2 };
            // Threshold predicate folds in: lanes below GMin go neutral.
            let active = pass & (gradj >= gmin);
            let b = gmin - gradj;
            let a_raw = kii + kernel_diag[j] - 2.0 * ki_block[j - j_start];
            let a = if a_raw <= 0.0 { tau } else { a_raw };
            let dt = b / a;
            let obj = b * dt;
            obj_lane[l] = if active { obj } else { f64::NEG_INFINITY };
            dt_lane[l] = dt;
        }
        gmax2 = gmax2.max(block_gmax2);
        // --- block reduction, index order preserves scalar tie-breaks ---
        for l in 0..len {
            if obj_lane[l] > gmax {
                gmax = obj_lane[l];
                bj = Some(base + l);
                delta = -dt_lane[l];
            }
        }
        base += len;
    }
    WssJResult { bj, obj: gmax, gmax2, delta }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::lanes::LaneProfile;
    use crate::rng::{Distribution, Engine, Gaussian, Mt19937, Uniform};

    /// The widest profile's scan width — the pre-profile default.
    const WL: usize = LaneProfile::Sve512.wss_lanes();

    /// Random-but-valid WSS inputs.
    fn random_case(seed: u32, n: usize) -> (Vec<f64>, Vec<u8>, f64, f64, Vec<f64>, Vec<f64>) {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::<f64>::standard();
        let mut u = Uniform::new(0.0, 1.0);
        let grad: Vec<f64> = (0..n).map(|_| g.sample(&mut e)).collect();
        let flags: Vec<u8> = (0..n)
            .map(|_| {
                let mut f = if u.sample(&mut e) < 0.5 { SIGN_POS } else { SIGN_NEG };
                if u.sample(&mut e) < 0.7 {
                    f |= LOW;
                }
                if u.sample(&mut e) < 0.7 {
                    f |= UP;
                }
                f
            })
            .collect();
        let gmin = g.sample(&mut e);
        let kii = 1.0 + u.sample(&mut e);
        let diag: Vec<f64> = (0..n).map(|_| 1.0 + u.sample(&mut e)).collect();
        let ki: Vec<f64> = (0..n).map(|_| g.sample(&mut e) * 0.5).collect();
        (grad, flags, gmin, kii, diag, ki)
    }

    #[test]
    fn vectorized_matches_scalar_bitwise() {
        // The paper's key validation claim: the SVE loop is bitwise
        // identical to the scalar one — at every profile's scan width.
        // Sweep sizes covering full blocks, ragged tails and sub-block
        // inputs.
        let cases = [(1u32, 1usize), (2, 7), (3, 16), (4, 17), (5, 100), (6, 1024), (7, 1023)];
        for profile in LaneProfile::ALL {
            for (seed, n) in cases {
                let (grad, flags, gmin, kii, diag, ki) = random_case(seed, n);
                let s =
                    wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, gmin, kii, &diag, &ki, 0, n, 1e-12);
                let v = crate::with_lane_count!(profile, L, {
                    wss_j_vectorized::<{ 2 * L }>(
                        &grad, &flags, SIGN_ANY, LOW, gmin, kii, &diag, &ki, 0, n, 1e-12,
                    )
                });
                assert_eq!(s.bj, v.bj, "{} n={n}", profile.name());
                assert_eq!(s.obj.to_bits(), v.obj.to_bits(), "{} n={n}", profile.name());
                assert_eq!(s.gmax2.to_bits(), v.gmax2.to_bits(), "{} n={n}", profile.name());
                assert_eq!(s.delta.to_bits(), v.delta.to_bits(), "{} n={n}", profile.name());
            }
        }
    }

    #[test]
    fn subrange_scan_matches() {
        let (grad, flags, gmin, kii, diag, ki) = random_case(8, 200);
        // KiBlock indexed from j_start.
        let (j0, j1) = (37, 161);
        let kb = &ki[j0..j1];
        let s = wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, gmin, kii, &diag, kb, j0, j1, 1e-12);
        let v =
            wss_j_vectorized::<WL>(&grad, &flags, SIGN_ANY, LOW, gmin, kii, &diag, kb, j0, j1, 1e-12);
        assert_eq!(s, v);
        if let Some(bj) = s.bj {
            assert!((j0..j1).contains(&bj));
        }
    }

    #[test]
    fn respects_low_mask() {
        let grad = vec![5.0, 10.0, 3.0];
        // Only index 2 is in the low set.
        let flags = vec![SIGN_POS | UP, SIGN_POS | UP, SIGN_POS | LOW];
        let diag = vec![1.0; 3];
        let ki = vec![0.0; 3];
        let r = wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, 0.0, 1.0, &diag, &ki, 0, 3, 1e-12);
        assert_eq!(r.bj, Some(2));
        assert_eq!(r.gmax2, 3.0);
    }

    #[test]
    fn below_gmin_updates_gmax2_but_not_bj() {
        let grad = vec![-1.0, -2.0];
        let flags = vec![SIGN_POS | LOW, SIGN_NEG | LOW];
        let diag = vec![1.0; 2];
        let ki = vec![0.0; 2];
        let r = wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, 0.5, 1.0, &diag, &ki, 0, 2, 1e-12);
        assert_eq!(r.bj, None);
        assert_eq!(r.gmax2, -1.0);
        assert_eq!(r.obj, f64::NEG_INFINITY);
    }

    #[test]
    fn denominator_guard_uses_tau() {
        // a = kii + diag − 2·ki = 1 + 1 − 2·1 = 0 → guarded to tau.
        let grad = vec![2.0];
        let flags = vec![SIGN_POS | LOW];
        let r = wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, 0.0, 1.0, &[1.0], &[1.0], 0, 1, 0.5);
        // b = −2, a = 0.5 → dt = −4, obj = 8, delta = 4.
        assert_eq!(r.bj, Some(0));
        assert!((r.obj - 8.0).abs() < 1e-12);
        assert!((r.delta - 4.0).abs() < 1e-12);
    }

    #[test]
    fn tie_breaks_to_first_index() {
        // Two identical candidates: scalar keeps the first (strict >).
        let grad = vec![1.0, 1.0];
        let flags = vec![SIGN_POS | LOW; 2];
        let diag = vec![2.0; 2];
        let ki = vec![0.0; 2];
        let s = wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, 0.0, 1.0, &diag, &ki, 0, 2, 1e-12);
        let v =
            wss_j_vectorized::<WL>(&grad, &flags, SIGN_ANY, LOW, 0.0, 1.0, &diag, &ki, 0, 2, 1e-12);
        assert_eq!(s.bj, Some(0));
        assert_eq!(v.bj, Some(0));
    }

    #[test]
    fn wss_i_picks_min_over_up() {
        let grad = vec![3.0, -1.0, -5.0, 0.0];
        let flags = vec![UP, UP, 0, UP];
        let (bi, gmin) = wss_i(&grad, &flags).unwrap();
        assert_eq!(bi, 1); // index 2 is not in UP
        assert_eq!(gmin, -1.0);
        assert!(wss_i(&grad, &[0; 4]).is_none());
    }

    /// `partial_select_by` must equal sort-then-truncate for every `h`,
    /// including heavy ties (quantized keys), `h = 0`, and `h ≥ len` —
    /// the Thunder selection's oracle at the primitive level.
    #[test]
    fn partial_select_matches_sort_truncate() {
        let mut meta = Mt19937::new(4242);
        let mut g = Gaussian::<f64>::standard();
        for trial in 0..40u32 {
            let n = 1 + (meta.next_u32() % 400) as usize;
            // Quantize to force many equal keys → index tie-breaks.
            let keys: Vec<f64> =
                (0..n).map(|_| (g.sample(&mut meta) * 3.0).round() / 3.0).collect();
            let cmp = |a: usize, b: usize| keys[a].total_cmp(&keys[b]).then(a.cmp(&b));
            let mut sorted: Vec<usize> = (0..n).collect();
            sorted.sort_by(|&a, &b| cmp(a, b));
            for h in [0usize, 1, 2, n / 3, n / 2, n.saturating_sub(1), n, n + 5] {
                let mut got: Vec<usize> = (0..n).collect();
                partial_select_by(&mut got, h, cmp);
                let want: Vec<usize> = sorted.iter().copied().take(h).collect();
                assert_eq!(got, want, "trial={trial} n={n} h={h}");
            }
        }
    }

    /// Descending-key selection (the LOW side's order) with ties.
    #[test]
    fn partial_select_descending_with_ties() {
        let keys = [1.0f64, 3.0, 3.0, 0.5, 3.0, 2.0];
        let cmp = |a: usize, b: usize| keys[b].total_cmp(&keys[a]).then(a.cmp(&b));
        let mut items: Vec<usize> = (0..keys.len()).collect();
        partial_select_by(&mut items, 4, cmp);
        // Largest first; equal keys in ascending index order.
        assert_eq!(items, vec![1, 2, 4, 5]);
    }

    /// Property sweep across many random shapes — the hypothesis-style
    /// invariant test for the bitwise-equality claim.
    #[test]
    fn property_bitwise_equality_sweep() {
        let mut meta = Mt19937::new(999);
        for trial in 0..50u32 {
            let n = 1 + (meta.next_u32() % 600) as usize;
            let (grad, flags, gmin, kii, diag, ki) = random_case(1000 + trial, n);
            let s = wss_j_scalar(&grad, &flags, SIGN_ANY, LOW, gmin, kii, &diag, &ki, 0, n, 1e-12);
            let v = wss_j_vectorized::<WL>(
                &grad, &flags, SIGN_ANY, LOW, gmin, kii, &diag, &ki, 0, n, 1e-12,
            );
            assert_eq!(s, v, "trial={trial} n={n}");
        }
    }
}
