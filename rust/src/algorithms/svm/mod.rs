//! Support Vector Machine classification — the paper's flagship
//! optimization target (§IV-E, Fig. 4: +22 % Boser / +5 % Thunder from
//! the SVE-predicated `WSSj`, and Fig. 5's headline 134×/217× over stock
//! sklearn on a9a/gisette-shaped data).
//!
//! Structure:
//! * [`kernel`] — linear / RBF kernels, gram-row and blocked gram-*tile*
//!   computation, and the caches: the legacy per-row [`kernel::RowCache`]
//!   (ablation baseline) and the [`kernel::TileCache`] the solver
//!   trains on;
//! * [`wss`]    — the WSS3 working-set selection listings: `wss_j_scalar`
//!   is the paper's branchy Listing 1, `wss_j_vectorized` its Listing-2
//!   masked restructure (kept as the Fig. 4 microbenchmark kernels),
//!   plus `partial_select_by`, the deterministic quickselect the
//!   Thunder block selection ranks its UP/LOW candidates with (ties
//!   broken by index; replaces the full per-block sorts);
//! * [`simd`]   — the predicated hot loops the solver actually runs:
//!   branch-free fused extrema / `WSSj` scans and gradient updates,
//!   monomorphized per lane profile (128/256/512-bit ⇒ 2/4/8 f64
//!   lanes, [`crate::primitives::lanes`]) and parallelized with
//!   fixed-order reductions;
//! * [`solver`] — the SMO dual solver: **Boser** and **Thunder**, both
//!   on the shrinking active-set engine.
//!
//! ## Shrinking schedule
//!
//! Every `shrink_period` inner iterations (default `min(n, 1000)`
//! floored at 8 — the LIBSVM schedule with a small-problem guard;
//! [`SvmParams::shrink_period`] overrides) the solver
//! drops *bound-pinned non-violators* from the active set: points out
//! of `I_up` with gradient strictly below the current `GMin`, or out of
//! `I_low` with gradient strictly above `GMax2`. Free points are never
//! shrunk. All WSS scans, gradient updates and gram tiles then run over
//! the compacted set, so per-iteration cost falls as training converges
//! — the Boser-method win. Any convergence certificate obtained on a
//! shrunk set triggers the **unshrink-and-recheck** pass: shrunk
//! gradients are reconstructed from the support vectors with one
//! `K(inactive × SV)` tile, the full set is reactivated, and training
//! continues until the certificate holds on all n points.
//!
//! ## Tile cache sizing
//!
//! Gram rows are cached over the *active* columns and computed in
//! working-set blocks — one packed-panel GEMM per block against the
//! active rows packed once per shrink generation
//! ([`crate::blas::pack_b_panels`]); the RBF distance expansion and
//! transform run fused on the shared engine
//! ([`crate::primitives::distances::rbf_gram`]). Capacity is
//! `cache_bytes / (8·active_len)` rows (oneDAL's `cacheSizeInBytes`,
//! default 8 MB), floored by the legacy `cache_rows` knob and by two
//! working sets; shrink events narrow the cached rows in place
//! ([`kernel::TileCache::compact`]), so the same byte budget holds more
//! rows late in training instead of flushing.
//!
//! ## Predication idiom
//!
//! The scans in [`simd`] mirror SVE predicate-driven execution in
//! portable Rust: every guard becomes a lane mask, dead lanes carry the
//! neutral element (±∞) via select instead of a branch, blocks are
//! lane-unrolled at the [`crate::primitives::lanes::LaneProfile`] the
//! owning `Context` resolved (2/4/8 f64 lanes for a 128/256/512-bit
//! vector — the paper's vector-length-agnostic loop, dispatched once
//! per call through [`crate::with_lane_count!`]), and block-local
//! reductions run in index order so tie-breaks match the scalar
//! listings bit for bit. Parallel fan-outs merge partials in ascending
//! partition order; because min/max/argmin carry no floating-point
//! accumulation, the merged result is bit-identical at any worker
//! count — and at any lane width, which is what makes the selected
//! pairs (and therefore whole training runs) profile-invariant.
//!
//! ## Sparse inputs
//!
//! Training and inference also accept `&CsrMatrix<f64>`
//! ([`crate::tables::TableRef`]): the shrinking engine packs the
//! active panel as a densified-transposed buffer instead of GEMM
//! micro-panels and computes gram blocks with
//! [`kernel::SvmKernel::gram_tile_csr`] (threaded CSR multiply + the
//! same fused RBF transform); everything else — shrink schedule, tile
//! cache, WSS — is layout-blind. The Thunder working-set quickselect
//! ranks under the IEEE `total_cmp` total order, so NaN gradients
//! degrade deterministically instead of panicking.
//!
//! [`SvmParams::shrink_period`]: solver::SvmParams::shrink_period

pub mod kernel;
pub mod simd;
pub mod solver;
pub mod wss;

pub use kernel::SvmKernel;
pub use solver::{Svc, SvcModel, SvmParams, SvmSolver, TrainStats};
