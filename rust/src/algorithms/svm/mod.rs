//! Support Vector Machine classification — the paper's flagship
//! optimization target (§IV-E, Fig. 4: +22 % Boser / +5 % Thunder from
//! the SVE-predicated `WSSj`, and Fig. 5's headline 134×/217× over stock
//! sklearn on a9a/gisette-shaped data).
//!
//! Structure:
//! * [`kernel`] — linear / RBF kernel functions + gram-row computation
//!   and the Thunder row cache;
//! * [`wss`]    — the WSS3 working-set selection: `wss_j_scalar` is the
//!   paper's Listing 1 (branchy, blocks auto-vectorization), and
//!   `wss_j_vectorized` is Listing 2 rebuilt as branch-free masked
//!   blocks (the SVE-predicate → mask mapping of DESIGN.md §3);
//! * [`solver`] — the SMO dual solver with the paper's two training
//!   methods: **Boser** (classic 2-index SMO, WSS every iteration) and
//!   **Thunder** (working-set batches solved on cached kernel rows).

pub mod kernel;
pub mod solver;
pub mod wss;

pub use kernel::SvmKernel;
pub use solver::{Svc, SvcModel, SvmParams, SvmSolver};
