//! SVM kernel functions, gram-row/tile computation and the caches the
//! solver amortizes kernel evaluation with: the legacy per-row LRU
//! [`RowCache`] (kept as the ablation baseline) and the blocked
//! [`TileCache`] the shrinking solver trains on — rows over the
//! *compacted active set*, computed in whole working-set blocks by one
//! packed GEMM call and compacted in place when the active set shrinks.

use crate::blas::{dot, gemm_prepacked_threads, gemv_threads, sqdist, PackedB, Transpose};
use crate::primitives::distances;
use crate::primitives::lanes::LaneProfile;
use crate::sparse::{csrmm_threads, CsrMatrix, SparseOp};
use crate::tables::DenseTable;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// Kernel function.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SvmKernel {
    Linear,
    /// `exp(−γ‖x−y‖²)`.
    Rbf { gamma: f64 },
}

impl SvmKernel {
    /// k(x, y) for two rows.
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        match *self {
            SvmKernel::Linear => dot(x, y),
            SvmKernel::Rbf { gamma } => (-gamma * sqdist(x, y)).exp(),
        }
    }

    /// Full gram row `K(i, ·)` against every training row, written into
    /// `out` (length n), on the process-default worker count.
    pub fn gram_row(&self, x: &DenseTable<f64>, i: usize, norms: &[f64], out: &mut [f64]) {
        self.gram_row_threads(x, i, norms, out, crate::parallel::default_threads());
    }

    /// [`SvmKernel::gram_row`] with an explicit worker count: the n
    /// output entries are independent dot products against row i, so
    /// workers each own a contiguous slice of `out` and run the gemv
    /// cross term (plus the RBF transform) on their row block. Every
    /// entry is computed whole by one worker — bit-identical at any
    /// worker count, which the solver's scalar-vs-vectorized fidelity
    /// tests rely on.
    pub fn gram_row_threads(
        &self,
        x: &DenseTable<f64>,
        i: usize,
        norms: &[f64],
        out: &mut [f64],
        threads: usize,
    ) {
        let n = x.rows();
        let d = x.cols();
        debug_assert_eq!(out.len(), n);
        let workers = crate::parallel::effective_threads(threads, n.saturating_mul(d), 1 << 14);
        let bounds = crate::parallel::even_bounds(n, workers);
        let xi = x.row(i);
        let kernel = *self;
        crate::parallel::scope_rows(out, 1, &bounds, |r0, r1, block| {
            let rows = r1 - r0;
            let ablock = &x.data()[r0 * d..r1 * d];
            // Inner gemv stays single-threaded: the fan-out already
            // happened one level up (nesting pool batches here would
            // only add scheduling overhead).
            match kernel {
                SvmKernel::Linear => {
                    gemv_threads(false, rows, d, 1.0, ablock, xi, 0.0, block, 1);
                }
                SvmKernel::Rbf { gamma } => {
                    // ‖xi−xj‖² = ‖xi‖² + ‖xj‖² − 2 xi·xj, cross term via gemv.
                    gemv_threads(false, rows, d, 1.0, ablock, xi, 0.0, block, 1);
                    let ni = norms[i];
                    for (j, v) in block.iter_mut().enumerate() {
                        let d2 = (ni + norms[r0 + j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            }
        });
    }

    /// Diagonal `K(i, i)` values from the squared row norms alone —
    /// the layout-blind entry the solver uses (norms carry everything
    /// either kernel needs).
    pub fn diag_from_norms(&self, norms: &[f64]) -> Vec<f64> {
        match *self {
            SvmKernel::Linear => norms.to_vec(),
            SvmKernel::Rbf { .. } => vec![1.0; norms.len()],
        }
    }

    /// Diagonal `K(i, i)` values for all rows of a dense table.
    pub fn diag(&self, x: &DenseTable<f64>, norms: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.rows(), norms.len());
        self.diag_from_norms(norms)
    }

    /// Blocked gram tile `K(W, P)` (`ws × na`) as one prepacked-GEMM
    /// block — the oneDAL `KiBlock` computed as a block instead of row
    /// by row. `w` holds the gathered working-set rows (`ws × d`,
    /// row-major), `pb` the pre-packed active-set panel (`op(B) = Pᵀ`
    /// from [`crate::blas::pack_b_panels`], packed once per shrink
    /// generation), `w_norms`/`p_norms` the squared row norms of each
    /// side for the RBF distance expansion.
    ///
    /// The RBF path delegates to the shared fused distance engine
    /// ([`crate::primitives::distances::rbf_gram`]): workers own
    /// MR-aligned row ranges, each computing its cross-term slice with
    /// one prepacked GEMM and applying the `exp(−γ·d²)` transform while
    /// the slice is cache-hot. The linear path is the prepacked GEMM
    /// alone. Both are bit-identical at any worker count — and
    /// independent of how the rows are batched into tiles, because each
    /// output element is one dot product plus an elementwise transform.
    /// The lane profile flows from `pb` (the panel carries the width it
    /// was packed at), so no separate profile argument is needed.
    pub fn gram_tile(
        &self,
        w: &[f64],
        w_norms: &[f64],
        p_norms: &[f64],
        pb: &PackedB<f64>,
        out: &mut [f64],
        threads: usize,
    ) {
        let ws = w_norms.len();
        let na = pb.n();
        debug_assert_eq!(w.len(), ws * pb.k());
        debug_assert_eq!(p_norms.len(), na);
        debug_assert_eq!(out.len(), ws * na);
        match *self {
            SvmKernel::Linear => {
                gemm_prepacked_threads(Transpose::No, ws, 1.0, w, pb, 0.0, out, threads);
            }
            SvmKernel::Rbf { gamma } => {
                distances::rbf_gram(w, w_norms, p_norms, pb, gamma, out, threads);
            }
        }
    }

    /// [`SvmKernel::gram_tile`] for a **sparse** working set: `w` holds
    /// the gathered working-set rows as a CSR matrix, `bt` the active
    /// panel densified-transposed (`d × na` row-major, packed once per
    /// shrink generation — the sparse analogue of the prepacked
    /// micro-panels). Linear is one threaded CSR multiply; RBF runs the
    /// fused `exp(−γ·d²)` transform of
    /// [`crate::primitives::distances::rbf_gram_csr_profile`] at the
    /// caller's lane profile (the densified panel carries no profile of
    /// its own, so the engine routes its `Context`-resolved one). Both
    /// partition whole output rows per worker — bit-identical at any
    /// count, and at any profile (the transform is elementwise).
    #[allow(clippy::too_many_arguments)]
    pub fn gram_tile_csr(
        &self,
        w: &CsrMatrix<f64>,
        w_norms: &[f64],
        p_norms: &[f64],
        bt: &[f64],
        out: &mut [f64],
        profile: LaneProfile,
        threads: usize,
    ) {
        let na = p_norms.len();
        debug_assert_eq!(w_norms.len(), w.rows());
        debug_assert_eq!(bt.len(), w.cols() * na);
        debug_assert_eq!(out.len(), w.rows() * na);
        match *self {
            SvmKernel::Linear => {
                if csrmm_threads(SparseOp::NoTranspose, 1.0, w, bt, na, 0.0, out, threads).is_err()
                {
                    unreachable!("gram_tile_csr: shapes checked by the debug asserts above");
                }
            }
            SvmKernel::Rbf { gamma } => {
                distances::rbf_gram_csr_profile(
                    w, w_norms, p_norms, bt, gamma, out, profile, threads,
                );
            }
        }
    }
}

/// LRU cache of gram rows over the **compacted active set** — the
/// shrinking solver's kernel cache. Differences from [`RowCache`]:
///
/// * rows are `na` wide (the current active-set size), not `n`, so the
///   same byte budget holds more rows as training shrinks;
/// * capacity is sized from **bytes** (oneDAL's `cacheSizeInBytes`)
///   by the solver, not from a fixed row count;
/// * misses are computed in **blocks**: one [`SvmKernel::gram_tile`]
///   call per fetch covers every missing row of a working set;
/// * [`TileCache::compact`] drops shrunk-out *columns* from every
///   cached row in place, so a shrink event keeps the cache warm
///   instead of flushing it.
///
/// The row store is a `BTreeMap`, not a `HashMap` (PAL-HASH,
/// docs/INVARIANTS.md): [`TileCache::compact`] and
/// [`TileCache::purge_missing`] *traverse* the store, and sorted-key
/// traversal keeps those sweeps — and any future one that accumulates
/// across rows — deterministic regardless of insertion history.
pub struct TileCache {
    capacity: usize,
    width: usize,
    rows: BTreeMap<usize, Arc<Vec<f64>>>,
    order: VecDeque<usize>,
    pub hits: u64,
    pub misses: u64,
}

impl TileCache {
    /// `capacity` rows of `width` entries (both clamped to ≥ 2/≥ 0 by
    /// the caller's sizing rule).
    pub fn new(capacity: usize, width: usize) -> Self {
        Self {
            capacity: capacity.max(2),
            width,
            rows: BTreeMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Current row width (= active-set size the rows were computed at).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Re-size the row budget (called after shrink events: the same
    /// byte budget buys more, narrower rows).
    pub fn set_capacity(&mut self, capacity: usize) {
        self.capacity = capacity.max(2);
        while self.rows.len() > self.capacity {
            if let Some(evict) = self.order.pop_front() {
                self.rows.remove(&evict);
            }
        }
    }

    /// Fetch the gram rows for `keys` (training-set indices, assumed
    /// distinct), computing **all** misses with a single call to
    /// `compute(miss_keys, tile)` — `tile` is the row-major
    /// `miss_keys.len() × width` output block. Returns the rows in
    /// `keys` order.
    pub fn fetch_block<F>(&mut self, keys: &[usize], compute: F) -> Vec<Arc<Vec<f64>>>
    where
        F: FnOnce(&[usize], &mut [f64]),
    {
        let miss_keys: Vec<usize> =
            keys.iter().copied().filter(|k| !self.rows.contains_key(k)).collect();
        self.hits += (keys.len() - miss_keys.len()) as u64;
        self.misses += miss_keys.len() as u64;
        if !miss_keys.is_empty() {
            let mut tile = vec![0.0f64; miss_keys.len() * self.width];
            compute(&miss_keys, &mut tile);
            let mut rest = tile;
            for &k in &miss_keys {
                let tail = rest.split_off(self.width);
                self.insert(k, Arc::new(rest), keys);
                rest = tail;
            }
        }
        keys.iter()
            .map(|k| {
                self.refresh(*k);
                // Every key was either cached or inserted just above;
                // the empty-row default is unreachable.
                self.rows.get(k).cloned().unwrap_or_default()
            })
            .collect()
    }

    /// Insert with LRU eviction that never evicts a key of the
    /// in-flight request (`pinned`); the solver guarantees
    /// `capacity ≥ 2·ws_size` so a whole working set always fits.
    fn insert(&mut self, key: usize, row: Arc<Vec<f64>>, pinned: &[usize]) {
        let mut scanned = 0;
        while self.rows.len() >= self.capacity && scanned < self.order.len() {
            let Some(candidate) = self.order.pop_front() else { break };
            crate::failpoint::check(crate::failpoint::SITE_TILE_CACHE_EVICT);
            if pinned.contains(&candidate) {
                self.order.push_back(candidate);
                scanned += 1;
            } else {
                self.rows.remove(&candidate);
            }
        }
        self.order.push_back(key);
        self.rows.insert(key, row);
    }

    fn refresh(&mut self, key: usize) {
        if let Some(pos) = self.order.iter().position(|&k| k == key) {
            self.order.remove(pos);
            self.order.push_back(key);
        }
    }

    /// Shrink compaction: keep only the active-local `keep` columns
    /// (ascending positions into the *current* width) of every cached
    /// row. Cached kernel values stay valid because shrinking removes
    /// points, it never reorders the survivors.
    pub fn compact(&mut self, keep: &[usize]) {
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(keep.iter().all(|&l| l < self.width));
        self.width = keep.len();
        for row in self.rows.values_mut() {
            let narrowed: Vec<f64> = keep.iter().map(|&l| row[l]).collect();
            *row = Arc::new(narrowed);
        }
    }

    /// Drop cached rows whose key is not in `live_keys` (ascending) —
    /// rows of shrunk-out points can never be fetched again before the
    /// cache-flushing unshrink, so keeping them would waste the byte
    /// budget and lengthen every LRU scan.
    pub fn purge_missing(&mut self, live_keys: &[usize]) {
        debug_assert!(live_keys.windows(2).all(|w| w[0] < w[1]));
        self.rows.retain(|k, _| live_keys.binary_search(k).is_ok());
        self.order.retain(|k| live_keys.binary_search(k).is_ok());
    }

    /// Drop everything and switch to a new row width (unshrink: cached
    /// rows lack the reactivated columns, so they cannot be reused).
    pub fn reset(&mut self, width: usize) {
        self.rows.clear();
        self.order.clear();
        self.width = width;
    }
}

/// LRU cache of gram rows keyed by training index — the Thunder method's
/// working-set amortization (§IV-E discussion of `KiBlock`). Rows are
/// shared out as `Arc`s so the solver holds two rows (i and j) while
/// updating the gradient without copying O(n) data per iteration.
///
/// The row store is a `BTreeMap` for the same PAL-HASH reason as
/// [`TileCache`].
pub struct RowCache {
    capacity: usize,
    rows: BTreeMap<usize, std::sync::Arc<Vec<f64>>>,
    order: VecDeque<usize>,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(2),
            rows: BTreeMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Fetch row `i`, computing it with `compute` on a miss.
    pub fn get<F: FnOnce(&mut [f64])>(
        &mut self,
        i: usize,
        n: usize,
        compute: F,
    ) -> std::sync::Arc<Vec<f64>> {
        if let Some(row) = self.rows.get(&i).cloned() {
            self.hits += 1;
            // refresh LRU position
            if let Some(pos) = self.order.iter().position(|&k| k == i) {
                self.order.remove(pos);
            }
            self.order.push_back(i);
            return row;
        }
        self.misses += 1;
        let mut buf = vec![0.0f64; n];
        compute(&mut buf);
        if self.rows.len() >= self.capacity {
            if let Some(evict) = self.order.pop_front() {
                self.rows.remove(&evict);
            }
        }
        self.order.push_back(i);
        let arc = std::sync::Arc::new(buf);
        self.rows.insert(i, arc.clone());
        arc
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Gaussian, Mt19937};

    fn dataset(n: usize, d: usize) -> DenseTable<f64> {
        let mut e = Mt19937::new(9);
        let mut g = Gaussian::<f64>::standard();
        let mut v = vec![0.0; n * d];
        g.fill(&mut e, &mut v);
        DenseTable::from_vec(v, n, d).unwrap()
    }

    #[test]
    fn gram_row_matches_eval() {
        let x = dataset(40, 6);
        let norms: Vec<f64> = (0..40).map(|i| dot(x.row(i), x.row(i))).collect();
        for k in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.3 }] {
            let mut row = vec![0.0; 40];
            k.gram_row(&x, 7, &norms, &mut row);
            for j in 0..40 {
                let expect = k.eval(x.row(7), x.row(j));
                assert!((row[j] - expect).abs() < 1e-10, "{k:?} j={j}");
            }
        }
    }

    #[test]
    fn gram_row_thread_counts_bit_identical() {
        let x = dataset(97, 5);
        let norms: Vec<f64> = (0..97).map(|i| dot(x.row(i), x.row(i))).collect();
        for k in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.4 }] {
            let mut base = vec![0.0; 97];
            k.gram_row_threads(&x, 13, &norms, &mut base, 1);
            for threads in 2..=4 {
                let mut row = vec![0.0; 97];
                k.gram_row_threads(&x, 13, &norms, &mut row, threads);
                for (u, v) in base.iter().zip(&row) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{k:?} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn rbf_diag_is_one_linear_diag_is_norm() {
        let x = dataset(10, 4);
        let norms: Vec<f64> = (0..10).map(|i| dot(x.row(i), x.row(i))).collect();
        let dr = SvmKernel::Rbf { gamma: 1.0 }.diag(&x, &norms);
        assert!(dr.iter().all(|&v| (v - 1.0).abs() < 1e-12));
        let dl = SvmKernel::Linear.diag(&x, &norms);
        assert_eq!(dl, norms);
    }

    #[test]
    fn cache_hits_and_eviction() {
        let mut c = RowCache::new(2);
        c.get(0, 4, |b| b.fill(0.0));
        c.get(1, 4, |b| b.fill(1.0));
        assert_eq!(c.misses, 2);
        c.get(0, 4, |_| panic!("must be cached"));
        assert_eq!(c.hits, 1);
        // Insert third row → evicts LRU (row 1, since row 0 was refreshed).
        c.get(2, 4, |b| b.fill(2.0));
        assert_eq!(c.len(), 2);
        c.get(1, 4, |b| b.fill(1.0)); // recompute = miss
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn gram_tile_matches_eval_and_thread_counts() {
        let x = dataset(53, 6);
        let norms: Vec<f64> = (0..53).map(|i| dot(x.row(i), x.row(i))).collect();
        // Active set: a strict subset of rows, ascending.
        let active: Vec<usize> = (0..53).filter(|i| i % 3 != 1).collect();
        let na = active.len();
        let d = 6;
        let mut packed = vec![0.0f64; na * d];
        let mut pn = vec![0.0f64; na];
        for (r, &g) in active.iter().enumerate() {
            packed[r * d..(r + 1) * d].copy_from_slice(x.row(g));
            pn[r] = norms[g];
        }
        let pb = crate::blas::pack_b_panels(Transpose::Yes, d, na, &packed);
        let ws = [7usize, 0, 31, 52];
        let mut w = vec![0.0f64; ws.len() * d];
        let mut wn = vec![0.0f64; ws.len()];
        for (r, &g) in ws.iter().enumerate() {
            w[r * d..(r + 1) * d].copy_from_slice(x.row(g));
            wn[r] = norms[g];
        }
        for k in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.3 }] {
            let mut base = vec![0.0f64; ws.len() * na];
            k.gram_tile(&w, &wn, &pn, &pb, &mut base, 1);
            for (r, &gi) in ws.iter().enumerate() {
                for (c, &gj) in active.iter().enumerate() {
                    let expect = k.eval(x.row(gi), x.row(gj));
                    let got = base[r * na + c];
                    assert!((got - expect).abs() < 1e-10, "{k:?} r={r} c={c}");
                }
            }
            for threads in 2..=4 {
                let mut tile = vec![0.0f64; ws.len() * na];
                k.gram_tile(&w, &wn, &pn, &pb, &mut tile, threads);
                for (u, v) in base.iter().zip(&tile) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{k:?} threads={threads}");
                }
            }
        }
    }

    /// The sparse gram tile equals per-pair `eval` on the densified
    /// rows and is bit-identical across worker counts.
    #[test]
    fn gram_tile_csr_matches_eval_and_threads() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut sp = dataset(41, 5);
        for (i, v) in sp.data_mut().iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 0.0;
            }
        }
        let xs = CsrMatrix::from_dense(&sp, 0.0, IndexBase::One);
        let norms: Vec<f64> = (0..41).map(|i| dot(sp.row(i), sp.row(i))).collect();
        let active: Vec<usize> = (0..41).filter(|i| i % 4 != 2).collect();
        let na = active.len();
        let d = 5;
        let mut bt = vec![0.0f64; d * na];
        for (r, &g) in active.iter().enumerate() {
            for (j, v) in xs.row_entries(g) {
                bt[j * na + r] = v;
            }
        }
        let pn: Vec<f64> = active.iter().map(|&g| norms[g]).collect();
        let ws = [0usize, 5, 17, 40];
        let wcsr = xs.gather_rows(&ws);
        let wn: Vec<f64> = ws.iter().map(|&g| norms[g]).collect();
        for k in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.4 }] {
            let mut base = vec![0.0f64; ws.len() * na];
            k.gram_tile_csr(&wcsr, &wn, &pn, &bt, &mut base, LaneProfile::Sve512, 1);
            for (r, &gi) in ws.iter().enumerate() {
                for (c, &gj) in active.iter().enumerate() {
                    let expect = k.eval(sp.row(gi), sp.row(gj));
                    let got = base[r * na + c];
                    assert!((got - expect).abs() < 1e-10, "{k:?} r={r} c={c}");
                }
            }
            // Worker counts and lane profiles must both leave the tile
            // bit-identical (the sparse epilogue is elementwise).
            for profile in LaneProfile::ALL {
                for threads in 1..=4 {
                    let mut tile = vec![0.0f64; ws.len() * na];
                    k.gram_tile_csr(&wcsr, &wn, &pn, &bt, &mut tile, profile, threads);
                    for (u, v) in base.iter().zip(&tile) {
                        assert_eq!(
                            u.to_bits(),
                            v.to_bits(),
                            "{k:?} {} threads={threads}",
                            profile.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn tile_cache_block_fetch_hits_and_compaction() {
        let mut c = TileCache::new(4, 5);
        // First fetch: all three rows missing, one compute call.
        let rows = c.fetch_block(&[3, 9, 1], |miss, tile| {
            assert_eq!(miss, &[3, 9, 1]);
            for (r, &k) in miss.iter().enumerate() {
                for j in 0..5 {
                    tile[r * 5 + j] = (k * 10 + j) as f64;
                }
            }
        });
        assert_eq!(c.misses, 3);
        assert_eq!(rows[1][2], 92.0);
        // Second fetch overlaps: only key 7 is computed.
        let rows = c.fetch_block(&[9, 7], |miss, tile| {
            assert_eq!(miss, &[7]);
            for (j, v) in tile.iter_mut().enumerate() {
                *v = (70 + j) as f64;
            }
        });
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 4);
        assert_eq!(rows[0][0], 30.0);
        assert_eq!(rows[1][4], 74.0);
        // Compact to columns {0, 2, 4}: widths shrink, values survive.
        c.compact(&[0, 2, 4]);
        assert_eq!(c.width(), 3);
        let rows = c.fetch_block(&[3], |_, _| panic!("must be cached"));
        assert_eq!(rows[0].as_slice(), &[30.0, 32.0, 34.0]);
        // Purge keys that left the active set: 7 is dropped, the
        // survivors stay fetchable without recompute.
        c.purge_missing(&[1, 3, 9]);
        assert_eq!(c.len(), 3);
        c.fetch_block(&[9], |_, _| panic!("must be cached"));
        c.fetch_block(&[7], |miss, tile| {
            assert_eq!(miss, &[7]);
            tile.fill(7.5);
        });
        // Reset drops everything.
        c.reset(6);
        assert!(c.is_empty());
        assert_eq!(c.width(), 6);
    }

    #[test]
    fn tile_cache_eviction_never_drops_in_flight_rows() {
        let mut c = TileCache::new(2, 1);
        c.fetch_block(&[0], |_, t| t[0] = 0.0);
        c.fetch_block(&[1], |_, t| t[0] = 1.0);
        // Fetching {1, 2} must evict 0 (LRU), never the pinned 1.
        let rows = c.fetch_block(&[1, 2], |miss, t| {
            assert_eq!(miss, &[2]);
            t[0] = 2.0;
        });
        assert_eq!(rows[0][0], 1.0);
        assert_eq!(rows[1][0], 2.0);
        assert_eq!(c.len(), 2);
        // 0 was evicted: re-fetch recomputes.
        c.fetch_block(&[0], |miss, t| {
            assert_eq!(miss, &[0]);
            t[0] = 0.5;
        });
    }

    /// Regression (ISSUE 7, PAL-HASH): `compact` and `purge_missing`
    /// traverse the row store — behind a hash map that traversal order
    /// depended on insertion history. The store is a `BTreeMap` now:
    /// caches built by different insertion orders must agree bit for
    /// bit after compaction and purge.
    #[test]
    fn tile_cache_compaction_is_insertion_order_independent() {
        let build = |keys: &[usize]| {
            let mut c = TileCache::new(16, 4);
            for &k in keys {
                c.fetch_block(&[k], |miss, tile| {
                    for (j, v) in tile.iter_mut().enumerate() {
                        *v = ((miss[0] * 100 + j) as f64).sin();
                    }
                });
            }
            c.compact(&[1, 3]);
            c.purge_missing(&[2, 5, 8, 11]);
            c
        };
        let mut a = build(&[2, 5, 8, 11]);
        let mut b = build(&[11, 8, 2, 5]);
        assert_eq!(a.len(), b.len());
        for k in [2usize, 5, 8, 11] {
            let ra = a.fetch_block(&[k], |_, _| panic!("must be cached"));
            let rb = b.fetch_block(&[k], |_, _| panic!("must be cached"));
            let bits_a: Vec<u64> = ra[0].iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u64> = rb[0].iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "k={k}");
        }
    }

    #[test]
    fn rbf_self_similarity_max() {
        let x = dataset(20, 3);
        let k = SvmKernel::Rbf { gamma: 0.7 };
        for i in 0..20 {
            assert!((k.eval(x.row(i), x.row(i)) - 1.0).abs() < 1e-12);
            for j in 0..20 {
                assert!(k.eval(x.row(i), x.row(j)) <= 1.0 + 1e-12);
            }
        }
    }
}
