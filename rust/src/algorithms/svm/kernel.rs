//! SVM kernel functions, gram-row computation and the LRU row cache the
//! Thunder method amortizes row computation with.

use crate::blas::{dot, gemv_threads, sqdist};
use crate::tables::DenseTable;
use std::collections::{HashMap, VecDeque};

/// Kernel function.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SvmKernel {
    Linear,
    /// `exp(−γ‖x−y‖²)`.
    Rbf { gamma: f64 },
}

impl SvmKernel {
    /// k(x, y) for two rows.
    #[inline]
    pub fn eval(&self, x: &[f64], y: &[f64]) -> f64 {
        match *self {
            SvmKernel::Linear => dot(x, y),
            SvmKernel::Rbf { gamma } => (-gamma * sqdist(x, y)).exp(),
        }
    }

    /// Full gram row `K(i, ·)` against every training row, written into
    /// `out` (length n), on the process-default worker count.
    pub fn gram_row(&self, x: &DenseTable<f64>, i: usize, norms: &[f64], out: &mut [f64]) {
        self.gram_row_threads(x, i, norms, out, crate::parallel::default_threads());
    }

    /// [`SvmKernel::gram_row`] with an explicit worker count: the n
    /// output entries are independent dot products against row i, so
    /// workers each own a contiguous slice of `out` and run the gemv
    /// cross term (plus the RBF transform) on their row block. Every
    /// entry is computed whole by one worker — bit-identical at any
    /// worker count, which the solver's scalar-vs-vectorized fidelity
    /// tests rely on.
    pub fn gram_row_threads(
        &self,
        x: &DenseTable<f64>,
        i: usize,
        norms: &[f64],
        out: &mut [f64],
        threads: usize,
    ) {
        let n = x.rows();
        let d = x.cols();
        debug_assert_eq!(out.len(), n);
        let workers = crate::parallel::effective_threads(threads, n.saturating_mul(d), 1 << 14);
        let bounds = crate::parallel::even_bounds(n, workers);
        let xi = x.row(i);
        let kernel = *self;
        crate::parallel::scope_rows(out, 1, &bounds, |r0, r1, block| {
            let rows = r1 - r0;
            let ablock = &x.data()[r0 * d..r1 * d];
            // Inner gemv stays single-threaded: the fan-out already
            // happened one level up (nesting pool batches here would
            // only add scheduling overhead).
            match kernel {
                SvmKernel::Linear => {
                    gemv_threads(false, rows, d, 1.0, ablock, xi, 0.0, block, 1);
                }
                SvmKernel::Rbf { gamma } => {
                    // ‖xi−xj‖² = ‖xi‖² + ‖xj‖² − 2 xi·xj, cross term via gemv.
                    gemv_threads(false, rows, d, 1.0, ablock, xi, 0.0, block, 1);
                    let ni = norms[i];
                    for (j, v) in block.iter_mut().enumerate() {
                        let d2 = (ni + norms[r0 + j] - 2.0 * *v).max(0.0);
                        *v = (-gamma * d2).exp();
                    }
                }
            }
        });
    }

    /// Diagonal `K(i, i)` values for all rows.
    pub fn diag(&self, x: &DenseTable<f64>, norms: &[f64]) -> Vec<f64> {
        match *self {
            SvmKernel::Linear => norms.to_vec(),
            SvmKernel::Rbf { .. } => vec![1.0; x.rows()],
        }
    }
}

/// LRU cache of gram rows keyed by training index — the Thunder method's
/// working-set amortization (§IV-E discussion of `KiBlock`). Rows are
/// shared out as `Arc`s so the solver holds two rows (i and j) while
/// updating the gradient without copying O(n) data per iteration.
pub struct RowCache {
    capacity: usize,
    rows: HashMap<usize, std::sync::Arc<Vec<f64>>>,
    order: VecDeque<usize>,
    pub hits: u64,
    pub misses: u64,
}

impl RowCache {
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity: capacity.max(2),
            rows: HashMap::new(),
            order: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Fetch row `i`, computing it with `compute` on a miss.
    pub fn get<F: FnOnce(&mut [f64])>(
        &mut self,
        i: usize,
        n: usize,
        compute: F,
    ) -> std::sync::Arc<Vec<f64>> {
        if self.rows.contains_key(&i) {
            self.hits += 1;
            // refresh LRU position
            if let Some(pos) = self.order.iter().position(|&k| k == i) {
                self.order.remove(pos);
            }
            self.order.push_back(i);
            return self.rows.get(&i).unwrap().clone();
        }
        self.misses += 1;
        let mut buf = vec![0.0f64; n];
        compute(&mut buf);
        if self.rows.len() >= self.capacity {
            if let Some(evict) = self.order.pop_front() {
                self.rows.remove(&evict);
            }
        }
        self.order.push_back(i);
        let arc = std::sync::Arc::new(buf);
        self.rows.insert(i, arc.clone());
        arc
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Gaussian, Mt19937};

    fn dataset(n: usize, d: usize) -> DenseTable<f64> {
        let mut e = Mt19937::new(9);
        let mut g = Gaussian::<f64>::standard();
        let mut v = vec![0.0; n * d];
        g.fill(&mut e, &mut v);
        DenseTable::from_vec(v, n, d).unwrap()
    }

    #[test]
    fn gram_row_matches_eval() {
        let x = dataset(40, 6);
        let norms: Vec<f64> = (0..40).map(|i| dot(x.row(i), x.row(i))).collect();
        for k in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.3 }] {
            let mut row = vec![0.0; 40];
            k.gram_row(&x, 7, &norms, &mut row);
            for j in 0..40 {
                let expect = k.eval(x.row(7), x.row(j));
                assert!((row[j] - expect).abs() < 1e-10, "{k:?} j={j}");
            }
        }
    }

    #[test]
    fn gram_row_thread_counts_bit_identical() {
        let x = dataset(97, 5);
        let norms: Vec<f64> = (0..97).map(|i| dot(x.row(i), x.row(i))).collect();
        for k in [SvmKernel::Linear, SvmKernel::Rbf { gamma: 0.4 }] {
            let mut base = vec![0.0; 97];
            k.gram_row_threads(&x, 13, &norms, &mut base, 1);
            for threads in 2..=4 {
                let mut row = vec![0.0; 97];
                k.gram_row_threads(&x, 13, &norms, &mut row, threads);
                for (u, v) in base.iter().zip(&row) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{k:?} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn rbf_diag_is_one_linear_diag_is_norm() {
        let x = dataset(10, 4);
        let norms: Vec<f64> = (0..10).map(|i| dot(x.row(i), x.row(i))).collect();
        let dr = SvmKernel::Rbf { gamma: 1.0 }.diag(&x, &norms);
        assert!(dr.iter().all(|&v| (v - 1.0).abs() < 1e-12));
        let dl = SvmKernel::Linear.diag(&x, &norms);
        assert_eq!(dl, norms);
    }

    #[test]
    fn cache_hits_and_eviction() {
        let mut c = RowCache::new(2);
        c.get(0, 4, |b| b.fill(0.0));
        c.get(1, 4, |b| b.fill(1.0));
        assert_eq!(c.misses, 2);
        c.get(0, 4, |_| panic!("must be cached"));
        assert_eq!(c.hits, 1);
        // Insert third row → evicts LRU (row 1, since row 0 was refreshed).
        c.get(2, 4, |b| b.fill(2.0));
        assert_eq!(c.len(), 2);
        c.get(1, 4, |b| b.fill(1.0)); // recompute = miss
        assert_eq!(c.misses, 4);
    }

    #[test]
    fn rbf_self_similarity_max() {
        let x = dataset(20, 3);
        let k = SvmKernel::Rbf { gamma: 0.7 };
        for i in 0..20 {
            assert!((k.eval(x.row(i), x.row(i)) - 1.0).abs() < 1e-12);
            for j in 0..20 {
                assert!(k.eval(x.row(i), x.row(j)) <= 1.0 + 1e-12);
            }
        }
    }
}
