//! Predicated SVM hot loops — the rust_pallas analogue of the paper's
//! SVE predicate-driven execution (§IV-E), applied to every per-
//! iteration scan of the shrinking solver.
//!
//! Three idioms, all branch-free in the loop body:
//!
//! * **mask by select** — every guard (`I[]` flag membership, threshold
//!   comparison) is evaluated as a boolean per lane and folded into the
//!   arithmetic by selecting a neutral element (±∞ for min/max scans)
//!   instead of `continue`-ing, exactly how an SVE predicate deadens
//!   lanes without a branch;
//! * **lane-blocked unrolling at the active profile's width** — the
//!   scan bodies are const-generic over the lane count and
//!   monomorphized per [`LaneProfile`] (128/256/512-bit ⇒ 2/4/8 f64
//!   lanes) through [`crate::with_lane_count!`]; arithmetic runs
//!   unconditionally on all lanes, and a block-local reduction in
//!   index order preserves the scalar loop's first-index tie-breaking
//!   exactly. Because the reductions are exact (compare/select, no
//!   accumulation), the selected indices and extrema are identical at
//!   **every** lane width, not just within one profile;
//! * **fixed-order parallel merge** — scans fan out over
//!   [`crate::parallel::par_map`] partitions and the partials merge in
//!   ascending partition order. Min/max/argmin reductions are *exact*
//!   (no floating-point accumulation), so with an ordered merge and
//!   strict comparisons the result is bit-identical for **any**
//!   partitioning — the worker count can never change the selected
//!   index, the extrema, or the step.
//!
//! Elementwise updates (the gradient axpy and the Thunder block
//! reconcile) are bit-identical across worker counts for the simpler
//! reason that every output element is computed whole, in the same
//! term order, by exactly one worker.

use super::wss::{self, WssJResult, LOW, UP};
use crate::parallel;
use crate::primitives::lanes::LaneProfile;

// The lane width is no longer a module constant: every entry point
// takes the caller's [`LaneProfile`] (the solver routes the profile its
// `Context` resolved) and dispatches once into a body monomorphized for
// that width. `profile.lanes()` drives the extrema/axpy blocks,
// `profile.wss_lanes()` (two vectors of headroom) drives the WSSj scan.

/// Minimum scan length before a WSS fan-out pays for itself.
const PAR_MIN_SCAN: usize = 1 << 12;

/// Fused first-index / stopping-gap extrema of one WSS pass:
/// `bi`/`gmin` = argmin/min of the signed gradient over `I_up`
/// (first-index tie-break), `gmax2` = max over `I_low`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WssExtrema {
    pub bi: Option<usize>,
    pub gmin: f64,
    pub gmax2: f64,
}

impl WssExtrema {
    const NEUTRAL: WssExtrema =
        WssExtrema { bi: None, gmin: f64::INFINITY, gmax2: f64::NEG_INFINITY };
}

/// Branch-free fused extrema scan over `[lo, hi)`: one pass computes
/// both the `WSSi` argmin over `I_up` and the `GMax2` stopping term
/// over `I_low`. Guards become lane masks; dead lanes carry ±∞ so the
/// arithmetic never branches; each lane block reduces in index order
/// (strict comparisons keep the earliest extremum, matching the scalar
/// [`wss::wss_i`] loop bit for bit at every lane width). Dispatches
/// once into the body monomorphized for `profile`.
pub fn extrema_range(
    profile: LaneProfile,
    grad: &[f64],
    flags: &[u8],
    lo: usize,
    hi: usize,
) -> WssExtrema {
    crate::with_lane_count!(profile, L, { extrema_lanes::<L>(grad, flags, lo, hi) })
}

/// The const-generic extrema body — `L` lanes per predicated block.
fn extrema_lanes<const L: usize>(grad: &[f64], flags: &[u8], lo: usize, hi: usize) -> WssExtrema {
    let mut out = WssExtrema::NEUTRAL;
    let mut up_lane = [f64::INFINITY; L];
    let mut low_lane = [f64::NEG_INFINITY; L];
    let mut base = lo;
    while base < hi {
        let len = L.min(hi - base);
        // --- predicated block body: every lane, no branches ---
        for l in 0..len {
            let t = base + l;
            let g = grad[t];
            let fl = flags[t];
            let in_up = fl & UP != 0;
            let in_low = fl & LOW != 0;
            up_lane[l] = if in_up { g } else { f64::INFINITY };
            low_lane[l] = if in_low { g } else { f64::NEG_INFINITY };
        }
        // --- block reduction in index order (exact, tie-break safe) ---
        for l in 0..len {
            if up_lane[l] < out.gmin {
                out.gmin = up_lane[l];
                out.bi = Some(base + l);
            }
            out.gmax2 = if low_lane[l] > out.gmax2 { low_lane[l] } else { out.gmax2 };
        }
        base += len;
    }
    out
}

/// Merge partition partials in ascending partition order. Strict
/// comparisons keep the earliest index on ties, so the merged result
/// equals a single full-range scan for any partitioning.
fn merge_extrema(partials: Vec<WssExtrema>) -> WssExtrema {
    let mut out = WssExtrema::NEUTRAL;
    for p in partials {
        if p.gmin < out.gmin {
            out.gmin = p.gmin;
            out.bi = p.bi;
        }
        out.gmax2 = if p.gmax2 > out.gmax2 { p.gmax2 } else { out.gmax2 };
    }
    out
}

/// Parallel fused extrema scan: partitions fan out on the worker pool,
/// partials merge in fixed order — bit-identical at any worker count
/// (and, by exactness of the reductions, at any lane profile).
pub fn wss_extrema_par(
    profile: LaneProfile,
    grad: &[f64],
    flags: &[u8],
    threads: usize,
) -> WssExtrema {
    let n = grad.len();
    debug_assert_eq!(flags.len(), n);
    let workers = parallel::effective_threads(threads, n, PAR_MIN_SCAN);
    if workers <= 1 {
        return extrema_range(profile, grad, flags, 0, n);
    }
    let bounds = parallel::even_bounds(n, workers);
    merge_extrema(parallel::par_map(&bounds, |lo, hi| extrema_range(profile, grad, flags, lo, hi)))
}

/// `L`-lane predicated `WSSj` block scan — the [`wss::wss_j_vectorized`]
/// body used as the per-partition kernel of [`wss_j_par`], which
/// instantiates it at the active profile's `wss_lanes()` width. Bitwise
/// identical to [`wss::wss_j_scalar`] over the same range for every `L`
/// (the property suite enforces this).
#[allow(clippy::too_many_arguments)]
pub fn wss_j_lanes<const L: usize>(
    grad: &[f64],
    flags: &[u8],
    sign: u8,
    low: u8,
    gmin: f64,
    kii: f64,
    kernel_diag: &[f64],
    ki_block: &[f64],
    j_start: usize,
    j_end: usize,
    tau: f64,
) -> WssJResult {
    let mut gmax = f64::NEG_INFINITY;
    let mut gmax2 = f64::NEG_INFINITY;
    let mut bj: Option<usize> = None;
    let mut delta = 0.0f64;
    let mut obj_lane = [f64::NEG_INFINITY; L];
    let mut dt_lane = [0.0f64; L];
    let mut base = j_start;
    while base < j_end {
        let len = L.min(j_end - base);
        let mut block_gmax2 = f64::NEG_INFINITY;
        for l in 0..len {
            let j = base + l;
            let gradj = grad[j];
            let fl = flags[j];
            // The two flag guards fuse into one predicate.
            let pass = (fl & sign != 0) & ((fl & low) == low);
            let g2 = if pass { gradj } else { f64::NEG_INFINITY };
            block_gmax2 = if g2 > block_gmax2 { g2 } else { block_gmax2 };
            // Threshold predicate folds in; dead lanes go neutral.
            let active = pass & (gradj >= gmin);
            let b = gmin - gradj;
            let a_raw = kii + kernel_diag[j] - 2.0 * ki_block[j - j_start];
            let a = if a_raw <= 0.0 { tau } else { a_raw };
            let dt = b / a;
            let obj = b * dt;
            obj_lane[l] = if active { obj } else { f64::NEG_INFINITY };
            dt_lane[l] = dt;
        }
        gmax2 = gmax2.max(block_gmax2);
        for l in 0..len {
            if obj_lane[l] > gmax {
                gmax = obj_lane[l];
                bj = Some(base + l);
                delta = -dt_lane[l];
            }
        }
        base += len;
    }
    WssJResult { bj, obj: gmax, gmax2, delta }
}

/// Parallel `WSSj` over a full compacted gram row: partitions run the
/// predicated lane scan at the profile's `wss_lanes()` width (or the
/// branchy scalar Listing-1 loop when `vectorized` is false — the
/// Fig. 4 comparison point), partials merge in ascending order with
/// strict comparisons. Because the per-lane objective involves no
/// accumulation, the merged result is bit-equal to a single-range scan
/// at any worker count — and the scalar and vectorized bodies are
/// themselves bitwise interchangeable at every lane width.
#[allow(clippy::too_many_arguments)]
pub fn wss_j_par(
    profile: LaneProfile,
    grad: &[f64],
    flags: &[u8],
    sign: u8,
    low: u8,
    gmin: f64,
    kii: f64,
    kernel_diag: &[f64],
    ki: &[f64],
    tau: f64,
    vectorized: bool,
    threads: usize,
) -> WssJResult {
    let n = grad.len();
    debug_assert_eq!(ki.len(), n);
    let body = |lo: usize, hi: usize| -> WssJResult {
        let block = &ki[lo..hi];
        if vectorized {
            // `wss_lanes() == 2·lanes()`, so the dispatch instantiates
            // the scan at twice the bound lane count.
            crate::with_lane_count!(profile, L, {
                wss_j_lanes::<{ 2 * L }>(
                    grad,
                    flags,
                    sign,
                    low,
                    gmin,
                    kii,
                    kernel_diag,
                    block,
                    lo,
                    hi,
                    tau,
                )
            })
        } else {
            wss::wss_j_scalar(grad, flags, sign, low, gmin, kii, kernel_diag, block, lo, hi, tau)
        }
    };
    let workers = parallel::effective_threads(threads, n, PAR_MIN_SCAN);
    if workers <= 1 {
        return body(0, n);
    }
    let bounds = parallel::even_bounds(n, workers);
    let partials = parallel::par_map(&bounds, body);
    let mut out = WssJResult {
        bj: None,
        obj: f64::NEG_INFINITY,
        gmax2: f64::NEG_INFINITY,
        delta: 0.0,
    };
    for p in partials {
        if p.gmax2 > out.gmax2 {
            out.gmax2 = p.gmax2;
        }
        if p.obj > out.obj {
            out.obj = p.obj;
            out.bj = p.bj;
            out.delta = p.delta;
        }
    }
    out
}

/// Gradient pair update `g[t] += τ·(Ki[t] − Kj[t])` over the compacted
/// active set — the Boser per-iteration axpy, lane-unrolled at the
/// profile's width and fanned out over disjoint chunks. Each element is
/// computed whole (one `mul_add`) by one worker, so any worker count —
/// and any lane profile — produces the same bits.
pub fn update_grad_pair(
    profile: LaneProfile,
    grad: &mut [f64],
    row_i: &[f64],
    row_j: &[f64],
    tau: f64,
    threads: usize,
) {
    let n = grad.len();
    debug_assert_eq!(row_i.len(), n);
    debug_assert_eq!(row_j.len(), n);
    let workers = parallel::effective_threads(threads, n, PAR_MIN_SCAN);
    let bounds = parallel::even_bounds(n, workers);
    crate::with_lane_count!(profile, L, {
        parallel::scope_rows(grad, 1, &bounds, |lo, hi, block| {
            let (ri, rj) = (&row_i[lo..hi], &row_j[lo..hi]);
            let chunks = (hi - lo) / L;
            for c in 0..chunks {
                let b = c * L;
                for l in 0..L {
                    block[b + l] = tau.mul_add(ri[b + l] - rj[b + l], block[b + l]);
                }
            }
            for t in chunks * L..hi - lo {
                block[t] = tau.mul_add(ri[t] - rj[t], block[t]);
            }
        });
    });
}

/// Thunder block reconcile `g[t] += Σ_l δ_l·K_l[t]` over the active
/// set: each element accumulates its `δ` terms in ascending `l` order
/// (δ = 0 rows contribute an exact `+0·K` — the multiply *is* the
/// predicate, no per-element branch), chunks fan out disjointly, so the
/// result is bit-identical at any worker count.
pub fn reconcile_grad(
    grad: &mut [f64],
    deltas: &[f64],
    rows: &[std::sync::Arc<Vec<f64>>],
    threads: usize,
) {
    let n = grad.len();
    debug_assert_eq!(deltas.len(), rows.len());
    let work = n.saturating_mul(rows.len().max(1));
    let workers = parallel::effective_threads(threads, work, PAR_MIN_SCAN);
    let bounds = parallel::even_bounds(n, workers);
    parallel::scope_rows(grad, 1, &bounds, |lo, hi, block| {
        for (l, row) in rows.iter().enumerate() {
            let d = deltas[l];
            let r = &row[lo..hi];
            for (g, &kv) in block.iter_mut().zip(r) {
                *g = d.mul_add(kv, *g);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::svm::wss::{SIGN_ANY, SIGN_NEG, SIGN_POS};
    use crate::rng::{Distribution, Gaussian, Mt19937, Uniform};

    fn random_case(seed: u32, n: usize) -> (Vec<f64>, Vec<u8>, Vec<f64>, Vec<f64>) {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::<f64>::standard();
        let mut u = Uniform::new(0.0, 1.0);
        let grad: Vec<f64> = (0..n).map(|_| g.sample(&mut e)).collect();
        let flags: Vec<u8> = (0..n)
            .map(|_| {
                let mut f = if u.sample(&mut e) < 0.5 { SIGN_POS } else { SIGN_NEG };
                if u.sample(&mut e) < 0.7 {
                    f |= LOW;
                }
                if u.sample(&mut e) < 0.7 {
                    f |= UP;
                }
                f
            })
            .collect();
        let diag: Vec<f64> = (0..n).map(|_| 1.0 + u.sample(&mut e)).collect();
        let ki: Vec<f64> = (0..n).map(|_| g.sample(&mut e) * 0.5).collect();
        (grad, flags, diag, ki)
    }

    /// Scalar oracle for the fused extrema scan.
    fn extrema_oracle(grad: &[f64], flags: &[u8]) -> WssExtrema {
        let (bi, gmin) = match wss::wss_i(grad, flags) {
            Some((b, g)) => (Some(b), g),
            None => (None, f64::INFINITY),
        };
        let gmax2 = grad
            .iter()
            .zip(flags)
            .filter(|(_, &f)| f & LOW != 0)
            .map(|(&g, _)| g)
            .fold(f64::NEG_INFINITY, f64::max);
        WssExtrema { bi, gmin, gmax2 }
    }

    #[test]
    fn extrema_matches_scalar_oracle_all_sizes_and_profiles() {
        for profile in LaneProfile::ALL {
            for (seed, n) in
                [(1u32, 1usize), (2, 7), (3, 8), (4, 9), (5, 100), (6, 1023), (7, 4099)]
            {
                let (grad, flags, _, _) = random_case(seed, n);
                let got = extrema_range(profile, &grad, &flags, 0, n);
                let want = extrema_oracle(&grad, &flags);
                assert_eq!(got.bi, want.bi, "{} n={n}", profile.name());
                assert_eq!(got.gmin.to_bits(), want.gmin.to_bits(), "{} n={n}", profile.name());
                assert_eq!(got.gmax2.to_bits(), want.gmax2.to_bits(), "{} n={n}", profile.name());
            }
        }
    }

    #[test]
    fn extrema_par_bit_identical_across_workers_and_profiles() {
        let (grad, flags, _, _) = random_case(11, 9001);
        let base = wss_extrema_par(LaneProfile::Sve512, &grad, &flags, 1);
        for profile in LaneProfile::ALL {
            for threads in 1..=4 {
                let got = wss_extrema_par(profile, &grad, &flags, threads);
                assert_eq!(got, base, "{} threads={threads}", profile.name());
            }
        }
        assert_eq!(base, extrema_oracle(&grad, &flags));
    }

    #[test]
    fn extrema_tie_breaks_to_first_index() {
        // Equal minima in different lane blocks and lanes — the first
        // index must win at every lane width.
        let mut grad = vec![1.0; 40];
        grad[3] = -2.0;
        grad[17] = -2.0;
        let flags = vec![UP | LOW; 40];
        for profile in LaneProfile::ALL {
            let r = extrema_range(profile, &grad, &flags, 0, 40);
            assert_eq!(r.bi, Some(3), "{}", profile.name());
        }
    }

    #[test]
    fn wss_j_lanes_matches_scalar_bitwise_at_every_width() {
        for profile in LaneProfile::ALL {
            for (seed, n) in [(21u32, 1usize), (22, 8), (23, 9), (24, 100), (25, 1023)] {
                let (grad, flags, diag, ki) = random_case(seed, n);
                let s = wss::wss_j_scalar(
                    &grad, &flags, SIGN_ANY, LOW, -0.1, 1.5, &diag, &ki, 0, n, 1e-12,
                );
                let v = crate::with_lane_count!(profile, L, {
                    wss_j_lanes::<{ 2 * L }>(
                        &grad, &flags, SIGN_ANY, LOW, -0.1, 1.5, &diag, &ki, 0, n, 1e-12,
                    )
                });
                assert_eq!(s, v, "{} n={n}", profile.name());
            }
        }
    }

    #[test]
    fn wss_j_par_bit_identical_across_workers_and_bodies() {
        let (grad, flags, diag, ki) = random_case(31, 8191);
        // Scalar reference: one full-range Listing-1 scan.
        let scalar = wss::wss_j_scalar(
            &grad, &flags, SIGN_ANY, LOW, -0.05, 1.3, &diag, &ki, 0, 8191, 1e-12,
        );
        for profile in LaneProfile::ALL {
            for vectorized in [false, true] {
                let base = wss_j_par(
                    profile, &grad, &flags, SIGN_ANY, LOW, -0.05, 1.3, &diag, &ki, 1e-12,
                    vectorized, 1,
                );
                for threads in 2..=4 {
                    let got = wss_j_par(
                        profile, &grad, &flags, SIGN_ANY, LOW, -0.05, 1.3, &diag, &ki, 1e-12,
                        vectorized, threads,
                    );
                    assert_eq!(
                        got,
                        base,
                        "{} vectorized={vectorized} threads={threads}",
                        profile.name()
                    );
                }
                // Scalar and predicated bodies agree bit for bit at
                // every lane width.
                assert_eq!(base, scalar, "{} vectorized={vectorized}", profile.name());
            }
        }
    }

    #[test]
    fn grad_updates_bit_identical_across_workers() {
        let mut e = Mt19937::new(41);
        let mut g = Gaussian::<f64>::standard();
        let n = 6007;
        let g0: Vec<f64> = (0..n).map(|_| g.sample(&mut e)).collect();
        let ri: Vec<f64> = (0..n).map(|_| g.sample(&mut e)).collect();
        let rj: Vec<f64> = (0..n).map(|_| g.sample(&mut e)).collect();
        let mut base = g0.clone();
        update_grad_pair(LaneProfile::Sve512, &mut base, &ri, &rj, 0.37, 1);
        for profile in LaneProfile::ALL {
            for threads in 1..=4 {
                let mut gt = g0.clone();
                update_grad_pair(profile, &mut gt, &ri, &rj, 0.37, threads);
                for (u, v) in base.iter().zip(&gt) {
                    assert_eq!(u.to_bits(), v.to_bits(), "{} threads={threads}", profile.name());
                }
            }
        }
        // Reconcile: three delta rows, one exactly zero (the multiply-
        // as-predicate case).
        let rows: Vec<std::sync::Arc<Vec<f64>>> = (0..3)
            .map(|_| std::sync::Arc::new((0..n).map(|_| g.sample(&mut e)).collect::<Vec<f64>>()))
            .collect();
        let deltas = [0.21, 0.0, -0.4];
        let mut rbase = g0.clone();
        reconcile_grad(&mut rbase, &deltas, &rows, 1);
        for threads in 2..=4 {
            let mut gt = g0.clone();
            reconcile_grad(&mut gt, &deltas, &rows, threads);
            for (u, v) in rbase.iter().zip(&gt) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }
}
