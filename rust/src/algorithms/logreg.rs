//! Binary logistic regression trained by mini-batch gradient descent —
//! the Fig. 9 fraud-detection workload (40× over stock sklearn) and part
//! of the Fig. 5/6 grids.
//!
//! Backend ladder: naive = per-sample scalar updates; reference /
//! vectorized = batched gemv-based gradient; artifact = the fused
//! `logreg_step` Pallas kernel (forward + gradient in one HLO program)
//! executed via PJRT on fixed-shape tiles.
//!
//! CSR tables train through the same mini-batch schedule with both
//! gemv calls swapped for the threaded `csrmv` (forward `X_b·w`,
//! gradient `X_bᵀ·err`); the fixed mini-batch tiles are sliced from
//! the CSR input **once** before the epoch loop (pack-once). Inference
//! is one `csrmv` per call. `Backend::Naive` densifies first — the
//! sparse path's test oracle; the artifact rung has no sparse kernel
//! and falls back to the sparse batched path.

use crate::blas::{axpy, dot, gemv_threads};
use crate::coordinator::{batch, Backend, BudgetMeter, Context, ConvergenceStatus};
use crate::error::{Error, Result};
use crate::parallel;
use crate::primitives::packed::ModelPanel;
use crate::sparse::{csrmv_threads, CsrMatrix, SparseOp};
use crate::tables::{DenseTable, TableRef};
use crate::validate;

#[derive(Clone, Debug)]
pub struct LogRegParams {
    pub lr: f64,
    pub epochs: usize,
    pub l2: f64,
    /// Mini-batch size for the batched backends.
    pub batch: usize,
}

pub struct LogisticRegression;

impl LogisticRegression {
    pub fn params() -> LogRegParams {
        LogRegParams { lr: 0.1, epochs: 50, l2: 1e-4, batch: 256 }
    }
}

#[derive(Clone, Debug)]
pub struct LogRegModel {
    pub coef: Vec<f64>,
    pub intercept: f64,
    /// `Converged` when every configured epoch ran; `IterLimit` /
    /// `DeadlineExceeded` when the context's budget cut the epoch loop
    /// short (the weights are the last completed epoch's iterate).
    pub status: ConvergenceStatus,
    /// Model-resident weight panel ([`ModelPanel::Weights`]) built at
    /// `train` time — inference reads the coefficients through it so
    /// the pack-free contract covers coefficient models uniformly.
    panel: ModelPanel,
}

#[inline]
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

impl LogRegParams {
    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    pub fn epochs(mut self, n: usize) -> Self {
        self.epochs = n;
        self
    }

    pub fn l2(mut self, l2: f64) -> Self {
        self.l2 = l2;
        self
    }

    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b.max(1);
        self
    }

    pub fn train<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
        y: &[f64],
    ) -> Result<LogRegModel> {
        let x = x.into();
        let n = x.rows();
        let p = x.cols();
        validate::non_empty(n, p, "logreg")?;
        validate::labels_match(n, y.len(), "logreg")?;
        validate::positive_finite(self.lr, "lr", "logreg")?;
        validate::non_negative_finite(self.l2, "l2", "logreg")?;
        if !y.iter().all(|&v| v == 0.0 || v == 1.0) {
            return Err(Error::Param("logreg: labels must be 0/1".into()));
        }
        parallel::quarantine("logreg.train", || {
            let mut w = vec![0.0f64; p];
            let mut b = 0.0f64;
            let mut meter = ctx.budget().meter();
            let status = match x {
                TableRef::Dense(d) => match ctx.dispatch("logreg_step", &[self.batch, p]) {
                    Backend::Naive => self.train_naive(d, y, &mut w, &mut b, &mut meter),
                    Backend::Artifact => {
                        self.train_artifact(ctx, d, y, &mut w, &mut b, &mut meter)?
                    }
                    _ => self.train_batched(d, y, &mut w, &mut b, ctx.threads(), &mut meter),
                },
                TableRef::Csr(s) => match ctx.dispatch("logreg_step", &[self.batch, p]) {
                    // Densified naive rung — the sparse path's oracle.
                    Backend::Naive => self.train_naive(&s.to_dense(), y, &mut w, &mut b, &mut meter),
                    // No sparse Pallas kernel: Artifact falls back to the
                    // sparse batched path (same update cadence).
                    _ => self.train_batched_csr(s, y, &mut w, &mut b, ctx.threads(), &mut meter)?,
                },
            };
            let panel = ModelPanel::from_weights(&w);
            Ok(LogRegModel { coef: w, intercept: b, status, panel })
        })
    }

    /// Naive rung: the *same* mini-batch gradient as the optimized path
    /// (so the ladder is a controlled implementation comparison), but in
    /// the stock-sklearn-on-ARM style — per-row scalar loops and fresh
    /// allocations inside the hot loop instead of batched BLAS.
    fn train_naive(
        &self,
        x: &DenseTable<f64>,
        y: &[f64],
        w: &mut Vec<f64>,
        b: &mut f64,
        meter: &mut BudgetMeter,
    ) -> ConvergenceStatus {
        let n = x.rows();
        let p = x.cols();
        for _ in 0..self.epochs {
            if let Some(expired) = meter.check_before_iter() {
                return expired;
            }
            for (start, len) in batch::tiles(n, self.batch) {
                // Allocation-heavy: fresh buffers per tile (intentional).
                let mut err: Vec<f64> = Vec::with_capacity(len);
                for i in 0..len {
                    let row = x.row(start + i);
                    let mut z = *b;
                    for j in 0..p {
                        z += w[j] * row[j];
                    }
                    err.push(sigmoid(z) - y[start + i]);
                }
                let mut grad = vec![0.0f64; p];
                for i in 0..len {
                    let row = x.row(start + i);
                    for j in 0..p {
                        grad[j] += err[i] * row[j];
                    }
                }
                let inv = 1.0 / len as f64;
                for j in 0..p {
                    w[j] -= self.lr * (grad[j] * inv + self.l2 * w[j]);
                }
                *b -= self.lr * err.iter().sum::<f64>() * inv;
            }
        }
        ConvergenceStatus::Converged
    }

    /// Vectorized rung: full mini-batch gradient with gemv, on the
    /// context's worker count (large batches fan out on the pool).
    fn train_batched(
        &self,
        x: &DenseTable<f64>,
        y: &[f64],
        w: &mut Vec<f64>,
        b: &mut f64,
        threads: usize,
        meter: &mut BudgetMeter,
    ) -> ConvergenceStatus {
        let n = x.rows();
        let p = x.cols();
        let mut z = vec![0.0f64; self.batch];
        let mut err = vec![0.0f64; self.batch];
        let mut grad = vec![0.0f64; p];
        for _ in 0..self.epochs {
            if let Some(expired) = meter.check_before_iter() {
                return expired;
            }
            for (start, len) in batch::tiles(n, self.batch) {
                let xb = &x.data()[start * p..(start + len) * p];
                // z = Xb·w + b
                gemv_threads(false, len, p, 1.0, xb, w, 0.0, &mut z[..len], threads);
                for i in 0..len {
                    err[i] = sigmoid(z[i] + *b) - y[start + i];
                }
                // grad = Xbᵀ·err / len + l2·w
                let inv = 1.0 / len as f64;
                gemv_threads(true, len, p, inv, xb, &err[..len], 0.0, &mut grad, threads);
                axpy(self.l2, w, &mut grad);
                axpy(-self.lr, &grad, w);
                *b -= self.lr * err[..len].iter().sum::<f64>() / len as f64;
            }
        }
        ConvergenceStatus::Converged
    }

    /// Sparse twin of [`LogRegParams::train_batched`]: identical
    /// mini-batch schedule, the two gemv calls replaced by the threaded
    /// `csrmv` (forward on the batch slice, transposed gradient
    /// scatter). The fixed batch tiles are sliced from the CSR input
    /// once, before the epoch loop. Both csrmv entry points are
    /// bit-identical at any worker count, and everything else here is
    /// sequential — whole trainings are bit-identical across workers.
    fn train_batched_csr(
        &self,
        x: &CsrMatrix<f64>,
        y: &[f64],
        w: &mut Vec<f64>,
        b: &mut f64,
        threads: usize,
        meter: &mut BudgetMeter,
    ) -> Result<ConvergenceStatus> {
        let n = x.rows();
        let p = x.cols();
        let slices: Vec<(usize, usize, CsrMatrix<f64>)> = batch::tiles(n, self.batch)
            .into_iter()
            .map(|(start, len)| Ok((start, len, x.slice_rows(start, start + len)?)))
            .collect::<Result<_>>()?;
        let mut z = vec![0.0f64; self.batch];
        let mut err = vec![0.0f64; self.batch];
        let mut grad = vec![0.0f64; p];
        for _ in 0..self.epochs {
            if let Some(expired) = meter.check_before_iter() {
                return Ok(expired);
            }
            for (start, len, xb) in &slices {
                let (start, len) = (*start, *len);
                // z = Xb·w
                csrmv_threads(SparseOp::NoTranspose, 1.0, xb, w, 0.0, &mut z[..len], threads)?;
                for i in 0..len {
                    err[i] = sigmoid(z[i] + *b) - y[start + i];
                }
                // grad = Xbᵀ·err / len + l2·w
                let inv = 1.0 / len as f64;
                csrmv_threads(SparseOp::Transpose, inv, xb, &err[..len], 0.0, &mut grad, threads)?;
                axpy(self.l2, w, &mut grad);
                axpy(-self.lr, &grad, w);
                *b -= self.lr * err[..len].iter().sum::<f64>() / len as f64;
            }
        }
        Ok(ConvergenceStatus::Converged)
    }

    /// Artifact rung: fused fwd+grad HLO kernel on padded f32 tiles.
    fn train_artifact(
        &self,
        ctx: &Context,
        x: &DenseTable<f64>,
        y: &[f64],
        w: &mut Vec<f64>,
        b: &mut f64,
        meter: &mut BudgetMeter,
    ) -> Result<ConvergenceStatus> {
        let n = x.rows();
        let p = x.cols();
        // Tightest tile covering the configured mini-batch: batch size
        // is an *algorithm* parameter (it sets the update cadence), so
        // the artifact rung must not silently enlarge it — padding rows
        // are masked, semantics match the vectorized rung exactly.
        // (§Perf: a larger-tile variant was tried and rejected — it
        // amortized PJRT dispatch but changed convergence.)
        let art = ctx
            .registry()
            .best_fit("logreg_step", &[self.batch.min(n.max(1)), p])
            .ok_or_else(|| Error::MissingArtifact("logreg_step".into()))?
            .clone();
        let rt = ctx
            .runtime()
            .ok_or_else(|| Error::Runtime("artifact backend without runtime".into()))?;
        let (tb, tp) = (art.dims[0], art.dims[1]);
        let xf: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
        let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
        for _ in 0..self.epochs {
            if let Some(expired) = meter.check_before_iter() {
                return Ok(expired);
            }
            for (start, len) in batch::tiles(n, tb) {
                let xpad = batch::pad_to(&xf[start * p..(start + len) * p], len, p, tb, tp);
                let mut ypad = vec![0.0f32; tb];
                ypad[..len].copy_from_slice(&yf[start..start + len]);
                let mut wpad = vec![0.0f32; tp];
                for (dst, &src) in wpad.iter_mut().zip(w.iter()) {
                    *dst = src as f32;
                }
                let scalars = [*b as f32, len as f32];
                let outs = rt.execute_f32(
                    &art.name,
                    &[
                        (&xpad.data, &[tb, tp]),
                        (&ypad, &[tb]),
                        (&wpad, &[tp]),
                        (&scalars, &[2]),
                    ],
                )?;
                // outputs: grad_w f32[tp], grad_b f32[1]
                let gw = &outs[0];
                let gb = f64::from(outs[1][0]);
                for (wj, &g) in w.iter_mut().zip(gw.iter()) {
                    *wj -= self.lr * (f64::from(g) + self.l2 * *wj);
                }
                *b -= self.lr * gb;
            }
        }
        Ok(ConvergenceStatus::Converged)
    }
}

impl LogRegModel {
    /// Probability of the positive class (one threaded csrmv for CSR
    /// queries). The weights come from the model-resident panel
    /// (bit-identical to `coef`).
    pub fn predict_proba<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
    ) -> Result<Vec<f64>> {
        let x = x.into();
        validate::dims_match(self.coef.len(), x.cols(), "logreg")?;
        parallel::quarantine("logreg.predict_proba", || {
            let w: &[f64] = self.panel.weights().unwrap_or(&self.coef);
            match x {
                TableRef::Dense(d) => Ok((0..d.rows())
                    .map(|i| sigmoid(dot(d.row(i), w) + self.intercept))
                    .collect()),
                TableRef::Csr(s) => {
                    let mut z = vec![0.0f64; s.rows()];
                    let t = ctx.threads();
                    csrmv_threads(SparseOp::NoTranspose, 1.0, s, w, 0.0, &mut z, t)?;
                    Ok(z.into_iter().map(|v| sigmoid(v + self.intercept)).collect())
                }
            }
        })
    }

    /// Hard 0/1 prediction at threshold 0.5.
    pub fn infer<'a>(&self, ctx: &Context, x: impl Into<TableRef<'a>>) -> Result<Vec<f64>> {
        Ok(self.predict_proba(ctx, x)?.into_iter().map(|p| f64::from(p >= 0.5)).collect())
    }

    /// The model-resident weight panel.
    pub fn panel(&self) -> &ModelPanel {
        &self.panel
    }
}

impl crate::coordinator::serve::ServeModel for LogRegModel {
    fn serve_dims(&self) -> usize {
        self.coef.len()
    }

    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
        // Positive-class probability per row; `predict_proba` is
        // quarantined.
        self.predict_proba(ctx, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_classification;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    #[test]
    fn separable_data_high_accuracy() {
        let mut e = Mt19937::new(1);
        let (x, y) = make_classification(&mut e, 2000, 10, 2.0);
        let c = ctx(Backend::Vectorized);
        let m = LogisticRegression::params().epochs(30).train(&c, &x, &y).unwrap();
        let pred = m.infer(&c, &x).unwrap();
        let acc = crate::metrics::accuracy(&pred, &y);
        assert!(acc > 0.97, "acc={acc}");
    }

    #[test]
    fn naive_and_batched_similar_quality() {
        let mut e = Mt19937::new(2);
        let (x, y) = make_classification(&mut e, 800, 6, 1.5);
        let cn = ctx(Backend::Naive);
        let cv = ctx(Backend::Vectorized);
        let mn = LogisticRegression::params().epochs(20).train(&cn, &x, &y).unwrap();
        let mv = LogisticRegression::params().epochs(20).train(&cv, &x, &y).unwrap();
        let an = crate::metrics::accuracy(&mn.infer(&cn, &x).unwrap(), &y);
        let av = crate::metrics::accuracy(&mv.infer(&cv, &x).unwrap(), &y);
        assert!((an - av).abs() < 0.05, "naive {an} vs vectorized {av}");
        assert!(an > 0.9 && av > 0.9);
    }

    #[test]
    fn probabilities_in_unit_interval() {
        let mut e = Mt19937::new(3);
        let (x, y) = make_classification(&mut e, 300, 4, 1.0);
        let c = ctx(Backend::Vectorized);
        let m = LogisticRegression::params().epochs(5).train(&c, &x, &y).unwrap();
        for p in m.predict_proba(&c, &x).unwrap() {
            assert!((0.0..=1.0).contains(&p));
        }
    }

    /// CSR training follows the dense batched rung's trajectory to
    /// rounding (gemv ↔ csrmv swap), reaches the same accuracy, and is
    /// bit-identical across worker counts.
    #[test]
    fn csr_matches_dense_batched_and_threads() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut e = Mt19937::new(8);
        let (mut xd, y) = make_classification(&mut e, 900, 8, 2.0);
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 2 == 1 {
                *v = 0.0;
            }
        }
        let xs = CsrMatrix::from_dense(&xd, 0.0, IndexBase::One);
        let cv = ctx(Backend::Vectorized);
        let params = || LogisticRegression::params().epochs(15);
        let md = params().train(&cv, &xd, &y).unwrap();
        let ms = params().train(&cv, &xs, &y).unwrap();
        for (a, b) in md.coef.iter().zip(&ms.coef) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert!((md.intercept - ms.intercept).abs() < 1e-6);
        let acc = crate::metrics::accuracy(&ms.infer(&cv, &xs).unwrap(), &y);
        assert!(acc > 0.93, "acc={acc}");
        // Sparse probabilities match dense probabilities of one model.
        let ps = ms.predict_proba(&cv, &xs).unwrap();
        let pd = ms.predict_proba(&cv, &xd).unwrap();
        for (a, b) in ps.iter().zip(&pd) {
            assert!((a - b).abs() < 1e-9);
        }
        // 1–4-worker bit-identity of sparse train + proba.
        let mk = |t: usize| {
            Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Vectorized)
                .threads(t)
                .build()
                .unwrap()
        };
        let m1 = params().train(&mk(1), &xs, &y).unwrap();
        let p1 = m1.predict_proba(&mk(1), &xs).unwrap();
        for threads in 2..=4 {
            let m = params().train(&mk(threads), &xs, &y).unwrap();
            for (a, b) in m1.coef.iter().zip(&m.coef) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
            assert_eq!(m1.intercept.to_bits(), m.intercept.to_bits(), "threads={threads}");
            let p = m.predict_proba(&mk(threads), &xs).unwrap();
            for (a, b) in p1.iter().zip(&p) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn sigmoid_stable_at_extremes() {
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn label_validation() {
        let c = ctx(Backend::Vectorized);
        let x = DenseTable::<f64>::zeros(4, 2);
        assert!(LogisticRegression::params().train(&c, &x, &[0.0, 1.0, 2.0, 0.0]).is_err());
        assert!(LogisticRegression::params().train(&c, &x, &[0.0, 1.0]).is_err());
    }
}
