//! Brute-force k-nearest-neighbours classifier — the distance-bound
//! workload of Fig. 3 (RNG comparison uses KNN) and Figs. 5–6 ("KNN-based
//! algorithms achieve consistent speedups up to 1.5×").
//!
//! Backend ladder: naive = per-query full distance vector + full sort
//! (NaN-safe `total_cmp` order, so NaN features degrade to
//! sorted-last instead of panicking); reference/vectorized = the shared
//! fused pairwise-distance engine ([`crate::primitives::distances`]):
//! the training corpus packed once per call, query tiles streamed
//! through the worker pool, and the bounded top-k selection fused onto
//! each cache-hot distance tile.
//!
//! Both the reference set and the queries may be CSR
//! ([`crate::tables::TableRef`]): the corpus is packed **once at
//! `train` time** into a model-resident
//! [`crate::primitives::packed::ModelPanel`] (prepacked micro-panels +
//! transposed view for dense corpora; densified-transposed view + the
//! `O(nnz)` CSR transpose for sparse ones), so `kneighbors` is
//! pack-free for every layout pairing — including dense queries
//! against a CSR corpus, which run the sparse end-to-end
//! `csrmm(Transpose)` cross term instead of densifying. Under
//! `Backend::Naive` everything densifies — the sparse paths' test
//! oracle.

use crate::blas::sqdist;
use crate::coordinator::{Backend, Context};
use crate::error::Result;
use crate::primitives::distances;
use crate::primitives::packed::ModelPanel;
use crate::tables::{DenseTable, Table, TableRef};
use crate::validate;

/// Parameters (oneDAL `kdtree_knn_classification`-style, brute force).
#[derive(Clone, Debug)]
pub struct KnnParams {
    pub k: usize,
}

pub struct KnnClassifier;

impl KnnClassifier {
    pub fn params() -> KnnParams {
        KnnParams { k: 5 }
    }
}

/// "Training" stores the reference set (brute-force KNN is lazy) in
/// whichever layout it arrived, plus the corpus packed once into a
/// model-resident [`ModelPanel`] so queries never re-pack.
#[derive(Clone, Debug)]
pub struct KnnModel {
    pub k: usize,
    pub x: Table,
    pub y: Vec<f64>,
    pub classes: usize,
    panel: ModelPanel,
}

impl KnnParams {
    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn train<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
        y: &[f64],
    ) -> Result<KnnModel> {
        let x = x.into();
        validate::non_empty(x.rows(), x.cols(), "knn")?;
        validate::labels_match(x.rows(), y.len(), "knn")?;
        validate::k_in_range(self.k, x.rows(), "k", "knn")?;
        // Training is where the pack now happens (PAL-QUAR covers it):
        // the corpus is packed once into the model-resident panel, and
        // every later query borrows it.
        let threads = ctx.threads();
        let profile = ctx.lane_profile();
        crate::parallel::quarantine("knn.train", || {
            let classes = y.iter().fold(0.0f64, |a, &b| a.max(b)) as usize + 1;
            let panel = ModelPanel::from_table_profile(x, profile, threads);
            Ok(KnnModel { k: self.k, x: x.to_table(), y: y.to_vec(), classes, panel })
        })
    }
}

impl KnnModel {
    /// Predict class labels for each query row (majority vote, ties to
    /// the lower class id — deterministic across backends).
    pub fn infer<'a>(&self, ctx: &Context, q: impl Into<TableRef<'a>>) -> Result<Vec<f64>> {
        let q = q.into();
        let neighbours = self.kneighbors(ctx, q)?;
        Ok(self.vote(&neighbours))
    }

    /// Majority vote over neighbour sets, ties to the lower class id —
    /// deterministic across backends and serving rungs.
    fn vote(&self, neighbours: &[Vec<(usize, f64)>]) -> Vec<f64> {
        let mut out = Vec::with_capacity(neighbours.len());
        let mut votes = vec![0usize; self.classes];
        for row in neighbours {
            votes.iter_mut().for_each(|v| *v = 0);
            for &(idx, _) in row {
                votes[self.y[idx] as usize] += 1;
            }
            // `classes >= 1` always (labels exist), so the fold yields
            // a real argmax.
            let best = votes
                .iter()
                .enumerate()
                .fold((0usize, 0usize), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc })
                .0;
            out.push(best as f64);
        }
        out
    }

    /// The k nearest `(train_index, sqdist)` per query, ascending.
    pub fn kneighbors<'a>(
        &self,
        ctx: &Context,
        q: impl Into<TableRef<'a>>,
    ) -> Result<Vec<Vec<(usize, f64)>>> {
        let q = q.into();
        validate::dims_match(self.x.cols(), q.cols(), "knn")?;
        let dims = [q.rows().min(256), self.x.rows(), q.cols()];
        let naive = matches!(ctx.dispatch("pairwise_sqdist", &dims), Backend::Naive);
        let t = ctx.threads();
        crate::parallel::quarantine("knn.kneighbors", || {
            if naive {
                // Densified naive rung — the packed paths' oracle.
                return Ok(match (self.x.view(), q) {
                    (TableRef::Dense(x), TableRef::Dense(qd)) => {
                        kneighbors_naive(x, qd, self.k)
                    }
                    (corpus, query) => {
                        kneighbors_naive(&corpus.to_dense(), &query.to_dense(), self.k)
                    }
                });
            }
            // Every non-naive layout pairing borrows the panel packed
            // at train time — no per-call corpus packing.
            distances::top_k_packed(q, &self.panel, self.k, t)
        })
    }

    /// The model-resident packed corpus (built once at `train` time).
    pub fn panel(&self) -> &ModelPanel {
        &self.panel
    }
}

impl crate::coordinator::serve::ServeModel for KnnModel {
    fn serve_dims(&self) -> usize {
        self.x.cols()
    }

    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
        // Majority-vote class per row; `infer` is quarantined.
        self.infer(ctx, q)
    }

    fn serve_batch_rung(
        &self,
        ctx: &Context,
        q: &DenseTable<f64>,
        rung: crate::coordinator::serve::ServeRung,
    ) -> Result<Vec<f64>> {
        use crate::coordinator::serve::ServeRung;
        match rung {
            ServeRung::Packed => self.serve_batch(ctx, q),
            ServeRung::Repack => {
                // Degraded rung: re-pack the corpus per call (CSR
                // corpora densify first), bypassing the model-resident
                // panel the circuit breaker suspects. Neighbour index
                // sets — and therefore class labels — match the packed
                // path.
                let dense = self.x.view().to_dense();
                let corpus = distances::pack_corpus_table_profile(
                    &dense,
                    ctx.lane_profile(),
                    ctx.threads(),
                );
                let nn = distances::top_k(q.data(), q.rows(), &corpus, self.k, ctx.threads());
                Ok(self.vote(&nn))
            }
            ServeRung::Naive => {
                // Last rung before fast-reject: densified scalar
                // oracle — full distance vector + total_cmp sort.
                let dense = self.x.view().to_dense();
                Ok(self.vote(&kneighbors_naive(&dense, q, self.k)))
            }
        }
    }
}

/// Naive rung: full distance vector + full sort per query. The sort is
/// `total_cmp`-ordered (IEEE totalOrder): a NaN feature makes its
/// distances NaN, which sort **last** deterministically — never a
/// panic (the old `partial_cmp(..).unwrap()` aborted mid-sort).
fn kneighbors_naive(x: &DenseTable<f64>, q: &DenseTable<f64>, k: usize) -> Vec<Vec<(usize, f64)>> {
    let mut out = Vec::with_capacity(q.rows());
    for i in 0..q.rows() {
        let mut dists: Vec<(usize, f64)> =
            (0..x.rows()).map(|j| (j, sqdist(q.row(i), x.row(j)))).collect();
        dists.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        dists.truncate(k);
        out.push(dists);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::sparse::{CsrMatrix, IndexBase};
    use crate::tables::synth::make_blobs;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    #[test]
    fn classifies_separated_blobs() {
        let mut e = Mt19937::new(1);
        let (x, labels) = make_blobs(&mut e, 400, 6, 3, 0.5);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let c = ctx(Backend::Vectorized);
        let model = KnnClassifier::params().k(5).train(&c, &x, &y).unwrap();
        let pred = model.infer(&c, &x).unwrap();
        let acc = crate::metrics::accuracy(&pred, &y);
        assert!(acc > 0.98, "acc={acc}");
    }

    #[test]
    fn naive_and_tiled_agree() {
        let mut e = Mt19937::new(2);
        let (x, labels) = make_blobs(&mut e, 150, 4, 3, 2.0);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let (q, _) = make_blobs(&mut e, 40, 4, 3, 2.0);
        let cn = ctx(Backend::Naive);
        let cv = ctx(Backend::Vectorized);
        let model = KnnClassifier::params().k(7).train(&cv, &x, &y).unwrap();
        let nn_naive = model.kneighbors(&cn, &q).unwrap();
        let nn_tiled = model.kneighbors(&cv, &q).unwrap();
        for (a, b) in nn_naive.iter().zip(&nn_tiled) {
            let ia: Vec<usize> = a.iter().map(|p| p.0).collect();
            let ib: Vec<usize> = b.iter().map(|p| p.0).collect();
            assert_eq!(ia, ib);
        }
        assert_eq!(model.infer(&cn, &q).unwrap(), model.infer(&cv, &q).unwrap());
    }

    #[test]
    fn k1_returns_self_on_train_set() {
        let mut e = Mt19937::new(3);
        let (x, labels) = make_blobs(&mut e, 60, 3, 2, 1.0);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let c = ctx(Backend::Vectorized);
        let model = KnnClassifier::params().k(1).train(&c, &x, &y).unwrap();
        let nn = model.kneighbors(&c, &x).unwrap();
        for (i, row) in nn.iter().enumerate() {
            assert_eq!(row[0].0, i);
            assert!(row[0].1 < 1e-9);
        }
    }

    /// Every (corpus, query) layout pairing returns the densified naive
    /// rung's neighbour sets.
    #[test]
    fn csr_layout_pairings_match_densified_oracle() {
        let mut e = Mt19937::new(9);
        let (mut xd, labels) = make_blobs(&mut e, 160, 5, 3, 1.0);
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 3 == 1 {
                *v = 0.0;
            }
        }
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let (mut qd, _) = make_blobs(&mut e, 50, 5, 3, 1.0);
        for (i, v) in qd.data_mut().iter_mut().enumerate() {
            if i % 4 == 2 {
                *v = 0.0;
            }
        }
        let xs = CsrMatrix::from_dense(&xd, 0.0, IndexBase::One);
        let qs = CsrMatrix::from_dense(&qd, 0.0, IndexBase::Zero);
        let cn = ctx(Backend::Naive);
        let cv = ctx(Backend::Vectorized);
        let dense_model = KnnClassifier::params().k(6).train(&cv, &xd, &y).unwrap();
        let csr_model = KnnClassifier::params().k(6).train(&cv, &xs, &y).unwrap();
        let oracle = dense_model.kneighbors(&cn, &qd).unwrap();
        let idx = |nn: &Vec<Vec<(usize, f64)>>| -> Vec<Vec<usize>> {
            nn.iter().map(|r| r.iter().map(|p| p.0).collect()).collect()
        };
        let want = idx(&oracle);
        for (model, query) in [
            (&dense_model, TableRef::from(&qs)),
            (&csr_model, TableRef::from(&qs)),
            (&csr_model, TableRef::from(&qd)),
        ] {
            let got = model.kneighbors(&cv, query).unwrap();
            assert_eq!(idx(&got), want);
        }
        // Predictions agree across layouts too.
        let p_oracle = dense_model.infer(&cn, &qd).unwrap();
        assert_eq!(csr_model.infer(&cv, &qs).unwrap(), p_oracle);
    }

    /// A NaN feature value must never panic either rung. The naive
    /// sort now runs the `total_cmp` total order, so NaN distances sort
    /// deterministically **last**; the fused rung stays deterministic
    /// too (bit-identical across worker counts). The rungs are *not*
    /// cross-compared on the poisoned row — the fused engine's
    /// `max(0.0)` clamp maps a NaN distance to 0 while the naive sort
    /// parks it at the end; both are documented, deterministic
    /// degradations.
    #[test]
    fn nan_features_degrade_without_panic() {
        let mut e = Mt19937::new(12);
        let (mut x, labels) = make_blobs(&mut e, 40, 3, 2, 0.5);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let last = x.rows() - 1;
        x.row_mut(last)[0] = f64::NAN;
        let cn = ctx(Backend::Naive);
        let model = KnnClassifier::params().k(3).train(&cn, &x, &y).unwrap();
        let (q, _) = make_blobs(&mut e, 10, 3, 2, 0.5);
        // Naive rung: no panic, poisoned row excluded (NaN sorts last),
        // distances finite.
        let nn_naive = model.kneighbors(&cn, &q).unwrap();
        for a in &nn_naive {
            assert!(a.iter().all(|p| p.0 != last && p.1.is_finite()));
        }
        // Full-k: the NaN row is selected — at the deterministic end.
        let all = KnnClassifier::params().k(40).train(&cn, &x, &y).unwrap();
        let nn = all.kneighbors(&cn, &q).unwrap();
        assert_eq!(nn[0].len(), 40);
        assert_eq!(nn[0].last().unwrap().0, last, "NaN distance sorts last");
        // Fused rung: no panic, deterministic across worker counts.
        let mk = |t: usize| {
            Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Vectorized)
                .threads(t)
                .build()
                .unwrap()
        };
        let base = model.kneighbors(&mk(1), &q).unwrap();
        for threads in 2..=4 {
            let nn = model.kneighbors(&mk(threads), &q).unwrap();
            for (a, b) in base.iter().zip(&nn) {
                assert_eq!(a.len(), b.len());
                for (p, r) in a.iter().zip(b) {
                    assert_eq!(p.0, r.0, "threads={threads}");
                    assert_eq!(p.1.to_bits(), r.1.to_bits(), "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn param_validation() {
        let c = ctx(Backend::Naive);
        let x = DenseTable::from_vec(vec![0.0; 6], 3, 2).unwrap();
        let y = vec![0.0, 1.0, 0.0];
        assert!(KnnClassifier::params().k(0).train(&c, &x, &y).is_err());
        assert!(KnnClassifier::params().k(4).train(&c, &x, &y).is_err());
        assert!(KnnClassifier::params().k(2).train(&c, &x, &y[..2]).is_err());
    }
}
