//! Brute-force k-nearest-neighbours classifier — the distance-bound
//! workload of Fig. 3 (RNG comparison uses KNN) and Figs. 5–6 ("KNN-based
//! algorithms achieve consistent speedups up to 1.5×").
//!
//! Backend ladder: naive = per-query full distance vector + full sort;
//! reference/vectorized = the shared fused pairwise-distance engine
//! ([`crate::primitives::distances`]): the training corpus packed once
//! per call, query tiles streamed through the worker pool, and the
//! bounded top-k selection fused onto each cache-hot distance tile.

use crate::blas::sqdist;
use crate::coordinator::{Backend, Context};
use crate::error::{Error, Result};
use crate::primitives::distances;
use crate::tables::DenseTable;

/// Parameters (oneDAL `kdtree_knn_classification`-style, brute force).
#[derive(Clone, Debug)]
pub struct KnnParams {
    pub k: usize,
}

pub struct KnnClassifier;

impl KnnClassifier {
    pub fn params() -> KnnParams {
        KnnParams { k: 5 }
    }
}

/// "Training" stores the reference set (brute-force KNN is lazy).
#[derive(Clone, Debug)]
pub struct KnnModel {
    pub k: usize,
    pub x: DenseTable<f64>,
    pub y: Vec<f64>,
    pub classes: usize,
}

impl KnnParams {
    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn train(&self, _ctx: &Context, x: &DenseTable<f64>, y: &[f64]) -> Result<KnnModel> {
        if x.rows() != y.len() {
            return Err(Error::Shape("knn: label count mismatch".into()));
        }
        if self.k == 0 || self.k > x.rows() {
            return Err(Error::Param(format!("knn: k={} out of range", self.k)));
        }
        let classes = y.iter().fold(0.0f64, |a, &b| a.max(b)) as usize + 1;
        Ok(KnnModel { k: self.k, x: x.clone(), y: y.to_vec(), classes })
    }
}

impl KnnModel {
    /// Predict class labels for each query row (majority vote, ties to
    /// the lower class id — deterministic across backends).
    pub fn infer(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
        if q.cols() != self.x.cols() {
            return Err(Error::Shape("knn: query dim mismatch".into()));
        }
        let neighbours = self.kneighbors(ctx, q)?;
        let mut out = Vec::with_capacity(q.rows());
        let mut votes = vec![0usize; self.classes];
        for row in &neighbours {
            votes.iter_mut().for_each(|v| *v = 0);
            for &(idx, _) in row {
                votes[self.y[idx] as usize] += 1;
            }
            let best =
                votes.iter().enumerate().max_by_key(|&(i, &v)| (v, usize::MAX - i)).unwrap().0;
            out.push(best as f64);
        }
        Ok(out)
    }

    /// The k nearest `(train_index, sqdist)` per query, ascending.
    pub fn kneighbors(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<Vec<(usize, f64)>>> {
        match ctx.dispatch("pairwise_sqdist", &[q.rows().min(256), self.x.rows(), q.cols()]) {
            Backend::Naive => Ok(self.kneighbors_naive(q)),
            _ => Ok(self.kneighbors_fused(q, ctx.threads())),
        }
    }

    /// Naive: full distance vector + full sort per query.
    fn kneighbors_naive(&self, q: &DenseTable<f64>) -> Vec<Vec<(usize, f64)>> {
        let mut out = Vec::with_capacity(q.rows());
        for i in 0..q.rows() {
            let mut dists: Vec<(usize, f64)> =
                (0..self.x.rows()).map(|j| (j, sqdist(q.row(i), self.x.row(j)))).collect();
            dists.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            dists.truncate(self.k);
            out.push(dists);
        }
        out
    }

    /// Fused-engine rung: the training corpus is packed **once per
    /// call** (the old tiled path re-packed X for every 128-row query
    /// tile) and re-used by every query M-tile streamed through the
    /// worker pool; the bounded top-k selection runs on each distance
    /// tile while it is cache-hot. Bit-identical at any worker count.
    fn kneighbors_fused(&self, q: &DenseTable<f64>, threads: usize) -> Vec<Vec<(usize, f64)>> {
        let corpus = distances::pack_corpus_table(&self.x, threads);
        distances::top_k(q.data(), q.rows(), &corpus, self.k, threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_blobs;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    #[test]
    fn classifies_separated_blobs() {
        let mut e = Mt19937::new(1);
        let (x, labels) = make_blobs(&mut e, 400, 6, 3, 0.5);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let c = ctx(Backend::Vectorized);
        let model = KnnClassifier::params().k(5).train(&c, &x, &y).unwrap();
        let pred = model.infer(&c, &x).unwrap();
        let acc = crate::metrics::accuracy(&pred, &y);
        assert!(acc > 0.98, "acc={acc}");
    }

    #[test]
    fn naive_and_tiled_agree() {
        let mut e = Mt19937::new(2);
        let (x, labels) = make_blobs(&mut e, 150, 4, 3, 2.0);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let (q, _) = make_blobs(&mut e, 40, 4, 3, 2.0);
        let cn = ctx(Backend::Naive);
        let cv = ctx(Backend::Vectorized);
        let model = KnnClassifier::params().k(7).train(&cv, &x, &y).unwrap();
        let nn_naive = model.kneighbors(&cn, &q).unwrap();
        let nn_tiled = model.kneighbors(&cv, &q).unwrap();
        for (a, b) in nn_naive.iter().zip(&nn_tiled) {
            let ia: Vec<usize> = a.iter().map(|p| p.0).collect();
            let ib: Vec<usize> = b.iter().map(|p| p.0).collect();
            assert_eq!(ia, ib);
        }
        assert_eq!(model.infer(&cn, &q).unwrap(), model.infer(&cv, &q).unwrap());
    }

    #[test]
    fn k1_returns_self_on_train_set() {
        let mut e = Mt19937::new(3);
        let (x, labels) = make_blobs(&mut e, 60, 3, 2, 1.0);
        let y: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
        let c = ctx(Backend::Vectorized);
        let model = KnnClassifier::params().k(1).train(&c, &x, &y).unwrap();
        let nn = model.kneighbors(&c, &x).unwrap();
        for (i, row) in nn.iter().enumerate() {
            assert_eq!(row[0].0, i);
            assert!(row[0].1 < 1e-9);
        }
    }

    #[test]
    fn param_validation() {
        let c = ctx(Backend::Naive);
        let x = DenseTable::from_vec(vec![0.0; 6], 3, 2).unwrap();
        let y = vec![0.0, 1.0, 0.0];
        assert!(KnnClassifier::params().k(0).train(&c, &x, &y).is_err());
        assert!(KnnClassifier::params().k(4).train(&c, &x, &y).is_err());
        assert!(KnnClassifier::params().k(2).train(&c, &x, &y[..2]).is_err());
    }
}
