//! KMeans (Lloyd's algorithm + kmeans++ seeding) — the clustering
//! workload of Fig. 3 (RNG backends), Fig. 6 (2.75× over MKL) and the
//! TPC-AI customer-segmentation case of Fig. 8.
//!
//! Backend ladder:
//! * naive      — per-point per-centroid scalar distance loop with a
//!                fresh allocation per point (stock-sklearn analogue);
//! * reference  — the shared fused distance engine
//!                ([`crate::primitives::distances`]) with the branchy
//!                scalar argmin epilogue;
//! * vectorized — the same engine with the predicated lane-unrolled
//!                argmin epilogue (lane count from the context's
//!                [`crate::primitives::lanes::LaneProfile`]) consumed
//!                while the tile is cache-hot;
//! * artifact   — the `kmeans_assign` Pallas kernel via PJRT, tiled by
//!                the coordinator's fixed-shape batcher.
//!
//! Entry points take [`TableRef`], so CSR tables train and infer too:
//! the assignment pass runs the engine's sparse query path (centroids —
//! dense by construction — packed once per pass as the
//! [`distances::CsrCorpus`], same argmin epilogues, bit-identical at
//! any worker count), the update scatter accumulates only the stored
//! values, and `Backend::Naive` densifies first — the sparse paths'
//! test oracle. No sparse Pallas kernel exists, so `Artifact` contexts
//! fall back to the vectorized sparse path for CSR inputs.
//!
//! Two pack/compute hoists keep the hot loops lean:
//!
//! * the query-side norms `‖x‖²` are constant across Lloyd iterations
//!   (only the centroids move), so both training loops compute them
//!   once before the loop and feed the `*_with_norms` engine entry
//!   points — bit-identical to the per-iteration recompute, tested;
//! * the final centroids are packed once at `train` time into a
//!   model-resident [`ModelPanel`], so `infer` is pack-free for both
//!   query layouts ([`distances::argmin_packed`]).

use crate::blas::sqdist;
use crate::coordinator::{batch, Backend, Context, ConvergenceStatus};
use crate::error::{Error, Result};
use crate::parallel;
use crate::primitives::distances;
use crate::primitives::lanes::LaneProfile;
use crate::primitives::packed::ModelPanel;
use crate::rng::{distributions::sample_indices, Engine, Mt19937, Uniform};
use crate::rng::Distribution;
use crate::sparse::CsrMatrix;
use crate::tables::{DenseTable, TableRef};

/// Centroid initialization strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KMeansInit {
    /// Uniform random rows (the paper's Fig. 3 RNG-sensitive path).
    Random,
    /// kmeans++ D² weighting.
    PlusPlus,
}

/// Parameter object (oneDAL `kmeans::Batch` analogue).
#[derive(Clone, Debug)]
pub struct KMeansParams {
    pub k: usize,
    pub max_iter: usize,
    pub tol: f64,
    pub seed: u32,
    pub init: KMeansInit,
}

/// Entry point: `KMeans::params()`.
pub struct KMeans;

impl KMeans {
    pub fn params() -> KMeansParams {
        KMeansParams { k: 8, max_iter: 100, tol: 1e-6, seed: 7777, init: KMeansInit::PlusPlus }
    }
}

/// Trained model.
#[derive(Clone, Debug)]
pub struct KMeansModel {
    pub centroids: DenseTable<f64>,
    pub inertia: f64,
    pub iterations: usize,
    /// How training ended: tolerance met (`Converged`), `max_iter` or a
    /// budget iteration cap exhausted (`IterLimit`), or the context's
    /// wall-time deadline expired (`DeadlineExceeded`). The centroids
    /// are the last completed Lloyd iterate in every case.
    pub status: ConvergenceStatus,
    /// Final centroids prepacked at `train` time (micro-panels +
    /// pooled norms + transposed view), so [`KMeansModel::infer`] is
    /// pack-free for both query layouts.
    panel: ModelPanel,
}

/// One kmeans++ draw from the D² distribution (uniform fallback when
/// all mass is zero) — shared by the dense and CSR seeders so the
/// weighted-pick arithmetic can never diverge between layouts.
fn d2_weighted_pick(e: &mut dyn Engine, u: &mut Uniform<f64>, d2: &[f64]) -> usize {
    let n = d2.len();
    let total: f64 = d2.iter().sum();
    if total <= 0.0 {
        // All points coincide with a center: fall back to uniform.
        return (u.sample(e) * n as f64) as usize % n;
    }
    let mut target = u.sample(e) * total;
    let mut pick = n - 1;
    for (i, &w) in d2.iter().enumerate() {
        target -= w;
        if target <= 0.0 {
            pick = i;
            break;
        }
    }
    pick
}

/// Lloyd centroid update from per-cluster `(count, sum)` scratches:
/// occupied clusters move to their mean, empty clusters keep their
/// previous centroid. Shared by the dense and CSR training loops.
fn apply_centroid_means(centroids: &mut DenseTable<f64>, counts: &[usize], sums: &[f64]) {
    let d = centroids.cols();
    for (c, &count) in counts.iter().enumerate() {
        if count == 0 {
            continue;
        }
        let inv = 1.0 / count as f64;
        let crow = centroids.row_mut(c);
        let srow = &sums[c * d..(c + 1) * d];
        for (cv, &sv) in crow.iter_mut().zip(srow) {
            *cv = sv * inv;
        }
    }
}

impl KMeansParams {
    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn max_iter(mut self, it: usize) -> Self {
        self.max_iter = it;
        self
    }

    pub fn tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    pub fn init(mut self, init: KMeansInit) -> Self {
        self.init = init;
        self
    }

    /// Initialize centroids with a caller-supplied engine (Fig. 3 swaps
    /// the engine here: `StdCxxRng` vs OpenRNG-style `Mt19937`/`Mcg59`).
    pub fn init_centroids(
        &self,
        e: &mut dyn Engine,
        x: &DenseTable<f64>,
    ) -> Result<DenseTable<f64>> {
        let n = x.rows();
        if self.k == 0 || self.k > n {
            return Err(Error::Param(format!("k={} must be in 1..={n}", self.k)));
        }
        match self.init {
            KMeansInit::Random => {
                let idx = sample_indices(e, n, self.k);
                Ok(x.gather_rows(&idx))
            }
            KMeansInit::PlusPlus => {
                let mut centers: Vec<usize> = Vec::with_capacity(self.k);
                let mut u = Uniform::new(0.0, 1.0);
                centers.push((u.sample(e) * n as f64) as usize % n);
                let mut d2: Vec<f64> =
                    (0..n).map(|i| sqdist(x.row(i), x.row(centers[0]))).collect();
                while centers.len() < self.k {
                    let next = d2_weighted_pick(e, &mut u, &d2);
                    centers.push(next);
                    for i in 0..n {
                        d2[i] = d2[i].min(sqdist(x.row(i), x.row(next)));
                    }
                }
                Ok(x.gather_rows(&centers))
            }
        }
    }

    /// Train with the default engine derived from `seed`. Accepts
    /// either layout (`&DenseTable<f64>` or `&CsrMatrix<f64>`).
    pub fn train<'a>(&self, ctx: &Context, x: impl Into<TableRef<'a>>) -> Result<KMeansModel> {
        let mut e = Mt19937::new(self.seed);
        self.train_with_engine(ctx, x, &mut e)
    }

    /// Train with an explicit RNG engine (Fig. 3 entry point).
    pub fn train_with_engine<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
        e: &mut dyn Engine,
    ) -> Result<KMeansModel> {
        let x = x.into();
        crate::validate::non_empty(x.rows(), x.cols(), "kmeans")?;
        crate::validate::k_in_range(self.k, x.rows(), "k", "kmeans")?;
        crate::validate::non_negative_finite(self.tol, "tol", "kmeans")?;
        parallel::quarantine("kmeans.train", || match x {
            TableRef::Dense(d) => self.train_dense(ctx, d, e),
            TableRef::Csr(s) => {
                if matches!(ctx.backend(), Backend::Naive) {
                    // Densified naive rung — the sparse path's oracle.
                    self.train_dense(ctx, &s.to_dense(), e)
                } else {
                    self.train_csr(ctx, s, e)
                }
            }
        })
    }

    fn train_dense(
        &self,
        ctx: &Context,
        x: &DenseTable<f64>,
        e: &mut dyn Engine,
    ) -> Result<KMeansModel> {
        let n = x.rows();
        let mut centroids = self.init_centroids(e, x)?;
        let mut assign = vec![0usize; n];
        let mut inertia = f64::INFINITY;
        let mut iterations = 0;
        let mut status = ConvergenceStatus::IterLimit;
        let mut meter = ctx.budget().meter();
        // The query-side norms are iteration-invariant (only the
        // centroids move), so hoist them out of the Lloyd loop when the
        // fused engine will consume them. The dispatch dims are loop
        // constants, so the rung choice is too.
        let fused_rung = matches!(
            ctx.dispatch("kmeans_assign", &[n, x.cols(), self.k]),
            Backend::Reference | Backend::Vectorized | Backend::Auto
        );
        let qnorms = fused_rung
            .then(|| distances::dense_row_norms(x.data(), n, x.cols(), ctx.threads()));
        for it in 0..self.max_iter {
            if let Some(expired) = meter.check_before_iter() {
                // Budget spent: return the last completed Lloyd iterate.
                status = expired;
                break;
            }
            iterations = it + 1;
            let new_inertia = assign_step(ctx, x, &centroids, qnorms.as_deref(), &mut assign)?;
            // Update step: mean of assigned points per cluster,
            // parallelized over fixed input-keyed chunks (see
            // [`update_sums`]).
            let (counts, sums) = update_sums(x, &assign, self.k, ctx.threads());
            apply_centroid_means(&mut centroids, &counts, &sums);
            if inertia.is_finite() && (inertia - new_inertia).abs() <= self.tol * inertia.max(1.0) {
                inertia = new_inertia;
                status = ConvergenceStatus::Converged;
                break;
            }
            inertia = new_inertia;
        }
        let panel =
            ModelPanel::from_dense_table_profile(&centroids, ctx.lane_profile(), ctx.threads());
        Ok(KMeansModel { centroids, inertia, iterations, status, panel })
    }

    /// CSR training loop: the same Lloyd iteration, with the
    /// assignment pass on the engine's sparse query path (centroids
    /// packed once per pass — the centroids move every iteration; the
    /// query norms do not, and are hoisted) and the update scatter
    /// accumulating only the stored values. Bit-identical at any
    /// worker count.
    fn train_csr(
        &self,
        ctx: &Context,
        x: &CsrMatrix<f64>,
        e: &mut dyn Engine,
    ) -> Result<KMeansModel> {
        let n = x.rows();
        let d = x.cols();
        let mut centroids = self.init_centroids_csr(e, x)?;
        let predicated =
            !matches!(ctx.dispatch("kmeans_assign", &[n, d, self.k]), Backend::Reference);
        let mut assign = vec![0usize; n];
        let mut inertia = f64::INFINITY;
        let mut iterations = 0;
        let mut status = ConvergenceStatus::IterLimit;
        let mut meter = ctx.budget().meter();
        // Iteration-invariant query norms, hoisted out of the loop.
        let qnorms = distances::csr_row_norms(x, ctx.threads());
        for it in 0..self.max_iter {
            if let Some(expired) = meter.check_before_iter() {
                // Budget spent: return the last completed Lloyd iterate.
                status = expired;
                break;
            }
            iterations = it + 1;
            let corpus = distances::CsrCorpus::from_dense_profile(
                &centroids,
                ctx.lane_profile(),
                ctx.threads(),
            );
            let new_inertia = distances::argmin_assign_csr_with_norms(
                x,
                &corpus,
                &qnorms,
                predicated,
                &mut assign,
                ctx.threads(),
            );
            let (counts, sums) = update_sums_csr(x, &assign, self.k, ctx.threads());
            apply_centroid_means(&mut centroids, &counts, &sums);
            if inertia.is_finite() && (inertia - new_inertia).abs() <= self.tol * inertia.max(1.0) {
                inertia = new_inertia;
                status = ConvergenceStatus::Converged;
                break;
            }
            inertia = new_inertia;
        }
        let panel =
            ModelPanel::from_dense_table_profile(&centroids, ctx.lane_profile(), ctx.threads());
        Ok(KMeansModel { centroids, inertia, iterations, status, panel })
    }

    /// Centroid seeding for CSR inputs — the same strategies as the
    /// dense [`KMeansParams::init_centroids`]. Each candidate row is
    /// densified into a scratch before the `sqdist` call, so the D²
    /// weights (and therefore every weighted pick) carry the exact bits
    /// of the densified run.
    fn init_centroids_csr(
        &self,
        e: &mut dyn Engine,
        x: &CsrMatrix<f64>,
    ) -> Result<DenseTable<f64>> {
        let n = x.rows();
        if self.k == 0 || self.k > n {
            return Err(Error::Param(format!("k={} must be in 1..={n}", self.k)));
        }
        match self.init {
            KMeansInit::Random => {
                let idx = sample_indices(e, n, self.k);
                Ok(x.gather_rows_dense(&idx))
            }
            KMeansInit::PlusPlus => {
                fn row_d2(x: &CsrMatrix<f64>, i: usize, c: &[f64], scratch: &mut [f64]) -> f64 {
                    scratch.fill(0.0);
                    for (j, v) in x.row_entries(i) {
                        scratch[j] = v;
                    }
                    sqdist(scratch, c)
                }
                let mut centers: Vec<usize> = Vec::with_capacity(self.k);
                let mut u = Uniform::new(0.0, 1.0);
                centers.push((u.sample(e) * n as f64) as usize % n);
                let mut scratch = vec![0.0f64; x.cols()];
                let mut crow = x.gather_rows_dense(&[centers[0]]);
                let mut d2: Vec<f64> =
                    (0..n).map(|i| row_d2(x, i, crow.row(0), &mut scratch)).collect();
                while centers.len() < self.k {
                    let next = d2_weighted_pick(e, &mut u, &d2);
                    centers.push(next);
                    crow = x.gather_rows_dense(&[next]);
                    for i in 0..n {
                        d2[i] = d2[i].min(row_d2(x, i, crow.row(0), &mut scratch));
                    }
                }
                Ok(x.gather_rows_dense(&centers))
            }
        }
    }
}

impl KMeansModel {
    /// Assign each row of `x` (either layout) to its nearest centroid.
    ///
    /// Pack-free: the fused rungs borrow the model-resident
    /// [`ModelPanel`] built at `train` time ([`distances::argmin_packed`]);
    /// only the naive and artifact rungs bypass it.
    pub fn infer<'a>(&self, ctx: &Context, x: impl Into<TableRef<'a>>) -> Result<Vec<usize>> {
        let x = x.into();
        crate::validate::dims_match(self.centroids.cols(), x.cols(), "kmeans")?;
        parallel::quarantine("kmeans.infer", || {
            let dims = &[x.rows(), x.cols(), self.centroids.rows()];
            let rung = ctx.dispatch("kmeans_assign", dims);
            match x {
                TableRef::Dense(d) => match rung {
                    Backend::Naive => {
                        let mut assign = vec![0usize; d.rows()];
                        assign_naive(d, &self.centroids, &mut assign);
                        Ok(assign)
                    }
                    Backend::Artifact => {
                        let mut assign = vec![0usize; d.rows()];
                        assign_artifact(ctx, d, &self.centroids, &mut assign)?;
                        Ok(assign)
                    }
                    other => {
                        let predicated = !matches!(other, Backend::Reference);
                        let mut assign = vec![0usize; d.rows()];
                        distances::argmin_packed(
                            x,
                            &self.panel,
                            predicated,
                            &mut assign,
                            ctx.threads(),
                        )?;
                        Ok(assign)
                    }
                },
                TableRef::Csr(s) => {
                    if matches!(ctx.backend(), Backend::Naive) {
                        let dense = s.to_dense();
                        let mut assign = vec![0usize; s.rows()];
                        assign_naive(&dense, &self.centroids, &mut assign);
                        return Ok(assign);
                    }
                    let predicated = !matches!(rung, Backend::Reference);
                    let mut assign = vec![0usize; s.rows()];
                    distances::argmin_packed(
                        x,
                        &self.panel,
                        predicated,
                        &mut assign,
                        ctx.threads(),
                    )?;
                    Ok(assign)
                }
            }
        })
    }

    /// The model-resident packed centroid panel.
    pub fn panel(&self) -> &ModelPanel {
        &self.panel
    }
}

impl crate::coordinator::serve::ServeModel for KMeansModel {
    fn serve_dims(&self) -> usize {
        self.centroids.cols()
    }

    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
        // Cluster index per row, widened to the serving wire type;
        // `infer` is quarantined and pack-free.
        Ok(self.infer(ctx, q)?.into_iter().map(|c| c as f64).collect())
    }

    fn serve_batch_rung(
        &self,
        ctx: &Context,
        q: &DenseTable<f64>,
        rung: crate::coordinator::serve::ServeRung,
    ) -> Result<Vec<f64>> {
        use crate::coordinator::serve::ServeRung;
        match rung {
            ServeRung::Packed => self.serve_batch(ctx, q),
            ServeRung::Repack => {
                // Degraded rung: re-pack the centroid panels per call,
                // bypassing the model-resident panel the circuit
                // breaker suspects. Same fused kernel, same bits.
                let corpus = distances::pack_corpus_table_profile(
                    &self.centroids,
                    ctx.lane_profile(),
                    ctx.threads(),
                );
                let mut assign = vec![0usize; q.rows()];
                distances::argmin_assign(
                    q.data(),
                    q.rows(),
                    &corpus,
                    true,
                    &mut assign,
                    ctx.threads(),
                );
                Ok(assign.into_iter().map(|c| c as f64).collect())
            }
            ServeRung::Naive => {
                // Last rung before fast-reject: the scalar oracle,
                // no packing, no pool fan-out state.
                let mut assign = vec![0usize; q.rows()];
                assign_naive(q, &self.centroids, &mut assign);
                Ok(assign.into_iter().map(|c| c as f64).collect())
            }
        }
    }
}

/// Fixed chunk count of the parallel centroid-update scatter. Chunk
/// boundaries depend only on the input size — never on the worker
/// count — so partial sums and the ordered merge replay identically
/// at any parallelism (the same invariant as the sparse Transpose
/// kernels).
const UPDATE_CHUNKS: usize = 8;
/// Minimum accumulate work before per-chunk scratches pay for their
/// zero-fill and merge.
const UPDATE_MIN_WORK: usize = 1 << 14;

/// Centroid update scatter: per-cluster point counts and coordinate
/// sums. Points scatter into their assigned cluster's row, so workers
/// cannot own disjoint output rows; instead the rows of `x` are cut
/// into a fixed, input-keyed set of chunks, each chunk accumulates into
/// a private `(counts, sums)` scratch in row order, and the scratches
/// merge in ascending chunk order — bit-identical across 1–N workers.
fn update_sums(
    x: &DenseTable<f64>,
    assign: &[usize],
    k: usize,
    threads: usize,
) -> (Vec<usize>, Vec<f64>) {
    let n = x.rows();
    let d = x.cols();
    let mut counts = vec![0usize; k];
    let mut sums = vec![0.0f64; k * d];
    let work = n.saturating_mul(d);
    let chunks = if work < UPDATE_MIN_WORK || work < UPDATE_CHUNKS.saturating_mul(k * d) {
        1
    } else {
        UPDATE_CHUNKS.min(n.max(1))
    };
    let accumulate = |lo: usize, hi: usize, counts: &mut [usize], sums: &mut [f64]| {
        for i in lo..hi {
            let c = assign[i];
            counts[c] += 1;
            let srow = &mut sums[c * d..(c + 1) * d];
            for (s, &v) in srow.iter_mut().zip(x.row(i)) {
                *s += v;
            }
        }
    };
    if chunks == 1 {
        accumulate(0, n, &mut counts, &mut sums);
        return (counts, sums);
    }
    let cbounds = parallel::even_bounds(n, chunks);
    let nchunks = cbounds.len() - 1;
    let workers = parallel::effective_threads(threads, nchunks, 1);
    let wbounds = parallel::even_bounds(nchunks, workers);
    let (cbounds, accumulate) = (&cbounds, &accumulate);
    let partials = parallel::par_map(&wbounds, |clo, chi| {
        (clo..chi)
            .map(|ci| {
                let mut pc = vec![0usize; k];
                let mut ps = vec![0.0f64; k * d];
                accumulate(cbounds[ci], cbounds[ci + 1], &mut pc, &mut ps);
                (pc, ps)
            })
            .collect::<Vec<_>>()
    });
    // Deterministic ascending-chunk merge.
    for (pc, ps) in partials.into_iter().flatten() {
        for (c, &cnt) in pc.iter().enumerate() {
            counts[c] += cnt;
        }
        for (sv, &pv) in sums.iter_mut().zip(&ps) {
            *sv += pv;
        }
    }
    (counts, sums)
}

/// [`update_sums`] for CSR inputs: identical input-keyed chunking and
/// ascending-chunk merge, accumulating only the stored values (an
/// implicit zero adds nothing to a coordinate sum). Bit-identical
/// across 1–N workers.
fn update_sums_csr(
    x: &CsrMatrix<f64>,
    assign: &[usize],
    k: usize,
    threads: usize,
) -> (Vec<usize>, Vec<f64>) {
    let n = x.rows();
    let d = x.cols();
    let mut counts = vec![0usize; k];
    let mut sums = vec![0.0f64; k * d];
    let work = x.nnz().max(n);
    let chunks = if work < UPDATE_MIN_WORK || work < UPDATE_CHUNKS.saturating_mul(k * d) {
        1
    } else {
        UPDATE_CHUNKS.min(n.max(1))
    };
    let accumulate = |lo: usize, hi: usize, counts: &mut [usize], sums: &mut [f64]| {
        for i in lo..hi {
            let c = assign[i];
            counts[c] += 1;
            let srow = &mut sums[c * d..(c + 1) * d];
            for (j, v) in x.row_entries(i) {
                srow[j] += v;
            }
        }
    };
    if chunks == 1 {
        accumulate(0, n, &mut counts, &mut sums);
        return (counts, sums);
    }
    let cbounds = parallel::even_bounds(n, chunks);
    let nchunks = cbounds.len() - 1;
    let workers = parallel::effective_threads(threads, nchunks, 1);
    let wbounds = parallel::even_bounds(nchunks, workers);
    let (cbounds, accumulate) = (&cbounds, &accumulate);
    let partials = parallel::par_map(&wbounds, |clo, chi| {
        (clo..chi)
            .map(|ci| {
                let mut pc = vec![0usize; k];
                let mut ps = vec![0.0f64; k * d];
                accumulate(cbounds[ci], cbounds[ci + 1], &mut pc, &mut ps);
                (pc, ps)
            })
            .collect::<Vec<_>>()
    });
    // Deterministic ascending-chunk merge.
    for (pc, ps) in partials.into_iter().flatten() {
        for (c, &cnt) in pc.iter().enumerate() {
            counts[c] += cnt;
        }
        for (sv, &pv) in sums.iter_mut().zip(&ps) {
            *sv += pv;
        }
    }
    (counts, sums)
}

/// One assignment pass; returns the inertia. Dispatches on the ladder.
/// `qnorms` optionally carries the hoisted query norms (the Lloyd loop
/// computes them once; one-shot callers pass `None` and the engine
/// computes them inline with the same bits).
fn assign_step(
    ctx: &Context,
    x: &DenseTable<f64>,
    centroids: &DenseTable<f64>,
    qnorms: Option<&[f64]>,
    assign: &mut [usize],
) -> Result<f64> {
    let d = x.cols();
    if centroids.cols() != d {
        return Err(Error::Shape("kmeans: centroid dim mismatch".into()));
    }
    match ctx.dispatch("kmeans_assign", &[x.rows(), d, centroids.rows()]) {
        Backend::Naive => Ok(assign_naive(x, centroids, assign)),
        Backend::Reference => {
            Ok(assign_gemm(x, centroids, qnorms, assign, false, ctx.lane_profile(), ctx.threads()))
        }
        Backend::Vectorized | Backend::Auto => {
            Ok(assign_gemm(x, centroids, qnorms, assign, true, ctx.lane_profile(), ctx.threads()))
        }
        Backend::Artifact => assign_artifact(ctx, x, centroids, assign),
    }
}

/// Naive rung: scalar distance loop, fresh Vec per row (intentional —
/// this is the allocation-heavy style of unvectorized Python-era code).
fn assign_naive(x: &DenseTable<f64>, c: &DenseTable<f64>, assign: &mut [usize]) -> f64 {
    let k = c.rows();
    let mut inertia = 0.0;
    for i in 0..x.rows() {
        let dists: Vec<f64> = (0..k).map(|j| sqdist(x.row(i), c.row(j))).collect();
        let (mut best, mut bestv) = (0usize, f64::INFINITY);
        for (j, &v) in dists.iter().enumerate() {
            if v < bestv {
                best = j;
                bestv = v;
            }
        }
        assign[i] = best;
        inertia += bestv;
    }
    inertia
}

/// Reference / vectorized rungs: one call into the shared fused
/// pairwise-distance engine ([`crate::primitives::distances`]). The
/// centroid corpus is packed once per assignment pass (micro-panels +
/// pooled norms), query M-tiles stream through the worker pool, and the
/// argmin epilogue consumes each distance tile while it is cache-hot.
/// `fused` selects the predicated lane-profile scan (vectorized rung)
/// over the branchy scalar scan (reference rung) — both produce identical
/// assignments and bit-identical inertia, and the engine's fixed-order
/// tile merge keeps assignments *and* inertia bit-stable across
/// `Context::threads()` settings.
fn assign_gemm(
    x: &DenseTable<f64>,
    c: &DenseTable<f64>,
    qnorms: Option<&[f64]>,
    assign: &mut [usize],
    fused: bool,
    profile: LaneProfile,
    threads: usize,
) -> f64 {
    let corpus = distances::pack_corpus_profile(c.data(), c.rows(), c.cols(), profile, threads);
    distances::argmin_assign_with_norms(x.data(), x.rows(), &corpus, qnorms, fused, assign, threads)
}

/// Artifact rung: run the Pallas `kmeans_assign` kernel via PJRT on
/// fixed-shape padded tiles.
fn assign_artifact(
    ctx: &Context,
    x: &DenseTable<f64>,
    c: &DenseTable<f64>,
    assign: &mut [usize],
) -> Result<f64> {
    let n = x.rows();
    let d = x.cols();
    let k = c.rows();
    // Small inputs take the tightest tile (least padding waste); large
    // inputs take the biggest row tile to amortize PJRT dispatch (§Perf).
    let registry = ctx.registry();
    let art = if n > 1024 {
        registry.largest_tile_fit("kmeans_assign", &[n, d, k])
    } else {
        registry.best_fit("kmeans_assign", &[n, d, k])
    }
    .or_else(|| registry.best_fit("kmeans_assign", &[n.min(1024), d, k]))
    .ok_or_else(|| Error::MissingArtifact("kmeans_assign".into()))?
    .clone();
    let rt = ctx
        .runtime()
        .ok_or_else(|| Error::Runtime("artifact backend without runtime".into()))?;
    let (tn, td, tk) = (art.dims[0], art.dims[1], art.dims[2]);
    // Pad centroids once per call. Padding centroids sit at +inf distance
    // via the kernel's k-mask, so they are never selected.
    let cf: Vec<f32> = c.data().iter().map(|&v| v as f32).collect();
    let cpad = batch::pad_to(&cf, k, d, tk, td);
    let mut inertia = 0.0f64;
    let xf: Vec<f32> = x.data().iter().map(|&v| v as f32).collect();
    for (start, len) in batch::tiles(n, tn) {
        let xpad = batch::pad_to(&xf[start * d..(start + len) * d], len, d, tn, td);
        let valid = [len as f32, k as f32];
        let outs = rt.execute_f32(
            &art.name,
            &[
                (&xpad.data, &[tn, td]),
                (&cpad.data, &[tk, td]),
                (&valid, &[2]),
            ],
        )?;
        // outputs: assignments f32[tn], min-distances f32[tn]
        let a = &outs[0];
        let dist = &outs[1];
        for i in 0..len {
            assign[start + i] = a[i] as usize;
            inertia += f64::from(dist[i]).max(0.0);
        }
    }
    Ok(inertia)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::synth::make_blobs;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    #[test]
    fn recovers_well_separated_blobs() {
        let mut e = Mt19937::new(1);
        let (x, truth) = make_blobs(&mut e, 600, 5, 3, 0.3);
        let ctx = ctx(Backend::Vectorized);
        let model = KMeans::params().k(3).seed(5).train(&ctx, &x).unwrap();
        let assign = model.infer(&ctx, &x).unwrap();
        // Cluster purity: every predicted cluster maps to one true label.
        let mut purity = 0usize;
        for c in 0..3 {
            let mut counts = [0usize; 3];
            for i in 0..600 {
                if assign[i] == c {
                    counts[truth[i]] += 1;
                }
            }
            purity += counts.iter().max().unwrap();
        }
        assert!(purity as f64 / 600.0 > 0.95, "purity {}", purity as f64 / 600.0);
    }

    #[test]
    fn backends_agree_on_assignment() {
        let mut e = Mt19937::new(2);
        let (x, _) = make_blobs(&mut e, 300, 7, 4, 1.0);
        let naive = ctx(Backend::Naive);
        let refr = ctx(Backend::Reference);
        let vect = ctx(Backend::Vectorized);
        let model = KMeans::params().k(4).seed(9).train(&vect, &x).unwrap();
        let a1 = model.infer(&naive, &x).unwrap();
        let a2 = model.infer(&refr, &x).unwrap();
        let a3 = model.infer(&vect, &x).unwrap();
        assert_eq!(a1, a2);
        assert_eq!(a2, a3);
    }

    #[test]
    fn assignment_and_inertia_bit_stable_across_threads() {
        let mut e = Mt19937::new(8);
        let (x, _) = make_blobs(&mut e, 6_000, 8, 6, 1.0);
        let ctxv = ctx(Backend::Vectorized);
        let model = KMeans::params().k(6).seed(2).max_iter(5).train(&ctxv, &x).unwrap();
        let mut a1 = vec![0usize; 6_000];
        let i1 = assign_gemm(&x, &model.centroids, None, &mut a1, true, LaneProfile::Sve512, 1);
        for threads in 2..=4 {
            let mut a = vec![0usize; 6_000];
            let it =
                assign_gemm(&x, &model.centroids, None, &mut a, true, LaneProfile::Sve512, threads);
            assert_eq!(a, a1, "threads={threads}");
            assert_eq!(it.to_bits(), i1.to_bits(), "threads={threads}");
        }
    }

    /// Satellite of the norm hoist: feeding precomputed query norms
    /// into the assignment pass is bit-identical to the inline
    /// computation — the hoisted reduction shares the engine's exact
    /// per-row `dot` bits.
    #[test]
    fn hoisted_query_norms_do_not_change_assignment_bits() {
        let mut e = Mt19937::new(17);
        let (x, _) = make_blobs(&mut e, 900, 6, 4, 1.0);
        let ctxv = ctx(Backend::Vectorized);
        let model = KMeans::params().k(4).seed(6).max_iter(4).train(&ctxv, &x).unwrap();
        let norms = distances::dense_row_norms(x.data(), x.rows(), x.cols(), 3);
        for fused in [false, true] {
            let mut a_inline = vec![0usize; 900];
            let mut a_hoist = vec![0usize; 900];
            let i_inline = assign_gemm(
                &x,
                &model.centroids,
                None,
                &mut a_inline,
                fused,
                LaneProfile::Sve512,
                3,
            );
            let i_hoist = assign_gemm(
                &x,
                &model.centroids,
                Some(&norms),
                &mut a_hoist,
                fused,
                LaneProfile::Sve512,
                3,
            );
            assert_eq!(a_inline, a_hoist, "fused={fused}");
            assert_eq!(i_inline.to_bits(), i_hoist.to_bits(), "fused={fused}");
        }
    }

    /// Both query layouts route `infer` through the model-resident
    /// panel and land on the same assignment. (The strict zero-pack
    /// counter contract lives in `tests/serve_property.rs`, where a
    /// file-local lock serializes the counter reads; the process-global
    /// counter is racy against unrelated unit tests here.)
    #[test]
    fn panel_infer_agrees_across_query_layouts() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut e = Mt19937::new(23);
        let (x, _) = make_blobs(&mut e, 400, 5, 3, 0.5);
        let xs = CsrMatrix::from_dense(&x, 0.0, IndexBase::Zero);
        let cv = ctx(Backend::Vectorized);
        let model = KMeans::params().k(3).seed(4).max_iter(8).train(&cv, &x).unwrap();
        let a_dense = model.infer(&cv, &x).unwrap();
        let a_csr = model.infer(&cv, &xs).unwrap();
        assert_eq!(a_dense, a_csr);
        assert_eq!(model.panel().rows(), 3);
    }

    /// The centroid *update* step is now parallel too: whole trainings
    /// must be bit-identical across worker counts (chunking is
    /// input-keyed, merges run in fixed chunk order).
    #[test]
    fn training_bit_stable_across_threads() {
        let mut e = Mt19937::new(12);
        let (x, _) = make_blobs(&mut e, 6_000, 8, 5, 1.0);
        let mk_ctx = |t: usize| {
            Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Vectorized)
                .threads(t)
                .build()
                .unwrap()
        };
        let base = KMeans::params().k(5).seed(3).max_iter(6).train(&mk_ctx(1), &x).unwrap();
        for threads in 2..=4 {
            let m = KMeans::params().k(5).seed(3).max_iter(6).train(&mk_ctx(threads), &x).unwrap();
            for (u, v) in base.centroids.data().iter().zip(m.centroids.data()) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
            assert_eq!(base.inertia.to_bits(), m.inertia.to_bits(), "threads={threads}");
            assert_eq!(base.iterations, m.iterations, "threads={threads}");
        }
        // The update scatter itself, in isolation.
        let assign: Vec<usize> = (0..6_000).map(|i| i % 5).collect();
        let (c1, s1) = update_sums(&x, &assign, 5, 1);
        for threads in 2..=4 {
            let (c, s) = update_sums(&x, &assign, 5, threads);
            assert_eq!(c, c1, "threads={threads}");
            for (u, v) in s1.iter().zip(&s) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    /// CSR inputs train and infer through the sparse engine, matching
    /// the densified naive oracle (Backend::Naive densifies first) and
    /// staying bit-identical across worker counts.
    #[test]
    fn csr_matches_densified_oracle_and_threads() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut e = Mt19937::new(21);
        let (mut xd, _) = make_blobs(&mut e, 500, 6, 3, 0.3);
        // Sparsify half the entries so the CSR path is exercised for real.
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 2 == 1 {
                *v = 0.0;
            }
        }
        let xs = CsrMatrix::from_dense(&xd, 0.0, IndexBase::One);
        let cv = ctx(Backend::Vectorized);
        let cn = ctx(Backend::Naive);
        let params = || KMeans::params().k(3).seed(5).max_iter(20);
        let m_csr = params().train(&cv, &xs).unwrap();
        let m_oracle = params().train(&cn, &xs).unwrap(); // densified naive rung
        let a_csr = m_csr.infer(&cv, &xs).unwrap();
        let a_oracle = m_oracle.infer(&cn, &xs).unwrap();
        assert_eq!(a_csr, a_oracle);
        for (u, v) in m_csr.centroids.data().iter().zip(m_oracle.centroids.data()) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
        assert!((m_csr.inertia - m_oracle.inertia).abs() < 1e-8 * (1.0 + m_oracle.inertia));
        // The dense table of the same data lands on the same clustering.
        let m_dense = params().train(&cv, &xd).unwrap();
        assert_eq!(m_dense.infer(&cv, &xd).unwrap(), a_csr);
        // 1–4-worker bit-identity of the whole sparse training.
        let mk = |t: usize| {
            Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Vectorized)
                .threads(t)
                .build()
                .unwrap()
        };
        let base = params().train(&mk(1), &xs).unwrap();
        for threads in 2..=4 {
            let m = params().train(&mk(threads), &xs).unwrap();
            for (u, v) in base.centroids.data().iter().zip(m.centroids.data()) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
            assert_eq!(base.inertia.to_bits(), m.inertia.to_bits(), "threads={threads}");
            assert_eq!(base.iterations, m.iterations, "threads={threads}");
        }
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let mut e = Mt19937::new(3);
        let (x, _) = make_blobs(&mut e, 400, 4, 5, 1.5);
        let ctx = ctx(Backend::Vectorized);
        let m2 = KMeans::params().k(2).seed(1).train(&ctx, &x).unwrap();
        let m8 = KMeans::params().k(8).seed(1).train(&ctx, &x).unwrap();
        assert!(m8.inertia < m2.inertia);
    }

    #[test]
    fn k_larger_than_n_rejected() {
        let ctx = ctx(Backend::Naive);
        let x = DenseTable::from_vec(vec![0.0; 10], 5, 2).unwrap();
        assert!(KMeans::params().k(6).train(&ctx, &x).is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut e = Mt19937::new(4);
        let (x, _) = make_blobs(&mut e, 200, 3, 3, 1.0);
        let ctx = ctx(Backend::Vectorized);
        let a = KMeans::params().k(3).seed(42).train(&ctx, &x).unwrap();
        let b = KMeans::params().k(3).seed(42).train(&ctx, &x).unwrap();
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn random_init_works_too() {
        let mut e = Mt19937::new(5);
        let (x, _) = make_blobs(&mut e, 200, 3, 3, 0.5);
        let ctx = ctx(Backend::Vectorized);
        let m = KMeans::params().k(3).init(KMeansInit::Random).train(&ctx, &x).unwrap();
        assert!(m.inertia.is_finite());
        assert_eq!(m.centroids.rows(), 3);
    }
}
