//! Linear and ridge regression via the normal equations — the workloads
//! where the paper *honestly reports losses* (Fig. 5: 0.24× / 0.45× —
//! memory-bound linear algebra where vectorization of the solver cannot
//! compensate), and inference wins on Fig. 6.
//!
//! `XᵀX` is computed with the VSL `xcp` machinery's BLAS path (syrk on
//! the transposed layout), the solve with the Cholesky substrate.

use crate::blas::{gemv_threads, syrk_threads};
use crate::coordinator::{Backend, Context};
use crate::error::{Error, Result};
use crate::linalg::cholesky_solve;
use crate::tables::DenseTable;

#[derive(Clone, Debug)]
pub struct LinRegParams {
    /// L2 penalty (0 = ordinary least squares).
    pub alpha: f64,
    pub fit_intercept: bool,
}

pub struct LinearRegression;

impl LinearRegression {
    pub fn params() -> LinRegParams {
        LinRegParams { alpha: 0.0, fit_intercept: true }
    }
}

/// Ridge is the same estimator with a nonzero penalty (oneDAL exposes
/// both; the paper benches them separately on the 10M×20 grid).
pub struct RidgeRegression;

impl RidgeRegression {
    pub fn params() -> LinRegParams {
        LinRegParams { alpha: 1.0, fit_intercept: true }
    }
}

#[derive(Clone, Debug)]
pub struct LinRegModel {
    pub coef: Vec<f64>,
    pub intercept: f64,
}

impl LinRegParams {
    pub fn alpha(mut self, a: f64) -> Self {
        self.alpha = a;
        self
    }

    pub fn fit_intercept(mut self, b: bool) -> Self {
        self.fit_intercept = b;
        self
    }

    pub fn train(&self, ctx: &Context, x: &DenseTable<f64>, y: &[f64]) -> Result<LinRegModel> {
        let n = x.rows();
        let p = x.cols();
        if y.len() != n {
            return Err(Error::Shape("linreg: label count mismatch".into()));
        }
        if n <= p {
            return Err(Error::Param(format!("linreg: need n > p (n={n}, p={p})")));
        }
        if self.alpha < 0.0 {
            return Err(Error::Param("linreg: alpha must be ≥ 0".into()));
        }
        // Center to absorb the intercept.
        let (xc, yc, xmeans, ymean) = if self.fit_intercept {
            let xm = x.col_means();
            let ym = y.iter().sum::<f64>() / n as f64;
            let mut xc = x.clone();
            for i in 0..n {
                for (v, &m) in xc.row_mut(i).iter_mut().zip(&xm) {
                    *v -= m;
                }
            }
            let yc: Vec<f64> = y.iter().map(|&v| v - ym).collect();
            (xc, yc, xm, ym)
        } else {
            (x.clone(), y.to_vec(), vec![0.0; p], 0.0)
        };
        // Normal equations: (XᵀX + αI) w = Xᵀy.
        let mut xtx = vec![0.0f64; p * p];
        match ctx.backend() {
            Backend::Naive => {
                // Textbook triple loop.
                for i in 0..p {
                    for j in 0..p {
                        let mut acc = 0.0;
                        for r in 0..n {
                            acc += xc.get(r, i) * xc.get(r, j);
                        }
                        xtx[i * p + j] = acc;
                    }
                }
            }
            _ => {
                // XᵀX = parallel packed syrk over the transposed (p×n)
                // layout, on the context's worker count.
                let xt = xc.transposed();
                syrk_threads(p, n, 1.0, xt.data(), 0.0, &mut xtx, ctx.threads());
            }
        }
        for i in 0..p {
            xtx[i * p + i] += self.alpha;
        }
        let mut xty = vec![0.0f64; p];
        gemv_threads(true, n, p, 1.0, xc.data(), &yc, 0.0, &mut xty, ctx.threads());
        let coef = cholesky_solve(&xtx, p, &xty)?;
        let intercept = if self.fit_intercept {
            ymean - coef.iter().zip(&xmeans).map(|(c, m)| c * m).sum::<f64>()
        } else {
            0.0
        };
        Ok(LinRegModel { coef, intercept })
    }
}

impl LinRegModel {
    /// Tall-skinny inference: one threaded gemv row-partitioned on the
    /// context's worker count.
    pub fn infer(&self, ctx: &Context, x: &DenseTable<f64>) -> Result<Vec<f64>> {
        if x.cols() != self.coef.len() {
            return Err(Error::Shape("linreg: dim mismatch".into()));
        }
        let mut out = vec![self.intercept; x.rows()];
        let (n, p) = (x.rows(), x.cols());
        gemv_threads(false, n, p, 1.0, x.data(), &self.coef, 1.0, &mut out, ctx.threads());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_regression;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    #[test]
    fn recovers_true_weights() {
        let mut e = Mt19937::new(1);
        let (x, y, w) = make_regression(&mut e, 2000, 8, 0.01);
        let m = LinearRegression::params().train(&ctx(Backend::Vectorized), &x, &y).unwrap();
        for (a, b) in m.coef.iter().zip(&w) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
        assert!(m.intercept.abs() < 0.05);
    }

    #[test]
    fn naive_and_blas_backends_agree() {
        let mut e = Mt19937::new(2);
        let (x, y, _) = make_regression(&mut e, 500, 6, 0.1);
        let a = LinearRegression::params().train(&ctx(Backend::Naive), &x, &y).unwrap();
        let b = LinearRegression::params().train(&ctx(Backend::Vectorized), &x, &y).unwrap();
        for (u, v) in a.coef.iter().zip(&b.coef) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn ridge_shrinks_towards_zero() {
        let mut e = Mt19937::new(3);
        let (x, y, _) = make_regression(&mut e, 300, 5, 0.5);
        let ols = LinearRegression::params().train(&ctx(Backend::Vectorized), &x, &y).unwrap();
        let ridge = RidgeRegression::params()
            .alpha(1000.0)
            .train(&ctx(Backend::Vectorized), &x, &y)
            .unwrap();
        let n_ols: f64 = ols.coef.iter().map(|c| c * c).sum();
        let n_ridge: f64 = ridge.coef.iter().map(|c| c * c).sum();
        assert!(n_ridge < n_ols);
    }

    #[test]
    fn inference_r2_high_on_train() {
        let mut e = Mt19937::new(4);
        let (x, y, _) = make_regression(&mut e, 1000, 10, 0.1);
        let c = ctx(Backend::Vectorized);
        let m = LinearRegression::params().train(&c, &x, &y).unwrap();
        let pred = m.infer(&c, &x).unwrap();
        assert!(crate::metrics::r2(&pred, &y) > 0.99);
    }

    #[test]
    fn intercept_handled() {
        // y = 2x + 5
        let x = DenseTable::from_vec((0..50).map(|i| i as f64).collect(), 50, 1).unwrap();
        let y: Vec<f64> = (0..50).map(|i| 2.0 * i as f64 + 5.0).collect();
        let c = ctx(Backend::Vectorized);
        let m = LinearRegression::params().train(&c, &x, &y).unwrap();
        assert!((m.coef[0] - 2.0).abs() < 1e-8);
        assert!((m.intercept - 5.0).abs() < 1e-6);
    }

    #[test]
    fn validation_errors() {
        let c = ctx(Backend::Vectorized);
        let x = DenseTable::<f64>::zeros(5, 8);
        let y = vec![0.0; 5];
        assert!(LinearRegression::params().train(&c, &x, &y).is_err()); // n <= p
        let x2 = DenseTable::<f64>::zeros(10, 2);
        assert!(LinearRegression::params().train(&c, &x2, &y).is_err()); // len mismatch
        let x3 = DenseTable::from_vec((0..20).map(|i| (i % 7) as f64).collect(), 10, 2).unwrap();
        let y3 = vec![1.0; 10];
        assert!(LinearRegression::params().alpha(-1.0).train(&c, &x3, &y3).is_err());
    }
}
