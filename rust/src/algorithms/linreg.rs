//! Linear and ridge regression via the normal equations — the workloads
//! where the paper *honestly reports losses* (Fig. 5: 0.24× / 0.45× —
//! memory-bound linear algebra where vectorization of the solver cannot
//! compensate), and inference wins on Fig. 6.
//!
//! `XᵀX` is computed with the VSL `xcp` machinery's BLAS path (syrk on
//! the transposed layout), the solve with the Cholesky substrate.
//!
//! CSR tables train through the sparse normal equations: `XᵀX` from the
//! sparse×sparse `csrmultd(AᵀB)` kernel, `Xᵀy` from the threaded
//! `csrmv`, and — since centering would densify the matrix — the
//! intercept is absorbed analytically (`XcᵀXc = XᵀX − n·x̄x̄ᵀ`,
//! `Xcᵀyc = Xᵀy − n·x̄·ȳ`). Inference is one threaded `csrmv`.
//! `Backend::Naive` densifies first — the sparse path's test oracle.

use crate::blas::{gemv_threads, syrk_threads_profile};
use crate::coordinator::{Backend, Context};
use crate::error::{Error, Result};
use crate::linalg::cholesky_solve;
use crate::primitives::packed::ModelPanel;
use crate::sparse::{csrmultd, csrmv_threads, CsrMatrix, IndexBase, SparseOp};
use crate::tables::{DenseTable, TableRef};

#[derive(Clone, Debug)]
pub struct LinRegParams {
    /// L2 penalty (0 = ordinary least squares).
    pub alpha: f64,
    pub fit_intercept: bool,
}

pub struct LinearRegression;

impl LinearRegression {
    pub fn params() -> LinRegParams {
        LinRegParams { alpha: 0.0, fit_intercept: true }
    }
}

/// Ridge is the same estimator with a nonzero penalty (oneDAL exposes
/// both; the paper benches them separately on the 10M×20 grid).
pub struct RidgeRegression;

impl RidgeRegression {
    pub fn params() -> LinRegParams {
        LinRegParams { alpha: 1.0, fit_intercept: true }
    }
}

#[derive(Clone, Debug)]
pub struct LinRegModel {
    pub coef: Vec<f64>,
    pub intercept: f64,
    /// Model-resident weight panel ([`ModelPanel::Weights`]) built at
    /// `train` time — inference reads the coefficients through it so
    /// the pack-free contract covers coefficient models uniformly.
    panel: ModelPanel,
}

impl LinRegParams {
    pub fn alpha(mut self, a: f64) -> Self {
        self.alpha = a;
        self
    }

    pub fn fit_intercept(mut self, b: bool) -> Self {
        self.fit_intercept = b;
        self
    }

    pub fn train<'a>(
        &self,
        ctx: &Context,
        x: impl Into<TableRef<'a>>,
        y: &[f64],
    ) -> Result<LinRegModel> {
        let x = x.into();
        let n = x.rows();
        let p = x.cols();
        crate::validate::non_empty(n, p, "linreg")?;
        crate::validate::labels_match(n, y.len(), "linreg")?;
        crate::validate::non_negative_finite(self.alpha, "alpha", "linreg")?;
        if n <= p {
            return Err(Error::Param(format!("linreg: need n > p (n={n}, p={p})")));
        }
        crate::parallel::quarantine("linreg.train", || match x {
            TableRef::Dense(d) => self.train_dense(ctx, d, y),
            TableRef::Csr(s) => {
                if matches!(ctx.backend(), Backend::Naive) {
                    // Densified naive rung — the sparse path's oracle.
                    self.train_dense(ctx, &s.to_dense(), y)
                } else {
                    self.train_csr(ctx, s, y)
                }
            }
        })
    }

    fn train_dense(&self, ctx: &Context, x: &DenseTable<f64>, y: &[f64]) -> Result<LinRegModel> {
        let n = x.rows();
        let p = x.cols();
        // Center to absorb the intercept.
        let (xc, yc, xmeans, ymean) = if self.fit_intercept {
            let xm = x.col_means();
            let ym = y.iter().sum::<f64>() / n as f64;
            let mut xc = x.clone();
            for i in 0..n {
                for (v, &m) in xc.row_mut(i).iter_mut().zip(&xm) {
                    *v -= m;
                }
            }
            let yc: Vec<f64> = y.iter().map(|&v| v - ym).collect();
            (xc, yc, xm, ym)
        } else {
            (x.clone(), y.to_vec(), vec![0.0; p], 0.0)
        };
        // Normal equations: (XᵀX + αI) w = Xᵀy.
        let mut xtx = vec![0.0f64; p * p];
        match ctx.backend() {
            Backend::Naive => {
                // Textbook triple loop.
                for i in 0..p {
                    for j in 0..p {
                        let mut acc = 0.0;
                        for r in 0..n {
                            acc += xc.get(r, i) * xc.get(r, j);
                        }
                        xtx[i * p + j] = acc;
                    }
                }
            }
            _ => {
                // XᵀX = parallel packed syrk over the transposed (p×n)
                // layout, on the context's worker count and lane profile.
                let xt = xc.transposed();
                syrk_threads_profile(
                    p,
                    n,
                    1.0,
                    xt.data(),
                    0.0,
                    &mut xtx,
                    ctx.threads(),
                    ctx.lane_profile(),
                );
            }
        }
        for i in 0..p {
            xtx[i * p + i] += self.alpha;
        }
        let mut xty = vec![0.0f64; p];
        gemv_threads(true, n, p, 1.0, xc.data(), &yc, 0.0, &mut xty, ctx.threads());
        let coef = cholesky_solve(&xtx, p, &xty)?;
        let intercept = if self.fit_intercept {
            ymean - coef.iter().zip(&xmeans).map(|(c, m)| c * m).sum::<f64>()
        } else {
            0.0
        };
        let panel = ModelPanel::from_weights(&coef);
        Ok(LinRegModel { coef, intercept, panel })
    }

    /// Sparse normal equations: `XᵀX` from one `csrmultd(AᵀB)` call
    /// (the paper's sparse×sparse kernel — its col-major output is
    /// symmetric here, so no transposition is needed), `Xᵀy` from the
    /// threaded `csrmv`, and the centering of the intercept absorbed
    /// analytically instead of densifying `X`:
    /// `XcᵀXc = XᵀX − n·x̄x̄ᵀ`, `Xcᵀyc = Xᵀy − n·x̄·ȳ` (the standard
    /// sparse-solver treatment — exact centering would densify the
    /// Gram accumulation). Conditioning caveat: the correction cancels
    /// catastrophically when a column's mean dwarfs its spread (e.g.
    /// raw timestamps); such data should be pre-shifted or trained
    /// with a ridge `alpha` — the dense path, which centers `X`
    /// explicitly, does not share this limit.
    fn train_csr(&self, ctx: &Context, x: &CsrMatrix<f64>, y: &[f64]) -> Result<LinRegModel> {
        let n = x.rows();
        let p = x.cols();
        // csrmultd requires 1-based operands; rebase a copy if needed.
        let rebased;
        let x1 = if x.base() == IndexBase::One {
            x
        } else {
            let mut c = x.clone();
            c.rebase(IndexBase::One);
            rebased = c;
            &rebased
        };
        let mut xtx = vec![0.0f64; p * p];
        csrmultd(SparseOp::Transpose, x1, x1, &mut xtx)?;
        let mut xty = vec![0.0f64; p];
        csrmv_threads(SparseOp::Transpose, 1.0, x, y, 0.0, &mut xty, ctx.threads())?;
        let (xmeans, ymean) = if self.fit_intercept {
            let mut m = vec![0.0f64; p];
            for i in 0..n {
                for (j, v) in x.row_entries(i) {
                    m[j] += v;
                }
            }
            let inv = 1.0 / n as f64;
            for v in m.iter_mut() {
                *v *= inv;
            }
            (m, y.iter().sum::<f64>() / n as f64)
        } else {
            (vec![0.0; p], 0.0)
        };
        if self.fit_intercept {
            let nf = n as f64;
            for i in 0..p {
                for j in 0..p {
                    xtx[i * p + j] -= nf * xmeans[i] * xmeans[j];
                }
            }
            for (v, &m) in xty.iter_mut().zip(&xmeans) {
                *v -= nf * m * ymean;
            }
        }
        for i in 0..p {
            xtx[i * p + i] += self.alpha;
        }
        let coef = cholesky_solve(&xtx, p, &xty)?;
        let intercept = if self.fit_intercept {
            ymean - coef.iter().zip(&xmeans).map(|(c, m)| c * m).sum::<f64>()
        } else {
            0.0
        };
        let panel = ModelPanel::from_weights(&coef);
        Ok(LinRegModel { coef, intercept, panel })
    }
}

impl LinRegModel {
    /// Tall-skinny inference: one threaded gemv (dense) or csrmv (CSR)
    /// row-partitioned on the context's worker count. The weights come
    /// from the model-resident panel (bit-identical to `coef`).
    pub fn infer<'a>(&self, ctx: &Context, x: impl Into<TableRef<'a>>) -> Result<Vec<f64>> {
        let x = x.into();
        crate::validate::dims_match(self.coef.len(), x.cols(), "linreg")?;
        crate::parallel::quarantine("linreg.infer", || {
            let w: &[f64] = self.panel.weights().unwrap_or(&self.coef);
            let mut out = vec![self.intercept; x.rows()];
            match x {
                TableRef::Dense(d) => {
                    let (n, p) = (d.rows(), d.cols());
                    gemv_threads(false, n, p, 1.0, d.data(), w, 1.0, &mut out, ctx.threads());
                }
                TableRef::Csr(s) => {
                    let t = ctx.threads();
                    csrmv_threads(SparseOp::NoTranspose, 1.0, s, w, 1.0, &mut out, t)?;
                }
            }
            Ok(out)
        })
    }

    /// The model-resident weight panel.
    pub fn panel(&self) -> &ModelPanel {
        &self.panel
    }
}

impl crate::coordinator::serve::ServeModel for LinRegModel {
    fn serve_dims(&self) -> usize {
        self.coef.len()
    }

    fn serve_batch(&self, ctx: &Context, q: &DenseTable<f64>) -> Result<Vec<f64>> {
        // One predicted value per row; `infer` is quarantined.
        self.infer(ctx, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;
    use crate::tables::synth::make_regression;

    fn ctx(b: Backend) -> Context {
        Context::builder().artifact_dir("/nonexistent").backend(b).build().unwrap()
    }

    #[test]
    fn recovers_true_weights() {
        let mut e = Mt19937::new(1);
        let (x, y, w) = make_regression(&mut e, 2000, 8, 0.01);
        let m = LinearRegression::params().train(&ctx(Backend::Vectorized), &x, &y).unwrap();
        for (a, b) in m.coef.iter().zip(&w) {
            assert!((a - b).abs() < 0.01, "{a} vs {b}");
        }
        assert!(m.intercept.abs() < 0.05);
    }

    #[test]
    fn naive_and_blas_backends_agree() {
        let mut e = Mt19937::new(2);
        let (x, y, _) = make_regression(&mut e, 500, 6, 0.1);
        let a = LinearRegression::params().train(&ctx(Backend::Naive), &x, &y).unwrap();
        let b = LinearRegression::params().train(&ctx(Backend::Vectorized), &x, &y).unwrap();
        for (u, v) in a.coef.iter().zip(&b.coef) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn ridge_shrinks_towards_zero() {
        let mut e = Mt19937::new(3);
        let (x, y, _) = make_regression(&mut e, 300, 5, 0.5);
        let ols = LinearRegression::params().train(&ctx(Backend::Vectorized), &x, &y).unwrap();
        let ridge = RidgeRegression::params()
            .alpha(1000.0)
            .train(&ctx(Backend::Vectorized), &x, &y)
            .unwrap();
        let n_ols: f64 = ols.coef.iter().map(|c| c * c).sum();
        let n_ridge: f64 = ridge.coef.iter().map(|c| c * c).sum();
        assert!(n_ridge < n_ols);
    }

    #[test]
    fn inference_r2_high_on_train() {
        let mut e = Mt19937::new(4);
        let (x, y, _) = make_regression(&mut e, 1000, 10, 0.1);
        let c = ctx(Backend::Vectorized);
        let m = LinearRegression::params().train(&c, &x, &y).unwrap();
        let pred = m.infer(&c, &x).unwrap();
        assert!(crate::metrics::r2(&pred, &y) > 0.99);
    }

    #[test]
    fn intercept_handled() {
        // y = 2x + 5
        let x = DenseTable::from_vec((0..50).map(|i| i as f64).collect(), 50, 1).unwrap();
        let y: Vec<f64> = (0..50).map(|i| 2.0 * i as f64 + 5.0).collect();
        let c = ctx(Backend::Vectorized);
        let m = LinearRegression::params().train(&c, &x, &y).unwrap();
        assert!((m.coef[0] - 2.0).abs() < 1e-8);
        assert!((m.intercept - 5.0).abs() < 1e-6);
    }

    /// CSR training solves the sparse normal equations to the same
    /// coefficients as the densified naive oracle, recovers the true
    /// weights on noise-free data, and is bit-identical across worker
    /// counts (both index bases).
    #[test]
    fn csr_matches_densified_oracle_and_threads() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut e = Mt19937::new(9);
        let (mut xd, _, _) = make_regression(&mut e, 600, 7, 0.0);
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let w_true: Vec<f64> = (0..7).map(|j| j as f64 - 3.0).collect();
        let y: Vec<f64> = (0..600)
            .map(|i| xd.row(i).iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>() + 2.5)
            .collect();
        for base in [IndexBase::Zero, IndexBase::One] {
            let xs = CsrMatrix::from_dense(&xd, 0.0, base);
            let cv = ctx(Backend::Vectorized);
            let cn = ctx(Backend::Naive);
            let m_csr = LinearRegression::params().train(&cv, &xs, &y).unwrap();
            let m_oracle = LinearRegression::params().train(&cn, &xs, &y).unwrap();
            for (a, b) in m_csr.coef.iter().zip(&m_oracle.coef) {
                assert!((a - b).abs() < 1e-6, "{base:?}: {a} vs {b}");
            }
            assert!((m_csr.intercept - m_oracle.intercept).abs() < 1e-6, "{base:?}");
            for (a, b) in m_csr.coef.iter().zip(&w_true) {
                assert!((a - b).abs() < 1e-6, "{base:?}: {a} vs {b}");
            }
            assert!((m_csr.intercept - 2.5).abs() < 1e-5, "{base:?}");
            // Sparse inference matches dense inference of the same model.
            let pred_s = m_csr.infer(&cv, &xs).unwrap();
            let pred_d = m_csr.infer(&cv, &xd).unwrap();
            for (a, b) in pred_s.iter().zip(&pred_d) {
                assert!((a - b).abs() < 1e-9, "{base:?}");
            }
            // 1–4-worker bit-identity of sparse train + infer.
            let mk = |t: usize| {
                Context::builder()
                    .artifact_dir("/nonexistent")
                    .backend(Backend::Vectorized)
                    .threads(t)
                    .build()
                    .unwrap()
            };
            let m1 = LinearRegression::params().train(&mk(1), &xs, &y).unwrap();
            let p1 = m1.infer(&mk(1), &xs).unwrap();
            for threads in 2..=4 {
                let m = LinearRegression::params().train(&mk(threads), &xs, &y).unwrap();
                for (a, b) in m1.coef.iter().zip(&m.coef) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{base:?} threads={threads}");
                }
                let p = m.infer(&mk(threads), &xs).unwrap();
                for (a, b) in p1.iter().zip(&p) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{base:?} threads={threads}");
                }
            }
        }
    }

    /// Ridge shrinks CSR fits exactly like dense fits.
    #[test]
    fn csr_ridge_matches_dense_ridge() {
        use crate::sparse::{CsrMatrix, IndexBase};
        let mut e = Mt19937::new(11);
        let (mut xd, y, _) = make_regression(&mut e, 400, 5, 0.3);
        for (i, v) in xd.data_mut().iter_mut().enumerate() {
            if i % 2 == 1 {
                *v = 0.0;
            }
        }
        let xs = CsrMatrix::from_dense(&xd, 0.0, IndexBase::One);
        let cv = ctx(Backend::Vectorized);
        let ridge = RidgeRegression::params().alpha(50.0);
        let ms = ridge.train(&cv, &xs, &y).unwrap();
        let md = ridge.train(&cv, &xd, &y).unwrap();
        for (a, b) in ms.coef.iter().zip(&md.coef) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
        assert!((ms.intercept - md.intercept).abs() < 1e-7);
    }

    #[test]
    fn validation_errors() {
        let c = ctx(Backend::Vectorized);
        let x = DenseTable::<f64>::zeros(5, 8);
        let y = vec![0.0; 5];
        assert!(LinearRegression::params().train(&c, &x, &y).is_err()); // n <= p
        let x2 = DenseTable::<f64>::zeros(10, 2);
        assert!(LinearRegression::params().train(&c, &x2, &y).is_err()); // len mismatch
        let x3 = DenseTable::from_vec((0..20).map(|i| (i % 7) as f64).collect(), 10, 2).unwrap();
        let y3 = vec![1.0; 10];
        assert!(LinearRegression::params().alpha(-1.0).train(&c, &x3, &y3).is_err());
    }
}
