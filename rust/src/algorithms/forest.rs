//! Random-forest classifier — the Fig. 9 workload (31× on fraud
//! detection) and the algorithm the paper singles out in §IV-D as the
//! beneficiary of parallel RNG streams ("adding mt2203 could further
//! improve performance for algorithms like Random Forests").
//!
//! Per-tree randomness comes from the RNG substrate's **Family method**
//! (decorrelated per-tree streams), so trees can be trained on worker
//! threads with zero RNG coordination — exactly the OpenRNG pattern.

use super::tree::{DecisionTree, TreeParams};
use crate::coordinator::Context;
use crate::error::{Error, Result};
use crate::rng::{family_streams, Distribution, UniformInt};
use crate::tables::DenseTable;

#[derive(Clone, Debug)]
pub struct ForestParams {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_samples_split: usize,
    /// Features per node; 0 = √p.
    pub max_features: usize,
    /// Bootstrap sample size as a fraction of n.
    pub sample_frac: f64,
    pub seed: u64,
}

pub struct RandomForestClassifier;

impl RandomForestClassifier {
    pub fn params() -> ForestParams {
        ForestParams {
            n_trees: 50,
            max_depth: 12,
            min_samples_split: 2,
            max_features: 0,
            sample_frac: 1.0,
            seed: 20_240_401,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ForestModel {
    trees: Vec<DecisionTree>,
    pub n_classes: usize,
}

impl ForestParams {
    pub fn n_trees(mut self, n: usize) -> Self {
        self.n_trees = n;
        self
    }

    pub fn max_depth(mut self, d: usize) -> Self {
        self.max_depth = d;
        self
    }

    pub fn max_features(mut self, m: usize) -> Self {
        self.max_features = m;
        self
    }

    pub fn sample_frac(mut self, f: f64) -> Self {
        self.sample_frac = f;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn train(&self, ctx: &Context, x: &DenseTable<f64>, y: &[f64]) -> Result<ForestModel> {
        let n = x.rows();
        let p = x.cols();
        crate::validate::non_empty(n, p, "forest")?;
        crate::validate::labels_match(n, y.len(), "forest")?;
        crate::validate::positive_int(self.n_trees, "n_trees", "forest")?;
        if !(0.0..=1.0).contains(&self.sample_frac) || self.sample_frac == 0.0 {
            return Err(Error::Param("forest: sample_frac must be in (0, 1]".into()));
        }
        crate::parallel::quarantine("forest.train", || self.train_inner(ctx, x, y))
    }

    fn train_inner(&self, ctx: &Context, x: &DenseTable<f64>, y: &[f64]) -> Result<ForestModel> {
        let n = x.rows();
        let p = x.cols();
        let n_classes = y.iter().fold(0.0f64, |a, &b| a.max(b)) as usize + 1;
        let max_features = if self.max_features == 0 {
            ((p as f64).sqrt().round() as usize).max(1)
        } else {
            self.max_features
        };
        let tree_params = TreeParams {
            max_depth: self.max_depth,
            min_samples_split: self.min_samples_split,
            max_features,
            n_classes,
        };
        let sample_n = ((n as f64 * self.sample_frac) as usize).max(1);
        // Family method: one decorrelated stream per tree.
        let streams = family_streams(self.seed, self.n_trees);
        let n_threads = ctx.threads().min(self.n_trees).max(1);
        // Static round-robin sharding of trees over worker threads.
        let mut tree_results: Vec<Option<Result<DecisionTree>>> =
            (0..self.n_trees).map(|_| None).collect();
        let shard_len = self.n_trees.div_ceil(n_threads);
        let stream_chunks: Vec<Vec<_>> = streams
            .into_iter()
            .enumerate()
            .collect::<Vec<_>>()
            .chunks(shard_len)
            .map(|c| c.to_vec())
            .collect();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (shard, chunk) in stream_chunks.into_iter().enumerate() {
                let tp = tree_params.clone();
                handles.push((shard, scope.spawn(move || {
                    let mut local = Vec::new();
                    for (tree_idx, mut engine) in chunk {
                        let mut ui = UniformInt::new(0, n as u64);
                        let idx: Vec<usize> =
                            (0..sample_n).map(|_| ui.sample(engine.as_mut()) as usize).collect();
                        let t = DecisionTree::fit(&tp, x, y, &idx, engine.as_mut());
                        local.push((tree_idx, t));
                    }
                    local
                })));
            }
            for (_, h) in handles {
                match h.join() {
                    Ok(batch) => {
                        for (tree_idx, t) in batch {
                            tree_results[tree_idx] = Some(t);
                        }
                    }
                    // Re-throw on the caller's thread so the quarantine
                    // boundary above converts it to Error::Internal.
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        });
        let mut trees = Vec::with_capacity(self.n_trees);
        for t in tree_results {
            trees.push(t.ok_or_else(|| {
                Error::Internal("forest.train: tree slot left unfilled by a worker shard".into())
            })??);
        }
        Ok(ForestModel { trees, n_classes })
    }
}

impl ForestModel {
    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    /// Soft voting: mean of per-tree class probabilities.
    pub fn predict_proba(&self, _ctx: &Context, x: &DenseTable<f64>) -> Result<DenseTable<f64>> {
        crate::parallel::quarantine("forest.predict_proba", || {
            let mut out = DenseTable::zeros(x.rows(), self.n_classes);
            let inv = 1.0 / self.trees.len() as f64;
            for i in 0..x.rows() {
                let row = x.row(i);
                let orow = out.row_mut(i);
                for t in &self.trees {
                    for (o, &p) in orow.iter_mut().zip(t.predict_proba_row(row)) {
                        *o += p;
                    }
                }
                for o in orow.iter_mut() {
                    *o *= inv;
                }
            }
            Ok(out)
        })
    }

    pub fn infer(&self, ctx: &Context, x: &DenseTable<f64>) -> Result<Vec<f64>> {
        let proba = self.predict_proba(ctx, x)?;
        Ok((0..x.rows())
            .map(|i| {
                let row = proba.row(i);
                let mut best = 0usize;
                for (c, &p) in row.iter().enumerate() {
                    if p > row[best] {
                        best = c;
                    }
                }
                best as f64
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::rng::Mt19937;
    use crate::tables::synth::{make_classification, make_fraud};

    fn ctx() -> Context {
        Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .threads(4)
            .build()
            .unwrap()
    }

    #[test]
    fn learns_separable_task() {
        let mut e = Mt19937::new(1);
        let (x, y) = make_classification(&mut e, 600, 8, 1.5);
        let c = ctx();
        let m = RandomForestClassifier::params().n_trees(20).train(&c, &x, &y).unwrap();
        let pred = m.infer(&c, &x).unwrap();
        let acc = crate::metrics::accuracy(&pred, &y);
        assert!(acc > 0.95, "acc={acc}");
        assert_eq!(m.n_trees(), 20);
    }

    #[test]
    fn deterministic_given_seed_regardless_of_threads() {
        let mut e = Mt19937::new(2);
        let (x, y) = make_classification(&mut e, 300, 5, 1.0);
        let mk = |t: usize| {
            Context::builder()
                .artifact_dir("/nonexistent")
                .backend(Backend::Vectorized)
                .threads(t)
                .build()
                .unwrap()
        };
        let (c1, c4) = (mk(1), mk(4));
        let m1 = RandomForestClassifier::params().n_trees(8).seed(99).train(&c1, &x, &y).unwrap();
        let m4 = RandomForestClassifier::params().n_trees(8).seed(99).train(&c4, &x, &y).unwrap();
        // Family streams are per-tree, so thread count must not change
        // the model (the OpenRNG reproducibility property).
        let p1 = m1.predict_proba(&c1, &x).unwrap();
        let p4 = m4.predict_proba(&c4, &x).unwrap();
        assert_eq!(p1.data(), p4.data());
    }

    #[test]
    fn detects_fraud_minority() {
        let mut e = Mt19937::new(3);
        let (x, y) = make_fraud(&mut e, 4000, 10, 200);
        let c = ctx();
        let m = RandomForestClassifier::params().n_trees(30).train(&c, &x, &y).unwrap();
        let pred = m.infer(&c, &x).unwrap();
        let (_, recall, f1) = crate::metrics::precision_recall_f1(&pred, &y);
        assert!(recall > 0.5, "recall={recall}");
        assert!(f1 > 0.6, "f1={f1}");
    }

    /// NaN feature values must not panic forest training (regression:
    /// the tree's split sort used `partial_cmp(..).unwrap()`); the
    /// model still trains, stays deterministic across thread counts,
    /// and classifies the clean subspace.
    #[test]
    fn nan_features_degrade_without_panic() {
        let mut e = Mt19937::new(17);
        let (mut x, y) = make_classification(&mut e, 300, 6, 1.5);
        for i in (0..300).step_by(11) {
            x.row_mut(i)[3] = f64::NAN;
        }
        let c = ctx();
        let params = || RandomForestClassifier::params().n_trees(10).seed(42);
        let m = params().train(&c, &x, &y).unwrap();
        let pred = m.infer(&c, &x).unwrap();
        let mut correct = 0usize;
        let mut clean = 0usize;
        for i in 0..300 {
            if x.row(i).iter().all(|v| v.is_finite()) {
                clean += 1;
                if pred[i] == y[i] {
                    correct += 1;
                }
            }
        }
        assert!(correct as f64 / clean as f64 > 0.85, "{correct}/{clean}");
        let m2 = params().train(&c, &x, &y).unwrap();
        assert_eq!(m2.infer(&c, &x).unwrap(), pred, "NaN handling must stay deterministic");
    }

    #[test]
    fn probabilities_rows_sum_to_one() {
        let mut e = Mt19937::new(4);
        let (x, y) = make_classification(&mut e, 200, 4, 1.0);
        let c = ctx();
        let m = RandomForestClassifier::params().n_trees(5).train(&c, &x, &y).unwrap();
        let proba = m.predict_proba(&c, &x).unwrap();
        for i in 0..200 {
            let s: f64 = proba.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn param_validation() {
        let c = ctx();
        let x = DenseTable::<f64>::zeros(10, 2);
        let y = vec![0.0; 10];
        assert!(RandomForestClassifier::params().n_trees(0).train(&c, &x, &y).is_err());
        assert!(RandomForestClassifier::params().sample_frac(0.0).train(&c, &x, &y).is_err());
    }
}
