//! The ML algorithms of the paper's evaluation (Figs. 5–9): each exposes
//! oneDAL's `params() → train(&ctx, …) → Model → infer(&ctx, …)` shape
//! and implements the backend ladder (naive / reference / vectorized /
//! artifact) so the benches can sweep exactly the comparisons the paper
//! plots.
//!
//! ## Fail-safe boundary contract
//!
//! Every public `train`/`infer`/`predict` in this module validates its
//! inputs **first** — shapes, label lengths, hyperparameter finiteness
//! and ranges, via [`crate::validate`] — returning
//! [`crate::error::Error::Shape`] / [`crate::error::Error::Param`] with
//! the algorithm name and offending value, so the deep kernel asserts
//! are unreachable from the public API. The compute body then runs
//! inside [`crate::parallel::quarantine`]: a panic escaping any
//! algorithm call (fault injection, a latent kernel bug) surfaces as
//! [`crate::error::Error::Internal`] tagged with the fan-out site
//! instead of aborting the process, and the worker pool respawns
//! panicked workers on the next batch. Iterative trainers (k-means,
//! logreg, SVM, PCA's Jacobi sweeps) additionally draw a
//! [`crate::coordinator::BudgetMeter`] from the context's
//! [`crate::coordinator::Budget`] and check it at outer-iteration
//! boundaries only — on expiry they return the best-so-far model tagged
//! with a [`crate::coordinator::ConvergenceStatus`] instead of erroring,
//! and an unlimited budget never reads the clock, keeping uncapped runs
//! bit-identical.

pub mod covariance;
pub mod dbscan;
pub mod forest;
pub mod kmeans;
pub mod knn;
pub mod linreg;
pub mod logreg;
pub mod pca;
pub mod svm;
pub mod tree;
