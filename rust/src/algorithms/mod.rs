//! The ML algorithms of the paper's evaluation (Figs. 5–9): each exposes
//! oneDAL's `params() → train(&ctx, …) → Model → infer(&ctx, …)` shape
//! and implements the backend ladder (naive / reference / vectorized /
//! artifact) so the benches can sweep exactly the comparisons the paper
//! plots.

pub mod covariance;
pub mod dbscan;
pub mod forest;
pub mod kmeans;
pub mod knn;
pub mod linreg;
pub mod logreg;
pub mod pca;
pub mod svm;
pub mod tree;
