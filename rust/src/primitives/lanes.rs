//! Vector-length-agnostic lane profiles — the paper's `getCpuId`
//! runtime-width probe, reproduced as one `LaneProfile` resolved once
//! and threaded through every predicated kernel.
//!
//! SVE's defining property is that one predicated kernel body serves
//! 128/256/512-bit hardware with the vector length resolved at run
//! time. This crate's stand-in for a vector register is the fixed-width
//! unrolled block (`[f64; LANES]` + mask/select), and before this
//! module the width was hard-coded to the 512-bit case as two drifted
//! `LANES = 8` constants plus unrelated `MR/NR/KC/TILE` panel-geometry
//! constants. Now there is exactly one source of truth:
//!
//! * [`LaneProfile`] — 128/256/512-bit ⇒ 2/4/8 f64 lanes. Every
//!   derived geometry constant is a `const fn` of the profile:
//!   [`LaneProfile::nr`] (GEMM micro-panel width = lanes),
//!   [`LaneProfile::kc`] (k-blocking depth, constant `KC×NR` B-panel
//!   footprint), [`LaneProfile::tile`] (distance-sweep query tile
//!   rows) and [`LaneProfile::wss_lanes`] (the two-registers-of-
//!   headroom WSS scan width). [`MR`] (register-tile height) is
//!   profile-independent.
//! * [`default_profile`] — the process default, resolved **once**
//!   (lazily, cached in an atomic) from the `ONEDAL_SVE_BACKEND`
//!   environment variable; this module is the variable's single
//!   approved read site (PAL-ENV/PAL-LANE). The default is
//!   [`LaneProfile::Sve512`], bit-compatible with the pre-profile
//!   outputs. `Context::build` resolves the active profile from the
//!   builder override or this default and threads it through the
//!   algorithm layer.
//! * [`with_lane_count!`](crate::with_lane_count) — the dispatch seam:
//!   expands a profile into a `const L: usize` binding so the
//!   const-generic kernel bodies ([`crate::algorithms::svm::simd`],
//!   the `primitives::distances` epilogues, the `blas::level3`
//!   microkernel) monomorphize per profile and are selected **once per
//!   tile**, never per element.
//!
//! ## Env grammar
//!
//! `ONEDAL_SVE_BACKEND` accepts a comma-separated token list; each
//! token is either a backend rung name (`naive`, `reference`,
//! `vectorized`, `artifact`, `auto` — consumed by
//! `coordinator::Backend::parse`) or a lane-profile name (`sve128`,
//! `sve256`, `sve512`). Examples: `sve256`, `vectorized,sve128`.
//! [`resolve_spec`] is the pure parser (testable without touching the
//! process environment); the first profile token wins, non-profile
//! tokens are passed through to the backend parser (several of them
//! are rejoined so `Backend::parse` rejects the ambiguity loudly).
//!
//! ## Determinism contract
//!
//! Within a profile: every kernel is bit-identical at any worker count
//! (same tile cuts, same merge order as before), and `sve512` is
//! bit-identical to the pre-profile implementation. Across profiles:
//! discrete outputs (argmin winners, top-k index sets, ε-membership,
//! WSS picks, support-vector sets) are **identical** — the predicated
//! reductions compare exact per-element values, which do not depend on
//! the block width — while accumulated floats (GEMM/`syrk` values,
//! RBF gram entries, inertia) may differ across profiles because
//! [`LaneProfile::kc`]/[`LaneProfile::tile`] regroup the accumulation;
//! the scalar naive rungs are the per-profile oracles. See
//! `docs/KERNELS.md` for the full contract.

use std::sync::atomic::{AtomicU8, Ordering};

/// Register-tile height of the packed GEMM microkernel (A-side rows
/// held in the accumulator). Profile-independent: widening the vector
/// widens the B-side (`nr`), not the unroll over A rows.
pub const MR: usize = 4;

/// One SVE vector-length profile: how many f64 lanes a predicated
/// block carries. Resolved once (builder override or
/// [`default_profile`]) and threaded through packing, kernels and
/// epilogues so they widen together.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LaneProfile {
    /// 128-bit vectors — 2 f64 lanes (the NEON-width floor).
    Sve128,
    /// 256-bit vectors — 4 f64 lanes.
    Sve256,
    /// 512-bit vectors — 8 f64 lanes (the paper's A64FX case and this
    /// crate's historical hard-coded width; the default).
    Sve512,
}

impl LaneProfile {
    /// f64 lanes per predicated block (2 / 4 / 8).
    pub const fn lanes(self) -> usize {
        match self {
            LaneProfile::Sve128 => 2,
            LaneProfile::Sve256 => 4,
            LaneProfile::Sve512 => 8,
        }
    }

    /// Vector width in bits (128 / 256 / 512).
    pub const fn bits(self) -> usize {
        self.lanes() * 64
    }

    /// GEMM micro-panel width `NR`: one vector of output columns per
    /// accumulator row, so the register tile is `MR × lanes`.
    pub const fn nr(self) -> usize {
        self.lanes()
    }

    /// GEMM k-blocking depth `KC`, chosen to keep the resident B-panel
    /// footprint (`KC × NR` values) constant across profiles:
    /// 1024 / 512 / 256 for 2 / 4 / 8 lanes. `sve512` ⇒ 256, the
    /// pre-profile constant.
    pub const fn kc(self) -> usize {
        2048 / self.nr()
    }

    /// Query rows per distance-sweep tile (`32 × lanes`): the
    /// `tile × n` cross-term block one worker computes and consumes
    /// cache-hot. `sve512` ⇒ 256, the pre-profile constant.
    pub const fn tile(self) -> usize {
        32 * self.lanes()
    }

    /// Block width of the `wss_j_vectorized` scan — two vectors of
    /// headroom for the autovectorizer (`2 × lanes`; `sve512` ⇒ 16,
    /// the pre-profile `WSS_LANES`).
    pub const fn wss_lanes(self) -> usize {
        2 * self.lanes()
    }

    /// Canonical token name (`sve128` / `sve256` / `sve512`).
    pub const fn name(self) -> &'static str {
        match self {
            LaneProfile::Sve128 => "sve128",
            LaneProfile::Sve256 => "sve256",
            LaneProfile::Sve512 => "sve512",
        }
    }

    /// Parse one profile token; `None` for anything else (backend rung
    /// names fall through to `coordinator::Backend::parse`, and a bare
    /// `sve` stays an error there — a width must be named).
    pub fn parse(token: &str) -> Option<LaneProfile> {
        match token.trim() {
            "sve128" => Some(LaneProfile::Sve128),
            "sve256" => Some(LaneProfile::Sve256),
            "sve512" => Some(LaneProfile::Sve512),
            _ => None,
        }
    }

    /// All profiles, narrowest first (test matrices iterate this).
    pub const ALL: [LaneProfile; 3] =
        [LaneProfile::Sve128, LaneProfile::Sve256, LaneProfile::Sve512];
}

/// The bit-compatible default: 512-bit vectors, 8 f64 lanes.
pub const DEFAULT_PROFILE: LaneProfile = LaneProfile::Sve512;

/// Split an `ONEDAL_SVE_BACKEND` value into `(backend_request,
/// lane_profile)`. Pure — the testable core of the probe. The first
/// profile token wins; every non-profile token is collected into the
/// backend request verbatim (rejoined with commas when there are
/// several, so `Backend::parse` rejects the malformed spec instead of
/// this layer guessing).
pub fn resolve_spec(spec: Option<&str>) -> (Option<String>, Option<LaneProfile>) {
    let Some(spec) = spec else { return (None, None) };
    let mut backend_tokens: Vec<&str> = Vec::new();
    let mut profile: Option<LaneProfile> = None;
    for token in spec.split(',') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        match LaneProfile::parse(token) {
            Some(p) => {
                if profile.is_none() {
                    profile = Some(p);
                }
            }
            None => backend_tokens.push(token),
        }
    }
    let backend =
        if backend_tokens.is_empty() { None } else { Some(backend_tokens.join(",")) };
    (backend, profile)
}

/// The single approved read of `ONEDAL_SVE_BACKEND`. Everything else
/// (the coordinator's backend resolution included) consumes the parsed
/// result through [`env_backend_request`] / [`default_profile`], so
/// library behavior stays a function of arguments plus this one
/// documented switch (PAL-ENV; PAL-LANE pins the variable name to this
/// file).
fn env_spec() -> Option<String> {
    std::env::var("ONEDAL_SVE_BACKEND").ok()
}

/// Backend rung requested by the environment, if any — the non-profile
/// remainder of the `ONEDAL_SVE_BACKEND` token list. `Context::build`
/// feeds this to `Backend::parse` exactly like the old direct read.
pub fn env_backend_request() -> Option<String> {
    resolve_spec(env_spec().as_deref()).0
}

/// Cached process-default profile: 0 = unresolved, else 1 + index into
/// the resolution table below.
static DEFAULT_CELL: AtomicU8 = AtomicU8::new(0);

fn encode(p: LaneProfile) -> u8 {
    match p {
        LaneProfile::Sve128 => 1,
        LaneProfile::Sve256 => 2,
        LaneProfile::Sve512 => 3,
    }
}

fn decode(v: u8) -> Option<LaneProfile> {
    match v {
        1 => Some(LaneProfile::Sve128),
        2 => Some(LaneProfile::Sve256),
        3 => Some(LaneProfile::Sve512),
        _ => None,
    }
}

/// The process-default lane profile: the `ONEDAL_SVE_BACKEND` profile
/// token if present, else [`DEFAULT_PROFILE`]. Resolved on first call
/// and cached (one env read per process — the paper's probe-once
/// `getCpuId` discipline), so every default-profile entry point in a
/// run agrees on the width. `Context::build` consumes this as the
/// fallback under an absent builder override.
pub fn default_profile() -> LaneProfile {
    if let Some(p) = decode(DEFAULT_CELL.load(Ordering::Relaxed)) {
        return p;
    }
    let resolved = resolve_spec(env_spec().as_deref()).1.unwrap_or(DEFAULT_PROFILE);
    // Racing first calls resolve from the same environment, so any
    // winner stores the same value.
    DEFAULT_CELL.store(encode(resolved), Ordering::Relaxed);
    resolved
}

/// Dispatch a [`LaneProfile`] into a `const L: usize` lane count —
/// the seam where runtime profile selection meets const-generic
/// monomorphization. `$body` is compiled once per profile with `$L`
/// bound as a local `const` item (so `kernel::<L>(..)` and even
/// `kernel::<{ 2 * L }>(..)` are ordinary const-generic calls), and
/// the match selects one instantiation at run time. Call it at tile
/// (or coarser) granularity: the whole point is that the profile test
/// happens once per block of work, never per element.
///
/// The three lane-count literals below are the only ones in the
/// library — PAL-LANE keeps it that way.
#[macro_export]
macro_rules! with_lane_count {
    ($profile:expr, $L:ident, $body:expr) => {
        match $profile {
            $crate::primitives::lanes::LaneProfile::Sve128 => {
                const $L: usize = 2;
                $body
            }
            $crate::primitives::lanes::LaneProfile::Sve256 => {
                const $L: usize = 4;
                $body
            }
            $crate::primitives::lanes::LaneProfile::Sve512 => {
                const $L: usize = 8;
                $body
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_table() {
        // (profile, lanes, bits, nr, kc, tile, wss_lanes)
        let rows = [
            (LaneProfile::Sve128, 2, 128, 2, 1024, 64, 4),
            (LaneProfile::Sve256, 4, 256, 4, 512, 128, 8),
            (LaneProfile::Sve512, 8, 512, 8, 256, 256, 16),
        ];
        for (p, lanes, bits, nr, kc, tile, wss) in rows {
            assert_eq!(p.lanes(), lanes);
            assert_eq!(p.bits(), bits);
            assert_eq!(p.nr(), nr);
            assert_eq!(p.kc(), kc);
            assert_eq!(p.tile(), tile);
            assert_eq!(p.wss_lanes(), wss);
            // Constant B-panel footprint across profiles.
            assert_eq!(p.kc() * p.nr(), 2048);
            // Tile cuts stay MR- and lane-aligned.
            assert_eq!(p.tile() % MR, 0);
            assert_eq!(p.tile() % p.lanes(), 0);
        }
    }

    #[test]
    fn sve512_matches_the_pre_profile_constants() {
        // The bit-compatibility anchor: the default profile reproduces
        // the constants the kernels hard-coded before this module.
        let p = DEFAULT_PROFILE;
        assert_eq!(p, LaneProfile::Sve512);
        assert_eq!(p.lanes(), 8);
        assert_eq!(p.nr(), 8);
        assert_eq!(p.kc(), 256);
        assert_eq!(p.tile(), 256);
        assert_eq!(p.wss_lanes(), 16);
        assert_eq!(MR, 4);
    }

    #[test]
    fn parse_round_trip_and_rejects() {
        for p in LaneProfile::ALL {
            assert_eq!(LaneProfile::parse(p.name()), Some(p));
        }
        for bad in ["sve", "sve1024", "SVE512", "neon", "", "8"] {
            assert_eq!(LaneProfile::parse(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn resolve_spec_grammar() {
        // Absent / empty.
        assert_eq!(resolve_spec(None), (None, None));
        assert_eq!(resolve_spec(Some("")), (None, None));
        assert_eq!(resolve_spec(Some(" , ,")), (None, None));
        // Pure backend token passes through untouched.
        assert_eq!(resolve_spec(Some("naive")), (Some("naive".into()), None));
        // `sve` without a width is NOT a profile — it must reach
        // Backend::parse and fail there, as it always has.
        assert_eq!(resolve_spec(Some("sve")), (Some("sve".into()), None));
        // Pure profile token.
        assert_eq!(resolve_spec(Some("sve256")), (None, Some(LaneProfile::Sve256)));
        // Mixed, either order, with spaces.
        assert_eq!(
            resolve_spec(Some("vectorized,sve128")),
            (Some("vectorized".into()), Some(LaneProfile::Sve128))
        );
        assert_eq!(
            resolve_spec(Some(" sve512 , auto ")),
            (Some("auto".into()), Some(LaneProfile::Sve512))
        );
        // First profile token wins.
        assert_eq!(resolve_spec(Some("sve128,sve512")), (None, Some(LaneProfile::Sve128)));
        // Multiple backend tokens are rejoined for Backend::parse to
        // reject loudly, not silently dropped.
        assert_eq!(
            resolve_spec(Some("naive,reference")),
            (Some("naive,reference".into()), None)
        );
    }

    #[test]
    fn default_profile_is_cached_and_consistent() {
        let a = default_profile();
        let b = default_profile();
        assert_eq!(a, b);
        // Whatever the test environment sets, the result is a valid
        // profile and the cache holds it.
        assert!(LaneProfile::ALL.contains(&a));
        assert_eq!(decode(DEFAULT_CELL.load(Ordering::Relaxed)), Some(a));
    }

    #[test]
    fn with_lane_count_binds_a_const() {
        fn probe<const L: usize>() -> usize {
            L
        }
        for p in LaneProfile::ALL {
            let got = crate::with_lane_count!(p, L, probe::<L>());
            assert_eq!(got, p.lanes(), "{}", p.name());
            // Derived const expressions work too (the WSS width).
            let wss = crate::with_lane_count!(p, L, probe::<{ 2 * L }>());
            assert_eq!(wss, p.wss_lanes(), "{}", p.name());
        }
    }
}
