//! Algorithm-level primitives shared across the ML layer — kernels that
//! several algorithms previously carried as private copies, hoisted onto
//! the BLAS/parallel substrate so every consumer inherits the same
//! packing discipline, threading and determinism contract.
//!
//! * [`distances`] — the fused pairwise squared-distance engine under
//!   k-means assignment, brute-force KNN, DBSCAN region queries and the
//!   SVM RBF gram tiles.

pub mod distances;
