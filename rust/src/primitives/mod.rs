//! Algorithm-level primitives shared across the ML layer — kernels that
//! several algorithms previously carried as private copies, hoisted onto
//! the BLAS/parallel substrate so every consumer inherits the same
//! packing discipline, threading and determinism contract.
//!
//! * [`distances`] — the fused pairwise squared-distance engine under
//!   k-means assignment, brute-force KNN, DBSCAN region queries and the
//!   SVM RBF gram tiles. Both input layouts feed the same fused
//!   epilogues: dense queries run prepacked-GEMM cross terms
//!   ([`distances::PackedCorpus`]), CSR queries run the threaded sparse
//!   multiply against a densified-transposed corpus packed once per
//!   call ([`distances::CsrCorpus`]). ε-neighbourhoods come back as a
//!   CSR-style [`distances::NeighborTable`] — one flat
//!   `(offsets, indices)` pair instead of a `Vec` per row.
//! * [`lanes`] — the vector-length-agnostic lane-profile layer: one
//!   [`lanes::LaneProfile`] (128/256/512-bit ⇒ 2/4/8 f64 lanes,
//!   resolved once per process or per `Context`) from which every
//!   lane-width and panel-geometry constant (`LANES`, `MR×NR`, `KC`,
//!   `TILE`, the WSS scan width) is derived, plus the
//!   [`crate::with_lane_count!`] dispatch macro that monomorphizes the
//!   predicated kernel bodies per profile at tile granularity.
//! * [`packed`] — model-resident packed state: a [`packed::ModelPanel`]
//!   (prepacked corpus + norms, CSR transpose, or weight vector) built
//!   once at `train` time and stored inside the fitted models, so
//!   every inference entry point is pack-free. Carries the
//!   process-global pack counter ([`packed::pack_events`]) tests use
//!   to assert that contract.

pub mod distances;
pub mod lanes;
pub mod packed;
