//! Model-resident packed corpus state — the pack-once ownership layer
//! under the serving front end (`coordinator::serve`).
//!
//! The fused distance engine packs its corpus per call; that amortizes
//! the pack across the tiles of *one* call. A fitted model answering
//! many small requests re-pays it on every request. [`ModelPanel`]
//! moves the packed state into the model: built **once at `train`
//! time** from the fitted corpus (KNN training set, k-means centroids,
//! SVM support vectors, linreg/logreg weights) and borrowed by every
//! inference call thereafter — `kneighbors` / `infer` /
//! `decision_function` / `predict_proba` are pack-free.
//!
//! Three shapes cover the model families:
//!
//! * [`DensePanel`] — a dense corpus, carried as **both** views the
//!   engine can consume: the prepacked micro-panels + norms
//!   ([`PackedCorpus`], for dense queries) and the
//!   densified-transposed buffer ([`CsrCorpus`], for CSR queries).
//!   One pooled norm reduction is shared between them, so both views
//!   hold bit-identical norms. The deliberate cost is ~2× the corpus
//!   memory; the win is that either query layout is pack-free.
//! * [`SparsePanel`] — a CSR corpus: the [`CsrCorpus`] view (stored-
//!   value norms + densified transpose, for CSR queries) plus the
//!   `O(nnz)` CSR transpose (for dense queries via the sparse
//!   end-to-end `csrmm(Transpose)` cross term,
//!   [`super::distances::top_k_dense_csr`]).
//! * [`WeightPanel`] — a coefficient vector (linreg/logreg): inference
//!   is a `gemv`/`csrmv` against the weights, so "packed" state is the
//!   owned copy itself; the panel exists so the pack-counter contract
//!   covers every model family uniformly.
//!
//! ## The pack counter
//!
//! Every corpus-pack constructor ([`super::distances::pack_corpus`],
//! the [`CsrCorpus`] constructors, the panel builders) bumps a
//! process-global relaxed counter; [`pack_events`] reads it. Tests
//! snapshot the counter around inference calls and assert the delta is
//! zero — the machine-checked form of the "pack-free inference"
//! contract (`tests/serve_property.rs`).

use crate::primitives::distances::{self, CsrCorpus, PackedCorpus};
use crate::primitives::lanes::{default_profile, LaneProfile};
use crate::sparse::CsrMatrix;
use crate::tables::DenseTable;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-global count of corpus-pack events (see module docs).
static PACK_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Record one corpus-pack event. Called by every pack constructor;
/// relaxed — the counter is test observability, not synchronization.
pub(crate) fn note_pack() {
    PACK_EVENTS.fetch_add(1, Ordering::Relaxed);
}

/// Total corpus-pack events since process start. Monotone; compare
/// snapshots around a call to assert it packed nothing.
pub fn pack_events() -> u64 {
    PACK_EVENTS.load(Ordering::Relaxed)
}

/// A dense corpus resident in a fitted model: prepacked micro-panels
/// for dense queries, the transposed view for CSR queries, one shared
/// norm vector (bit-identical in both views).
#[derive(Clone, Debug)]
pub struct DensePanel {
    packed: PackedCorpus,
    csr_view: CsrCorpus,
}

impl DensePanel {
    /// The prepacked micro-panels + norms (dense-query path).
    pub fn packed(&self) -> &PackedCorpus {
        &self.packed
    }

    /// The densified-transposed view + norms (CSR-query path).
    pub fn csr_view(&self) -> &CsrCorpus {
        &self.csr_view
    }
}

/// A CSR corpus resident in a fitted model: the [`CsrCorpus`] view for
/// CSR queries plus the `O(nnz)` CSR transpose for dense queries.
#[derive(Clone, Debug)]
pub struct SparsePanel {
    csr_view: CsrCorpus,
    at: CsrMatrix<f64>,
}

impl SparsePanel {
    /// The densified-transposed view + stored-value norms.
    pub fn csr_view(&self) -> &CsrCorpus {
        &self.csr_view
    }

    /// The corpus transposed as CSR (`d × n`), the sparse operand of
    /// the dense-query `csrmm(Transpose)` cross term.
    pub fn transposed(&self) -> &CsrMatrix<f64> {
        &self.at
    }
}

/// A coefficient vector resident in a fitted model (linreg/logreg).
#[derive(Clone, Debug)]
pub struct WeightPanel {
    weights: Vec<f64>,
}

impl WeightPanel {
    pub fn weights(&self) -> &[f64] {
        &self.weights
    }
}

/// Packed state owned by a fitted model, built once at `train` time.
/// Which variant a model holds follows its corpus: dense corpora get a
/// [`DensePanel`], CSR corpora a [`SparsePanel`], coefficient models a
/// [`WeightPanel`]. Inference borrows the panel through the
/// `primitives::distances` `*_packed` entry points (or the accessors
/// here) and never packs.
#[derive(Clone, Debug)]
pub enum ModelPanel {
    Dense(DensePanel),
    Sparse(SparsePanel),
    Weights(WeightPanel),
}

impl ModelPanel {
    /// Pack a dense corpus once, sharing one pooled norm reduction
    /// between the micro-panel and transposed views. Uses the
    /// process-default lane profile; `train` paths holding a `Context`
    /// route its profile through the `*_profile` builders.
    pub fn from_dense_table(y: &DenseTable<f64>, threads: usize) -> Self {
        Self::from_dense_table_profile(y, default_profile(), threads)
    }

    /// [`ModelPanel::from_dense_table`] under an explicit
    /// [`LaneProfile`]: both views carry the same profile, so every
    /// query layout is served at the width the model was trained with.
    pub fn from_dense_table_profile(
        y: &DenseTable<f64>,
        profile: LaneProfile,
        threads: usize,
    ) -> Self {
        let packed = distances::pack_corpus_table_profile(y, profile, threads);
        let csr_view = CsrCorpus::from_dense_with_norms(y, packed.norms().to_vec(), profile);
        ModelPanel::Dense(DensePanel { packed, csr_view })
    }

    /// Pack a CSR corpus once: the [`CsrCorpus`] view plus the
    /// `O(nnz)` counting-sort transpose. Process-default lane profile.
    pub fn from_csr(y: &CsrMatrix<f64>, threads: usize) -> Self {
        Self::from_csr_profile(y, default_profile(), threads)
    }

    /// [`ModelPanel::from_csr`] under an explicit [`LaneProfile`].
    pub fn from_csr_profile(y: &CsrMatrix<f64>, profile: LaneProfile, threads: usize) -> Self {
        let csr_view = CsrCorpus::from_csr_profile(y, profile, threads);
        ModelPanel::Sparse(SparsePanel { csr_view, at: y.transposed() })
    }

    /// Pack a corpus of either table layout (KNN's `train` ingests
    /// both). Process-default lane profile.
    pub fn from_table(y: crate::tables::TableRef<'_>, threads: usize) -> Self {
        Self::from_table_profile(y, default_profile(), threads)
    }

    /// [`ModelPanel::from_table`] under an explicit [`LaneProfile`].
    pub fn from_table_profile(
        y: crate::tables::TableRef<'_>,
        profile: LaneProfile,
        threads: usize,
    ) -> Self {
        match y {
            crate::tables::TableRef::Dense(t) => Self::from_dense_table_profile(t, profile, threads),
            crate::tables::TableRef::Csr(m) => Self::from_csr_profile(m, profile, threads),
        }
    }

    /// Own a coefficient vector (counted as one pack event so the
    /// pack-free-inference contract covers coefficient models too).
    pub fn from_weights(w: &[f64]) -> Self {
        note_pack();
        ModelPanel::Weights(WeightPanel { weights: w.to_vec() })
    }

    /// Corpus row count (`1` for a weight panel).
    pub fn rows(&self) -> usize {
        match self {
            ModelPanel::Dense(p) => p.packed.rows(),
            ModelPanel::Sparse(p) => p.csr_view.rows(),
            ModelPanel::Weights(_) => 1,
        }
    }

    /// Feature dimension the panel was packed with.
    pub fn dims(&self) -> usize {
        match self {
            ModelPanel::Dense(p) => p.packed.dims(),
            ModelPanel::Sparse(p) => p.csr_view.dims(),
            ModelPanel::Weights(p) => p.weights.len(),
        }
    }

    /// Corpus squared row norms (`None` for a weight panel).
    pub fn norms(&self) -> Option<&[f64]> {
        match self {
            ModelPanel::Dense(p) => Some(p.packed.norms()),
            ModelPanel::Sparse(p) => Some(p.csr_view.norms()),
            ModelPanel::Weights(_) => None,
        }
    }

    /// The prepacked dense corpus, if this is a dense panel.
    pub fn dense(&self) -> Option<&PackedCorpus> {
        match self {
            ModelPanel::Dense(p) => Some(&p.packed),
            _ => None,
        }
    }

    /// The transposed corpus view, for panels that carry one.
    pub fn csr_corpus(&self) -> Option<&CsrCorpus> {
        match self {
            ModelPanel::Dense(p) => Some(&p.csr_view),
            ModelPanel::Sparse(p) => Some(&p.csr_view),
            ModelPanel::Weights(_) => None,
        }
    }

    /// The CSR transpose of a sparse corpus panel.
    pub fn transposed_csr(&self) -> Option<&CsrMatrix<f64>> {
        match self {
            ModelPanel::Sparse(p) => Some(&p.at),
            _ => None,
        }
    }

    /// The coefficient vector of a weight panel.
    pub fn weights(&self) -> Option<&[f64]> {
        match self {
            ModelPanel::Weights(p) => Some(&p.weights),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Distribution, Gaussian, Mt19937};
    use crate::sparse::IndexBase;

    fn random_table(seed: u32, n: usize, d: usize) -> DenseTable<f64> {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::<f64>::standard();
        let mut v = vec![0.0; n * d];
        g.fill(&mut e, &mut v);
        DenseTable::from_vec(v, n, d).unwrap()
    }

    #[test]
    fn dense_panel_shares_norm_bits_between_views() {
        let y = random_table(1, 37, 5);
        let p = ModelPanel::from_dense_table(&y, 3);
        assert_eq!(p.rows(), 37);
        assert_eq!(p.dims(), 5);
        let packed = p.dense().unwrap();
        let view = p.csr_corpus().unwrap();
        for (a, b) in packed.norms().iter().zip(view.norms()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(p.transposed_csr().is_none());
        assert!(p.weights().is_none());
    }

    #[test]
    fn sparse_panel_transpose_round_trips() {
        let y = random_table(2, 29, 6);
        let m = CsrMatrix::from_dense(&y, 0.0, IndexBase::Zero);
        let p = ModelPanel::from_csr(&m, 2);
        assert_eq!(p.rows(), 29);
        assert_eq!(p.dims(), 6);
        let at = p.transposed_csr().unwrap();
        assert_eq!(at.rows(), 6);
        assert_eq!(at.cols(), 29);
        // The transpose densifies back to the same values the view's
        // `d × n` buffer holds.
        assert_eq!(at.to_dense().data(), p.csr_corpus().unwrap().bt());
        assert!(p.dense().is_none());
    }

    #[test]
    fn weight_panel_round_trips_and_counts_a_pack() {
        let before = pack_events();
        let p = ModelPanel::from_weights(&[1.0, -2.0, 0.5]);
        // Monotone assertion only: the counter is process-global and
        // unrelated unit tests pack concurrently. The strict delta
        // contract lives in `tests/serve_property.rs` under a lock.
        assert!(pack_events() > before, "from_weights must register a pack event");
        assert_eq!(p.weights().unwrap(), &[1.0, -2.0, 0.5]);
        assert_eq!(p.dims(), 3);
        assert_eq!(p.rows(), 1);
        assert!(p.norms().is_none());
    }

    #[test]
    fn pack_counter_registers_panel_builds() {
        let y = random_table(3, 16, 4);
        let before = pack_events();
        let p = ModelPanel::from_dense_table(&y, 1);
        assert!(pack_events() > before, "panel build must register pack events");
        // Borrowing the panel packs nothing (asserted strictly, under a
        // lock, in `tests/serve_property.rs`).
        let _ = p.dense().unwrap().norms();
        let _ = p.csr_corpus().unwrap().bt();
    }
}
