//! Fused pairwise squared-distance engine — **one** blocked, pooled
//! implementation of `‖x−y‖² = ‖x‖² − 2·x·y + ‖y‖²` under every
//! distance-based kernel in the paper's evaluation: k-means assignment
//! (argmin epilogue), brute-force KNN (bounded top-k), DBSCAN region
//! queries (ε-threshold neighbor lists) and the SVM RBF gram
//! (`exp(−γ·d²)` transform). Before this module each consumer carried a
//! private, partially sequential copy of the expansion; KNN and DBSCAN
//! never touched the worker pool at all and re-packed the corpus for
//! every query tile.
//!
//! ## Packing reuse
//!
//! The corpus side is packed **once per call** into the prepacked-GEMM
//! micro-panel layout ([`crate::blas::pack_b_panels`], the pack-once
//! discipline of the SVE packed-layout literature) and reused across
//! every query tile — [`PackedCorpus`] couples the panels with the
//! corpus row norms, which come from **one pooled reduction** (each
//! norm computed whole by one worker, partials concatenated in
//! partition order). Query rows stream through
//! [`crate::parallel::WorkerPool::global`] in `TILE`-row M-tiles; each
//! worker owns a private cross-term scratch and issues one
//! single-threaded [`crate::blas::gemm_prepacked_threads`] call per
//! tile — the fan-out happens at this level, never nested.
//!
//! ## Epilogue contract
//!
//! Every epilogue consumes the distance tile **while it is cache-hot**,
//! in the `svm/simd.rs` predication idiom: guards become lane masks
//! over `lanes()`-wide blocks — one vector of f64 under the active
//! [`LaneProfile`](crate::primitives::lanes::LaneProfile) (2/4/8 lanes
//! for 128/256/512-bit SVE; the corpus carries the profile it was
//! packed under) — arithmetic runs on all lanes with neutral elements
//! for dead lanes, and block reductions scan in index order so ties
//! always break to the **lowest corpus index**. The epilogue bodies are
//! const-generic over the lane count and monomorphize per profile;
//! [`crate::with_lane_count!`] selects the instantiation **once per
//! tile**, never per element. Distances are evaluated as
//! `qn − 2·cross + corpus_norm` — the one canonical expression order —
//! so consumers comparing against each other (or against their naive
//! scalar rungs) see consistent values. Because every comparison is on
//! exact per-element values (no accumulation across lanes), the
//! discrete outputs — argmin winners, top-k sets, ε-membership — are
//! identical across profiles; only the blocked GEMM cross terms can
//! differ across profiles (KC regrouping), to documented rounding.
//!
//! ## Determinism rules
//!
//! Worker-range cuts land only on `tile()` boundaries (the profile's
//! query-tile height; the RBF entry cuts on `MR` micro-panel
//! boundaries), so the global tile decomposition is
//! keyed by the input sizes alone — a tile is always computed whole, by
//! one worker, with the same instruction order, whatever the worker
//! count. Per-tile partials (e.g. inertia sums) merge in ascending tile
//! order. Every entry point is therefore **bit-identical at any worker
//! count**, which `tests/distances_property.rs` enforces for all four
//! epilogues.
//!
//! ## Sparse query path
//!
//! Every epilogue also has a CSR entry point (`*_csr`): the query side
//! is a [`CsrMatrix`], per-row `‖x‖²` comes from **one** pooled sweep of
//! the stored values ([`csr_row_norms`]), and the cross-term `X·Cᵀ` is
//! computed per query tile by a zero-copy row-window form of the
//! [`crate::sparse::csrmm`] inner loop (one worker per tile — the
//! fan-out happens at the tile level, exactly like the dense
//! sweep) against a corpus that is packed once per call into
//! [`CsrCorpus`]: the densified-*transposed* `d × n` buffer every tile
//! multiplies against, plus the corpus norms. The same epilogues then
//! consume the cache-hot tile, so sparse results obey the same
//! determinism rules: tile cuts are input-keyed, partials merge in
//! ascending tile order, and every `*_csr` entry point is
//! **bit-identical at any worker count**. Against the *densified*
//! oracle: cross terms accumulate in the same ascending-index order as
//! the dense microkernel (implicit zeros are exact no-ops), but norms
//! use a single-accumulator sweep rather than the 4-way unrolled dense
//! [`dot`], so distances agree to rounding — discrete outputs match the
//! oracle exactly away from exact decision boundaries.
//!
//! ## Model-resident panels
//!
//! Per-call packing amortizes across tiles; serving amortizes across
//! *requests*. [`crate::primitives::packed::ModelPanel`] wraps a
//! [`PackedCorpus`] / [`CsrCorpus`] (plus, for CSR corpora, the
//! `O(nnz)` CSR transpose) built **once at `train` time** and stored
//! inside the fitted models; [`top_k_packed`] / [`argmin_packed`] are
//! the borrowed-corpus entry points the algorithm layer calls at
//! inference time — pack-free, same epilogues, same determinism rules.
//! The per-call constructors above remain for one-shot callers, and
//! [`crate::primitives::packed::pack_events`] counts every corpus pack
//! so tests can assert inference performs none.

use crate::blas::level3::MR;
use crate::blas::{dot, gemm_prepacked_threads, pack_b_panels_profile, PackedB, Transpose};
use crate::coordinator::batch;
use crate::error::{Error, Result};
use crate::parallel;
use crate::primitives::lanes::{default_profile, LaneProfile};
use crate::primitives::packed::ModelPanel;
use crate::sparse::{csrmm_threads, CsrMatrix, SparseOp};
use crate::tables::{DenseTable, TableRef};

// Lane and tile geometry comes from the active `LaneProfile`: the
// predicated epilogue blocks are `lanes()` wide (one SVE vector of
// f64) and each worker consumes query tiles of `tile() = 32·lanes`
// rows — the `tile × n` cross-term block it computes and scans in one
// cache-hot piece.
/// Minimum multiply-adds per worker before the tile sweep fans out.
const PAR_MIN_FLOP: usize = 1 << 16;
/// Fan-out floor of the thin-m RBF gram entry (working sets are small,
/// so the bar is lower — matches the old `gram_tile` transform gate).
const RBF_MIN_FLOP: usize = 1 << 13;
/// Fan-out floor of the pooled corpus-norm reduction.
const NORM_MIN_WORK: usize = 1 << 14;

/// The corpus side of a pairwise-distance sweep, packed once: the
/// prepacked `op(B) = Yᵀ` micro-panels reused by every query tile plus
/// the corpus squared row norms from one pooled reduction. `Clone` so
/// a [`ModelPanel`] can live inside a `Clone` fitted model.
#[derive(Clone, Debug)]
pub struct PackedCorpus {
    pb: PackedB<f64>,
    norms: Vec<f64>,
}

impl PackedCorpus {
    /// Corpus row count `n`.
    pub fn rows(&self) -> usize {
        self.pb.n()
    }

    /// Feature dimension `d` the panels were packed with.
    pub fn dims(&self) -> usize {
        self.pb.k()
    }

    /// Squared row norms `‖y_j‖²`, length [`PackedCorpus::rows`].
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// The packed micro-panels (for callers issuing their own prepacked
    /// multiplies against the corpus).
    pub fn packed(&self) -> &PackedB<f64> {
        &self.pb
    }

    /// Lane profile the corpus was packed under. Geometry (panel width,
    /// tile height, epilogue block width) flows from here, so a corpus
    /// is always swept at the width it was packed with.
    pub fn profile(&self) -> LaneProfile {
        self.pb.profile()
    }
}

/// Pack an `n × d` row-major corpus once: micro-panel layout for the
/// cross-term GEMM plus pooled squared row norms. Packs under the
/// process-default lane profile; see [`pack_corpus_profile`].
pub fn pack_corpus(y: &[f64], n: usize, d: usize, threads: usize) -> PackedCorpus {
    pack_corpus_profile(y, n, d, default_profile(), threads)
}

/// [`pack_corpus`] under an explicit [`LaneProfile`] — the entry the
/// `Context`-aware algorithm layer uses so builder-selected profiles
/// reach the packed corpus.
pub fn pack_corpus_profile(
    y: &[f64],
    n: usize,
    d: usize,
    profile: LaneProfile,
    threads: usize,
) -> PackedCorpus {
    debug_assert_eq!(y.len(), n * d);
    super::packed::note_pack();
    PackedCorpus {
        pb: pack_b_panels_profile(Transpose::Yes, d, n, y, profile),
        norms: corpus_norms(y, n, d, threads),
    }
}

/// [`pack_corpus`] for a [`DenseTable`].
pub fn pack_corpus_table(y: &DenseTable<f64>, threads: usize) -> PackedCorpus {
    pack_corpus(y.data(), y.rows(), y.cols(), threads)
}

/// [`pack_corpus_table`] under an explicit [`LaneProfile`].
pub fn pack_corpus_table_profile(
    y: &DenseTable<f64>,
    profile: LaneProfile,
    threads: usize,
) -> PackedCorpus {
    pack_corpus_profile(y.data(), y.rows(), y.cols(), profile, threads)
}

/// Pooled corpus-norm reduction: each norm is one whole dot product
/// computed by exactly one worker, partials concatenated in partition
/// order — bit-identical at any worker count.
fn corpus_norms(y: &[f64], n: usize, d: usize, threads: usize) -> Vec<f64> {
    let workers = parallel::effective_threads(threads, n.saturating_mul(d), NORM_MIN_WORK);
    let bounds = parallel::even_bounds(n, workers);
    let partials = parallel::par_map(&bounds, |lo, hi| {
        (lo..hi)
            .map(|i| {
                let row = &y[i * d..(i + 1) * d];
                dot(row, row)
            })
            .collect::<Vec<f64>>()
    });
    let mut norms = Vec::with_capacity(n);
    for p in partials {
        norms.extend_from_slice(&p);
    }
    norms
}

/// Per-row `‖x_i‖²` of a dense row-major block — the same pooled
/// [`dot`]-based reduction the corpus norms use, exposed so iterative
/// callers (the Lloyd loop) can hoist the query-side norms out of
/// their loop: only the corpus changes between iterations. Bit-shares
/// with the inline `dot(qi, qi)` the epilogues would otherwise
/// compute, so hoisting is bit-identical.
pub fn dense_row_norms(x: &[f64], n: usize, d: usize, threads: usize) -> Vec<f64> {
    debug_assert_eq!(x.len(), n * d);
    corpus_norms(x, n, d, threads)
}

/// Per-row `‖x_i‖²` of a CSR matrix from **one** sweep of the stored
/// values (implicit zeros contribute nothing). Pooled like
/// [`PackedCorpus`]'s norms: each row is reduced whole by one worker
/// (single accumulator, ascending stored order) and partials
/// concatenate in partition order — bit-identical at any worker count.
pub fn csr_row_norms(x: &CsrMatrix<f64>, threads: usize) -> Vec<f64> {
    let n = x.rows();
    let workers = parallel::effective_threads(threads, x.nnz().max(n), NORM_MIN_WORK);
    let bounds = parallel::even_bounds(n, workers);
    let partials = parallel::par_map(&bounds, |lo, hi| {
        (lo..hi)
            .map(|i| {
                let mut acc = 0.0f64;
                for (_, v) in x.row_entries(i) {
                    acc = v.mul_add(v, acc);
                }
                acc
            })
            .collect::<Vec<f64>>()
    });
    let mut norms = Vec::with_capacity(n);
    for p in partials {
        norms.extend_from_slice(&p);
    }
    norms
}

/// The corpus side of a **sparse-query** distance sweep, packed once:
/// the corpus densified-*transposed* into a `d × n` row-major buffer —
/// the dense `B` operand every CSR cross-term multiply consumes — plus
/// the corpus squared row norms.
#[derive(Clone, Debug)]
pub struct CsrCorpus {
    /// `d × n` row-major transposed corpus.
    bt: Vec<f64>,
    n: usize,
    d: usize,
    norms: Vec<f64>,
    profile: LaneProfile,
}

impl CsrCorpus {
    /// Pack a dense corpus for sparse queries: one transpose plus the
    /// pooled [`dot`]-based norm reduction (the same norms the dense
    /// [`PackedCorpus`] carries). Uses the process-default lane
    /// profile; see [`CsrCorpus::from_dense_profile`].
    pub fn from_dense(y: &DenseTable<f64>, threads: usize) -> Self {
        Self::from_dense_profile(y, default_profile(), threads)
    }

    /// [`CsrCorpus::from_dense`] under an explicit [`LaneProfile`].
    pub fn from_dense_profile(y: &DenseTable<f64>, profile: LaneProfile, threads: usize) -> Self {
        let norms = corpus_norms(y.data(), y.rows(), y.cols(), threads);
        Self::from_dense_with_norms(y, norms, profile)
    }

    /// [`CsrCorpus::from_dense`] with the norms already in hand: the
    /// dense [`ModelPanel`] shares one pooled reduction between its
    /// packed and transposed views (same bits either way).
    pub(crate) fn from_dense_with_norms(
        y: &DenseTable<f64>,
        norms: Vec<f64>,
        profile: LaneProfile,
    ) -> Self {
        debug_assert_eq!(norms.len(), y.rows());
        super::packed::note_pack();
        CsrCorpus { bt: y.transposed().into_vec(), n: y.rows(), d: y.cols(), norms, profile }
    }

    /// Pack a CSR corpus for sparse queries: one densifying transpose
    /// scatter plus norms from one sweep of the stored values. Uses the
    /// process-default lane profile; see [`CsrCorpus::from_csr_profile`].
    pub fn from_csr(y: &CsrMatrix<f64>, threads: usize) -> Self {
        Self::from_csr_profile(y, default_profile(), threads)
    }

    /// [`CsrCorpus::from_csr`] under an explicit [`LaneProfile`].
    pub fn from_csr_profile(y: &CsrMatrix<f64>, profile: LaneProfile, threads: usize) -> Self {
        super::packed::note_pack();
        let norms = csr_row_norms(y, threads);
        CsrCorpus {
            bt: y.to_dense_transposed().into_vec(),
            n: y.rows(),
            d: y.cols(),
            norms,
            profile,
        }
    }

    /// Corpus row count `n`.
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Lane profile the corpus was packed under (fixes the sweep's tile
    /// height and the epilogues' block width).
    pub fn profile(&self) -> LaneProfile {
        self.profile
    }

    /// Feature dimension `d`.
    pub fn dims(&self) -> usize {
        self.d
    }

    /// Squared row norms `‖y_j‖²`, length [`CsrCorpus::rows`].
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// The densified-transposed `d × n` buffer (for callers issuing
    /// their own CSR multiplies against the corpus).
    pub fn bt(&self) -> &[f64] {
        &self.bt
    }
}

/// CSR-style neighbour table:
/// `indices[offsets[i]..offsets[i + 1]]` is the ascending neighbour
/// list of query row `i`. One flat allocation replaces the per-row
/// `Vec<Vec<usize>>` the ε-epilogue used to build — on dense-ε graphs
/// that was one allocator round-trip per row — and the shape dovetails
/// with the CSR table layout the sparse ingestion paths consume.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NeighborTable {
    offsets: Vec<usize>,
    indices: Vec<usize>,
}

impl NeighborTable {
    /// Build from per-row lists (test/oracle convenience).
    pub fn from_lists(lists: &[Vec<usize>]) -> Self {
        let mut offsets = Vec::with_capacity(lists.len() + 1);
        offsets.push(0);
        let mut indices = Vec::new();
        for l in lists {
            indices.extend_from_slice(l);
            offsets.push(indices.len());
        }
        NeighborTable { offsets, indices }
    }

    /// Number of query rows.
    pub fn rows(&self) -> usize {
        self.offsets.len().saturating_sub(1)
    }

    pub fn is_empty(&self) -> bool {
        self.rows() == 0
    }

    /// Ascending neighbour list of query row `i`.
    pub fn row(&self, i: usize) -> &[usize] {
        &self.indices[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Neighbour count of query row `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// The CSR offsets array (`rows + 1` entries).
    pub fn offsets(&self) -> &[usize] {
        &self.offsets
    }

    /// The flat, tile-ordered index array.
    pub fn indices(&self) -> &[usize] {
        &self.indices
    }

    /// Expand back into per-row lists (oracle comparisons).
    pub fn to_lists(&self) -> Vec<Vec<usize>> {
        (0..self.rows()).map(|i| self.row(i).to_vec()).collect()
    }
}

/// The shared tile sweep: stream query M-tiles through the worker pool,
/// computing each `len × n` cross-term block with one single-threaded
/// prepacked GEMM into the worker's private scratch, then hand the
/// cache-hot block to `tile_fn(tile_start, len, cross, out_rows)`.
/// Worker cuts land only on tile boundaries (the packing profile's
/// `tile()` height), so the tile decomposition — and the flattened,
/// ascending-tile order of the returned partials — is identical at any
/// worker count.
#[allow(clippy::too_many_arguments)]
fn sweep<T, R, F>(
    q: &[f64],
    m: usize,
    d: usize,
    corpus: &PackedCorpus,
    out: &mut [T],
    stride: usize,
    threads: usize,
    tile_fn: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &[f64], &mut [T]) -> R + Sync,
{
    let n = corpus.rows();
    let tile = corpus.profile().tile();
    debug_assert_eq!(q.len(), m * d);
    debug_assert_eq!(out.len(), m * stride);
    let work = m.saturating_mul(n).saturating_mul(d.max(1));
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, tile);
    let (pb, tile_fn) = (&corpus.pb, &tile_fn);
    let partials = parallel::scope_rows(out, stride, &bounds, |r0, r1, block| {
        let mut cross = vec![0.0f64; tile.min(r1 - r0) * n];
        let mut results = Vec::with_capacity((r1 - r0).div_ceil(tile));
        for (start, len) in batch::tiles(r1 - r0, tile) {
            crate::failpoint::check(crate::failpoint::SITE_TILE_SWEEP);
            let g0 = r0 + start;
            let ctile = &mut cross[..len * n];
            // Inner GEMM stays single-threaded: the fan-out already
            // happened one level up.
            gemm_prepacked_threads(
                Transpose::No,
                len,
                1.0,
                &q[g0 * d..(g0 + len) * d],
                pb,
                0.0,
                ctile,
                1,
            );
            let oblock = &mut block[start * stride..(start + len) * stride];
            results.push(tile_fn(g0, len, ctile, oblock));
        }
        results
    });
    partials.into_iter().flatten().collect()
}

/// Row-window CSR cross term: `out[i, :] = X[r0 + i, :] · Bt` for
/// `i < len`, straight off the query's existing CSR arrays — the
/// [`crate::sparse::csrmm`] `NoTranspose` inner loop (`β == 0`
/// overwrite, one `mul_add` per stored entry in ascending order, so
/// bit-identical to running the threaded kernel on a materialized row
/// slice) without allocating a sub-matrix per tile.
fn csr_window_cross(
    q: &CsrMatrix<f64>,
    r0: usize,
    len: usize,
    bt: &[f64],
    n: usize,
    out: &mut [f64],
) {
    debug_assert_eq!(out.len(), len * n);
    out.fill(0.0);
    for i in 0..len {
        let crow = &mut out[i * n..(i + 1) * n];
        for (kk, av) in q.row_entries(r0 + i) {
            let brow = &bt[kk * n..(kk + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv = av.mul_add(bv, *cv);
            }
        }
    }
}

/// [`sweep`]'s sparse-query twin: stream CSR query row tiles through
/// the worker pool, computing each `len × n` cross-term block with the
/// row-window CSR multiply (`X_tile · Cᵀ` against the
/// densified-transposed corpus — [`csr_window_cross`], zero copies)
/// into the worker's private scratch, then hand the cache-hot block to
/// `tile_fn(tile_start, len, cross, out_rows)`. Tile cuts land only on
/// tile boundaries (the corpus profile's `tile()` height) and partials
/// return in ascending tile order — bit-identical at any worker count.
fn sweep_csr<T, R, F>(
    q: &CsrMatrix<f64>,
    corpus: &CsrCorpus,
    out: &mut [T],
    stride: usize,
    threads: usize,
    tile_fn: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &[f64], &mut [T]) -> R + Sync,
{
    let m = q.rows();
    let n = corpus.n;
    let tile = corpus.profile.tile();
    debug_assert_eq!(q.cols(), corpus.d);
    debug_assert_eq!(out.len(), m * stride);
    let work = q.nnz().saturating_mul(n).max(m);
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, tile);
    let (bt, tile_fn) = (corpus.bt.as_slice(), &tile_fn);
    let partials = parallel::scope_rows(out, stride, &bounds, |r0, r1, block| {
        let mut cross = vec![0.0f64; tile.min(r1 - r0) * n];
        let mut results = Vec::with_capacity((r1 - r0).div_ceil(tile));
        for (start, len) in batch::tiles(r1 - r0, tile) {
            crate::failpoint::check(crate::failpoint::SITE_TILE_SWEEP);
            let g0 = r0 + start;
            let ctile = &mut cross[..len * n];
            // The fan-out already happened one level up; the window
            // multiply runs whole on this worker.
            csr_window_cross(q, g0, len, bt, n, ctile);
            let oblock = &mut block[start * stride..(start + len) * stride];
            results.push(tile_fn(g0, len, ctile, oblock));
        }
        results
    });
    partials.into_iter().flatten().collect()
}

/// k-means assignment epilogue: nearest corpus row per query (strict
/// `<`, ties to the lowest index) written into `assign`; returns the
/// inertia `Σ max(d²_min, 0)` accumulated in ascending row order.
/// `predicated` selects the branch-free lane scan (block width from the
/// corpus's packing profile) over the branchy scalar one — both produce
/// identical assignments and inertia bits (the reference-vs-vectorized
/// rung split of the dispatch ladder).
pub fn argmin_assign(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    predicated: bool,
    assign: &mut [usize],
    threads: usize,
) -> f64 {
    argmin_assign_with_norms(q, m, corpus, None, predicated, assign, threads)
}

/// [`argmin_assign`] with the query-side norms precomputed (`None` ⇒
/// compute `dot(qi, qi)` inline per row). [`dense_row_norms`] runs the
/// same [`dot`] per row, so hoisting the norms out of an iterative
/// caller's loop is bit-identical to the inline path.
pub fn argmin_assign_with_norms(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    qnorms: Option<&[f64]>,
    predicated: bool,
    assign: &mut [usize],
    threads: usize,
) -> f64 {
    let d = corpus.dims();
    let n = corpus.rows();
    assert!(n > 0, "argmin_assign: empty corpus");
    debug_assert_eq!(assign.len(), m);
    if let Some(v) = qnorms {
        debug_assert_eq!(v.len(), m);
    }
    let norms = corpus.norms.as_slice();
    let profile = corpus.profile();
    let partials = sweep(q, m, d, corpus, assign, 1, threads, |g0, len, cross, ablock| {
        // Profile dispatch happens once per tile; the lane-generic
        // epilogue body is monomorphized per profile.
        crate::with_lane_count!(profile, L, {
            let mut inertia = 0.0f64;
            for i in 0..len {
                let qn = match qnorms {
                    Some(v) => v[g0 + i],
                    None => {
                        let qi = &q[(g0 + i) * d..(g0 + i + 1) * d];
                        dot(qi, qi)
                    }
                };
                let row = &cross[i * n..(i + 1) * n];
                let (best, bestv) = if predicated {
                    argmin_lanes::<L>(qn, row, norms)
                } else {
                    argmin_scalar(qn, row, norms)
                };
                ablock[i] = best;
                inertia += bestv.max(0.0);
            }
            inertia
        })
    });
    partials.into_iter().sum()
}

/// [`argmin_assign`] for CSR queries: per-row norms from one
/// [`csr_row_norms`] sweep, cross terms from the tiled CSR multiply,
/// the **same** argmin epilogues (scalar or predicated lanes).
/// Bit-identical at any worker count.
pub fn argmin_assign_csr(
    q: &CsrMatrix<f64>,
    corpus: &CsrCorpus,
    predicated: bool,
    assign: &mut [usize],
    threads: usize,
) -> f64 {
    if q.rows() == 0 {
        return 0.0;
    }
    let qnorms = csr_row_norms(q, threads);
    argmin_assign_csr_with_norms(q, corpus, &qnorms, predicated, assign, threads)
}

/// [`argmin_assign_csr`] with the stored-value query norms
/// precomputed — the CSR Lloyd loop runs [`csr_row_norms`] once per
/// training call instead of once per iteration (the query side never
/// changes between iterations; bit-identical).
pub fn argmin_assign_csr_with_norms(
    q: &CsrMatrix<f64>,
    corpus: &CsrCorpus,
    qnorms: &[f64],
    predicated: bool,
    assign: &mut [usize],
    threads: usize,
) -> f64 {
    let m = q.rows();
    let n = corpus.n;
    assert!(n > 0, "argmin_assign_csr: empty corpus");
    debug_assert_eq!(assign.len(), m);
    debug_assert_eq!(qnorms.len(), m);
    if m == 0 {
        return 0.0;
    }
    let norms = corpus.norms.as_slice();
    let profile = corpus.profile();
    let partials = sweep_csr(q, corpus, assign, 1, threads, |g0, len, cross, ablock| {
        crate::with_lane_count!(profile, L, {
            let mut inertia = 0.0f64;
            for i in 0..len {
                let qn = qnorms[g0 + i];
                let row = &cross[i * n..(i + 1) * n];
                let (best, bestv) = if predicated {
                    argmin_lanes::<L>(qn, row, norms)
                } else {
                    argmin_scalar(qn, row, norms)
                };
                ablock[i] = best;
                inertia += bestv.max(0.0);
            }
            inertia
        })
    });
    partials.into_iter().sum()
}

/// Branchy scalar argmin over one distance row (the reference rung).
fn argmin_scalar(qn: f64, cross: &[f64], norms: &[f64]) -> (usize, f64) {
    let (mut best, mut bestv) = (0usize, f64::INFINITY);
    for (j, (&xc, &cn)) in cross.iter().zip(norms).enumerate() {
        let dist = qn - 2.0 * xc + cn;
        if dist < bestv {
            bestv = dist;
            best = j;
        }
    }
    (best, bestv)
}

/// Predicated `L`-lane argmin: distances evaluated unconditionally per
/// lane, then a block reduction in index order (strict `<` keeps the
/// earliest minimizer — the scalar loop's tie-break exactly). Because
/// the reduction compares exact per-element values in ascending index
/// order, the winner is independent of `L`: every profile returns the
/// scalar loop's answer bit-for-bit.
fn argmin_lanes<const L: usize>(qn: f64, cross: &[f64], norms: &[f64]) -> (usize, f64) {
    let n = cross.len();
    let (mut best, mut bestv) = (0usize, f64::INFINITY);
    let mut lane = [f64::INFINITY; L];
    let mut base = 0usize;
    while base < n {
        let len = L.min(n - base);
        for l in 0..len {
            let j = base + l;
            lane[l] = qn - 2.0 * cross[j] + norms[j];
        }
        for (l, &v) in lane.iter().take(len).enumerate() {
            let better = v < bestv;
            bestv = if better { v } else { bestv };
            best = if better { base + l } else { best };
        }
        base += len;
    }
    (best, bestv)
}

/// KNN epilogue: the `k` nearest `(corpus_index, sqdist)` per query
/// row, ascending by distance with ties to the lower index. Distances
/// are clamped at 0 (the expansion can go ε-negative for coincident
/// points). Returns fewer than `k` pairs only when the corpus is
/// smaller than `k`.
pub fn top_k(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    k: usize,
    threads: usize,
) -> Vec<Vec<(usize, f64)>> {
    let d = corpus.dims();
    let n = corpus.rows();
    let mut out: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
    if k == 0 || n == 0 || m == 0 {
        return out;
    }
    let norms = corpus.norms.as_slice();
    let profile = corpus.profile();
    sweep(q, m, d, corpus, &mut out, 1, threads, |g0, len, cross, oblock| {
        crate::with_lane_count!(profile, L, {
            for i in 0..len {
                let qi = &q[(g0 + i) * d..(g0 + i + 1) * d];
                let qn = dot(qi, qi);
                let row = &cross[i * n..(i + 1) * n];
                oblock[i] = select_k::<L>(qn, row, norms, k);
            }
        })
    });
    out
}

/// [`top_k`] for CSR queries — same bounded selection, same tie rules,
/// bit-identical at any worker count.
pub fn top_k_csr(
    q: &CsrMatrix<f64>,
    corpus: &CsrCorpus,
    k: usize,
    threads: usize,
) -> Vec<Vec<(usize, f64)>> {
    let m = q.rows();
    let n = corpus.n;
    let mut out: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
    if k == 0 || n == 0 || m == 0 {
        return out;
    }
    let qnorms = csr_row_norms(q, threads);
    let norms = corpus.norms.as_slice();
    let profile = corpus.profile();
    let qnorms = &qnorms;
    sweep_csr(q, corpus, &mut out, 1, threads, |g0, len, cross, oblock| {
        crate::with_lane_count!(profile, L, {
            for i in 0..len {
                let qn = qnorms[g0 + i];
                let row = &cross[i * n..(i + 1) * n];
                oblock[i] = select_k::<L>(qn, row, norms, k);
            }
        })
    });
    out
}

/// [`top_k`] for a **dense query × CSR corpus** pairing, sparse end to
/// end: the cross term is `corpus · Q_tileᵀ` via one
/// [`crate::sparse::csrmm`] `Transpose` multiply of the corpus's
/// `O(nnz)` CSR transpose `at` (`d × n`, [`CsrMatrix::transposed`])
/// against the transposed query tile — no densified corpus buffer is
/// ever built. Query tiles fan out on `TILE` boundaries through the
/// pool with the inner multiply single-threaded, so the tile
/// decomposition is input-keyed and the result is bit-identical at any
/// worker count. `corpus_norms` are the stored-value norms
/// ([`csr_row_norms`] of the corpus), so distances agree with the
/// densified oracle to rounding and index sets match it exactly away
/// from decision boundaries (the documented CSR approximation).
pub fn top_k_dense_csr(
    q: &[f64],
    m: usize,
    at: &CsrMatrix<f64>,
    corpus_norms: &[f64],
    k: usize,
    threads: usize,
) -> Vec<Vec<(usize, f64)>> {
    top_k_dense_csr_profile(q, m, at, corpus_norms, k, default_profile(), threads)
}

/// [`top_k_dense_csr`] under an explicit [`LaneProfile`] (no corpus
/// struct carries the profile on this pairing — the sparse panel's
/// stored profile is routed here by [`top_k_packed`]).
pub fn top_k_dense_csr_profile(
    q: &[f64],
    m: usize,
    at: &CsrMatrix<f64>,
    corpus_norms: &[f64],
    k: usize,
    profile: LaneProfile,
    threads: usize,
) -> Vec<Vec<(usize, f64)>> {
    let d = at.rows();
    let n = at.cols();
    let tile = profile.tile();
    debug_assert_eq!(q.len(), m * d);
    debug_assert_eq!(corpus_norms.len(), n);
    let mut out: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
    if k == 0 || n == 0 || m == 0 {
        return out;
    }
    let work = at.nnz().saturating_mul(m).max(m);
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, tile);
    parallel::scope_rows(&mut out, 1, &bounds, |r0, r1, oblock| {
        let cap = tile.min(r1 - r0);
        let mut qt = vec![0.0f64; d * cap];
        let mut ct = vec![0.0f64; n * cap];
        let mut cross = vec![0.0f64; cap * n];
        for (start, len) in batch::tiles(r1 - r0, tile) {
            crate::failpoint::check(crate::failpoint::SITE_TILE_SWEEP);
            let g0 = r0 + start;
            // Transpose the query tile into the dense `d × len` B
            // operand (every slot written — no clearing needed).
            let qtile = &mut qt[..d * len];
            for i in 0..len {
                let row = &q[(g0 + i) * d..(g0 + i + 1) * d];
                for (kk, &v) in row.iter().enumerate() {
                    qtile[kk * len + i] = v;
                }
            }
            // `C = atᵀ · Q_tileᵀ = corpus · Q_tileᵀ` (`n × len`), β == 0
            // overwrite. Single-threaded: the fan-out already happened
            // one level up.
            let ctile = &mut ct[..n * len];
            if csrmm_threads(SparseOp::Transpose, 1.0, at, qtile, len, 0.0, ctile, 1).is_err() {
                unreachable!("top_k_dense_csr: shapes checked by the debug asserts above");
            }
            // Back to row-major `len × n` for the cache-hot epilogue.
            let xtile = &mut cross[..len * n];
            for j in 0..n {
                for i in 0..len {
                    xtile[i * n + j] = ctile[j * len + i];
                }
            }
            crate::with_lane_count!(profile, L, {
                for i in 0..len {
                    let qi = &q[(g0 + i) * d..(g0 + i + 1) * d];
                    let qn = dot(qi, qi);
                    oblock[start + i] =
                        select_k::<L>(qn, &xtile[i * n..(i + 1) * n], corpus_norms, k);
                }
            });
        }
    });
    out
}

/// Borrowed-corpus KNN entry point: route a query of either layout
/// against a model-resident [`ModelPanel`] — **pack-free**; every
/// layout pairing reuses the panel state built at `train` time.
/// Dense panels serve dense queries from the prepacked micro-panels
/// and CSR queries from the transposed view; sparse panels serve CSR
/// queries from the densified-transposed buffer and dense queries
/// through the sparse-end-to-end [`top_k_dense_csr`] cross term.
pub fn top_k_packed(
    q: TableRef<'_>,
    panel: &ModelPanel,
    k: usize,
    threads: usize,
) -> Result<Vec<Vec<(usize, f64)>>> {
    if q.cols() != panel.dims() {
        return Err(Error::Shape(format!(
            "top_k_packed: query has {} features, panel expects {}",
            q.cols(),
            panel.dims()
        )));
    }
    match (panel, q) {
        (ModelPanel::Dense(p), TableRef::Dense(qd)) => {
            Ok(top_k(qd.data(), qd.rows(), p.packed(), k, threads))
        }
        (ModelPanel::Dense(p), TableRef::Csr(qs)) => Ok(top_k_csr(qs, p.csr_view(), k, threads)),
        (ModelPanel::Sparse(p), TableRef::Csr(qs)) => Ok(top_k_csr(qs, p.csr_view(), k, threads)),
        (ModelPanel::Sparse(p), TableRef::Dense(qd)) => Ok(top_k_dense_csr_profile(
            qd.data(),
            qd.rows(),
            p.transposed(),
            p.csr_view().norms(),
            k,
            p.csr_view().profile(),
            threads,
        )),
        (ModelPanel::Weights(_), _) => {
            Err(Error::Shape("top_k_packed: weight panel carries no corpus".into()))
        }
    }
}

/// Borrowed-corpus assignment entry point: nearest panel row per query
/// of either layout against a **dense** model-resident panel (k-means
/// centroids are always dense) — pack-free, same epilogues and inertia
/// bits as the per-call [`argmin_assign`] / [`argmin_assign_csr`].
pub fn argmin_packed(
    q: TableRef<'_>,
    panel: &ModelPanel,
    predicated: bool,
    assign: &mut [usize],
    threads: usize,
) -> Result<f64> {
    if q.cols() != panel.dims() {
        return Err(Error::Shape(format!(
            "argmin_packed: query has {} features, panel expects {}",
            q.cols(),
            panel.dims()
        )));
    }
    match (panel, q) {
        (ModelPanel::Dense(p), TableRef::Dense(qd)) => {
            Ok(argmin_assign(qd.data(), qd.rows(), p.packed(), predicated, assign, threads))
        }
        (ModelPanel::Dense(p), TableRef::Csr(qs)) => {
            Ok(argmin_assign_csr(qs, p.csr_view(), predicated, assign, threads))
        }
        _ => Err(Error::Shape("argmin_packed: requires a dense corpus panel".into())),
    }
}

/// Bounded top-k selection over one distance row: distances evaluated
/// in predicated `L`-lane blocks, candidates folded into a sorted bound
/// list (insertion keeps equal distances in ascending index order, so
/// the result matches a full `(dist, index)` sort). The fold consumes
/// candidates in ascending index order whatever `L` is, so the selected
/// set — values and order — is identical across profiles.
fn select_k<const L: usize>(qn: f64, cross: &[f64], norms: &[f64], k: usize) -> Vec<(usize, f64)> {
    let n = cross.len();
    let mut best: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
    let mut worst = f64::INFINITY;
    let mut lane = [0.0f64; L];
    let mut base = 0usize;
    while base < n {
        let len = L.min(n - base);
        for l in 0..len {
            let j = base + l;
            lane[l] = (qn - 2.0 * cross[j] + norms[j]).max(0.0);
        }
        for (l, &dist) in lane.iter().take(len).enumerate() {
            if dist < worst || best.len() < k {
                let pos = best.partition_point(|&(_, v)| v <= dist);
                best.insert(pos, (base + l, dist));
                if best.len() > k {
                    best.pop();
                }
                // `best` is nonempty right after the insert (k ≥ 1).
                if let Some(&(_, w)) = best.last() {
                    worst = w;
                }
            }
        }
        base += len;
    }
    best
}

/// One row of the ε-threshold epilogue: push every corpus index within
/// `eps2` of the row (ascending, predicated `L`-lane mask blocks) onto
/// `list`; return how many were pushed. Shared by the dense and CSR
/// sweeps so both produce bit-identical lists; the membership test is
/// an exact per-element compare, so the lists are identical across
/// profiles too.
#[inline]
fn eps_scan_row<const L: usize>(
    qn: f64,
    cross: &[f64],
    norms: &[f64],
    eps2: f64,
    skip: Option<usize>,
    list: &mut Vec<usize>,
) -> usize {
    let n = cross.len();
    let before = list.len();
    let mut lane = [false; L];
    let mut base = 0usize;
    while base < n {
        let blen = L.min(n - base);
        // Predicated block: the threshold compare is the mask.
        for l in 0..blen {
            let j = base + l;
            lane[l] = qn - 2.0 * cross[j] + norms[j] <= eps2;
        }
        for (l, &hit) in lane.iter().take(blen).enumerate() {
            let j = base + l;
            if hit && Some(j) != skip {
                list.push(j);
            }
        }
        base += blen;
    }
    list.len() - before
}

/// Assemble the CSR-style neighbour table from per-row counts (written
/// by the sweep's out buffer) and the tile-ordered index partials.
fn assemble_neighbors(counts: &[usize], partials: Vec<Vec<usize>>) -> NeighborTable {
    let mut offsets = Vec::with_capacity(counts.len() + 1);
    offsets.push(0usize);
    let mut acc = 0usize;
    for &c in counts {
        acc += c;
        offsets.push(acc);
    }
    let mut indices = Vec::with_capacity(acc);
    for p in partials {
        indices.extend_from_slice(&p);
    }
    debug_assert_eq!(indices.len(), acc);
    NeighborTable { offsets, indices }
}

/// DBSCAN epilogue: per query row, the ascending list of corpus indices
/// within squared radius `eps2` (`d² ≤ eps2`, the naive rung's exact
/// comparison), returned as a CSR-style [`NeighborTable`] — one flat
/// `(offsets, indices)` pair instead of a `Vec` per row, built from
/// per-tile partials concatenated in ascending tile order (so the lists
/// are bit-identical to the per-row-`Vec` construction at any worker
/// count). With `exclude_self`, corpus index `j` equal to the query's
/// own global row index is skipped — the self-query convention of a
/// corpus-vs-itself region query.
pub fn eps_neighbors(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    eps2: f64,
    exclude_self: bool,
    threads: usize,
) -> NeighborTable {
    let d = corpus.dims();
    let n = corpus.rows();
    let mut counts = vec![0usize; m];
    if m == 0 || n == 0 {
        return NeighborTable { offsets: vec![0; m + 1], indices: Vec::new() };
    }
    let norms = corpus.norms.as_slice();
    let profile = corpus.profile();
    let partials = sweep(q, m, d, corpus, &mut counts, 1, threads, |g0, len, cross, cblock| {
        crate::with_lane_count!(profile, L, {
            let mut local: Vec<usize> = Vec::new();
            for i in 0..len {
                let gi = g0 + i;
                let qi = &q[gi * d..(gi + 1) * d];
                let qn = dot(qi, qi);
                let row = &cross[i * n..(i + 1) * n];
                let skip = if exclude_self { Some(gi) } else { None };
                cblock[i] = eps_scan_row::<L>(qn, row, norms, eps2, skip, &mut local);
            }
            local
        })
    });
    assemble_neighbors(&counts, partials)
}

/// [`eps_neighbors`] for CSR queries — same predicated threshold scan,
/// same [`NeighborTable`] assembly, bit-identical at any worker count.
pub fn eps_neighbors_csr(
    q: &CsrMatrix<f64>,
    corpus: &CsrCorpus,
    eps2: f64,
    exclude_self: bool,
    threads: usize,
) -> NeighborTable {
    let m = q.rows();
    let n = corpus.n;
    let mut counts = vec![0usize; m];
    if m == 0 || n == 0 {
        return NeighborTable { offsets: vec![0; m + 1], indices: Vec::new() };
    }
    let qnorms = csr_row_norms(q, threads);
    let norms = corpus.norms.as_slice();
    let profile = corpus.profile();
    let qnorms = &qnorms;
    let partials = sweep_csr(q, corpus, &mut counts, 1, threads, |g0, len, cross, cblock| {
        crate::with_lane_count!(profile, L, {
            let mut local: Vec<usize> = Vec::new();
            for i in 0..len {
                let gi = g0 + i;
                let qn = qnorms[gi];
                let row = &cross[i * n..(i + 1) * n];
                let skip = if exclude_self { Some(gi) } else { None };
                cblock[i] = eps_scan_row::<L>(qn, row, norms, eps2, skip, &mut local);
            }
            local
        })
    });
    assemble_neighbors(&counts, partials)
}

/// The fused RBF epilogue over a row-major block, in place:
/// `v ← exp(−γ·max(qn_r − 2·v + cn_j, 0))`, `L`-lane chunked. One
/// helper shared by the dense and CSR gram paths so the canonical
/// expression order (and therefore the documented dense-vs-CSR rounding
/// agreement) lives in exactly one place. Purely elementwise, so the
/// transform itself is bit-identical for every `L`; only the GEMM cross
/// terms feeding it can differ across profiles.
fn rbf_transform_rows<const L: usize>(
    block: &mut [f64],
    r0: usize,
    w_norms: &[f64],
    corpus_norms: &[f64],
    gamma: f64,
) {
    let n = corpus_norms.len();
    for (r, orow) in block.chunks_mut(n).enumerate() {
        let qn = w_norms[r0 + r];
        for (vchunk, nchunk) in orow.chunks_mut(L).zip(corpus_norms.chunks(L)) {
            for (v, &cn) in vchunk.iter_mut().zip(nchunk) {
                let d2 = (qn - 2.0 * *v + cn).max(0.0);
                *v = (-gamma * d2).exp();
            }
        }
    }
}

/// RBF gram epilogue: `out[r, j] = exp(−γ·max(d²(w_r, y_j), 0))` with
/// the distance expansion fused into the cross-term tile while it is
/// cache-hot. Row ranges fan out on `MR` micro-panel boundaries (the
/// working sets this serves are thin — a `TILE`-aligned cut would
/// serialize them), each worker running one single-threaded prepacked
/// GEMM straight into its slice of `out` followed by the in-place
/// transform; bit-identical at any worker count.
pub fn rbf_gram(
    w: &[f64],
    w_norms: &[f64],
    corpus_norms: &[f64],
    pb: &PackedB<f64>,
    gamma: f64,
    out: &mut [f64],
    threads: usize,
) {
    let m = w_norms.len();
    let n = pb.n();
    let d = pb.k();
    debug_assert_eq!(w.len(), m * d);
    debug_assert_eq!(corpus_norms.len(), n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let profile = pb.profile();
    let work = m.saturating_mul(n).saturating_mul(d.max(1));
    let workers = parallel::effective_threads(threads, work, RBF_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, MR);
    parallel::scope_rows(out, n, &bounds, |r0, r1, block| {
        gemm_prepacked_threads(Transpose::No, r1 - r0, 1.0, &w[r0 * d..r1 * d], pb, 0.0, block, 1);
        crate::with_lane_count!(profile, L, {
            rbf_transform_rows::<L>(block, r0, w_norms, corpus_norms, gamma);
        });
    });
}

/// [`rbf_gram`] against a [`PackedCorpus`] (panels + norms packed once).
pub fn rbf_gram_corpus(
    w: &[f64],
    w_norms: &[f64],
    corpus: &PackedCorpus,
    gamma: f64,
    out: &mut [f64],
    threads: usize,
) {
    rbf_gram(w, w_norms, &corpus.norms, &corpus.pb, gamma, out, threads);
}

/// [`rbf_gram`] for a **sparse** working set: the cross term is one
/// threaded CSR multiply of `w` against the densified-transposed corpus
/// panel `bt` (`d × n` row-major — [`CsrCorpus::bt`] or the SVM active
/// panel), the `exp(−γ·d²)` transform is applied per output row while
/// it is hot. Both stages partition whole output rows per worker, so
/// the result is bit-identical at any worker count.
pub fn rbf_gram_csr(
    w: &CsrMatrix<f64>,
    w_norms: &[f64],
    corpus_norms: &[f64],
    bt: &[f64],
    gamma: f64,
    out: &mut [f64],
    threads: usize,
) {
    rbf_gram_csr_profile(w, w_norms, corpus_norms, bt, gamma, out, default_profile(), threads)
}

/// [`rbf_gram_csr`] under an explicit [`LaneProfile`] (the `bt` buffer
/// carries no profile of its own — the SVM engine routes its active
/// profile here).
#[allow(clippy::too_many_arguments)]
pub fn rbf_gram_csr_profile(
    w: &CsrMatrix<f64>,
    w_norms: &[f64],
    corpus_norms: &[f64],
    bt: &[f64],
    gamma: f64,
    out: &mut [f64],
    profile: LaneProfile,
    threads: usize,
) {
    let m = w.rows();
    let n = corpus_norms.len();
    debug_assert_eq!(w_norms.len(), m);
    debug_assert_eq!(bt.len(), w.cols() * n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if csrmm_threads(SparseOp::NoTranspose, 1.0, w, bt, n, 0.0, out, threads).is_err() {
        unreachable!("rbf_gram_csr: shapes checked by the debug asserts above");
    }
    let workers = parallel::effective_threads(threads, m.saturating_mul(n), RBF_MIN_FLOP);
    let bounds = parallel::even_bounds(m, workers);
    parallel::scope_rows(out, n, &bounds, |r0, _r1, block| {
        crate::with_lane_count!(profile, L, {
            rbf_transform_rows::<L>(block, r0, w_norms, corpus_norms, gamma);
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::sqdist;
    use crate::rng::{Distribution, Gaussian, Mt19937};

    fn random_rows(seed: u32, n: usize, d: usize) -> Vec<f64> {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::<f64>::standard();
        let mut v = vec![0.0; n * d];
        g.fill(&mut e, &mut v);
        v
    }

    #[test]
    fn corpus_norms_match_dot_oracle() {
        let (n, d) = (97, 6);
        let y = random_rows(1, n, d);
        let c = pack_corpus(&y, n, d, 4);
        assert_eq!(c.rows(), n);
        assert_eq!(c.dims(), d);
        for i in 0..n {
            let row = &y[i * d..(i + 1) * d];
            assert_eq!(c.norms()[i].to_bits(), dot(row, row).to_bits(), "row {i}");
        }
    }

    #[test]
    fn argmin_scalar_and_lanes_agree_with_sqdist_oracle() {
        let (m, n, d) = (41, 19, 5);
        let q = random_rows(2, m, d);
        let y = random_rows(3, n, d);
        let c = pack_corpus(&y, n, d, 1);
        let mut a_s = vec![0usize; m];
        let mut a_l = vec![0usize; m];
        let i_s = argmin_assign(&q, m, &c, false, &mut a_s, 1);
        let i_l = argmin_assign(&q, m, &c, true, &mut a_l, 1);
        assert_eq!(a_s, a_l);
        assert_eq!(i_s.to_bits(), i_l.to_bits());
        for i in 0..m {
            let qi = &q[i * d..(i + 1) * d];
            let (mut best, mut bestv) = (0usize, f64::INFINITY);
            for j in 0..n {
                let dist = sqdist(qi, &y[j * d..(j + 1) * d]);
                if dist < bestv {
                    bestv = dist;
                    best = j;
                }
            }
            assert_eq!(a_s[i], best, "row {i}");
        }
    }

    #[test]
    fn select_k_orders_ties_by_index() {
        // Corpus with duplicate rows: equal distances must list the
        // lower corpus index first.
        let d = 3usize;
        let y = [1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0, 0.0];
        let q = [0.0f64, 0.0, 0.0];
        let c = pack_corpus(&y, 4, d, 1);
        let nn = top_k(&q, 1, &c, 3, 1);
        let idx: Vec<usize> = nn[0].iter().map(|p| p.0).collect();
        assert_eq!(idx, vec![0, 2, 1]);
        assert_eq!(nn[0][0].1.to_bits(), nn[0][1].1.to_bits());
    }

    #[test]
    fn degenerate_shapes() {
        // Empty query set.
        let y = random_rows(4, 3, 2);
        let c = pack_corpus(&y, 3, 2, 2);
        let mut assign: Vec<usize> = Vec::new();
        assert_eq!(argmin_assign(&[], 0, &c, true, &mut assign, 4), 0.0);
        assert!(top_k(&[], 0, &c, 2, 4).is_empty());
        assert!(eps_neighbors(&[], 0, &c, 1.0, true, 4).is_empty());
        // 1×1 corpus, 1-col data.
        let c1 = pack_corpus(&[2.0], 1, 1, 1);
        let mut a1 = vec![9usize];
        let inertia = argmin_assign(&[2.0], 1, &c1, true, &mut a1, 1);
        assert_eq!(a1, vec![0]);
        assert!(inertia.abs() < 1e-12);
        let nn = top_k(&[2.0], 1, &c1, 5, 1);
        assert_eq!(nn[0], vec![(0, 0.0)]);
        // Self-exclusion leaves a lone point with no neighbours.
        let lists = eps_neighbors(&[2.0], 1, &c1, 100.0, true, 1);
        assert!(lists.row(0).is_empty());
        // k == 0 yields empty result rows.
        assert!(top_k(&[2.0], 1, &c1, 0, 1)[0].is_empty());
    }

    fn csr_from_dense(y: &[f64], rows: usize, cols: usize) -> crate::sparse::CsrMatrix<f64> {
        let t = DenseTable::from_vec(y.to_vec(), rows, cols).unwrap();
        crate::sparse::CsrMatrix::from_dense(&t, 0.0, crate::sparse::IndexBase::Zero)
    }

    #[test]
    fn csr_row_norms_match_stored_sweep() {
        let (n, d) = (57, 7);
        let y = random_rows(11, n, d);
        let m = csr_from_dense(&y, n, d);
        let norms = csr_row_norms(&m, 4);
        for i in 0..n {
            let row = &y[i * d..(i + 1) * d];
            let naive: f64 = row.iter().map(|v| v * v).sum();
            assert!((norms[i] - naive).abs() < 1e-12 * (1.0 + naive), "row {i}");
        }
        // Bit-identical at any worker count.
        let base = csr_row_norms(&m, 1);
        for threads in 2..=4 {
            let got = csr_row_norms(&m, threads);
            for (u, v) in base.iter().zip(&got) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn csr_epilogues_match_dense_engine() {
        // Sparsify by zeroing ~60% of entries, then compare the CSR
        // entry points against the dense engine on the densified data.
        let (m, n, d) = (83, 29, 6);
        let mut q = random_rows(12, m, d);
        for (i, v) in q.iter_mut().enumerate() {
            if (i * 7 + 3) % 5 < 3 {
                *v = 0.0;
            }
        }
        let y = random_rows(13, n, d);
        let qd = DenseTable::from_vec(q.clone(), m, d).unwrap();
        let qs = crate::sparse::CsrMatrix::from_dense(&qd, 0.0, crate::sparse::IndexBase::One);
        let dense_corpus = pack_corpus(&y, n, d, 2);
        let yd = DenseTable::from_vec(y.clone(), n, d).unwrap();
        let csr_corpus = CsrCorpus::from_dense(&yd, 2);
        // argmin assignments agree with the dense engine.
        let mut a_dense = vec![0usize; m];
        let mut a_csr = vec![0usize; m];
        let i_dense = argmin_assign(&q, m, &dense_corpus, true, &mut a_dense, 2);
        let i_csr = argmin_assign_csr(&qs, &csr_corpus, true, &mut a_csr, 2);
        assert_eq!(a_dense, a_csr);
        assert!((i_dense - i_csr).abs() < 1e-9 * (1.0 + i_dense.abs()));
        // top-k index sets agree.
        let nn_dense = top_k(&q, m, &dense_corpus, 4, 2);
        let nn_csr = top_k_csr(&qs, &csr_corpus, 4, 2);
        for (a, b) in nn_dense.iter().zip(&nn_csr) {
            let ia: Vec<usize> = a.iter().map(|p| p.0).collect();
            let ib: Vec<usize> = b.iter().map(|p| p.0).collect();
            assert_eq!(ia, ib);
        }
        // ε-lists agree.
        let e_dense = eps_neighbors(&q, m, &dense_corpus, 9.0, false, 2);
        let e_csr = eps_neighbors_csr(&qs, &csr_corpus, 9.0, false, 2);
        assert_eq!(e_dense.to_lists(), e_csr.to_lists());
    }

    #[test]
    fn csr_entry_points_bit_identical_across_workers() {
        let (m, n, d) = (700, 61, 5);
        let mut q = random_rows(14, m, d);
        for (i, v) in q.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let qd = DenseTable::from_vec(q, m, d).unwrap();
        let qs = crate::sparse::CsrMatrix::from_dense(&qd, 0.0, crate::sparse::IndexBase::Zero);
        let y = random_rows(15, n, d);
        let yd = DenseTable::from_vec(y, n, d).unwrap();
        let corpus = CsrCorpus::from_csr(
            &crate::sparse::CsrMatrix::from_dense(&yd, 0.0, crate::sparse::IndexBase::One),
            1,
        );
        let mut a1 = vec![0usize; m];
        let i1 = argmin_assign_csr(&qs, &corpus, true, &mut a1, 1);
        let nn1 = top_k_csr(&qs, &corpus, 3, 1);
        let e1 = eps_neighbors_csr(&qs, &corpus, 4.0, false, 1);
        for threads in 2..=4 {
            let mut a = vec![0usize; m];
            let it = argmin_assign_csr(&qs, &corpus, true, &mut a, threads);
            assert_eq!(a, a1, "threads={threads}");
            assert_eq!(it.to_bits(), i1.to_bits(), "threads={threads}");
            let nn = top_k_csr(&qs, &corpus, 3, threads);
            for (x, yy) in nn1.iter().zip(&nn) {
                assert_eq!(x.len(), yy.len());
                for (p, r) in x.iter().zip(yy) {
                    assert_eq!(p.0, r.0);
                    assert_eq!(p.1.to_bits(), r.1.to_bits());
                }
            }
            let e = eps_neighbors_csr(&qs, &corpus, 4.0, false, threads);
            assert_eq!(e1, e, "threads={threads}");
        }
    }

    #[test]
    fn rbf_gram_csr_matches_dense_rbf_gram() {
        let (ws, n, d) = (9, 33, 6);
        let mut w = random_rows(16, ws, d);
        for (i, v) in w.iter_mut().enumerate() {
            if i % 2 == 1 {
                *v = 0.0;
            }
        }
        let y = random_rows(17, n, d);
        let wd = DenseTable::from_vec(w.clone(), ws, d).unwrap();
        let wcsr = crate::sparse::CsrMatrix::from_dense(&wd, 0.0, crate::sparse::IndexBase::Zero);
        let w_norms = csr_row_norms(&wcsr, 1);
        let yd = DenseTable::from_vec(y.clone(), n, d).unwrap();
        let corpus = CsrCorpus::from_dense(&yd, 1);
        let pb = crate::blas::pack_b_panels(Transpose::Yes, d, n, &y);
        let dense_wn: Vec<f64> = (0..ws)
            .map(|i| {
                let row = &w[i * d..(i + 1) * d];
                dot(row, row)
            })
            .collect();
        let mut dense_out = vec![0.0f64; ws * n];
        rbf_gram(&w, &dense_wn, corpus.norms(), &pb, 0.3, &mut dense_out, 1);
        let mut base = vec![0.0f64; ws * n];
        rbf_gram_csr(&wcsr, &w_norms, corpus.norms(), corpus.bt(), 0.3, &mut base, 1);
        for (u, v) in dense_out.iter().zip(&base) {
            assert!((u - v).abs() < 1e-12, "{u} vs {v}");
        }
        for threads in 2..=4 {
            let mut out = vec![0.0f64; ws * n];
            rbf_gram_csr(&wcsr, &w_norms, corpus.norms(), corpus.bt(), 0.3, &mut out, threads);
            for (u, v) in base.iter().zip(&out) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn neighbor_table_round_trip_and_degenerates() {
        let lists = vec![vec![1usize, 3], vec![], vec![0, 1, 2]];
        let t = NeighborTable::from_lists(&lists);
        assert_eq!(t.rows(), 3);
        assert_eq!(t.degree(0), 2);
        assert_eq!(t.degree(1), 0);
        assert_eq!(t.row(2), &[0, 1, 2]);
        assert_eq!(t.offsets(), &[0, 2, 2, 5]);
        assert_eq!(t.indices(), &[1, 3, 0, 1, 2]);
        assert_eq!(t.to_lists(), lists);
        let empty = NeighborTable::from_lists(&[]);
        assert!(empty.is_empty());
        // nnz = 0 queries: every distance is the corpus norm.
        use crate::sparse::{CsrMatrix, IndexBase};
        let zero_rows =
            CsrMatrix::<f64>::new(2, 2, vec![], vec![], vec![0, 0, 0], IndexBase::Zero).unwrap();
        let yd = DenseTable::from_vec(vec![0.1, 0.0, 3.0, 4.0], 2, 2).unwrap();
        let corpus = CsrCorpus::from_dense(&yd, 1);
        let mut a = vec![9usize; 2];
        argmin_assign_csr(&zero_rows, &corpus, true, &mut a, 1);
        assert_eq!(a, vec![0, 0]);
        let e = eps_neighbors_csr(&zero_rows, &corpus, 1.0, false, 1);
        assert_eq!(e.to_lists(), vec![vec![0], vec![0]]);
    }

    #[test]
    fn hoisted_query_norms_are_bit_identical() {
        let (m, n, d) = (130, 17, 6);
        let q = random_rows(21, m, d);
        let y = random_rows(22, n, d);
        let c = pack_corpus(&y, n, d, 2);
        let qn = dense_row_norms(&q, m, d, 3);
        let mut a0 = vec![0usize; m];
        let mut a1 = vec![0usize; m];
        let i0 = argmin_assign(&q, m, &c, true, &mut a0, 2);
        let i1 = argmin_assign_with_norms(&q, m, &c, Some(&qn), true, &mut a1, 2);
        assert_eq!(a0, a1);
        assert_eq!(i0.to_bits(), i1.to_bits());
        // CSR twin: hoisted stored-value norms share bits with the
        // per-call sweep inside `argmin_assign_csr`.
        let qs = csr_from_dense(&q, m, d);
        let yd = DenseTable::from_vec(y, n, d).unwrap();
        let cc = CsrCorpus::from_dense(&yd, 2);
        let qsn = csr_row_norms(&qs, 2);
        let mut b0 = vec![0usize; m];
        let mut b1 = vec![0usize; m];
        let j0 = argmin_assign_csr(&qs, &cc, true, &mut b0, 2);
        let j1 = argmin_assign_csr_with_norms(&qs, &cc, &qsn, true, &mut b1, 2);
        assert_eq!(b0, b1);
        assert_eq!(j0.to_bits(), j1.to_bits());
    }

    #[test]
    fn dense_query_csr_corpus_matches_densified_oracle() {
        let (m, n, d) = (300, 23, 7);
        let q = random_rows(23, m, d);
        let mut y = random_rows(24, n, d);
        for (i, v) in y.iter_mut().enumerate() {
            if i % 3 != 0 {
                *v = 0.0;
            }
        }
        let ys = csr_from_dense(&y, n, d);
        let at = ys.transposed();
        let norms = csr_row_norms(&ys, 1);
        let got = top_k_dense_csr(&q, m, &at, &norms, 4, 1);
        // Densified oracle: index sets must match exactly.
        let c = pack_corpus(&y, n, d, 1);
        let oracle = top_k(&q, m, &c, 4, 1);
        for (row, (a, b)) in got.iter().zip(&oracle).enumerate() {
            let ia: Vec<usize> = a.iter().map(|p| p.0).collect();
            let ib: Vec<usize> = b.iter().map(|p| p.0).collect();
            assert_eq!(ia, ib, "row {row}");
        }
        // Bit-identical at any worker count.
        for threads in 2..=4 {
            let got_t = top_k_dense_csr(&q, m, &at, &norms, 4, threads);
            for (a, b) in got.iter().zip(&got_t) {
                assert_eq!(a.len(), b.len());
                for (p, r) in a.iter().zip(b) {
                    assert_eq!(p.0, r.0, "threads={threads}");
                    assert_eq!(p.1.to_bits(), r.1.to_bits(), "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn packed_entry_points_match_per_call_paths() {
        use crate::primitives::packed::ModelPanel;
        let (m, n, d) = (90, 21, 5);
        let q = random_rows(25, m, d);
        let y = random_rows(26, n, d);
        let yd = DenseTable::from_vec(y.clone(), n, d).unwrap();
        let qd = DenseTable::from_vec(q.clone(), m, d).unwrap();
        let panel = ModelPanel::from_dense_table(&yd, 2);
        // Dense query against the dense panel == per-call pack path.
        let per_call = top_k(&q, m, &pack_corpus(&y, n, d, 2), 3, 2);
        let packed = top_k_packed(TableRef::Dense(&qd), &panel, 3, 2).unwrap();
        assert_eq!(per_call, packed);
        // Assignment too, including inertia bits.
        let mut a0 = vec![0usize; m];
        let mut a1 = vec![0usize; m];
        let i0 = argmin_assign(&q, m, &pack_corpus(&y, n, d, 2), true, &mut a0, 2);
        let i1 = argmin_packed(TableRef::Dense(&qd), &panel, true, &mut a1, 2).unwrap();
        assert_eq!(a0, a1);
        assert_eq!(i0.to_bits(), i1.to_bits());
        // Shape mismatch is a typed error, not a panic.
        let bad = DenseTable::from_vec(vec![0.0; d + 1], 1, d + 1).unwrap();
        assert!(top_k_packed(TableRef::Dense(&bad), &panel, 3, 1).is_err());
        assert!(argmin_packed(TableRef::Dense(&bad), &panel, true, &mut [0usize], 1).is_err());
    }

    /// Cross-profile contract at the epilogue level: discrete outputs
    /// (assignments, top-k index sets, ε-lists) are identical at 2/4/8
    /// lanes; float outputs (inertia, top-k distances) agree to the
    /// documented tolerance. Shapes are remainder-heavy so every
    /// profile has a fringe block.
    #[test]
    fn profiles_agree_on_discrete_outputs() {
        use crate::primitives::lanes::LaneProfile;
        let (m, n, d) = (67, 21, 5);
        let q = random_rows(31, m, d);
        let y = random_rows(32, n, d);
        let base = pack_corpus_profile(&y, n, d, LaneProfile::Sve512, 2);
        assert_eq!(base.profile(), LaneProfile::Sve512);
        let mut a_base = vec![0usize; m];
        let i_base = argmin_assign(&q, m, &base, true, &mut a_base, 2);
        let nn_base = top_k(&q, m, &base, 4, 2);
        let e_base = eps_neighbors(&q, m, &base, 6.0, false, 2);
        for p in [LaneProfile::Sve128, LaneProfile::Sve256] {
            let c = pack_corpus_profile(&y, n, d, p, 2);
            assert_eq!(c.profile(), p);
            let mut a = vec![0usize; m];
            let inertia = argmin_assign(&q, m, &c, true, &mut a, 2);
            assert_eq!(a, a_base, "{}", p.name());
            assert!((inertia - i_base).abs() < 1e-9 * (1.0 + i_base.abs()), "{}", p.name());
            let nn = top_k(&q, m, &c, 4, 2);
            for (row, (got, want)) in nn.iter().zip(&nn_base).enumerate() {
                let ia: Vec<usize> = got.iter().map(|t| t.0).collect();
                let ib: Vec<usize> = want.iter().map(|t| t.0).collect();
                assert_eq!(ia, ib, "{} row {row}", p.name());
                for (u, v) in got.iter().zip(want) {
                    assert!((u.1 - v.1).abs() < 1e-9 * (1.0 + v.1.abs()), "{}", p.name());
                }
            }
            let e = eps_neighbors(&q, m, &c, 6.0, false, 2);
            assert_eq!(e.to_lists(), e_base.to_lists(), "{}", p.name());
        }
    }
}
