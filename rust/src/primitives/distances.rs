//! Fused pairwise squared-distance engine — **one** blocked, pooled
//! implementation of `‖x−y‖² = ‖x‖² − 2·x·y + ‖y‖²` under every
//! distance-based kernel in the paper's evaluation: k-means assignment
//! (argmin epilogue), brute-force KNN (bounded top-k), DBSCAN region
//! queries (ε-threshold neighbor lists) and the SVM RBF gram
//! (`exp(−γ·d²)` transform). Before this module each consumer carried a
//! private, partially sequential copy of the expansion; KNN and DBSCAN
//! never touched the worker pool at all and re-packed the corpus for
//! every query tile.
//!
//! ## Packing reuse
//!
//! The corpus side is packed **once per call** into the prepacked-GEMM
//! micro-panel layout ([`crate::blas::pack_b_panels`], the pack-once
//! discipline of the SVE packed-layout literature) and reused across
//! every query tile — [`PackedCorpus`] couples the panels with the
//! corpus row norms, which come from **one pooled reduction** (each
//! norm computed whole by one worker, partials concatenated in
//! partition order). Query rows stream through
//! [`crate::parallel::WorkerPool::global`] in `TILE`-row M-tiles; each
//! worker owns a private cross-term scratch and issues one
//! single-threaded [`crate::blas::gemm_prepacked_threads`] call per
//! tile — the fan-out happens at this level, never nested.
//!
//! ## Epilogue contract
//!
//! Every epilogue consumes the distance tile **while it is cache-hot**,
//! in the `svm/simd.rs` predication idiom: guards become lane masks
//! over 8-lane blocks ([`LANES`], one 512-bit SVE vector of f64),
//! arithmetic runs on all lanes with neutral elements for dead lanes,
//! and block reductions scan in index order so ties always break to the
//! **lowest corpus index**. Distances are evaluated as
//! `qn − 2·cross + corpus_norm` — the one canonical expression order —
//! so consumers comparing against each other (or against their naive
//! scalar rungs) see consistent values.
//!
//! ## Determinism rules
//!
//! Worker-range cuts land only on `TILE` boundaries (and the RBF entry
//! on `MR` micro-panel boundaries), so the global tile decomposition is
//! keyed by the input sizes alone — a tile is always computed whole, by
//! one worker, with the same instruction order, whatever the worker
//! count. Per-tile partials (e.g. inertia sums) merge in ascending tile
//! order. Every entry point is therefore **bit-identical at any worker
//! count**, which `tests/distances_property.rs` enforces for all four
//! epilogues.

use crate::blas::level3::MR;
use crate::blas::{dot, gemm_prepacked_threads, pack_b_panels, PackedB, Transpose};
use crate::coordinator::batch;
use crate::parallel;
use crate::tables::DenseTable;

/// Lanes per predicated epilogue block (a 512-bit SVE vector of f64).
pub const LANES: usize = 8;
/// Query rows per distance tile: the `TILE × n` cross-term block a
/// worker computes (and its epilogue consumes) in one piece.
const TILE: usize = 256;
/// Minimum multiply-adds per worker before the tile sweep fans out.
const PAR_MIN_FLOP: usize = 1 << 16;
/// Fan-out floor of the thin-m RBF gram entry (working sets are small,
/// so the bar is lower — matches the old `gram_tile` transform gate).
const RBF_MIN_FLOP: usize = 1 << 13;
/// Fan-out floor of the pooled corpus-norm reduction.
const NORM_MIN_WORK: usize = 1 << 14;

/// The corpus side of a pairwise-distance sweep, packed once: the
/// prepacked `op(B) = Yᵀ` micro-panels reused by every query tile plus
/// the corpus squared row norms from one pooled reduction.
pub struct PackedCorpus {
    pb: PackedB<f64>,
    norms: Vec<f64>,
}

impl PackedCorpus {
    /// Corpus row count `n`.
    pub fn rows(&self) -> usize {
        self.pb.n()
    }

    /// Feature dimension `d` the panels were packed with.
    pub fn dims(&self) -> usize {
        self.pb.k()
    }

    /// Squared row norms `‖y_j‖²`, length [`PackedCorpus::rows`].
    pub fn norms(&self) -> &[f64] {
        &self.norms
    }

    /// The packed micro-panels (for callers issuing their own prepacked
    /// multiplies against the corpus).
    pub fn packed(&self) -> &PackedB<f64> {
        &self.pb
    }
}

/// Pack an `n × d` row-major corpus once: micro-panel layout for the
/// cross-term GEMM plus pooled squared row norms.
pub fn pack_corpus(y: &[f64], n: usize, d: usize, threads: usize) -> PackedCorpus {
    debug_assert_eq!(y.len(), n * d);
    PackedCorpus {
        pb: pack_b_panels(Transpose::Yes, d, n, y),
        norms: corpus_norms(y, n, d, threads),
    }
}

/// [`pack_corpus`] for a [`DenseTable`].
pub fn pack_corpus_table(y: &DenseTable<f64>, threads: usize) -> PackedCorpus {
    pack_corpus(y.data(), y.rows(), y.cols(), threads)
}

/// Pooled corpus-norm reduction: each norm is one whole dot product
/// computed by exactly one worker, partials concatenated in partition
/// order — bit-identical at any worker count.
fn corpus_norms(y: &[f64], n: usize, d: usize, threads: usize) -> Vec<f64> {
    let workers = parallel::effective_threads(threads, n.saturating_mul(d), NORM_MIN_WORK);
    let bounds = parallel::even_bounds(n, workers);
    let partials = parallel::par_map(&bounds, |lo, hi| {
        (lo..hi)
            .map(|i| {
                let row = &y[i * d..(i + 1) * d];
                dot(row, row)
            })
            .collect::<Vec<f64>>()
    });
    let mut norms = Vec::with_capacity(n);
    for p in partials {
        norms.extend_from_slice(&p);
    }
    norms
}

/// The shared tile sweep: stream query M-tiles through the worker pool,
/// computing each `len × n` cross-term block with one single-threaded
/// prepacked GEMM into the worker's private scratch, then hand the
/// cache-hot block to `tile_fn(tile_start, len, cross, out_rows)`.
/// Worker cuts land only on `TILE` boundaries, so the tile
/// decomposition — and the flattened, ascending-tile order of the
/// returned partials — is identical at any worker count.
#[allow(clippy::too_many_arguments)]
fn sweep<T, R, F>(
    q: &[f64],
    m: usize,
    d: usize,
    corpus: &PackedCorpus,
    out: &mut [T],
    stride: usize,
    threads: usize,
    tile_fn: F,
) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &[f64], &mut [T]) -> R + Sync,
{
    let n = corpus.rows();
    debug_assert_eq!(q.len(), m * d);
    debug_assert_eq!(out.len(), m * stride);
    let work = m.saturating_mul(n).saturating_mul(d.max(1));
    let workers = parallel::effective_threads(threads, work, PAR_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, TILE);
    let (pb, tile_fn) = (&corpus.pb, &tile_fn);
    let partials = parallel::scope_rows(out, stride, &bounds, |r0, r1, block| {
        let mut cross = vec![0.0f64; TILE.min(r1 - r0) * n];
        let mut results = Vec::with_capacity((r1 - r0).div_ceil(TILE));
        for (start, len) in batch::tiles(r1 - r0, TILE) {
            let g0 = r0 + start;
            let ctile = &mut cross[..len * n];
            // Inner GEMM stays single-threaded: the fan-out already
            // happened one level up.
            gemm_prepacked_threads(
                Transpose::No,
                len,
                1.0,
                &q[g0 * d..(g0 + len) * d],
                pb,
                0.0,
                ctile,
                1,
            );
            let oblock = &mut block[start * stride..(start + len) * stride];
            results.push(tile_fn(g0, len, ctile, oblock));
        }
        results
    });
    partials.into_iter().flatten().collect()
}

/// k-means assignment epilogue: nearest corpus row per query (strict
/// `<`, ties to the lowest index) written into `assign`; returns the
/// inertia `Σ max(d²_min, 0)` accumulated in ascending row order.
/// `predicated` selects the branch-free 8-lane scan over the branchy
/// scalar one — both produce identical assignments and inertia bits
/// (the reference-vs-vectorized rung split of the dispatch ladder).
pub fn argmin_assign(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    predicated: bool,
    assign: &mut [usize],
    threads: usize,
) -> f64 {
    let d = corpus.dims();
    let n = corpus.rows();
    assert!(n > 0, "argmin_assign: empty corpus");
    debug_assert_eq!(assign.len(), m);
    let norms = corpus.norms.as_slice();
    let partials = sweep(q, m, d, corpus, assign, 1, threads, |g0, len, cross, ablock| {
        let mut inertia = 0.0f64;
        for i in 0..len {
            let qi = &q[(g0 + i) * d..(g0 + i + 1) * d];
            let qn = dot(qi, qi);
            let row = &cross[i * n..(i + 1) * n];
            let (best, bestv) = if predicated {
                argmin_lanes(qn, row, norms)
            } else {
                argmin_scalar(qn, row, norms)
            };
            ablock[i] = best;
            inertia += bestv.max(0.0);
        }
        inertia
    });
    partials.into_iter().sum()
}

/// Branchy scalar argmin over one distance row (the reference rung).
fn argmin_scalar(qn: f64, cross: &[f64], norms: &[f64]) -> (usize, f64) {
    let (mut best, mut bestv) = (0usize, f64::INFINITY);
    for (j, (&xc, &cn)) in cross.iter().zip(norms).enumerate() {
        let dist = qn - 2.0 * xc + cn;
        if dist < bestv {
            bestv = dist;
            best = j;
        }
    }
    (best, bestv)
}

/// Predicated 8-lane argmin: distances evaluated unconditionally per
/// lane, then a block reduction in index order (strict `<` keeps the
/// earliest minimizer — the scalar loop's tie-break exactly).
fn argmin_lanes(qn: f64, cross: &[f64], norms: &[f64]) -> (usize, f64) {
    let n = cross.len();
    let (mut best, mut bestv) = (0usize, f64::INFINITY);
    let mut lane = [f64::INFINITY; LANES];
    let mut base = 0usize;
    while base < n {
        let len = LANES.min(n - base);
        for l in 0..len {
            let j = base + l;
            lane[l] = qn - 2.0 * cross[j] + norms[j];
        }
        for (l, &v) in lane.iter().take(len).enumerate() {
            let better = v < bestv;
            bestv = if better { v } else { bestv };
            best = if better { base + l } else { best };
        }
        base += len;
    }
    (best, bestv)
}

/// KNN epilogue: the `k` nearest `(corpus_index, sqdist)` per query
/// row, ascending by distance with ties to the lower index. Distances
/// are clamped at 0 (the expansion can go ε-negative for coincident
/// points). Returns fewer than `k` pairs only when the corpus is
/// smaller than `k`.
pub fn top_k(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    k: usize,
    threads: usize,
) -> Vec<Vec<(usize, f64)>> {
    let d = corpus.dims();
    let n = corpus.rows();
    let mut out: Vec<Vec<(usize, f64)>> = vec![Vec::new(); m];
    if k == 0 || n == 0 || m == 0 {
        return out;
    }
    let norms = corpus.norms.as_slice();
    sweep(q, m, d, corpus, &mut out, 1, threads, |g0, len, cross, oblock| {
        for i in 0..len {
            let qi = &q[(g0 + i) * d..(g0 + i + 1) * d];
            let qn = dot(qi, qi);
            let row = &cross[i * n..(i + 1) * n];
            oblock[i] = select_k(qn, row, norms, k);
        }
    });
    out
}

/// Bounded top-k selection over one distance row: distances evaluated
/// in predicated 8-lane blocks, candidates folded into a sorted bound
/// list (insertion keeps equal distances in ascending index order, so
/// the result matches a full `(dist, index)` sort).
fn select_k(qn: f64, cross: &[f64], norms: &[f64], k: usize) -> Vec<(usize, f64)> {
    let n = cross.len();
    let mut best: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
    let mut worst = f64::INFINITY;
    let mut lane = [0.0f64; LANES];
    let mut base = 0usize;
    while base < n {
        let len = LANES.min(n - base);
        for l in 0..len {
            let j = base + l;
            lane[l] = (qn - 2.0 * cross[j] + norms[j]).max(0.0);
        }
        for (l, &dist) in lane.iter().take(len).enumerate() {
            if dist < worst || best.len() < k {
                let pos = best.partition_point(|&(_, v)| v <= dist);
                best.insert(pos, (base + l, dist));
                if best.len() > k {
                    best.pop();
                }
                worst = best.last().expect("k >= 1 candidates").1;
            }
        }
        base += len;
    }
    best
}

/// DBSCAN epilogue: per query row, the ascending list of corpus indices
/// within squared radius `eps2` (`d² ≤ eps2`, the naive rung's exact
/// comparison). With `exclude_self`, corpus index `j` equal to the
/// query's own global row index is skipped — the self-query convention
/// of a corpus-vs-itself region query.
pub fn eps_neighbors(
    q: &[f64],
    m: usize,
    corpus: &PackedCorpus,
    eps2: f64,
    exclude_self: bool,
    threads: usize,
) -> Vec<Vec<usize>> {
    let d = corpus.dims();
    let n = corpus.rows();
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); m];
    if m == 0 || n == 0 {
        return out;
    }
    let norms = corpus.norms.as_slice();
    sweep(q, m, d, corpus, &mut out, 1, threads, |g0, len, cross, oblock| {
        for i in 0..len {
            let gi = g0 + i;
            let qi = &q[gi * d..(gi + 1) * d];
            let qn = dot(qi, qi);
            let row = &cross[i * n..(i + 1) * n];
            let list = &mut oblock[i];
            let mut lane = [false; LANES];
            let mut base = 0usize;
            while base < n {
                let blen = LANES.min(n - base);
                // Predicated block: the threshold compare is the mask.
                for l in 0..blen {
                    let j = base + l;
                    lane[l] = qn - 2.0 * row[j] + norms[j] <= eps2;
                }
                for (l, &hit) in lane.iter().take(blen).enumerate() {
                    let j = base + l;
                    if hit && !(exclude_self && j == gi) {
                        list.push(j);
                    }
                }
                base += blen;
            }
        }
    });
    out
}

/// RBF gram epilogue: `out[r, j] = exp(−γ·max(d²(w_r, y_j), 0))` with
/// the distance expansion fused into the cross-term tile while it is
/// cache-hot. Row ranges fan out on `MR` micro-panel boundaries (the
/// working sets this serves are thin — a `TILE`-aligned cut would
/// serialize them), each worker running one single-threaded prepacked
/// GEMM straight into its slice of `out` followed by the in-place
/// transform; bit-identical at any worker count.
pub fn rbf_gram(
    w: &[f64],
    w_norms: &[f64],
    corpus_norms: &[f64],
    pb: &PackedB<f64>,
    gamma: f64,
    out: &mut [f64],
    threads: usize,
) {
    let m = w_norms.len();
    let n = pb.n();
    let d = pb.k();
    debug_assert_eq!(w.len(), m * d);
    debug_assert_eq!(corpus_norms.len(), n);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let work = m.saturating_mul(n).saturating_mul(d.max(1));
    let workers = parallel::effective_threads(threads, work, RBF_MIN_FLOP);
    let bounds = parallel::aligned_bounds(m, workers, MR);
    parallel::scope_rows(out, n, &bounds, |r0, r1, block| {
        gemm_prepacked_threads(Transpose::No, r1 - r0, 1.0, &w[r0 * d..r1 * d], pb, 0.0, block, 1);
        for (r, orow) in block.chunks_mut(n).enumerate() {
            let qn = w_norms[r0 + r];
            for (vchunk, nchunk) in orow.chunks_mut(LANES).zip(corpus_norms.chunks(LANES)) {
                for (v, &cn) in vchunk.iter_mut().zip(nchunk) {
                    let d2 = (qn - 2.0 * *v + cn).max(0.0);
                    *v = (-gamma * d2).exp();
                }
            }
        }
    });
}

/// [`rbf_gram`] against a [`PackedCorpus`] (panels + norms packed once).
pub fn rbf_gram_corpus(
    w: &[f64],
    w_norms: &[f64],
    corpus: &PackedCorpus,
    gamma: f64,
    out: &mut [f64],
    threads: usize,
) {
    rbf_gram(w, w_norms, &corpus.norms, &corpus.pb, gamma, out, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::sqdist;
    use crate::rng::{Distribution, Gaussian, Mt19937};

    fn random_rows(seed: u32, n: usize, d: usize) -> Vec<f64> {
        let mut e = Mt19937::new(seed);
        let mut g = Gaussian::<f64>::standard();
        let mut v = vec![0.0; n * d];
        g.fill(&mut e, &mut v);
        v
    }

    #[test]
    fn corpus_norms_match_dot_oracle() {
        let (n, d) = (97, 6);
        let y = random_rows(1, n, d);
        let c = pack_corpus(&y, n, d, 4);
        assert_eq!(c.rows(), n);
        assert_eq!(c.dims(), d);
        for i in 0..n {
            let row = &y[i * d..(i + 1) * d];
            assert_eq!(c.norms()[i].to_bits(), dot(row, row).to_bits(), "row {i}");
        }
    }

    #[test]
    fn argmin_scalar_and_lanes_agree_with_sqdist_oracle() {
        let (m, n, d) = (41, 19, 5);
        let q = random_rows(2, m, d);
        let y = random_rows(3, n, d);
        let c = pack_corpus(&y, n, d, 1);
        let mut a_s = vec![0usize; m];
        let mut a_l = vec![0usize; m];
        let i_s = argmin_assign(&q, m, &c, false, &mut a_s, 1);
        let i_l = argmin_assign(&q, m, &c, true, &mut a_l, 1);
        assert_eq!(a_s, a_l);
        assert_eq!(i_s.to_bits(), i_l.to_bits());
        for i in 0..m {
            let qi = &q[i * d..(i + 1) * d];
            let (mut best, mut bestv) = (0usize, f64::INFINITY);
            for j in 0..n {
                let dist = sqdist(qi, &y[j * d..(j + 1) * d]);
                if dist < bestv {
                    bestv = dist;
                    best = j;
                }
            }
            assert_eq!(a_s[i], best, "row {i}");
        }
    }

    #[test]
    fn select_k_orders_ties_by_index() {
        // Corpus with duplicate rows: equal distances must list the
        // lower corpus index first.
        let d = 3usize;
        let y = [1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0, 0.0];
        let q = [0.0f64, 0.0, 0.0];
        let c = pack_corpus(&y, 4, d, 1);
        let nn = top_k(&q, 1, &c, 3, 1);
        let idx: Vec<usize> = nn[0].iter().map(|p| p.0).collect();
        assert_eq!(idx, vec![0, 2, 1]);
        assert_eq!(nn[0][0].1.to_bits(), nn[0][1].1.to_bits());
    }

    #[test]
    fn degenerate_shapes() {
        // Empty query set.
        let y = random_rows(4, 3, 2);
        let c = pack_corpus(&y, 3, 2, 2);
        let mut assign: Vec<usize> = Vec::new();
        assert_eq!(argmin_assign(&[], 0, &c, true, &mut assign, 4), 0.0);
        assert!(top_k(&[], 0, &c, 2, 4).is_empty());
        assert!(eps_neighbors(&[], 0, &c, 1.0, true, 4).is_empty());
        // 1×1 corpus, 1-col data.
        let c1 = pack_corpus(&[2.0], 1, 1, 1);
        let mut a1 = vec![9usize];
        let inertia = argmin_assign(&[2.0], 1, &c1, true, &mut a1, 1);
        assert_eq!(a1, vec![0]);
        assert!(inertia.abs() < 1e-12);
        let nn = top_k(&[2.0], 1, &c1, 5, 1);
        assert_eq!(nn[0], vec![(0, 0.0)]);
        // Self-exclusion leaves a lone point with no neighbours.
        let lists = eps_neighbors(&[2.0], 1, &c1, 100.0, true, 1);
        assert!(lists[0].is_empty());
        // k == 0 yields empty result rows.
        assert!(top_k(&[2.0], 1, &c1, 0, 1)[0].is_empty());
    }
}
