//! Debug-build merge-order auditor — the runtime half of the `palint`
//! contract checking (see `crate::lint` and docs/INVARIANTS.md).
//!
//! The static pass can prove a `HashMap` is never traversed, but it
//! cannot prove that every *future* reduction combines its partials in
//! partition order — the "input-keyed chunks, fixed-order merges" rule
//! that makes parallel results bit-identical at any worker count. This
//! module turns that rule into a checked property: every batch drain in
//! `scheduler` opens a [`MergeAuditor`] for its fan-out site and feeds
//! it the chunk index of each partial as it is merged. Under
//! `debug_assertions` the auditor asserts the sequence is exactly
//! `0, 1, …, parts−1` (ascending, gapless, complete — completeness is
//! enforced on drop, so a refactor cannot silently skip it) and records
//! the `(site, chunk)` stream in a bounded thread-local ring that tests
//! inspect via [`recent_merges`]. Because every existing suite
//! (`parallel_property`, `pool_lifecycle`, `chaos`) runs the schedulers
//! at 1–4 workers, the property is exercised on every debug test run.
//!
//! Under `--release` the whole thing compiles out: the auditor is a
//! zero-sized type with empty `#[inline(always)]` methods, so the gates
//! add zero work to production drains.

#[cfg(debug_assertions)]
use std::cell::RefCell;
#[cfg(debug_assertions)]
use std::collections::VecDeque;

/// Bound on the thread-local merge record ring.
#[cfg(debug_assertions)]
const RING_CAPACITY: usize = 256;

#[cfg(debug_assertions)]
thread_local! {
    /// Merges always happen on the thread draining the batch (the
    /// submitter), so a thread-local ring sees a coherent sequence
    /// without any cross-thread synchronization.
    static RECENT: RefCell<VecDeque<(&'static str, usize)>> =
        RefCell::new(VecDeque::with_capacity(RING_CAPACITY));
}

/// Asserts that one batch's partial results are merged in ascending
/// fixed chunk order. Construct with [`MergeAuditor::begin`], call
/// [`MergeAuditor::merged`] per partial, end with
/// [`MergeAuditor::finish`].
#[cfg(debug_assertions)]
#[derive(Debug)]
pub struct MergeAuditor {
    site: &'static str,
    parts: usize,
    next: usize,
}

#[cfg(debug_assertions)]
impl MergeAuditor {
    /// Open an audit for a fan-out `site` merging `parts` partials.
    pub fn begin(site: &'static str, parts: usize) -> Self {
        MergeAuditor { site, parts, next: 0 }
    }

    /// Record that the partial for `chunk` was merged. Panics (debug
    /// builds only) unless chunks arrive in exactly ascending order.
    pub fn merged(&mut self, chunk: usize) {
        assert_eq!(
            chunk, self.next,
            "{}: merge order violation — chunk {chunk} merged where {} was expected \
             (fixed-order merging is what keeps parallel results bit-identical)",
            self.site, self.next
        );
        assert!(
            chunk < self.parts,
            "{}: chunk {chunk} out of range for {} parts",
            self.site,
            self.parts
        );
        self.next += 1;
        RECENT.with(|ring| {
            let mut ring = ring.borrow_mut();
            if ring.len() == RING_CAPACITY {
                ring.pop_front();
            }
            ring.push_back((self.site, chunk));
        });
    }

    /// Explicit end of the batch. The completeness assert lives in
    /// `Drop`, so even a drain that forgets `finish` is still checked.
    pub fn finish(self) {}
}

#[cfg(debug_assertions)]
impl Drop for MergeAuditor {
    fn drop(&mut self) {
        // Skip during unwinding: the batch legitimately stops short
        // when a job panic is being rethrown to the quarantine.
        if !std::thread::panicking() {
            assert_eq!(
                self.next, self.parts,
                "{}: batch dropped after merging {} of {} partials",
                self.site, self.next, self.parts
            );
        }
    }
}

/// Snapshot of this thread's most recent `(site, chunk)` merge records,
/// oldest first (bounded to the last [`RING_CAPACITY`]).
#[cfg(debug_assertions)]
pub fn recent_merges() -> Vec<(&'static str, usize)> {
    RECENT.with(|ring| ring.borrow().iter().copied().collect())
}

/// Clear this thread's merge record ring (test isolation helper).
#[cfg(debug_assertions)]
pub fn clear_recent() {
    RECENT.with(|ring| ring.borrow_mut().clear());
}

// ---------------------------------------------------------------------
// Release builds: same API surface, zero size, zero work. Everything
// inlines to nothing, which is what lets the schedulers call the
// auditor unconditionally.
// ---------------------------------------------------------------------

#[cfg(not(debug_assertions))]
#[derive(Debug)]
pub struct MergeAuditor;

#[cfg(not(debug_assertions))]
impl MergeAuditor {
    #[inline(always)]
    pub fn begin(_site: &'static str, _parts: usize) -> Self {
        MergeAuditor
    }

    #[inline(always)]
    pub fn merged(&mut self, _chunk: usize) {}

    #[inline(always)]
    pub fn finish(self) {}
}

#[cfg(not(debug_assertions))]
#[inline(always)]
pub fn recent_merges() -> Vec<(&'static str, usize)> {
    Vec::new()
}

#[cfg(not(debug_assertions))]
#[inline(always)]
pub fn clear_recent() {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascending_complete_sequence_passes_and_records() {
        clear_recent();
        let mut audit = MergeAuditor::begin("audit.test.ok", 3);
        for chunk in 0..3 {
            audit.merged(chunk);
        }
        audit.finish();
        if cfg!(debug_assertions) {
            let recs = recent_merges();
            let ours: Vec<usize> = recs
                .iter()
                .filter(|(site, _)| *site == "audit.test.ok")
                .map(|&(_, chunk)| chunk)
                .collect();
            assert_eq!(ours, vec![0, 1, 2]);
        } else {
            assert!(recent_merges().is_empty(), "release auditor must record nothing");
        }
    }

    #[test]
    fn single_part_batch_passes() {
        let mut audit = MergeAuditor::begin("audit.test.single", 1);
        audit.merged(0);
        audit.finish();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "merge order violation")]
    fn out_of_order_merge_panics() {
        let mut audit = MergeAuditor::begin("audit.test.ooo", 2);
        audit.merged(1);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "merge order violation")]
    fn repeated_chunk_panics() {
        let mut audit = MergeAuditor::begin("audit.test.dup", 2);
        audit.merged(0);
        audit.merged(0);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "after merging 1 of 2")]
    fn incomplete_batch_panics_on_drop() {
        let mut audit = MergeAuditor::begin("audit.test.short", 2);
        audit.merged(0);
        drop(audit);
    }

    #[cfg(debug_assertions)]
    #[test]
    fn ring_stays_bounded() {
        clear_recent();
        let n = RING_CAPACITY + 17;
        let mut audit = MergeAuditor::begin("audit.test.ring", n);
        for chunk in 0..n {
            audit.merged(chunk);
        }
        audit.finish();
        let recs = recent_merges();
        assert_eq!(recs.len(), RING_CAPACITY);
        // Oldest entries were evicted; the tail survives in order.
        assert_eq!(recs[RING_CAPACITY - 1], ("audit.test.ring", n - 1));
        assert_eq!(recs[0], ("audit.test.ring", n - RING_CAPACITY));
    }
}
