//! Threading substrate — the reproduction of the paper's "scales across
//! cores" half of the OpenBLAS story (§IV, Fig. 6).
//!
//! The paper's GEMM wins come from two levers: vector-friendly packed
//! panels *and* multicore scaling. This module supplies the second lever
//! as a dependency-free scheduler the BLAS layer (and the
//! row-independent algorithm hot paths) fan out on:
//!
//! * [`pool::WorkerPool`] — the **persistent worker pool** (PR 2): a
//!   lazily-initialized set of parked resident `std` threads behind a
//!   mutex-protected injector. Every fan-out below submits batch jobs to
//!   [`pool::WorkerPool::global`] instead of spawning scoped threads per
//!   call, so small/medium kernel launches no longer pay thread start-up
//!   cost. The submitting thread runs one partition itself and
//!   help-steals queued jobs while waiting, which keeps nested fan-outs
//!   deadlock-free; panicking closures are caught, the batch still
//!   drains, and the payload is re-thrown on the submitter.
//! * [`scope_rows`] — partition a mutable row-major buffer into disjoint
//!   contiguous row blocks and run one pool job per block; each job may
//!   return a partial result (reduction values are collected in
//!   partition order, so the combine step is deterministic).
//!   [`scope_rows_scoped`] is the retired per-call `std::thread::scope`
//!   implementation, kept as the launch-overhead baseline.
//! * [`par_map`] — the read-only variant: jobs see only an index range
//!   and return partials.
//! * [`audit::MergeAuditor`] — the debug-build merge-order auditor:
//!   every scheduler drain above asserts ascending, gapless, complete
//!   chunk merging under `debug_assertions` (and compiles to nothing
//!   under `--release`), turning the "input-keyed chunks, fixed-order
//!   merges" determinism rule into a property checked by every debug
//!   test run. See docs/INVARIANTS.md.
//! * [`even_bounds`] / [`aligned_bounds`] / [`triangle_bounds`] — the
//!   partitioners. `aligned_bounds` keeps cuts on micro-panel boundaries
//!   so a tile is always computed whole by one worker (this is what
//!   makes the parallel GEMM bit-identical to the single-thread run at
//!   any worker count); `triangle_bounds` balances the `Σ (m−i)` work
//!   profile of a triangular SYRK sweep.
//!
//! Worker counts come from [`crate::coordinator::Context::threads`] on
//! every path that has a `Context`; the bare BLAS entry points fall back
//! to the process default below, so `blas::gemm` stays callable from
//! code that never builds a context (tests, linalg helpers, benches).
//!
//! ## Process default
//!
//! [`default_threads`] resolves once from the `ONEDAL_SVE_THREADS`
//! environment override (mirroring oneDAL's `threader_env` /
//! `DAAL_NUM_THREADS` switch) falling back to
//! `std::thread::available_parallelism`, and can be pinned at runtime
//! with [`set_default_threads`].

pub mod audit;
// The one `unsafe` in the crate lives in the pool's job-lifetime
// transmute (see the SAFETY contract at its definition). The crate
// root carries `#![deny(unsafe_code)]`; only this module is licensed
// to override it. (`forbid` would be stronger but cannot be overridden
// by a scoped allow at all — E0453 — so `deny` + this one allow is the
// tightest expressible policy.)
#[allow(unsafe_code)]
pub mod pool;
mod scheduler;

pub use pool::{PoolHealth, WorkerPool};
pub use scheduler::{
    aligned_bounds, even_bounds, par_map, scope_rows, scope_rows_scoped, triangle_bounds,
};

use crate::error::{Error, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = "not resolved yet"; resolved lazily on first read.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Crate-level panic quarantine: run `f`, converting any panic that
/// escapes it — a worker-pool job, a deep kernel assert, an injected
/// failpoint — into [`Error::Internal`] carrying the fan-out `site` and
/// the panic payload message. Every public algorithm `train`/`infer`
/// body runs under this guard (validation stays outside it, so typed
/// validation errors pass through untouched), which is what makes the
/// library's fault contract hold: internal faults surface as typed
/// errors, never aborts.
pub fn quarantine<T>(site: &str, f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(result) => result,
        Err(payload) => {
            let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                s.to_string()
            } else if let Some(s) = payload.downcast_ref::<String>() {
                s.clone()
            } else {
                "non-string panic payload".to_string()
            };
            Err(Error::Internal(format!("{site}: {msg}")))
        }
    }
}

/// Resolution rule for the process default: a positive integer in the
/// `ONEDAL_SVE_THREADS` override wins; anything else falls back to the
/// machine's available parallelism. Exposed separately so tests can
/// exercise the rule directly — mutating the process environment would
/// race `getenv` calls on sibling test threads.
pub fn resolve_default_threads(env_value: Option<&str>) -> usize {
    env_value
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Process-default worker count for BLAS calls made without a `Context`.
pub fn default_threads() -> usize {
    let cur = DEFAULT_THREADS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let resolved =
        resolve_default_threads(std::env::var("ONEDAL_SVE_THREADS").ok().as_deref());
    DEFAULT_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Pin the process-default worker count (clamped to ≥ 1).
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Clamp a requested worker count so each worker has at least
/// `min_work` units of work — fanning out a 4×4 GEMM across 16 cores
/// costs more in thread launch than the multiply itself.
pub fn effective_threads(requested: usize, work: usize, min_work: usize) -> usize {
    let cap = (work / min_work.max(1)).max(1);
    requested.max(1).min(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_at_least_one() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn effective_threads_clamps_small_work() {
        assert_eq!(effective_threads(8, 10, 100), 1);
        assert_eq!(effective_threads(8, 250, 100), 2);
        assert_eq!(effective_threads(4, 1_000_000, 100), 4);
        assert_eq!(effective_threads(0, 1_000_000, 100), 1);
    }

    #[test]
    fn quarantine_passes_ok_and_typed_errors_through() {
        assert_eq!(quarantine("t", || Ok(7)).unwrap(), 7);
        let e = quarantine::<()>("t", || Err(Error::Param("bad".into()))).unwrap_err();
        assert!(matches!(e, Error::Param(_)));
    }

    #[test]
    fn quarantine_converts_panics_with_site_and_payload() {
        let e = quarantine::<()>("kmeans.train", || panic!("boom {}", 3)).unwrap_err();
        match e {
            Error::Internal(msg) => assert_eq!(msg, "kmeans.train: boom 3"),
            other => panic!("wrong variant: {other:?}"),
        }
        let e = quarantine::<()>("s", || std::panic::panic_any(42i32)).unwrap_err();
        assert!(e.to_string().contains("non-string panic payload"));
    }
}
