//! Threading substrate — the reproduction of the paper's "scales across
//! cores" half of the OpenBLAS story (§IV, Fig. 6).
//!
//! The paper's GEMM wins come from two levers: vector-friendly packed
//! panels *and* multicore scaling. This module supplies the second lever
//! as a dependency-free scoped-thread scheduler the BLAS layer (and the
//! row-independent algorithm hot paths) fan out on:
//!
//! * [`scope_rows`] — partition a mutable row-major buffer into disjoint
//!   contiguous row blocks and run one scoped worker per block; each
//!   worker may return a partial result (reduction values are collected
//!   in worker order, so the combine step is deterministic).
//! * [`par_map`] — the read-only variant: workers see only an index
//!   range and return partials.
//! * [`even_bounds`] / [`aligned_bounds`] / [`triangle_bounds`] — the
//!   partitioners. `aligned_bounds` keeps cuts on micro-panel boundaries
//!   so a tile is always computed whole by one worker (this is what
//!   makes the parallel GEMM bit-identical to the single-thread run at
//!   any worker count); `triangle_bounds` balances the `Σ (m−i)` work
//!   profile of a triangular SYRK sweep.
//!
//! Worker counts come from [`crate::coordinator::Context::threads`] on
//! every path that has a `Context`; the bare BLAS entry points fall back
//! to the process default below, so `blas::gemm` stays callable from
//! code that never builds a context (tests, linalg helpers, benches).
//!
//! ## Process default
//!
//! [`default_threads`] resolves once from the `ONEDAL_SVE_THREADS`
//! environment override (mirroring oneDAL's `threader_env` /
//! `DAAL_NUM_THREADS` switch) falling back to
//! `std::thread::available_parallelism`, and can be pinned at runtime
//! with [`set_default_threads`].

mod scheduler;

pub use scheduler::{aligned_bounds, even_bounds, par_map, scope_rows, triangle_bounds};

use std::sync::atomic::{AtomicUsize, Ordering};

/// 0 = "not resolved yet"; resolved lazily on first read.
static DEFAULT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Process-default worker count for BLAS calls made without a `Context`.
pub fn default_threads() -> usize {
    let cur = DEFAULT_THREADS.load(Ordering::Relaxed);
    if cur != 0 {
        return cur;
    }
    let resolved = std::env::var("ONEDAL_SVE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    DEFAULT_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Pin the process-default worker count (clamped to ≥ 1).
pub fn set_default_threads(n: usize) {
    DEFAULT_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Clamp a requested worker count so each worker has at least
/// `min_work` units of work — fanning out a 4×4 GEMM across 16 cores
/// costs more in thread launch than the multiply itself.
pub fn effective_threads(requested: usize, work: usize, min_work: usize) -> usize {
    let cap = (work / min_work.max(1)).max(1);
    requested.max(1).min(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_at_least_one() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn effective_threads_clamps_small_work() {
        assert_eq!(effective_threads(8, 10, 100), 1);
        assert_eq!(effective_threads(8, 250, 100), 2);
        assert_eq!(effective_threads(4, 1_000_000, 100), 4);
        assert_eq!(effective_threads(0, 1_000_000, 100), 1);
    }
}
