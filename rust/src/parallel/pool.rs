//! Persistent worker pool — the resident execution engine under
//! [`super::scope_rows`] / [`super::par_map`].
//!
//! PR 1 spawned a fresh `std::thread::scope` per BLAS call, which is
//! correct but pays the full thread launch cost on every kernel — the
//! paper's multithreaded-OpenBLAS speedups (§V) only materialize for
//! small/medium launches when the execution engine stays resident. This
//! module keeps a process-wide set of parked `std` threads alive across
//! calls:
//!
//! * **Lazy** — no thread exists until the first multi-part batch; the
//!   pool then grows to the demanded width (capped) and never shrinks.
//! * **Dependency-free** — a `Mutex<VecDeque>` injector plus a `Condvar`;
//!   no crossbeam, no channels.
//! * **Caller participates** — the submitting thread always runs one
//!   partition itself and then *helps drain the queue* while waiting, so
//!   nested batches (a pool job that itself fans out) can never deadlock
//!   even on a single-worker pool.
//! * **Panic-safe** — jobs run under `catch_unwind`; the first payload is
//!   re-thrown on the submitting thread *after* every job of the batch
//!   has finished, so a panicking closure can neither deadlock the latch
//!   nor kill a worker thread (workers survive and take the next job).
//! * **Shutdown-safe** — dropping a non-global pool flags shutdown,
//!   wakes every worker, drains the queue and joins all threads. The
//!   global pool lives for the process and its parked workers exit with
//!   it.
//!
//! The pool schedules *batches*, not futures: [`WorkerPool::run_batch`]
//! takes one closure per partition and returns only when all of them
//! have run. That blocking contract is also what makes the lifetime
//! erasure sound (see the `SAFETY` note in `run_batch`): borrows
//! captured by the closures are guaranteed to outlive every execution.
//! Determinism is unaffected — which thread runs a partition never
//! changes what the partition computes or where it writes, so the
//! bit-identical-across-worker-counts property of the panel-aligned
//! partitioners carries over unchanged.

use crate::failpoint::{self, SITE_POOL_JOB};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock, PoisonError};
use std::thread::JoinHandle;
use std::time::Duration;

/// A queued, lifetime-erased batch job.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Point-in-time liveness snapshot of a [`WorkerPool`] — the probe the
/// resilience layer and the lifecycle tests use to assert "the pool
/// respawned after a panic" instead of sleeping and hoping.
/// `alive + dead` equals [`WorkerPool::worker_count`] at the instant
/// of the probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolHealth {
    /// Workers whose threads are still running their loop.
    pub alive: usize,
    /// Workers whose threads have exited (killed by an escaped panic)
    /// and await reaping — the next batch reaps and respawns them.
    pub dead: usize,
}

impl PoolHealth {
    /// No dead workers awaiting respawn.
    pub fn is_healthy(&self) -> bool {
        self.dead == 0
    }
}

#[derive(Default)]
struct Queue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<Queue>,
    /// Signaled when a job is queued or shutdown is requested.
    ready: Condvar,
}

/// Completion latch for one `run_batch` call: counts outstanding remote
/// jobs and holds the first panic payload until the submitter rethrows.
struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn Any + Send>>,
}

impl Latch {
    fn new(remaining: usize) -> Self {
        Self {
            state: Mutex::new(LatchState { remaining, panic: None }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        st.remaining -= 1;
        if st.panic.is_none() {
            st.panic = panic;
        }
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).remaining == 0
    }

    /// Block until the batch completes or `timeout` elapses. The timeout
    /// covers the race where a *nested* batch lands helpable jobs in the
    /// queue after the submitter found it empty and went to sleep.
    fn wait_done_timeout(&self, timeout: Duration) {
        let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        if st.remaining > 0 {
            match self.done.wait_timeout(st, timeout) {
                Ok((guard, _timed_out)) => drop(guard),
                Err(poisoned) => drop(poisoned.into_inner()),
            }
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner).panic.take()
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut q = shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
    loop {
        if let Some(job) = q.jobs.pop_front() {
            drop(q);
            job(); // wrapped: catches its own panics, signals its latch
            q = shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
        } else if q.shutdown {
            return;
        } else {
            q = shared.ready.wait(q).unwrap_or_else(PoisonError::into_inner);
        }
    }
}

/// Persistent worker pool. Most code never touches this type directly —
/// [`super::scope_rows`] / [`super::par_map`] go through
/// [`WorkerPool::global`] — but tests and benches can build private
/// pools to exercise lifecycle behavior in isolation.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    max_workers: usize,
}

impl WorkerPool {
    /// Pool with no threads yet; workers spawn lazily as batches demand
    /// them, up to `max_workers`, and then persist.
    pub fn new(max_workers: usize) -> Self {
        Self {
            shared: Arc::new(Shared {
                queue: Mutex::new(Queue::default()),
                ready: Condvar::new(),
            }),
            workers: Mutex::new(Vec::new()),
            max_workers: max_workers.max(1),
        }
    }

    /// The process-wide pool every scheduler entry point uses. Sized to
    /// twice the available parallelism (batches wider than the pool
    /// still complete — surplus partitions queue and the caller helps).
    pub fn global() -> &'static WorkerPool {
        static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            WorkerPool::new((cores * 2).clamp(4, 64))
        })
    }

    /// Live workers (dead handles are pruned lazily by the next batch,
    /// so the count can briefly include a worker that has panicked but
    /// not yet been reaped).
    pub fn worker_count(&self) -> usize {
        self.workers.lock().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// Liveness probe: how many workers are alive vs dead-but-unreaped
    /// right now. Dead workers are respawned by the next batch
    /// (`ensure_workers` reaps then regrows), so
    /// `run_batch(...); health().is_healthy()` is the deterministic
    /// "respawn completed" assertion — no sleeps.
    pub fn health(&self) -> PoolHealth {
        let ws = self.workers.lock().unwrap_or_else(PoisonError::into_inner);
        let dead = ws.iter().filter(|h| h.is_finished()).count();
        PoolHealth { alive: ws.len() - dead, dead }
    }

    fn ensure_workers(&self, want: usize) {
        let want = want.min(self.max_workers);
        let mut ws = self.workers.lock().unwrap_or_else(PoisonError::into_inner);
        // Reap workers killed by an escaped panic (fault injection, or a
        // raw job bypassing the batch wrapper) so the pool respawns back
        // to full width instead of silently narrowing for the rest of
        // the process.
        let mut i = 0;
        while i < ws.len() {
            if ws[i].is_finished() {
                let _ = ws.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        while ws.len() < want {
            let shared = Arc::clone(&self.shared);
            let spawned = std::thread::Builder::new()
                .name(format!("onedal-pool-{}", ws.len()))
                .spawn(move || worker_loop(shared));
            match spawned {
                Ok(handle) => ws.push(handle),
                // Resource exhaustion: run narrower — the batch still
                // completes because the caller help-steals the surplus.
                Err(_) => break,
            }
        }
    }

    /// Run every job of a batch, one per output partition, and return
    /// once **all** of them have finished. The last job runs inline on
    /// the calling thread (a 1-job batch touches no lock at all); the
    /// rest go to the resident workers. If any job panics, the first
    /// payload is re-thrown here — after the whole batch has completed,
    /// so no borrow handed to a sibling job is ever freed early.
    pub fn run_batch<'a>(&self, mut jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let Some(local) = jobs.pop() else { return };
        if jobs.is_empty() {
            failpoint::check(SITE_POOL_JOB);
            local();
            return;
        }
        let n_remote = jobs.len();
        self.ensure_workers(n_remote);
        let latch = Arc::new(Latch::new(n_remote));
        {
            let mut q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            for job in jobs {
                let latch = Arc::clone(&latch);
                let wrapped: Box<dyn FnOnce() + Send + 'a> = Box::new(move || {
                    let panic = catch_unwind(AssertUnwindSafe(|| {
                        failpoint::check(SITE_POOL_JOB);
                        job();
                    }))
                    .err();
                    latch.complete(panic);
                });
                // SAFETY: lifetime erasure (`'a` → `'static`), sound by
                // three obligations this function upholds:
                //
                // 1. Containment — `run_batch` does not return, not
                //    even on panic, until the latch has counted every
                //    queued job complete: `help_until_done` loops until
                //    `remaining == 0`, and the local panic payload is
                //    rethrown only after that loop. The `'a` borrows
                //    live in the caller's frame, which is pinned for
                //    exactly that long.
                // 2. Ordering — a job signals its latch strictly after
                //    the erased closure has finished and dropped its
                //    captures (`job()` consumes the box; the borrows
                //    are dead before `latch.complete` runs), so the
                //    latch reaching zero happens-after every access to
                //    the borrows. Panic payloads cannot smuggle a
                //    borrow out: `panic_any` requires `Any`, which is
                //    `'static`.
                // 3. Exclusivity — the queue hands each `Job` to
                //    exactly one thread (`pop_front` under the mutex),
                //    so no `&mut` capture is ever aliased.
                //
                // The `'static` in `Job` is taken to mean nothing more
                // than "outlives its execution", which 1–3 guarantee.
                // This is the crate's only `unsafe` (the root carries
                // `#![deny(unsafe_code)]`; `parallel::pool` alone holds
                // a scoped allow) and the Miri CI job runs these pool
                // tests to check the erasure and the atomics for UB.
                let wrapped: Job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Job>(wrapped)
                };
                q.jobs.push_back(wrapped);
            }
            self.shared.ready.notify_all();
        }
        // The caller is worker zero: run its own partition, then help.
        let local_panic = catch_unwind(AssertUnwindSafe(|| {
            failpoint::check(SITE_POOL_JOB);
            local();
        }))
        .err();
        self.help_until_done(&latch);
        let panic = latch.take_panic().or(local_panic);
        if let Some(payload) = panic {
            resume_unwind(payload);
        }
    }

    /// Drain queue jobs (own batch or anyone else's) until `latch` is
    /// done. Stealing instead of blocking is what makes nested batches
    /// deadlock-free: a worker waiting on an inner batch executes that
    /// batch's jobs itself if no other thread is free.
    fn help_until_done(&self, latch: &Latch) {
        loop {
            if latch.is_done() {
                return;
            }
            let job = {
                let mut q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
                q.jobs.pop_front()
            };
            match job {
                Some(job) => job(),
                None => latch.wait_done_timeout(Duration::from_micros(200)),
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            q.shutdown = true;
            self.shared.ready.notify_all();
        }
        let mut ws = self.workers.lock().unwrap_or_else(PoisonError::into_inner);
        for handle in std::mem::take(&mut *ws) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn boxed<'a, F: FnOnce() + Send + 'a>(f: F) -> Box<dyn FnOnce() + Send + 'a> {
        Box::new(f)
    }

    #[test]
    fn batch_runs_every_job_exactly_once() {
        let pool = WorkerPool::new(4);
        for round in 0..25 {
            let counter = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..8)
                .map(|_| {
                    let c = &counter;
                    boxed(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    })
                })
                .collect();
            pool.run_batch(jobs);
            assert_eq!(counter.load(Ordering::Relaxed), 8, "round {round}");
        }
        assert!(pool.worker_count() >= 1);
    }

    #[test]
    fn empty_and_single_batches_are_inline() {
        let pool = WorkerPool::new(4);
        pool.run_batch(Vec::new());
        let hit = AtomicUsize::new(0);
        pool.run_batch(vec![boxed(|| {
            hit.fetch_add(1, Ordering::Relaxed);
        })]);
        assert_eq!(hit.load(Ordering::Relaxed), 1);
        // Neither call may have spawned a thread.
        assert_eq!(pool.worker_count(), 0);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        // Panic in a remote job (index 0 is queued) and in the local job
        // (the last index runs on the caller) both propagate.
        for panic_at in [0usize, 3] {
            for round in 0..3 {
                let jobs: Vec<_> = (0..4)
                    .map(|w| {
                        boxed(move || {
                            if w == panic_at {
                                panic!("injected pool panic {w}");
                            }
                        })
                    })
                    .collect();
                let caught = catch_unwind(AssertUnwindSafe(|| pool.run_batch(jobs)));
                assert!(caught.is_err(), "panic_at={panic_at} round={round}");
                // The pool must still run fresh work to completion.
                let ok = AtomicUsize::new(0);
                let jobs: Vec<_> = (0..4)
                    .map(|_| {
                        let ok = &ok;
                        boxed(move || {
                            ok.fetch_add(1, Ordering::Relaxed);
                        })
                    })
                    .collect();
                pool.run_batch(jobs);
                assert_eq!(ok.load(Ordering::Relaxed), 4);
            }
        }
    }

    #[test]
    fn nested_batches_complete_on_a_narrow_pool() {
        // 3 outer jobs each fanning out 3 inner jobs on a pool capped at
        // two workers: completion requires caller help-stealing.
        let pool = WorkerPool::new(2);
        let total = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..3)
            .map(|_| {
                let total = &total;
                let pool = &pool;
                boxed(move || {
                    let inner: Vec<_> = (0..3)
                        .map(|_| {
                            boxed(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            })
                        })
                        .collect();
                    pool.run_batch(inner);
                })
            })
            .collect();
        pool.run_batch(jobs);
        assert_eq!(total.load(Ordering::Relaxed), 9);
    }

    /// A worker killed by a panic that escapes the batch wrapper (the
    /// quarantine bypass only raw queue jobs can hit) is reaped and
    /// replaced by the next batch, and results after the respawn match
    /// a fresh pool bit for bit.
    // Miri: the test polls a 10 s wall-clock deadline around real
    // thread teardown — minutes under the interpreter for no extra UB
    // coverage (the transmute and atomics are exercised by the other
    // pool tests).
    #[cfg_attr(miri, ignore = "wall-clock deadline poll around thread teardown")]
    #[test]
    fn dead_worker_is_replaced_at_next_batch() {
        let pool = WorkerPool::new(2);
        // Grow to full width first.
        pool.run_batch((0..3).map(|_| boxed(|| {})).collect());
        let width = pool.worker_count();
        assert!(width >= 1);
        // Kill every worker with raw, unwrapped panicking jobs.
        {
            let mut q = pool.shared.queue.lock().unwrap_or_else(PoisonError::into_inner);
            for _ in 0..width {
                q.jobs.push_back(Box::new(|| panic!("raw job panic")) as Job);
            }
            pool.shared.ready.notify_all();
        }
        // Wait for the panics to take the threads down.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let all_dead = {
                let ws = pool.workers.lock().unwrap_or_else(PoisonError::into_inner);
                ws.iter().all(|h| h.is_finished())
            };
            if all_dead {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "workers never died");
            std::thread::sleep(Duration::from_millis(1));
        }
        // The next batch reaps the corpses, respawns to full width and
        // completes normally.
        let sum = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..3)
            .map(|w| {
                let sum = &sum;
                boxed(move || {
                    sum.fetch_add(w + 1, Ordering::Relaxed);
                })
            })
            .collect();
        // Before that batch, the probe must see the corpses.
        let sick = pool.health();
        assert_eq!(sick.dead, width, "probe must count the dead workers");
        assert!(!sick.is_healthy());
        pool.run_batch(jobs);
        assert_eq!(sum.load(Ordering::Relaxed), 6);
        assert_eq!(pool.worker_count(), width, "pool must be back at full width");
        // The respawn-completed assertion the resilience layer relies
        // on: after one batch, no dead worker remains unreaped.
        let healed = pool.health();
        assert!(healed.is_healthy(), "respawn must have completed: {healed:?}");
        assert_eq!(healed.alive, width);
        let fresh = WorkerPool::new(2);
        let a = AtomicUsize::new(0);
        fresh.run_batch(
            (0..3)
                .map(|w| {
                    let a = &a;
                    boxed(move || {
                        a.fetch_add(w + 1, Ordering::Relaxed);
                    })
                })
                .collect(),
        );
        assert_eq!(a.load(Ordering::Relaxed), sum.load(Ordering::Relaxed));
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(3);
        let jobs: Vec<_> = (0..6).map(|_| boxed(|| {})).collect();
        pool.run_batch(jobs);
        assert!(pool.worker_count() >= 1);
        drop(pool); // must terminate promptly, not hang
    }

    // Miri: the global pool's workers park for the life of the process
    // by design, and Miri reports still-running threads at main-thread
    // exit as an error. Private-pool tests cover the same code paths
    // with joined threads.
    #[cfg_attr(miri, ignore = "global pool threads outlive main by design")]
    #[test]
    fn global_pool_is_reusable() {
        for _ in 0..4 {
            let sum = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..5)
                .map(|w| {
                    let sum = &sum;
                    boxed(move || {
                        sum.fetch_add(w, Ordering::Relaxed);
                    })
                })
                .collect();
            WorkerPool::global().run_batch(jobs);
            assert_eq!(sum.load(Ordering::Relaxed), 10);
        }
    }
}
