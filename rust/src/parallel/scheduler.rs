//! Row-panel scheduler on the persistent worker pool.
//!
//! All scheduling is *static*: a partitioner produces ascending row
//! boundaries, one pool job is submitted per part, and each job owns a
//! disjoint contiguous row block of the output buffer. Because a cut
//! never lands inside a micro-panel (the partitioners align cuts), every
//! tile is computed whole by exactly one job with the same instruction
//! order at any worker count — which is what lets the property suite
//! demand bit-identical results across 1–4 threads. Which pool thread
//! happens to run a job is irrelevant to the result.
//!
//! Execution goes through [`super::pool::WorkerPool::global`] — parked
//! resident threads — instead of the per-call `std::thread::scope` of
//! PR 1. The old scoped implementation survives as
//! [`scope_rows_scoped`], the launch-overhead baseline the
//! `ablate_threads` bench and the pool lifecycle tests compare against.

use super::audit::MergeAuditor;
use super::pool::WorkerPool;

/// Evenly split `units` into at most `parts` contiguous ranges.
/// Returns ascending boundaries `[0, …, units]` (deduplicated).
pub fn even_bounds(units: usize, parts: usize) -> Vec<usize> {
    aligned_bounds(units, parts, 1)
}

/// Split `total` rows into at most `parts` ranges whose interior cuts
/// are multiples of `align` (the micro-panel height), so no panel is
/// ever shared between two workers.
pub fn aligned_bounds(total: usize, parts: usize, align: usize) -> Vec<usize> {
    let align = align.max(1);
    let units = total.div_ceil(align);
    let parts = parts.max(1).min(units.max(1));
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0);
    for w in 1..parts {
        bounds.push(((units * w) / parts * align).min(total));
    }
    bounds.push(total);
    bounds.dedup();
    bounds
}

/// Partition `total` rows for a triangular sweep where row `i` costs
/// `total − i` (the SYRK upper-triangle profile): early rows are
/// expensive, late rows cheap, so an even split would starve the last
/// workers. Cuts stay aligned to `align`.
pub fn triangle_bounds(total: usize, parts: usize, align: usize) -> Vec<usize> {
    let align = align.max(1);
    let units = total.div_ceil(align);
    let parts = parts.max(1).min(units.max(1));
    if parts <= 1 {
        return vec![0, total];
    }
    let total_work = (total as u128) * (total as u128 + 1) / 2;
    let mut bounds = Vec::with_capacity(parts + 1);
    bounds.push(0);
    let mut acc: u128 = 0;
    let mut next_cut = 1usize;
    for u in 0..units {
        let lo = u * align;
        let hi = ((u + 1) * align).min(total);
        let cnt = (hi - lo) as u128;
        let sum_i = (lo as u128 + hi as u128 - 1) * cnt / 2;
        acc += cnt * total as u128 - sum_i;
        if next_cut < parts && acc * parts as u128 >= total_work * next_cut as u128 {
            if hi < total {
                bounds.push(hi);
            }
            while next_cut < parts && acc * parts as u128 >= total_work * next_cut as u128 {
                next_cut += 1;
            }
        }
    }
    bounds.push(total);
    bounds.dedup();
    bounds
}

/// Split `data` into one disjoint row block per part of `bounds`.
/// Degenerate shapes are legal: with `stride == 0` (zero-width rows) or
/// an empty output buffer every block is simply empty.
#[allow(clippy::type_complexity)]
fn row_blocks<'d, T>(
    data: &'d mut [T],
    stride: usize,
    bounds: &[usize],
) -> Vec<(usize, usize, &'d mut [T])> {
    let parts = bounds.len() - 1;
    // Only an all-empty buffer (and stride == 0, where len is 0 anyway)
    // is a legal degenerate. A non-empty buffer whose remainder runs
    // short — even exactly at a partition boundary — is a genuine
    // bounds/stride mismatch, and split_at_mut fails loudly on it in
    // release builds too.
    let all_empty = data.is_empty();
    let mut blocks = Vec::with_capacity(parts);
    let mut rest = data;
    for w in 0..parts {
        let len = if all_empty { 0 } else { (bounds[w + 1] - bounds[w]) * stride };
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(len);
        blocks.push((bounds[w], bounds[w + 1], head));
        rest = tail;
    }
    // Undershoot is just as inconsistent as overshoot: every element of
    // a non-empty buffer must be handed to exactly one worker.
    assert!(
        rest.is_empty(),
        "scope_rows: bounds/stride leave {} elements unassigned",
        rest.len()
    );
    blocks
}

/// Run `f(row_lo, row_hi, block)` over disjoint row blocks of `data`
/// (row-major, `stride` elements per row), one persistent-pool job per
/// part described by `bounds` (as produced by the partitioners above).
/// Job results are collected **in partition order**, so reductions
/// combined by the caller are deterministic for a given `bounds`. In
/// debug builds a [`MergeAuditor`] checks that order on every drain
/// (including the single-part path), so any future refactor toward
/// completion-order merging fails the whole test suite immediately.
///
/// With a single part the closure runs inline on the caller's thread —
/// the 1-thread path never touches the pool.
pub fn scope_rows<T, R, F>(data: &mut [T], stride: usize, bounds: &[usize], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &mut [T]) -> R + Sync,
{
    let parts = bounds.len().saturating_sub(1);
    if parts == 0 {
        return Vec::new();
    }
    debug_assert_eq!(bounds[0], 0);
    // `stride == 0` and empty-output degenerates are legitimate (every
    // block is empty); only a genuinely inconsistent row/stride claim
    // against a non-empty buffer is a caller bug.
    debug_assert!(
        data.is_empty() || bounds[parts] * stride == data.len(),
        "scope_rows: bounds cover {} rows of stride {stride} but data holds {} elements",
        bounds[parts],
        data.len()
    );
    if parts == 1 {
        let mut audit = MergeAuditor::begin("scope_rows", 1);
        let out = vec![f(bounds[0], bounds[1], data)];
        audit.merged(0);
        audit.finish();
        return out;
    }
    let f = &f;
    let mut results: Vec<Option<R>> = (0..parts).map(|_| None).collect();
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = row_blocks(data, stride, bounds)
        .into_iter()
        .zip(results.iter_mut())
        .map(|((lo, hi, block), slot)| {
            Box::new(move || {
                *slot = Some(f(lo, hi, block));
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    WorkerPool::global().run_batch(jobs);
    let mut audit = MergeAuditor::begin("scope_rows", parts);
    let out = results
        .into_iter()
        .enumerate()
        .map(|(w, r)| {
            audit.merged(w);
            match r {
                Some(v) => v,
                None => unreachable!("run_batch executes every job"),
            }
        })
        .collect();
    audit.finish();
    out
}

/// Pre-pool reference implementation of [`scope_rows`]: one
/// `std::thread::scope` spawn per part, identical partitioning contract
/// and results. Kept as the launch-overhead baseline for the
/// `ablate_threads` bench and as the oracle the pool lifecycle tests
/// compare bit-for-bit against.
pub fn scope_rows_scoped<T, R, F>(data: &mut [T], stride: usize, bounds: &[usize], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, usize, &mut [T]) -> R + Sync,
{
    let parts = bounds.len().saturating_sub(1);
    if parts == 0 {
        return Vec::new();
    }
    debug_assert_eq!(bounds[0], 0);
    debug_assert!(
        data.is_empty() || bounds[parts] * stride == data.len(),
        "scope_rows_scoped: bounds cover {} rows of stride {stride} but data holds {} elements",
        bounds[parts],
        data.len()
    );
    if parts == 1 {
        let mut audit = MergeAuditor::begin("scope_rows_scoped", 1);
        let out = vec![f(bounds[0], bounds[1], data)];
        audit.merged(0);
        audit.finish();
        return out;
    }
    let f = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = row_blocks(data, stride, bounds)
            .into_iter()
            .map(|(lo, hi, block)| s.spawn(move || f(lo, hi, block)))
            .collect();
        let mut audit = MergeAuditor::begin("scope_rows_scoped", parts);
        let out = handles
            .into_iter()
            .enumerate()
            .map(|(w, h)| {
                audit.merged(w);
                match h.join() {
                    Ok(v) => v,
                    // Re-throw on the caller's thread so the crate-level
                    // quarantine sees the original payload.
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            })
            .collect();
        audit.finish();
        out
    })
}

/// Read-only fan-out: run `f(lo, hi)` per partition on the persistent
/// pool and collect the partial results in partition order.
pub fn par_map<R, F>(bounds: &[usize], f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    let parts = bounds.len().saturating_sub(1);
    if parts == 0 {
        return Vec::new();
    }
    if parts == 1 {
        let mut audit = MergeAuditor::begin("par_map", 1);
        let out = vec![f(bounds[0], bounds[1])];
        audit.merged(0);
        audit.finish();
        return out;
    }
    let f = &f;
    let mut results: Vec<Option<R>> = (0..parts).map(|_| None).collect();
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = results
        .iter_mut()
        .enumerate()
        .map(|(w, slot)| {
            let (lo, hi) = (bounds[w], bounds[w + 1]);
            Box::new(move || {
                *slot = Some(f(lo, hi));
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    WorkerPool::global().run_batch(jobs);
    let mut audit = MergeAuditor::begin("par_map", parts);
    let out = results
        .into_iter()
        .enumerate()
        .map(|(w, r)| {
            audit.merged(w);
            match r {
                Some(v) => v,
                None => unreachable!("run_batch executes every job"),
            }
        })
        .collect();
    audit.finish();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_bounds_cover_and_ascend() {
        for units in [0usize, 1, 2, 5, 17, 100] {
            for parts in [1usize, 2, 3, 4, 8, 200] {
                let b = even_bounds(units, parts);
                assert_eq!(*b.first().unwrap(), 0);
                assert_eq!(*b.last().unwrap(), units);
                assert!(b.windows(2).all(|w| w[0] < w[1]) || units == 0);
                assert!(b.len() <= parts + 1);
            }
        }
    }

    #[test]
    fn aligned_bounds_cut_on_multiples() {
        for total in [1usize, 3, 4, 7, 63, 64, 65, 130] {
            for parts in [1usize, 2, 3, 4] {
                for align in [1usize, 4, 8] {
                    let b = aligned_bounds(total, parts, align);
                    assert_eq!(*b.last().unwrap(), total);
                    for &cut in &b[1..b.len() - 1] {
                        assert_eq!(cut % align, 0, "total={total} parts={parts} align={align}");
                    }
                }
            }
        }
    }

    #[test]
    fn triangle_bounds_front_loads_small_chunks() {
        let b = triangle_bounds(1000, 4, 4);
        assert_eq!(*b.first().unwrap(), 0);
        assert_eq!(*b.last().unwrap(), 1000);
        // Work profile total−i: first chunk must hold fewer rows than the last.
        let first = b[1] - b[0];
        let last = b[b.len() - 1] - b[b.len() - 2];
        assert!(first < last, "bounds={b:?}");
        for &cut in &b[1..b.len() - 1] {
            assert_eq!(cut % 4, 0);
        }
    }

    #[test]
    fn scope_rows_writes_disjoint_blocks_and_orders_results() {
        let rows = 103usize;
        let stride = 7usize;
        let mut data = vec![0u32; rows * stride];
        for threads in 1..=4 {
            data.fill(0);
            let bounds = even_bounds(rows, threads);
            let partials = scope_rows(&mut data, stride, &bounds, |lo, hi, block| {
                for (r, row) in block.chunks_mut(stride).enumerate() {
                    row.fill((lo + r) as u32);
                }
                hi - lo
            });
            assert_eq!(partials.iter().sum::<usize>(), rows);
            for r in 0..rows {
                assert!(data[r * stride..(r + 1) * stride].iter().all(|&v| v == r as u32));
            }
        }
    }

    #[test]
    fn pool_and_scoped_agree() {
        let rows = 61usize;
        let stride = 3usize;
        let seed: Vec<u64> = (0..rows * stride).map(|i| (i as u64) * 7 + 1).collect();
        let f = |lo: usize, hi: usize, block: &mut [u64]| {
            let mut acc = 0u64;
            for (r, row) in block.chunks_mut(stride).enumerate() {
                for v in row.iter_mut() {
                    *v = v.wrapping_mul(3).wrapping_add((lo + r) as u64);
                    acc = acc.wrapping_add(*v);
                }
            }
            (hi, acc)
        };
        for parts in 1..=4 {
            let bounds = even_bounds(rows, parts);
            let mut a = seed.clone();
            let mut b = seed.clone();
            let pa = scope_rows(&mut a, stride, &bounds, f);
            let pb = scope_rows_scoped(&mut b, stride, &bounds, f);
            assert_eq!(pa, pb, "parts={parts}");
            assert_eq!(a, b, "parts={parts}");
        }
    }

    #[test]
    fn par_map_collects_in_order() {
        let bounds = even_bounds(40, 4);
        let parts = par_map(&bounds, |lo, hi| (lo, hi));
        for w in 0..parts.len() {
            assert_eq!(parts[w], (bounds[w], bounds[w + 1]));
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        let mut empty: Vec<f64> = Vec::new();
        let b = even_bounds(0, 4);
        let r = scope_rows(&mut empty, 3, &b, |_, _, _| 1usize);
        assert!(r.is_empty() || r.iter().sum::<usize>() == 0);
        assert!(par_map::<usize, _>(&[], |_, _| 1).is_empty());
    }

    /// Regression (ISSUE 2): the old `debug_assert_eq!(rows·stride,
    /// len)` panicked on the legitimate degenerate shapes — zero-width
    /// rows (`stride == 0`) and an all-empty output partitioned with a
    /// nonzero stride. Both must schedule empty blocks instead.
    #[test]
    fn stride_zero_and_empty_output_are_legal() {
        let mut zero_width: Vec<f64> = Vec::new();
        let partials = scope_rows(&mut zero_width, 0, &[0, 2, 5], |lo, hi, block| {
            assert!(block.is_empty());
            hi - lo
        });
        assert_eq!(partials, vec![2, 3]);

        let mut empty_out: Vec<f64> = Vec::new();
        let partials = scope_rows(&mut empty_out, 4, &[0, 1, 3], |_, _, block| block.len());
        assert_eq!(partials, vec![0, 0]);

        // The scoped baseline accepts the same degenerates.
        let mut empty_out2: Vec<f64> = Vec::new();
        let partials = scope_rows_scoped(&mut empty_out2, 4, &[0, 1, 3], |_, _, block| block.len());
        assert_eq!(partials, vec![0, 0]);
    }

    /// Every drain feeds the debug-build merge auditor: after a
    /// fan-out, the thread-local record shows the complete ascending
    /// chunk sequence for the site, at every worker count (the
    /// single-part inline path included).
    #[cfg(debug_assertions)]
    #[test]
    fn drains_feed_the_merge_auditor_in_order() {
        use super::super::audit;

        let seq_for = |site: &str| -> Vec<usize> {
            audit::recent_merges()
                .iter()
                .filter(|(s, _)| *s == site)
                .map(|&(_, chunk)| chunk)
                .collect()
        };
        for parts in 1..=4 {
            audit::clear_recent();
            let bounds = even_bounds(40, parts);
            let n = bounds.len() - 1;
            let _ = par_map(&bounds, |lo, hi| hi - lo);
            assert_eq!(seq_for("par_map"), (0..n).collect::<Vec<_>>(), "parts={parts}");
        }
        audit::clear_recent();
        let mut data = vec![0u8; 12];
        let _ = scope_rows(&mut data, 3, &[0, 2, 4], |_, _, _| 0usize);
        assert_eq!(seq_for("scope_rows"), vec![0, 1]);
        audit::clear_recent();
        let mut data2 = vec![0u8; 12];
        let _ = scope_rows_scoped(&mut data2, 3, &[0, 2, 4], |_, _, _| 0usize);
        assert_eq!(seq_for("scope_rows_scoped"), vec![0, 1]);
    }
}
