//! Floating-point abstraction mirroring oneDAL's `algorithmFPType`
//! template parameter: every numeric substrate is generic over [`Float`]
//! so both `f32` and `f64` pipelines exist, as in the original library.

use std::fmt::{Debug, Display};
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Scalar trait covering what the kernels need from `f32`/`f64`.
pub trait Float:
    Copy
    + Debug
    + Display
    + PartialOrd
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + Send
    + Sync
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    const TWO: Self;
    /// Machine epsilon.
    const EPSILON: Self;
    /// `tau` regularizer used by the SVM WSS denominator guard (paper §IV-E).
    const TAU: Self;

    fn from_f64(v: f64) -> Self;
    fn from_usize(v: usize) -> Self;
    fn to_f64(self) -> f64;
    /// IEEE-754 `totalOrder` comparison (`-NaN < -∞ < … < +∞ < +NaN`).
    /// Library comparators sort with this instead of
    /// `partial_cmp(..).unwrap()` so a NaN feature value degrades to a
    /// deterministic ordering instead of panicking mid-train.
    fn total_cmp(self, o: Self) -> std::cmp::Ordering;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn exp(self) -> Self;
    fn ln(self) -> Self;
    fn powi(self, n: i32) -> Self;
    fn mul_add(self, a: Self, b: Self) -> Self;
    fn infinity() -> Self;
    fn neg_infinity() -> Self;
    fn is_finite(self) -> bool;
    fn maxf(self, o: Self) -> Self;
    fn minf(self, o: Self) -> Self;
}

macro_rules! impl_float {
    ($t:ty) => {
        impl Float for $t {
            const ZERO: Self = 0.0;
            const ONE: Self = 1.0;
            const TWO: Self = 2.0;
            const EPSILON: Self = <$t>::EPSILON;
            const TAU: Self = 1.0e-6;

            #[inline(always)]
            fn from_f64(v: f64) -> Self {
                v as $t
            }
            #[inline(always)]
            fn from_usize(v: usize) -> Self {
                v as $t
            }
            #[inline(always)]
            fn to_f64(self) -> f64 {
                self as f64
            }
            #[inline(always)]
            fn total_cmp(self, o: Self) -> std::cmp::Ordering {
                <$t>::total_cmp(&self, &o)
            }
            #[inline(always)]
            fn abs(self) -> Self {
                <$t>::abs(self)
            }
            #[inline(always)]
            fn sqrt(self) -> Self {
                <$t>::sqrt(self)
            }
            #[inline(always)]
            fn exp(self) -> Self {
                <$t>::exp(self)
            }
            #[inline(always)]
            fn ln(self) -> Self {
                <$t>::ln(self)
            }
            #[inline(always)]
            fn powi(self, n: i32) -> Self {
                <$t>::powi(self, n)
            }
            #[inline(always)]
            fn mul_add(self, a: Self, b: Self) -> Self {
                <$t>::mul_add(self, a, b)
            }
            #[inline(always)]
            fn infinity() -> Self {
                <$t>::INFINITY
            }
            #[inline(always)]
            fn neg_infinity() -> Self {
                <$t>::NEG_INFINITY
            }
            #[inline(always)]
            fn is_finite(self) -> bool {
                <$t>::is_finite(self)
            }
            #[inline(always)]
            fn maxf(self, o: Self) -> Self {
                <$t>::max(self, o)
            }
            #[inline(always)]
            fn minf(self, o: Self) -> Self {
                <$t>::min(self, o)
            }
        }
    };
}

impl_float!(f32);
impl_float!(f64);

#[cfg(test)]
mod tests {
    use super::*;

    fn generic_sum<T: Float>(xs: &[T]) -> T {
        xs.iter().copied().sum()
    }

    #[test]
    fn float_trait_f32_f64_agree() {
        let a32: Vec<f32> = vec![1.0, 2.5, -0.5];
        let a64: Vec<f64> = vec![1.0, 2.5, -0.5];
        assert_eq!(generic_sum(&a32).to_f64(), generic_sum(&a64));
    }

    #[test]
    fn constants() {
        assert_eq!(f32::ZERO + f32::ONE, 1.0f32);
        assert_eq!(f64::TWO, 2.0);
        assert!(f64::TAU > 0.0 && f64::TAU < 1e-3);
    }

    #[test]
    fn total_cmp_orders_nan_last() {
        use std::cmp::Ordering;
        assert_eq!(Float::total_cmp(1.0f64, f64::NAN), Ordering::Less);
        assert_eq!(Float::total_cmp(f64::NAN, f64::INFINITY), Ordering::Greater);
        assert_eq!(Float::total_cmp(2.0f32, 3.0f32), Ordering::Less);
        // Never panics — the property the library comparators rely on.
        let mut v = vec![f64::NAN, 1.0, f64::NEG_INFINITY, f64::NAN, 0.0];
        v.sort_by(|a, b| Float::total_cmp(*a, *b));
        assert_eq!(v[0], f64::NEG_INFINITY);
        assert!(v[3].is_nan() && v[4].is_nan());
    }

    #[test]
    fn minmax_and_infinities() {
        assert_eq!(2.0f64.maxf(3.0), 3.0);
        assert_eq!(2.0f64.minf(3.0), 2.0);
        assert!(f64::infinity() > 1e300);
        assert!(f32::neg_infinity() < -1e30);
    }
}
