//! Deterministic fault injection — an env-keyed failpoint registry for
//! the chaos suite (`tests/chaos.rs`).
//!
//! A failpoint is a named site in the library (worker job entry, tile
//! sweep, tile-cache eviction, CSV record parse) where a panic can be
//! injected on demand. Arm one with
//!
//! ```text
//! ONEDAL_SVE_FAILPOINT=<site>:<nth>
//! ```
//!
//! (or programmatically via [`arm`]); the `nth` visit to that site —
//! counting from 1, default 1 — panics with a recognizable message,
//! **exactly once**. The panic is then quarantined at the public
//! boundary into [`crate::error::Error::Internal`], so the chaos suite
//! can assert that every site yields a typed error, the worker pool
//! recovers to full width, and a retried call is bit-identical to an
//! uninjected run.
//!
//! Cost when disarmed: one relaxed atomic load per [`check`] call —
//! the registry holds no lock and allocates nothing unless a site is
//! armed, so production hot paths are unaffected.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, PoisonError};

/// Worker-pool job entry (remote, local, and single-job-inline paths of
/// [`crate::parallel::WorkerPool::run_batch`]).
pub const SITE_POOL_JOB: &str = "pool-worker-job";
/// Per-tile body of the fused distance sweeps
/// ([`crate::primitives::distances`], dense and CSR).
pub const SITE_TILE_SWEEP: &str = "tile-sweep";
/// LRU eviction branch of the SVM gram [`TileCache`]
/// (`crate::algorithms::svm::kernel`).
pub const SITE_TILE_CACHE_EVICT: &str = "tile-cache-evict";
/// Per-record loop of the CSV reader ([`crate::tables::csv::parse_csv`]).
pub const SITE_CSV_RECORD: &str = "csv-record";
/// Super-batch execution of the serving layer
/// ([`crate::coordinator::serve::InferenceSession`]), inside the
/// `serve.batch` quarantine — a fired batch must surface as a typed
/// per-request failure without poisoning neighboring batches.
pub const SITE_SERVE_BATCH: &str = "serve-batch";

/// Fast gate: false ⇒ no failpoint armed ⇒ [`check`] is one relaxed
/// load and returns immediately.
static ARMED: AtomicBool = AtomicBool::new(false);
static CONFIG: Mutex<Option<Config>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

struct Config {
    site: String,
    nth: u64,
    hits: u64,
}

fn lock_config() -> std::sync::MutexGuard<'static, Option<Config>> {
    // A panic while holding the lock is the failpoint firing, not
    // corrupted state — recover the guard.
    CONFIG.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Arm a failpoint from a `site[:nth]` spec (`nth` counts visits from
/// 1; omitted ⇒ 1). Replaces any previously armed site.
pub fn arm(spec: &str) {
    let (site, nth) = match spec.split_once(':') {
        Some((s, n)) => (s, n.parse::<u64>().unwrap_or(1).max(1)),
        None => (spec, 1),
    };
    *lock_config() = Some(Config { site: site.to_string(), nth, hits: 0 });
    ARMED.store(true, Ordering::Release);
}

/// Disarm whatever failpoint is armed (no-op when none is).
pub fn disarm() {
    *lock_config() = None;
    ARMED.store(false, Ordering::Release);
}

/// One-time lazy read of `ONEDAL_SVE_FAILPOINT` — called on the armed
/// slow path and once per process from the first [`check`].
fn env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("ONEDAL_SVE_FAILPOINT") {
            if !spec.is_empty() {
                arm(&spec);
            }
        }
    });
}

/// Visit the named failpoint site: panics iff an armed spec matches
/// `site` and this is its `nth` visit. The armed flag clears when the
/// failpoint fires, so a retried call runs clean.
#[inline]
pub fn check(site: &str) {
    // Disarmed fast path: a single relaxed load after the one-time env
    // probe. ENV_INIT is itself a single atomic load once initialized.
    env_init();
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    check_slow(site);
}

#[cold]
fn check_slow(site: &str) {
    let mut guard = lock_config();
    let fire = match guard.as_mut() {
        Some(cfg) if cfg.site == site => {
            cfg.hits += 1;
            cfg.hits == cfg.nth
        }
        _ => false,
    };
    if fire {
        // Fire exactly once: disarm before panicking so the in-flight
        // batch (and any retry) completes clean.
        *guard = None;
        ARMED.store(false, Ordering::Release);
        drop(guard);
        panic!("failpoint {site} fired");
    }
}

/// Whether any failpoint is currently armed (test observability).
pub fn is_armed() -> bool {
    env_init();
    ARMED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // The registry is process-global; serialize the tests that touch it.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn disarmed_check_is_silent() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        disarm();
        check(SITE_POOL_JOB);
        check(SITE_TILE_SWEEP);
        assert!(!is_armed());
    }

    #[test]
    fn fires_on_nth_visit_exactly_once() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("tile-sweep:3");
        assert!(is_armed());
        check(SITE_TILE_SWEEP);
        check(SITE_TILE_SWEEP);
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_TILE_SWEEP)));
        assert!(r.is_err(), "third visit must fire");
        // Fired once ⇒ disarmed ⇒ later visits are clean.
        assert!(!is_armed());
        check(SITE_TILE_SWEEP);
        disarm();
    }

    #[test]
    fn other_sites_do_not_fire() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm(SITE_CSV_RECORD);
        check(SITE_POOL_JOB);
        check(SITE_TILE_CACHE_EVICT);
        assert!(is_armed(), "non-matching visits must not consume the failpoint");
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_CSV_RECORD)));
        assert!(r.is_err());
        disarm();
    }

    #[test]
    fn bare_site_spec_defaults_to_first_visit() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("pool-worker-job");
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_POOL_JOB)));
        assert!(r.is_err());
        assert!(!is_armed());
        disarm();
    }
}
