//! Deterministic fault injection — an env-keyed failpoint registry for
//! the chaos suite (`tests/chaos.rs`) and the resilience layer
//! (`coordinator/resilience.rs`).
//!
//! A failpoint is a named site in the library (worker job entry, tile
//! sweep, tile-cache eviction, CSV record parse, serve super-batch)
//! where a fault can be injected on demand. Arm one with
//!
//! ```text
//! ONEDAL_SVE_FAILPOINT=<site>[:<mode>][:<payload>]
//! ```
//!
//! (or programmatically via [`arm`]). Visits to a site count from 1.
//!
//! **Firing modes** (default `1`):
//!
//! * `<n>` — fire on the `n`th visit, **exactly once**, then disarm
//!   (the original chaos-suite mode: a retried call runs clean).
//! * `every:<k>` — fire on every `k`th visit (`k`, `2k`, `3k`, ...)
//!   and **stay armed** until [`disarm`] — the persistent-fault mode
//!   that drives retry exhaustion and circuit-breaker trips.
//! * `times:<n>` — fire on each of the first `n` visits, then disarm —
//!   the bounded-fault mode: a retry loop with more than `n` attempts
//!   eventually runs clean.
//!
//! **Payloads** (default `panic`):
//!
//! * `panic` — the site panics with a recognizable message; the panic
//!   is quarantined at the public boundary into
//!   [`crate::error::Error::Internal`].
//! * `error` — sites visited through [`check_result`] return a typed
//!   [`crate::error::Error::Internal`] directly, exercising the
//!   error-path plumbing without unwinding. Sites visited through the
//!   plain [`check`] cannot return, so there the payload falls back to
//!   a panic.
//!
//! Cost when disarmed: one relaxed atomic load per [`check`] /
//! [`check_result`] call — the registry holds no lock and allocates
//! nothing unless a site is armed, so production hot paths are
//! unaffected.

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, Once, PoisonError};

/// Worker-pool job entry (remote, local, and single-job-inline paths of
/// [`crate::parallel::WorkerPool::run_batch`]).
pub const SITE_POOL_JOB: &str = "pool-worker-job";
/// Per-tile body of the fused distance sweeps
/// ([`crate::primitives::distances`], dense and CSR).
pub const SITE_TILE_SWEEP: &str = "tile-sweep";
/// LRU eviction branch of the SVM gram [`TileCache`]
/// (`crate::algorithms::svm::kernel`).
pub const SITE_TILE_CACHE_EVICT: &str = "tile-cache-evict";
/// Per-record loop of the CSV reader ([`crate::tables::csv::parse_csv`]).
pub const SITE_CSV_RECORD: &str = "csv-record";
/// Super-batch execution of the serving layer
/// ([`crate::coordinator::serve::InferenceSession`]), inside the
/// `serve.batch` quarantine — a fired batch must surface as a typed
/// per-request failure without poisoning neighboring batches. Visited
/// once per execution *attempt* (not per tile), so the resilience
/// layer's fault accounting is one count per injected fault.
pub const SITE_SERVE_BATCH: &str = "serve-batch";
/// Degraded-rung execution of the resilience layer
/// ([`crate::coordinator::resilience`]): the per-call-pack and naive
/// fallback paths an open circuit breaker routes to. A separate site
/// from [`SITE_SERVE_BATCH`] on purpose — a persistent fault armed at
/// the primary path must leave the fallback rungs working, and tests
/// arm this site to force escalation down the ladder.
pub const SITE_SERVE_DEGRADED: &str = "serve-degraded";

/// Fast gate: false ⇒ no failpoint armed ⇒ [`check`] is one relaxed
/// load and returns immediately.
static ARMED: AtomicBool = AtomicBool::new(false);
static CONFIG: Mutex<Option<Config>> = Mutex::new(None);
static ENV_INIT: Once = Once::new();

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    /// Fire on the `n`th visit, once, then disarm.
    Nth(u64),
    /// Fire on every `k`th visit; stays armed until [`disarm`].
    Every(u64),
    /// Fire on each of the first `n` visits, then disarm.
    Times(u64),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Payload {
    Panic,
    TypedError,
}

struct Config {
    site: String,
    mode: Mode,
    payload: Payload,
    hits: u64,
    fired: u64,
}

fn lock_config() -> std::sync::MutexGuard<'static, Option<Config>> {
    // A panic while holding the lock is the failpoint firing, not
    // corrupted state — recover the guard.
    CONFIG.lock().unwrap_or_else(PoisonError::into_inner)
}

fn parse_count(s: &str) -> u64 {
    s.parse::<u64>().unwrap_or(1).max(1)
}

/// Arm a failpoint from a `site[:mode][:payload]` spec (see module
/// docs for the grammar; malformed mode/payload segments degrade to
/// the defaults, `1` and `panic`). Replaces any previously armed site.
pub fn arm(spec: &str) {
    let mut segs = spec.split(':');
    let site = segs.next().unwrap_or("").to_string();
    let mut rest: Vec<&str> = segs.collect();
    let payload = match rest.last() {
        Some(&"error") => {
            rest.pop();
            Payload::TypedError
        }
        Some(&"panic") => {
            rest.pop();
            Payload::Panic
        }
        _ => Payload::Panic,
    };
    let mode = match rest.as_slice() {
        [] => Mode::Nth(1),
        ["every", k] => Mode::Every(parse_count(k)),
        ["times", n] => Mode::Times(parse_count(n)),
        [n] => Mode::Nth(parse_count(n)),
        _ => Mode::Nth(1),
    };
    *lock_config() = Some(Config { site, mode, payload, hits: 0, fired: 0 });
    ARMED.store(true, Ordering::Release);
}

/// Disarm whatever failpoint is armed (no-op when none is).
pub fn disarm() {
    *lock_config() = None;
    ARMED.store(false, Ordering::Release);
}

/// One-time lazy read of `ONEDAL_SVE_FAILPOINT` — called on the armed
/// slow path and once per process from the first [`check`].
fn env_init() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("ONEDAL_SVE_FAILPOINT") {
            if !spec.is_empty() {
                arm(&spec);
            }
        }
    });
}

/// Visit the named failpoint site: panics iff an armed spec matches
/// `site` and the firing mode selects this visit. A typed-error
/// payload also panics here — only [`check_result`] sites can return
/// the typed form.
#[inline]
pub fn check(site: &str) {
    // Disarmed fast path: a single relaxed load after the one-time env
    // probe. ENV_INIT is itself a single atomic load once initialized.
    env_init();
    if !ARMED.load(Ordering::Relaxed) {
        return;
    }
    if visit_slow(site).is_some() {
        panic!("failpoint {site} fired");
    }
}

/// Visit the named failpoint site on a fallible path: a firing with
/// the `panic` payload panics (to be quarantined at the boundary),
/// while the `error` payload returns [`Error::Internal`] directly —
/// same variant the quarantine would produce, without unwinding.
#[inline]
pub fn check_result(site: &str) -> Result<()> {
    env_init();
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    match visit_slow(site) {
        None => Ok(()),
        Some(Payload::Panic) => panic!("failpoint {site} fired"),
        Some(Payload::TypedError) => {
            Err(Error::Internal(format!("{site}: failpoint fired (typed)")))
        }
    }
}

/// Armed slow path: count the visit, decide whether it fires, and
/// disarm when the mode's firing budget is spent. Returns the payload
/// to deliver iff this visit fires.
#[cold]
fn visit_slow(site: &str) -> Option<Payload> {
    let mut guard = lock_config();
    let cfg = match guard.as_mut() {
        Some(cfg) if cfg.site == site => cfg,
        _ => return None,
    };
    cfg.hits += 1;
    let fire = match cfg.mode {
        Mode::Nth(n) => cfg.hits == n,
        Mode::Every(k) => cfg.hits % k == 0,
        Mode::Times(n) => cfg.hits <= n,
    };
    if !fire {
        return None;
    }
    cfg.fired += 1;
    let payload = cfg.payload;
    let exhausted = match cfg.mode {
        Mode::Nth(_) => true,
        Mode::Every(_) => false,
        Mode::Times(n) => cfg.fired >= n,
    };
    if exhausted {
        // Firing budget spent: disarm before delivering so in-flight
        // retries (and every later visit) complete clean.
        *guard = None;
        ARMED.store(false, Ordering::Release);
    }
    Some(payload)
}

/// Whether any failpoint is currently armed (test observability).
pub fn is_armed() -> bool {
    env_init();
    ARMED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // The registry is process-global; serialize the tests that touch it.
    static GATE: Mutex<()> = Mutex::new(());

    #[test]
    fn disarmed_check_is_silent() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        disarm();
        check(SITE_POOL_JOB);
        check(SITE_TILE_SWEEP);
        assert!(check_result(SITE_SERVE_BATCH).is_ok());
        assert!(!is_armed());
    }

    #[test]
    fn fires_on_nth_visit_exactly_once() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("tile-sweep:3");
        assert!(is_armed());
        check(SITE_TILE_SWEEP);
        check(SITE_TILE_SWEEP);
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_TILE_SWEEP)));
        assert!(r.is_err(), "third visit must fire");
        // Fired once ⇒ disarmed ⇒ later visits are clean.
        assert!(!is_armed());
        check(SITE_TILE_SWEEP);
        disarm();
    }

    #[test]
    fn other_sites_do_not_fire() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm(SITE_CSV_RECORD);
        check(SITE_POOL_JOB);
        check(SITE_TILE_CACHE_EVICT);
        assert!(is_armed(), "non-matching visits must not consume the failpoint");
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_CSV_RECORD)));
        assert!(r.is_err());
        disarm();
    }

    #[test]
    fn bare_site_spec_defaults_to_first_visit() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("pool-worker-job");
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_POOL_JOB)));
        assert!(r.is_err());
        assert!(!is_armed());
        disarm();
    }

    #[test]
    fn every_mode_fires_periodically_and_stays_armed() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("tile-sweep:every:3");
        for round in 0..3 {
            check(SITE_TILE_SWEEP);
            check(SITE_TILE_SWEEP);
            let r = catch_unwind(AssertUnwindSafe(|| check(SITE_TILE_SWEEP)));
            assert!(r.is_err(), "every 3rd visit must fire (round {round})");
            assert!(is_armed(), "every-mode must stay armed (round {round})");
        }
        disarm();
        check(SITE_TILE_SWEEP);
    }

    #[test]
    fn times_mode_fires_n_times_then_disarms() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("serve-batch:times:2");
        for visit in 0..2 {
            let r = catch_unwind(AssertUnwindSafe(|| check(SITE_SERVE_BATCH)));
            assert!(r.is_err(), "visit {visit} must fire");
        }
        assert!(!is_armed(), "times:2 must disarm after its second firing");
        check(SITE_SERVE_BATCH);
        disarm();
    }

    #[test]
    fn typed_error_payload_surfaces_through_check_result() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("serve-batch:times:2:error");
        let e = check_result(SITE_SERVE_BATCH).unwrap_err();
        assert!(matches!(e, Error::Internal(_)), "typed payload must be Internal");
        assert!(e.to_string().contains("failpoint"));
        // The plain `check` cannot return an error: the payload falls
        // back to a panic there.
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_SERVE_BATCH)));
        assert!(r.is_err());
        assert!(!is_armed());
        disarm();
    }

    #[test]
    fn explicit_panic_payload_parses() {
        let _g = GATE.lock().unwrap_or_else(PoisonError::into_inner);
        arm("csv-record:2:panic");
        check(SITE_CSV_RECORD);
        let r = catch_unwind(AssertUnwindSafe(|| check(SITE_CSV_RECORD)));
        assert!(r.is_err());
        disarm();
    }
}
