//! CSR matrix type: state-management + analysis + helper routines of the
//! SPBLAS group structure (§II "Sparse Matrix Processing").

use crate::dtype::Float;
use crate::error::{Error, Result};
use crate::tables::DenseTable;

/// Index base of the CSR index arrays — §IV-B: `csrmultd` requires
/// 1-based, `csrmv` accepts either.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum IndexBase {
    Zero,
    One,
}

impl IndexBase {
    #[inline]
    pub fn offset(self) -> i64 {
        match self {
            IndexBase::Zero => 0,
            IndexBase::One => 1,
        }
    }
}

/// 3-array CSR matrix (`values`, `col_idx`, `row_ptr`), the
/// `sparse::matrix_handle_t` analogue. The 4-array form used by `csrmv`
/// is exposed through [`CsrMatrix::pointer_b`] / [`CsrMatrix::pointer_e`].
#[derive(Clone, Debug)]
pub struct CsrMatrix<T> {
    rows: usize,
    cols: usize,
    values: Vec<T>,
    col_idx: Vec<i64>,
    row_ptr: Vec<i64>,
    base: IndexBase,
}

/// Result of the SPBLAS "inspector" stage: structural metadata the
/// execution routines use to pick kernels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Inspection {
    pub nnz: usize,
    pub density: f64,
    pub max_row_nnz: usize,
    /// Rows whose nnz is 0 (empty-row fraction drives kernel choice).
    pub empty_rows: usize,
    /// True when column indices are sorted within every row.
    pub sorted_rows: bool,
}

impl<T: Float> CsrMatrix<T> {
    /// State-management: wrap raw CSR arrays. `row_ptr` has `rows + 1`
    /// entries in the given base.
    pub fn new(
        rows: usize,
        cols: usize,
        values: Vec<T>,
        col_idx: Vec<i64>,
        row_ptr: Vec<i64>,
        base: IndexBase,
    ) -> Result<Self> {
        let m = Self { rows, cols, values, col_idx, row_ptr, base };
        m.validate()?;
        Ok(m)
    }

    /// Validate structural invariants (the checks MKL's analysis stage
    /// performs before optimizing).
    pub fn validate(&self) -> Result<()> {
        let off = self.base.offset();
        if self.row_ptr.len() != self.rows + 1 {
            return Err(Error::Shape(format!(
                "row_ptr length {} != rows+1 = {}",
                self.row_ptr.len(),
                self.rows + 1
            )));
        }
        if self.values.len() != self.col_idx.len() {
            return Err(Error::Shape("values / col_idx length mismatch".into()));
        }
        if self.row_ptr[0] != off {
            return Err(Error::Shape(format!("row_ptr[0] = {} != base {off}", self.row_ptr[0])));
        }
        if self.row_ptr[self.rows] - off != self.values.len() as i64 {
            return Err(Error::Shape("row_ptr[rows] does not match nnz".into()));
        }
        for w in self.row_ptr.windows(2) {
            if w[1] < w[0] {
                return Err(Error::Shape("row_ptr not monotone".into()));
            }
        }
        for &c in &self.col_idx {
            let c0 = c - off;
            if c0 < 0 || c0 >= self.cols as i64 {
                return Err(Error::Shape(format!("column index {c} out of range (base {off})")));
            }
        }
        Ok(())
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn base(&self) -> IndexBase {
        self.base
    }

    pub fn values(&self) -> &[T] {
        &self.values
    }

    pub fn col_idx(&self) -> &[i64] {
        &self.col_idx
    }

    pub fn row_ptr(&self) -> &[i64] {
        &self.row_ptr
    }

    /// 4-array form: `pointer_b[i]` = start of row i (in the base).
    pub fn pointer_b(&self) -> &[i64] {
        &self.row_ptr[..self.rows]
    }

    /// 4-array form: `pointer_e[i]` = one-past-end of row i (in the base).
    pub fn pointer_e(&self) -> &[i64] {
        &self.row_ptr[1..]
    }

    /// Zero-based `(cols, values)` iterator over row `i` regardless of
    /// the stored base.
    #[inline]
    pub fn row_entries(&self, i: usize) -> impl Iterator<Item = (usize, T)> + '_ {
        let off = self.base.offset();
        let lo = (self.row_ptr[i] - off) as usize;
        let hi = (self.row_ptr[i + 1] - off) as usize;
        self.col_idx[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(move |(&c, &v)| ((c - off) as usize, v))
    }

    /// Analysis ("inspector") stage: gather structural metadata.
    pub fn inspect(&self) -> Inspection {
        let off = self.base.offset();
        let mut max_row_nnz = 0usize;
        let mut empty_rows = 0usize;
        let mut sorted_rows = true;
        for i in 0..self.rows {
            let lo = (self.row_ptr[i] - off) as usize;
            let hi = (self.row_ptr[i + 1] - off) as usize;
            let nnz = hi - lo;
            max_row_nnz = max_row_nnz.max(nnz);
            if nnz == 0 {
                empty_rows += 1;
            }
            if !self.col_idx[lo..hi].windows(2).all(|w| w[0] <= w[1]) {
                sorted_rows = false;
            }
        }
        Inspection {
            nnz: self.nnz(),
            density: self.nnz() as f64 / (self.rows * self.cols).max(1) as f64,
            max_row_nnz,
            empty_rows,
            sorted_rows,
        }
    }

    /// Helper: convert to the other index base in place.
    pub fn rebase(&mut self, base: IndexBase) {
        if base == self.base {
            return;
        }
        let delta = base.offset() - self.base.offset();
        for c in self.col_idx.iter_mut() {
            *c += delta;
        }
        for p in self.row_ptr.iter_mut() {
            *p += delta;
        }
        self.base = base;
    }

    /// Helper: dense → CSR with an absolute drop threshold.
    pub fn from_dense(t: &DenseTable<T>, threshold: T, base: IndexBase) -> Self {
        let off = base.offset();
        let mut values = Vec::new();
        let mut col_idx = Vec::new();
        let mut row_ptr = Vec::with_capacity(t.rows() + 1);
        row_ptr.push(off);
        for i in 0..t.rows() {
            for (j, &v) in t.row(i).iter().enumerate() {
                if v.abs() > threshold {
                    values.push(v);
                    col_idx.push(j as i64 + off);
                }
            }
            row_ptr.push(values.len() as i64 + off);
        }
        Self { rows: t.rows(), cols: t.cols(), values, col_idx, row_ptr, base }
    }

    /// Helper: CSR → dense (row-major).
    pub fn to_dense(&self) -> DenseTable<T> {
        let mut out = DenseTable::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row_entries(i) {
                out.set(i, j, v);
            }
        }
        out
    }

    /// Helper: CSR → **transposed** dense (`cols × rows` row-major) in
    /// one scatter sweep — the dense `B` operand the sparse query paths
    /// multiply CSR tiles against (packed once, consumed by every tile).
    pub fn to_dense_transposed(&self) -> DenseTable<T> {
        let mut out = DenseTable::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for (j, v) in self.row_entries(i) {
                out.set(j, i, v);
            }
        }
        out
    }

    /// Copy rows `lo..hi` into a standalone CSR matrix (same base) —
    /// the row-tile gather of the sparse distance sweeps and the
    /// mini-batch slicing of the sparse logistic-regression trainer.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<Self> {
        if lo > hi || hi > self.rows {
            return Err(Error::Shape(format!("row slice {lo}..{hi} out of 0..{}", self.rows)));
        }
        let off = self.base.offset();
        let p0 = (self.row_ptr[lo] - off) as usize;
        let p1 = (self.row_ptr[hi] - off) as usize;
        let row_ptr: Vec<i64> = self.row_ptr[lo..=hi].iter().map(|&p| p - p0 as i64).collect();
        Ok(Self {
            rows: hi - lo,
            cols: self.cols,
            values: self.values[p0..p1].to_vec(),
            col_idx: self.col_idx[p0..p1].to_vec(),
            row_ptr,
            base: self.base,
        })
    }

    /// Gather the given rows (repeats allowed) into a new CSR matrix —
    /// the sparse analogue of [`DenseTable::gather_rows`].
    pub fn gather_rows(&self, idx: &[usize]) -> Self {
        let off = self.base.offset();
        let mut values = Vec::new();
        let mut col_idx = Vec::new();
        let mut row_ptr = Vec::with_capacity(idx.len() + 1);
        row_ptr.push(off);
        for &i in idx {
            let lo = (self.row_ptr[i] - off) as usize;
            let hi = (self.row_ptr[i + 1] - off) as usize;
            values.extend_from_slice(&self.values[lo..hi]);
            col_idx.extend_from_slice(&self.col_idx[lo..hi]);
            row_ptr.push(values.len() as i64 + off);
        }
        Self { rows: idx.len(), cols: self.cols, values, col_idx, row_ptr, base: self.base }
    }

    /// Gather the given rows into a **dense** table (densified gather) —
    /// how sparse trainings extract dense artifacts such as SVM support
    /// vectors or k-means seed centroids.
    pub fn gather_rows_dense(&self, idx: &[usize]) -> DenseTable<T> {
        let mut out = DenseTable::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            for (j, v) in self.row_entries(i) {
                out.set(r, j, v);
            }
        }
        out
    }

    /// Helper: explicit transpose (CSC-equivalent re-bucketing).
    pub fn transposed(&self) -> Self {
        let off = self.base.offset();
        let mut counts = vec![0i64; self.cols + 1];
        for &c in &self.col_idx {
            counts[(c - off) as usize + 1] += 1;
        }
        for j in 1..=self.cols {
            counts[j] += counts[j - 1];
        }
        let row_ptr: Vec<i64> = counts.iter().map(|&c| c + off).collect();
        let mut col_idx = vec![0i64; self.nnz()];
        let mut values = vec![T::ZERO; self.nnz()];
        let mut cursor = counts.clone();
        for i in 0..self.rows {
            for (j, v) in self.row_entries(i) {
                let pos = cursor[j] as usize;
                cursor[j] += 1;
                col_idx[pos] = i as i64 + off;
                values[pos] = v;
            }
        }
        debug_assert_eq!(row_ptr.len(), self.cols + 1);
        Self { rows: self.cols, cols: self.rows, values, col_idx, row_ptr, base: self.base }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix<f64> {
        // [[1, 0, 2],
        //  [0, 0, 0],
        //  [3, 4, 0]]
        CsrMatrix::new(
            3,
            3,
            vec![1.0, 2.0, 3.0, 4.0],
            vec![1, 3, 1, 2],
            vec![1, 3, 3, 5],
            IndexBase::One,
        )
        .unwrap()
    }

    #[test]
    fn validate_catches_bad_row_ptr() {
        let r = CsrMatrix::new(2, 2, vec![1.0], vec![0], vec![0, 2, 1], IndexBase::Zero);
        assert!(r.is_err());
    }

    #[test]
    fn validate_catches_out_of_range_col() {
        let r = CsrMatrix::new(1, 2, vec![1.0], vec![5], vec![0, 1], IndexBase::Zero);
        assert!(r.is_err());
    }

    #[test]
    fn row_entries_zero_based_regardless_of_base() {
        let m = sample();
        let r0: Vec<(usize, f64)> = m.row_entries(0).collect();
        assert_eq!(r0, vec![(0, 1.0), (2, 2.0)]);
        let r1: Vec<(usize, f64)> = m.row_entries(1).collect();
        assert!(r1.is_empty());
    }

    #[test]
    fn four_array_views() {
        let m = sample();
        assert_eq!(m.pointer_b(), &[1, 3, 3]);
        assert_eq!(m.pointer_e(), &[3, 3, 5]);
    }

    #[test]
    fn inspect_metadata() {
        let m = sample();
        let ins = m.inspect();
        assert_eq!(ins.nnz, 4);
        assert_eq!(ins.max_row_nnz, 2);
        assert_eq!(ins.empty_rows, 1);
        assert!(ins.sorted_rows);
        assert!((ins.density - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn rebase_round_trip() {
        let mut m = sample();
        let dense_before = m.to_dense();
        m.rebase(IndexBase::Zero);
        m.validate().unwrap();
        assert_eq!(m.base(), IndexBase::Zero);
        assert_eq!(m.to_dense(), dense_before);
        m.rebase(IndexBase::One);
        assert_eq!(m.to_dense(), dense_before);
    }

    #[test]
    fn dense_round_trip() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(d.get(0, 2), 2.0);
        assert_eq!(d.get(1, 1), 0.0);
        let back = CsrMatrix::from_dense(&d, 0.0, IndexBase::One);
        assert_eq!(back.to_dense(), d);
        assert_eq!(back.nnz(), 4);
    }

    #[test]
    fn transpose_matches_dense_transpose() {
        let m = sample();
        let t = m.transposed();
        t.validate().unwrap();
        assert_eq!(t.to_dense(), m.to_dense().transposed());
    }

    #[test]
    fn dense_transposed_matches_transpose_then_densify() {
        let m = sample();
        assert_eq!(m.to_dense_transposed(), m.transposed().to_dense());
    }

    #[test]
    fn slice_rows_matches_dense_slice() {
        let m = sample();
        for (lo, hi) in [(0usize, 3usize), (0, 1), (1, 3), (1, 1), (3, 3)] {
            let s = m.slice_rows(lo, hi).unwrap();
            s.validate().unwrap();
            assert_eq!(s.to_dense(), m.to_dense().slice_rows(lo, hi).unwrap(), "{lo}..{hi}");
            assert_eq!(s.base(), m.base());
        }
        assert!(m.slice_rows(2, 4).is_err());
        assert!(m.slice_rows(2, 1).is_err());
    }

    #[test]
    fn gather_rows_matches_dense_gather() {
        let m = sample();
        let idx = [2usize, 0, 2, 1];
        let g = m.gather_rows(&idx);
        g.validate().unwrap();
        assert_eq!(g.to_dense(), m.to_dense().gather_rows(&idx));
        assert_eq!(g.base(), m.base());
        assert_eq!(m.gather_rows_dense(&idx), m.to_dense().gather_rows(&idx));
        // Empty gather keeps the shape contract.
        let e = m.gather_rows(&[]);
        e.validate().unwrap();
        assert_eq!(e.rows(), 0);
        assert_eq!(e.cols(), 3);
    }
}
