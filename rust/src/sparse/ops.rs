//! Execution routines of the sparse substrate — the three kernels the
//! paper implements (§IV-B), with its exact contracts:
//!
//! * [`csrmm`]    — `C ← α·op(A)·B + β·C`, `A` CSR, `B`/`C` dense;
//! * [`csrmultd`] — `C ← op(A)·B`, both sparse (1-based, 3-array CSR),
//!                  `C` dense **column-major**;
//! * [`csrmv`]    — `y ← α·op(A)·x + β·y`, `A` 4-array CSR (0- or
//!                  1-based), `x`/`y` dense vectors.
//!
//! The loop orders follow the paper's analysis: row-traversal of every
//! CSR operand; for `csrmultd(AB)` the j-k-i nest (option (a): row
//! traversal on A, column traversal on C), for `csrmultd(AᵀB)` the
//! i-j-k nest that makes both the C traversal column-wise and the A/B
//! traversals row-wise.
//!
//! `csrmm` and `csrmv` are threaded on the persistent worker pool via
//! their `*_threads` entry points — **both** `op` variants: NoTranspose
//! partitions output rows directly; Transpose runs the input-keyed
//! chunk-scratch scheme described at [`csrmm_threads`]. Results are
//! bit-identical at any worker count. β == 0 overwrites the output
//! without reading it (`fill(0)`), matching the dense BLAS contract.

use super::csr::{CsrMatrix, IndexBase};
use crate::dtype::Float;
use crate::error::{Error, Result};

/// `op(A)` selector shared by the three routines.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SparseOp {
    /// `op(A) = A`
    NoTranspose,
    /// `op(A) = Aᵀ`
    Transpose,
}

/// Fixed chunk count of the Transpose scatter paths. Chunk boundaries
/// depend only on the *input* (never on the requested worker count), so
/// scratch contents and the ordered merge replay identically whatever
/// the parallelism — that is what keeps the parallel Transpose kernels
/// bit-identical across 1–N workers.
const T_CHUNKS: usize = 8;
/// Minimum scatter flop volume before the Transpose paths switch from
/// the sequential sweep to per-chunk scratch buffers.
const T_SCRATCH_MIN_WORK: usize = 1 << 14;

/// The chunked Transpose path also zero-fills and merges
/// `chunks · out_len` scratch elements, so the useful scatter work must
/// dominate that overhead too (hyper-sparse matrices with huge outputs
/// stay on the sequential sweep). Both operands depend only on the
/// input — never on the requested worker count — so chunking remains
/// deterministic and the bit-identity contract holds.
fn transpose_chunks(rows: usize, work: usize, out_len: usize) -> usize {
    let chunks = T_CHUNKS.min(rows.max(1));
    if work < T_SCRATCH_MIN_WORK || work < chunks.saturating_mul(out_len) {
        1
    } else {
        chunks
    }
}

/// Chunk-scratch executor shared by the two Transpose scatter kernels:
/// runs `scatter(row_lo, row_hi, scratch)` once per input-keyed chunk of
/// A's rows (chunk boundaries never depend on `threads` — the
/// bit-identity invariant lives here, in one place), collecting one
/// zero-initialized scratch of `out_len` per chunk, then merges the
/// scratches into `out` in ascending chunk order.
fn scatter_chunked<T: Float, F>(
    rows: usize,
    chunks: usize,
    threads: usize,
    out_len: usize,
    out: &mut [T],
    scatter: F,
) where
    F: Fn(usize, usize, &mut [T]) + Sync,
{
    let cbounds = crate::parallel::even_bounds(rows, chunks);
    let nchunks = cbounds.len() - 1;
    let workers = crate::parallel::effective_threads(threads, nchunks, 1);
    let wbounds = crate::parallel::even_bounds(nchunks, workers);
    let (cbounds, scatter) = (&cbounds, &scatter);
    let partials = crate::parallel::par_map(&wbounds, |clo, chi| {
        (clo..chi)
            .map(|ci| {
                let mut scratch = vec![T::ZERO; out_len];
                scatter(cbounds[ci], cbounds[ci + 1], &mut scratch);
                scratch
            })
            .collect::<Vec<_>>()
    });
    // Deterministic partition-order merge.
    for scratch in partials.into_iter().flatten() {
        for (ov, &sv) in out.iter_mut().zip(&scratch) {
            *ov += sv;
        }
    }
}

/// `C ← α·op(A)·B + β·C` — sparse×dense → dense (row-major `B`, `C`),
/// on the process-default worker count (see [`csrmm_threads`]).
///
/// `op=NoTranspose`: `A (m×k)`, `B (k×n)`, `C (m×n)`.
/// `op=Transpose`  : `A (k×m)`, `B (k×n)`, `C (m×n)`.
///
/// Both `op` variants are multithreaded (the Transpose path through the
/// chunk-scratch merge documented at [`csrmm_threads`]) and both are
/// bit-identical across worker counts. `β == 0` overwrites `C`.
pub fn csrmm<T: Float>(
    op: SparseOp,
    alpha: T,
    a: &CsrMatrix<T>,
    b: &[T],
    n: usize,
    beta: T,
    c: &mut [T],
) -> Result<()> {
    csrmm_threads(op, alpha, a, b, n, beta, c, crate::parallel::default_threads())
}

/// [`csrmm`] with an explicit worker count — the algorithm layer routes
/// `Context::threads()` here.
///
/// `op=NoTranspose` is a row traversal of both `A` and `C`, so C's row
/// blocks fan out across pool workers (each output row is produced
/// whole by one worker — bit-identical at any worker count).
///
/// `op=Transpose` scatters into C rows keyed by A's column indices, so
/// workers cannot own disjoint C row blocks directly. Above a small
/// work threshold, A's rows are cut into a **fixed, input-keyed** set of
/// chunks; each chunk accumulates its contributions into a private
/// scratch C (in row order) and the scratches are merged into C in
/// chunk order. Chunking never depends on `threads`, so the merge
/// replays identically and this path is bit-identical across worker
/// counts too (PR 1 silently ignored `threads` here and ran
/// sequentially).
///
/// When the scratch scheme's own `chunks·|C|` zero-fill/merge cost
/// would dominate (hyper-sparse A with a huge output), the kernel
/// instead **echoes A into CSC form** (one `transposed()` re-bucketing,
/// `O(nnz + m)`) and partitions C's rows directly — true disjoint
/// output ownership, with each row's contributions accumulated in
/// ascending input order so the result is bit-identical to the
/// sequential sweep at any worker count.
#[allow(clippy::too_many_arguments)]
pub fn csrmm_threads<T: Float>(
    op: SparseOp,
    alpha: T,
    a: &CsrMatrix<T>,
    b: &[T],
    n: usize,
    beta: T,
    c: &mut [T],
    threads: usize,
) -> Result<()> {
    let (m, k) = match op {
        SparseOp::NoTranspose => (a.rows(), a.cols()),
        SparseOp::Transpose => (a.cols(), a.rows()),
    };
    if b.len() != k * n {
        return Err(Error::Shape(format!("csrmm: B length {} != k*n = {k}x{n}", b.len())));
    }
    if c.len() != m * n {
        return Err(Error::Shape(format!("csrmm: C length {} != m*n = {m}x{n}", c.len())));
    }
    crate::blas::beta_scale(beta, c);
    match op {
        SparseOp::NoTranspose => {
            // Row traversal of A; C row i accumulates α·a_ik · B[k,:].
            let workers = crate::parallel::effective_threads(
                threads,
                a.nnz().saturating_mul(n),
                1 << 14,
            );
            let bounds = crate::parallel::even_bounds(a.rows(), workers);
            crate::parallel::scope_rows(c, n, &bounds, |r0, r1, cblock| {
                for i in r0..r1 {
                    let crow = &mut cblock[(i - r0) * n..(i - r0 + 1) * n];
                    for (kk, av) in a.row_entries(i) {
                        let scaled = alpha * av;
                        let brow = &b[kk * n..(kk + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv = scaled.mul_add(bv, *cv);
                        }
                    }
                }
            });
        }
        SparseOp::Transpose => {
            // (AᵀB)[j,:] += a_ij · B[i,:] — still a row traversal of A,
            // scattering into C. Per-chunk scratch + ordered merge (see
            // the docstring) when the work clears the threshold.
            let work = a.nnz().saturating_mul(n);
            let chunks = transpose_chunks(a.rows(), work, m * n);
            if chunks == 1 {
                let workers =
                    crate::parallel::effective_threads(threads, work, T_SCRATCH_MIN_WORK);
                if workers > 1 {
                    // Hyper-sparse huge-output inputs: the chunk-scratch
                    // scheme tripped on its `chunks·|C|` zero-fill/merge
                    // bound, but the scatter itself is still worth
                    // parallelizing. Echo A into CSC form (= the CSR of
                    // Aᵀ) once — O(nnz + m), dwarfed by the scratches it
                    // replaces — which turns the scatter into a row
                    // traversal of C: workers own disjoint C row blocks
                    // outright. Within each output row, contributions
                    // arrive in ascending i (the echo buckets preserve
                    // input order), the exact order of the sequential
                    // sweep — bit-identical to it at any worker count.
                    let at = a.transposed();
                    let bounds = crate::parallel::even_bounds(m, workers);
                    let at = &at;
                    crate::parallel::scope_rows(c, n, &bounds, |r0, r1, cblock| {
                        for j in r0..r1 {
                            let crow = &mut cblock[(j - r0) * n..(j - r0 + 1) * n];
                            for (i, av) in at.row_entries(j) {
                                let scaled = alpha * av;
                                let brow = &b[i * n..(i + 1) * n];
                                for (cv, &bv) in crow.iter_mut().zip(brow) {
                                    *cv = scaled.mul_add(bv, *cv);
                                }
                            }
                        }
                    });
                    return Ok(());
                }
                for i in 0..a.rows() {
                    let brow = &b[i * n..(i + 1) * n];
                    for (j, av) in a.row_entries(i) {
                        let scaled = alpha * av;
                        let crow = &mut c[j * n..(j + 1) * n];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv = scaled.mul_add(bv, *cv);
                        }
                    }
                }
            } else {
                scatter_chunked(a.rows(), chunks, threads, m * n, c, |r0, r1, scratch| {
                    for i in r0..r1 {
                        let brow = &b[i * n..(i + 1) * n];
                        for (j, av) in a.row_entries(i) {
                            let scaled = alpha * av;
                            let srow = &mut scratch[j * n..(j + 1) * n];
                            for (sv, &bv) in srow.iter_mut().zip(brow) {
                                *sv = scaled.mul_add(bv, *sv);
                            }
                        }
                    }
                });
            }
        }
    }
    Ok(())
}

/// `C ← op(A)·B` — sparse×sparse → dense **column-major** `C`
/// (the paper's §IV-B-1 contract: 3-array CSR, 1-based indices).
///
/// `op=NoTranspose`: `A (m×k)`, `B (k×n)`, `C (m×n)` col-major.
/// `op=Transpose`  : `A (k×m)`, `B (k×n)`, `C (m×n)` col-major.
pub fn csrmultd<T: Float>(
    op: SparseOp,
    a: &CsrMatrix<T>,
    b: &CsrMatrix<T>,
    c: &mut [T],
) -> Result<()> {
    if a.base() != IndexBase::One || b.base() != IndexBase::One {
        return Err(Error::Param("csrmultd requires 1-based CSR operands (§IV-B)".into()));
    }
    let (m, inner) = match op {
        SparseOp::NoTranspose => (a.rows(), a.cols()),
        SparseOp::Transpose => (a.cols(), a.rows()),
    };
    if inner != b.rows() {
        return Err(Error::Shape(format!(
            "csrmultd: inner dim mismatch {inner} vs {}",
            b.rows()
        )));
    }
    let n = b.cols();
    if c.len() != m * n {
        return Err(Error::Shape(format!("csrmultd: C length {} != {m}x{n}", c.len())));
    }
    c.fill(T::ZERO);
    match op {
        SparseOp::NoTranspose => {
            // Option (a) of the paper: row traversal on A (outer i), then
            // k over A's row, inner j over B's row k — the j-k-i nest
            // (innermost→outermost). C is column-major: C[i + j*m].
            for i in 0..a.rows() {
                for (k, av) in a.row_entries(i) {
                    for (j, bv) in b.row_entries(k) {
                        c[i + j * m] = av.mul_add(bv, c[i + j * m]);
                    }
                }
            }
        }
        SparseOp::Transpose => {
            // i-j-k nest (innermost→outermost): outer k walks rows of A
            // and B simultaneously; for each B entry (j) the inner loop
            // over A's row-k entries (i) writes C column j contiguously.
            for k in 0..a.rows() {
                for (j, bv) in b.row_entries(k) {
                    let ccol = &mut c[j * m..(j + 1) * m];
                    for (i, av) in a.row_entries(k) {
                        ccol[i] = av.mul_add(bv, ccol[i]);
                    }
                }
            }
        }
    }
    Ok(())
}

/// `y ← α·op(A)·x + β·y` — the 4-array CSR matrix–vector product
/// (§IV-B-2; index arrays may be 0- or 1-based), on the process-default
/// worker count (see [`csrmv_threads`]). `β == 0` overwrites `y`.
pub fn csrmv<T: Float>(
    op: SparseOp,
    alpha: T,
    a: &CsrMatrix<T>,
    x: &[T],
    beta: T,
    y: &mut [T],
) -> Result<()> {
    csrmv_threads(op, alpha, a, x, beta, y, crate::parallel::default_threads())
}

/// [`csrmv`] with an explicit worker count — the tall-skinny inference
/// entry the algorithm layer routes `Context::threads()` into.
///
/// Both kernels keep the paper's row-order traversal of `A`.
/// `op=NoTranspose` partitions `y` directly (each element is reduced
/// whole by one worker). `op=Transpose` scatters by column index and
/// uses the same input-keyed chunk-scratch merge as
/// [`csrmm_threads`] — per-chunk scratch vectors merged in fixed chunk
/// order. When the scratch scheme's `chunks·|y|` zero-fill/merge cost
/// would dominate (hyper-sparse A with a huge output) the kernel
/// instead echoes A into CSC form once and partitions `y` disjointly,
/// exactly like the `csrmm` Transpose path — each output element
/// accumulates its contributions in ascending input order, so the echo
/// is bit-identical to the sequential sweep. All paths are
/// bit-identical across worker counts.
pub fn csrmv_threads<T: Float>(
    op: SparseOp,
    alpha: T,
    a: &CsrMatrix<T>,
    x: &[T],
    beta: T,
    y: &mut [T],
    threads: usize,
) -> Result<()> {
    let (out_len, in_len) = match op {
        SparseOp::NoTranspose => (a.rows(), a.cols()),
        SparseOp::Transpose => (a.cols(), a.rows()),
    };
    if x.len() != in_len {
        return Err(Error::Shape(format!("csrmv: x length {} != {in_len}", x.len())));
    }
    if y.len() != out_len {
        return Err(Error::Shape(format!("csrmv: y length {} != {out_len}", y.len())));
    }
    crate::blas::beta_scale(beta, y);
    match op {
        SparseOp::NoTranspose => {
            let workers = crate::parallel::effective_threads(threads, a.nnz(), 1 << 13);
            let bounds = crate::parallel::even_bounds(a.rows(), workers);
            crate::parallel::scope_rows(y, 1, &bounds, |lo, hi, yblock| {
                for i in lo..hi {
                    let mut acc = T::ZERO;
                    for (j, av) in a.row_entries(i) {
                        acc = av.mul_add(x[j], acc);
                    }
                    yblock[i - lo] = alpha.mul_add(acc, yblock[i - lo]);
                }
            });
        }
        SparseOp::Transpose => {
            let chunks = transpose_chunks(a.rows(), a.nnz(), out_len);
            if chunks == 1 {
                let workers =
                    crate::parallel::effective_threads(threads, a.nnz(), T_SCRATCH_MIN_WORK);
                if workers > 1 {
                    // Hyper-sparse huge-output inputs: the chunk-scratch
                    // scheme tripped on its `chunks·|y|` bound but the
                    // scatter still clears the parallel threshold. Echo
                    // A into CSC form (= the CSR of Aᵀ) once — O(nnz+m)
                    // — turning the scatter into a row traversal of y:
                    // workers own disjoint y ranges outright, and each
                    // element's contributions arrive in ascending i
                    // (the echo buckets preserve input order), the
                    // exact order of the sequential sweep below —
                    // bit-identical to it at any worker count.
                    let at = a.transposed();
                    let bounds = crate::parallel::even_bounds(out_len, workers);
                    let at = &at;
                    crate::parallel::scope_rows(y, 1, &bounds, |r0, _r1, yblock| {
                        for (j, yv) in yblock.iter_mut().enumerate() {
                            for (i, av) in at.row_entries(r0 + j) {
                                *yv = (alpha * x[i]).mul_add(av, *yv);
                            }
                        }
                    });
                    return Ok(());
                }
                for i in 0..a.rows() {
                    let axi = alpha * x[i];
                    for (j, av) in a.row_entries(i) {
                        y[j] = axi.mul_add(av, y[j]);
                    }
                }
            } else {
                scatter_chunked(a.rows(), chunks, threads, out_len, y, |r0, r1, scratch| {
                    for i in r0..r1 {
                        let axi = alpha * x[i];
                        for (j, av) in a.row_entries(i) {
                            scratch[j] = axi.mul_add(av, scratch[j]);
                        }
                    }
                });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm_naive, Transpose};
    use crate::rng::Mt19937;
    use crate::tables::synth::make_sparse_csr;

    /// Dense oracle for op(A)·B (+ scaling) in row-major.
    fn dense_ref(
        op: SparseOp,
        alpha: f64,
        a: &CsrMatrix<f64>,
        b: &[f64],
        n: usize,
        beta: f64,
        c: &mut [f64],
    ) {
        let ad = a.to_dense();
        let ta = match op {
            SparseOp::NoTranspose => Transpose::No,
            SparseOp::Transpose => Transpose::Yes,
        };
        let m = if op == SparseOp::NoTranspose { a.rows() } else { a.cols() };
        let k = if op == SparseOp::NoTranspose { a.cols() } else { a.rows() };
        // gemm_naive interprets Transpose::Yes as A stored k-major; our
        // dense A is rows×cols row-major which matches.
        gemm_naive(ta, Transpose::No, m, n, k, alpha, ad.data(), b, beta, c);
    }

    #[test]
    fn csrmm_matches_dense_both_ops() {
        let mut e = Mt19937::new(21);
        for op in [SparseOp::NoTranspose, SparseOp::Transpose] {
            let a = make_sparse_csr(&mut e, 40, 30, 0.15);
            let n = 7;
            let k = if op == SparseOp::NoTranspose { 30 } else { 40 };
            let m = if op == SparseOp::NoTranspose { 40 } else { 30 };
            let b: Vec<f64> = (0..k * n).map(|i| (i % 13) as f64 * 0.17 - 1.0).collect();
            let c0: Vec<f64> = (0..m * n).map(|i| (i % 7) as f64 * 0.3).collect();
            let mut c1 = c0.clone();
            let mut c2 = c0.clone();
            csrmm(op, 1.7, &a, &b, n, 0.4, &mut c1).unwrap();
            dense_ref(op, 1.7, &a, &b, n, 0.4, &mut c2);
            for (u, v) in c1.iter().zip(&c2) {
                assert!((u - v).abs() < 1e-9, "op={op:?}");
            }
        }
    }

    /// The Transpose chunk-scratch path (engaged only above the work
    /// threshold) still matches the dense oracle.
    #[test]
    fn csrmm_transpose_chunked_matches_dense() {
        let mut e = Mt19937::new(28);
        let a = make_sparse_csr(&mut e, 300, 120, 0.2); // nnz·n ≫ threshold
        let n = 6;
        let b: Vec<f64> = (0..300 * n).map(|i| (i % 17) as f64 * 0.13 - 1.1).collect();
        let c0: Vec<f64> = (0..120 * n).map(|i| (i % 5) as f64 * 0.2).collect();
        let mut c1 = c0.clone();
        let mut c2 = c0.clone();
        csrmm(SparseOp::Transpose, 1.4, &a, &b, n, 0.7, &mut c1).unwrap();
        dense_ref(SparseOp::Transpose, 1.4, &a, &b, n, 0.7, &mut c2);
        for (u, v) in c1.iter().zip(&c2) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn csrmultd_ab_matches_dense() {
        let mut e = Mt19937::new(22);
        let a = make_sparse_csr(&mut e, 25, 18, 0.2);
        let b = make_sparse_csr(&mut e, 18, 12, 0.2);
        let mut c = vec![0.0f64; 25 * 12]; // column-major
        csrmultd(SparseOp::NoTranspose, &a, &b, &mut c).unwrap();
        // Dense oracle in row-major, then compare transposed layout.
        let mut cref = vec![0.0f64; 25 * 12];
        gemm_naive(
            Transpose::No,
            Transpose::No,
            25,
            12,
            18,
            1.0,
            a.to_dense().data(),
            b.to_dense().data(),
            0.0,
            &mut cref,
        );
        for i in 0..25 {
            for j in 0..12 {
                assert!((c[i + j * 25] - cref[i * 12 + j]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn csrmultd_atb_matches_dense() {
        let mut e = Mt19937::new(23);
        let a = make_sparse_csr(&mut e, 18, 25, 0.2); // Aᵀ is 25x18
        let b = make_sparse_csr(&mut e, 18, 12, 0.2);
        let mut c = vec![0.0f64; 25 * 12];
        csrmultd(SparseOp::Transpose, &a, &b, &mut c).unwrap();
        let mut cref = vec![0.0f64; 25 * 12];
        gemm_naive(
            Transpose::Yes,
            Transpose::No,
            25,
            12,
            18,
            1.0,
            a.to_dense().data(),
            b.to_dense().data(),
            0.0,
            &mut cref,
        );
        for i in 0..25 {
            for j in 0..12 {
                assert!((c[i + j * 25] - cref[i * 12 + j]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn csrmultd_rejects_zero_based() {
        let mut e = Mt19937::new(24);
        let mut a = make_sparse_csr(&mut e, 5, 5, 0.5);
        let b = make_sparse_csr(&mut e, 5, 5, 0.5);
        a.rebase(IndexBase::Zero);
        let mut c = vec![0.0f64; 25];
        assert!(csrmultd(SparseOp::NoTranspose, &a, &b, &mut c).is_err());
    }

    #[test]
    fn csrmv_matches_dense_both_ops_and_bases() {
        let mut e = Mt19937::new(25);
        for base in [IndexBase::One, IndexBase::Zero] {
            for op in [SparseOp::NoTranspose, SparseOp::Transpose] {
                let mut a = make_sparse_csr(&mut e, 30, 20, 0.25);
                a.rebase(base);
                let in_len = if op == SparseOp::NoTranspose { 20 } else { 30 };
                let out_len = if op == SparseOp::NoTranspose { 30 } else { 20 };
                let x: Vec<f64> = (0..in_len).map(|i| i as f64 * 0.1 - 1.0).collect();
                let y0: Vec<f64> = (0..out_len).map(|i| i as f64 * 0.05).collect();
                let mut y1 = y0.clone();
                csrmv(op, 2.0, &a, &x, 0.5, &mut y1).unwrap();
                // dense oracle
                let ad = a.to_dense();
                let mut y2 = y0.clone();
                crate::blas::gemv(
                    op == SparseOp::Transpose,
                    30,
                    20,
                    2.0,
                    ad.data(),
                    &x,
                    0.5,
                    &mut y2,
                );
                for (u, v) in y1.iter().zip(&y2) {
                    assert!((u - v).abs() < 1e-10, "base={base:?} op={op:?}");
                }
            }
        }
    }

    #[test]
    fn csrmm_shape_errors() {
        let mut e = Mt19937::new(26);
        let a = make_sparse_csr(&mut e, 10, 8, 0.3);
        let b = vec![0.0f64; 8 * 4];
        let mut c = vec![0.0f64; 10 * 3]; // wrong n
        assert!(csrmm(SparseOp::NoTranspose, 1.0, &a, &b, 4, 0.0, &mut c).is_err());
    }

    /// Thread-count bit-identity for **both** op variants — including
    /// the Transpose path PR 1 left sequential (sized past the scratch
    /// threshold so the chunked scheme really engages).
    #[test]
    fn csrmm_thread_counts_bit_identical() {
        let mut e = Mt19937::new(27);
        for op in [SparseOp::NoTranspose, SparseOp::Transpose] {
            // nnz·n ≥ 4·2^14 so the NoTranspose fan-out grants 4 workers
            // (and the Transpose scratch threshold is well cleared).
            let a = make_sparse_csr(&mut e, 400, 150, 0.2);
            let n = 9;
            let m = if op == SparseOp::NoTranspose { 400 } else { 150 };
            let k = if op == SparseOp::NoTranspose { 150 } else { 400 };
            let b: Vec<f64> = (0..k * n).map(|i| (i % 11) as f64 * 0.21 - 1.0).collect();
            let mut base = vec![0.5f64; m * n];
            csrmm_threads(op, 1.3, &a, &b, n, 0.6, &mut base, 1).unwrap();
            for threads in 2..=4 {
                let mut c = vec![0.5f64; m * n];
                csrmm_threads(op, 1.3, &a, &b, n, 0.6, &mut c, threads).unwrap();
                for (u, v) in base.iter().zip(&c) {
                    assert_eq!(u.to_bits(), v.to_bits(), "op={op:?} threads={threads}");
                }
            }
        }
    }

    /// The CSC-echo path: hyper-sparse A with a huge output trips the
    /// chunk-scratch bound (`work < chunks·|C|`) while still clearing
    /// the parallel threshold — it must match the dense oracle and be
    /// bit-identical to the sequential (1-thread) sweep at any count.
    #[test]
    fn csrmm_transpose_csc_echo_matches_dense_and_threads() {
        let mut e = Mt19937::new(31);
        // nnz ≈ 2000·1500·0.002 ≈ 6k, work = nnz·12 ≈ 72k ≥ 2^14,
        // but chunks·|C| = 8·1500·12 = 144k > work → echo engages.
        let a = make_sparse_csr(&mut e, 2000, 1500, 0.002);
        let n = 12;
        let work = a.nnz() * n;
        assert!(work >= (1 << 14), "fixture too sparse: work={work}");
        assert!(work < 8 * 1500 * n, "fixture too dense for the echo path");
        let b: Vec<f64> = (0..2000 * n).map(|i| (i % 19) as f64 * 0.07 - 0.6).collect();
        let c0: Vec<f64> = (0..1500 * n).map(|i| (i % 3) as f64 * 0.4).collect();
        let mut base = c0.clone();
        csrmm_threads(SparseOp::Transpose, 1.6, &a, &b, n, 0.8, &mut base, 1).unwrap();
        let mut oracle = c0.clone();
        dense_ref(SparseOp::Transpose, 1.6, &a, &b, n, 0.8, &mut oracle);
        for (u, v) in base.iter().zip(&oracle) {
            assert!((u - v).abs() < 1e-9);
        }
        for threads in 2..=4 {
            let mut c = c0.clone();
            csrmm_threads(SparseOp::Transpose, 1.6, &a, &b, n, 0.8, &mut c, threads).unwrap();
            for (u, v) in base.iter().zip(&c) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    /// Same property for the threaded matrix–vector entry.
    #[test]
    fn csrmv_thread_counts_bit_identical() {
        let mut e = Mt19937::new(29);
        for op in [SparseOp::NoTranspose, SparseOp::Transpose] {
            // nnz ≈ 36k ≥ 4·2^13: the NoTranspose fan-out grants 4
            // workers and the Transpose chunk threshold is cleared.
            let a = make_sparse_csr(&mut e, 600, 400, 0.15);
            let in_len = if op == SparseOp::NoTranspose { 400 } else { 600 };
            let out_len = if op == SparseOp::NoTranspose { 600 } else { 400 };
            let x: Vec<f64> = (0..in_len).map(|i| (i % 9) as f64 * 0.23 - 1.0).collect();
            let y0: Vec<f64> = (0..out_len).map(|i| (i % 5) as f64 * 0.4).collect();
            let mut base = y0.clone();
            csrmv_threads(op, 1.8, &a, &x, 0.3, &mut base, 1).unwrap();
            for threads in 2..=4 {
                let mut y = y0.clone();
                csrmv_threads(op, 1.8, &a, &x, 0.3, &mut y, threads).unwrap();
                for (u, v) in base.iter().zip(&y) {
                    assert_eq!(u.to_bits(), v.to_bits(), "op={op:?} threads={threads}");
                }
            }
        }
    }

    /// The `csrmv` CSC-echo path (mirroring `csrmm`'s): hyper-sparse A
    /// with a huge output trips the chunk-scratch bound
    /// (`nnz < chunks·|y|`) while still clearing the parallel
    /// threshold — it must match the dense oracle and be bit-identical
    /// to the sequential (1-thread) sweep at any worker count.
    #[test]
    fn csrmv_transpose_csc_echo_matches_dense_and_threads() {
        let mut e = Mt19937::new(33);
        // nnz ≈ 1200·6000·0.0055 ≈ 39.6k ≥ 2·2^14 (so at least two
        // workers clear the fan-out gate), but chunks·|y| = 8·6000 =
        // 48k > nnz → the echo engages instead of the chunk-scratch
        // scheme.
        let a = make_sparse_csr(&mut e, 1200, 6000, 0.0055);
        let nnz = a.nnz();
        assert!(nnz >= (2 << 14), "fixture too sparse: nnz={nnz}");
        assert!(nnz < 8 * 6000, "fixture too dense for the echo path: nnz={nnz}");
        let x: Vec<f64> = (0..1200).map(|i| (i % 13) as f64 * 0.11 - 0.7).collect();
        let y0: Vec<f64> = (0..6000).map(|i| (i % 7) as f64 * 0.25).collect();
        let mut base = y0.clone();
        csrmv_threads(SparseOp::Transpose, 1.4, &a, &x, 0.6, &mut base, 1).unwrap();
        let ad = a.to_dense();
        let mut oracle = y0.clone();
        crate::blas::gemv(true, 1200, 6000, 1.4, ad.data(), &x, 0.6, &mut oracle);
        for (u, v) in base.iter().zip(&oracle) {
            assert!((u - v).abs() < 1e-9);
        }
        for threads in 2..=4 {
            let mut y = y0.clone();
            csrmv_threads(SparseOp::Transpose, 1.4, &a, &x, 0.6, &mut y, threads).unwrap();
            for (u, v) in base.iter().zip(&y) {
                assert_eq!(u.to_bits(), v.to_bits(), "threads={threads}");
            }
        }
    }

    /// β == 0 must overwrite: NaN in y cannot leak through either op.
    #[test]
    fn csrmv_beta_zero_overwrites_nan_y() {
        let mut e = Mt19937::new(30);
        for op in [SparseOp::NoTranspose, SparseOp::Transpose] {
            let a = make_sparse_csr(&mut e, 30, 20, 0.25);
            let in_len = if op == SparseOp::NoTranspose { 20 } else { 30 };
            let out_len = if op == SparseOp::NoTranspose { 30 } else { 20 };
            let x: Vec<f64> = (0..in_len).map(|i| i as f64 * 0.1 - 1.0).collect();
            let mut y = vec![f64::NAN; out_len];
            csrmv(op, 1.0, &a, &x, 0.0, &mut y).unwrap();
            assert!(y.iter().all(|v| v.is_finite()), "op={op:?} y={y:?}");
        }
    }

    #[test]
    fn csrmv_empty_rows_ok() {
        // Matrix with an all-zero row: y for that row must be β·y only.
        let a =
            CsrMatrix::new(3, 2, vec![5.0], vec![0], vec![0, 1, 1, 1], IndexBase::Zero).unwrap();
        let mut y = vec![1.0f64, 1.0, 1.0];
        csrmv(SparseOp::NoTranspose, 1.0, &a, &[2.0, 3.0], 0.5, &mut y).unwrap();
        assert_eq!(y, vec![10.5, 0.5, 0.5]);
    }
}
