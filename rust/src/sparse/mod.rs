//! Sparse BLAS substrate — the MKL SPBLAS replacement of paper §IV-B.
//!
//! OpenBLAS offers no sparse module, so the paper implements the three
//! CSR routines oneDAL needs: [`csrmm`], [`csrmultd`] and [`csrmv`]. This
//! module reproduces them with the exact contracts of §IV-B, including
//! the 3-array vs 4-array CSR forms, 0-/1-based indexing, the identity /
//! transpose `op`, and — for `csrmultd` — the paper's loop-order analysis
//! (j-k-i for `AB`, i-j-k for `AᵀB`, column-major `C`).
//!
//! The module follows MKL SPBLAS's four-group structure (state
//! management / analysis / execution / helpers):
//! * state — [`CsrMatrix`] construction and [`CsrMatrix::validate`];
//! * analysis — [`CsrMatrix::inspect`] returning an [`Inspection`] used
//!   to pick execution kernels;
//! * execution — [`ops`];
//! * helpers — dense↔CSR converters, transpose, index-base conversion.

pub mod csr;
pub mod ops;

pub use csr::{CsrMatrix, IndexBase, Inspection};
pub use ops::{csrmm, csrmm_threads, csrmultd, csrmv, csrmv_threads, SparseOp};
