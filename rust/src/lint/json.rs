//! Zero-dependency JSON for `palint --json`.
//!
//! The emitter produces the machine-readable findings report consumed
//! by the CI gate; the (deliberately minimal) parser exists so the
//! round-trip contract — emit, parse, recover the identical findings —
//! is testable without adding a serde dependency to a crate that has
//! none.
//!
//! Schema, version 1:
//!
//! ```json
//! {
//!   "palint": 1,
//!   "findings": [
//!     { "rule": "PAL-ORD", "path": "algorithms/foo.rs",
//!       "line": 42, "message": "…" }
//!   ]
//! }
//! ```

use super::rules::Finding;

/// Parsed JSON value. Object keys keep emission order (the emitter is
/// deterministic, so the parse tree is too).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Render the findings report (stable field order, findings already
/// sorted by the scanner).
pub fn emit(findings: &[Finding]) -> String {
    let mut out = String::from("{\n  \"palint\": 1,\n  \"findings\": [");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n    { \"rule\": ");
        emit_str(&mut out, &f.rule);
        out.push_str(", \"path\": ");
        emit_str(&mut out, &f.path);
        out.push_str(", \"line\": ");
        out.push_str(&f.line.to_string());
        out.push_str(", \"message\": ");
        emit_str(&mut out, &f.message);
        out.push_str(" }");
    }
    if findings.is_empty() {
        out.push_str("]\n}\n");
    } else {
        out.push_str("\n  ]\n}\n");
    }
    out
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str("\\u");
                let code = c as u32;
                for shift in [12u32, 8, 4, 0] {
                    let digit = (code >> shift) & 0xf;
                    out.push(char::from_digit(digit, 16).unwrap_or('0'));
                }
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recover findings from a parsed report; `None` if the shape does not
/// match the schema.
pub fn findings_from_value(v: &Value) -> Option<Vec<Finding>> {
    if v.get("palint")?.as_usize()? != 1 {
        return None;
    }
    let mut out = Vec::new();
    for item in v.get("findings")?.as_arr()? {
        out.push(Finding {
            rule: item.get("rule")?.as_str()?.to_string(),
            path: item.get("path")?.as_str()?.to_string(),
            line: item.get("line")?.as_usize()?,
            message: item.get("message")?.as_str()?.to_string(),
        });
    }
    Some(out)
}

/// Parse a JSON document. Errors carry the char offset of the problem.
pub fn parse(input: &str) -> Result<Value, String> {
    let chars: Vec<char> = input.chars().collect();
    let mut p = Parser { chars, pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.chars.len() {
        return Err(format!("trailing characters at offset {}", p.pos));
    }
    Ok(v)
}

struct Parser {
    chars: Vec<char>,
    pos: usize,
}

impl Parser {
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\t' | '\n' | '\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<char, String> {
        let c = self.peek().ok_or_else(|| format!("unexpected end at offset {}", self.pos))?;
        self.pos += 1;
        Ok(c)
    }

    fn expect(&mut self, want: char) -> Result<(), String> {
        let at = self.pos;
        let got = self.bump()?;
        if got == want {
            Ok(())
        } else {
            Err(format!("expected {want:?} at offset {at}, got {got:?}"))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        for want in word.chars() {
            self.expect(want)?;
        }
        Ok(v)
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(Value::Str(self.string()?)),
            Some('t') => self.literal("true", Value::Bool(true)),
            Some('f') => self.literal("false", Value::Bool(false)),
            Some('n') => self.literal("null", Value::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at offset {}", self.pos)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect('{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump()? {
                ',' => continue,
                '}' => return Ok(Value::Obj(pairs)),
                c => return Err(format!("expected ',' or '}}', got {c:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                ',' => continue,
                ']' => return Ok(Value::Arr(items)),
                c => return Err(format!("expected ',' or ']', got {c:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                '"' => return Ok(out),
                '\\' => match self.bump()? {
                    '"' => out.push('"'),
                    '\\' => out.push('\\'),
                    '/' => out.push('/'),
                    'n' => out.push('\n'),
                    'r' => out.push('\r'),
                    't' => out.push('\t'),
                    'b' => out.push('\u{0008}'),
                    'f' => out.push('\u{000c}'),
                    'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let at = self.pos;
                            let d = self
                                .bump()?
                                .to_digit(16)
                                .ok_or_else(|| format!("bad \\u digit at offset {at}"))?;
                            code = (code << 4) | d;
                        }
                        // Lone surrogates (which this emitter never
                        // produces) degrade to U+FFFD.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => return Err(format!("bad escape {c:?}")),
                },
                c => out.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || "+-.eE".contains(c)) {
            self.pos += 1;
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("bad number {text:?} at offset {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &str, path: &str, line: usize, message: &str) -> Finding {
        Finding {
            rule: rule.to_string(),
            path: path.to_string(),
            line,
            message: message.to_string(),
        }
    }

    #[test]
    fn empty_report_round_trips() {
        let report = emit(&[]);
        let v = parse(&report).unwrap();
        assert_eq!(findings_from_value(&v).unwrap(), Vec::new());
    }

    #[test]
    fn findings_round_trip_bit_exact() {
        let original = vec![
            finding("PAL-ORD", "algorithms/foo.rs", 12, "sort under total_cmp"),
            finding("PAL-HASH", "x.rs", 3, "tricky \"quoted\" text\nwith a newline\tand tab"),
            finding("PAL-META", "y.rs", 1, "backslash \\ and control \u{0001} char"),
        ];
        let report = emit(&original);
        let recovered = findings_from_value(&parse(&report).unwrap()).unwrap();
        assert_eq!(recovered, original);
    }

    #[test]
    fn schema_fields_present() {
        let report = emit(&[finding("PAL-ENV", "a.rs", 7, "m")]);
        let v = parse(&report).unwrap();
        assert_eq!(v.get("palint").and_then(Value::as_usize), Some(1));
        let arr = v.get("findings").and_then(Value::as_arr).unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("line").and_then(Value::as_usize), Some(7));
        assert_eq!(arr[0].get("rule").and_then(Value::as_str), Some("PAL-ENV"));
    }

    #[test]
    fn wrong_shape_is_rejected() {
        let wrong_version = parse("{\"palint\": 2, \"findings\": []}").unwrap();
        assert!(findings_from_value(&wrong_version).is_none());
        assert!(findings_from_value(&parse("{\"findings\": []}").unwrap()).is_none());
        assert!(findings_from_value(&parse("[1, 2]").unwrap()).is_none());
    }

    #[test]
    fn parser_handles_scalars_and_rejects_garbage() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(" -12.5e1 ").unwrap(), Value::Num(-125.0));
        assert!(parse("{\"a\": }").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"open").is_err());
        assert!(parse("{} trailing").is_err());
    }
}
