//! Comment/string-aware lexical scan for `palint`.
//!
//! The rule engine must never fire on a trigger token that only appears
//! inside a comment or a string literal (`// the old partial_cmp
//! sort…`, `let s = "Instant::now";`). This module performs one pass
//! over a source file and splits every line into
//!
//! * `code` — the source text with the *contents* of comments, string
//!   literals and char literals blanked to spaces (column positions are
//!   preserved, so findings can point at the original text), and
//! * `comment` — the concatenated comment text of the line, which is
//!   where `// SAFETY:` contracts and `// palint: allow(..)` directives
//!   live.
//!
//! The scan is a small state machine, not a parser: it understands
//! line comments, *nested* block comments, plain/byte strings with
//! escapes, raw strings (`r"…"`, `r#"…"#`, `br##"…"##`), char literals,
//! and the char-literal/lifetime ambiguity (`'a'` vs `<'a>`). It also
//! records the first line of a `#[cfg(test)]` item, which every
//! library-code rule treats as the start of the file's test region (in
//! this crate the unit-test module is always the final item of a file;
//! the approximation is documented in docs/INVARIANTS.md).

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct ScanLine {
    /// Source text with comment/string/char-literal contents blanked.
    pub code: String,
    /// Comment text carried by this line (line + block comments).
    pub comment: String,
}

/// Whole-file scan result.
#[derive(Debug)]
pub struct FileScan {
    pub lines: Vec<ScanLine>,
    /// 0-based line of the first `#[cfg(test)]` occurrence in code, if
    /// any; lines at or after it belong to the file's test region.
    pub test_start: Option<usize>,
}

impl FileScan {
    /// 0-based `line` is inside the file's `#[cfg(test)]` region.
    pub fn in_test_region(&self, line: usize) -> bool {
        self.test_start.is_some_and(|t| line >= t)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Normal,
    /// Nesting depth of `/* … */`.
    Block(u32),
    /// Inside `"…"`; `true` when the previous char was a backslash.
    Str(bool),
    /// Inside `r##"…"##` with this many hashes.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Scan one file. Never fails: malformed source degrades to blanked
/// text, which can only *hide* tokens from the rules, never invent
/// them.
pub fn scan(source: &str) -> FileScan {
    let chars: Vec<char> = source.chars().collect();
    let mut lines: Vec<ScanLine> = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut test_start: Option<usize> = None;
    let mut i = 0usize;
    let n = chars.len();

    macro_rules! flush_line {
        () => {{
            if code.contains("#[cfg(test)]") && test_start.is_none() {
                test_start = Some(lines.len());
            }
            lines.push(ScanLine {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
        }};
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            // A line comment ends here; every other state persists.
            flush_line!();
            i += 1;
            continue;
        }
        match state {
            State::Normal => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    // Line comment (covers `///` and `//!`): the rest of
                    // the physical line is comment text.
                    let mut j = i + 2;
                    while j < n && chars[j] != '\n' {
                        comment.push(chars[j]);
                        j += 1;
                    }
                    code.push_str(&" ".repeat(j - i));
                    i = j;
                } else if c == '/' && next == Some('*') {
                    code.push_str("  ");
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str(false);
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && (i == 0 || !is_ident(chars[i - 1]))
                    && raw_str_hashes(&chars, i).is_some()
                {
                    let (skip, hashes) = raw_str_hashes(&chars, i).unwrap_or((1, 0));
                    code.push_str(&" ".repeat(skip - 1));
                    code.push('"');
                    state = State::RawStr(hashes);
                    i += skip;
                } else if c == 'b' && next == Some('"') && (i == 0 || !is_ident(chars[i - 1])) {
                    // Byte string `b"…"` — same body rules as `"…"`.
                    code.push_str(" \"");
                    state = State::Str(false);
                    i += 2;
                } else if c == '\'' {
                    i = lex_quote(&chars, i, &mut code);
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    state = State::Block(depth + 1);
                    code.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    state = if depth == 1 { State::Normal } else { State::Block(depth - 1) };
                    code.push_str("  ");
                    i += 2;
                } else {
                    comment.push(c);
                    code.push(' ');
                    i += 1;
                }
            }
            State::Str(escaped) => {
                if escaped {
                    state = State::Str(false);
                    code.push(' ');
                } else if c == '\\' {
                    state = State::Str(true);
                    code.push(' ');
                } else if c == '"' {
                    state = State::Normal;
                    code.push('"');
                } else {
                    code.push(' ');
                }
                i += 1;
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    code.push('"');
                    code.push_str(&" ".repeat(hashes as usize));
                    state = State::Normal;
                    i += 1 + hashes as usize;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() || lines.is_empty() {
        flush_line!();
    }
    FileScan { lines, test_start }
}

/// At `chars[i]` ∈ {`r`, `b`}: if this starts a raw-string prefix
/// (`r"`, `r#"`, `br##"` …), return `(chars_to_consume_through_quote,
/// hash_count)`.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<(usize, u32)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((j - i + 1, hashes))
    } else {
        None
    }
}

/// `chars[i] == '"'` inside a raw string with `hashes` hashes: true
/// when the quote is followed by exactly the closing hash run.
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// Disambiguate `'` at `chars[i]`: lifetime (`'a`, `'_`, `'static`) or
/// char literal (`'x'`, `'\n'`, `'"'`). Lifetimes pass through as code;
/// char-literal bodies are blanked. Returns the next scan index.
fn lex_quote(chars: &[char], i: usize, code: &mut String) -> usize {
    let next = chars.get(i + 1).copied();
    let after = chars.get(i + 2).copied();
    let is_lifetime = match next {
        Some(c) if c.is_alphabetic() || c == '_' => after != Some('\''),
        _ => false,
    };
    if is_lifetime {
        code.push('\'');
        return i + 1;
    }
    // Char literal: blank through the closing quote (same line; an
    // unterminated literal blanks to end of line, which is safe).
    code.push('\'');
    let mut j = i + 1;
    let mut escaped = false;
    while let Some(&c) = chars.get(j) {
        if c == '\n' {
            break;
        }
        if escaped {
            escaped = false;
        } else if c == '\\' {
            escaped = true;
        } else if c == '\'' {
            code.push('\'');
            return j + 1;
        }
        code.push(' ');
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        scan(src).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_move_to_comment_channel() {
        let s = scan("let x = 1; // the old partial_cmp sort\n");
        assert!(!s.lines[0].code.contains("partial_cmp"));
        assert!(s.lines[0].comment.contains("partial_cmp"));
        assert!(s.lines[0].code.contains("let x = 1;"));
    }

    #[test]
    fn doc_and_inner_comments_are_comments() {
        let s = scan("/// uses Instant::now\n//! env::var notes\nfn f() {}\n");
        assert!(!s.lines[0].code.contains("Instant"));
        assert!(s.lines[0].comment.contains("Instant::now"));
        assert!(s.lines[1].comment.contains("env::var"));
        assert!(s.lines[2].code.contains("fn f()"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let src = "a /* one /* two */ still */ b\n/* open\nInstant::now()\n*/ c\n";
        let c = codes(src);
        assert!(c[0].contains('a') && c[0].contains('b'));
        assert!(!c[0].contains("still"));
        assert!(!c[2].contains("Instant"));
        assert!(c[3].contains('c'));
        let s = scan(src);
        assert!(s.lines[2].comment.contains("Instant::now()"));
    }

    #[test]
    fn string_contents_are_blanked_quotes_kept() {
        let c = codes("let s = \"Instant::now \\\" still\"; f(s);\n");
        assert!(!c[0].contains("Instant"));
        assert!(!c[0].contains("still"));
        assert!(c[0].contains("let s = \""));
        assert!(c[0].contains("f(s);"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let c = codes("let s = r#\"env::var \"quoted\" inside\"#; g();\n");
        assert!(!c[0].contains("env::var"));
        assert!(c[0].contains("g();"));
        let c = codes("let s = br\"HashMap\"; h();\n");
        assert!(!c[0].contains("HashMap"));
        assert!(c[0].contains("h();"));
    }

    #[test]
    fn multiline_strings_persist_state() {
        let c = codes("let s = \"line one\npartial_cmp inside\nend\"; tail();\n");
        assert!(!c[1].contains("partial_cmp"));
        assert!(c[2].contains("tail();"));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let c = codes("let a: &'static str = x; let q = '\"'; let z = 'y'; s.split('/');\n");
        // Lifetime survives as code; char-literal bodies are blanked.
        assert!(c[0].contains("'static str"));
        assert!(!c[0].contains("'y'"));
        // The quote char literal must not open a string state.
        assert!(c[0].contains("let z ="));
        assert!(c[0].contains("s.split("));
    }

    #[test]
    fn escaped_char_literal() {
        let c = codes("let nl = '\\n'; let bs = '\\\\'; after();\n");
        assert!(c[0].contains("after();"));
    }

    #[test]
    fn cfg_test_marks_region() {
        let s = scan("fn lib() {}\n#[cfg(test)]\nmod tests {\n  use super::*;\n}\n");
        assert_eq!(s.test_start, Some(1));
        assert!(!s.in_test_region(0));
        assert!(s.in_test_region(1));
        assert!(s.in_test_region(3));
    }

    #[test]
    fn cfg_test_inside_string_does_not_mark() {
        let s = scan("let x = \"#[cfg(test)]\";\nfn f() {}\n");
        assert_eq!(s.test_start, None);
    }

    #[test]
    fn columns_are_preserved() {
        let src = "abc /* xx */ def\n";
        let s = scan(src);
        // `def` must sit at the same column as in the original text.
        assert_eq!(s.lines[0].code.find("def"), src.find("def"));
    }

    #[test]
    fn raw_identifier_is_not_a_raw_string() {
        let c = codes("let r#type = 3; use_it(r#type);\n");
        assert!(c[0].contains("use_it"));
        assert!(c[0].contains("= 3;"));
    }
}
