//! The `palint` rule set — the house determinism & fault contracts as
//! named, numbered, mechanically-checked rules.
//!
//! Every rule is grounded in an existing contract (see
//! docs/INVARIANTS.md for the catalogue and the enforcing-mechanism
//! table):
//!
//! | rule       | contract |
//! |------------|----------|
//! | PAL-ORD    | NaN degrades under IEEE `total_cmp` (PR 5): no `.partial_cmp(` in library code. |
//! | PAL-CLOCK  | unbudgeted runs never read the clock (PR 6): `Instant::now` / `SystemTime::now` only in `coordinator/budget.rs`, `profiling/`, and binary targets. |
//! | PAL-HASH   | fixed-order merges: no iteration over `HashMap`/`HashSet` bindings in library code (key lookup is fine; traversal must go through sorted keys, an index `Vec`, or a `BTreeMap`). |
//! | PAL-UNSAFE | every `unsafe` carries a `// SAFETY:` contract comment; `static mut` is banned outright. |
//! | PAL-ENV    | `std::env::var` confined to the approved config sites (`parallel/`, `failpoint.rs`, `coordinator/`, `primitives/lanes.rs`). |
//! | PAL-QUAR   | panic quarantine (PR 6): every public algorithm entry point (`train`/`infer`/…) runs under `parallel::quarantine` or delegates to an entry point that does. |
//! | PAL-LANE   | lane-profile confinement (PR 10): no literal lane-count/panel-geometry `const` (`LANES`/`NR`/`KC`/`TILE`/`WSS_LANES`/`MR`) and no `ONEDAL_SVE_BACKEND` token in library code outside `primitives/lanes.rs` — geometry derives from the active `LaneProfile`. |
//! | PAL-META   | suppressions are themselves contracts: a malformed, reason-less, unknown-rule or *unused* `// palint: allow(..)` directive is a finding. |
//!
//! Scope conventions shared by the path-scoped rules: binary targets
//! (`main.rs`, `bin/`) are CLI surface, not library code, and the
//! `#[cfg(test)]` region of a file is exempt (test fixtures measure
//! wall-time and build adversarial inputs on purpose). PAL-UNSAFE is
//! the exception — it applies everywhere, tests and binaries included.
//!
//! Suppression: `// palint: allow(RULE-ID, reason)` on the finding's
//! line or the line directly above suppresses **exactly one** finding
//! of that rule. The reason is mandatory; an allow that suppresses
//! nothing is flagged by PAL-META so stale escapes cannot linger.

use super::lexer::FileScan;

/// One finding: rule, location, message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    /// Path relative to the scanned root, forward slashes.
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    pub message: String,
}

/// Rule ids an allow-directive may name (PAL-META itself cannot be
/// suppressed — the escape hatch must not have an escape hatch).
pub const RULE_IDS: [&str; 7] =
    ["PAL-ORD", "PAL-CLOCK", "PAL-HASH", "PAL-UNSAFE", "PAL-ENV", "PAL-QUAR", "PAL-LANE"];

/// (id, one-line description) for `palint --list-rules`.
pub const RULE_DESCRIPTIONS: [(&str, &str); 8] = [
    ("PAL-ORD", "no partial_cmp in library code; float comparators sort under total_cmp"),
    ("PAL-CLOCK", "clock reads only in coordinator/budget.rs, profiling/ and binary targets"),
    ("PAL-HASH", "no iteration over HashMap/HashSet in library code (nondeterministic order)"),
    ("PAL-UNSAFE", "every `unsafe` needs a // SAFETY: contract comment; `static mut` is banned"),
    ("PAL-ENV", "std::env::var confined to parallel/, failpoint.rs, coordinator/ and primitives/lanes.rs"),
    ("PAL-QUAR", "public algorithm entry points run under parallel::quarantine"),
    ("PAL-LANE", "lane/panel geometry consts and ONEDAL_SVE_BACKEND only in primitives/lanes.rs"),
    ("PAL-META", "palint allow-directives must be well-formed, reasoned, and actually used"),
];

/// Everything a rule gets to see about one file.
pub struct FileCtx<'a> {
    pub rel_path: &'a str,
    pub scan: &'a FileScan,
}

impl FileCtx<'_> {
    fn is_binary_target(&self) -> bool {
        self.rel_path == "main.rs" || self.rel_path.starts_with("bin/")
    }

    fn path_in(&self, prefixes: &[&str]) -> bool {
        prefixes.iter().any(|p| {
            if let Some(dir) = p.strip_suffix('/') {
                self.rel_path == dir || self.rel_path.starts_with(p)
            } else {
                self.rel_path == *p
            }
        })
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte offsets of whole-word occurrences of `needle` in `hay`
/// (neither neighbor is an identifier char).
fn word_occurrences(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let at = from + pos;
        let before_ok = at == 0 || !hay[..at].chars().next_back().is_some_and(is_ident);
        let after_ok = !hay[at + needle.len()..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + needle.len().max(1);
    }
    out
}

/// Run every rule over one scanned file, then apply the allow
/// directives. Returned findings are sorted by (line, rule).
pub fn check_file(ctx: &FileCtx) -> Vec<Finding> {
    let mut findings = Vec::new();
    rule_ord(ctx, &mut findings);
    rule_clock(ctx, &mut findings);
    rule_hash(ctx, &mut findings);
    rule_unsafe(ctx, &mut findings);
    rule_env(ctx, &mut findings);
    rule_quar(ctx, &mut findings);
    rule_lane(ctx, &mut findings);
    findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    apply_allows(ctx, findings)
}

fn push(findings: &mut Vec<Finding>, ctx: &FileCtx, rule: &str, line0: usize, msg: String) {
    findings.push(Finding {
        rule: rule.to_string(),
        path: ctx.rel_path.to_string(),
        line: line0 + 1,
        message: msg,
    });
}

/// PAL-ORD — the PR 5 total-order contract. `partial_cmp` on floats is
/// either a latent NaN panic (`.unwrap()`) or a NaN-order hazard; every
/// library comparator sorts under IEEE `total_cmp`.
fn rule_ord(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    if ctx.is_binary_target() {
        return;
    }
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        if ctx.scan.in_test_region(i) {
            break;
        }
        if !word_occurrences(&line.code, "partial_cmp").is_empty() {
            push(
                findings,
                ctx,
                "PAL-ORD",
                i,
                "partial_cmp in library code: sort under IEEE total_cmp (dtype::Float::total_cmp) \
                 so NaN degrades deterministically instead of panicking"
                    .to_string(),
            );
        }
    }
}

/// PAL-CLOCK — the PR 6 budget contract: unlimited budgets never read
/// the clock, so uncapped runs stay bit-identical. Wall-clock reads are
/// confined to the budget meter, the profiling harness, and binaries.
fn rule_clock(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    if ctx.is_binary_target() || ctx.path_in(&["coordinator/budget.rs", "profiling/"]) {
        return;
    }
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        if ctx.scan.in_test_region(i) {
            break;
        }
        for tok in ["Instant::now", "SystemTime::now"] {
            if !word_occurrences(&line.code, tok).is_empty() {
                push(
                    findings,
                    ctx,
                    "PAL-CLOCK",
                    i,
                    format!(
                        "{tok} outside coordinator/budget.rs, profiling/ and binaries: \
                         route wall-time through coordinator::Budget so unbudgeted runs \
                         never read the clock"
                    ),
                );
            }
        }
    }
}

/// Method names whose call on a hash container traverses it in
/// nondeterministic order.
const HASH_ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
];

/// PAL-HASH — fixed-order-merge contract. Key *lookup* on a hash map is
/// deterministic; *traversal* is not. The pass first collects the
/// file's hash-typed bindings (`name: HashMap<..>` fields/params and
/// `let name = HashMap::new()`-style initializers), then flags
/// iteration-method calls and `for … in` loops whose receiver is one of
/// them. This is an approximation (no type inference) — the
/// debug-build merge-order auditor in `parallel::audit` backstops what
/// it cannot see.
fn rule_hash(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    if ctx.is_binary_target() {
        return;
    }
    let bindings = hash_bindings(ctx.scan);
    if bindings.is_empty() {
        return;
    }
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        if ctx.scan.in_test_region(i) {
            break;
        }
        let code = &line.code;
        for m in HASH_ITER_METHODS {
            let pat = format!(".{m}(");
            let mut from = 0;
            while let Some(pos) = code[from..].find(&pat) {
                let at = from + pos;
                if let Some(recv) = ident_before(code, at) {
                    if bindings.iter().any(|b| b == recv) {
                        push(
                            findings,
                            ctx,
                            "PAL-HASH",
                            i,
                            format!(
                                "`{recv}.{m}(..)` iterates a HashMap/HashSet in library code: \
                                 traversal order is nondeterministic — iterate sorted keys, an \
                                 index Vec, or switch the container to BTreeMap"
                            ),
                        );
                    }
                }
                from = at + pat.len();
            }
        }
        for_loop_over_binding(ctx, i, code, &bindings, findings);
    }
}

/// Collect identifiers bound to `HashMap`/`HashSet` anywhere in the
/// file (declarations are scanned in the test region too: a lib-region
/// traversal of a binding declared next to the test boundary must not
/// escape).
fn hash_bindings(scan: &FileScan) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for line in &scan.lines {
        let code = &line.code;
        for ty in ["HashMap", "HashSet"] {
            for at in word_occurrences(code, ty) {
                // `name: HashMap<..>` (field, param, typed let).
                let before = code[..at].trim_end();
                if let Some(pre) = before.strip_suffix(':') {
                    if let Some(name) = last_ident(pre) {
                        push_unique(&mut out, name);
                        continue;
                    }
                }
                // `let [mut] name = HashMap::new()` / `= HashMap::from(..)`.
                if let Some(pre) = before.strip_suffix('=') {
                    if let Some(name) = last_ident(pre.trim_end()) {
                        push_unique(&mut out, name);
                    }
                }
            }
        }
    }
    out
}

fn push_unique(v: &mut Vec<String>, s: &str) {
    if !v.iter().any(|x| x == s) {
        v.push(s.to_string());
    }
}

/// Trailing identifier of `s`, if `s` ends with one.
fn last_ident(s: &str) -> Option<&str> {
    let end = s.len();
    let start = s
        .char_indices()
        .rev()
        .take_while(|(_, c)| is_ident(*c))
        .last()
        .map(|(i, _)| i)?;
    if start == end {
        return None;
    }
    let id = &s[start..end];
    id.chars().next().filter(|c| c.is_alphabetic() || *c == '_').map(|_| id)
}

/// Identifier directly before byte offset `at` (receiver of a `.m(`
/// call), if any.
fn ident_before(code: &str, at: usize) -> Option<&str> {
    let head = &code[..at];
    let start = head
        .char_indices()
        .rev()
        .take_while(|(_, c)| is_ident(*c))
        .last()
        .map(|(i, _)| i)?;
    if start == at {
        return None;
    }
    Some(&head[start..])
}

/// Flag `for … in [&[mut ]]binding` loops.
fn for_loop_over_binding(
    ctx: &FileCtx,
    line0: usize,
    code: &str,
    bindings: &[String],
    findings: &mut Vec<Finding>,
) {
    for at in word_occurrences(code, "for") {
        let Some(in_rel) = code[at..].find(" in ") else { continue };
        let mut rest = code[at + in_rel + 4..].trim_start();
        rest = rest.strip_prefix('&').unwrap_or(rest).trim_start();
        rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
        let ident: String = rest.chars().take_while(|&c| is_ident(c)).collect();
        let tail = rest[ident.len()..].chars().next();
        // `for k in map.keys()` is caught by the method pass; here we
        // only want bare `for x in &map {`-style traversals.
        if bindings.iter().any(|b| *b == ident) && tail != Some('.') {
            push(
                findings,
                ctx,
                "PAL-HASH",
                line0,
                format!(
                    "`for … in {ident}` iterates a HashMap/HashSet in library code: \
                     traversal order is nondeterministic — iterate sorted keys, an index \
                     Vec, or switch the container to BTreeMap"
                ),
            );
        }
    }
}

/// PAL-UNSAFE — applies everywhere (tests and binaries included):
/// every `unsafe` token must sit under a `// SAFETY:` contract comment
/// (same line, or the contiguous comment block directly above), and
/// `static mut` is banned outright — it is UB-prone shared mutable
/// state no SAFETY comment can license in a parallel library.
fn rule_unsafe(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        let code = &line.code;
        if !word_occurrences(code, "static").is_empty() {
            // Tolerate arbitrary spacing between the two keywords.
            let squashed: String = code.split_whitespace().collect::<Vec<_>>().join(" ");
            if squashed.contains("static mut ") {
                push(
                    findings,
                    ctx,
                    "PAL-UNSAFE",
                    i,
                    "`static mut` is banned: use an atomic, a Mutex, or OnceLock".to_string(),
                );
            }
        }
        if word_occurrences(code, "unsafe").is_empty() {
            continue;
        }
        if has_safety_comment(ctx.scan, i) {
            continue;
        }
        push(
            findings,
            ctx,
            "PAL-UNSAFE",
            i,
            "`unsafe` without a // SAFETY: contract comment (same line or the comment \
             block directly above)"
                .to_string(),
        );
    }
}

/// `// SAFETY:` on the line itself or anywhere in the contiguous run of
/// comment-only lines directly above it. A bare `//` separator inside a
/// multi-paragraph contract stays part of the block (its code channel is
/// the blanked `//`, non-empty); a fully blank source line (both channels
/// empty) ends it.
fn has_safety_comment(scan: &FileScan, line0: usize) -> bool {
    if scan.lines[line0].comment.contains("SAFETY:") {
        return true;
    }
    let mut i = line0;
    while i > 0 {
        i -= 1;
        let l = &scan.lines[i];
        let in_block = l.code.trim().is_empty() && !(l.code.is_empty() && l.comment.is_empty());
        if !in_block {
            return false;
        }
        if l.comment.contains("SAFETY:") {
            return true;
        }
    }
    false
}

/// PAL-ENV — configuration is read at the approved sites only
/// (`parallel/` worker-count default, `failpoint.rs` registry,
/// `coordinator/` backend/dispatch switches, `primitives/lanes.rs`
/// lane-profile probe), so library behavior is a function of its
/// arguments plus those documented switches.
fn rule_env(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    if ctx.is_binary_target()
        || ctx.path_in(&["parallel/", "failpoint.rs", "coordinator/", "primitives/lanes.rs"])
    {
        return;
    }
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        if ctx.scan.in_test_region(i) {
            break;
        }
        for tok in ["env::var", "env::var_os"] {
            // `env::var` is a prefix of `env::var_os`; demand the exact
            // call form so each occurrence is reported once.
            if line.code.contains(&format!("{tok}(")) {
                push(
                    findings,
                    ctx,
                    "PAL-ENV",
                    i,
                    format!(
                        "{tok} outside the approved config sites (parallel/, failpoint.rs, \
                         coordinator/, primitives/lanes.rs): thread configuration through \
                         Context instead"
                    ),
                );
            }
        }
    }
}

/// Entry-point names PAL-QUAR audits (and accepts as delegation
/// targets — `infer` bodies that call `predict_proba` are covered by
/// the callee's quarantine).
const QUAR_ENTRY_FNS: [&str; 8] = [
    "train",
    "train_with_engine",
    "infer",
    "predict",
    "predict_proba",
    "kneighbors",
    "decision_function",
    "transform",
];

/// PAL-QUAR — the PR 6 fault contract: pool fan-outs reachable from a
/// public algorithm entry point surface panics as
/// `Error::Internal(site)` because the entry body runs under
/// `parallel::quarantine`. Statically proving reachability is beyond a
/// lexer, so the rule checks the contract at its boundary: in
/// `algorithms/`, every `pub fn` named like an entry point must call
/// `quarantine(` in its brace-matched body, or delegate to another
/// entry-point name. The debug-build merge-order auditor and the chaos
/// suite cover the gap between this approximation and true
/// reachability.
fn rule_quar(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    if !ctx.rel_path.starts_with("algorithms/") {
        return;
    }
    let joined: Vec<&str> = ctx.scan.lines.iter().map(|l| l.code.as_str()).collect();
    let code = joined.join("\n");
    // Byte offset of each line start, for offset → line conversion.
    let mut line_starts = vec![0usize];
    for l in &joined {
        line_starts.push(line_starts[line_starts.len() - 1] + l.len() + 1);
    }
    let line_of = |off: usize| line_starts.partition_point(|&s| s <= off) - 1;
    for at in word_occurrences(&code, "fn") {
        if !code[..at].trim_end().ends_with("pub") {
            continue;
        }
        let line0 = line_of(at);
        if ctx.scan.in_test_region(line0) {
            continue;
        }
        let after = &code[at + 2..];
        let name: String =
            after.trim_start().chars().take_while(|&c| is_ident(c)).collect();
        if !QUAR_ENTRY_FNS.contains(&name.as_str()) {
            continue;
        }
        let Some(body) = fn_body(&code, at) else { continue };
        let quarantined = body.contains("quarantine(");
        let delegates = QUAR_ENTRY_FNS.iter().any(|e| {
            *e != name
                && word_occurrences(body, e)
                    .iter()
                    .any(|&p| body[p + e.len()..].trim_start().starts_with('('))
        });
        if !quarantined && !delegates {
            push(
                findings,
                ctx,
                "PAL-QUAR",
                line0,
                format!(
                    "pub fn {name} in algorithms/ neither runs under parallel::quarantine \
                     nor delegates to an entry point that does: panics from pool fan-outs \
                     would abort instead of surfacing as Error::Internal"
                ),
            );
        }
    }
}

/// Geometry constant names whose *literal* definition is confined to
/// `primitives/lanes.rs` — the single source of lane widths and panel
/// geometry (ISSUE 10). Everywhere else these values must derive from
/// the active [`crate::primitives::lanes::LaneProfile`].
const LANE_GEOMETRY_CONSTS: [&str; 6] = ["LANES", "NR", "KC", "TILE", "WSS_LANES", "MR"];

/// PAL-LANE — the lane-profile confinement contract: library code
/// neither hard-codes a lane-count/panel-geometry constant nor names
/// the `ONEDAL_SVE_BACKEND` switch outside `primitives/lanes.rs`. A
/// `const NR: usize = 8` that drifts out of the profile table would
/// silently pin one width while the rest of the kernel follows the
/// context's profile — exactly the two-copies drift this PR deduped.
/// (The lexer blanks string literals, so the env-token check also
/// catches a stray read reconstructed via a named constant.)
fn rule_lane(ctx: &FileCtx, findings: &mut Vec<Finding>) {
    if ctx.is_binary_target() || ctx.path_in(&["primitives/lanes.rs"]) {
        return;
    }
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        if ctx.scan.in_test_region(i) {
            break;
        }
        let code = &line.code;
        for name in LANE_GEOMETRY_CONSTS {
            for at in word_occurrences(code, name) {
                if !code[..at].trim_end().ends_with("const") {
                    continue;
                }
                // `const NAME: usize = <digits>` — a literal geometry
                // definition outside the profile table. Derived forms
                // (`= profile.nr()`, `= LaneProfile::Sve512.tile()`)
                // stay legal.
                let after = code[at + name.len()..].trim_start();
                let Some(rest) = after.strip_prefix(':') else { continue };
                let rest = rest.trim_start();
                let Some(rest) = rest.strip_prefix("usize") else { continue };
                let rest = rest.trim_start();
                let Some(rest) = rest.strip_prefix('=') else { continue };
                if rest.trim_start().starts_with(|c: char| c.is_ascii_digit()) {
                    push(
                        findings,
                        ctx,
                        "PAL-LANE",
                        i,
                        format!(
                            "literal `const {name}: usize = …` outside primitives/lanes.rs: \
                             lane counts and panel geometry derive from the active LaneProfile \
                             (lanes()/nr()/kc()/tile()/wss_lanes())"
                        ),
                    );
                }
            }
        }
        if !word_occurrences(code, "ONEDAL_SVE_BACKEND").is_empty() {
            push(
                findings,
                ctx,
                "PAL-LANE",
                i,
                "ONEDAL_SVE_BACKEND named in library code outside primitives/lanes.rs: the \
                 lane/backend switch has one approved probe (lanes::env_spec) — take the \
                 profile from the Context instead"
                    .to_string(),
            );
        }
    }
}

/// Brace-matched body of the fn whose `fn` keyword sits at `at`.
fn fn_body(code: &str, at: usize) -> Option<&str> {
    let open_rel = code[at..].find('{')?;
    let open = at + open_rel;
    let mut depth = 0usize;
    for (i, c) in code[open..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(&code[open + 1..open + i]);
                }
            }
            _ => {}
        }
    }
    None
}

// ---------------------------------------------------------------------
// Allow directives.
// ---------------------------------------------------------------------

struct Allow {
    /// 0-based line the directive sits on.
    line0: usize,
    rule: String,
    reason: String,
}

/// Parse `palint: allow(RULE, reason)` directives out of the comment
/// channel. Malformed directives become PAL-META findings immediately.
fn parse_allows(ctx: &FileCtx, findings: &mut Vec<Finding>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for (i, line) in ctx.scan.lines.iter().enumerate() {
        // A directive is a comment that *starts* with `palint:` — prose
        // that merely mentions the syntax mid-sentence is not one.
        let Some(rest) = line.comment.trim_start().strip_prefix("palint:") else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(args) =
            rest.strip_prefix("allow(").and_then(|r| r.find(')').map(|close| &r[..close]))
        else {
            push(
                findings,
                ctx,
                "PAL-META",
                i,
                "malformed palint directive: expected `palint: allow(RULE-ID, reason)`"
                    .to_string(),
            );
            continue;
        };
        let (rule, reason) = match args.split_once(',') {
            Some((r, why)) => (r.trim(), why.trim()),
            None => (args.trim(), ""),
        };
        if !RULE_IDS.contains(&rule) {
            push(
                findings,
                ctx,
                "PAL-META",
                i,
                format!("palint allow names unknown rule {rule:?}"),
            );
            continue;
        }
        if reason.is_empty() {
            push(
                findings,
                ctx,
                "PAL-META",
                i,
                format!("palint allow({rule}) has no reason: every suppression must say why"),
            );
            continue;
        }
        allows.push(Allow { line0: i, rule: rule.to_string(), reason: reason.to_string() });
    }
    allows
}

/// Apply allows: each well-formed directive suppresses exactly one
/// finding of its rule on its own line or the line directly below.
/// Directives that suppress nothing are stale and become PAL-META
/// findings themselves.
fn apply_allows(ctx: &FileCtx, mut findings: Vec<Finding>) -> Vec<Finding> {
    let mut meta = Vec::new();
    let allows = parse_allows(ctx, &mut meta);
    for allow in &allows {
        let target = findings.iter().position(|f| {
            f.rule == allow.rule && (f.line == allow.line0 + 1 || f.line == allow.line0 + 2)
        });
        match target {
            Some(idx) => {
                findings.remove(idx);
            }
            None => push(
                &mut meta,
                ctx,
                "PAL-META",
                allow.line0,
                format!(
                    "stale palint allow({}, {}): it suppresses nothing — remove it",
                    allow.rule, allow.reason
                ),
            ),
        }
    }
    findings.extend(meta);
    findings.sort_by(|a, b| (a.line, &a.rule).cmp(&(b.line, &b.rule)));
    findings
}

#[cfg(test)]
mod tests {
    use super::super::scan_file;

    fn run(path: &str, src: &str) -> Vec<super::Finding> {
        scan_file(path, src)
    }

    fn rules(findings: &[super::Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule.as_str()).collect()
    }

    // ---- PAL-ORD ----------------------------------------------------

    #[test]
    fn ord_fires_on_partial_cmp_in_library_code() {
        let src = "fn s(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let f = run("algorithms/foo.rs", src);
        assert_eq!(rules(&f), ["PAL-ORD"]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn ord_ignores_comments_strings_and_tests() {
        assert!(run("a.rs", "// the old partial_cmp sort\nfn f() {}\n").is_empty());
        assert!(run("a.rs", "fn f() -> &'static str { \"partial_cmp\" }\n").is_empty());
        let in_test =
            "fn f() {}\n#[cfg(test)]\nmod t { fn g(a: f64, b: f64) { a.partial_cmp(&b); } }\n";
        assert!(run("a.rs", in_test).is_empty());
        assert!(run("main.rs", "fn f(a: f64, b: f64) { a.partial_cmp(&b); }\n").is_empty());
    }

    #[test]
    fn ord_allow_suppresses_exactly_one() {
        let src = "\
// palint: allow(PAL-ORD, ordering a non-float key type)
fn f(a: K, b: K) { a.partial_cmp(&b); }
fn g(a: K, b: K) { a.partial_cmp(&b); }
";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["PAL-ORD"]);
        assert_eq!(f[0].line, 3, "the un-allowed second hit must survive");
    }

    // ---- PAL-CLOCK --------------------------------------------------

    #[test]
    fn clock_fires_outside_approved_files() {
        let f = run("algorithms/foo.rs", "fn f() { let t = std::time::Instant::now(); }\n");
        assert_eq!(rules(&f), ["PAL-CLOCK"]);
        let f = run("vsl/moments.rs", "fn f() { let t = SystemTime::now(); }\n");
        assert_eq!(rules(&f), ["PAL-CLOCK"]);
    }

    #[test]
    fn clock_approved_sites_and_tests_are_exempt() {
        let src = "fn f() { let t = Instant::now(); }\n";
        assert!(run("coordinator/budget.rs", src).is_empty());
        assert!(run("profiling/timer.rs", src).is_empty());
        assert!(run("main.rs", src).is_empty());
        assert!(run("bin/palint.rs", src).is_empty());
        let wrapped = format!("fn f() {{}}\n#[cfg(test)]\nmod t {{ {src} }}\n");
        assert!(run("pool.rs", &wrapped).is_empty());
    }

    // ---- PAL-HASH ---------------------------------------------------

    #[test]
    fn hash_fires_on_iteration_not_lookup() {
        let src = "\
struct C { rows: HashMap<usize, f64> }
impl C {
    fn sum(&self) -> f64 { self.rows.values().sum() }
    fn get(&self, k: usize) -> Option<&f64> { self.rows.get(&k) }
}
";
        let f = run("cache.rs", src);
        assert_eq!(rules(&f), ["PAL-HASH"]);
        assert_eq!(f[0].line, 3);
        assert!(f[0].message.contains("rows.values"));
    }

    #[test]
    fn hash_fires_on_for_loop_and_retain() {
        let src = "\
fn f() {
    let mut seen = HashSet::new();
    for k in &seen { use_it(k); }
    seen.retain(|k| k.is_live());
}
";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["PAL-HASH", "PAL-HASH"]);
    }

    #[test]
    fn hash_ignores_btreemap_and_unrelated_receivers() {
        let src = "\
fn f(v: Vec<u32>, m: BTreeMap<u32, u32>) {
    for x in &v { use_it(x); }
    for (k, _) in &m { use_it(k); }
    let total: u32 = v.iter().sum();
}
";
        assert!(run("x.rs", src).is_empty());
    }

    #[test]
    fn hash_binary_targets_exempt() {
        let src = "fn f() { let m = HashMap::new(); for k in &m {} }\n";
        assert!(run("main.rs", src).is_empty());
        assert_eq!(rules(&run("lib_file.rs", src)), ["PAL-HASH"]);
    }

    // ---- PAL-UNSAFE -------------------------------------------------

    #[test]
    fn unsafe_without_safety_comment_fires() {
        let f = run("x.rs", "fn f(p: *const u8) -> u8 { unsafe { *p } }\n");
        assert_eq!(rules(&f), ["PAL-UNSAFE"]);
    }

    #[test]
    fn unsafe_with_safety_block_above_is_clean() {
        let src = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: `p` is non-null and valid for reads — the caller
    // constructed it from a live reference two lines up.
    unsafe { *p }
}
";
        assert!(run("x.rs", src).is_empty());
    }

    /// A multi-paragraph SAFETY contract uses bare `//` separator lines
    /// (the pool transmute does); they must not break block contiguity.
    /// A fully blank line still does.
    #[test]
    fn unsafe_safety_block_survives_bare_comment_separators() {
        let src = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: three obligations hold:
    //
    // 1. the caller keeps `p` alive.
    unsafe { *p }
}
";
        assert!(run("x.rs", src).is_empty());
        let broken = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: stale contract, detached by the blank line below.

    unsafe { *p }
}
";
        assert_eq!(rules(&run("x.rs", broken)), ["PAL-UNSAFE"]);
    }

    #[test]
    fn unsafe_same_line_safety_is_clean_and_tests_are_not_exempt() {
        let same_line = "fn f() { unsafe { g() } } // SAFETY: g has no preconditions\n";
        assert!(run("x.rs", same_line).is_empty());
        let in_test = "fn f() {}\n#[cfg(test)]\nmod t { fn g() { unsafe { h() } } }\n";
        assert_eq!(rules(&run("x.rs", in_test)), ["PAL-UNSAFE"]);
    }

    #[test]
    fn static_mut_is_banned_even_with_safety() {
        let f = run("x.rs", "// SAFETY: single-threaded init\nstatic mut COUNTER: u32 = 0;\n");
        assert_eq!(rules(&f), ["PAL-UNSAFE"]);
        assert!(f[0].message.contains("static mut"));
    }

    #[test]
    fn unsafe_in_doc_comment_is_ignored() {
        assert!(run("x.rs", "/// this API is unsafe to misuse\nfn f() {}\n").is_empty());
        assert!(run("x.rs", "#[allow(unsafe_code)]\nmod m;\n").is_empty());
    }

    // ---- PAL-ENV ----------------------------------------------------

    #[test]
    fn env_fires_outside_approved_sites() {
        let f = run("tables/csv.rs", "fn f() { let v = std::env::var(\"X\"); }\n");
        assert_eq!(rules(&f), ["PAL-ENV"]);
        let f = run("x.rs", "fn f() { let v = std::env::var_os(\"X\"); }\n");
        assert_eq!(rules(&f), ["PAL-ENV"]);
    }

    #[test]
    fn env_approved_sites_are_exempt() {
        let src = "fn f() { let v = std::env::var(\"ONEDAL_SVE_THREADS\"); }\n";
        assert!(run("parallel/mod.rs", src).is_empty());
        assert!(run("failpoint.rs", src).is_empty());
        assert!(run("coordinator/mod.rs", src).is_empty());
        assert!(run("primitives/lanes.rs", src).is_empty());
        assert!(run("main.rs", src).is_empty());
    }

    // ---- PAL-LANE ---------------------------------------------------

    #[test]
    fn lane_fires_on_literal_geometry_const_outside_lanes() {
        for decl in [
            "pub const LANES: usize = 8;",
            "const NR: usize = 8;",
            "pub(crate) const KC: usize = 256;",
            "const TILE: usize = 256;",
            "const WSS_LANES: usize = 16;",
            "pub const MR: usize = 4;",
        ] {
            let src = format!("{decl}\nfn f() {{}}\n");
            let f = run("blas/level3.rs", &src);
            assert_eq!(rules(&f), ["PAL-LANE"], "decl: {decl}");
            assert_eq!(f[0].line, 1);
        }
    }

    #[test]
    fn lane_derived_consts_and_other_names_are_clean() {
        // Derived from the profile table — the sanctioned form.
        let derived = "const TILE: usize = LaneProfile::Sve512.tile();\nfn f() {}\n";
        assert!(run("primitives/distances.rs", derived).is_empty());
        // Unlisted names and non-usize types don't match.
        assert!(run("x.rs", "const LANES_DOC: usize = 8;\nfn f() {}\n").is_empty());
        assert!(run("x.rs", "const TILER: usize = 3;\nfn f() {}\n").is_empty());
        assert!(run("x.rs", "const KC: u32 = 256;\nfn f() {}\n").is_empty());
        // const-generic params are not definitions.
        let generic = "fn k<T, const NR: usize>(a: &[T]) {}\n";
        assert!(run("blas/level3.rs", generic).is_empty());
    }

    #[test]
    fn lane_exempts_lanes_rs_binaries_and_tests() {
        let src = "pub const LANES: usize = 8;\nfn f() {}\n";
        assert!(run("primitives/lanes.rs", src).is_empty());
        assert!(run("main.rs", src).is_empty());
        assert!(run("bin/bench.rs", src).is_empty());
        let in_test = "fn f() {}\n#[cfg(test)]\nmod t { const TILE: usize = 64; }\n";
        assert!(run("x.rs", in_test).is_empty());
    }

    #[test]
    fn lane_fires_on_env_token_in_code_channel_only() {
        let code = "fn f() { let v = read(ONEDAL_SVE_BACKEND); }\n";
        let f = run("primitives/distances.rs", code);
        assert_eq!(rules(&f), ["PAL-LANE"]);
        // Comments and (lexer-blanked) string literals are not findings.
        assert!(run("x.rs", "// the ONEDAL_SVE_BACKEND switch\nfn f() {}\n").is_empty());
        assert!(run(
            "coordinator/mod.rs",
            "fn f() -> &'static str { \"ONEDAL_SVE_BACKEND\" }\n"
        )
        .is_empty());
    }

    #[test]
    fn lane_allow_suppresses_one_finding() {
        let src = "\
// palint: allow(PAL-LANE, ablation scaffold pins the legacy width)
const TILE: usize = 256;
fn f() {}
";
        assert!(run("profiling/ablate.rs", src).is_empty());
    }

    // ---- PAL-QUAR ---------------------------------------------------

    #[test]
    fn quar_fires_on_bare_entry_point() {
        let src = "\
impl M {
    pub fn train(&self, x: &T) -> Result<Model> {
        heavy_compute(x)
    }
}
";
        let f = run("algorithms/foo.rs", src);
        assert_eq!(rules(&f), ["PAL-QUAR"]);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn quar_quarantined_and_delegating_bodies_are_clean() {
        let direct = "\
impl M {
    pub fn train(&self, x: &T) -> Result<Model> {
        crate::parallel::quarantine(\"m.train\", || heavy_compute(x))
    }
}
";
        assert!(run("algorithms/foo.rs", direct).is_empty());
        let delegating = "\
impl M {
    pub fn infer(&self, x: &T) -> Result<Vec<f64>> {
        let p = self.predict_proba(x)?;
        Ok(argmax_rows(&p))
    }
}
";
        assert!(run("algorithms/foo.rs", delegating).is_empty());
    }

    #[test]
    fn quar_only_applies_to_algorithms_entry_names() {
        let src = "pub fn train(&self) -> Result<M> { compute() }\n";
        assert!(run("blas/level3.rs", src).is_empty(), "outside algorithms/");
        let other = "impl M { pub fn helper(&self) { fan_out() } }\n";
        assert!(run("algorithms/foo.rs", other).is_empty(), "not an entry-point name");
    }

    // ---- allow directives / PAL-META --------------------------------

    #[test]
    fn allow_without_reason_is_meta() {
        let src = "// palint: allow(PAL-ORD)\nfn f(a: f64, b: f64) { a.partial_cmp(&b); }\n";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["PAL-META", "PAL-ORD"], "reason-less allow suppresses nothing");
    }

    #[test]
    fn allow_with_unknown_rule_is_meta() {
        let f = run("x.rs", "// palint: allow(PAL-NOPE, because)\nfn f() {}\n");
        assert_eq!(rules(&f), ["PAL-META"]);
    }

    #[test]
    fn stale_allow_is_meta() {
        let f = run("x.rs", "// palint: allow(PAL-CLOCK, leftover from a refactor)\nfn f() {}\n");
        assert_eq!(rules(&f), ["PAL-META"]);
        assert!(f[0].message.contains("stale"));
    }

    #[test]
    fn malformed_directive_is_meta() {
        let f = run("x.rs", "// palint: allow PAL-ORD please\nfn f() {}\n");
        assert_eq!(rules(&f), ["PAL-META"]);
    }

    #[test]
    fn same_line_allow_works() {
        let src =
            "fn f() { let t = Instant::now(); } // palint: allow(PAL-CLOCK, bench scaffolding)\n";
        assert!(run("x.rs", src).is_empty());
    }

    #[test]
    fn one_allow_one_suppression_two_hits_on_one_line() {
        let src = "\
// palint: allow(PAL-CLOCK, first read is licensed)
fn f() { let a = Instant::now(); let b = SystemTime::now(); }
";
        let f = run("x.rs", src);
        assert_eq!(rules(&f), ["PAL-CLOCK"], "the second hit on the line must survive");
    }
}
