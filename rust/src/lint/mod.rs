//! `palint` — the in-repo determinism & fault-contract static
//! analyzer.
//!
//! The crate's differentiators are invariants, not features:
//! bit-identical parallel results at any worker count, NaN degrading
//! under IEEE `total_cmp`, unbudgeted runs that never read the clock,
//! panics surfacing as `Error::Internal` instead of aborting. Prose
//! and property tests cannot see a *new* violation introduced in an
//! untested path; this module makes the contracts machine-checked on
//! every push. docs/INVARIANTS.md is the catalogue: each contract,
//! its PAL rule ID, the enforcing mechanism, and the escape hatch.
//!
//! Layout: [`lexer`] performs the comment/string-aware scan (rules
//! never fire on tokens inside comments or string literals), [`rules`]
//! implements the PAL-* rule set and the `palint: allow` suppression
//! grammar (mentioned here mid-sentence on purpose — a directive must
//! *start* its comment), and [`json`] is the `--json` report format.
//! The `palint` binary (`src/bin/palint.rs`) is a thin CLI over
//! [`scan_tree`]; the same entry points run in-process in this
//! module's tests, so `cargo test` keeps the tree palint-clean even
//! where the CI gate is not wired.
//!
//! The static pass is deliberately an approximation (a lexer, not a
//! type checker); the runtime merge-order auditor in
//! `crate::parallel::audit` backstops the gap on every debug-build
//! test run.

pub mod json;
pub mod lexer;
pub mod rules;

pub use rules::{Finding, RULE_DESCRIPTIONS, RULE_IDS};

use std::io;
use std::path::{Path, PathBuf};

/// Scan one file's source. `rel_path` is the path relative to the
/// scanned root with forward slashes — rule scoping (`coordinator/`,
/// `bin/`, `main.rs`, …) matches against it.
pub fn scan_file(rel_path: &str, source: &str) -> Vec<Finding> {
    let scan = lexer::scan(source);
    rules::check_file(&rules::FileCtx { rel_path, scan: &scan })
}

/// Walk `root` (the crate's `src/` directory), scan every `.rs` file,
/// and return all findings. The walk is sorted at every level, so the
/// report order is a pure function of the tree — same contract the
/// library holds for its own output.
pub fn scan_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let source = std::fs::read_to_string(path)?;
        let rel = rel_path(root, path);
        findings.extend(scan_file(&rel, &source));
    }
    Ok(findings)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `root`-relative path with forward slashes regardless of platform.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    parts.join("/")
}

/// Render findings for humans: `path:line: RULE message`.
pub fn render_human(findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{}: {} {}\n", f.path, f.line, f.rule, f.message));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance contract: palint reports zero findings on its
    /// own tree. Runs under plain `cargo test` (cwd is `rust/`), so a
    /// regression fails locally before the CI gate sees it.
    #[test]
    fn repo_tree_is_clean() {
        let root = Path::new("src");
        assert!(root.is_dir(), "expected to run from the crate root (rust/)");
        let findings = scan_tree(root).expect("scan_tree failed");
        assert!(
            findings.is_empty(),
            "palint found contract violations:\n{}",
            render_human(&findings)
        );
    }

    /// ISSUE 10 regression on the raw bytes: PAL-LANE cannot see the
    /// quoted env name (the lexer blanks string literals), so this
    /// asserts directly that the one `env::var("ONEDAL_SVE_BACKEND")`
    /// read in the library lives in `primitives/lanes.rs` — the single
    /// approved lane-profile/backend probe.
    #[test]
    fn sve_backend_env_read_confined_to_lanes_probe() {
        let root = Path::new("src");
        let mut files = Vec::new();
        collect_rs_files(root, &mut files).expect("walk src/");
        files.sort();
        let mut readers = Vec::new();
        for path in &files {
            let source = std::fs::read_to_string(path).expect("read source");
            if source.contains("env::var(\"ONEDAL_SVE_BACKEND\"") {
                readers.push(rel_path(root, path));
            }
        }
        assert_eq!(
            readers,
            ["primitives/lanes.rs"],
            "ONEDAL_SVE_BACKEND must be read only by lanes::env_spec"
        );
    }

    #[test]
    fn tree_walk_is_deterministic() {
        let root = Path::new("src");
        let a = scan_tree(root).expect("scan");
        let b = scan_tree(root).expect("scan");
        assert_eq!(a, b);
    }

    #[test]
    fn human_rendering_format() {
        let f = scan_file("algorithms/x.rs", "fn f(a: f64, b: f64) { a.partial_cmp(&b); }\n");
        let text = render_human(&f);
        assert!(text.starts_with("algorithms/x.rs:1: PAL-ORD "), "got: {text}");
    }

    #[test]
    fn json_report_of_live_scan_round_trips() {
        let findings = scan_file(
            "algorithms/x.rs",
            "fn f() { let t = Instant::now(); }\nfn g(m: HashMap<u8, u8>) { m.iter(); }\n",
        );
        assert_eq!(findings.len(), 2);
        let report = json::emit(&findings);
        let parsed = json::parse(&report).expect("parse");
        let recovered = json::findings_from_value(&parsed).expect("schema");
        assert_eq!(recovered, findings);
    }
}
