//! # onedal-sve
//!
//! A Rust + JAX + Pallas reproduction of *"oneDAL Optimization for ARM
//! Scalable Vector Extension: Maximizing Efficiency for High-Performance
//! Data Science"* (CS.DC 2025, Fujitsu Research).
//!
//! The crate rebuilds the paper's whole stack on a three-layer
//! architecture:
//!
//! * **Layer 3 (this crate)** — the data-analytics library itself: tables,
//!   the CPU-dispatch ladder (the paper's NEON/SVE dynamic dispatch),
//!   every substrate oneDAL took from MKL (Sparse BLAS, VSL statistics,
//!   RNG engines, and a packed-panel multithreaded dense BLAS in
//!   [`blas`]/[`parallel`] playing the OpenBLAS role) and the ML
//!   algorithms the paper benchmarks. All parallel kernels execute on
//!   the **persistent worker pool** ([`parallel::WorkerPool`]): parked
//!   resident threads fed batch jobs per call, so small/medium launches
//!   skip thread start-up cost, and partitioning stays panel-aligned so
//!   every result is bit-identical at any worker count. Worker counts
//!   flow from [`coordinator::Context::threads`] into every `*_threads`
//!   entry point — `gemm`/`syrk` (KC-blocked packed panels), `gemv`,
//!   `csrmm` (both `op` variants), `csrmv`, the VSL kernels and the
//!   algorithm hot paths; context-free callers get the
//!   [`parallel::default_threads`] process default
//!   (`ONEDAL_SVE_THREADS` overrides it). Scaled-output BLAS kernels
//!   honor the reference β == 0 contract: the output is overwritten,
//!   never read. Distance-based algorithms (k-means assignment, KNN,
//!   DBSCAN, the SVM RBF gram) all share the fused pairwise
//!   squared-distance engine in [`primitives::distances`]: corpus
//!   packed once per call, pooled norm reduction, query tiles streamed
//!   through the pool with fused predicated epilogues. Algorithm entry
//!   points ingest either table layout through
//!   [`tables::TableRef`] — CSR inputs run the engine's sparse query
//!   path and the threaded CSR kernels end to end (§IV-B), and every
//!   library comparator sorts under the IEEE `total_cmp` total order
//!   so NaN features degrade deterministically instead of panicking.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   hot paths, AOT-lowered once to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing
//!   the paper's SVE-predicated loops as masked tile reductions.
//!
//! Python never runs at request time: `runtime` loads the pre-built HLO
//! artifacts through the PJRT C API (`xla` crate) and executes them from
//! Rust. The PJRT path is gated behind the off-by-default `runtime-xla`
//! cargo feature; the default build is pure Rust and the artifact rung
//! degrades gracefully to the vectorized rung.
//!
//! ## Quickstart
//!
//! ```no_run
//! use onedal_sve::prelude::*;
//!
//! let ctx = Context::builder().backend(Backend::Auto).build().unwrap();
//! let (x, _y) = onedal_sve::tables::synth::make_blobs(&mut Mt19937::new(42), 1000, 8, 4, 1.0);
//! let model = KMeans::params().k(4).max_iter(50).train(&ctx, &x).unwrap();
//! let labels = model.infer(&ctx, &x).unwrap();
//! assert_eq!(labels.len(), 1000);
//! ```

pub mod algorithms;
pub mod blas;
pub mod coordinator;
pub mod dtype;
pub mod error;
pub mod linalg;
pub mod metrics;
pub mod parallel;
pub mod primitives;
pub mod profiling;
pub mod rng;
pub mod runtime;
pub mod sparse;
pub mod tables;
pub mod vsl;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::algorithms::covariance::Covariance;
    pub use crate::algorithms::dbscan::Dbscan;
    pub use crate::algorithms::forest::RandomForestClassifier;
    pub use crate::algorithms::kmeans::KMeans;
    pub use crate::algorithms::knn::KnnClassifier;
    pub use crate::algorithms::linreg::{LinearRegression, RidgeRegression};
    pub use crate::algorithms::logreg::LogisticRegression;
    pub use crate::algorithms::pca::Pca;
    pub use crate::algorithms::svm::{Svc, SvmSolver};
    pub use crate::coordinator::{Backend, Context};
    pub use crate::error::{Error, Result};
    pub use crate::rng::{Engine, Mcg59, Mt19937};
    pub use crate::sparse::CsrMatrix;
    pub use crate::tables::{DenseTable, Table, TableRef};
}
