//! # onedal-sve
//!
//! A Rust + JAX + Pallas reproduction of *"oneDAL Optimization for ARM
//! Scalable Vector Extension: Maximizing Efficiency for High-Performance
//! Data Science"* (CS.DC 2025, Fujitsu Research).
//!
//! The crate rebuilds the paper's whole stack on a three-layer
//! architecture:
//!
//! * **Layer 3 (this crate)** — the data-analytics library itself: tables,
//!   the CPU-dispatch ladder (the paper's NEON/SVE dynamic dispatch),
//!   every substrate oneDAL took from MKL (Sparse BLAS, VSL statistics,
//!   RNG engines, and a packed-panel multithreaded dense BLAS in
//!   [`blas`]/[`parallel`] playing the OpenBLAS role) and the ML
//!   algorithms the paper benchmarks. All parallel kernels execute on
//!   the **persistent worker pool** ([`parallel::WorkerPool`]): parked
//!   resident threads fed batch jobs per call, so small/medium launches
//!   skip thread start-up cost, and partitioning stays panel-aligned so
//!   every result is bit-identical at any worker count. Worker counts
//!   flow from [`coordinator::Context::threads`] into every `*_threads`
//!   entry point — `gemm`/`syrk` (KC-blocked packed panels), `gemv`,
//!   `csrmm` (both `op` variants), `csrmv`, the VSL kernels and the
//!   algorithm hot paths; context-free callers get the
//!   [`parallel::default_threads`] process default
//!   (`ONEDAL_SVE_THREADS` overrides it). Scaled-output BLAS kernels
//!   honor the reference β == 0 contract: the output is overwritten,
//!   never read. Distance-based algorithms (k-means assignment, KNN,
//!   DBSCAN, the SVM RBF gram) all share the fused pairwise
//!   squared-distance engine in [`primitives::distances`]: corpus
//!   packed once per call, pooled norm reduction, query tiles streamed
//!   through the pool with fused predicated epilogues. Algorithm entry
//!   points ingest either table layout through
//!   [`tables::TableRef`] — CSR inputs run the engine's sparse query
//!   path and the threaded CSR kernels end to end (§IV-B), and every
//!   library comparator sorts under the IEEE `total_cmp` total order
//!   so NaN features degrade deterministically instead of panicking.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   hot paths, AOT-lowered once to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing
//!   the paper's SVE-predicated loops as masked tile reductions.
//!
//! Python never runs at request time: `runtime` loads the pre-built HLO
//! artifacts through the PJRT C API (`xla` crate) and executes them from
//! Rust. The PJRT path is gated behind the off-by-default `runtime-xla`
//! cargo feature; the default build is pure Rust and the artifact rung
//! degrades gracefully to the vectorized rung.
//!
//! ## Error handling and fault contract
//!
//! The crate's robustness floor (the prerequisite for serving traffic):
//! invalid input and internal faults surface as typed
//! [`error::Error`]s and partial results, never aborts.
//!
//! * **Validated boundaries** — every public `train`/`infer`/`predict`
//!   runs the shared [`validate`] checks (empty table, zero features,
//!   label-length mismatch, non-finite hyperparameters, `k ≤ n`) before
//!   touching a kernel, returning [`error::Error::Shape`] /
//!   [`error::Error::Param`] with actionable messages. Deep kernel
//!   asserts are therefore unreachable from the public API.
//! * **Panic quarantine** — algorithm bodies run under
//!   [`parallel::quarantine`]: a panic escaping any internal kernel
//!   (including a worker-pool job) is converted into
//!   [`error::Error::Internal`] carrying the fan-out site and the
//!   payload message. The worker pool reaps and respawns any worker a
//!   panic kills, so the process stays at full width.
//! * **Deadline budgets** — a [`coordinator::Budget`] (max wall-time
//!   and/or max outer iterations) on the [`coordinator::Context`] is
//!   checked deterministically at outer-iteration boundaries of the
//!   iterative solvers (Lloyd rounds, logreg epochs, SVM generations,
//!   Jacobi sweeps). On expiry training returns the best-so-far model
//!   tagged with [`coordinator::ConvergenceStatus::DeadlineExceeded`]
//!   (or `IterLimit`) instead of erroring; an unlimited budget — the
//!   default — is bit-identical to the pre-budget behavior.
//! * **Deterministic fault injection** —
//!   `ONEDAL_SVE_FAILPOINT=site[:mode][:payload]` (see [`failpoint`])
//!   arms a named failpoint: mode `nth` (fire once on the nth visit,
//!   the default), `every:k` (periodic, stays armed), or `times:n`
//!   (first n visits); payload `panic` (default) or `error` (a typed
//!   [`error::Error::Internal`] through [`failpoint::check_result`]).
//!   The chaos suite (`tests/chaos.rs`) proves every site yields
//!   `Error::Internal`, the pool recovers, and a retried call is
//!   bit-identical to an uninjected run. Disarmed cost: one relaxed
//!   atomic load per site visit.
//! * **Resilient serving** — [`coordinator::resilience`] wraps the
//!   serving session with admission control (bounded queue, typed
//!   shed), deterministic retry of quarantined faults, a per-model
//!   circuit breaker (count/budget-driven, never wall-clock), and a
//!   graceful-degradation rung ladder (packed → per-call pack → naive
//!   → fast-reject), with every hop counted in
//!   [`coordinator::ResilienceStats`] (`docs/RESILIENCE.md`).
//!
//! ## Model-resident packing and batched serving
//!
//! Fitted models own their packed compute state: `train` builds a
//! [`primitives::packed::ModelPanel`] (prepacked dense micro-panels or
//! a transposed CSR view, plus pooled norms) once, and every inference
//! entry point — `infer`, `predict`, `kneighbors`,
//! `decision_function` — reuses it, so the per-call pack/norm work of
//! the fused distance engine disappears from the serving hot path
//! (asserted by a pack-event counter, `tests/serve_property.rs`). On
//! top sits [`coordinator::serve`]: an
//! [`coordinator::InferenceSession`] coalesces many small query
//! batches into tile-aligned super-batches (the [`coordinator::batch`]
//! pad-and-mask idiom), runs them under per-request
//! [`coordinator::Budget`] deadlines with typed outcomes (checked
//! cooperatively at every execution tile, dense and CSR), and demuxes
//! results in submission order — deterministically: same request set,
//! same super-batch cuts, bit-identical per-request outputs at any
//! worker count (`docs/SERVING.md`). The queued front end
//! ([`coordinator::QueuedSession`]) adds bounded-capacity admission
//! with typed `Overloaded` shedding and `Cancelled` shutdown drains.
//!
//! ## Lane-profile kernel layer
//!
//! Every predicated kernel (argmin / top-k / ε-threshold / RBF
//! epilogues, the SVM WSS scans) is written once over a const-generic
//! lane count and monomorphized at the three SVE vector lengths —
//! 128/256/512-bit — with the active
//! [`primitives::lanes::LaneProfile`] resolved exactly once at
//! [`coordinator::Context`] build time (builder override, else the
//! `ONEDAL_SVE_BACKEND` profile token, else `sve512`). All derived
//! geometry — the GEMM `MR × NR` microkernel and `KC` blocking, the
//! distance-engine `TILE`, the WSS scan width — comes from the same
//! profile, so the whole stack widens together; packed buffers record
//! their packing profile and consumers derive the sweep width from the
//! data. Within a profile results are bit-identical at any worker
//! count; the default `sve512` is bit-identical to the pre-profile
//! library; across profiles discrete outputs are identical and
//! accumulated floats agree to documented tolerance. `docs/KERNELS.md`
//! is the design note; `tests/lanes_property.rs` and the
//! three-profile CI matrix enforce the contract.
//!
//! ## Machine-checked invariants
//!
//! The contracts above are enforced mechanically, not by convention —
//! `docs/INVARIANTS.md` is the catalogue (each contract, its PAL rule
//! ID, the enforcing mechanism, the escape hatch). The [`lint`] module
//! and its `palint` binary statically check every source file on every
//! push (no `partial_cmp`, no clock reads outside the budget meter, no
//! `HashMap` iteration, `SAFETY`-documented `unsafe` only in
//! [`parallel::pool`], `env::var` only at approved sites, quarantined
//! entry points), and the debug-build [`parallel::audit::MergeAuditor`]
//! asserts fixed-order merging on every scheduler drain at test time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use onedal_sve::prelude::*;
//!
//! let ctx = Context::builder().backend(Backend::Auto).build().unwrap();
//! let (x, _y) = onedal_sve::tables::synth::make_blobs(&mut Mt19937::new(42), 1000, 8, 4, 1.0);
//! let model = KMeans::params().k(4).max_iter(50).train(&ctx, &x).unwrap();
//! let labels = model.infer(&ctx, &x).unwrap();
//! assert_eq!(labels.len(), 1000);
//! ```

// House policy (PAL-UNSAFE, docs/INVARIANTS.md): unsafe code is denied
// crate-wide; `parallel::pool` alone carries a scoped, justified allow
// for its one job-lifetime transmute. `forbid` would be preferable but
// cannot be overridden by a scoped allow (E0453), so `deny` is the
// tightest expressible spelling. Within that one licensed module,
// every unsafe operation still needs its own explicit `unsafe` block.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod algorithms;
pub mod blas;
pub mod coordinator;
pub mod dtype;
pub mod error;
pub mod failpoint;
pub mod linalg;
pub mod lint;
pub mod metrics;
pub mod parallel;
pub mod primitives;
pub mod profiling;
pub mod rng;
pub mod runtime;
pub mod sparse;
pub mod tables;
pub mod validate;
pub mod vsl;

/// Convenience re-exports covering the common public API surface.
pub mod prelude {
    pub use crate::algorithms::covariance::Covariance;
    pub use crate::algorithms::dbscan::Dbscan;
    pub use crate::algorithms::forest::RandomForestClassifier;
    pub use crate::algorithms::kmeans::KMeans;
    pub use crate::algorithms::knn::KnnClassifier;
    pub use crate::algorithms::linreg::{LinearRegression, RidgeRegression};
    pub use crate::algorithms::logreg::LogisticRegression;
    pub use crate::algorithms::pca::Pca;
    pub use crate::algorithms::svm::{Svc, SvmSolver};
    pub use crate::coordinator::{
        Backend, BreakerPolicy, Budget, Context, ConvergenceStatus, InferenceSession, QueueStats,
        QueuedSession, ResilienceStats, ResilientSession, RetryPolicy, ServeExecutor, ServeModel,
        ServeRequest, ServeResult, ServeRung, ServeStatus,
    };
    pub use crate::error::{Error, Result};
    pub use crate::rng::{Engine, Mcg59, Mt19937};
    pub use crate::sparse::CsrMatrix;
    pub use crate::tables::{DenseTable, Table, TableRef};
}
