//! Cyclic Jacobi eigensolver for symmetric matrices (LAPACK `syev`
//! slice) — the decomposition behind PCA's correlation/covariance method.

use crate::coordinator::{BudgetMeter, ConvergenceStatus};
use crate::dtype::Float;
use crate::error::{Error, Result};

/// Eigen-decomposition of a symmetric row-major `n×n` matrix.
///
/// Returns `(eigenvalues, eigenvectors)` sorted by **descending**
/// eigenvalue (PCA order); eigenvectors are rows of the returned matrix.
pub fn jacobi_eigen<T: Float>(a_in: &[T], n: usize) -> Result<(Vec<T>, Vec<T>)> {
    let mut meter = BudgetMeter::unlimited();
    jacobi_eigen_budgeted(a_in, n, &mut meter).map(|(vals, vecs, _)| (vals, vecs))
}

/// [`jacobi_eigen`] under a training budget: the meter is consulted
/// once per sweep, and on expiry the current (partially diagonalized)
/// iterate is extracted and tagged — PCA's graceful-degradation path.
/// The returned status is `Converged` when the off-diagonal norm met
/// the tolerance, `IterLimit` when the sweep cap (internal or budget)
/// ran out first, `DeadlineExceeded` on wall-time expiry.
pub fn jacobi_eigen_budgeted<T: Float>(
    a_in: &[T],
    n: usize,
    meter: &mut BudgetMeter,
) -> Result<(Vec<T>, Vec<T>, ConvergenceStatus)> {
    if a_in.len() != n * n {
        return Err(Error::Shape(format!("jacobi: buffer {} != {n}x{n}", a_in.len())));
    }
    let mut a = a_in.to_vec();
    // V starts as identity; accumulates rotations (columns are eigenvectors).
    let mut v = vec![T::ZERO; n * n];
    for i in 0..n {
        v[i * n + i] = T::ONE;
    }
    let max_sweeps = 64;
    let tol = T::EPSILON.sqrt() * T::from_f64(1e-4);
    let mut status = ConvergenceStatus::IterLimit;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = T::ZERO;
        for i in 0..n {
            for j in i + 1..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() <= tol {
            status = ConvergenceStatus::Converged;
            break;
        }
        if let Some(expired) = meter.check_before_iter() {
            // Budget spent: extract the partially diagonalized iterate.
            status = expired;
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = a[p * n + q];
                if apq.abs() <= T::EPSILON {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                // Rotation angle: tan(2θ) = 2a_pq / (a_pp − a_qq).
                let theta = (aqq - app) / (T::TWO * apq);
                let t = {
                    let sign = if theta >= T::ZERO { T::ONE } else { -T::ONE };
                    sign / (theta.abs() + (T::ONE + theta * theta).sqrt())
                };
                let c = T::ONE / (T::ONE + t * t).sqrt();
                let s = t * c;
                // Apply rotation to rows/cols p and q.
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                for k in 0..n {
                    let vkp = v[k * n + p];
                    let vkq = v[k * n + q];
                    v[k * n + p] = c * vkp - s * vkq;
                    v[k * n + q] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Extract eigenpairs and sort descending. `total_cmp` keeps the
    // ordering total when the input carried NaNs — the eigensolve
    // degrades to deterministically-placed NaN eigenpairs instead of
    // panicking in the sort (the sweep loop itself is bounded by
    // `max_sweeps`, so NaN never spins it).
    let mut pairs: Vec<(T, usize)> = (0..n).map(|i| (a[i * n + i], i)).collect();
    pairs.sort_by(|x, y| y.0.total_cmp(x.0));
    let eigenvalues: Vec<T> = pairs.iter().map(|&(val, _)| val).collect();
    let mut eigenvectors = vec![T::ZERO; n * n];
    for (row, &(_, col)) in pairs.iter().enumerate() {
        for k in 0..n {
            eigenvectors[row * n + k] = v[k * n + col];
        }
    }
    Ok((eigenvalues, eigenvectors, status))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm, Transpose};
    use crate::rng::{Distribution, Mt19937, Uniform};

    fn random_symmetric(seed: u32, n: usize) -> Vec<f64> {
        let mut e = Mt19937::new(seed);
        let mut u = Uniform::new(-2.0, 2.0);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = u.sample(&mut e);
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_exact() {
        let a = vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0];
        let (vals, vecs) = jacobi_eigen(&a, 3).unwrap();
        assert!((vals[0] - 3.0).abs() < 1e-12);
        assert!((vals[1] - 2.0).abs() < 1e-12);
        assert!((vals[2] - 1.0).abs() < 1e-12);
        // First eigenvector is ±e0.
        assert!((vecs[0].abs() - 1.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 3 and 1.
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let (vals, _) = jacobi_eigen(&a, 2).unwrap();
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn reconstruction_and_orthonormality() {
        let n = 10;
        let a = random_symmetric(3, n);
        let (vals, vecs) = jacobi_eigen(&a, n).unwrap();
        // Vᵀ·diag(λ)·V reconstruction: rows of `vecs` are eigenvectors.
        let mut lv = vec![0.0; n * n];
        for i in 0..n {
            for k in 0..n {
                lv[i * n + k] = vals[i] * vecs[i * n + k];
            }
        }
        let mut rec = vec![0.0; n * n];
        gemm(Transpose::Yes, Transpose::No, n, n, n, 1.0, &vecs, &lv, 0.0, &mut rec);
        for (u, v) in a.iter().zip(&rec) {
            assert!((u - v).abs() < 1e-8);
        }
        // Orthonormal rows.
        let mut gram = vec![0.0; n * n];
        gemm(Transpose::No, Transpose::Yes, n, n, n, 1.0, &vecs, &vecs, 0.0, &mut gram);
        for i in 0..n {
            for j in 0..n {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((gram[i * n + j] - expect).abs() < 1e-9);
            }
        }
    }

    /// NaN entries must not panic the eigen-sort (regression: it used
    /// `partial_cmp(..).unwrap()`) nor spin the bounded sweep loop.
    #[test]
    fn nan_matrix_terminates_without_panic() {
        let mut a = random_symmetric(7, 5);
        a[7] = f64::NAN; // (1, 2)
        a[11] = f64::NAN; // (2, 1)
        let (vals, vecs) = jacobi_eigen(&a, 5).unwrap();
        assert_eq!(vals.len(), 5);
        assert_eq!(vecs.len(), 25);
        // Deterministic degradation: same bits on a second run.
        let (vals2, _) = jacobi_eigen(&a, 5).unwrap();
        for (u, v) in vals.iter().zip(&vals2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let a = random_symmetric(4, 8);
        let (vals, _) = jacobi_eigen(&a, 8).unwrap();
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    /// A sweep-capped budget returns the partially diagonalized iterate
    /// tagged `IterLimit`; an unlimited meter reproduces `jacobi_eigen`
    /// bit for bit.
    #[test]
    fn budgeted_sweeps_degrade_gracefully() {
        use crate::coordinator::Budget;
        let n = 12;
        let a = random_symmetric(9, n);
        let mut capped = Budget::default().max_iters(1).meter();
        let (vals, vecs, status) = jacobi_eigen_budgeted(&a, n, &mut capped).unwrap();
        assert_eq!(status, ConvergenceStatus::IterLimit);
        assert_eq!(vals.len(), n);
        assert_eq!(vecs.len(), n * n);
        // Trace is preserved by every completed sweep, so the partial
        // iterate is still a usable spectrum estimate.
        let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
        assert!((vals.iter().sum::<f64>() - trace).abs() < 1e-9);
        let mut unlimited = BudgetMeter::unlimited();
        let (v1, e1, status) = jacobi_eigen_budgeted(&a, n, &mut unlimited).unwrap();
        assert_eq!(status, ConvergenceStatus::Converged);
        let (v2, e2) = jacobi_eigen(&a, n).unwrap();
        for (u, v) in v1.iter().zip(&v2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
        for (u, v) in e1.iter().zip(&e2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn trace_preserved() {
        let n = 7;
        let a = random_symmetric(5, n);
        let trace: f64 = (0..n).map(|i| a[i * n + i]).sum();
        let (vals, _) = jacobi_eigen(&a, n).unwrap();
        assert!((vals.iter().sum::<f64>() - trace).abs() < 1e-9);
    }
}
