//! Cholesky factorization and SPD solves (LAPACK `potrf`/`potrs` slice).

use crate::dtype::Float;
use crate::error::{Error, Result};

/// Factor a symmetric positive-definite `n×n` row-major matrix as
/// `A = L·Lᵀ`; returns the lower factor `L` (row-major, upper part zero).
pub fn cholesky_factor<T: Float>(a: &[T], n: usize) -> Result<Vec<T>> {
    if a.len() != n * n {
        return Err(Error::Shape(format!("cholesky: buffer {} != {n}x{n}", a.len())));
    }
    let mut l = vec![T::ZERO; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= T::ZERO {
                    return Err(Error::Numerical(format!(
                        "cholesky: non-positive pivot {s} at {i} (matrix not SPD)"
                    )));
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve `A·x = b` for SPD `A` via Cholesky (forward + back substitution).
pub fn cholesky_solve<T: Float>(a: &[T], n: usize, b: &[T]) -> Result<Vec<T>> {
    if b.len() != n {
        return Err(Error::Shape(format!("cholesky_solve: rhs {} != {n}", b.len())));
    }
    let l = cholesky_factor(a, n)?;
    // L·y = b
    let mut y = vec![T::ZERO; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    // Lᵀ·x = y
    let mut x = vec![T::ZERO; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blas::{gemm, Transpose};
    use crate::rng::{Distribution, Mt19937, Uniform};

    /// Random SPD matrix A = MᵀM + n·I.
    fn random_spd(seed: u32, n: usize) -> Vec<f64> {
        let mut e = Mt19937::new(seed);
        let mut u = Uniform::new(-1.0, 1.0);
        let m: Vec<f64> = (0..n * n).map(|_| u.sample(&mut e)).collect();
        let mut a = vec![0.0; n * n];
        gemm(Transpose::Yes, Transpose::No, n, n, n, 1.0, &m, &m, 0.0, &mut a);
        for i in 0..n {
            a[i * n + i] += n as f64;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let n = 12;
        let a = random_spd(1, n);
        let l = cholesky_factor(&a, n).unwrap();
        let mut rec = vec![0.0; n * n];
        gemm(Transpose::No, Transpose::Yes, n, n, n, 1.0, &l, &l, 0.0, &mut rec);
        for (u, v) in a.iter().zip(&rec) {
            assert!((u - v).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_recovers_solution() {
        let n = 9;
        let a = random_spd(2, n);
        let x_true: Vec<f64> = (0..n).map(|i| i as f64 - 4.0).collect();
        let mut b = vec![0.0; n];
        crate::blas::gemv(false, n, n, 1.0, &a, &x_true, 0.0, &mut b);
        let x = cholesky_solve(&a, n, &b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-8);
        }
    }

    #[test]
    fn non_spd_rejected() {
        // Negative-definite 2x2.
        let a = vec![-1.0, 0.0, 0.0, -1.0];
        assert!(cholesky_factor(&a, 2).is_err());
    }

    #[test]
    fn identity_factor_is_identity() {
        let n = 5;
        let mut a = vec![0.0f64; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let l = cholesky_factor(&a, n).unwrap();
        assert_eq!(l, a);
    }
}
