//! Dense linear-algebra substrate (the LAPACK slice oneDAL pulls from
//! OpenBLAS/MKL): Cholesky factorization + SPD solve for the normal
//! equations of linear/ridge regression, and a Jacobi symmetric
//! eigensolver for PCA.

pub mod cholesky;
pub mod jacobi;

pub use cholesky::{cholesky_factor, cholesky_solve};
pub use jacobi::{jacobi_eigen, jacobi_eigen_budgeted};
