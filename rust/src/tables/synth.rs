//! Synthetic dataset generators standing in for the paper's benchmark
//! data (DESIGN.md §2 substitution table):
//!
//! * [`make_blobs`] / [`make_classification`] / [`make_regression`] — the
//!   scikit-learn_bench grids of Figs. 5–6;
//! * [`make_fraud`] — the Kaggle credit-card set of Fig. 9 (284 807×30,
//!   492 positives, PCA-like decorrelated features);
//! * [`make_speech_embeddings`] — the DataPerf keyword-spotting
//!   embeddings of Fig. 7 (per-"language" cluster structure);
//! * [`make_segmentation`] — the TPC-AI customer-segmentation mixture of
//!   Fig. 8;
//! * [`make_sparse_csr`] — CSR matrices with controlled density for the
//!   Sparse BLAS ablations (a9a/gisette-like SVM inputs).

// Generators construct tables from buffers whose shapes they themselves
// just sized, so the `from_vec`/`new` unwraps cannot fire; test-support
// code is exempt from the crate's no-unwrap gate.
#![allow(clippy::unwrap_used)]

use super::dense::DenseTable;
use crate::rng::{Distribution, Engine, Gaussian, Uniform, UniformInt};
use crate::sparse::CsrMatrix;

/// Isotropic Gaussian blobs: `n` points, `d` features, `k` centers.
/// Returns `(X, labels)`. Centers are drawn uniformly in `[-10, 10]^d`.
pub fn make_blobs(
    e: &mut dyn Engine,
    n: usize,
    d: usize,
    k: usize,
    std: f64,
) -> (DenseTable<f64>, Vec<usize>) {
    let mut centers = vec![0.0f64; k * d];
    let mut uc = Uniform::new(-10.0, 10.0);
    uc.fill(e, &mut centers);
    let mut g = Gaussian::new(0.0, std);
    let mut ui = UniformInt::new(0, k as u64);
    let mut x = vec![0.0f64; n * d];
    let mut labels = vec![0usize; n];
    for i in 0..n {
        let c = ui.sample(e) as usize;
        labels[i] = c;
        for j in 0..d {
            x[i * d + j] = centers[c * d + j] + g.sample(e);
        }
    }
    (DenseTable::from_vec(x, n, d).unwrap(), labels)
}

/// Two-class classification task: class-conditional Gaussians with a
/// random informative subspace (scikit-learn `make_classification`-like).
/// Returns `(X, y∈{0,1})`.
pub fn make_classification(
    e: &mut dyn Engine,
    n: usize,
    d: usize,
    sep: f64,
) -> (DenseTable<f64>, Vec<f64>) {
    // Random unit direction for class separation.
    let mut g = Gaussian::<f64>::standard();
    let mut dir = vec![0.0f64; d];
    g.fill(e, &mut dir);
    let norm = dir.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-12);
    for v in dir.iter_mut() {
        *v /= norm;
    }
    let mut x = vec![0.0f64; n * d];
    let mut y = vec![0.0f64; n];
    let mut coin = Uniform::new(0.0, 1.0);
    for i in 0..n {
        let cls = if coin.sample(e) < 0.5 { 0.0 } else { 1.0 };
        y[i] = cls;
        let shift = if cls > 0.5 { sep } else { -sep };
        for j in 0..d {
            x[i * d + j] = g.sample(e) + shift * dir[j];
        }
    }
    (DenseTable::from_vec(x, n, d).unwrap(), y)
}

/// Linear regression task `y = Xw + ε`. Returns `(X, y, w_true)`.
pub fn make_regression(
    e: &mut dyn Engine,
    n: usize,
    d: usize,
    noise: f64,
) -> (DenseTable<f64>, Vec<f64>, Vec<f64>) {
    let mut g = Gaussian::<f64>::standard();
    let mut w = vec![0.0f64; d];
    let mut uw = Uniform::new(-3.0, 3.0);
    uw.fill(e, &mut w);
    let mut x = vec![0.0f64; n * d];
    g.fill(e, &mut x);
    let mut noise_d = Gaussian::new(0.0, noise);
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let row = &x[i * d..(i + 1) * d];
        y[i] = row.iter().zip(&w).map(|(a, b)| a * b).sum::<f64>() + noise_d.sample(e);
    }
    (DenseTable::from_vec(x, n, d).unwrap(), y, w)
}

/// Credit-card-fraud-shaped dataset (Fig. 9 substitution): `n` rows,
/// `d` decorrelated features (the Kaggle set is PCA-transformed, so
/// independent Gaussians are the right analogue), `n_pos` positives drawn
/// from a shifted, heavier-tailed distribution. Returns `(X, y)`.
pub fn make_fraud(
    e: &mut dyn Engine,
    n: usize,
    d: usize,
    n_pos: usize,
) -> (DenseTable<f64>, Vec<f64>) {
    assert!(n_pos <= n);
    let mut g = Gaussian::<f64>::standard();
    let mut x = vec![0.0f64; n * d];
    g.fill(e, &mut x);
    let mut y = vec![0.0f64; n];
    // Choose positive rows without replacement.
    let pos = crate::rng::distributions::sample_indices(e, n, n_pos);
    let mut shift = Gaussian::new(1.8, 1.5);
    for &i in &pos {
        y[i] = 1.0;
        for j in 0..d {
            x[i * d + j] += shift.sample(e);
        }
    }
    (DenseTable::from_vec(x, n, d).unwrap(), y)
}

/// DataPerf-speech-shaped embeddings (Fig. 7 substitution): keyword
/// clusters + a background mass, mimicking MSWC embedding geometry.
/// Returns `(X, y)` where `y` is 1 for target-keyword rows.
pub fn make_speech_embeddings(
    e: &mut dyn Engine,
    n: usize,
    d: usize,
    n_keywords: usize,
    target_frac: f64,
) -> (DenseTable<f64>, Vec<f64>) {
    let (x_tbl, cluster) = make_blobs(e, n, d, n_keywords + 1, 2.0);
    let mut x = x_tbl;
    // Cluster 0 is diffuse background: widen it.
    let mut g = Gaussian::new(0.0, 4.0);
    let mut y = vec![0.0f64; n];
    let mut coin = Uniform::new(0.0, 1.0);
    for i in 0..n {
        if cluster[i] == 0 {
            for v in x.row_mut(i) {
                *v += g.sample(e);
            }
        } else if coin.sample(e) < target_frac {
            y[i] = 1.0;
        }
    }
    (x, y)
}

/// TPC-AI customer-segmentation mixture (Fig. 8 substitution):
/// behavioural features (order counts, spend, recency …) from a mixture
/// of `k` customer archetypes with per-feature scales. Returns `X`.
pub fn make_segmentation(e: &mut dyn Engine, n: usize, d: usize, k: usize) -> DenseTable<f64> {
    let mut centers = vec![0.0f64; k * d];
    let mut uc = Uniform::new(0.0, 100.0);
    uc.fill(e, &mut centers);
    // Per-archetype, per-feature scales: spend-like features vary more.
    let mut scales = vec![0.0f64; k * d];
    let mut us = Uniform::new(0.5, 15.0);
    us.fill(e, &mut scales);
    let mut ui = UniformInt::new(0, k as u64);
    let mut g = Gaussian::<f64>::standard();
    let mut x = vec![0.0f64; n * d];
    for i in 0..n {
        let c = ui.sample(e) as usize;
        for j in 0..d {
            x[i * d + j] = centers[c * d + j] + scales[c * d + j] * g.sample(e);
        }
    }
    DenseTable::from_vec(x, n, d).unwrap()
}

/// Random CSR matrix with the given density; values uniform in [-1, 1).
/// 1-based index arrays (the `csrmultd` convention — see §IV-B).
pub fn make_sparse_csr(
    e: &mut dyn Engine,
    rows: usize,
    cols: usize,
    density: f64,
) -> CsrMatrix<f64> {
    let mut vals = Vec::new();
    let mut col_idx = Vec::new();
    let mut row_ptr = Vec::with_capacity(rows + 1);
    row_ptr.push(1i64); // 1-based
    let mut coin = Uniform::new(0.0, 1.0);
    let mut uv = Uniform::new(-1.0, 1.0);
    for _ in 0..rows {
        for j in 0..cols {
            if coin.sample(e) < density {
                vals.push(uv.sample(e));
                col_idx.push(j as i64 + 1);
            }
        }
        row_ptr.push(vals.len() as i64 + 1);
    }
    CsrMatrix::new(rows, cols, vals, col_idx, row_ptr, crate::sparse::IndexBase::One).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Mt19937;

    #[test]
    fn blobs_shapes_and_label_range() {
        let mut e = Mt19937::new(1);
        let (x, y) = make_blobs(&mut e, 500, 6, 4, 1.0);
        assert_eq!(x.rows(), 500);
        assert_eq!(x.cols(), 6);
        assert_eq!(y.len(), 500);
        assert!(y.iter().all(|&c| c < 4));
        // every cluster occupied
        for c in 0..4 {
            assert!(y.iter().any(|&v| v == c), "cluster {c} empty");
        }
    }

    #[test]
    fn classification_separable_along_direction() {
        let mut e = Mt19937::new(2);
        let (x, y) = make_classification(&mut e, 2000, 10, 3.0);
        // Class means should differ substantially in at least one feature.
        let mut m0 = vec![0.0; 10];
        let mut m1 = vec![0.0; 10];
        let (mut n0, mut n1) = (0.0, 0.0);
        for i in 0..2000 {
            let (m, n) = if y[i] < 0.5 { (&mut m0, &mut n0) } else { (&mut m1, &mut n1) };
            *n += 1.0;
            for j in 0..10 {
                m[j] += x.get(i, j);
            }
        }
        let gap: f64 = (0..10).map(|j| (m0[j] / n0 - m1[j] / n1).powi(2)).sum::<f64>().sqrt();
        assert!(gap > 3.0, "class-mean gap {gap}");
    }

    #[test]
    fn regression_recoverable_signal() {
        let mut e = Mt19937::new(3);
        let (x, y, w) = make_regression(&mut e, 1000, 5, 0.01);
        // With tiny noise, y ≈ Xw.
        let mut err = 0.0;
        for i in 0..1000 {
            let pred: f64 = x.row(i).iter().zip(&w).map(|(a, b)| a * b).sum();
            err += (pred - y[i]).powi(2);
        }
        assert!((err / 1000.0).sqrt() < 0.05);
    }

    #[test]
    fn fraud_imbalance_exact() {
        let mut e = Mt19937::new(4);
        let (x, y) = make_fraud(&mut e, 10_000, 8, 49);
        assert_eq!(x.rows(), 10_000);
        assert_eq!(y.iter().filter(|&&v| v > 0.5).count(), 49);
    }

    #[test]
    fn speech_embeddings_have_targets() {
        let mut e = Mt19937::new(5);
        let (x, y) = make_speech_embeddings(&mut e, 3000, 16, 10, 0.3);
        assert_eq!(x.rows(), 3000);
        let pos = y.iter().filter(|&&v| v > 0.5).count();
        assert!(pos > 100 && pos < 1500, "pos={pos}");
    }

    #[test]
    fn segmentation_shape() {
        let mut e = Mt19937::new(6);
        let x = make_segmentation(&mut e, 1000, 10, 8);
        assert_eq!((x.rows(), x.cols()), (1000, 10));
    }

    #[test]
    fn sparse_csr_density_and_validity() {
        let mut e = Mt19937::new(7);
        let a = make_sparse_csr(&mut e, 200, 100, 0.05);
        let nnz = a.nnz();
        let density = nnz as f64 / (200.0 * 100.0);
        assert!((density - 0.05).abs() < 0.01, "density={density}");
        a.validate().unwrap();
    }
}
