//! Minimal CSV load/save for [`DenseTable`] — the data-source role of
//! oneDAL's `CSVFeatureManager`. Supports optional header rows, comment
//! lines and a selectable delimiter; numeric parsing only (the workloads
//! in the paper are all-numeric feature matrices).

use super::dense::DenseTable;
use crate::dtype::Float;
use crate::error::{Error, Result};
use std::io::{BufReader, Read, Write};
use std::path::Path;

/// CSV reader options.
#[derive(Clone, Debug)]
pub struct CsvOptions {
    pub delimiter: char,
    pub has_header: bool,
    /// Lines starting with this char are skipped.
    pub comment: Option<char>,
}

impl Default for CsvOptions {
    fn default() -> Self {
        Self { delimiter: ',', has_header: false, comment: Some('#') }
    }
}

/// Parse CSV text into a table.
///
/// Runs under the crate's panic quarantine: a panic escaping the parse
/// loop (fault injection via the `csv-record` failpoint, or a latent
/// bug) surfaces as [`Error::Internal`] instead of aborting the caller.
pub fn parse_csv<T: Float>(text: &str, opts: &CsvOptions) -> Result<DenseTable<T>> {
    crate::parallel::quarantine("csv.parse", || parse_csv_inner(text, opts))
}

fn parse_csv_inner<T: Float>(text: &str, opts: &CsvOptions) -> Result<DenseTable<T>> {
    let mut data: Vec<T> = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    let mut skipped_header = !opts.has_header;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(c) = opts.comment {
            if line.starts_with(c) {
                continue;
            }
        }
        if !skipped_header {
            skipped_header = true;
            continue;
        }
        crate::failpoint::check(crate::failpoint::SITE_CSV_RECORD);
        let mut count = 0usize;
        for (col, field) in line.split(opts.delimiter).enumerate() {
            let v: f64 = field.trim().trim_matches('"').parse().map_err(|_| {
                Error::Parse(format!(
                    "line {}, column {}: bad number {field:?}",
                    lineno + 1,
                    col + 1
                ))
            })?;
            data.push(T::from_f64(v));
            count += 1;
        }
        if rows == 0 {
            cols = count;
        } else if count != cols {
            return Err(Error::Parse(format!(
                "line {}: {count} fields, expected {cols}",
                lineno + 1
            )));
        }
        rows += 1;
    }
    if rows == 0 {
        return Err(Error::Parse(
            "empty input: no data rows (only blank/comment/header lines)".into(),
        ));
    }
    DenseTable::from_vec(data, rows, cols)
}

/// Load a table from a CSV file.
pub fn load_csv<T: Float, P: AsRef<Path>>(path: P, opts: &CsvOptions) -> Result<DenseTable<T>> {
    let f = std::fs::File::open(path)?;
    let mut text = String::new();
    BufReader::new(f).read_to_string(&mut text)?;
    parse_csv(&text, opts)
}

/// Save a table to a CSV file.
pub fn save_csv<T: Float, P: AsRef<Path>>(table: &DenseTable<T>, path: P) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for i in 0..table.rows() {
        let row = table.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                write!(f, ",")?;
            }
            write!(f, "{v}")?;
        }
        writeln!(f)?;
    }
    Ok(())
}

impl DenseTable<f64> {
    /// Load from CSV with default options (convenience used in examples).
    pub fn from_csv<P: AsRef<Path>>(path: P) -> Result<Self> {
        load_csv(path, &CsvOptions::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let t: DenseTable<f64> = parse_csv("1,2,3\n4,5,6\n", &CsvOptions::default()).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.get(1, 2), 6.0);
    }

    #[test]
    fn parse_header_comments_blank_lines() {
        let text = "# generated\na,b\n1.5,2.5\n\n3.5,4.5\n";
        let opts = CsvOptions { has_header: true, ..Default::default() };
        let t: DenseTable<f32> = parse_csv(text, &opts).unwrap();
        assert_eq!(t.rows(), 2);
        assert_eq!(t.row(0), &[1.5f32, 2.5]);
    }

    #[test]
    fn ragged_rows_rejected() {
        let r: Result<DenseTable<f64>> = parse_csv("1,2\n3\n", &CsvOptions::default());
        assert!(r.is_err());
    }

    #[test]
    fn bad_number_rejected() {
        let r: Result<DenseTable<f64>> = parse_csv("1,zzz\n", &CsvOptions::default());
        assert!(r.is_err());
    }

    /// Parse errors name both the 1-based line and column of the
    /// offending field — the actionable-context contract.
    #[test]
    fn bad_number_error_carries_line_and_column() {
        let r: Result<DenseTable<f64>> = parse_csv("1,2,3\n4,oops,6\n", &CsvOptions::default());
        match r {
            Err(Error::Parse(msg)) => {
                assert!(msg.contains("line 2"), "{msg}");
                assert!(msg.contains("column 2"), "{msg}");
                assert!(msg.contains("oops"), "{msg}");
            }
            other => panic!("expected Error::Parse, got {other:?}"),
        }
    }

    /// Ragged rows report the line and both field counts.
    #[test]
    fn ragged_row_error_carries_line() {
        let r: Result<DenseTable<f64>> = parse_csv("1,2,3\n4,5\n", &CsvOptions::default());
        match r {
            Err(Error::Parse(msg)) => {
                assert!(msg.contains("line 2"), "{msg}");
                assert!(msg.contains("2 fields"), "{msg}");
                assert!(msg.contains("expected 3"), "{msg}");
            }
            other => panic!("expected Error::Parse, got {other:?}"),
        }
    }

    /// Inputs with no data rows are a typed parse error, not a silent
    /// 0×0 table that algorithms would then reject with a shape error
    /// far from the real cause.
    #[test]
    fn empty_inputs_rejected() {
        for text in ["", "\n\n", "# only a comment\n"] {
            let r: Result<DenseTable<f64>> = parse_csv(text, &CsvOptions::default());
            assert!(matches!(r, Err(Error::Parse(_))), "text={text:?}");
        }
        // Header-only input has no data rows either.
        let opts = CsvOptions { has_header: true, ..Default::default() };
        let r: Result<DenseTable<f64>> = parse_csv("a,b,c\n", &opts);
        assert!(matches!(r, Err(Error::Parse(_))));
    }

    #[test]
    fn round_trip_via_file() {
        let t = DenseTable::from_vec(vec![1.0f64, -2.5, 3.25, 4.0], 2, 2).unwrap();
        let path = std::env::temp_dir().join("onedal_sve_csv_roundtrip.csv");
        save_csv(&t, &path).unwrap();
        let u = DenseTable::from_csv(&path).unwrap();
        assert_eq!(t, u);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn semicolon_delimiter() {
        let opts = CsvOptions { delimiter: ';', ..Default::default() };
        let t: DenseTable<f64> = parse_csv("1;2\n3;4\n", &opts).unwrap();
        assert_eq!(t.get(1, 0), 3.0);
    }
}
