//! The layout-polymorphic table the algorithm layer ingests — oneDAL's
//! `NumericTable` boundary. Every algorithm entry point takes
//! `impl Into<TableRef<'_>>`, so callers hand in `&DenseTable<f64>` or
//! `&CsrMatrix<f64>` directly and the ladder dispatches once, at the
//! top: dense inputs run the existing dense engines unchanged, CSR
//! inputs route through the sparse query paths
//! ([`crate::primitives::distances`] sweeps, the threaded CSR kernels
//! of [`crate::sparse`]) — and under `Backend::Naive` a CSR input is
//! densified and run through the dense naive rung, which is exactly the
//! "densified oracle" every sparse path is tested against.
//!
//! Determinism contract: each sparse path partitions work the same
//! input-keyed way as its dense sibling (tiles/rows computed whole by
//! one worker, partials merged in ascending order), so CSR results are
//! **bit-identical at any worker count**. Across layouts, cross terms
//! accumulate in the same ascending-index order as the dense engines
//! (implicit zeros are exact no-ops), but row norms come from a
//! single-accumulator sweep of the stored values rather than the 4-way
//! unrolled dense [`crate::blas::dot`], so distances agree with the
//! densified run to rounding — discrete outputs (assignments, neighbour
//! sets, labels) match the densified oracle exactly on non-degenerate
//! data, float outputs to tolerance.

use crate::sparse::CsrMatrix;
use crate::tables::DenseTable;

/// Borrowed view over either supported layout — the argument type of
/// the algorithm entry points.
#[derive(Clone, Copy, Debug)]
pub enum TableRef<'a> {
    Dense(&'a DenseTable<f64>),
    Csr(&'a CsrMatrix<f64>),
}

impl<'a> TableRef<'a> {
    pub fn rows(&self) -> usize {
        match self {
            TableRef::Dense(t) => t.rows(),
            TableRef::Csr(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            TableRef::Dense(t) => t.cols(),
            TableRef::Csr(m) => m.cols(),
        }
    }

    /// Densify: clones a dense table, scatters a CSR one — the input of
    /// the densified naive rung (and of every sparse path's oracle).
    pub fn to_dense(&self) -> DenseTable<f64> {
        match self {
            TableRef::Dense(t) => (*t).clone(),
            TableRef::Csr(m) => m.to_dense(),
        }
    }

    /// Clone the referenced data into an owned [`Table`] (named
    /// `to_table` rather than `to_owned` to keep the blanket
    /// `ToOwned` impl unshadowed).
    pub fn to_table(&self) -> Table {
        match self {
            TableRef::Dense(t) => Table::Dense((*t).clone()),
            TableRef::Csr(m) => Table::Csr((*m).clone()),
        }
    }
}

impl<'a> From<&'a DenseTable<f64>> for TableRef<'a> {
    fn from(t: &'a DenseTable<f64>) -> Self {
        TableRef::Dense(t)
    }
}

impl<'a> From<&'a CsrMatrix<f64>> for TableRef<'a> {
    fn from(m: &'a CsrMatrix<f64>) -> Self {
        TableRef::Csr(m)
    }
}

impl<'a> From<&'a Table> for TableRef<'a> {
    fn from(t: &'a Table) -> Self {
        t.view()
    }
}

/// Owned table in either layout — what lazy models (KNN) store.
#[derive(Clone, Debug)]
pub enum Table {
    Dense(DenseTable<f64>),
    Csr(CsrMatrix<f64>),
}

impl Table {
    pub fn rows(&self) -> usize {
        self.view().rows()
    }

    pub fn cols(&self) -> usize {
        self.view().cols()
    }

    /// Borrow as a [`TableRef`] (named `view` rather than `as_ref` to
    /// keep the std `AsRef` trait name free).
    pub fn view(&self) -> TableRef<'_> {
        match self {
            Table::Dense(t) => TableRef::Dense(t),
            Table::Csr(m) => TableRef::Csr(m),
        }
    }
}

impl From<DenseTable<f64>> for Table {
    fn from(t: DenseTable<f64>) -> Self {
        Table::Dense(t)
    }
}

impl From<CsrMatrix<f64>> for Table {
    fn from(m: CsrMatrix<f64>) -> Self {
        Table::Csr(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::IndexBase;

    fn sample_csr() -> CsrMatrix<f64> {
        CsrMatrix::new(2, 3, vec![1.5, -2.0], vec![0, 2], vec![0, 1, 2], IndexBase::Zero)
            .unwrap()
    }

    #[test]
    fn shapes_and_densify_agree_across_layouts() {
        let d = DenseTable::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        let s = sample_csr();
        let rd: TableRef = (&d).into();
        let rs: TableRef = (&s).into();
        assert_eq!((rd.rows(), rd.cols()), (2, 3));
        assert_eq!((rs.rows(), rs.cols()), (2, 3));
        assert_eq!(rd.to_dense(), d);
        assert_eq!(rs.to_dense(), s.to_dense());
    }

    #[test]
    fn owned_round_trip() {
        let s = sample_csr();
        let owned = TableRef::from(&s).to_table();
        assert_eq!(owned.rows(), 2);
        let r: TableRef = (&owned).into();
        assert_eq!(r.to_dense(), s.to_dense());
        let od: Table = DenseTable::<f64>::zeros(4, 2).into();
        assert_eq!((od.rows(), od.cols()), (4, 2));
    }
}
