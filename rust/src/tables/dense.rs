//! Dense row-major numeric table (oneDAL `HomogenNumericTable` analogue).

use crate::dtype::Float;
use crate::error::{Error, Result};

/// A dense, row-major `rows × cols` table of `T`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseTable<T = f64> {
    data: Vec<T>,
    rows: usize,
    cols: usize,
}

impl<T: Float> DenseTable<T> {
    /// Wrap an existing row-major buffer.
    pub fn from_vec(data: Vec<T>, rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer length {} != rows*cols = {}x{}",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Self { data, rows, cols })
    }

    /// Zero-filled table.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { data: vec![T::ZERO; rows * cols], rows, cols }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Flat row-major view.
    pub fn data(&self) -> &[T] {
        &self.data
    }

    /// Mutable flat view.
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Consume the table, yielding the row-major buffer.
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [T] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> T {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: T) {
        self.data[i * self.cols + j] = v;
    }

    /// New table holding rows `lo..hi` (copy).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Result<Self> {
        if lo > hi || hi > self.rows {
            return Err(Error::Shape(format!("row slice {lo}..{hi} out of 0..{}", self.rows)));
        }
        Ok(Self {
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
            rows: hi - lo,
            cols: self.cols,
        })
    }

    /// Gather the given rows into a new table (bootstrap sampling etc.).
    pub fn gather_rows(&self, idx: &[usize]) -> Self {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Self { data, rows: idx.len(), cols: self.cols }
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Self {
        let mut t = Self::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Per-column means.
    pub fn col_means(&self) -> Vec<T> {
        let mut m = vec![T::ZERO; self.cols];
        for i in 0..self.rows {
            for (mj, &v) in m.iter_mut().zip(self.row(i)) {
                *mj += v;
            }
        }
        let inv = T::ONE / T::from_usize(self.rows.max(1));
        for v in m.iter_mut() {
            *v *= inv;
        }
        m
    }

    /// Convert element type (e.g. f64 table → f32 artifact inputs).
    pub fn cast<U: Float>(&self) -> DenseTable<U> {
        DenseTable {
            data: self.data.iter().map(|v| U::from_f64(v.to_f64())).collect(),
            rows: self.rows,
            cols: self.cols,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_checks_shape() {
        assert!(DenseTable::from_vec(vec![1.0f64; 6], 2, 3).is_ok());
        assert!(DenseTable::from_vec(vec![1.0f64; 5], 2, 3).is_err());
    }

    #[test]
    fn rows_and_indexing() {
        let t = DenseTable::from_vec(vec![1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(t.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(t.get(0, 2), 3.0);
    }

    #[test]
    fn transpose_round_trip() {
        let t = DenseTable::from_vec((0..12).map(|i| i as f64).collect(), 3, 4).unwrap();
        assert_eq!(t.transposed().transposed(), t);
        assert_eq!(t.transposed().get(2, 1), t.get(1, 2));
    }

    #[test]
    fn gather_and_slice() {
        let t = DenseTable::from_vec((0..8).map(|i| i as f64).collect(), 4, 2).unwrap();
        let g = t.gather_rows(&[3, 0, 3]);
        assert_eq!(g.rows(), 3);
        assert_eq!(g.row(0), &[6.0, 7.0]);
        assert_eq!(g.row(2), &[6.0, 7.0]);
        let s = t.slice_rows(1, 3).unwrap();
        assert_eq!(s.row(0), &[2.0, 3.0]);
        assert!(t.slice_rows(3, 5).is_err());
    }

    #[test]
    fn col_means_simple() {
        let t = DenseTable::from_vec(vec![1.0f64, 10.0, 3.0, 20.0], 2, 2).unwrap();
        assert_eq!(t.col_means(), vec![2.0, 15.0]);
    }

    #[test]
    fn cast_f64_to_f32() {
        let t = DenseTable::from_vec(vec![1.5f64, -2.25], 1, 2).unwrap();
        let u: DenseTable<f32> = t.cast();
        assert_eq!(u.data(), &[1.5f32, -2.25]);
    }
}
