//! Numeric-table abstraction — the data-management layer of oneDAL.
//!
//! oneDAL's public API hands every algorithm a `NumericTable`; this
//! module provides the two layouts the paper's workloads use (dense
//! row-major, CSR sparse), the layout-polymorphic [`TableRef`]/[`Table`]
//! boundary the algorithm entry points ingest (`impl Into<TableRef>` —
//! pass `&DenseTable<f64>` or `&CsrMatrix<f64>` interchangeably; see
//! [`table`] for the sparse-path determinism contract), CSV I/O, and the
//! synthetic dataset generators standing in for the paper's benchmark
//! data (scikit-learn_bench grids, DataPerf speech embeddings, TPC-AI
//! segmentation, Kaggle fraud).

pub mod csv;
pub mod dense;
pub mod synth;
pub mod table;

pub use dense::DenseTable;
pub use table::{Table, TableRef};
