//! Numeric-table abstraction — the data-management layer of oneDAL.
//!
//! oneDAL's public API hands every algorithm a `NumericTable`; this
//! module provides the two layouts the paper's workloads use (dense
//! row-major, CSR sparse), CSV I/O, and the synthetic dataset generators
//! standing in for the paper's benchmark data (scikit-learn_bench grids,
//! DataPerf speech embeddings, TPC-AI segmentation, Kaggle fraud).

pub mod csv;
pub mod dense;
pub mod synth;

pub use dense::DenseTable;
