//! Lane-profile ablation (ISSUE 10): the same predicated kernels
//! monomorphized at each SVE vector length the runtime dispatcher can
//! resolve — 128-bit (2 × f64), 256-bit (4 × f64), 512-bit (8 × f64).
//!
//! Two questions, per hot kernel:
//!
//! * **width scaling** — how much the wider block buys on this host.
//!   On a scalar-ILP machine the "lanes" are unrolled loop blocks, so
//!   the sweep measures unroll-depth + panel-geometry effects (`NR`,
//!   `KC`, `TILE` all derive from the profile); on real SVE silicon
//!   the same sweep would measure hardware vector-length scaling.
//! * **fidelity across widths** — discrete outputs (argmin winners,
//!   top-k sets, ε-membership, WSS picks) must be identical at every
//!   profile; the gate runs before any timing so a divergence fails
//!   loudly rather than polluting the numbers.
//!
//! Results land in `BENCH_lanes.json` (repo root when run from
//! `rust/`, else the current directory) with the same "pending first
//! run" scaffold convention as the other ablation benches.

use onedal_sve::algorithms::svm::simd;
use onedal_sve::algorithms::svm::wss::{LOW, SIGN_ANY, SIGN_NEG, SIGN_POS, UP};
use onedal_sve::prelude::*;
use onedal_sve::primitives::distances;
use onedal_sve::primitives::lanes::LaneProfile;
use onedal_sve::profiling::{BenchResult, Bencher};
use onedal_sve::rng::{Distribution, Gaussian, Uniform};
use onedal_sve::tables::synth::make_blobs;

const N: usize = 4_096; // corpus rows
const M: usize = 1_024; // query rows
const D: usize = 32;
const K_CENT: usize = 16; // k-means centroids (argmin corpus)
const K_NN: usize = 10; // top-k neighbours
const EPS2: f64 = 16.0;
const WSS_N: usize = 100_000; // WSS scan length
const THREADS: usize = 4;

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Hand-rolled JSON dump (no serde in the offline image).
fn write_json(results: &[BenchResult]) -> std::io::Result<String> {
    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_lanes.json"
    } else {
        "BENCH_lanes.json"
    };
    let mut rows = Vec::new();
    for r in results {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"median_ms\": {:.4}, \"mean_ms\": {:.4}, \"samples\": {}}}",
            json_escape(&r.name),
            r.median.as_secs_f64() * 1e3,
            r.mean.as_secs_f64() * 1e3,
            r.samples
        ));
    }
    let med =
        |name: &str| results.iter().find(|r| r.name == name).map(|r| r.median.as_secs_f64());
    let mut speedups = Vec::new();
    for kernel in ["argmin", "topk", "eps", "wss-extrema", "wssj"] {
        if let (Some(narrow), Some(wide)) =
            (med(&format!("{kernel}/sve128")), med(&format!("{kernel}/sve512")))
        {
            speedups.push(format!(
                "    {{\"case\": \"{kernel}/sve512-vs-sve128\", \"speedup\": {:.3}}}",
                narrow / wide
            ));
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"ablate_lanes\",\n  \"results\": [\n{}\n  ],\n  \"speedups\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
        speedups.join(",\n")
    );
    std::fs::write(path, json)?;
    Ok(path.to_string())
}

fn main() {
    let mut e = Mt19937::new(10);
    let (x, _) = make_blobs(&mut e, N, D, K_CENT, 1.0);
    let (c, _) = make_blobs(&mut e, K_CENT, D, K_CENT, 1.0);
    let q = &x.data()[..M * D];

    // WSS fixture — same shape as the Fig. 4 microbenchmark.
    let mut g = Gaussian::<f64>::standard();
    let mut u = Uniform::<f64>::new(0.0, 1.0);
    let grad: Vec<f64> = (0..WSS_N).map(|_| g.sample(&mut e)).collect();
    let flags: Vec<u8> = (0..WSS_N)
        .map(|_| {
            let mut f = if u.sample(&mut e) < 0.5 { SIGN_POS } else { SIGN_NEG };
            if u.sample(&mut e) < 0.7 {
                f |= LOW;
            }
            if u.sample(&mut e) < 0.7 {
                f |= UP;
            }
            f
        })
        .collect();
    let diag: Vec<f64> = (0..WSS_N).map(|_| 1.0 + u.sample(&mut e)).collect();
    let ki: Vec<f64> = (0..WSS_N).map(|_| 0.5 * g.sample(&mut e)).collect();

    // ---- fidelity gate: discrete outputs identical at every width ----
    let base_corpus = distances::pack_corpus_table_profile(&c, LaneProfile::Sve512, THREADS);
    let base_knn = distances::pack_corpus_table_profile(&x, LaneProfile::Sve512, THREADS);
    let mut base_assign = vec![0usize; M];
    distances::argmin_assign(q, M, &base_corpus, true, &mut base_assign, THREADS);
    let base_topk = distances::top_k(q, M, &base_knn, K_NN, THREADS);
    let base_eps = distances::eps_neighbors(q, M, &base_knn, EPS2, false, THREADS);
    let base_ex = simd::wss_extrema_par(LaneProfile::Sve512, &grad, &flags, THREADS);
    let base_j = simd::wss_j_par(
        LaneProfile::Sve512,
        &grad,
        &flags,
        SIGN_ANY,
        LOW,
        base_ex.gmin,
        1.5,
        &diag,
        &ki,
        1e-12,
        true,
        THREADS,
    );
    for profile in LaneProfile::ALL {
        let corpus = distances::pack_corpus_table_profile(&c, profile, THREADS);
        let knn = distances::pack_corpus_table_profile(&x, profile, THREADS);
        let mut assign = vec![0usize; M];
        distances::argmin_assign(q, M, &corpus, true, &mut assign, THREADS);
        assert_eq!(assign, base_assign, "{}: argmin winners diverged", profile.name());
        let topk = distances::top_k(q, M, &knn, K_NN, THREADS);
        for (a, b) in topk.iter().zip(&base_topk) {
            let ia: Vec<usize> = a.iter().map(|p| p.0).collect();
            let ib: Vec<usize> = b.iter().map(|p| p.0).collect();
            assert_eq!(ia, ib, "{}: top-k sets diverged", profile.name());
        }
        let eps = distances::eps_neighbors(q, M, &knn, EPS2, false, THREADS);
        assert_eq!(eps.to_lists(), base_eps.to_lists(), "{}: ε-membership diverged", profile.name());
        let ex = simd::wss_extrema_par(profile, &grad, &flags, THREADS);
        assert_eq!(ex.bi, base_ex.bi, "{}: WSSi pick diverged", profile.name());
        let j = simd::wss_j_par(
            profile, &grad, &flags, SIGN_ANY, LOW, base_ex.gmin, 1.5, &diag, &ki, 1e-12,
            true, THREADS,
        );
        assert_eq!(j.bj, base_j.bj, "{}: WSSj pick diverged", profile.name());
    }
    println!("fidelity gate: discrete outputs identical across all three profiles\n");

    // ---- width sweep ----
    let mut b = Bencher::new(200, 9);
    for profile in LaneProfile::ALL {
        let name = profile.name();
        let corpus = distances::pack_corpus_table_profile(&c, profile, THREADS);
        let knn = distances::pack_corpus_table_profile(&x, profile, THREADS);
        let mut assign = vec![0usize; M];
        b.bench(&format!("argmin/{name}"), || {
            let inertia = distances::argmin_assign(q, M, &corpus, true, &mut assign, THREADS);
            std::hint::black_box(inertia);
        });
        b.bench(&format!("topk/{name}"), || {
            let nn = distances::top_k(q, M, &knn, K_NN, THREADS);
            std::hint::black_box(nn.len());
        });
        b.bench(&format!("eps/{name}"), || {
            let nt = distances::eps_neighbors(q, M, &knn, EPS2, false, THREADS);
            std::hint::black_box(nt.indices().len());
        });
        b.bench(&format!("wss-extrema/{name}"), || {
            let ex = simd::wss_extrema_par(profile, &grad, &flags, THREADS);
            std::hint::black_box(ex.gmin);
        });
        b.bench(&format!("wssj/{name}"), || {
            let j = simd::wss_j_par(
                profile, &grad, &flags, SIGN_ANY, LOW, base_ex.gmin, 1.5, &diag, &ki,
                1e-12, true, THREADS,
            );
            std::hint::black_box(j.obj);
        });
    }

    b.speedup_table("Lane-width scaling (vs the 128-bit profile)", "sve128");
    match write_json(b.results()) {
        Ok(path) => println!("\nrecorded: {path}"),
        Err(err) => eprintln!("\nfailed to write BENCH_lanes.json: {err}"),
    }
}
