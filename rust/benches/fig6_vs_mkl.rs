//! Fig. 6 — "ARM SVE optimized oneDAL vs. x86 oneDAL (MKL)":
//! the optimized rung against the well-optimized incumbent (reference
//! rung = blocked native BLAS, the MKL stand-in), plus the artifact rung
//! when available.
//!
//! Paper shape: training up to 2.75× (KMeans), DBSCAN 1.92×, KNN ≤1.5×,
//! inference parity to 1.83×, SVM/forest ≈ parity.

use onedal_sve::algorithms::svm::kernel::SvmKernel;
use onedal_sve::coordinator::{Backend, Context};
use onedal_sve::prelude::*;
use onedal_sve::profiling::Bencher;
use onedal_sve::tables::synth;

fn main() {
    let reference = Context::with_backend(Backend::Reference).unwrap();
    let opt = Context::with_backend(Backend::Vectorized).unwrap();
    let artifact = if std::path::Path::new("artifacts/manifest.txt").exists() {
        Context::with_backend(Backend::Artifact).ok()
    } else {
        None
    };
    let mut rungs: Vec<(&Context, &str)> =
        vec![(&reference, "mkl-analogue"), (&opt, "sve-optimized")];
    if let Some(a) = artifact.as_ref() {
        rungs.push((a, "aot-artifact"));
    }
    let mut e = Mt19937::new(6);
    let mut b = Bencher::new(200, 7);

    // KMeans (paper: 2.75×)
    let (xk, _) = synth::make_blobs(&mut e, 30_000, 20, 10, 1.0);
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/kmeans-train/{rung}"), || {
            let m = KMeans::params().k(10).seed(1).max_iter(15).train(ctx, &xk).unwrap();
            std::hint::black_box(m.inertia);
        });
    }

    // DBSCAN (paper: 1.92×)
    let (xd, _) = synth::make_blobs(&mut e, 4_000, 8, 10, 0.8);
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/dbscan-train/{rung}"), || {
            let m = Dbscan::params().eps(2.0).min_pts(5).train(ctx, &xd).unwrap();
            std::hint::black_box(m.n_clusters);
        });
    }

    // KNN (paper: ≤1.5×)
    let (xn, labels) = synth::make_blobs(&mut e, 10_000, 16, 5, 1.5);
    let yn: Vec<f64> = labels.iter().map(|&c| c as f64).collect();
    let knn = KnnClassifier::params().k(5).train(&opt, &xn, &yn).unwrap();
    let (q, _) = synth::make_blobs(&mut e, 500, 16, 5, 1.5);
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/knn-infer/{rung}"), || {
            std::hint::black_box(knn.infer(ctx, &q).unwrap());
        });
    }

    // Logistic + linear regression inference (paper: up to 1.83×)
    let (xl, yl) = synth::make_classification(&mut e, 50_000, 64, 1.5);
    let lr = LogisticRegression::params().epochs(2).train(&opt, &xl, &yl).unwrap();
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/logreg-infer/{rung}"), || {
            std::hint::black_box(lr.infer(ctx, &xl).unwrap());
        });
    }
    let (xr, yr, _) = synth::make_regression(&mut e, 100_000, 20, 0.1);
    let lin = LinearRegression::params().train(&opt, &xr, &yr).unwrap();
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/linreg-infer/{rung}"), || {
            std::hint::black_box(lin.infer(ctx, &xr).unwrap());
        });
    }

    // SVM + forest (paper: comparable)
    let (xs, ys) = synth::make_classification(&mut e, 2_000, 40, 1.0);
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/svm-train/{rung}"), || {
            let m = Svc::params()
                .kernel(SvmKernel::Rbf { gamma: 0.025 })
                .train(ctx, &xs, &ys)
                .unwrap();
            std::hint::black_box(m.n_support());
        });
    }
    for (ctx, rung) in &rungs {
        b.bench(&format!("fig6/forest-train/{rung}"), || {
            let m = RandomForestClassifier::params()
                .n_trees(8)
                .max_depth(8)
                .sample_frac(0.3)
                .train(ctx, &xs, &ys)
                .unwrap();
            std::hint::black_box(m.n_trees());
        });
    }

    b.speedup_table("Fig. 6: vs the MKL-analogue reference backend", "mkl-analogue");
}
