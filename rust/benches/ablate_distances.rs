//! Distance-engine ablation (ISSUE 4): what the fused, pooled,
//! pack-once `primitives::distances` engine buys over the per-algorithm
//! legacy expansions it replaced —
//!
//! * **fused vs legacy** at 1 worker: the pack-once + cache-hot-epilogue
//!   win (the legacy KNN/DBSCAN paths re-packed the corpus for every
//!   query tile and never touched the worker pool);
//! * **1 vs 2 vs 4 workers** on the fused engine: the pooled-scaling
//!   win for the two previously sequential consumers (KNN, DBSCAN) and
//!   the already-parallel ones (k-means assign, RBF gram).
//!
//! Results land in `BENCH_distances.json` (repo root when run from
//! `rust/`, else the current directory) with the same "pending first
//! run" scaffold convention as `BENCH_blas.json` / `BENCH_svm.json`.

use onedal_sve::blas::{dot, gemm_prepacked_threads, gemm_threads, pack_b_panels, Transpose};
use onedal_sve::prelude::*;
use onedal_sve::primitives::distances;
use onedal_sve::profiling::{BenchResult, Bencher};
use onedal_sve::tables::synth::make_blobs;
use std::io::Write as _;

const N: usize = 4_096; // corpus rows
const M: usize = 1_024; // query rows
const D: usize = 32;
const K_CENT: usize = 16; // k-means centroids
const K_NN: usize = 10; // KNN neighbours
const WS: usize = 64; // RBF working-set rows
const EPS2: f64 = 16.0;
const THREADS: [usize; 3] = [1, 2, 4];

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Hand-rolled JSON dump (no serde in the offline image).
fn write_json(results: &[BenchResult]) -> std::io::Result<String> {
    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_distances.json"
    } else {
        "BENCH_distances.json"
    };
    let mut rows = Vec::new();
    for r in results {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"median_ms\": {:.4}, \"mean_ms\": {:.4}, \"samples\": {}}}",
            json_escape(&r.name),
            r.median.as_secs_f64() * 1e3,
            r.mean.as_secs_f64() * 1e3,
            r.samples
        ));
    }
    let med =
        |name: &str| results.iter().find(|r| r.name == name).map(|r| r.median.as_secs_f64());
    let mut speedups = Vec::new();
    for algo in ["kmeans-assign", "knn-kneighbors", "dbscan-neigh", "rbf-gram"] {
        let legacy = med(&format!("{algo}/legacy"));
        if let (Some(l), Some(f)) = (legacy, med(&format!("{algo}/t1"))) {
            speedups.push(format!(
                "    {{\"case\": \"{algo}/fused-vs-legacy\", \"speedup\": {:.3}}}",
                l / f
            ));
        }
        if let (Some(t1), Some(t4)) =
            (med(&format!("{algo}/t1")), med(&format!("{algo}/t4")))
        {
            speedups.push(format!(
                "    {{\"case\": \"{algo}/scaling-1-to-4\", \"speedup\": {:.3}}}",
                t1 / t4
            ));
        }
    }
    let body = format!(
        "{{\n  \"bench\": \"ablate_distances\",\n  \
         \"regenerate\": \"cd rust && cargo bench --bench ablate_distances\",\n  \
         \"fixtures\": {{\"corpus\": \"{N}x{D} blobs\", \"queries\": \"{M}x{D}\", \
         \"kmeans_k\": {K_CENT}, \"knn_k\": {K_NN}, \"rbf_ws\": {WS}}},\n  \
         \"results\": [\n{}\n  ],\n  \"speedups\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
        speedups.join(",\n"),
    );
    let mut f = std::fs::File::create(path)?;
    f.write_all(body.as_bytes())?;
    Ok(path.to_string())
}

/// Legacy k-means assignment: per-256-tile cross-term GEMM that
/// re-packs the centroid operand every tile, scalar argmin epilogue —
/// the pre-engine `assign_gemm` at one worker.
fn legacy_assign(x: &DenseTable<f64>, c: &DenseTable<f64>, assign: &mut [usize]) -> f64 {
    let (n, d, k) = (x.rows(), x.cols(), c.rows());
    let cnorm: Vec<f64> = (0..k).map(|j| dot(c.row(j), c.row(j))).collect();
    const TILE: usize = 256;
    let mut cross = vec![0.0f64; TILE * k];
    let mut inertia = 0.0f64;
    let mut start = 0usize;
    while start < n {
        let len = TILE.min(n - start);
        let xb = &x.data()[start * d..(start + len) * d];
        gemm_threads(
            Transpose::No,
            Transpose::Yes,
            len,
            k,
            d,
            1.0,
            xb,
            c.data(),
            0.0,
            &mut cross[..len * k],
            1,
        );
        for i in 0..len {
            let xi = &x.data()[(start + i) * d..(start + i + 1) * d];
            let xn = dot(xi, xi);
            let row = &cross[i * k..(i + 1) * k];
            let (mut best, mut bestv) = (0usize, f64::INFINITY);
            for (j, &xc) in row.iter().enumerate() {
                let dist = xn - 2.0 * xc + cnorm[j];
                if dist < bestv {
                    bestv = dist;
                    best = j;
                }
            }
            assign[start + i] = best;
            inertia += bestv.max(0.0);
        }
        start += len;
    }
    inertia
}

/// Legacy KNN: per-128-tile GEMM re-packing the full corpus each tile
/// (the pre-engine `kneighbors_tiled`), sequential.
fn legacy_kneighbors(
    x: &DenseTable<f64>,
    q: &DenseTable<f64>,
    k: usize,
) -> Vec<Vec<(usize, f64)>> {
    let (n, d, m) = (x.rows(), x.cols(), q.rows());
    let xnorm: Vec<f64> = (0..n).map(|j| dot(x.row(j), x.row(j))).collect();
    const TILE: usize = 128;
    let mut cross = vec![0.0f64; TILE * n];
    let mut out = vec![Vec::new(); m];
    let mut start = 0usize;
    while start < m {
        let len = TILE.min(m - start);
        let qb = &q.data()[start * d..(start + len) * d];
        gemm_threads(
            Transpose::No,
            Transpose::Yes,
            len,
            n,
            d,
            1.0,
            qb,
            x.data(),
            0.0,
            &mut cross[..len * n],
            1,
        );
        for i in 0..len {
            let qi = &q.data()[(start + i) * d..(start + i + 1) * d];
            let qn = dot(qi, qi);
            let row = &cross[i * n..(i + 1) * n];
            let mut best: Vec<(usize, f64)> = Vec::with_capacity(k + 1);
            let mut worst = f64::INFINITY;
            for (j, &xc) in row.iter().enumerate() {
                let dist = (qn - 2.0 * xc + xnorm[j]).max(0.0);
                if dist < worst || best.len() < k {
                    let pos = best.partition_point(|&(_, v)| v <= dist);
                    best.insert(pos, (j, dist));
                    if best.len() > k {
                        best.pop();
                    }
                    worst = best.last().unwrap().1;
                }
            }
            out[start + i] = best;
        }
        start += len;
    }
    out
}

/// Legacy DBSCAN region query: per-256-tile GEMM re-packing the corpus
/// each tile (the pre-engine `neighbours_tiled`), sequential.
fn legacy_neighbours(x: &DenseTable<f64>, eps2: f64) -> Vec<Vec<usize>> {
    let (n, d) = (x.rows(), x.cols());
    let norms: Vec<f64> = (0..n).map(|i| dot(x.row(i), x.row(i))).collect();
    const TILE: usize = 256;
    let mut cross = vec![0.0f64; TILE * n];
    let mut out = vec![Vec::new(); n];
    let mut start = 0usize;
    while start < n {
        let len = TILE.min(n - start);
        let xb = &x.data()[start * d..(start + len) * d];
        gemm_threads(
            Transpose::No,
            Transpose::Yes,
            len,
            n,
            d,
            1.0,
            xb,
            x.data(),
            0.0,
            &mut cross[..len * n],
            1,
        );
        for i in 0..len {
            let gi = start + i;
            let row = &cross[i * n..(i + 1) * n];
            let ni = norms[gi];
            let list = &mut out[gi];
            for (j, &xc) in row.iter().enumerate() {
                if ni - 2.0 * xc + norms[j] <= eps2 && j != gi {
                    list.push(j);
                }
            }
        }
        start += len;
    }
    out
}

fn main() {
    let mut e = Mt19937::new(90);
    let (x, _) = make_blobs(&mut e, N, D, 8, 2.0);
    let (q, _) = make_blobs(&mut e, M, D, 8, 2.0);
    let (cent, _) = make_blobs(&mut e, K_CENT, D, 8, 2.0);
    let mut b = Bencher::new(300, 7);

    // --- k-means assignment ---
    let mut assign = vec![0usize; N];
    b.bench("kmeans-assign/legacy", || {
        std::hint::black_box(legacy_assign(&x, &cent, &mut assign));
    });
    for t in THREADS {
        b.bench(&format!("kmeans-assign/t{t}"), || {
            let corpus = distances::pack_corpus_table(&cent, t);
            let inertia = distances::argmin_assign(x.data(), N, &corpus, true, &mut assign, t);
            std::hint::black_box(inertia);
        });
    }

    // --- KNN kneighbors ---
    b.bench("knn-kneighbors/legacy", || {
        std::hint::black_box(legacy_kneighbors(&x, &q, K_NN).len());
    });
    for t in THREADS {
        b.bench(&format!("knn-kneighbors/t{t}"), || {
            let corpus = distances::pack_corpus_table(&x, t);
            std::hint::black_box(distances::top_k(q.data(), M, &corpus, K_NN, t).len());
        });
    }

    // --- DBSCAN neighbour lists ---
    b.bench("dbscan-neigh/legacy", || {
        std::hint::black_box(legacy_neighbours(&x, EPS2).len());
    });
    for t in THREADS {
        b.bench(&format!("dbscan-neigh/t{t}"), || {
            let corpus = distances::pack_corpus_table(&x, t);
            let lists = distances::eps_neighbors(x.data(), N, &corpus, EPS2, true, t);
            std::hint::black_box(lists.rows());
        });
    }

    // --- RBF gram tile (64-row working set × full corpus) ---
    let norms: Vec<f64> = (0..N).map(|i| dot(x.row(i), x.row(i))).collect();
    let ws_rows: Vec<usize> = (0..WS).map(|i| (i * 37) % N).collect();
    let mut w = vec![0.0f64; WS * D];
    let mut wn = vec![0.0f64; WS];
    for (r, &g) in ws_rows.iter().enumerate() {
        w[r * D..(r + 1) * D].copy_from_slice(x.row(g));
        wn[r] = norms[g];
    }
    let pb = pack_b_panels(Transpose::Yes, D, N, x.data());
    let gamma = 0.05f64;
    let mut tile = vec![0.0f64; WS * N];
    // Legacy: cross-term GEMM then a *separate* transform pass (the
    // unfused PR 3 structure), at one worker.
    b.bench("rbf-gram/legacy", || {
        gemm_prepacked_threads(Transpose::No, WS, 1.0, &w, &pb, 0.0, &mut tile, 1);
        for (r, row) in tile.chunks_mut(N).enumerate() {
            let ni = wn[r];
            for (v, &nj) in row.iter_mut().zip(&norms) {
                let d2 = (ni - 2.0 * *v + nj).max(0.0);
                *v = (-gamma * d2).exp();
            }
        }
        std::hint::black_box(tile[0]);
    });
    for t in THREADS {
        b.bench(&format!("rbf-gram/t{t}"), || {
            distances::rbf_gram(&w, &wn, &norms, &pb, gamma, &mut tile, t);
            std::hint::black_box(tile[0]);
        });
    }

    b.speedup_table("distance-engine ablation", "legacy");
    match write_json(b.results()) {
        Ok(path) => println!("\nrecorded: {path}"),
        Err(err) => eprintln!("\nfailed to write BENCH_distances.json: {err}"),
    }
}
