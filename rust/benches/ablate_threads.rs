//! Threading ablation — the multicore half of the paper's OpenBLAS
//! story (§IV, Fig. 6): GEMM, SYRK and the k-means assignment pass swept
//! over 1/2/4/all worker threads to document scaling of the packed-panel
//! engine. Acceptance bar: ≥ 2× GEMM speedup at 4 threads vs 1 on
//! 512³ f64.
//!
//! PR 2 additions: (a) launch-overhead comparison of the persistent
//! worker pool vs the retired per-call `std::thread::scope` baseline on
//! a near-empty 4-way fan-out (pure scheduling cost, no compute), and
//! (b) a KC-blocked large-`k` GEMM case (256×256×4096) where full-`k`
//! panels fall out of L2.
//!
//! Besides the usual stdout table, the run is recorded as
//! `BENCH_blas.json` (written to the repo root when run from `rust/`,
//! else the current directory).

use onedal_sve::blas::{gemm_threads, syrk_threads, Transpose};
use onedal_sve::coordinator::{Backend, Context};
use onedal_sve::parallel::{even_bounds, scope_rows, scope_rows_scoped};
use onedal_sve::prelude::*;
use onedal_sve::profiling::{BenchResult, Bencher};
use onedal_sve::rng::{Distribution, Uniform};
use onedal_sve::tables::synth;
use std::io::Write as _;

const DIM: usize = 512;
/// Large-k fixture: m = n = 256, k = 4096 (16 KC blocks of 256).
const KDIM: usize = 4096;
const KM: usize = 256;

fn rand_mat(e: &mut Mt19937, n: usize) -> Vec<f64> {
    let mut d = Uniform::new(-1.0, 1.0);
    (0..n).map(|_| d.sample(e)).collect()
}

fn thread_sweep() -> Vec<usize> {
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut sweep = vec![1usize, 2, 4, avail];
    sweep.sort_unstable();
    sweep.dedup();
    sweep.retain(|&t| t <= avail.max(4));
    sweep
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Hand-rolled JSON dump (no serde in the offline image): flat result
/// rows plus per-case speedup-vs-1-thread entries.
fn write_json(results: &[BenchResult]) -> std::io::Result<String> {
    let path = if std::path::Path::new("../CHANGES.md").exists() {
        "../BENCH_blas.json"
    } else {
        "BENCH_blas.json"
    };
    let mut rows = Vec::new();
    for r in results {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"median_ms\": {:.4}, \"mean_ms\": {:.4}, \"samples\": {}}}",
            json_escape(&r.name),
            r.median.as_secs_f64() * 1e3,
            r.mean.as_secs_f64() * 1e3,
            r.samples
        ));
    }
    let mut speedups = Vec::new();
    for r in results {
        let Some(slash) = r.name.rfind('/') else { continue };
        let (case, variant) = (&r.name[..slash], &r.name[slash + 1..]);
        if variant == "t1" {
            continue;
        }
        let base = format!("{case}/t1");
        if let Some(b) = results.iter().find(|b| b.name == base) {
            speedups.push(format!(
                "    {{\"case\": \"{}\", \"variant\": \"{}\", \"speedup_vs_t1\": {:.3}}}",
                json_escape(case),
                json_escape(variant),
                b.median.as_secs_f64() / r.median.as_secs_f64()
            ));
        }
    }
    let body = format!(
        "{{\n  \"bench\": \"ablate_threads\",\n  \"regenerate\": \"cd rust && cargo bench --bench ablate_threads\",\n  \"fixtures\": {{\"gemm\": \"{DIM}x{DIM}x{DIM} f64\", \"gemm_large_k\": \"{KM}x{KM}x{KDIM} f64 (KC-blocked)\", \"syrk\": \"{DIM}x{DIM} f64\", \"kmeans_assign\": \"20000x16, k=16\", \"launch\": \"4-way near-empty fan-out, pool vs scoped\"}},\n  \"results\": [\n{}\n  ],\n  \"speedups\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
        speedups.join(",\n"),
    );
    let mut f = std::fs::File::create(path)?;
    f.write_all(body.as_bytes())?;
    Ok(path.to_string())
}

fn main() {
    let sweep = thread_sweep();
    println!("threads sweep: {sweep:?}\n");
    let mut e = Mt19937::new(14);
    let mut b = Bencher::new(300, 7);

    // GEMM 512^3 f64 — the acceptance case.
    let a = rand_mat(&mut e, DIM * DIM);
    let bm = rand_mat(&mut e, DIM * DIM);
    let mut c = vec![0.0f64; DIM * DIM];
    for &t in &sweep {
        b.bench(&format!("blas/gemm-{DIM}/t{t}"), || {
            gemm_threads(
                Transpose::No,
                Transpose::No,
                DIM,
                DIM,
                DIM,
                1.0,
                &a,
                &bm,
                0.0,
                &mut c,
                t,
            );
            std::hint::black_box(c[0]);
        });
    }

    // Launch overhead: a 4-way fan-out over a tiny buffer with a
    // near-empty closure — pure scheduling cost. `pool` rides the
    // persistent workers; `scoped` is the retired per-call
    // std::thread::scope baseline.
    let launch_bounds = even_bounds(4, 4);
    let mut tiny = vec![0.0f64; 4 * 64];
    b.bench("parallel/launch-4way/pool", || {
        let partials = scope_rows(&mut tiny, 64, &launch_bounds, |_, _, block| block[0]);
        std::hint::black_box(partials);
    });
    b.bench("parallel/launch-4way/scoped", || {
        let partials = scope_rows_scoped(&mut tiny, 64, &launch_bounds, |_, _, block| block[0]);
        std::hint::black_box(partials);
    });

    // KC-blocked large-k GEMM: full-k packed panels stop fitting L2 at
    // this size, so this case isolates the k-block sweep.
    let ak = rand_mat(&mut e, KM * KDIM);
    let bk = rand_mat(&mut e, KDIM * KM);
    let mut ck = vec![0.0f64; KM * KM];
    for &t in &sweep {
        b.bench(&format!("blas/gemm-{KM}x{KM}x{KDIM}/t{t}"), || {
            let (no, kd) = (Transpose::No, KDIM);
            gemm_threads(no, no, KM, KM, kd, 1.0, &ak, &bk, 0.0, &mut ck, t);
            std::hint::black_box(ck[0]);
        });
    }

    // SYRK m=k=512 — the covariance/linreg/PCA workhorse.
    let mut cs = vec![0.0f64; DIM * DIM];
    for &t in &sweep {
        b.bench(&format!("blas/syrk-{DIM}/t{t}"), || {
            syrk_threads(DIM, DIM, 1.0, &a, 0.0, &mut cs, t);
            std::hint::black_box(cs[0]);
        });
    }

    // K-means assignment pass (the gemm-expansion rung) through the
    // Context::threads() wiring.
    let (x, _) = synth::make_blobs(&mut e, 20_000, 16, 16, 1.0);
    let train_ctx = Context::builder()
        .artifact_dir("/nonexistent")
        .backend(Backend::Vectorized)
        .build()
        .unwrap();
    let model = KMeans::params().k(16).seed(3).max_iter(10).train(&train_ctx, &x).unwrap();
    for &t in &sweep {
        let ctx = Context::builder()
            .artifact_dir("/nonexistent")
            .backend(Backend::Vectorized)
            .threads(t)
            .build()
            .unwrap();
        b.bench(&format!("kmeans/assign-20k/t{t}"), || {
            std::hint::black_box(model.infer(&ctx, &x).unwrap());
        });
    }

    b.speedup_table("thread scaling", "t1");
    match write_json(b.results()) {
        Ok(path) => println!("\nrecorded: {path}"),
        Err(err) => eprintln!("\nfailed to write BENCH_blas.json: {err}"),
    }

    // Make the acceptance bar visible in the output.
    let med = |name: &str| {
        b.results().iter().find(|r| r.name == name).map(|r| r.median.as_secs_f64())
    };
    let (t1, t4) = (med(&format!("blas/gemm-{DIM}/t1")), med(&format!("blas/gemm-{DIM}/t4")));
    if let (Some(t1), Some(t4)) = (t1, t4) {
        let s = t1 / t4;
        println!("gemm-{DIM} 4-thread speedup: {s:.2}x (target ≥ 2x)");
    }
}
