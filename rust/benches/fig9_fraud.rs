//! Fig. 9 — credit-card fraud detection: random-forest and
//! logistic-regression training, optimized rung vs the stock-sklearn
//! analogue (paper: 31× and 40× on Graviton3; our single-core Rust
//! baseline is far stronger than interpreted sklearn, so expect the
//! same ordering at smaller magnitude — EXPERIMENTS.md discusses).

use onedal_sve::coordinator::{Backend, Context};
use onedal_sve::prelude::*;
use onedal_sve::profiling::Bencher;
use onedal_sve::tables::synth;

fn main() {
    let naive = Context::builder().backend(Backend::Naive).threads(1).build().unwrap();
    let opt = Context::with_backend(Backend::Vectorized).unwrap();
    let mut e = Mt19937::new(9);
    let (x, y) = synth::make_fraud(&mut e, 60_000, 30, 200);
    let mut b = Bencher::new(300, 5);

    for (ctx, rung) in [(&naive, "sklearn-arm"), (&opt, "arm-sve")] {
        b.bench(&format!("fig9/logreg-train/{rung}"), || {
            let m = LogisticRegression::params().epochs(5).lr(0.3).train(ctx, &x, &y).unwrap();
            std::hint::black_box(m.intercept);
        });
    }
    for (ctx, rung) in [(&naive, "sklearn-arm"), (&opt, "arm-sve")] {
        b.bench(&format!("fig9/forest-train/{rung}"), || {
            let m = RandomForestClassifier::params()
                .n_trees(10)
                .max_depth(10)
                .sample_frac(0.2)
                .train(ctx, &x, &y)
                .unwrap();
            std::hint::black_box(m.n_trees());
        });
    }

    b.speedup_table("Fig. 9: fraud detection", "sklearn-arm");
    println!("\nPaper shape: logreg 40×, forest 31× over interpreted sklearn-on-ARM.");
}
