//! §IV-B ablation — the Sparse BLAS substrate: csrmm / csrmv / csrmultd
//! against dense gemm/gemv across a density sweep, plus the AᵀB vs AB
//! loop-order comparison the paper analyzes.
//!
//! The paper's claim: the reference sparse routines "do not yet match
//! MKL" but win over dense once sparsity is high enough — the crossover
//! is what this bench locates.

use onedal_sve::blas::{gemm, gemv, Transpose};
use onedal_sve::prelude::*;
use onedal_sve::profiling::Bencher;
use onedal_sve::sparse::{csrmm, csrmultd, csrmv, SparseOp};
use onedal_sve::tables::synth;

fn main() {
    let mut e = Mt19937::new(10);
    let mut b = Bencher::new(200, 9);
    let (m, k, n) = (2_000usize, 1_000usize, 32usize);

    for density in [0.01, 0.05, 0.2] {
        let a = synth::make_sparse_csr(&mut e, m, k, density);
        let ad = a.to_dense();
        let bm: Vec<f64> = (0..k * n).map(|i| (i % 17) as f64 * 0.1).collect();
        let tag = format!("d{:03}", (density * 100.0) as u32);

        // csrmm vs dense gemm
        let mut c = vec![0.0f64; m * n];
        b.bench(&format!("sparse/csrmm-{tag}/sparse"), || {
            csrmm(SparseOp::NoTranspose, 1.0, &a, &bm, n, 0.0, &mut c).unwrap();
            std::hint::black_box(c[0]);
        });
        b.bench(&format!("sparse/csrmm-{tag}/dense"), || {
            gemm(Transpose::No, Transpose::No, m, n, k, 1.0, ad.data(), &bm, 0.0, &mut c);
            std::hint::black_box(c[0]);
        });

        // csrmv vs dense gemv
        let xv: Vec<f64> = (0..k).map(|i| (i as f64).cos()).collect();
        let mut yv = vec![0.0f64; m];
        b.bench(&format!("sparse/csrmv-{tag}/sparse"), || {
            csrmv(SparseOp::NoTranspose, 1.0, &a, &xv, 0.0, &mut yv).unwrap();
            std::hint::black_box(yv[0]);
        });
        b.bench(&format!("sparse/csrmv-{tag}/dense"), || {
            gemv(false, m, k, 1.0, ad.data(), &xv, 0.0, &mut yv);
            std::hint::black_box(yv[0]);
        });
    }

    // csrmultd loop orders: AB (j-k-i) vs AᵀB (i-j-k) at fixed density.
    let a = synth::make_sparse_csr(&mut e, 800, 800, 0.05);
    let bs = synth::make_sparse_csr(&mut e, 800, 200, 0.05);
    let mut c = vec![0.0f64; 800 * 200];
    b.bench("sparse/csrmultd/ab-jki", || {
        csrmultd(SparseOp::NoTranspose, &a, &bs, &mut c).unwrap();
        std::hint::black_box(c[0]);
    });
    b.bench("sparse/csrmultd/atb-ijk", || {
        csrmultd(SparseOp::Transpose, &a, &bs, &mut c).unwrap();
        std::hint::black_box(c[0]);
    });

    b.speedup_table("Sparse substrate vs dense (crossover sweep)", "dense");
}
